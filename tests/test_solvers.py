"""Krylov + Newton + batched-direct solver tests (SUNLinearSolver analogs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; property tests
from hypothesis import given, settings, strategies as st

from repro.core import direct, kinsol, krylov, matrix


def _make_system(n=24, cond=8.0, seed=0):
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (n, n)) + cond * jnp.eye(n)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    return A, b


@pytest.mark.parametrize("solver", ["gmres", "bicgstab", "tfqmr"])
def test_krylov_nonsymmetric(solver):
    A, b = _make_system()
    fn = getattr(krylov, solver)
    x, st = fn(lambda v: A @ v, b, tol=1e-10, maxiter=300) \
        if solver != "gmres" else fn(lambda v: A @ v, b, tol=1e-10)
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-7
    assert bool(st.converged)


def test_pcg_spd_and_preconditioner_helps():
    # badly scaled SPD system: Jacobi preconditioning must clearly win
    n = 40
    key = jax.random.PRNGKey(0)
    D = jnp.logspace(0, 4, n)                       # condition ~1e4
    Q = jax.random.normal(key, (n, n)) * 0.05
    S = jnp.diag(D) + Q @ Q.T
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    x, st0 = krylov.pcg(lambda v: S @ v, b, tol=1e-10, maxiter=2000)
    assert float(jnp.linalg.norm(S @ x - b)) < 1e-5
    dinv = 1.0 / jnp.diag(S)
    x, st1 = krylov.pcg(lambda v: S @ v, b, tol=1e-10, maxiter=2000,
                        precond=lambda v: dinv * v)
    assert float(jnp.linalg.norm(S @ x - b)) < 1e-5
    assert int(st1.iters) < int(st0.iters)


def test_gmres_right_preconditioning():
    A, b = _make_system(n=30)
    dinv = 1.0 / jnp.diag(A)
    x, st = krylov.gmres(lambda v: A @ v, b, tol=1e-10,
                         precond=lambda v: dinv * v)
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-7


def test_gmres_on_pytree_system():
    """Matrix-free solve where the 'vector' is a pytree (integrator use)."""
    key = jax.random.PRNGKey(3)
    A = jax.random.normal(key, (10, 10)) + 6 * jnp.eye(10)

    def matvec(tree):
        v = jnp.concatenate([tree["u"], tree["w"]])
        out = A @ v
        return {"u": out[:4], "w": out[4:]}

    b = {"u": jnp.ones((4,)), "w": jnp.full((6,), 2.0)}
    x, st = krylov.gmres(matvec, b, tol=1e-10)
    r = matvec(x)
    res = jnp.linalg.norm(jnp.concatenate([r["u"] - b["u"], r["w"] - b["w"]]))
    assert float(res) < 1e-7


def test_newton_quadratic_convergence():
    def g(z):
        return jnp.stack([z[0] ** 2 + z[1] ** 2 - 4.0, z[0] - z[1]])

    def lin_solve(z, rhs):
        J = jax.jacfwd(g)(z)
        return jnp.linalg.solve(J, rhs)

    z, st = kinsol.newton_solve(g, jnp.asarray([1.0, 2.0]), lin_solve,
                                tol=1e-12, max_iters=20)
    np.testing.assert_allclose(np.asarray(z), [np.sqrt(2), np.sqrt(2)],
                               rtol=1e-8)
    assert int(st.iters) <= 8


def test_anderson_beats_picard():
    # linear contraction with rate ~0.9: Picard needs ~200 iters for 1e-9
    M = 0.9 * jnp.eye(6) * jnp.asarray([1, .9, .8, .7, .6, .5])
    b = jnp.arange(6.0)
    g = lambda y: M @ y + b
    y, st = kinsol.fixed_point_solve(g, jnp.zeros((6,)), m=4, tol=1e-10,
                                     max_iters=60)
    y_exact = jnp.linalg.solve(jnp.eye(6) - M, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_exact), rtol=1e-6)
    assert bool(st.converged)
    assert int(st.iters) < 50


# ---------------------------------------------------------------------------
# batched block-diagonal direct solver (the submodel solver)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.integers(1, 6), st.integers(0, 1000))
def test_gauss_jordan_batched_property(nb, bsize, seed):
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (nb, bsize, bsize)) + \
        (bsize + 2.0) * jnp.eye(bsize)
    x_true = jax.random.normal(jax.random.PRNGKey(seed + 1), (nb, bsize))
    b = jnp.einsum("nij,nj->ni", A, x_true)
    x = direct.gauss_jordan_batched(A, b)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_true),
                               rtol=1e-6, atol=1e-8)


def test_gauss_jordan_pivoting_handles_zero_diagonal():
    A = jnp.asarray([[[0.0, 1.0], [1.0, 0.0]]])  # requires a row swap
    b = jnp.asarray([[2.0, 3.0]])
    x = direct.gauss_jordan_batched(A, b)
    np.testing.assert_allclose(np.asarray(x), [[3.0, 2.0]], rtol=1e-12)


def test_block_solve_vs_lu_path():
    key = jax.random.PRNGKey(1)
    A = jax.random.normal(key, (17, 5, 5)) + 7 * jnp.eye(5)
    b = jax.random.normal(jax.random.PRNGKey(2), (17, 5))
    m = matrix.BlockDiagMatrix(A)
    x1 = direct.block_solve(m, b)
    lu = direct.block_lu_factor(m)
    x2 = direct.block_lu_solve(lu, b, 5)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-8)


def test_blockdiag_matrix_ops():
    A = jnp.ones((3, 2, 2))
    m = matrix.BlockDiagMatrix(A)
    m2 = matrix.bd_scale_addi(-0.5, m)   # I - 0.5 A
    x = jnp.arange(6.0)
    y = matrix.bd_matvec(m2, x)
    # block [[0.5,-0.5],[-0.5,0.5]] applied per 2-block
    xb = x.reshape(3, 2)
    ref = jnp.stack([0.5 * xb[:, 0] - 0.5 * xb[:, 1],
                     -0.5 * xb[:, 0] + 0.5 * xb[:, 1]], axis=1).reshape(-1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref))


def test_blockdiag_shared_sparsity_mask():
    key = jax.random.PRNGKey(5)
    A = jax.random.normal(key, (4, 3, 3)) + 5 * jnp.eye(3)
    mask = jnp.asarray([[1., 1., 0.], [1., 1., 0.], [0., 0., 1.]])
    m = matrix.BlockDiagMatrix(A, mask=mask)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 3))
    y = matrix.bd_matvec(m, x)
    ref = jnp.einsum("nij,nj->ni", A * mask[None], x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref))
    # solve honors the mask too
    b = jnp.einsum("nij,nj->ni", A * mask[None], x)
    got = direct.block_solve(m, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-6)
