"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; property tests
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _tol(dt):
    return dict(rtol=2e-5, atol=2e-5) if dt == jnp.float32 else \
        dict(rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("nb", [1, 7, 128, 300, 513])
@pytest.mark.parametrize("b", [2, 3, 4, 8])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.float64])
def test_block_solve_sweep(nb, b, dt):
    key = jax.random.PRNGKey(nb * 131 + b)
    A = (jax.random.normal(key, (nb, b, b)) +
         (b + 3.0) * jnp.eye(b)).astype(dt)
    r = jax.random.normal(jax.random.PRNGKey(nb + b), (nb, b)).astype(dt)
    x = ops.block_solve(A, r, batch_tile=128)
    xr = ref.block_solve_ref(A, r)
    np.testing.assert_allclose(np.asarray(x), np.asarray(xr), **_tol(dt))


def test_block_solve_soa_layout_direct():
    key = jax.random.PRNGKey(0)
    b, NB = 3, 256
    A = jnp.transpose(jax.random.normal(key, (NB, b, b)) + 5 * jnp.eye(b),
                      (1, 2, 0))
    r = jax.random.normal(jax.random.PRNGKey(1), (b, NB))
    x = ops.block_solve_soa(A, r, batch_tile=128)
    xr = ref.block_solve_soa_ref(A, r)
    np.testing.assert_allclose(np.asarray(x), np.asarray(xr), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(1, 3000), st.integers(0, 100))
def test_linear_combination_property(K, N, seed):
    key = jax.random.PRNGKey(seed)
    c = jax.random.normal(key, (K,))
    X = jax.random.normal(jax.random.PRNGKey(seed + 1), (K, N))
    z = ops.linear_combination(c, X)
    zr = ref.linear_combination_ref(c, X)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("n", [1, 127, 128, 129, 5000, 128 * 64, 128 * 64 + 3])
def test_wrms_and_dot_padding_edges(n):
    key = jax.random.PRNGKey(n)
    x = jax.random.normal(key, (n,))
    w = jax.random.uniform(jax.random.PRNGKey(n + 1), (n,)) + 0.5
    y = jax.random.normal(jax.random.PRNGKey(n + 2), (n,))
    got = float(ops.wrms_norm(x, w))
    want = float(jnp.sqrt(jnp.mean((x * w) ** 2)))
    assert np.isclose(got, want, rtol=1e-6), (n, got, want)
    assert np.isclose(float(ops.dot(x, y)), float(jnp.vdot(x, y)),
                      rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("nb,b", [(64, 3), (200, 5), (1, 2), (515, 4)])
def test_blockdiag_spmv_sweep(nb, b):
    key = jax.random.PRNGKey(nb)
    A = jax.random.normal(key, (nb, b, b))
    x = jax.random.normal(jax.random.PRNGKey(nb + 1), (nb, b))
    y = ops.blockdiag_spmv(A, x)
    yr = jnp.einsum("nij,nj->ni", A, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5,
                               atol=1e-6)


def test_kernels_match_core_vector_semantics():
    """The fused kernels implement exactly the N_Vector ops they replace."""
    from repro.core import vector as nv
    vecs = [jax.random.normal(jax.random.PRNGKey(i), (777,))
            for i in range(3)]
    coeffs = jnp.asarray([0.3, -1.2, 2.5])
    fused = ops.linear_combination(coeffs, jnp.stack(vecs))
    core = nv.linear_combination(list(coeffs), vecs)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(core),
                               rtol=1e-6)
    w = jnp.abs(vecs[1]) + 0.1
    np.testing.assert_allclose(float(ops.wrms_norm(vecs[0], w)),
                               float(nv.wrms_norm(vecs[0], w)), rtol=1e-6)
