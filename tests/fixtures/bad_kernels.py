"""Deliberately-broken kernels and traces for the sunlint test suite.

``FIXTURES`` maps a fixture name to ``(expected_rule, setup)`` where
``setup(ctx)`` mutates a :class:`repro.analysis.lint.LintContext` so
that exactly the targeted invariant is violated.  The lint CLI seeds
one with ``--fixture <name>`` (expected exit status: 1), and
``tests/test_sunlint.py`` asserts each expected rule actually fires.

These are *negative controls*: if a rule rewrite stops flagging its
fixture, the rule has gone blind.
"""
import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis import lint
from repro.analysis.opcost import OpSig


# --- hot-loop-layout -------------------------------------------------------
# A transpose smuggled into the Newton-style while body through a
# lax.cond branch.  The retired source grep would never see it (the
# transpose lives in a helper lambda, and conversely the grep DID trip
# on commented-out code like the next line):
# z = z.T   # noqa — inert text; the jaxpr rule must not flag comments


def _hidden_transpose_target():
    def thunk():
        def flip(a):
            return a.T @ a          # the hidden layout conversion

        def keep(a):
            return a @ a

        def body(c):
            z, it = c
            z = lax.cond(it % 2 == 0, flip, keep, z)
            return z, it + 1

        def run(z):
            return lax.while_loop(lambda c: c[1] < jnp.int32(3),
                                  body, (z, jnp.int32(0)))[0]

        return jax.make_jaxpr(run)(jnp.ones((4, 4))).jaxpr
    return lint.TraceTarget("bad:hidden_transpose", thunk)


def _setup_hidden_transpose(ctx):
    ctx.hot_loop_targets = [_hidden_transpose_target()]


# --- donation-aliasing -----------------------------------------------------
# A donated call whose "carry" binds the same buffer twice, and whose
# donated buffer is read again after the call.


def _aliased_donation_target():
    def thunk():
        donated = jax.jit(lambda c: c[0] + c[1], donate_argnums=0)

        def run(x):
            s = donated((x, x))     # aliased leaves, both donated
            return s + x            # read-after-donation

        return jax.make_jaxpr(run)(jnp.ones(8)).jaxpr
    return lint.TraceTarget("bad:aliased_donation", thunk)


def _setup_aliased_donation(ctx):
    ctx.donation_targets = [_aliased_donation_target()]


# --- dtype-drift -----------------------------------------------------------
# A Newton-style while body that silently round-trips the f64 iterate
# through f32 (the truncation AND the re-promotion are both drift).


def _silent_upcast_target():
    def thunk():
        def body(c):
            z, it = c
            z32 = z.astype(jnp.float32)
            z = (2.0 * z32).astype(jnp.float64)
            return z, it + 1

        def run(z):
            return lax.while_loop(lambda c: c[1] < jnp.int32(3),
                                  body, (z, jnp.int32(0)))[0]

        return jax.make_jaxpr(run)(jnp.ones(8, jnp.float64)).jaxpr
    return lint.TraceTarget("bad:silent_upcast", thunk)


def _setup_silent_upcast(ctx):
    ctx.hot_loop_targets = [_silent_upcast_target()]


# --- bounded-loops ---------------------------------------------------------
# A Newton-style while whose condition is purely float: "iterate until
# the residual is small".  The moment a lane's residual goes NaN the
# `> tol` comparison is false... but so is every later one, and a
# `~converged`-style wrapper flips it right back — either way there is
# no integer ceiling, so the loop's trip count is unbounded.


def _unbounded_newton_target():
    def thunk():
        def body(z):
            return z * 0.5 + 1.0

        def run(z):
            return lax.while_loop(
                lambda z: jnp.max(jnp.abs(z - 2.0)) > 1e-10, body, z)

        return jax.make_jaxpr(run)(jnp.ones(8)).jaxpr
    return lint.TraceTarget("bad:unbounded_newton", thunk)


def _setup_unbounded_newton(ctx):
    ctx.hot_loop_targets = [_unbounded_newton_target()]


# --- kernel-contract -------------------------------------------------------
# An OpSig whose minimum lane tile already exceeds the compiled
# devices' VMEM budget: b=64 float64 block solve needs
# b*(b+1) * 128 * 8 bytes ~ 4.3 MB of working set per grid step.


def _setup_oversize_tile(ctx):
    sigs = dict(ctx.contract_sigs)
    sigs["block_solve_soa"] = sigs["block_solve_soa"] + [
        OpSig("block_solve_soa", "float64", n=64, nsys=256, b=64)]
    ctx.contract_sigs = sigs


# --- table-coherence -------------------------------------------------------
# An op registered in the table with no opcost model, no OP_NOTES row,
# and no autotune coverage — the half-wired-op drift the rule exists
# to catch.


def _setup_orphan_op(ctx):
    def frob(x, *, policy=None):
        return x

    table = dict(ctx.op_table)
    table["frobnicate_soa"] = {"jnp": frob, "pallas": frob}
    ctx.op_table = table


# --- trace-purity ----------------------------------------------------------
# A Python branch on a traced value: abstract evaluation cannot know
# `sum(x) > 0`, so tracing raises a concretization error.


def _tracer_leak_target():
    def thunk():
        def leaky(x):
            if jnp.sum(x) > 0:      # concrete-value leak
                return x * 2
            return x

        return jax.eval_shape(leaky,
                              jax.ShapeDtypeStruct((8,), jnp.float64))
    return lint.TraceTarget("bad:tracer_leak", thunk)


def _setup_tracer_leak(ctx):
    ctx.purity_targets = [_tracer_leak_target()]


# --- telemetry-purity ------------------------------------------------------
# A "disabled observability" candidate whose step loop carries one more
# equation than the raw baseline — the exact drift the zero-overhead
# contract forbids (e.g. a telemetry counter that failed to DCE).


def _leaky_telemetry_pair():
    def base_thunk():
        def body(c):
            z, it = c
            return z * 0.5 + 1.0, it + 1

        def run(z):
            return lax.while_loop(lambda c: c[1] < jnp.int32(3),
                                  body, (z, jnp.int32(0)))[0]

        return jax.make_jaxpr(run)(jnp.ones(8)).jaxpr

    def cand_thunk():
        def body(c):
            z, it = c
            z = z * 0.5 + 1.0
            z = z + jnp.float64(0.0)   # the leaked telemetry op
            return z, it + 1

        def run(z):
            return lax.while_loop(lambda c: c[1] < jnp.int32(3),
                                  body, (z, jnp.int32(0)))[0]

        return jax.make_jaxpr(run)(jnp.ones(8)).jaxpr

    return ("bad:leaky_telemetry",
            lint.TraceTarget("bad:leaky_telemetry[raw]", base_thunk),
            lint.TraceTarget("bad:leaky_telemetry[obs-off]", cand_thunk))


def _setup_leaky_telemetry(ctx):
    ctx.telemetry_targets = [_leaky_telemetry_pair()]
    ctx.telemetry_enabled_targets = []


FIXTURES = {
    "hidden_transpose": ("hot-loop-layout", _setup_hidden_transpose),
    "unbounded_newton": ("bounded-loops", _setup_unbounded_newton),
    "aliased_donation": ("donation-aliasing", _setup_aliased_donation),
    "silent_upcast": ("dtype-drift", _setup_silent_upcast),
    "oversize_tile": ("kernel-contract", _setup_oversize_tile),
    "orphan_op": ("table-coherence", _setup_orphan_op),
    "tracer_leak": ("trace-purity", _setup_tracer_leak),
    "leaky_telemetry": ("telemetry-purity", _setup_leaky_telemetry),
}
