"""Root-finding / event detection (CVodeRootInit analog) tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import butcher, events
from repro.core.arkode import ODEOptions


def test_event_on_decay_threshold():
    """y' = -y, y(0)=1; event y - 0.5 = 0 at t = ln 2."""
    f = lambda t, y: -y
    g = lambda t, y: y[0] - 0.5
    res = events.erk_integrate_with_events(
        f, g, jnp.ones((1,)), 0.0, 5.0, butcher.DORMAND_PRINCE,
        ODEOptions(rtol=1e-8, atol=1e-12))
    assert bool(res.found)
    assert abs(float(res.t_event) - np.log(2.0)) < 1e-6
    assert abs(float(res.y_event[0]) - 0.5) < 1e-5
    assert int(res.which) == 0


def test_event_oscillator_zero_crossing():
    """Harmonic oscillator: first zero of position at t = pi/2."""
    def f(t, y):
        return jnp.stack([y[1], -y[0]])

    g = lambda t, y: y[0]
    res = events.erk_integrate_with_events(
        f, g, jnp.asarray([1.0, 0.0]), 0.0, 10.0,
        butcher.DORMAND_PRINCE, ODEOptions(rtol=1e-9, atol=1e-12))
    assert bool(res.found)
    assert abs(float(res.t_event) - np.pi / 2) < 1e-6


def test_multiple_event_functions_first_wins():
    f = lambda t, y: jnp.ones_like(y)       # y = t
    def g(t, y):
        return jnp.stack([y[0] - 3.0, y[0] - 1.0])  # second fires first

    res = events.erk_integrate_with_events(
        f, g, jnp.zeros((1,)), 0.0, 10.0, butcher.BOGACKI_SHAMPINE,
        ODEOptions(rtol=1e-8, atol=1e-12))
    assert bool(res.found)
    assert int(res.which) == 1
    assert abs(float(res.t_event) - 1.0) < 1e-6


def test_no_event_runs_to_tf():
    f = lambda t, y: -y
    g = lambda t, y: y[0] + 1.0              # never zero (y stays > 0)
    res = events.erk_integrate_with_events(
        f, g, jnp.ones((1,)), 0.0, 2.0, butcher.DORMAND_PRINCE,
        ODEOptions(rtol=1e-8, atol=1e-12))
    assert not bool(res.found)
    assert abs(float(res.t_event) - 2.0) < 1e-12


def test_event_detection_is_jittable():
    f = lambda t, y: -y
    g = lambda t, y: y[0] - 0.25

    @jax.jit
    def run(y0):
        return events.erk_integrate_with_events(
            f, g, y0, 0.0, 5.0, butcher.DORMAND_PRINCE,
            ODEOptions(rtol=1e-8, atol=1e-12))

    res = run(jnp.ones((1,)))
    assert bool(res.found)
    assert abs(float(res.t_event) - np.log(4.0)) < 1e-6
