"""Training-infrastructure tests: optimizer, microbatching, checkpoint
restart semantics, fault logic, data determinism, gradflow, hlocost."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import pipeline
from repro.models import Model
from repro.optim import adamw, gradflow
from repro.train import checkpoint as ckpt
from repro.train import fault
from repro.train import step as tstep


def _small_model():
    return Model(configs.get("internlm2-1.8b-smoke"))


def test_adamw_decreases_loss_and_clips():
    m = _small_model()
    ocfg = adamw.AdamWConfig(lr=1e-2, clip_norm=0.5, warmup_steps=0,
                             total_steps=100)
    state = tstep.init_state(m, jax.random.PRNGKey(0), ocfg)
    d = pipeline.DataConfig(vocab_size=m.cfg.vocab_size, seq_len=32,
                            global_batch=4)
    train = jax.jit(tstep.make_train_step(m, ocfg=ocfg))
    losses = []
    for i, b in zip(range(10), pipeline.batches(d)):
        state, met = train(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(met["loss"]))
        assert float(met["grad_norm"]) > 0
    assert losses[-1] < losses[0]


def test_microbatch_accumulation_matches_full_batch():
    m = _small_model()
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    state = tstep.init_state(m, jax.random.PRNGKey(0), ocfg)
    d = pipeline.DataConfig(vocab_size=m.cfg.vocab_size, seq_len=16,
                            global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in
             pipeline.synthetic_batch(d, 0).items()}
    s1, m1 = jax.jit(tstep.make_train_step(m, ocfg=ocfg))(state, batch)
    s2, m2 = jax.jit(tstep.make_train_step(m, ocfg=ocfg,
                                           microbatches=4))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        # per-microbatch grads are bf16 before the f32 accumulation, so
        # the two paths round near-zero gradient sums differently; Adam's
        # bias-corrected first step is lr * g/|g| = +/-lr for any g >> eps,
        # so a sign flip on one such element moves the param by up to
        # 2*lr = 2e-3.  Bound per-element disagreement by that, plus bf16
        # rounding slack.
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=2.5e-3)


def test_checkpoint_restart_resumes_identically():
    """Crash-restart: training continued from a checkpoint reproduces the
    uninterrupted run exactly (bitwise state + deterministic data)."""
    m = _small_model()
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=20)
    d = pipeline.DataConfig(vocab_size=m.cfg.vocab_size, seq_len=16,
                            global_batch=4)
    train = jax.jit(tstep.make_train_step(m, ocfg=ocfg))

    def run(state, s0, s1):
        for i in range(s0, s1):
            b = {k: jnp.asarray(v) for k, v in
                 pipeline.synthetic_batch(d, i).items()}
            state, _ = train(state, b)
        return state

    st = tstep.init_state(m, jax.random.PRNGKey(0), ocfg)
    full = run(st, 0, 6)

    st2 = tstep.init_state(m, jax.random.PRNGKey(0), ocfg)
    st2 = run(st2, 0, 3)
    with tempfile.TemporaryDirectory() as tmp:
        ckpt.save(st2, tmp, 3)
        assert ckpt.latest_step(tmp) == 3
        ab = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st2)
        restored = ckpt.restore(ab, tmp, 3)
    resumed = run(restored, 3, 6)
    for a, b in zip(jax.tree_util.tree_leaves(full),
                    jax.tree_util.tree_leaves(resumed)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


def test_checkpoint_prune_and_atomicity():
    with tempfile.TemporaryDirectory() as tmp:
        tree = {"w": jnp.arange(4.0)}
        for s in (1, 2, 3, 4):
            ckpt.save(tree, tmp, s)
        ckpt.prune(tmp, keep=2)
        assert ckpt.latest_step(tmp) == 4
        assert sorted(os.listdir(tmp)) == ["step_00000003", "step_00000004"]
        # a stale .tmp dir must not be seen as a checkpoint
        os.makedirs(os.path.join(tmp, "step_00000009.tmp0"))
        assert ckpt.latest_step(tmp) == 4


def test_fault_monitor_and_elastic_plan():
    mon = fault.HeartbeatMonitor(n_workers=8, timeout_s=10.0)
    for w in range(8):
        mon.heartbeat(w, now=100.0)
    mon.heartbeat(3, now=100.0)  # worker 3 then goes silent
    for w in range(8):
        if w != 3:
            mon.heartbeat(w, now=120.0)
    assert mon.dead(now=125.0) == {3}
    for w in range(8):
        for _ in range(10):
            mon.record_step(w, 1.0 if w != 5 else 3.0)
    assert mon.stragglers() == {5}
    # elastic: lose 2 of 32 hosts, model=16 held fixed
    plan = fault.plan_elastic_mesh(30, chips_per_host=8, model_parallel=16,
                                   prefer_pods=2)
    assert plan is not None and plan[2] == 16
    assert plan[0] * plan[1] * plan[2] <= 30 * 8
    assert fault.plan_elastic_mesh(1, 8, 16) is None
    rp = fault.reshard_batch_plan(256, old_data=16, new_data=12)
    assert rp["global_batch"] % 12 == 0


def test_data_determinism_and_host_sharding():
    d = pipeline.DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    b1 = pipeline.synthetic_batch(d, 5)
    b2 = pipeline.synthetic_batch(d, 5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    b3 = pipeline.synthetic_batch(d, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # two hosts partition the global batch disjointly & deterministically
    h0 = pipeline.synthetic_batch(d, 5, process_index=0, process_count=2)
    h1 = pipeline.synthetic_batch(d, 5, process_index=1, process_count=2)
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_gradflow_reduces_loss():
    m = _small_model()
    state = tstep.init_state(m, jax.random.PRNGKey(0))
    d = pipeline.DataConfig(vocab_size=m.cfg.vocab_size, seq_len=16,
                            global_batch=4)
    batch = {k: jnp.asarray(v) for k, v in
             pipeline.synthetic_batch(d, 0).items()}
    lf = lambda p: m.loss(p, batch)
    before = float(lf(state.params))
    p2, st = gradflow.step(lf, state.params,
                           gradflow.GradFlowConfig(tau=0.1, max_steps=6))
    assert float(lf(p2)) < before
    assert int(st.steps) >= 1


def test_hlocost_loop_awareness():
    """The HLO cost walk must multiply while-body costs by trip count."""
    from repro.analysis import hlocost

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    res = hlocost.analyze(txt)
    want = 7 * 2 * 64 * 64 * 64
    assert abs(res["flops"] - want) / want < 0.05, res["flops"]


def test_hlocost_nonsquare_dot_flops():
    """Non-square dot: multi-dim shape types put commas inside the dot
    operand list (f32[8,16] %Arg_0.1), which must not fragment the
    operand parse — m==k on square matrices used to hide a wrong k.
    (Lives here rather than test_ssm_and_analysis.py: that module is
    importorskip-gated on hypothesis and never runs in tier-1.)"""
    from repro.analysis import hlocost
    txt = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 4), jnp.float32)).compile().as_text()
    res = hlocost.analyze(txt)
    assert res["flops"] == 2 * 8 * 16 * 4


def test_adamw_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(adamw.schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(adamw.schedule(cfg, jnp.asarray(110))) - 0.1) < 1e-6
