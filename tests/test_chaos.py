"""Fault-containment suite: per-lane retcodes, quarantine masking,
session hygiene after failure, serving-tier graceful degradation, and
the deterministic chaos injectors that drive all of it.

The invariants under test (PR 10):

* k injected faults produce EXACTLY k non-success retcodes — at the
  injected lanes, with the rest of the ensemble bitwise clean (jnp);
* quarantined lanes freeze at their last ACCEPTED state (finite), and
  re-enter later legs through the cold-start sentinel;
* the serving tier fails ONLY the offending requests' Futures, with
  typed errors (retcode + lane stats / deadline / exec), and can
  degrade a bundle to the jnp oracle after a backend failure.
"""
import numpy as np
import pytest

from repro.core import status
from repro.core.batched import (SolverSession, ensemble_bdf_integrate,
                                ensemble_bdf_integrate_sharded,
                                ensemble_dirk_integrate)
from repro.core.butcher import DIRK_TABLES
from repro.core.context import Context
from repro.core.ivp import IVP, integrate
from repro.core.policies import ExecPolicy
from repro.core.problems import (batched_robertson,
                                 batched_robertson_soa,
                                 robertson_family)
from repro.observability.config import ObservabilityConfig
from repro.serve.solver import (AdmissionQueue, ProblemFamily,
                                RetryAfter, SolverServer)
from repro.serve.solver.queue import IVPRequest
from repro.serve.solver.server import DeadlineExceeded, SolverError
from repro.testing.chaos import (ChaosPlan, chaotic_robertson_family,
                                 failing_executions, poison_rhs,
                                 run_core_chaos)

ROB_PARAMS = {"k1": 0.04, "k2": 1.2e4, "k3": 3e7}


# ---------------------------------------------------------------------------
# retcode vocabulary
# ---------------------------------------------------------------------------

class TestStatus:
    def test_names_and_flags(self):
        assert status.retcode_name(status.SUCCESS) == "SUCCESS"
        assert status.retcode_name(status.CONV_FAILURE) == "CONV_FAILURE"
        assert status.retcode_name(-999) == "UNKNOWN(-999)"
        assert status.is_success(0) and not status.is_success(-4)
        # every retcode maps onto a documented SUNDIALS flag
        assert set(status.SUNDIALS_FLAGS) == set(status.RETCODE_NAMES)
        assert status.SUNDIALS_FLAGS[status.RHSFUNC_FAIL] == \
            "CV_RHSFUNC_FAIL"


# ---------------------------------------------------------------------------
# seeded fault plans
# ---------------------------------------------------------------------------

class TestChaosPlan:
    def test_deterministic_and_bounded(self):
        a = ChaosPlan.draw(64, 5, 0.0, 1.0, seed=7)
        b = ChaosPlan.draw(64, 5, 0.0, 1.0, seed=7)
        assert a == b
        assert ChaosPlan.draw(64, 5, 0.0, 1.0, seed=8) != a
        assert list(a.lanes) == sorted(set(a.lanes))
        assert all(0 <= l < 64 for l in a.lanes)
        assert all(0.3 <= t <= 0.7 for t in a.onsets)
        assert a.mask().sum() == 5
        v = a.onset_vector()
        assert np.isinf(v).sum() == 64 - 5

    def test_validation(self):
        with pytest.raises(ValueError):
            ChaosPlan.draw(4, 5, 0.0, 1.0)


# ---------------------------------------------------------------------------
# core containment (tentpole part 1)
# ---------------------------------------------------------------------------

class TestCoreContainment:
    def test_no_fault_run_is_all_success(self):
        f, jac, y0 = batched_robertson(4)
        _, st = ensemble_bdf_integrate(f, jac, y0, 0.0, 0.2)
        assert np.all(np.asarray(st.retcodes) == 0)
        assert np.all(np.asarray(st.ok))

    def test_nan_faults_exactly_k_and_bitwise(self):
        r = run_core_chaos(24, 3, seed=1, tf=0.3)
        assert r["failed"] == 3 and r["bitwise_checked"]

    def test_divergent_faults_contained(self):
        r = run_core_chaos(12, 2, seed=2, tf=0.3, mode="divergent")
        assert r["failed"] == 2
        assert set(r["retcodes"].values()) <= {"ERR_FAILURE",
                                               "CONV_FAILURE"}

    def test_pallas_interpret_containment(self):
        # masked-reduction containment on the kernel path: faults stay
        # in their lanes through the fused WRMS/Newton reductions too
        r = run_core_chaos(
            16, 2, seed=3, tf=0.25,
            policy=ExecPolicy(backend="pallas", interpret=True,
                              batch_tile=16),
            check_bitwise=False)
        assert r["failed"] == 2

    def test_dirk_lane_quarantine(self):
        nsys, tf = 6, 0.02
        f, jac, y0 = batched_robertson(nsys)
        plan = ChaosPlan.draw(nsys, 1, 0.0, tf, seed=5)
        y, st = ensemble_dirk_integrate(
            poison_rhs(f, plan, mode="nan"), jac, y0, 0.0, tf,
            DIRK_TABLES["sdirk2"])
        rcs = np.asarray(st.retcodes)
        assert set(np.flatnonzero(rcs != 0)) == set(plan.lanes)
        assert np.array_equal(np.asarray(st.ok), rcs == 0)
        healthy = ~plan.mask()
        assert np.isfinite(np.asarray(y)[healthy]).all()

    def test_sharded_containment(self):
        nsys, tf = 8, 0.2
        f, jac, y0 = batched_robertson(nsys)
        plan = ChaosPlan.draw(nsys, 2, 0.0, tf, seed=4)
        y, st = ensemble_bdf_integrate_sharded(
            poison_rhs(f, plan, mode="nan"), jac, y0, 0.0, tf)
        rcs = np.asarray(st.retcodes)
        assert set(np.flatnonzero(rcs != 0)) == set(plan.lanes)
        assert np.isfinite(np.asarray(y)[~plan.mask()]).all()

    def test_solution_surfaces_retcodes_and_event(self):
        nsys, tf = 6, 0.2
        f, jac, y0 = batched_robertson(nsys)
        plan = ChaosPlan.draw(nsys, 2, 0.0, tf, seed=6)
        ctx = Context(observability=ObservabilityConfig(
            log_level="WARNING"))
        sol = integrate(
            IVP(f=poison_rhs(f, plan, mode="nan"), jac=jac, y0=y0),
            0.0, tf, "ensemble_bdf", ctx=ctx)
        rcs = np.asarray(sol.retcodes)
        assert set(np.flatnonzero(rcs != 0)) == set(plan.lanes)
        assert np.array_equal(np.asarray(sol.ok), rcs == 0)
        assert not sol.degraded
        ev = [e for e in ctx.logger.events
              if e["event"] == "integrate.lane_failed"]
        assert len(ev) == 1 and ev[0]["failed"] == 2
        assert set(ev[0]["lanes"]) == set(plan.lanes)


# ---------------------------------------------------------------------------
# session hygiene after failure (satellite b)
# ---------------------------------------------------------------------------

class TestSessionHygiene:
    def test_mid_leg_nan_lane_cold_restarts(self):
        nsys, tm, tf = 6, 0.15, 0.4
        f, jac, y0 = batched_robertson(nsys)
        f_soa, jac_soa = batched_robertson_soa(nsys)
        fault_lane = 2
        plan = ChaosPlan(nsys=nsys, lanes=(fault_lane,), onsets=(0.08,))

        clean = integrate(IVP(f=f, jac=jac, f_soa=f_soa,
                              jac_soa=jac_soa, y0=y0),
                          0.0, tf, "ensemble_bdf")
        leg1_y, leg1_st, sess = ensemble_bdf_integrate(
            poison_rhs(f, plan, mode="nan"), jac, y0, 0.0, tm,
            f_soa=poison_rhs(f_soa, plan, mode="nan", soa=True),
            jac_soa=jac_soa, return_session=True)
        rcs1 = np.asarray(leg1_st.retcodes)
        assert rcs1[fault_lane] != 0
        assert np.all(np.delete(rcs1, fault_lane) == 0)
        # failed lane exported with the cold-start sentinel: h == 0,
        # reset order/step counters, last ACCEPTED (finite) state
        assert float(sess.h[fault_lane]) == 0.0
        assert int(sess.q[fault_lane]) == 1
        assert int(sess.steps[fault_lane]) == 0
        assert float(sess.t[fault_lane]) < tm
        assert np.isfinite(np.asarray(leg1_y)).all()
        # healthy lanes keep their warm handles
        assert np.all(np.asarray(sess.h) > 0.0) or True
        assert np.all(np.delete(np.asarray(sess.h), fault_lane) > 0.0)

        # leg 2 under the CLEAN rhs: the failed lane re-enters cold
        # (from its quarantine-time state) and completes; healthy lanes
        # continue warm — everyone succeeds
        leg2_y, leg2_st, sess2 = ensemble_bdf_integrate(
            f, jac, leg1_y, tm, tf, f_soa=f_soa, jac_soa=jac_soa,
            session=sess, return_session=True)
        assert np.all(np.asarray(leg2_st.retcodes) == 0)
        assert np.all(np.asarray(leg2_st.ok))
        assert np.allclose(np.asarray(sess2.t), tf)
        # ... with trajectories agreeing with the uninterrupted clean
        # run at tolerance level
        rel = np.max(np.abs(np.asarray(leg2_y) - np.asarray(clean.y)) /
                     (np.abs(np.asarray(clean.y)) + 1e-30))
        assert rel < 1e-3
        # cold restart accounting: the failed lane's cumulative session
        # step count restarts from zero at leg 2
        assert int(sess2.steps[fault_lane]) == \
            int(leg2_st.steps[fault_lane])


# ---------------------------------------------------------------------------
# depth-proportional RetryAfter hints (satellite a)
# ---------------------------------------------------------------------------

def _req(n=3):
    import jax.numpy as jnp
    return IVPRequest(family="robertson", y0=jnp.zeros(n), t0=0.0,
                      tf=0.2)


class TestRetryHint:
    def test_preflush_fallback_scales_with_depth(self):
        q = AdmissionQueue(bucket_sizes=(64,), max_batch=64,
                           max_wait=1e-2, max_depth=10_000)
        assert q.retry_hint() == pytest.approx(1e-2)   # empty: floor
        for _ in range(640):
            q.offer(_req(), now=0.0)
        # 10 flush windows of backlog -> 10x max_wait
        assert q.retry_hint() == pytest.approx(1e-1)

    def test_drain_rate_ema_drives_hint(self):
        q = AdmissionQueue(bucket_sizes=(4,), max_batch=4,
                           max_wait=1e-3, max_depth=10_000)
        for t in (0.0, 1.0):
            for _ in range(4):
                q.offer(_req(), now=t)
            q.poll(now=t + 0.5, flush_all=True)
        # second flush observed 4 requests / 1.0 s -> rate 4/s
        for _ in range(8):
            q.offer(_req(), now=2.0)
        assert q.retry_hint() == pytest.approx(8 / 4.0)
        # deeper backlog -> proportionally longer hint
        for _ in range(8):
            q.offer(_req(), now=2.0)
        assert q.retry_hint() == pytest.approx(16 / 4.0)

    def test_reject_carries_hint_and_clamp(self):
        q = AdmissionQueue(bucket_sizes=(4,), max_batch=4,
                           max_wait=1e-3, max_depth=2)
        q.offer(_req(), now=0.0)
        q.offer(_req(), now=0.0)
        with pytest.raises(RetryAfter) as ei:
            q.offer(_req(), now=0.0)
        assert ei.value.retry_after == pytest.approx(q.retry_hint())
        assert 1e-3 <= ei.value.retry_after <= 30.0


# ---------------------------------------------------------------------------
# serving-tier graceful degradation (tentpole part 2)
# ---------------------------------------------------------------------------

def _chaos_server(**kw):
    fam = chaotic_robertson_family()
    ctx = Context(observability=ObservabilityConfig(
        log_level="WARNING"))
    kw.setdefault("bucket_sizes", (4,))
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait", 1e-3)
    return SolverServer(
        [ProblemFamily("chaos_rob", 3, fam[0], fam[1], fam[2],
                       fam[3])], ctx=ctx, **kw)


def _params(t_fault=np.inf):
    return {**ROB_PARAMS, "t_fault": float(t_fault)}


class TestServingFaults:
    def test_lane_fault_fails_only_offender(self):
        srv = _chaos_server()
        try:
            healthy = [srv.submit("chaos_rob", [1.0, 0.0, 0.0], 0.0,
                                  0.2, params=_params())
                       for _ in range(3)]
            bad = srv.submit("chaos_rob", [1.0, 0.0, 0.0], 0.0, 0.2,
                             params=_params(t_fault=0.1))
            srv.drain()
            with pytest.raises(SolverError) as ei:
                bad.result(timeout=5)
            assert ei.value.retcode in (status.CONV_FAILURE,
                                        status.RHSFUNC_FAIL)
            assert ei.value.retcode_name in ("CONV_FAILURE",
                                             "RHSFUNC_FAIL")
            assert ei.value.stats is not None
            assert int(ei.value.stats.retcodes) == ei.value.retcode
            for fut in healthy:
                sol = fut.result(timeout=5)
                assert bool(sol.success) and bool(np.asarray(sol.ok))
                assert int(np.asarray(sol.retcodes)) == 0
                assert not sol.degraded
            ev = [e["event"] for e in srv.ctx.logger.events]
            assert "serve.lane_failed" in ev
            m = srv.metrics()
            assert sum(m["failures"].values()) == 1
            assert 'reason="' in srv.metrics_prometheus()
        finally:
            srv.stop()

    def test_deadline_shed_before_compute(self):
        srv = _chaos_server()
        try:
            with pytest.raises(ValueError, match="deadline"):
                srv.submit("chaos_rob", [1.0, 0.0, 0.0], 0.0, 0.2,
                           params=_params(), deadline=0.0)
            doomed = srv.submit("chaos_rob", [1.0, 0.0, 0.0], 0.0,
                                0.2, params=_params(), deadline=1e-9)
            ok = srv.submit("chaos_rob", [1.0, 0.0, 0.0], 0.0, 0.2,
                            params=_params())
            srv.drain()
            with pytest.raises(DeadlineExceeded):
                doomed.result(timeout=5)
            assert bool(ok.result(timeout=5).success)
            assert srv.metrics()["failures"]["deadline"] == 1
            ev = [e["event"] for e in srv.ctx.logger.events]
            assert "serve.deadline_shed" in ev
            assert ('repro_serve_failures_total{reason="deadline"} 1'
                    in srv.metrics_prometheus())
        finally:
            srv.stop()

    def test_executable_raise_degrades_to_oracle(self):
        srv = _chaos_server()
        try:
            with failing_executions(srv, k=1) as box:
                futs = [srv.submit("chaos_rob", [1.0, 0.0, 0.0], 0.0,
                                   0.2, params=_params())
                        for _ in range(2)]
                srv.drain()
            assert box["raised"] == 1
            for fut in futs:
                sol = fut.result(timeout=5)
                assert bool(sol.success) and sol.degraded
            m = srv.metrics()
            assert m["degraded"] == 1 and not m["failures"]
            ev = [e["event"] for e in srv.ctx.logger.events]
            assert "serve.bundle.degraded" in ev
            assert ("repro_serve_degraded_total 1"
                    in srv.metrics_prometheus())
        finally:
            srv.stop()

    def test_fallback_failure_fails_futures_typed(self):
        srv = _chaos_server()
        try:
            with failing_executions(srv, k=2):   # primary AND fallback
                fut = srv.submit("chaos_rob", [1.0, 0.0, 0.0], 0.0,
                                 0.2, params=_params())
                with pytest.raises(RuntimeError):
                    srv.drain()
            with pytest.raises(SolverError):
                fut.result(timeout=5)
            assert srv.metrics()["failures"]["exec_error"] == 1
        finally:
            srv.stop()

    def test_submit_with_retry_backoff(self):
        srv = _chaos_server(max_depth=1)
        try:
            srv.submit("chaos_rob", [1.0, 0.0, 0.0], 0.0, 0.2,
                       params=_params())           # queue now full
            sleeps = []

            def sleep(s):
                sleeps.append(s)
                srv.drain()                        # frees the queue

            fut = srv.submit_with_retry(
                "chaos_rob", [1.0, 0.0, 0.0], 0.0, 0.2,
                params=_params(), seed=0, sleep=sleep)
            srv.drain()
            assert bool(fut.result(timeout=5).success)
            assert len(sleeps) == 1 and sleeps[0] > 0
        finally:
            srv.stop()

    def test_submit_with_retry_exhaustion(self):
        srv = _chaos_server(max_depth=1)
        try:
            srv.submit("chaos_rob", [1.0, 0.0, 0.0], 0.0, 0.2,
                       params=_params())
            sleeps = []
            with pytest.raises(RetryAfter):
                srv.submit_with_retry(
                    "chaos_rob", [1.0, 0.0, 0.0], 0.0, 0.2,
                    params=_params(), retries=2, seed=0,
                    sleep=sleeps.append)
            # jittered exponential: strictly growing delays
            assert len(sleeps) == 2 and sleeps[1] > sleeps[0]
        finally:
            srv.stop()
