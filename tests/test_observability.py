"""Observability suite: profiler regions, structured event log, metrics
registry, the in-loop step-telemetry ring, and the serving Prometheus /
span surface.

The load-bearing assertions are the *exact* reconciliations: recorded
ring-buffer telemetry must sum to the very counters the Solution
reports (steps, Newton iterations, lsetups) — per system, including
padded dead lanes and the warm-start continuation leg.  The structural
zero-overhead contract (disabled config leaves the hot-loop jaxpr
byte-identical) is checked statically by the ``telemetry-purity``
sunlint rule; the runtime ceilings live in
``benchmarks/observability_bench.py``.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.context import Context
from repro.core.ivp import IVP, integrate
from repro.core.problems import (batched_robertson, batched_robertson_soa,
                                 robertson_family)
from repro.observability import (Counter, EventLogger, Gauge, Histogram,
                                 MetricsRegistry, ObservabilityConfig,
                                 Profiler, StepTelemetry, context_metrics,
                                 ring_init, ring_record)
from repro.serve.solver import ProblemFamily, SolverServer
from repro.serve.solver.server import _LatencyRing

ROB_PARAMS = {"k1": 0.04, "k2": 1.2e4, "k3": 3e7}


# ---------------------------------------------------------------------------
# config + profiler + logger
# ---------------------------------------------------------------------------

class TestConfig:
    def test_defaults_are_all_off(self):
        cfg = ObservabilityConfig()
        assert not cfg.profile and not cfg.telemetry
        assert cfg.log_level is None and not cfg.enabled
        assert ObservabilityConfig(profile=True).enabled
        assert ObservabilityConfig(telemetry=True).enabled
        assert ObservabilityConfig(log_level="INFO").enabled

    def test_context_lazy_surfaces(self):
        ctx = Context()
        assert not ctx.profiler.enabled and not ctx.logger.enabled
        ctx2 = Context(observability=ObservabilityConfig(
            profile=True, log_level="DEBUG"))
        assert ctx2.profiler.enabled and ctx2.logger.enabled_for("DEBUG")


class TestProfiler:
    def test_disabled_is_a_shared_noop(self):
        p = Profiler(enabled=False)
        r1, r2 = p.region("a"), p.region("b")
        assert r1 is r2                      # one shared null region
        with r1:
            pass
        p.add_span("x", 0.0, 1.0)
        assert p.spans == []

    def test_nesting_summary_and_render(self):
        clock = iter(float(i) for i in range(100))
        p = Profiler(enabled=True, sync=False,
                     clock=lambda: next(clock))
        with p.region("outer"):
            with p.region("inner"):
                pass
            with p.region("inner"):
                pass
        names = [(s.name, s.depth) for s in p.spans]
        assert names == [("inner", 1), ("inner", 1), ("outer", 0)]
        s = p.summary()
        assert s["inner"]["count"] == 2 and s["outer"]["count"] == 1
        assert s["outer"]["total_s"] > s["inner"]["total_s"]
        assert "outer" in p.render() and "count" in p.render()

    def test_sync_fn_called_on_exit(self):
        calls = []
        p = Profiler(enabled=True, sync=True,
                     sync_fn=lambda: calls.append(1))
        with p.region("r"):
            pass
        with p.region("nosync", sync=False):
            pass
        assert calls == [1]

    def test_chrome_trace_export(self, tmp_path):
        p = Profiler(enabled=True, sync=False)
        p.add_span("a", 10.0, 10.5, cat="serve", args={"k": 1})
        p.add_span("b", 10.2, 10.3)
        path = p.export_chrome_trace(str(tmp_path / "trace.json"))
        doc = json.loads(open(path).read())
        ev = doc["traceEvents"]
        assert len(ev) == 2
        assert all(e["ph"] == "X" and e["dur"] >= 0 for e in ev)
        # microseconds relative to the first span
        assert min(e["ts"] for e in ev) == 0.0
        a = next(e for e in ev if e["name"] == "a")
        assert a["cat"] == "serve" and a["args"] == {"k": 1}


class TestEventLogger:
    def test_threshold_filtering(self):
        log = EventLogger(level="WARNING")
        log.debug("d"); log.info("i"); log.warning("w"); log.error("e")
        assert [r["event"] for r in log.events] == ["w", "e"]
        assert log.enabled_for("ERROR") and not log.enabled_for("INFO")

    def test_disabled_drops_everything(self):
        log = EventLogger()
        log.error("boom")
        assert not log.enabled and len(log.events) == 0

    def test_jsonl_sink(self, tmp_path):
        path = tmp_path / "events.jsonl"
        log = EventLogger(level="INFO", path=str(path),
                          clock=lambda: 12.5)
        log.info("step.done", steps=3, method="bdf")
        log.debug("dropped")
        log.close()
        lines = path.read_text().splitlines()
        assert len(lines) == 1
        rec = json.loads(lines[0])
        assert rec == {"ts": 12.5, "level": "INFO",
                       "event": "step.done", "steps": 3,
                       "method": "bdf"}

    def test_unknown_level_raises(self):
        with pytest.raises(ValueError, match="level"):
            EventLogger(level="CHATTY")


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_gauge_render(self):
        reg = MetricsRegistry()
        c = reg.counter("repro_reqs", "requests")
        c.inc(); c.inc(2.0, family="rob")
        g = reg.gauge("repro_depth", "queue depth")
        g.set(3)
        text = reg.render()
        assert "# TYPE repro_reqs_total counter" in text
        assert "repro_reqs_total 1" in text
        assert 'repro_reqs_total{family="rob"} 2' in text
        assert "repro_depth 3" in text
        with pytest.raises(ValueError):
            c.inc(-1.0)

    def test_histogram_cumulative_buckets(self):
        h = Histogram("lat", "latency", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        lines = h.render()
        assert 'lat_bucket{le="0.1"} 1' in lines
        assert 'lat_bucket{le="1"} 2' in lines
        assert 'lat_bucket{le="+Inf"} 3' in lines
        assert "lat_count 3" in lines
        with pytest.raises(ValueError, match="bucket counts"):
            h.set_counts([1, 2], 0.0, 3)     # needs 3 (incl +Inf)

    def test_registry_idempotent_and_kind_clash(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(ValueError, match="registered"):
            reg.gauge("x")

    def test_context_metrics_export(self):
        ctx = Context()
        f, jac, y0 = batched_robertson(2)
        f_soa, jac_soa = batched_robertson_soa(2)
        integrate(IVP(f=f, jac=jac, f_soa=f_soa, jac_soa=jac_soa,
                      y0=y0), 0.0, 0.05, "ensemble_bdf", ctx=ctx)
        reg = MetricsRegistry()
        context_metrics(reg, ctx)
        text = reg.render()
        assert "repro_context_integrations_total 1" in text


class TestLatencyRing:
    def test_window_and_lifetime_split(self):
        r = _LatencyRing(size=4)
        for v in (1.0, 2.0, 3.0):
            r.observe(v)
        assert r.window() == [1.0, 2.0, 3.0] and r.count == 3
        assert r.clear() == [1.0, 2.0, 3.0]
        assert r.window() == [] and r.count == 0
        # lifetime aggregates survive the window clear
        assert r.total == 3 and r.sum_s == pytest.approx(6.0)

    def test_wraparound_keeps_newest_oldest_first(self):
        r = _LatencyRing(size=3)
        for v in (1.0, 2.0, 3.0, 4.0, 5.0):
            r.observe(v)
        assert r.window() == [3.0, 4.0, 5.0]
        assert r.count == 3 and r.total == 5

    def test_bucket_counts_cumulate_correctly(self):
        r = _LatencyRing(size=8, buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0, 0.01):
            r.observe(v)
        assert list(r.bucket_counts) == [2, 1, 1]   # <=0.1, <=1, +Inf


# ---------------------------------------------------------------------------
# telemetry ring (unit level)
# ---------------------------------------------------------------------------

def _rec(i, nsys=None):
    shape = () if nsys is None else (nsys,)
    f = lambda v, dt=jnp.float64: jnp.full(shape, v, dt)
    return (f(float(i)), f(0.1), f(2, jnp.int32), f(i, jnp.int32),
            f(0.5), f(i % 2 == 0, bool), f(True, bool), f(True, bool),
            f(True, bool))


class TestTelemetryRing:
    def test_record_and_chronological_wrap(self):
        ring = ring_init(3, (), jnp.float64)
        for i in range(5):                   # wraps: keeps 2, 3, 4
            ring = ring_record(ring, _rec(i))
        tel = StepTelemetry(ring)
        assert tel.truncated and tel.records == 3
        assert tel.total_records == 5
        assert tel.t.tolist() == [2.0, 3.0, 4.0]
        assert tel.newton_iters.tolist() == [2, 3, 4]

    def test_untruncated_prefix_only(self):
        ring = ring_init(8, (), jnp.float64)
        for i in range(3):
            ring = ring_record(ring, _rec(i))
        tel = StepTelemetry(ring)
        assert not tel.truncated and tel.records == 3
        assert tel.t.shape == (3,)

    def test_live_mask_zeroes_dead_lanes(self):
        ring = ring_init(4, (3,), jnp.float64)
        for i in range(2):
            ring = ring_record(ring, _rec(i, nsys=3))
        tel = StepTelemetry(ring, live=[True, False, True])
        assert tel.newton_iters[:, 1].tolist() == [0, 0]
        assert not tel.accepted[:, 1].any()
        assert tel.steps().tolist() == [2, 0, 2]
        assert tel.attempts().tolist() == [2, 0, 2]

    def test_capacity_validation(self):
        with pytest.raises(ValueError, match="capacity"):
            ring_init(0, (), jnp.float64)


# ---------------------------------------------------------------------------
# integrate() telemetry: exact reconciliation with Solution aggregates
# ---------------------------------------------------------------------------

def _rob_prob(nsys):
    f, jac, y0 = batched_robertson(nsys)
    f_soa, jac_soa = batched_robertson_soa(nsys)
    return IVP(f=f, jac=jac, f_soa=f_soa, jac_soa=jac_soa, y0=y0)


class TestIntegrateTelemetry:
    def test_ensemble_bdf_reconciles_exactly(self):
        prob = _rob_prob(4)
        plain = integrate(prob, 0.0, 0.2, "ensemble_bdf")
        sol = integrate(prob, 0.0, 0.2, "ensemble_bdf", telemetry=512)
        tel = sol.telemetry
        assert isinstance(tel, StepTelemetry) and not tel.truncated
        # telemetry must not perturb the integration
        assert np.array_equal(np.asarray(sol.y), np.asarray(plain.y))
        st = sol.stats
        assert tel.steps().tolist() == np.asarray(st.steps).tolist()
        assert tel.attempts().tolist() == \
            np.asarray(st.attempts).tolist()
        assert tel.newton_iters_total().tolist() == \
            np.asarray(st.nni).tolist()
        assert tel.lsetups().tolist() == np.asarray(st.nsetups).tolist()
        s = tel.summary()
        assert s["steps"] == int(jnp.sum(st.steps))
        assert s["h_hist_log10"]["counts"] and s["order_occupancy"]

    def test_config_driven_telemetry(self):
        ctx = Context(observability=ObservabilityConfig(
            telemetry=True, telemetry_capacity=512))
        sol = integrate(_rob_prob(2), 0.0, 0.1, "ensemble_bdf", ctx=ctx)
        assert sol.telemetry is not None
        assert sol.telemetry.steps().tolist() == \
            np.asarray(sol.stats.steps).tolist()
        # config must not force telemetry onto non-capable families
        sol_erk = integrate(IVP(f=lambda t, y: -y, y0=jnp.ones(2)),
                            0.0, 1.0, "erk:dopri5", ctx=ctx)
        assert sol_erk.telemetry is None

    def test_scalar_bdf_reconciles_exactly(self):
        f, jac, y0b = batched_robertson(1)
        y0 = np.asarray(y0b)[0]
        sf = lambda t, y: f(jnp.asarray(t)[None], y[None, :])[0]
        sjac = lambda t, y: jac(jnp.asarray(t)[None], y[None, :])[0]
        sol = integrate(IVP(f=sf, jac=sjac, y0=y0), 0.0, 0.2, "bdf",
                        telemetry=1024)
        tel = sol.telemetry
        assert not tel.truncated
        assert int(tel.steps()) == int(sol.stats.steps)
        assert int(tel.attempts()) == int(sol.stats.attempts)
        assert int(tel.newton_iters_total()) == int(sol.stats.nni)

    def test_ensemble_dirk_reconciles_exactly(self):
        sol = integrate(_rob_prob(3), 0.0, 0.05,
                        "ensemble_dirk:sdirk2", telemetry=2048)
        tel = sol.telemetry
        assert not tel.truncated
        st = sol.stats
        assert tel.steps().tolist() == np.asarray(st.steps).tolist()
        assert tel.newton_iters_total().tolist() == \
            np.asarray(st.nni).tolist()

    def test_telemetry_rejected_for_explicit_methods(self):
        with pytest.raises(ValueError, match="telemetry"):
            integrate(IVP(f=lambda t, y: -y, y0=jnp.ones(2)),
                      0.0, 1.0, "erk:dopri5", telemetry=64)

    def test_padded_bundle_masks_dead_lanes(self):
        live_n, pad_n, tf = 3, 4, 0.1
        prob = _rob_prob(pad_n)
        tfv = jnp.where(jnp.arange(pad_n) < live_n, tf, 0.0)
        mask = np.arange(pad_n) < live_n
        sol = integrate(prob, 0.0, tfv, "ensemble_bdf", live=mask,
                        telemetry=512)
        tel = sol.telemetry
        st = sol.stats                       # already live-masked
        assert tel.steps().tolist() == np.asarray(st.steps).tolist()
        assert tel.steps()[live_n:].tolist() == [0]
        assert tel.newton_iters_total()[live_n:].tolist() == [0]
        assert tel.newton_iters_total().sum() == int(sol.nni)

    def test_warm_start_leg_reconciles(self):
        prob = _rob_prob(2)
        leg1 = integrate(prob, 0.0, 0.1, "ensemble_bdf",
                         return_session=True, telemetry=512)
        assert leg1.telemetry.steps().tolist() == \
            np.asarray(leg1.stats.steps).tolist()
        leg2 = integrate(IVP(f=prob.f, jac=prob.jac, f_soa=prob.f_soa,
                             jac_soa=prob.jac_soa, y0=leg1.y),
                         0.1, 0.3, "ensemble_bdf",
                         session=leg1.session, return_session=True,
                         telemetry=512)
        tel = leg2.telemetry
        # the leg's ring records the LEG's work, not the cumulative
        # session counters
        assert tel.steps().tolist() == \
            np.asarray(leg2.stats.steps).tolist()
        assert tel.newton_iters_total().tolist() == \
            np.asarray(leg2.stats.nni).tolist()


class TestTimedIntegrate:
    def test_direct_timings_reported(self):
        sol = integrate(_rob_prob(2), 0.0, 0.05, "ensemble_bdf",
                        timed=True)
        assert set(sol.timings) == {"lower", "compile", "execute"}
        assert all(v >= 0.0 for v in sol.timings.values())
        assert sol.timings["compile"] > 0.0
        assert bool(sol.success)

    def test_untimed_has_no_timings(self):
        sol = integrate(_rob_prob(2), 0.0, 0.05, "ensemble_bdf")
        assert sol.timings is None

    def test_profile_config_records_regions_and_logs(self):
        ctx = Context(observability=ObservabilityConfig(
            profile=True, profile_sync=False, log_level="INFO"))
        sol = integrate(_rob_prob(2), 0.0, 0.05, "ensemble_bdf",
                        ctx=ctx)
        assert sol.timings is not None
        names = {s.name for s in ctx.profiler.spans}
        assert {"integrate.lower", "integrate.compile",
                "integrate.execute"} <= names
        assert any(e["event"] == "integrate.done"
                   for e in ctx.logger.events)


# ---------------------------------------------------------------------------
# serving surface: Prometheus text, bundle spans, queue events
# ---------------------------------------------------------------------------

@pytest.fixture(scope="class")
def obs_server():
    fr = robertson_family()
    ctx = Context(observability=ObservabilityConfig(
        profile=True, profile_sync=False, log_level="DEBUG"))
    srv = SolverServer(
        [ProblemFamily("robertson", 3, fr[0], fr[1], fr[2], fr[3])],
        ctx=ctx, bucket_sizes=(4,), max_batch=4, max_wait=1e-3,
        warmup_bundles=0, latency_window=8)
    futs = [srv.submit("robertson", [1.0, 0.0, 0.0], 0.0, 0.2,
                       params=ROB_PARAMS) for _ in range(6)]
    bundles = srv.drain()
    for f in futs:
        assert bool(f.result(timeout=30).success)
    yield srv, bundles
    srv.stop()


class TestServerObservability:
    def test_prometheus_exposition(self, obs_server):
        srv, _ = obs_server
        text = srv.metrics_prometheus()
        assert "# TYPE repro_serve_requests_total counter" in text
        assert "repro_serve_requests_total 6" in text
        assert "repro_serve_bundles_total 2" in text
        assert "repro_serve_latency_seconds_count 6" in text
        assert 'le="+Inf"' in text
        assert ('repro_serve_bucket_requests_total'
                '{family="robertson",n="3",nsys="4"} 6') in text
        assert "repro_context_integrations_total" in text
        assert "repro_serve_occupancy" in text

    def test_bundle_spans_cover_every_bundle(self, obs_server):
        srv, bundles = obs_server
        spans = srv.ctx.profiler.spans
        by_name = {}
        for s in spans:
            by_name.setdefault(s.name, []).append(s)
        for name in ("serve.bundle.queue_wait", "serve.bundle.compile",
                     "serve.bundle.execute"):
            assert len(by_name[name]) == bundles, name
        # queue wait must precede execute on the shared timebase
        qw = by_name["serve.bundle.queue_wait"][0]
        ex = by_name["serve.bundle.execute"][0]
        assert qw.t0 <= ex.t1
        trace = srv.ctx.profiler.chrome_trace()
        assert all(e["ph"] == "X" for e in trace["traceEvents"])

    def test_queue_and_bundle_events_logged(self, obs_server):
        srv, bundles = obs_server
        events = [e["event"] for e in srv.ctx.logger.events]
        assert events.count("queue.admit") == 6
        assert events.count("queue.flush") == bundles
        assert events.count("serve.bundle") == bundles

    def test_latency_window_vs_lifetime(self, obs_server):
        srv, _ = obs_server
        m = srv.metrics()
        assert m["latency_samples"] == 6 and m["latency_observed"] == 6
        taken = srv.take_latencies()
        assert len(taken) == 6
        m2 = srv.metrics()
        assert m2["latency_samples"] == 0
        assert m2["latency_observed"] == 6   # lifetime survives
        # the Prometheus histogram is lifetime-backed: still 6
        assert ("repro_serve_latency_seconds_count 6"
                in srv.metrics_prometheus())
