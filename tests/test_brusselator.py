"""Demonstration-problem tests (paper §7): correctness of both solver
configurations + the properties the paper claims."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import brusselator as br
from repro.configs.brusselator import BrusselatorConfig
from repro.core.policies import ExecPolicy

TF = 0.2


def test_task_local_matches_global():
    cfg_tl = BrusselatorConfig(nx=96, solver="task-local")
    cfg_gl = BrusselatorConfig(nx=96, solver="global")
    y_tl, st_tl = br.integrate(cfg_tl, t_final=TF)
    y_gl, st_gl = br.integrate(cfg_gl, t_final=TF)
    assert bool(st_tl.success) and bool(st_gl.success)
    np.testing.assert_allclose(np.asarray(y_tl), np.asarray(y_gl),
                               rtol=1e-7, atol=1e-9)


def test_against_explicit_reference():
    cfg = BrusselatorConfig(nx=64)
    y, st = br.integrate(cfg, t_final=TF)
    ref = br.reference_solution(cfg, TF, n_steps=20000)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=1e-6)
    # IMEX must need FAR fewer steps than the explicit stability limit
    # h_stab ~ eps = 5e-6  ->  explicit needs ~ tf/eps = 4e4 steps
    assert int(st.steps) < 500


def test_pallas_block_solver_path():
    cfg = BrusselatorConfig(nx=64, solver="task-local")
    pol = ExecPolicy(backend="pallas", interpret=True, batch_tile=128)
    y_pal, st = br.integrate(cfg, t_final=0.05, policy=pol)
    y_jnp, _ = br.integrate(cfg, t_final=0.05)
    assert bool(st.success)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_jnp),
                               rtol=1e-8, atol=1e-10)


def test_reaction_jacobian_is_exact():
    cfg = BrusselatorConfig(nx=8)
    fi = br.reaction_rhs(cfg)
    jac = br.reaction_jacobian(cfg)
    y = br.initial_state(cfg) + 0.05
    J_ad = jax.jacfwd(lambda yy: fi(0.0, yy))(y)   # (nx,3,nx,3)
    J_an = jac(0.0, y)
    for i in range(cfg.nx):
        np.testing.assert_allclose(np.asarray(J_ad[i, :, i, :]),
                                   np.asarray(J_an[i]), rtol=1e-10)
        # off-diagonal blocks are exactly zero (point-local reactions)
        if i:
            assert float(jnp.abs(J_ad[i, :, 0, :]).max()) == 0.0


def test_advection_is_conservative_and_periodic():
    cfg = BrusselatorConfig(nx=32)
    fe = br.advection_rhs(cfg)
    y = br.initial_state(cfg)
    dy = fe(0.0, y)
    # upwind advection conserves the total of each species (periodic BC)
    np.testing.assert_allclose(np.asarray(jnp.sum(dy, axis=0)),
                               np.zeros(3), atol=1e-10)


def test_mass_behavior_under_integration():
    """u+v evolves only through the A source and u-term (sanity physics)."""
    cfg = BrusselatorConfig(nx=48)
    y, st = br.integrate(cfg, t_final=0.1)
    assert bool(st.success)
    assert bool(jnp.all(y[:, 0] > 0)) and bool(jnp.all(y[:, 1] > 0))
    # w is pinned near B by the stiff relaxation
    np.testing.assert_allclose(np.asarray(y[:, 2]), cfg.B, rtol=0.2)
