"""Chunked-SSD equivalence + analysis-tooling tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; property tests
from hypothesis import given, settings, strategies as st

from repro.models import ssm


def _random_ssm_inputs(seed, B=2, S=32, nh=3, hd=8, ds=5):
    k = [jax.random.PRNGKey(seed + i) for i in range(6)]
    xs = jax.random.normal(k[0], (B, S, nh, hd))
    Bm = jax.random.normal(k[1], (B, S, ds))
    Cm = jax.random.normal(k[2], (B, S, ds))
    dt = jax.nn.softplus(jax.random.normal(k[3], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(k[4], (nh,)) * 0.5)
    h0 = 0.1 * jax.random.normal(k[5], (B, nh, hd, ds))
    return xs, Bm, Cm, dt, dt * A[None, None], h0


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_ssd_equals_stepwise(chunk):
    xs, Bm, Cm, dt, ld, h0 = _random_ssm_inputs(0)
    y1, h1 = ssm._ssm_scan_stepwise(xs, Bm, Cm, jnp.exp(ld), dt, h0)
    y2, h2 = ssm._ssm_scan_chunked(xs, Bm, Cm, ld, dt, h0, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 1000))
def test_chunked_ssd_property(seed):
    xs, Bm, Cm, dt, ld, h0 = _random_ssm_inputs(seed, B=1, S=16, nh=2,
                                                hd=4, ds=3)
    y1, h1 = ssm._ssm_scan_stepwise(xs, Bm, Cm, jnp.exp(ld), dt, h0)
    y2, h2 = ssm._ssm_scan_chunked(xs, Bm, Cm, ld, dt, h0, 4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=5e-4,
                               atol=5e-4)


def test_mamba2_decode_consistent_with_train_path():
    """Prefill via the train path == step-by-step decode with caches."""
    from repro import configs
    from repro.models.spec import init_params
    cfg = configs.get("zamba2-7b-smoke").replace(dtype=jnp.float32)
    p = init_params(ssm.mamba2_spec(cfg), jax.random.PRNGKey(0))
    B, S = 1, 6
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                                jnp.float32)
    y_train, _ = ssm.mamba2_apply(p, cfg, x)          # stepwise (S small)
    cache = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), ssm.mamba2_cache_spec(cfg, B))
    outs = []
    for t in range(S):
        yt, cache = ssm.mamba2_apply(p, cfg, x[:, t:t + 1], cache=cache)
        outs.append(yt)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               rtol=2e-3, atol=2e-3)


def test_collective_traffic_model():
    """Ring-model byte accounting from synthetic HLO lines."""
    from repro.analysis import hlocost
    hc = hlocost.HloCost("", n_devices=8)
    ag = ('%ag = f32[16,32] all-gather(%x), replica_groups=[2,4]<=[8], '
          'dimensions={0}')
    # out 2048 B, g=4 -> 2048*3/4 = 1536
    assert hc._coll_traffic(ag, "all-gather") == 1536
    ar = '%ar = bf16[64] all-reduce(%x), replica_groups=[1,8]<=[8]'
    # 128 B * 2 * 7/8 = 224
    assert hc._coll_traffic(ar, "all-reduce") == 224


def test_hlocost_collectives_in_loops():
    from repro.analysis import hlocost

    def f(x):
        def body(c, _):
            return jax.lax.psum(c, "i"), None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    import os
    # single-device "mesh" still emits the loop structure
    mesh = jax.make_mesh((1,), ("i",))
    g = shard_map(f, mesh=mesh, in_specs=P(None), out_specs=P(None))
    txt = jax.jit(g).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
    res = hlocost.analyze(txt, 1)
    # degenerate 1-device psum may be optimized away; the walk must not
    # crash and flops/bytes must be finite
    assert res["bytes"] >= 0 and res["flops"] >= 0


def test_roofline_math():
    from repro.analysis import roofline as rf
    row = rf.Roofline(arch="a", shape="s", mesh="m", chips=256,
                      hlo_flops=197e12, hlo_bytes=819e9, coll_bytes=50e9,
                      model_flops=197e12 * 256).finalize()
    assert abs(row.t_compute - 1.0) < 1e-9
    assert abs(row.t_memory - 1.0) < 1e-9
    assert abs(row.t_collective - 1.0) < 1e-9
    assert abs(row.useful_ratio - 1.0) < 1e-9
    assert abs(row.mfu_bound - 1.0) < 1e-9


def test_active_param_count_moe_scaling():
    from repro.analysis import roofline as rf
    from repro import configs
    dsv3 = configs.get("deepseek-v3-671b")
    total_like = rf.active_param_count(dsv3.replace(experts_per_tok=256))
    active = rf.active_param_count(dsv3)
    assert active < total_like / 10       # top-8 of 256 experts
    dense = configs.get("qwen2-72b")
    n = rf.active_param_count(dense)
    assert 70e9 < n < 82e9                # ~72-80B params as configured
