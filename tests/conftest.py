import jax

# x64 for the numerical-analysis tests (integrators, solvers).  Model smoke
# tests run in default precision; they opt out via their own fixtures.
jax.config.update("jax_enable_x64", True)

# NOTE: we deliberately do NOT set xla_force_host_platform_device_count
# here — smoke tests and benches must see 1 device (system spec).  The
# multi-device dry-run tests spawn subprocesses with their own XLA_FLAGS.
