"""Policy-dispatch layer: jnp vs Pallas(interpret) backend parity.

Every op in the dispatch table must agree between backends on flat
arrays and on tuple/ManyVector pytrees, in float32 and float64, and the
integrators must produce matching trajectories under either policy —
the paper's swappable-ExecPolicy contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import tree_util as tu

from repro.core import dispatch as dp
from repro.core import vector as nv
from repro.core.policies import (BLOCK_REDUCE, GRID_STRIDE, THREAD_DIRECT,
                                 XLA_FUSED, ExecPolicy)

POLICIES = {"thread_direct": THREAD_DIRECT, "grid_stride": GRID_STRIDE,
            "block_reduce": BLOCK_REDUCE}


def _tol(dt):
    # f64 parity is the acceptance bar (1e-10); f32 is rounding-limited.
    return dict(rtol=1e-10, atol=1e-10) if dt == jnp.float64 else \
        dict(rtol=2e-5, atol=2e-5)


def _make_tree(kind, dt, seed=0):
    k = jax.random.PRNGKey(seed)
    if kind == "flat":
        return jax.random.normal(k, (777,)).astype(dt)
    if kind == "manyvector":
        # tuple-of-subvectors (ManyVector), incl. a 2-D leaf and a ragged
        # (non-lane-multiple) leaf
        return nv.many_vector(
            jax.random.normal(k, (300,)).astype(dt),
            jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (13, 5)).astype(dt))
    raise ValueError(kind)


def _assert_tree_close(got, want, dt):
    for g, w in zip(tu.tree_leaves(got), tu.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), **_tol(dt))


@pytest.mark.parametrize("pol", POLICIES.values(), ids=POLICIES.keys())
@pytest.mark.parametrize("kind", ["flat", "manyvector"])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.float64])
def test_streaming_ops_backend_parity(pol, kind, dt):
    x = _make_tree(kind, dt, 0)
    y = _make_tree(kind, dt, 10)
    z = _make_tree(kind, dt, 20)
    coeffs = [0.3, -1.2, 2.5]

    got = dp.linear_sum(2.0, x, -0.5, y, pol)
    _assert_tree_close(got, nv.linear_sum(2.0, x, -0.5, y), dt)
    assert tu.tree_leaves(got)[0].dtype == dt   # realtype preserved

    _assert_tree_close(dp.linear_combination(coeffs, [x, y, z], pol),
                       nv.linear_combination(coeffs, [x, y, z]), dt)
    _assert_tree_close(dp.axpy(1.7, x, y, pol), nv.axpy(1.7, x, y), dt)

    for g, w in zip(dp.scale_add_multi(coeffs, x, [x, y, z], pol),
                    nv.scale_add_multi(coeffs, x, [x, y, z])):
        _assert_tree_close(g, w, dt)


@pytest.mark.parametrize("pol", POLICIES.values(), ids=POLICIES.keys())
@pytest.mark.parametrize("kind", ["flat", "manyvector"])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.float64])
def test_reduction_ops_backend_parity(pol, kind, dt):
    x = _make_tree(kind, dt, 0)
    y = _make_tree(kind, dt, 10)
    w = tu.tree_map(lambda l: jnp.abs(l) + 0.1, x)
    m = tu.tree_map(lambda l: (l > 0).astype(l.dtype), x)

    np.testing.assert_allclose(float(dp.dot(x, y, pol)),
                               float(nv.dot(x, y)), **_tol(dt))
    np.testing.assert_allclose(float(dp.wrms_norm(x, w, pol)),
                               float(nv.wrms_norm(x, w)), **_tol(dt))
    np.testing.assert_allclose(float(dp.wrms_norm_mask(x, w, m, pol)),
                               float(nv.wrms_norm_mask(x, w, m)), **_tol(dt))
    np.testing.assert_allclose(np.asarray(dp.dot_prod_multi(x, [y, w, m],
                                                            pol)),
                               np.asarray(nv.dot_prod_multi(x, [y, w, m])),
                               **_tol(dt))
    np.testing.assert_allclose(float(dp.wrms_ss(x, w, pol)),
                               float(dp.wrms_ss(x, w, XLA_FUSED)), **_tol(dt))


def test_dispatch_table_and_fallbacks():
    # jnp / None fall through to the vector-module oracles
    x = jnp.arange(5.0)
    np.testing.assert_allclose(np.asarray(dp.linear_sum(1.0, x, 1.0, x)),
                               np.asarray(nv.linear_sum(1.0, x, 1.0, x)))
    assert set(dp.OP_TABLE) >= {"linear_sum", "linear_combination",
                                "scale_add_multi", "axpy", "dot",
                                "wrms_norm", "wrms_norm_mask",
                                "dot_prod_multi"}
    for entry in dp.OP_TABLE.values():
        assert "jnp" in entry and "pallas" in entry
    with pytest.raises(ValueError):
        dp.dispatch("dot", ExecPolicy(backend="cuda"))


def test_dispatch_under_jit_and_traced_coeffs():
    """Coefficients in the step loop are traced scalars (h*A[i][j])."""
    x = jnp.linspace(-1, 1, 300)
    y = jnp.cos(x)

    def f(h):
        return dp.linear_combination([1.0, h * 0.5, h * h], [x, y, x],
                                     GRID_STRIDE)

    got = jax.jit(f)(0.3)
    want = nv.linear_combination([1.0, 0.3 * 0.5, 0.09], [x, y, x])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-10, atol=1e-10)


def test_mesh_vector_policy_routing():
    spec = nv.MeshVectorSpec(policy=GRID_STRIDE)
    data = {"a": jnp.arange(4.0), "b": jnp.ones((3,))}
    mv = nv.MeshVector(data, spec)
    ref = nv.MeshVector(data)
    w = mv.const(1.0)
    wr = ref.const(1.0)
    np.testing.assert_allclose(float(mv.dot(mv)), float(ref.dot(ref)),
                               rtol=1e-12)
    np.testing.assert_allclose(float(mv.wrms_norm(w)),
                               float(ref.wrms_norm(wr)), rtol=1e-12)
    got = mv.linear_sum(2.0, -1.0, mv).data["a"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(jnp.arange(4.0)),
                               rtol=1e-12)


def test_erk_trajectory_identical_across_policies():
    """arkode.erk_integrate: XLA_FUSED vs GRID_STRIDE trajectories match
    to 1e-10 in float64 (same steps, same result)."""
    from repro.core import arkode, butcher
    from repro.core.arkode import ODEOptions

    def f(t, y):
        return -y + jnp.sin(3.0 * t) * jnp.ones_like(y)

    y0 = jnp.linspace(0.5, 1.5, 6)
    base = dict(rtol=1e-8, atol=1e-10)
    y_j, st_j = arkode.erk_integrate(f, y0, 0.0, 2.0,
                                     butcher.DORMAND_PRINCE,
                                     ODEOptions(**base, policy=XLA_FUSED))
    y_p, st_p = arkode.erk_integrate(f, y0, 0.0, 2.0,
                                     butcher.DORMAND_PRINCE,
                                     ODEOptions(**base, policy=GRID_STRIDE))
    assert bool(st_j.success) and bool(st_p.success)
    assert int(st_j.steps) == int(st_p.steps)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_j),
                               rtol=1e-10, atol=1e-10)


def test_dirk_and_bdf_match_across_policies():
    """Implicit paths (Newton + GMRES + WRMS) under the pallas policy."""
    from repro.core import arkode, butcher, cvode
    from repro.core.arkode import ODEOptions

    def fi(t, y):
        return -20.0 * (y - jnp.cos(t))

    y0 = jnp.ones((4,))
    base = dict(rtol=1e-6, atol=1e-9)
    y_j, sj = arkode.dirk_integrate(fi, y0, 0.0, 1.0, butcher.SDIRK2,
                                    ODEOptions(**base, policy=XLA_FUSED))
    y_p, sp = arkode.dirk_integrate(fi, y0, 0.0, 1.0, butcher.SDIRK2,
                                    ODEOptions(**base, policy=GRID_STRIDE))
    assert bool(sj.success) and bool(sp.success)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_j),
                               rtol=1e-10, atol=1e-10)

    yb_j, bj = cvode.bdf_integrate(fi, y0, 0.0, 1.0, dense_jac=True,
                                   opts=ODEOptions(**base,
                                                   policy=XLA_FUSED))
    yb_p, bp = cvode.bdf_integrate(fi, y0, 0.0, 1.0, dense_jac=True,
                                   opts=ODEOptions(**base,
                                                   policy=GRID_STRIDE))
    assert bool(bj.success) and bool(bp.success)
    np.testing.assert_allclose(np.asarray(yb_p), np.asarray(yb_j),
                               rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("solver", ["pcg", "bicgstab", "tfqmr", "gmres"])
def test_krylov_policy_parity(solver):
    from repro.core import krylov
    n = 40
    key = jax.random.PRNGKey(3)
    A = jax.random.normal(key, (n, n))
    A = A @ A.T + n * jnp.eye(n)          # SPD so pcg works too
    b = jax.random.normal(jax.random.PRNGKey(4), (n,))

    def matvec(v):
        return A @ v

    fn = getattr(krylov, solver)
    x_j, st_j = fn(matvec, b, tol=1e-10, policy=XLA_FUSED)
    x_p, st_p = fn(matvec, b, tol=1e-10, policy=GRID_STRIDE)
    assert bool(st_j.converged) and bool(st_p.converged)
    np.testing.assert_allclose(np.asarray(x_p), np.asarray(x_j),
                               rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(np.asarray(A @ x_p), np.asarray(b),
                               rtol=1e-7, atol=1e-7)


def test_new_fused_kernels_match_refs():
    """Oracle checks for the kernels added for the dispatch layer."""
    from repro.kernels import ops, ref
    for N in (1, 127, 128, 129, 5000):
        x = jax.random.normal(jax.random.PRNGKey(N), (N,))
        Y = jax.random.normal(jax.random.PRNGKey(N + 1), (4, N))
        c = jnp.asarray([0.5, -1.0, 2.0, 0.25])
        w = jnp.abs(jax.random.normal(jax.random.PRNGKey(N + 2), (N,))) + 0.1
        m = (jax.random.uniform(jax.random.PRNGKey(N + 3), (N,)) > 0.5)
        m = m.astype(x.dtype)
        np.testing.assert_allclose(np.asarray(ops.scale_add_multi(c, x, Y)),
                                   np.asarray(ref.scale_add_multi_ref(c, x,
                                                                      Y)),
                                   rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(np.asarray(ops.dot_prod_multi(x, Y)),
                                   np.asarray(ref.dot_prod_multi_ref(x, Y)),
                                   rtol=1e-10, atol=1e-10)
        np.testing.assert_allclose(
            float(ops.wrms_norm_mask(x, w, m)),
            float(jnp.sqrt(ref.wrms_mask_partial_ref(x, w, m) / N)),
            rtol=1e-12, atol=1e-12)


def test_vector_dot_result_type_includes_y():
    """dot(f32 x, f64 y) accumulates in f64 (both operands considered)."""
    x = jnp.ones((8,), jnp.float32)
    y = jnp.full((8,), 1e-9, jnp.float64)
    assert nv.dot(x, y).dtype == jnp.float64
    assert dp.dot(x, y, GRID_STRIDE).dtype == jnp.float64
