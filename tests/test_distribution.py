"""Distribution tests: sharding rules, MoE EP vs dense oracle, small-mesh
dry-run — multi-device paths run in subprocesses with their own XLA_FLAGS
(this process must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel import sharding as shd

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_py(code: str, devices: int = 8, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


class FakeMesh:
    """Just enough for spec_for without touching jax devices."""

    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as _np
        self.devices = _np.zeros(tuple(sizes.values()))


def test_spec_for_divisibility_fallbacks():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    # fully divisible: both rules apply
    s = shd.spec_for((8192, 64, 128), ("embed", "heads", "head_dim"),
                     mesh, shd.PARAM_RULES)
    assert s[0] == ("pod", "data") and s[1] == "model"
    # 36 heads don't divide 16 -> replicated
    s = shd.spec_for((4608, 36, 128), ("embed", "heads", "head_dim"),
                     mesh, shd.PARAM_RULES)
    assert len(s) < 2 or s[1] is None
    # experts: 256 divides model*data -> owned; 16 shrinks to model-only
    s = shd.spec_for((256, 7168, 2048), ("experts", "embed", "expert_mlp"),
                     mesh, shd.PARAM_RULES)
    assert s[0] == ("model", "data")
    s = shd.spec_for((16, 6144, 10752), ("experts", "embed", "expert_mlp"),
                     mesh, shd.PARAM_RULES)
    assert s[0] == "model"
    # a mesh axis never appears twice (uniqueness)
    s = shd.spec_for((7168, 1536), ("embed", "q_lora"), mesh,
                     shd.PARAM_RULES)
    flat = []
    for e in s:
        if e is None:
            continue
        flat.extend(e if isinstance(e, tuple) else (e,))
    assert len(flat) == len(set(flat))
    # batch=1 decode falls back to replication
    s = shd.spec_for((1, 1), ("batch", "seq"), mesh, shd.ACT_RULES)
    assert all(e is None for e in s) or len(s) == 0


def test_fsdp_profile_rules():
    mesh = FakeMesh({"data": 16, "model": 16})
    prules, arules = shd.PROFILES["fsdp"]
    s = shd.spec_for((8192, 64, 128), ("embed", "heads", "head_dim"),
                     mesh, prules)
    assert s[0] == ("data", "model")   # pod absent -> dropped
    s = shd.spec_for((256, 4096, 8192), ("batch", "seq", "embed"),
                     mesh, arules)
    assert s[0] == "data" and s[1] == "model"


@pytest.mark.slow
def test_moe_ep_matches_dense_oracle():
    """EP (shard_map + all_to_all) == dense MoE when under capacity."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models import Model, ParallelCtx, transformer as T
        from repro.models import layers, moe_ep
        from repro.parallel import sharding as shd
        cfg = configs.get("dbrx-132b-smoke").replace(
            moe_cap_factor=8.0, dtype=jnp.float32)  # no drops
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        lp = jax.tree_util.tree_map(lambda x: x[0], params["layers"])
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                                    cfg.dtype)
        dense = layers.moe_dense_apply(lp["ffn"], cfg, x)
        ep = jax.jit(lambda x: moe_ep.moe_ep_apply(
            lp["ffn"], cfg, x, mesh, dp_axes=("data",), ep_axis="model",
            token_layout="split"))(x)
        err = float(jnp.max(jnp.abs(dense - ep)))
        assert err < 2e-4, err
        # multi-axis EP (experts owned per chip: 4 experts / 8 chips -> no;
        # use 8 experts)
        cfg2 = cfg.replace(n_experts=8)
        from repro.models.spec import init_params
        p2 = init_params(layers.moe_spec(cfg2), jax.random.PRNGKey(2))
        dense2 = layers.moe_dense_apply(p2, cfg2, x)
        ep2 = jax.jit(lambda x: moe_ep.moe_ep_apply(
            p2, cfg2, x, mesh, dp_axes=("data",),
            ep_axis=("model", "data"), token_layout="split"))(x)
        err2 = float(jnp.max(jnp.abs(dense2 - ep2)))
        assert err2 < 2e-4, err2
        # decode layout (tokens replicated over model, single-axis psum)
        ep3 = jax.jit(lambda x: moe_ep.moe_ep_apply(
            lp["ffn"], cfg, x, mesh, dp_axes=("data",), ep_axis="model",
            token_layout="replicated"))(x)
        err3 = float(jnp.max(jnp.abs(dense - ep3)))
        assert err3 < 2e-4, err3
        # decode layout, multi-axis (duplicated dispatch path)
        ep4 = jax.jit(lambda x: moe_ep.moe_ep_apply(
            p2, cfg2, x, mesh, dp_axes=("data",),
            ep_axis=("model", "data"), token_layout="replicated"))(x)
        err4 = float(jnp.max(jnp.abs(dense2 - ep4)))
        assert err4 < 2e-4, err4
        print("OK", err, err2, err3, err4)
    """)
    out = _run_py(code, devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_small_mesh_dryrun_and_sharded_equals_single():
    """(a) dry-run machinery on an 8-device debug mesh; (b) sharded train
    step loss == single-device loss (GSPMD correctness)."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.config import ShapeConfig, SHAPES
        SHAPES["tiny_train"] = ShapeConfig("tiny_train", 32, 8, "train")
        SHAPES["tiny_decode"] = ShapeConfig("tiny_decode", 32, 8, "decode")
        from repro import configs
        from repro.launch import dryrun
        from repro.models import Model
        from repro.train import step as tstep
        from repro.parallel import sharding as shd
        from repro.data import pipeline
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        for arch in ("internlm2-1.8b-smoke", "deepseek-v3-671b-smoke"):
            for shape in ("tiny_train", "tiny_decode"):
                res = dryrun.lower_cell(arch, shape, mesh, "debug")
                assert res["ok"], (arch, shape)
                assert res["roofline"]["hlo_flops"] > 0
        # GSPMD equivalence: same data, same init -> same loss
        cfg = configs.get("internlm2-1.8b-smoke").replace(dtype=jnp.float32)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        d = pipeline.DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8)
        b = {k: jnp.asarray(v) for k, v in
             pipeline.synthetic_batch(d, 0).items()}
        loss1 = float(m.loss(params, b))
        pctx = dryrun.make_pctx(cfg, mesh, "train")
        pshd = shd.param_shardings(
            jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(
                x.shape, x.dtype), params), m.param_axes(), mesh)
        params_sh = jax.device_put(params, pshd)
        loss2 = float(jax.jit(lambda p, b: m.loss(p, b, pctx))(params_sh, b))
        assert abs(loss1 - loss2) < 1e-4, (loss1, loss2)
        print("OK", loss1, loss2)
    """)
    out = _run_py(code, devices=8)
    assert "OK" in out


def test_cache_axes_structure_matches():
    from repro import configs
    from repro.models import Model
    for arch in ("qwen2-72b", "deepseek-v3-671b", "zamba2-7b",
                 "xlstm-125m", "whisper-tiny"):
        cfg = configs.get(arch)
        cs = Model(cfg).cache_specs(4, 64)
        ax = shd.cache_axes_like(cs, cfg)
        la = jax.tree_util.tree_leaves(ax, is_leaf=lambda x:
                                       isinstance(x, tuple))
        ls = jax.tree_util.tree_leaves(cs)
        assert len(la) == len(ls)
        for a, s in zip(la, ls):
            assert len(a) == len(s.shape), (arch, a, s.shape)
