"""Sparse SUNMatrix subsystem: matrix types (SparseCSR / EnsembleBSR),
the three dispatched sparse ops (jnp oracle vs Pallas-interpret to
1e-10, ragged batches included), and the static-pattern LU split
backing EnsembleSparseGJ."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch as dv
from repro.core import spsolve
from repro.core.linsol import EnsembleSparseGJ, encode_sparsity
from repro.core.policies import ExecPolicy, XLA_FUSED
from repro.core.sunmatrix import (EnsembleBSR, SparseCSR,
                                  block_pattern_from_element)

PALLAS = ExecPolicy(backend="pallas", interpret=True)


def _random_sparse(n, density, key=0, diag_boost=6.0):
    rng = np.random.default_rng(key)
    A = rng.normal(size=(n, n)) * (rng.random((n, n)) < density)
    A += np.diag(diag_boost + rng.random(n))
    return A


# ---------------------------------------------------------------------------
# SparseCSR
# ---------------------------------------------------------------------------


def test_sparse_csr_roundtrip_and_scale_addi():
    A = _random_sparse(13, 0.25)
    csr = SparseCSR.from_dense(A)
    assert csr.nnz == int((np.abs(A) > 0).sum())
    np.testing.assert_allclose(np.asarray(csr.to_dense()), A, atol=0)
    # SUNMatScaleAddI: values-only update, pattern reused
    M = csr.scale_addI(-0.37)
    np.testing.assert_allclose(np.asarray(M.to_dense()),
                               np.eye(13) - 0.37 * A, atol=1e-15)
    assert M.pattern == csr.pattern


def test_sparse_csr_scale_addi_requires_diagonal():
    A = np.zeros((3, 3))
    A[0, 1] = 1.0
    A[1, 0] = 2.0
    A[2, 2] = 3.0
    csr = SparseCSR.from_dense(A)          # diagonal (0,0),(1,1) absent
    with pytest.raises(ValueError, match="diagonal"):
        csr.scale_addI(-1.0)
    # ensure_diag materializes explicit zeros so the update is legal
    csr2 = SparseCSR.from_dense(A, ensure_diag=True)
    np.testing.assert_allclose(np.asarray(csr2.scale_addI(-1.0).to_dense()),
                               np.eye(3) - A, atol=0)


@pytest.mark.parametrize("n", [6, 130, 517])
def test_csr_spmv_dispatch_parity(n):
    A = _random_sparse(n, 0.1, key=n)
    csr = SparseCSR.from_dense(A)
    x = jnp.asarray(np.random.default_rng(1).normal(size=n))
    y_ref = jnp.asarray(A) @ x
    y_j = dv.csr_spmv(csr.data, x, csr.pattern, XLA_FUSED)
    y_p = dv.csr_spmv(csr.data, x, csr.pattern, PALLAS)
    np.testing.assert_allclose(np.asarray(y_j), np.asarray(y_ref),
                               atol=1e-12)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_j),
                               atol=1e-10)


# ---------------------------------------------------------------------------
# EnsembleBSR
# ---------------------------------------------------------------------------


def _block_tridiag_pattern(nblk, b):
    n = nblk * b
    P = np.zeros((n, n), bool)
    for i in range(nblk):
        for j in (i - 1, i, i + 1):
            if 0 <= j < nblk:
                P[i * b:(i + 1) * b, j * b:(j + 1) * b] = True
    return P


@pytest.mark.parametrize("nsys", [7, 130])
@pytest.mark.parametrize("nblk,b", [(4, 3), (3, 8)])
def test_ensemble_bsr_roundtrip_spmv_scale_addi(nsys, nblk, b):
    P = _block_tridiag_pattern(nblk, b)
    n = nblk * b
    rng = np.random.default_rng(0)
    J = jnp.asarray(rng.normal(size=(nsys, n, n)) * P)
    bsr = EnsembleBSR.from_dense(J, b, pattern=P)
    assert bsr.nnz_blocks == 3 * nblk - 2
    assert bsr.values.shape == (nsys, bsr.nnz_blocks, b, b)
    np.testing.assert_allclose(np.asarray(bsr.to_dense()), np.asarray(J),
                               atol=0)
    x = jnp.asarray(rng.normal(size=(nsys, n)))
    y_ref = jnp.einsum("sij,sj->si", J, x)
    for pol in (XLA_FUSED, PALLAS):
        np.testing.assert_allclose(np.asarray(bsr.matvec(x, pol)),
                                   np.asarray(y_ref), atol=1e-10)
    gam = jnp.asarray(rng.random(nsys))
    M = bsr.scale_addI(-gam)
    M_ref = jnp.eye(n)[None] - gam[:, None, None] * J
    np.testing.assert_allclose(np.asarray(M.to_dense()),
                               np.asarray(M_ref), atol=1e-15)


def test_block_pattern_from_element_collapses_and_keeps_diag():
    P = np.zeros((6, 6), bool)
    P[0, 3] = True                  # one entry -> whole (0,1) block
    brows, bcols, nblk = block_pattern_from_element(P, 3)
    assert nblk == 2
    assert set(zip(brows, bcols)) == {(0, 0), (0, 1), (1, 1)}


@pytest.mark.parametrize("nsys", [7, 130, 517])
def test_bsr_ops_dispatch_parity_ragged_batches(nsys):
    nblk, b = 5, 3
    P = _block_tridiag_pattern(nblk, b)
    rng = np.random.default_rng(nsys)
    n = nblk * b
    J = jnp.asarray(rng.normal(size=(nsys, n, n)) * P +
                    (b + 3.0) * np.eye(n))
    bsr = EnsembleBSR.from_dense(J, b, pattern=P)
    V = bsr.values_soa                       # (nnzb, b, b, nsys)
    x = jnp.asarray(rng.normal(size=(nblk, b, nsys)))
    pat = bsr.block_pattern
    for tile in (128, 512):
        pol = ExecPolicy(backend="pallas", interpret=True,
                         batch_tile=tile)
        np.testing.assert_allclose(
            np.asarray(dv.bsr_spmv_soa(V, x, pat, pol)),
            np.asarray(dv.bsr_spmv_soa(V, x, pat, XLA_FUSED)),
            atol=1e-10)
        np.testing.assert_allclose(
            np.asarray(dv.bsr_block_jacobi_inverse_soa(V, pat, pol)),
            np.asarray(dv.bsr_block_jacobi_inverse_soa(V, pat,
                                                       XLA_FUSED)),
            atol=1e-10)


def test_bsr_diag_inverse_inverts():
    nblk, b, nsys = 4, 3, 9
    P = _block_tridiag_pattern(nblk, b)
    rng = np.random.default_rng(3)
    n = nblk * b
    J = jnp.asarray(rng.normal(size=(nsys, n, n)) * P +
                    (b + 3.0) * np.eye(n))
    bsr = EnsembleBSR.from_dense(J, b, pattern=P)
    inv = dv.bsr_block_jacobi_inverse_soa(bsr.values_soa,
                                          bsr.block_pattern, XLA_FUSED)
    inv = np.asarray(inv).reshape(b, b, nblk, nsys)
    for I in range(nblk):
        for s in range(nsys):
            D = np.asarray(J)[s, I * b:(I + 1) * b, I * b:(I + 1) * b]
            np.testing.assert_allclose(inv[:, :, I, s] @ D, np.eye(b),
                                       atol=1e-10)


# ---------------------------------------------------------------------------
# static-pattern LU (the EnsembleSparseGJ engine)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("order", [True, False])
def test_spsolve_lu_matches_dense(order):
    n, nsys = 14, 6
    A = _random_sparse(n, 0.2, key=5)
    enc = spsolve.encode_pattern(np.abs(A) > 0)
    plan = spsolve.symbolic_lu(*enc, order=order, fill=True)
    rng = np.random.default_rng(7)
    M = jnp.asarray(A)[:, :, None] * jnp.ones((1, 1, nsys)) + \
        jnp.asarray(rng.normal(size=(n, n, nsys)) * 0.1 *
                    (np.abs(A) > 0)[..., None])
    f = spsolve.numeric_lu(plan, spsolve.gather_filled(plan, M))
    rhs = jnp.asarray(rng.normal(size=(n, nsys)))
    x = spsolve.lu_solve(plan, f, rhs)
    ref = jnp.linalg.solve(jnp.transpose(M, (2, 0, 1)),
                           jnp.transpose(rhs)[..., None])[..., 0].T
    np.testing.assert_allclose(np.asarray(x), np.asarray(ref),
                               atol=1e-10)


def test_spsolve_rcm_ordering_reduces_fill():
    # an arrowhead matrix eliminated in natural order fills completely;
    # RCM pushes the hub last and the factorization stays sparse
    n = 12
    P = np.eye(n, dtype=bool)
    P[0, :] = True
    P[:, 0] = True
    enc = spsolve.encode_pattern(P)
    plan_nat = spsolve.symbolic_lu(*enc, order=False, fill=True)
    plan_rcm = spsolve.symbolic_lu(*enc, order=True, fill=True)
    assert plan_nat.nnz_factored == n * n
    assert plan_rcm.nnz_factored == int(P.sum())


def test_ensemble_sparse_gj_setup_solve_roundtrip():
    n, nsys = 10, 5
    A = _random_sparse(n, 0.25, key=11)
    P = np.abs(A) > 0
    ls = EnsembleSparseGJ(sparsity=P)
    rng = np.random.default_rng(1)
    Jsoa = jnp.asarray(A)[:, :, None] + \
        jnp.asarray(rng.normal(size=(n, n, nsys)) * 0.05 * P[..., None])
    gamma = jnp.asarray(0.1 + 0.05 * rng.random(nsys))
    F = ls.soa_setup(Jsoa, gamma, None)
    # saved object is O(nnz_factored), not O(n^2)
    assert F.shape[0] < n * n and F.shape[1] == nsys
    rhs = jnp.asarray(rng.normal(size=(n, nsys)))
    x, nli, nps = ls.soa_solve(F, gamma, jnp.ones((nsys,)), rhs, None)
    assert int(nli) == 0 and int(nps) == 0
    M = jnp.eye(n)[:, :, None] - gamma[None, None, :] * Jsoa
    ref = jnp.linalg.solve(jnp.transpose(M, (2, 0, 1)),
                           jnp.transpose(rhs)[..., None])[..., 0].T
    np.testing.assert_allclose(np.asarray(x), np.asarray(ref),
                               atol=1e-9)


def test_ensemble_sparse_gj_needs_pattern():
    ls = EnsembleSparseGJ()
    with pytest.raises(ValueError, match="sparsity"):
        ls.soa_carry_init(4, 2, jnp.float64)
    bound = ls.with_sparsity(encode_sparsity(np.eye(4, dtype=bool)))
    assert bound.soa_carry_init(4, 2, jnp.float64).shape == (4, 2)
