"""Batched-BDF ensemble subsystem tests: per-system adaptivity, the
jnp-oracle vs Pallas(interpret) block-kernel parity (incl. a batch that
is not a multiple of 128), Jacobian-reuse (lsetup) accounting, and the
shard_map system-axis path."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched, dispatch as dv
from repro.core.arkode import ODEOptions
from repro.core.linsol import BlockDiagGJ
from repro.core.policies import ExecPolicy, XLA_FUSED
from repro.kernels import ops, ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# the batched-kinetics example problem (Robertson with per-cell rates)
# is shared with the example and the benchmark
from repro.core.problems import batched_robertson as _kinetics


def _decay(nsys, n):
    rates = jnp.linspace(10.0, 80.0, nsys)

    def f(t, y):
        return -rates[:, None] * (y - jnp.cos(t)[:, None])

    def jac(t, y):
        return jnp.broadcast_to(-rates[:, None, None] * jnp.eye(n),
                                (y.shape[0], n, n))

    lam = np.asarray(rates)[:, None]

    def exact(t):
        return (lam * (lam * np.cos(t) + np.sin(t)) -
                lam ** 2 * np.exp(-lam * t)) / (lam ** 2 + 1)

    return f, jac, exact


@pytest.mark.parametrize("factor_once", [True, False],
                         ids=["setup", "direct"])
def test_bdf_accuracy_and_per_system_control(factor_once):
    nsys, n = 6, 3
    f, jac, exact = _decay(nsys, n)
    y0 = jnp.zeros((nsys, n))
    y, st = batched.ensemble_bdf_integrate(
        f, jac, y0, 0.0, 2.0, opts=ODEOptions(rtol=1e-6, atol=1e-10),
        linear_solver=BlockDiagGJ(factor_once=factor_once))
    assert bool(jnp.all(st.success))
    np.testing.assert_allclose(np.asarray(y),
                               np.broadcast_to(exact(2.0), (nsys, n)),
                               rtol=1e-4, atol=1e-6)
    # per-system step control: step counts differ across stiffness
    steps = np.asarray(st.steps)
    assert steps.min() != steps.max()
    # modified Newton reuses the Jacobian: lsetups well below steps
    assert np.all(np.asarray(st.nsetups) < 0.7 * steps)
    # nni is counted per system
    assert np.asarray(st.nni).min() > 0


def test_bdf_high_order_beats_low_order():
    """Order ramp must pay off: BDF5 needs far fewer steps than BDF2.
    (order=1 is not compared: the scalar seed bdf_integrate stalls there
    on this problem too — shared fixed-leading-coefficient limitation.)"""
    nsys, n = 4, 3
    f, jac, _ = _decay(nsys, n)
    y0 = jnp.zeros((nsys, n))
    opts = ODEOptions(rtol=1e-7, atol=1e-10)
    _, st5 = batched.ensemble_bdf_integrate(f, jac, y0, 0.0, 2.0,
                                            order=5, opts=opts)
    _, st2 = batched.ensemble_bdf_integrate(f, jac, y0, 0.0, 2.0,
                                            order=2, opts=opts)
    assert bool(jnp.all(st5.success)) and bool(jnp.all(st2.success))
    assert np.median(np.asarray(st5.steps)) < \
        0.7 * np.median(np.asarray(st2.steps))


@pytest.mark.parametrize("factor_once", [True, False],
                         ids=["setup", "direct"])
def test_bdf_kinetics_jnp_vs_pallas_parity(factor_once):
    """Acceptance gate: trajectories agree between the jnp oracle and the
    Pallas(interpret) fused-kernel path at controller-tolerance scale on
    the batched-kinetics example, with nsys NOT a multiple of 128.

    The bound is the controller's, not machine eps: the fused
    Newton/history kernels round independently of XLA's fusion of the
    inline oracles (e.g. z + corr*spmv FMA-contracts inline but not
    across a kernel boundary), so per-system accept/order decisions can
    flip and the two *valid* adaptive trajectories separate by the
    local error the controller permits — which the WRMS control bounds
    PER COMPONENT as C*(rtol*|y_i| + atol), so the comparison uses the
    same mixed form (C=100) and the ~1e-5-magnitude intermediate
    species stays genuinely exercised.  Op-level parity is gated
    separately at 1e-10 (test_soa_carry.py, kernels_bench --smoke); the
    jnp path itself is pinned bitwise to the pre-SoA integrator in
    test_soa_carry.py."""
    nsys = 130
    ls = BlockDiagGJ(factor_once=factor_once)
    f, jac, y0 = _kinetics(nsys)
    opts = ODEOptions(rtol=1e-5, atol=1e-10, max_steps=100_000)
    y_j, st_j = batched.ensemble_bdf_integrate(
        f, jac, y0, 0.0, 10.0, opts=opts, policy=XLA_FUSED,
        linear_solver=ls)
    pol = ExecPolicy(backend="pallas", interpret=True, batch_tile=256)
    y_p, st_p = batched.ensemble_bdf_integrate(
        f, jac, y0, 0.0, 10.0, opts=opts, policy=pol, linear_solver=ls)
    assert bool(jnp.all(st_j.success)) and bool(jnp.all(st_p.success))
    np.testing.assert_allclose(np.asarray(y_j), np.asarray(y_p),
                               rtol=100 * opts.rtol, atol=100 * opts.atol)
    # physically sensible on BOTH backends: mass conserved to tol scale
    assert float(jnp.max(jnp.abs(jnp.sum(y_j, 1) - 1.0))) < 1e-4
    assert float(jnp.max(jnp.abs(jnp.sum(y_p, 1) - 1.0))) < 1e-4


def test_bdf_matches_scalar_cvode_reference():
    """One system of the ensemble path vs the scalar CVODE analog."""
    from repro.core import cvode
    n = 3
    f1 = lambda t, y: -40.0 * (y - jnp.cos(t))
    fb = lambda t, y: -40.0 * (y - jnp.cos(t)[:, None])
    jacb = lambda t, y: jnp.broadcast_to(-40.0 * jnp.eye(n),
                                         (y.shape[0], n, n))
    y0 = jnp.zeros((n,))
    opts = ODEOptions(rtol=1e-7, atol=1e-12)
    y_ref, st_ref = cvode.bdf_integrate(f1, y0, 0.0, 1.5, opts=opts,
                                        dense_jac=True)
    y_ens, st_ens = batched.ensemble_bdf_integrate(
        fb, jacb, y0[None, :], 0.0, 1.5, opts=opts)
    assert bool(st_ref.success) and bool(jnp.all(st_ens.success))
    # both must hit the analytic solution at their shared tolerance
    lam = 40.0
    exact = (lam * (lam * np.cos(1.5) + np.sin(1.5)) -
             lam ** 2 * np.exp(-lam * 1.5)) / (lam ** 2 + 1)
    np.testing.assert_allclose(np.asarray(y_ens)[0], exact, rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(y_ref), exact, rtol=1e-5,
                               atol=1e-7)


# ---------------------------------------------------------------------------
# dispatched SoA block ops: jnp oracle vs pallas-interpret
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb", [7, 130, 516])
@pytest.mark.parametrize("b", [3, 8, 16, 24])
def test_block_ops_dispatch_parity_ragged_batches(nb, b):
    """b <= 8 exercises the fully-unrolled GJ kernels, b >= 16 the
    row-tiled elimination that replaced them at large block sizes."""
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (b, b, nb)) + \
        (b + 2.0) * jnp.eye(b)[:, :, None]
    r = jax.random.normal(jax.random.PRNGKey(1), (b, nb))
    for tile in (128, 512):
        pol = ExecPolicy(backend="pallas", interpret=True, batch_tile=tile)
        np.testing.assert_allclose(
            np.asarray(dv.block_solve_soa(A, r, pol)),
            np.asarray(dv.block_solve_soa(A, r, XLA_FUSED)), atol=1e-10)
        np.testing.assert_allclose(
            np.asarray(dv.block_inverse_soa(A, pol)),
            np.asarray(dv.block_inverse_soa(A, XLA_FUSED)), atol=1e-10)
        np.testing.assert_allclose(
            np.asarray(dv.blockdiag_spmv_soa(A, r, pol)),
            np.asarray(dv.blockdiag_spmv_soa(A, r, XLA_FUSED)), atol=1e-12)


def test_block_inverse_kernel_vs_ref():
    key = jax.random.PRNGKey(2)
    A = jax.random.normal(key, (4, 4, 200)) + 6.0 * jnp.eye(4)[:, :, None]
    inv = ops.block_inverse_soa(A, batch_tile=128)
    np.testing.assert_allclose(np.asarray(inv),
                               np.asarray(ref.block_inverse_soa_ref(A)),
                               atol=1e-10)
    # identity check through the spmv kernel (lsetup @ lsolve round trip)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 200))
    y = ops.blockdiag_spmv_soa(inv, ops.blockdiag_spmv_soa(A, x))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-9)


def test_batch_tile_knob_is_honored():
    """Tiles above one lane must reach the kernel grid (regression: the
    old wrappers clamped every tile to 128), and the tile must divide
    the lane-padded batch so padding stays below one lane (regression:
    a rounded-up tile could pad nb=516 out to 1024, ~2x the work)."""
    from repro.kernels.ops import _batch_tile
    assert _batch_tile(4096, 512) == 512
    assert _batch_tile(4096, 300) == 256     # largest divisor <= knob
    assert _batch_tile(200, 512) == 256      # clamped to padded batch
    assert _batch_tile(7, 128) == 128
    assert _batch_tile(516, 512) == 128      # 640 % 512 != 0 -> one lane
    assert _batch_tile(516, 128 * 5) == 640  # exact bundle still taken


def test_gj_vmem_tile_cap_shrinks_with_b_squared():
    """Compiled-mode GJ tiles are clamped so the (b, width, tile) f64
    accumulator stays under GJ_VMEM_BYTES — the cap shrinks ~1/b^2.
    Interpret mode (CPU emulation, no VMEM) is uncapped.  This branch
    only executes on real TPU, so it is pinned here as pure arithmetic."""
    from repro.kernels.ops import _gj_batch_tile
    kw = dict(itemsize=8, interpret=False)
    # no cap under interpret emulation
    assert _gj_batch_tile(4096, 4096, b=16, width=17,
                          itemsize=8, interpret=True) == 4096
    # b=16 solve: 2MiB/(8*16*17)=963 -> 896 lanes-floor -> divisor 512
    assert _gj_batch_tile(4096, 4096, b=16, width=17, **kw) == 512
    # b=24 solve: 2MiB/(8*24*25)=436 -> 384 -> divisor 256
    assert _gj_batch_tile(4096, 4096, b=24, width=25, **kw) == 256
    # small blocks: cap (21k+) never binds on a practical tile
    assert _gj_batch_tile(4096, 512, b=3, width=4, **kw) == 512
    # floor at one lane even when the budget math rounds to zero
    assert _gj_batch_tile(4096, 4096, b=64, width=65, **kw) == 128


# ---------------------------------------------------------------------------
# sharded system axis (subprocess with its own fake-device XLA flags)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_bdf_sharded_matches_single_device():
    code = textwrap.dedent("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro.core import batched
        from repro.core.arkode import ODEOptions
        nsys, n = 10, 3   # not divisible by 4 -> exercises padding
        rates = jnp.linspace(10.0, 80.0, nsys)
        def f(t, y, prm):
            return -prm[:, None] * (y - jnp.cos(t)[:, None])
        def jac(t, y, prm):
            return jnp.broadcast_to(-prm[:, None, None] * jnp.eye(n),
                                    (y.shape[0], n, n))
        y0 = jnp.zeros((nsys, n))
        opts = ODEOptions(rtol=1e-6, atol=1e-10)
        y_sh, st = batched.ensemble_bdf_integrate_sharded(
            f, jac, y0, 0.0, 2.0, params=rates, opts=opts)
        y_1, _ = batched.ensemble_bdf_integrate(
            lambda t, y: f(t, y, rates), lambda t, y: jac(t, y, rates),
            y0, 0.0, 2.0, opts=opts)
        assert y_sh.shape == (nsys, n)
        assert bool(jnp.all(st.success))
        assert st.steps.shape == (nsys,)
        np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_1),
                                   rtol=0, atol=1e-12)
        # pluggable Krylov under shard_map: nli must keep its invariant
        # (every entry == the GLOBAL inner-iteration total, not a
        # per-shard broadcast)
        from repro.core.linsol import SPGMR
        y_k, st_k = batched.ensemble_bdf_integrate_sharded(
            f, jac, y0, 0.0, 2.0, params=rates, opts=opts,
            linear_solver=SPGMR(tol=1e-12, restart=30, max_restarts=6))
        assert int(np.asarray(st_k.nli)[0]) > 0
        assert len(np.unique(np.asarray(st_k.nli))) == 1
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_1),
                                   rtol=0, atol=1e-6)
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "OK" in out.stdout
