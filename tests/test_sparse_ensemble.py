"""Acceptance gates for the sparse subsystem (ISSUE 4): EnsembleSparseGJ
and preconditioned SPGMR each reproduce the dense BlockDiagGJ ensemble-
BDF trajectory on batched_robertson within 1e-8; workspace bytes are
strictly lower than dense at fill <= 25%; npsolves/npsetups surface
through Solution; and the MemoryHelper label accounting survives two
back-to-back integrate() calls on one Context."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched
from repro.core.arkode import ODEOptions
from repro.core.context import Context
from repro.core.ivp import IVP, integrate
from repro.core.linsol import SPGMR, BlockDiagGJ, EnsembleSparseGJ
from repro.core.policies import ExecPolicy, XLA_FUSED
from repro.core.precond import BlockJacobiPrecond, ILU0Precond
from repro.core.problems import batched_robertson, ensemble_brusselator

# the Robertson Jacobian pattern (row 3 of the analytic jac has a lone
# k3 term; the diagonal is forced in by the encoders)
ROBERTSON_PATTERN = np.array([[1, 1, 1], [1, 1, 1], [0, 1, 0]], bool)


def _robertson_runs(lin_solver, jac_sparsity=None, nsys=24, tf=10.0):
    f, jac, y0 = batched_robertson(nsys)
    opts = ODEOptions(rtol=1e-9, atol=1e-13, max_steps=400_000)
    return batched.ensemble_bdf_integrate(
        f, jac, y0, 0.0, tf, opts=opts, linear_solver=lin_solver,
        jac_sparsity=jac_sparsity)


@pytest.fixture(scope="module")
def dense_reference():
    return _robertson_runs(BlockDiagGJ())


def test_sparse_direct_matches_dense_trajectory(dense_reference):
    """Acceptance: EnsembleSparseGJ reproduces the dense BlockDiagGJ
    batched_robertson trajectory within 1e-8."""
    y_d, st_d = dense_reference
    y_s, st_s = _robertson_runs(EnsembleSparseGJ(),
                                jac_sparsity=ROBERTSON_PATTERN)
    assert bool(jnp.all(st_d.success)) and bool(jnp.all(st_s.success))
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_d),
                               rtol=0, atol=1e-8)
    # direct solver: no inner iterations, no psolves
    assert int(st_s.nli[0]) == 0 and int(st_s.npsolves[0]) == 0


def test_preconditioned_spgmr_matches_dense_trajectory(dense_reference):
    """Acceptance: SPGMR(precond=BlockJacobiPrecond) reproduces the
    dense trajectory within 1e-8 with NONZERO npsolves."""
    y_d, st_d = dense_reference
    ls = SPGMR(tol=1e-12, restart=30, max_restarts=6,
               precond=BlockJacobiPrecond(block_size=3))
    y_k, st_k = _robertson_runs(ls)
    assert bool(jnp.all(st_k.success))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_d),
                               rtol=0, atol=1e-8)
    assert int(st_k.npsolves[0]) > 0
    assert int(st_k.nli[0]) > 0
    # block size == system size: the preconditioner is the exact
    # inverse, so GMRES needs ~1 inner iteration per Newton solve
    assert int(st_k.nli[0]) <= 1.05 * int(jnp.sum(st_k.nni))


def test_sparse_workspace_below_dense_at_low_fill():
    """Acceptance: Solution workspace strictly lower than dense for
    fill <= 25% — both the sparse direct solver and the preconditioned
    sparse Krylov path."""
    nsys, nx = 8, 16
    f, jac, P, y0 = ensemble_brusselator(nsys, nx)
    n = 2 * nx
    fill = P.sum() / (n * n)
    assert fill <= 0.25, fill
    prob = IVP(f=f, jac=jac, jac_sparsity=P, y0=y0)
    ctx = Context()
    opts = ctx.options(rtol=1e-5, atol=1e-8, max_steps=100_000)
    runs = {}
    for name, ls in (
            ("dense", BlockDiagGJ()),
            ("sparse", EnsembleSparseGJ()),
            ("krylov", SPGMR(tol=1e-9, restart=10, max_restarts=6,
                             precond=BlockJacobiPrecond(block_size=2)))):
        runs[name] = integrate(prob, 0.0, 0.5, "ensemble_bdf", ctx=ctx,
                               opts=opts, lin_solver=ls)
        assert bool(runs[name].success), name
    ws = {k: s.workspace_bytes for k, s in runs.items()}
    assert ws["sparse"] < ws["dense"], ws
    assert ws["krylov"] < ws["dense"], ws
    # and the solutions agree at tolerance scale
    for k in ("sparse", "krylov"):
        np.testing.assert_allclose(np.asarray(runs[k].y),
                                   np.asarray(runs["dense"].y),
                                   rtol=0, atol=1e-3)


def test_solution_surfaces_npsolves_and_npsetups():
    nsys = 6
    f, jac, y0 = batched_robertson(nsys)
    prob = IVP(f=f, jac=jac, jac_sparsity=ROBERTSON_PATTERN, y0=y0)
    ctx = Context()
    opts = ctx.options(rtol=1e-5, atol=1e-10, max_steps=100_000)
    ls = SPGMR(tol=1e-10, restart=20, max_restarts=4,
               precond=BlockJacobiPrecond(block_size=3))
    sol = integrate(prob, 0.0, 1.0, "ensemble_bdf", ctx=ctx, opts=opts,
                    lin_solver=ls)
    assert bool(sol.success)
    assert sol.npsolves is not None and int(sol.npsolves) > 0
    # psetup rides the lsetup triggers: counts must match exactly
    assert sol.npsetups is not None
    assert int(sol.npsetups) == int(jnp.sum(sol.stats.nsetups)) > 0
    # an unpreconditioned direct run reports zero psolves, no psetups
    sol_d = integrate(prob, 0.0, 1.0, "ensemble_bdf", ctx=ctx,
                      opts=opts, lin_solver=BlockDiagGJ())
    assert int(sol_d.npsolves) == 0 and sol_d.npsetups is None


def test_ilu0_precond_through_ensemble_bdf():
    """ILU(0) on the banded shared pattern drives the sparse Krylov SoA
    path end to end (pattern-aware psetup at the lsetup triggers)."""
    nsys, nx = 6, 8
    f, jac, P, y0 = ensemble_brusselator(nsys, nx)
    prob = IVP(f=f, jac=jac, jac_sparsity=P, y0=y0)
    opts = ODEOptions(rtol=1e-5, atol=1e-8, max_steps=100_000)
    ls_ref = BlockDiagGJ()
    sol_ref = integrate(prob, 0.0, 0.3, "ensemble_bdf", opts=opts,
                        lin_solver=ls_ref)
    # a BARE ILU0Precond: the pattern must arrive via IVP.jac_sparsity
    # through the same with_sparsity binding the solver gets
    ls = SPGMR(tol=1e-9, restart=10, max_restarts=6,
               precond=ILU0Precond())
    sol = integrate(prob, 0.0, 0.3, "ensemble_bdf", opts=opts,
                    lin_solver=ls)
    assert bool(sol.success)
    assert int(sol.npsolves) > 0
    np.testing.assert_allclose(np.asarray(sol.y), np.asarray(sol_ref.y),
                               rtol=0, atol=1e-3)


def test_sparse_solvers_jnp_vs_pallas_parity():
    """The sparse lsolve path dispatches through the op table: jnp and
    Pallas(interpret) trajectories agree at controller-tolerance scale
    (ragged nsys).  Cross-backend agreement of an adaptive integrator
    is bounded by decision flips at the permitted local error — the
    WRMS control's per-component C*(rtol*|y_i| + atol), mirrored in
    the mixed comparison below (C=100) — not machine eps, now that the
    fused hot-loop kernels round independently of XLA's fusion of the
    inline oracles (see test_ensemble_bdf.py's parity gate; op-level
    parity is pinned at 1e-10 in test_soa_carry.py)."""
    nsys = 10
    f, jac, y0 = batched_robertson(nsys)
    opts = ODEOptions(rtol=1e-8, atol=1e-12, max_steps=400_000)
    ls = SPGMR(tol=1e-11, restart=20, max_restarts=6,
               precond=BlockJacobiPrecond(block_size=3))
    enc_kw = dict(linear_solver=ls, jac_sparsity=ROBERTSON_PATTERN)
    y_j, st_j = batched.ensemble_bdf_integrate(
        f, jac, y0, 0.0, 4.0, opts=opts, policy=XLA_FUSED, **enc_kw)
    pol = ExecPolicy(backend="pallas", interpret=True, batch_tile=256)
    y_p, st_p = batched.ensemble_bdf_integrate(
        f, jac, y0, 0.0, 4.0, opts=opts, policy=pol, **enc_kw)
    assert bool(jnp.all(st_j.success)) and bool(jnp.all(st_p.success))
    np.testing.assert_allclose(np.asarray(y_j), np.asarray(y_p),
                               rtol=100 * opts.rtol, atol=100 * opts.atol)


# ---------------------------------------------------------------------------
# MemoryHelper accounting across back-to-back runs (PR 3 label guard)
# ---------------------------------------------------------------------------


def test_memory_highwater_two_back_to_back_integrations():
    """Two integrate() calls on ONE Context: each call's labels are
    released afterwards (live returns to the pre-call level), foreign
    labels survive, and the high-water mark is monotone and reflects
    the larger run."""
    nsys = 6
    f, jac, y0 = batched_robertson(nsys)
    ctx = Context()
    # a foreign registration must survive both runs untouched
    ctx.memory.register("user.buffer", (128,), jnp.float64)
    foreign = ctx.memory.live_bytes
    assert foreign == 128 * 8
    prob = IVP(f=f, jac=jac, y0=y0)
    opts = ctx.options(rtol=1e-5, atol=1e-10, max_steps=100_000)
    s1 = integrate(prob, 0.0, 1.0, "ensemble_bdf", ctx=ctx, opts=opts,
                   lin_solver=BlockDiagGJ())
    hw1 = ctx.memory.high_water_bytes
    assert s1.workspace_bytes > 0
    assert ctx.memory.live_bytes == foreign         # labels released
    assert set(ctx.memory.workspaces) == {"user.buffer"}
    assert hw1 >= foreign + s1.workspace_bytes
    # second, larger run on the same context: high-water is monotone
    # and grows to cover the bigger workspace
    f2, jac2, y02 = batched_robertson(4 * nsys)
    prob2 = IVP(f=f2, jac=jac2, y0=y02)
    s2 = integrate(prob2, 0.0, 1.0, "ensemble_bdf", ctx=ctx, opts=opts,
                   lin_solver=BlockDiagGJ())
    hw2 = ctx.memory.high_water_bytes
    assert s2.workspace_bytes > s1.workspace_bytes
    assert ctx.memory.live_bytes == foreign
    assert set(ctx.memory.workspaces) == {"user.buffer"}
    assert hw2 >= hw1
    assert hw2 >= foreign + s2.workspace_bytes
    # and both Solutions report the run-wide (not per-call) high water
    assert s2.high_water_bytes == hw2 >= s1.high_water_bytes == hw1
