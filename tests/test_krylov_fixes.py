"""Krylov satellite-bugfix tests (no hypothesis dependency — the
property-test module test_solvers.py skips entirely when hypothesis is
absent, so these regression tests live here): tfqmr carry dtypes, gmres
actual iteration counts, true FGMRES, bicgstab breakdown guarding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import krylov


def _make_system(n=24, cond=8.0, seed=0):
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (n, n)) + cond * jnp.eye(n)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    return A, b


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_tfqmr_carry_dtypes(dtype):
    """tfqmr's theta/eta carry scalars must follow the input dtype:
    under jax_enable_x64 (on here, see conftest) an f32 system used to
    get an f64 zeros(()) init and crash the while_loop trace."""
    A, b = _make_system(n=16)
    A = A.astype(dtype)
    b = b.astype(dtype)
    tol = 1e-10 if dtype == jnp.float64 else 1e-5
    x, st = krylov.tfqmr(lambda v: A @ v, b, tol=tol, maxiter=300)
    assert x.dtype == dtype
    res = float(jnp.linalg.norm(A @ x - b))
    assert res < (1e-7 if dtype == jnp.float64 else 1e-2)
    assert bool(st.converged)


def test_gmres_reports_actual_iterations():
    """Early Arnoldi exit must be reflected in stats.iters (the old code
    reported restarts * m even when the loop broke out at iteration j)."""
    A, b = _make_system(n=24)
    x, st = krylov.gmres(lambda v: A @ v, b, tol=1e-10, restart=24)
    assert bool(st.converged)
    # well-conditioned 24x24 system converges well before a full cycle
    assert 0 < int(st.iters) < 24
    # a 2x2 system cannot need more than 2 iterations even with a large
    # restart window
    A2 = jnp.array([[3.0, 1.0], [0.0, 2.0]])
    b2 = jnp.array([1.0, 1.0])
    x2, st2 = krylov.gmres(lambda v: A2 @ v, b2, tol=1e-12, restart=30)
    assert bool(st2.converged) and int(st2.iters) <= 2


def test_fgmres_flexible_basis():
    """True FGMRES: the preconditioned basis is stored, the solution is
    assembled from it, and a preconditioner sharpens convergence exactly
    as for gmres."""
    n = 40
    key = jax.random.PRNGKey(0)
    D = jnp.logspace(0, 3, n)
    A = jnp.diag(D) + 0.01 * jax.random.normal(key, (n, n))
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    dinv = 1.0 / jnp.diag(A)
    x, st = krylov.fgmres(lambda v: A @ v, b, tol=1e-10,
                          precond=lambda v: dinv * v)
    assert bool(st.converged)
    assert float(jnp.linalg.norm(A @ x - b)) < 1e-6
    _, st_plain = krylov.fgmres(lambda v: A @ v, b, tol=1e-10)
    assert int(st.iters) < int(st_plain.iters)


def test_bicgstab_lucky_breakdown_keeps_half_update():
    """A = I: the BiCG half-step is exact, so t = A s = 0 (tt == 0).
    The solver must commit x + alpha*p_hat (the lucky breakdown) instead
    of freezing or committing an omega = garbage full update."""
    n = 12
    b = jax.random.normal(jax.random.PRNGKey(0), (n,))
    x, st = krylov.bicgstab(lambda v: v, b, tol=1e-12)
    np.testing.assert_allclose(np.asarray(x), np.asarray(b), atol=1e-12)
    assert bool(st.converged)
    assert int(st.iters) == 1
