"""Integrator correctness: convergence orders, adaptive tolerance tracking,
stiff problems, ensemble (submodel) mode — the paper's §7 numerics."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import arkode, batched, butcher, cvode
from repro.core.arkode import ODEOptions


LAM = 50.0


def fi_stiff(t, y):
    return -LAM * (y - jnp.cos(t))


def exact_stiff(t):
    a = LAM * LAM / (1 + LAM * LAM)
    b = LAM / (1 + LAM * LAM)
    return a * np.cos(t) + b * np.sin(t) - a * np.exp(-LAM * t)


def _order(errs):
    return [math.log2(errs[i] / errs[i + 1]) for i in range(len(errs) - 1)]


# ---------------------------------------------------------------------------
# explicit methods
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,expected", [
    ("euler", 1), ("heun_euler", 2), ("bogacki_shampine", 3),
    ("dormand_prince", 5)])
def test_erk_convergence_order(name, expected):
    tab = butcher.ERK_TABLES[name]
    f = lambda t, y: -y + jnp.sin(3 * t)
    y0 = jnp.ones((2,))
    # exact via very fine DP5
    ref = arkode.erk_fixed(f, y0, 0.0, 1.0, 2048, butcher.DORMAND_PRINCE)
    errs = []
    for n in (16, 32, 64):
        y = arkode.erk_fixed(f, y0, 0.0, 1.0, n, tab)
        errs.append(float(jnp.max(jnp.abs(y - ref))))
    orders = _order(errs)
    assert orders[-1] > expected - 0.45, (name, orders, errs)


def test_erk_adaptive_hits_tolerance():
    f = lambda t, y: -y
    y0 = jnp.ones((4,))
    for rtol in (1e-5, 1e-8):
        y, st = arkode.erk_integrate(f, y0, 0.0, 2.0,
                                     butcher.DORMAND_PRINCE,
                                     ODEOptions(rtol=rtol, atol=1e-12))
        err = float(jnp.max(jnp.abs(y - np.exp(-2.0))))
        assert bool(st.success)
        assert err < 50 * rtol * np.exp(-2.0) + 1e-12
    # tighter tolerance must take more steps
    _, s1 = arkode.erk_integrate(f, y0, 0.0, 2.0, butcher.DORMAND_PRINCE,
                                 ODEOptions(rtol=1e-4, atol=1e-12))
    _, s2 = arkode.erk_integrate(f, y0, 0.0, 2.0, butcher.DORMAND_PRINCE,
                                 ODEOptions(rtol=1e-9, atol=1e-12))
    assert int(s2.steps) > int(s1.steps)


def test_erk_rejects_and_recovers_on_kick():
    # RHS with a sharp feature: controller must reject some steps yet finish
    f = lambda t, y: -y + 100.0 * jnp.exp(-((t - 1.0) / 0.01) ** 2)
    y0 = jnp.ones((1,))
    y, st = arkode.erk_integrate(f, y0, 0.0, 2.0, butcher.BOGACKI_SHAMPINE,
                                 ODEOptions(rtol=1e-6, atol=1e-9))
    assert bool(st.success)
    assert int(st.netf) > 0       # the kick forces error-test failures


# ---------------------------------------------------------------------------
# implicit / IMEX
# ---------------------------------------------------------------------------


def test_dirk_stiff_adaptive():
    ls = arkode.dense_lin_solver(fi_stiff)
    y, st = arkode.dirk_integrate(fi_stiff, jnp.zeros((1,)), 0.0, 2.0,
                                  butcher.SDIRK2,
                                  ODEOptions(rtol=1e-6, atol=1e-9),
                                  lin_solver=ls)
    assert bool(st.success)
    assert abs(float(y[0]) - exact_stiff(2.0)) < 1e-5


def test_sdirk2_order():
    ls = arkode.dense_lin_solver(fi_stiff)
    errs = []
    for n in (40, 80, 160):
        y = arkode.dirk_fixed(fi_stiff, jnp.zeros((1,)), 0.0, 1.0, n,
                              butcher.SDIRK2, lin_solver=ls)
        errs.append(abs(float(y[0]) - exact_stiff(1.0)))
    assert _order(errs)[-1] > 1.6, errs


def test_ark324_imex_order3():
    fe = lambda t, y: LAM * jnp.cos(t) * jnp.ones_like(y)
    fi = lambda t, y: -LAM * y
    ls = arkode.dense_lin_solver(fi)
    errs = []
    for n in (40, 80, 160):
        y = arkode.imex_fixed(fe, fi, jnp.zeros((1,)), 0.0, 1.0, n,
                              butcher.ARK324, lin_solver=ls)
        errs.append(abs(float(y[0]) - exact_stiff(1.0)))
    assert _order(errs)[-1] > 2.5, errs   # asymptotic 3rd order


def test_imex_adaptive_stiff():
    fe = lambda t, y: LAM * jnp.cos(t) * jnp.ones_like(y)
    fi = lambda t, y: -LAM * y
    ls = arkode.dense_lin_solver(fi)
    y, st = arkode.imex_integrate(fe, fi, jnp.zeros((1,)), 0.0, 2.0,
                                  butcher.ARK324,
                                  ODEOptions(rtol=1e-7, atol=1e-10),
                                  lin_solver=ls)
    assert bool(st.success)
    assert abs(float(y[0]) - exact_stiff(2.0)) < 1e-5
    assert int(st.nni) > 0


def test_matrix_free_gmres_newton_path():
    """Default lin_solver (jvp+GMRES) on a 2x2 nonlinear stiff system."""
    def fi(t, y):
        return jnp.stack([-80.0 * y[0] + y[1] ** 2,
                          -0.5 * y[1] - 0.1 * y[0]])

    y, st = arkode.dirk_integrate(fi, jnp.asarray([1.0, 1.0]), 0.0, 1.0,
                                  butcher.SDIRK2,
                                  ODEOptions(rtol=1e-6, atol=1e-9))
    assert bool(st.success)
    ref = arkode.erk_fixed(fi, jnp.asarray([1.0, 1.0]), 0.0, 1.0, 4000,
                           butcher.DORMAND_PRINCE)
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-7)


# ---------------------------------------------------------------------------
# BDF / Adams (CVODE)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [1, 2, 3, 4])
def test_bdf_fixed_order(q):
    errs = []
    for n in (40, 80, 160):
        y = cvode.bdf_fixed(fi_stiff, jnp.zeros((1,)), 0.0, 1.0, n, order=q)
        errs.append(abs(float(y[0]) - exact_stiff(1.0)))
    assert _order(errs)[-1] > q - 0.5, (q, errs)


def test_bdf_adaptive_stiff():
    y, st = cvode.bdf_integrate(fi_stiff, jnp.zeros((1,)), 0.0, 2.0,
                                order=5,
                                opts=ODEOptions(rtol=1e-7, atol=1e-10),
                                dense_jac=True)
    assert bool(st.success)
    assert abs(float(y[0]) - exact_stiff(2.0)) < 1e-6


def test_bdf_robertson_like():
    """Classic very-stiff kinetics (Robertson, rescaled horizon)."""
    def f(t, y):
        return jnp.stack([
            -0.04 * y[0] + 1e4 * y[1] * y[2],
            0.04 * y[0] - 1e4 * y[1] * y[2] - 3e7 * y[1] ** 2,
            3e7 * y[1] ** 2])

    y0 = jnp.asarray([1.0, 0.0, 0.0])
    y, st = cvode.bdf_integrate(f, y0, 0.0, 40.0, order=5,
                                opts=ODEOptions(rtol=1e-6, atol=1e-10,
                                                max_steps=200_000),
                                dense_jac=True)
    assert bool(st.success)
    # mass conservation + literature values at t=40
    assert abs(float(jnp.sum(y)) - 1.0) < 1e-6
    assert abs(float(y[0]) - 0.7158) < 5e-3
    assert float(y[1]) < 1e-4


def test_adams_nonstiff():
    y, st = cvode.adams_integrate(lambda t, y: -y, jnp.ones((2,)), 0.0, 2.0,
                                  ODEOptions(rtol=1e-6, atol=1e-9))
    assert bool(st.success)
    assert float(jnp.max(jnp.abs(y - np.exp(-2.0)))) < 1e-5


# ---------------------------------------------------------------------------
# ensemble (submodel) integration
# ---------------------------------------------------------------------------


def test_ensemble_erk_per_system_adaptivity():
    rates = jnp.linspace(0.5, 3.0, 8)
    f = lambda t, y: -rates[:, None] * y
    y0 = jnp.ones((8, 4))
    y, st = batched.ensemble_erk_integrate(
        f, y0, 0.0, 1.5, butcher.BOGACKI_SHAMPINE,
        ODEOptions(rtol=1e-7, atol=1e-10))
    ref = np.broadcast_to(np.exp(-np.asarray(rates) * 1.5)[:, None],
                          y.shape)
    assert bool(jnp.all(st.success))
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-8)
    # stiffer systems must take more steps (independent step control)
    steps = np.asarray(st.steps)
    assert steps[-1] > steps[0]


def test_ensemble_dirk_blockdiag_newton():
    nsys, n = 6, 3

    def f(t, y):
        return -50.0 * (y - jnp.cos(t)[:, None])

    def jac(t, y):
        return jnp.broadcast_to(-50.0 * jnp.eye(n), (y.shape[0], n, n))

    y0 = jnp.zeros((nsys, n))
    y, st = batched.ensemble_dirk_integrate(
        f, jac, y0, 0.0, 2.0, butcher.SDIRK2,
        ODEOptions(rtol=1e-5, atol=1e-8))
    assert bool(jnp.all(st.success))
    np.testing.assert_allclose(np.asarray(y), exact_stiff(2.0), rtol=1e-4,
                               atol=1e-6)


def test_ensemble_dirk_with_pallas_backend():
    from repro.core.policies import ExecPolicy
    nsys, n = 4, 3

    def f(t, y):
        return -20.0 * (y - jnp.sin(t)[:, None])

    def jac(t, y):
        return jnp.broadcast_to(-20.0 * jnp.eye(n), (y.shape[0], n, n))

    y0 = jnp.zeros((nsys, n))
    pol = ExecPolicy(backend="pallas", batch_tile=128, interpret=True)
    y_pal, _ = batched.ensemble_dirk_integrate(
        f, jac, y0, 0.0, 1.0, butcher.SDIRK2,
        ODEOptions(rtol=1e-5, atol=1e-8), policy=pol)
    y_jnp, _ = batched.ensemble_dirk_integrate(
        f, jac, y0, 0.0, 1.0, butcher.SDIRK2,
        ODEOptions(rtol=1e-5, atol=1e-8))
    # cross-backend agreement of an adaptive integrator is bounded by
    # the controller: the DIRK stage Newton now runs through the fused
    # pallas kernels, which round independently of XLA's fusion of the
    # inline jnp oracles, so accept/step decisions may flip and the
    # trajectories separate by the permitted local error — which the
    # WRMS control bounds PER COMPONENT as C*(rtol*|y_i| + atol), so
    # the comparison uses the same mixed form (C=100) and small
    # components stay genuinely exercised (see test_ensemble_bdf.py /
    # test_soa_carry.py for the op-level and bitwise gates)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_jnp),
                               rtol=100 * 1e-5, atol=100 * 1e-8)


def test_ensemble_dirk_honors_h0_and_counts_nni_per_system():
    nsys, n = 5, 3
    rates = jnp.linspace(5.0, 60.0, nsys)

    def f(t, y):
        return -rates[:, None] * (y - jnp.cos(t)[:, None])

    def jac(t, y):
        return jnp.broadcast_to(-rates[:, None, None] * jnp.eye(n),
                                (nsys, n, n))

    y0 = jnp.zeros((nsys, n))
    # h0 seeds the first step (erk already honored it; dirk ignored it):
    # at a loose tolerance the ramp-up from the crude default seed
    # h = 1e-6*(tf-t0) dominates the attempt count, so a steady-state h0
    # must save attempts
    _, st_h0 = batched.ensemble_dirk_integrate(
        f, jac, y0, 0.0, 2.0, butcher.SDIRK2,
        ODEOptions(rtol=1e-2, atol=1e-4, h0=2e-2))
    _, st_def = batched.ensemble_dirk_integrate(
        f, jac, y0, 0.0, 2.0, butcher.SDIRK2,
        ODEOptions(rtol=1e-2, atol=1e-4))
    assert bool(jnp.all(st_h0.success)) and bool(jnp.all(st_def.success))
    assert int(jnp.sum(st_def.attempts)) > int(jnp.sum(st_h0.attempts))
    # nni is a true per-system count, not one scalar broadcast: stiffer
    # systems take more steps, hence strictly more Newton iterations
    nni = np.asarray(st_h0.nni)
    assert nni.shape == (nsys,)
    assert len(np.unique(nni)) > 1
    assert nni[-1] > nni[0]
