"""Preconditioner subsystem: the PSetup/PSolve protocol, left
preconditioning + exact npsolves accounting through all five Krylov
solvers, warn-free PCG==CG bitwise parity, and ILU(0) on the shared
CSR pattern."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import krylov
from repro.core.precond import (BlockJacobiPrecond, ILU0Precond,
                                JacobiPrecond)


def _spd_system(n=40, key=0):
    rng = np.random.default_rng(key)
    Q = rng.normal(size=(n, n))
    A = Q @ Q.T + n * np.diag(1.0 + 10.0 * rng.random(n))
    b = rng.normal(size=n)
    return jnp.asarray(A), jnp.asarray(b)


def _nonsym_system(n=40, key=1):
    rng = np.random.default_rng(key)
    A = rng.normal(size=(n, n)) * 0.3 + np.diag(3.0 + 10.0 * rng.random(n))
    b = rng.normal(size=n)
    return jnp.asarray(A), jnp.asarray(b)


# ---------------------------------------------------------------------------
# PCG(precond=None) is warn-free plain CG, bitwise
# ---------------------------------------------------------------------------


def test_pcg_none_is_cg_bitwise_and_warn_free():
    A, b = _spd_system()
    mv = lambda v: A @ v
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        x0, st0 = krylov.pcg(mv, b, tol=1e-12, maxiter=300)
    x1, st1 = krylov.pcg(mv, b, tol=1e-12, maxiter=300,
                         precond=lambda v: v)   # explicit identity
    # identical computation graph -> bitwise-equal iterates and stats
    np.testing.assert_array_equal(np.asarray(x0), np.asarray(x1))
    assert int(st0.iters) == int(st1.iters)
    assert bool(st0.converged) and bool(st1.converged)
    # identity is still a precond call for accounting purposes...
    assert int(st1.npsolves) == int(st1.iters) + 1
    # ...but plain CG reports zero preconditioner work
    assert int(st0.npsolves) == 0


def test_pcg_jacobi_counts_psolves_exactly():
    A, b = _spd_system()
    dinv = 1.0 / jnp.diag(A)
    x, st = krylov.pcg(lambda v: A @ v, b, tol=1e-12, maxiter=300,
                       precond=lambda v: dinv * v)
    assert bool(st.converged)
    assert int(st.npsolves) == int(st.iters) + 1   # one pre-loop + 1/iter
    assert int(st.npsetups) == 0                   # setup is not ours


# ---------------------------------------------------------------------------
# left preconditioning through the other four solvers, with counting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("solver,per_iter,pre", [
    (krylov.gmres, 1, None),       # per Arnoldi step: 1; per cycle: 1; +2
    (krylov.fgmres, 1, None),
    (krylov.bicgstab, 2, 2),       # 2 matvecs/iter; +2 pre-loop
    (krylov.tfqmr, 4, 3),          # 4 amv/iter; +3 (b, r0, initial v)
])
def test_left_precond_converges_and_counts(solver, per_iter, pre):
    A, b = _nonsym_system()
    dinv = 1.0 / jnp.diag(A)
    ML = lambda v: dinv * v
    x, st = solver(lambda v: A @ v, b, tol=1e-10,
                   precond_left=ML)
    assert bool(st.converged)
    np.testing.assert_allclose(np.asarray(A @ x), np.asarray(b),
                               atol=1e-7)
    it = int(st.iters)
    nps = int(st.npsolves)
    assert nps > 0
    if pre is None:   # gmres family: iters + cycles + 2
        assert nps >= it + 1 and nps <= it + 2 + 12  # cycles bounded
    else:
        assert nps == per_iter * it + pre


def test_left_precond_beats_unpreconditioned_gmres():
    # badly scaled diagonal: Jacobi-left must cut iterations sharply
    rng = np.random.default_rng(4)
    n = 60
    d = 10.0 ** rng.uniform(-3, 3, n)
    A = jnp.asarray(np.diag(d) + 0.05 * rng.normal(size=(n, n)))
    b = jnp.asarray(rng.normal(size=n))
    mv = lambda v: A @ v
    _, st0 = krylov.gmres(mv, b, tol=1e-8, restart=25, max_restarts=40)
    dinv = 1.0 / jnp.diag(A)
    x1, st1 = krylov.gmres(mv, b, tol=1e-8, restart=25, max_restarts=40,
                           precond_left=lambda v: dinv * v)
    assert int(st1.iters) < int(st0.iters)
    # the inner loop controls the PRECONDITIONED residual (documented
    # left-precond semantics), so test convergence there rather than
    # the unpreconditioned `converged` flag, which ill-scaling inflates
    pre_res = float(jnp.linalg.norm(dinv * (A @ x1 - b)))
    assert pre_res <= 1.01 * 1e-8 * float(jnp.linalg.norm(dinv * b))


# ---------------------------------------------------------------------------
# Preconditioner objects
# ---------------------------------------------------------------------------


def test_jacobi_precond_scalar_surface():
    A, b = _nonsym_system(n=12, key=7)
    P = JacobiPrecond(jac_diag=lambda t, y: jnp.diag(A))
    gamma = 0.25
    pdata = P.psetup(0.0, jnp.zeros(12), gamma)
    np.testing.assert_allclose(np.asarray(P.psolve(pdata, b)),
                               np.asarray(b) /
                               (1.0 - gamma * np.diag(np.asarray(A))),
                               rtol=1e-14)


def test_block_jacobi_precond_scalar_is_exact_for_block_diag():
    # M block-diagonal -> block-Jacobi psolve IS the exact solve
    rng = np.random.default_rng(9)
    b, nblk = 3, 4
    n = b * nblk
    J = np.zeros((n, n))
    for I in range(nblk):
        J[I * b:(I + 1) * b, I * b:(I + 1) * b] = rng.normal(size=(b, b))
    P = BlockJacobiPrecond(block_size=b, jac=lambda t, y: jnp.asarray(J))
    gamma = 0.2
    pdata = P.psetup(0.0, jnp.zeros(n), gamma)
    r = jnp.asarray(rng.normal(size=n))
    z = P.psolve(pdata, r)
    M = np.eye(n) - gamma * J
    np.testing.assert_allclose(np.asarray(M @ np.asarray(z)),
                               np.asarray(r), atol=1e-12)


def test_ilu0_exact_when_pattern_has_no_fill():
    # tridiagonal elimination has zero fill -> ILU(0) == exact LU
    n = 15
    rng = np.random.default_rng(11)
    i = np.arange(n)
    P = np.abs(i[:, None] - i[None, :]) <= 1
    J = rng.normal(size=(n, n)) * P
    prec = ILU0Precond(sparsity=P, jac=lambda t, y: jnp.asarray(J))
    gamma = 0.3
    pdata = prec.psetup(0.0, jnp.zeros(n), gamma)
    r = jnp.asarray(rng.normal(size=n))
    z = prec.psolve(pdata, r)
    M = np.eye(n) - gamma * J
    np.testing.assert_allclose(np.asarray(M @ np.asarray(z)),
                               np.asarray(r), atol=1e-10)


def test_ilu0_sharpens_gmres_on_banded_system():
    n = 80
    rng = np.random.default_rng(13)
    i = np.arange(n)
    band = np.abs(i[:, None] - i[None, :]) <= 2
    A = rng.normal(size=(n, n)) * band + np.diag(4.0 + rng.random(n))
    Aj = jnp.asarray(A)
    b = jnp.asarray(rng.normal(size=n))
    mv = lambda v: Aj @ v
    _, st0 = krylov.gmres(mv, b, tol=1e-9, restart=20, max_restarts=20)
    prec = ILU0Precond(sparsity=np.abs(A) > 0,
                       jac=lambda t, y: (jnp.eye(n) - Aj))
    # psetup with gamma=1 builds ILU0 of I - 1*(I - A) = A itself
    pdata = prec.psetup(0.0, jnp.zeros(n), 1.0)
    x1, st1 = krylov.gmres(mv, b, tol=1e-9, restart=20, max_restarts=20,
                           precond_left=lambda v: prec.psolve(pdata, v))
    assert bool(st1.converged)
    assert int(st1.iters) < int(st0.iters)
    assert int(st1.npsolves) > 0
    np.testing.assert_allclose(np.asarray(Aj @ x1), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_ensemble_soa_surfaces_agree_with_scalar():
    """soa_psetup/soa_psolve on a 1-system lane batch must match the
    scalar surface for all three preconditioners."""
    n = 8
    rng = np.random.default_rng(17)
    band = np.abs(np.arange(n)[:, None] - np.arange(n)) <= 1
    J = rng.normal(size=(n, n)) * band
    gamma = 0.4
    M = np.eye(n) - gamma * J
    Msoa = jnp.asarray(M)[:, :, None]
    gam = jnp.asarray([gamma])
    r = rng.normal(size=n)
    rj = jnp.asarray(r)
    cases = [
        (JacobiPrecond(jac_diag=lambda t, y: jnp.asarray(np.diag(J)))),
        (BlockJacobiPrecond(block_size=2,
                            jac=lambda t, y: jnp.asarray(J))),
        (ILU0Precond(sparsity=band, jac=lambda t, y: jnp.asarray(J))),
    ]
    for P in cases:
        pd_s = P.psetup(0.0, jnp.zeros(n), gamma)
        z_s = P.psolve(pd_s, rj)
        pd_e = P.soa_psetup(Msoa, None, gam)
        z_e = P.soa_psolve(pd_e, rj[:, None])[:, 0]
        np.testing.assert_allclose(np.asarray(z_e), np.asarray(z_s),
                                   atol=1e-12, err_msg=P.name)
