"""Serving front-end suite: admission queue, trace cache, padded-lane
stats hygiene, warm-start continuation, and the end-to-end server.

Scaled down (tiny buckets, short horizons) so the whole file stays
compile-bound at a few traces; the >= 10^4-request acceptance run lives
in ``benchmarks/serving_bench.py --smoke`` (CI serving smoke step).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batched import SolverSession, ensemble_bdf_integrate
from repro.core.context import Context
from repro.core.ivp import IVP, integrate
from repro.core.problems import (batched_robertson, batched_robertson_soa,
                                 decay_chain_family, robertson_family)
from repro.serve.solver import (AdmissionQueue, IVPRequest, ProblemFamily,
                                RetryAfter, SolverServer, TraceCache,
                                TraceKey, bucket_key,
                                bucket_sizes_from_bench, tolerance_class)

ROB_PARAMS = {"k1": 0.04, "k2": 1.2e4, "k3": 3e7}


def _req(family="robertson", n=3, rtol=1e-6, atol=1e-9, tf=0.2,
         method="ensemble_bdf"):
    return IVPRequest(family=family, y0=jnp.zeros(n), t0=0.0, tf=tf,
                      rtol=rtol, atol=atol, method=method)


# ---------------------------------------------------------------------------
# admission queue
# ---------------------------------------------------------------------------

class TestAdmissionQueue:
    def test_tolerance_class(self):
        assert tolerance_class(1e-6, 1e-9) == (-6, -9)
        assert tolerance_class(5e-6, 2e-9) == (-6, -9)  # same decade
        assert tolerance_class(1e-7, 1e-9) == (-7, -9)  # tighter decade
        with pytest.raises(ValueError):
            tolerance_class(0.0, 1e-9)
        with pytest.raises(ValueError):
            tolerance_class(1e-6, 2.0)

    def test_bucketing_key_splits(self):
        d = "float64"
        base = bucket_key(_req(), d)
        assert bucket_key(_req(rtol=3e-6), d) == base     # same decade
        assert bucket_key(_req(rtol=1e-4), d) != base     # other decade
        assert bucket_key(_req(family="x"), d) != base
        assert bucket_key(_req(n=6), d) != base
        assert bucket_key(_req(method="ensemble_dirk"), d) != base

    def test_flush_on_max_batch(self):
        q = AdmissionQueue(bucket_sizes=(4, 8), max_batch=4,
                           clock=lambda: 0.0)
        for _ in range(6):
            q.offer(_req(), now=0.0)
        bundles = q.poll(now=0.0)        # full chunk only; 2 remain fresh
        assert len(bundles) == 1 and bundles[0].live == 4
        assert bundles[0].nsys == 4 and q.depth == 2

    def test_flush_on_max_wait_and_padding(self):
        q = AdmissionQueue(bucket_sizes=(4, 8), max_batch=8,
                           max_wait=1e-3)
        for _ in range(3):
            q.offer(_req(), now=0.0)
        assert q.poll(now=5e-4) == []              # not stale yet
        bundles = q.poll(now=2e-3)                 # stale: partial flush
        assert len(bundles) == 1
        b = bundles[0]
        assert b.live == 3 and b.nsys == 4         # padded to bucket size
        assert b.occupancy == pytest.approx(0.75)
        assert q.depth == 0

    def test_staleness_clock_restarts_at_new_head(self):
        # after a full-chunk flush the REMAINING head's arrival drives
        # the stale timer — not the flushed (older) head's
        q = AdmissionQueue(bucket_sizes=(2, 4), max_batch=2,
                           max_wait=1.0)
        q.offer(_req(), now=0.0)
        q.offer(_req(), now=0.0)
        q.offer(_req(), now=0.9)                   # becomes the new head
        assert len(q.poll(now=0.95)) == 1          # the full chunk only
        assert q.poll(now=1.5) == []               # head is 0.6s old
        assert len(q.poll(now=2.0)) == 1           # now stale

    def test_backpressure_retry_after(self):
        q = AdmissionQueue(bucket_sizes=(64,), max_depth=2, max_wait=1e-3)
        q.offer(_req(), now=0.0)
        q.offer(_req(), now=0.0)
        with pytest.raises(RetryAfter) as ei:
            q.offer(_req(), now=0.0)
        assert ei.value.retry_after > 0 and ei.value.depth == 2
        assert q.rejected == 1
        q.poll(now=1.0)                            # drain
        q.offer(_req(), now=1.0)                   # admits again
        assert q.depth == 1

    def test_bucket_sizes_from_bench(self, tmp_path):
        assert bucket_sizes_from_bench(path="/nonexistent.json") == \
            (64, 128, 256, 512)
        p = tmp_path / "bench.json"
        p.write_text(json.dumps({"results": [
            {"nsys": 512, "jnp_systems_per_sec": 1.0,
             "pallas_interpret_systems_per_sec": 2.0},     # sweet spot
            {"nsys": 4096, "jnp_systems_per_sec": 1.0,
             "pallas_interpret_systems_per_sec": 2.0},     # > max_size
            {"nsys": 256, "jnp_systems_per_sec": 2.0,
             "pallas_interpret_systems_per_sec": 1.0},     # loses
        ]}))
        assert bucket_sizes_from_bench(path=str(p)) == (64, 128, 256, 512)


# ---------------------------------------------------------------------------
# trace cache
# ---------------------------------------------------------------------------

class TestTraceCache:
    def _key(self, i):
        return TraceKey(bucket=bucket_key(_req(n=3 + i), "float64"),
                        nsys=8, policy=None)

    def test_hit_miss_evict_counters(self):
        c = TraceCache(maxsize=2)
        built = []
        c.get(self._key(0), lambda: built.append(0) or "a")
        entry, hit = c.get(self._key(0), lambda: built.append(1) or "b")
        assert entry == "a" and hit and built == [0]
        c.get(self._key(1), lambda: "c")
        c.get(self._key(2), lambda: "d")           # evicts LRU key(0)
        assert self._key(0) not in c and len(c) == 2
        assert c.stats() == {"hits": 1, "misses": 3, "evictions": 1,
                             "size": 2, "hit_rate": 0.25}

    def test_lru_touch_refreshes(self):
        c = TraceCache(maxsize=2)
        c.get(self._key(0), lambda: "a")
        c.get(self._key(1), lambda: "b")
        c.get(self._key(0))                        # touch -> key(1) is LRU
        c.get(self._key(2), lambda: "c")
        assert self._key(0) in c and self._key(1) not in c

    def test_miss_without_builder_raises(self):
        with pytest.raises(KeyError):
            TraceCache().get(self._key(0))

    def test_context_surfaces_cache(self):
        ctx = Context()
        assert "trace_cache" not in ctx.dispatch_report()
        ctx.trace_cache = TraceCache()
        assert ctx.dispatch_report()["trace_cache"]["hits"] == 0


# ---------------------------------------------------------------------------
# padded-lane stats hygiene (satellite a)
# ---------------------------------------------------------------------------

class TestPaddedLanes:
    def test_padding_invariance_and_masked_stats(self):
        # 13 live systems padded to 16 (NOT a lane multiple): live
        # lanes must take the IDENTICAL discrete path (exact step
        # counts) with trajectories matching to ULP-level tolerance —
        # XLA fuses the nsys=16 program differently than the nsys=13
        # one, so last-bit float equality across the two programs is
        # not guaranteed — and live=-masked aggregates must exclude
        # the dead lanes
        live_n, pad_n, tf = 13, 16, 0.3
        f, jac, y0 = batched_robertson(live_n)
        f_soa, jac_soa = batched_robertson_soa(live_n)
        sol_ref = integrate(IVP(f=f, jac=jac, f_soa=f_soa,
                                jac_soa=jac_soa, y0=y0),
                            0.0, tf, "ensemble_bdf")

        def pad(fn, in_axis, out_axis):
            def wrapped(t, y):
                t_live = t[:live_n] if getattr(t, "ndim", 0) else t
                out = fn(t_live,
                         jnp.take(y, jnp.arange(live_n), axis=in_axis))
                pad_width = [(0, 0)] * out.ndim
                pad_width[out_axis] = (0, pad_n - live_n)
                return jnp.pad(out, pad_width, mode="edge")
            return wrapped

        # the padded problem replicates the LAST live system's physics
        # into the dead lanes (edge padding), matching the serving
        # convention of replicating the last live request
        y0p = jnp.concatenate(
            [y0, jnp.broadcast_to(y0[-1], (pad_n - live_n, 3))])
        tfv = jnp.where(jnp.arange(pad_n) < live_n, tf, 0.0)
        mask = np.arange(pad_n) < live_n
        sol_pad = integrate(IVP(f=pad(f, 0, 0), jac=pad(jac, 0, 0),
                                f_soa=pad(f_soa, 1, 1),
                                jac_soa=pad(jac_soa, 1, 2), y0=y0p),
                            0.0, tfv, "ensemble_bdf", live=mask)

        assert np.allclose(np.asarray(sol_pad.y[:live_n]),
                           np.asarray(sol_ref.y), rtol=1e-9, atol=1e-12)
        st_p, st_r = sol_pad.stats, sol_ref.stats
        assert np.array_equal(np.asarray(st_p.steps[:live_n]),
                              np.asarray(st_r.steps))
        # dead lanes zeroed by the mask, forced successful
        assert np.all(np.asarray(st_p.steps[live_n:]) == 0)
        assert np.all(np.asarray(st_p.nni[live_n:]) == 0)
        assert np.all(np.asarray(st_p.success[live_n:]))
        # aggregates count live work only
        assert int(sol_pad.nni) == int(sol_ref.nni)
        assert int(jnp.sum(sol_pad.nsetups)) == int(jnp.sum(sol_ref.nsetups))
        assert bool(sol_pad.success) == bool(sol_ref.success)

    def test_live_mask_rejected_for_scalar_methods(self):
        with pytest.raises(ValueError, match="live"):
            integrate(IVP(f=lambda t, y: -y, y0=jnp.ones(2)),
                      0.0, 1.0, "erk:dopri5", live=np.array([True]))


# ---------------------------------------------------------------------------
# warm-start continuation (satellite b)
# ---------------------------------------------------------------------------

class TestWarmStart:
    def test_split_matches_uninterrupted_and_beats_cold_restart(self):
        nsys, tm, tf, rtol = 4, 0.3, 0.8, 1e-6
        f, jac, y0 = batched_robertson(nsys)
        f_soa, jac_soa = batched_robertson_soa(nsys)
        prob = IVP(f=f, jac=jac, f_soa=f_soa, jac_soa=jac_soa, y0=y0)

        full = integrate(prob, 0.0, tf, "ensemble_bdf")
        leg1 = integrate(prob, 0.0, tm, "ensemble_bdf",
                         return_session=True)
        assert isinstance(leg1.session, SolverSession)
        leg2 = integrate(IVP(f=f, jac=jac, f_soa=f_soa, jac_soa=jac_soa,
                             y0=leg1.y),
                         tm, tf, "ensemble_bdf",
                         session=leg1.session, return_session=True)
        # parity: the split trajectory agrees with the uninterrupted
        # one to O(rtol) (different step sequences, same tolerance)
        rel = np.max(np.abs(np.asarray(leg2.y) - np.asarray(full.y)) /
                     (np.abs(np.asarray(full.y)) + 1e-30))
        assert rel < 100 * rtol
        assert bool(leg2.success)

        # the warm leg re-enters at terminal order/step: strictly fewer
        # steps than restarting the same leg cold from y(tm)
        cold = integrate(IVP(f=f, jac=jac, f_soa=f_soa, jac_soa=jac_soa,
                             y0=leg1.y), tm, tf, "ensemble_bdf")
        warm_steps = int(jnp.sum(leg2.stats.steps))
        cold_steps = int(jnp.sum(cold.stats.steps))
        assert warm_steps < cold_steps

        # session accounting: cumulative steps, per-call stats
        assert np.all(np.asarray(leg2.session.steps) ==
                      np.asarray(leg1.session.steps) +
                      np.asarray(leg2.stats.steps))
        assert np.allclose(np.asarray(leg2.session.t), tf)

    def test_cold_session_start_is_value_exact(self):
        # integrating WITH a cold session must match integrating
        # without one bitwise (the h<=0 sentinel path is the cold path)
        nsys = 3
        f, jac, y0 = batched_robertson(nsys)
        plain_y, plain_st = ensemble_bdf_integrate(
            f, jac, y0, 0.0, 0.2)
        sess_y, sess_st, _ = ensemble_bdf_integrate(
            f, jac, y0, 0.0, 0.2,
            session=SolverSession.cold(y0, 0.0), return_session=True)
        assert np.array_equal(np.asarray(plain_y), np.asarray(sess_y))
        assert np.array_equal(np.asarray(plain_st.steps),
                              np.asarray(sess_st.steps))

    def test_session_lanes_concat_roundtrip(self):
        y0 = jnp.arange(12.0).reshape(4, 3)
        s = SolverSession.cold(y0, 1.5)
        assert (s.nsys, s.n) == (4, 3)
        lanes = [s.lanes(slice(i, i + 1)) for i in range(4)]
        assert lanes[0].nsys == 1
        back = SolverSession.concat(lanes)
        for a, b in zip(jax.tree_util.tree_leaves(back),
                        jax.tree_util.tree_leaves(s)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_sharded_rejects_session(self):
        from repro.core.batched import ensemble_bdf_integrate_sharded
        f, jac, y0 = batched_robertson(2)
        with pytest.raises(ValueError, match="session"):
            ensemble_bdf_integrate_sharded(
                f, jac, y0, 0.0, 0.1,
                session=SolverSession.cold(y0, 0.0))


# ---------------------------------------------------------------------------
# end-to-end server (tentpole)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def server():
    fr = robertson_family()
    fd = decay_chain_family(6)
    srv = SolverServer(
        [ProblemFamily("robertson", 3, fr[0], fr[1], fr[2], fr[3]),
         ProblemFamily("decay6", 6, fd[0], fd[1], fd[2], fd[3])],
        bucket_sizes=(4,), max_batch=4, max_wait=1e-3,
        warmup_bundles=4)
    yield srv
    srv.stop()


def _submit_rob(srv, tf=0.2, session=None, y0=(1.0, 0.0, 0.0), t0=0.0):
    return srv.submit("robertson", list(y0), t0, tf, params=ROB_PARAMS,
                      session=session)


class TestSolverServer:
    def test_mixed_bundle_end_to_end(self, server):
        futs = [_submit_rob(server) for _ in range(3)]
        futs.append(server.submit("decay6", np.ones(6), 0.0, 0.5,
                                  params={"k": np.linspace(0.5, 3.0, 6)}))
        assert server.drain() == 2                 # one bundle per family
        sols = [f.result(timeout=5) for f in futs]
        assert all(bool(s.success) for s in sols)
        assert sols[0].y.shape == (3,) and sols[-1].y.shape == (6,)
        # identical requests -> identical lane results
        assert np.array_equal(np.asarray(sols[0].y), np.asarray(sols[1].y))
        # per-request result matches a direct integrate of the same IVP
        # (params as (nsys,) arrays — the batch form the family expects)
        fr = robertson_family()
        pb = {k: jnp.full((1,), v) for k, v in ROB_PARAMS.items()}
        direct = integrate(
            IVP(f=lambda t, y: fr[0](t, y, pb),
                jac=lambda t, y: fr[1](t, y, pb),
                y0=jnp.asarray([[1.0, 0.0, 0.0]])),
            0.0, 0.2, "ensemble_bdf")
        assert np.allclose(np.asarray(sols[0].y),
                           np.asarray(direct.y[0]), rtol=1e-10, atol=1e-12)

    def test_timings_and_cache_reuse(self, server):
        stats0 = server.cache.stats()
        futs = [_submit_rob(server) for _ in range(4)]
        server.drain()
        s = futs[0].result(timeout=5)
        assert set(s.timings) == {"queue_wait", "compile", "execute"}
        assert s.timings["queue_wait"] >= 0.0
        assert s.timings["execute"] > 0.0
        # the robertson@4 trace was compiled by the previous test:
        # this bundle must be a pure hit with NO compile time billed
        assert s.timings["compile"] == 0.0
        stats1 = server.cache.stats()
        assert stats1["hits"] == stats0["hits"] + 1
        assert stats1["misses"] == stats0["misses"]
        assert server.metrics()["steady_misses"] == 0

    def test_warm_start_via_server(self, server):
        f1 = _submit_rob(server, tf=0.4)
        server.drain()
        s1 = f1.result(timeout=5)
        assert s1.session is not None and s1.session.nsys == 1
        leg = dict(tf=float(s1.t) + 0.4, y0=np.asarray(s1.y),
                   t0=float(s1.t))
        f_warm = _submit_rob(server, session=s1.session, **leg)
        f_cold = _submit_rob(server, **leg)
        server.drain()
        warm, cold = f_warm.result(timeout=5), f_cold.result(timeout=5)
        assert int(warm.stats.steps) < int(cold.stats.steps)
        assert bool(warm.success) and bool(cold.success)
        # warm+cold rode ONE bundle: same trace, occupancy accounted
        assert np.allclose(np.asarray(warm.y), np.asarray(cold.y),
                           rtol=1e-4)

    def test_backpressure_propagates(self):
        fr = robertson_family()
        srv = SolverServer(
            [ProblemFamily("robertson", 3, fr[0], fr[1])],
            bucket_sizes=(4,), max_batch=4, max_depth=2)
        _submit_rob(srv)
        _submit_rob(srv)
        with pytest.raises(RetryAfter):
            _submit_rob(srv)

    def test_submit_validation(self, server):
        with pytest.raises(ValueError, match="unknown family"):
            server.submit("nope", np.ones(3), 0.0, 1.0)
        with pytest.raises(ValueError, match="y0 shape"):
            server.submit("robertson", np.ones(4), 0.0, 1.0)

    def test_metrics_and_dispatch_report(self, server):
        m = server.metrics()
        for k in ("queue_depth", "rejected", "requests", "bundles",
                  "occupancy", "latency_p50_s", "latency_p99_s",
                  "steady_misses", "trace_cache"):
            assert k in m
        assert 0.0 < m["occupancy"] <= 1.0
        assert m["trace_cache"]["hits"] > 0
        rep = server.ctx.dispatch_report()
        assert rep["trace_cache"] == server.cache.stats()

    def test_async_facade(self, server):
        with server:                               # start()/stop()
            fut = _submit_rob(server)
            sol = fut.result(timeout=30)           # background pump
        assert bool(sol.success)
