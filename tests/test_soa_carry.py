"""SoA-carry acceptance gates (ISSUE 5 tentpole).

1. The refactored ensemble BDF (end-to-end SoA carry + fused Newton
   ops) reproduces the PRE-REFACTOR AoS-carry integrator **bitwise**
   under the jnp backend on batched_robertson (nsys in {130, 512}) —
   the reference below is a faithful condensation of the pre-SoA loop
   (einsum history rescale, per-iteration transposes), kept here as the
   oracle the jnp path is pinned to.  Native SoA RHS/Jacobian forms
   (``batched_robertson_soa``) must land on the same bits as the
   wrapped AoS forms.
2. jnp-vs-pallas(interpret) parity at 1e-10 for the three new fused
   Newton ops (+ the per-system ``wrms_soa``) with ragged batches.
3. Layout gate: sunlint's ``hot-loop-layout`` jaxpr rule proves the
   traced Newton ``while_loop`` bodies (BDF and DIRK) contain no
   transposes or copying reshapes — replacing the old source grep,
   which a commented-out ``.T`` tripped and a helper-function
   transpose evaded.
4. MemoryHelper: back-to-back ensemble integrations on one Context do
   not double-buffer the history (donated carry; labels released per
   call, high-water flat across repeats).
"""
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched, controller as ctrl, cvode as _cv
from repro.core import dispatch as dv
from repro.core.arkode import ODEOptions
from repro.core.linsol import BlockDiagGJ
from repro.core.policies import ExecPolicy, XLA_FUSED
from repro.core.problems import batched_robertson, batched_robertson_soa


# ---------------------------------------------------------------------------
# The pre-refactor AoS-carry ensemble BDF (condensed, default solver
# config): history (nsys, QMAX+1, n), Newton iterate (nsys, n), einsum
# history rescale, -g.T / dz.T transposes on every Newton iteration and
# jnp.transpose(J, (1,2,0)) at every lsetup — the bitwise oracle.
# ---------------------------------------------------------------------------


class _AosCarry(NamedTuple):
    t: jnp.ndarray
    h: jnp.ndarray
    q: jnp.ndarray
    Z: jnp.ndarray
    e1: jnp.ndarray
    e2: jnp.ndarray
    MJ: jnp.ndarray
    gam_saved: jnp.ndarray
    since_jac: jnp.ndarray
    ncf_prev: jnp.ndarray
    steps: jnp.ndarray
    att: jnp.ndarray
    netf: jnp.ndarray
    nni: jnp.ndarray
    nsetups: jnp.ndarray
    ncfn: jnp.ndarray
    stall: jnp.ndarray


def _aos_bdf_reference(f, jac, y0, t0, tf, *, order=5,
                       opts=ODEOptions(), msbp=20, dgmax=0.3):
    from jax import lax
    ls = BlockDiagGJ()
    policy = XLA_FUSED
    nsys, n = y0.shape
    dtype = y0.dtype
    QMAX = _cv.QMAX
    t0 = jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,))
    tf = jnp.broadcast_to(jnp.asarray(tf, dtype), (nsys,))
    h0 = jnp.where(opts.h0 > 0, jnp.full((nsys,), opts.h0, dtype),
                   jnp.maximum(1e-6 * (tf - t0), 1e-12))
    one = jnp.ones((), dtype)

    def wrms(v, w):
        return jnp.sqrt(jnp.mean((v * w) ** 2, axis=1))

    def cond(c):
        return jnp.any((c.t < tf * (1 - 1e-12)) & (~c.stall)) & \
            jnp.all(c.att < opts.max_steps)

    def body(c):
        active = (c.t < tf * (1 - 1e-12)) & (~c.stall)
        hs = jnp.where(active, jnp.minimum(c.h, tf - c.t), c.h)
        nvalid = jnp.minimum(c.steps, QMAX)
        eta_clip = jnp.where(active, hs / c.h, one)
        W = jax.vmap(_cv._lagrange_matrix)(eta_clip, nvalid)
        Z = jnp.einsum("sji,sik->sjk", W, c.Z)
        qi = c.q - 1
        alphas = _cv._ALPHA_T[qi].astype(dtype)
        beta = _cv._BETA_T[qi].astype(dtype)
        p_pred = jnp.minimum(nvalid, c.q)
        pred_c = _cv._PREDP_T[p_pred].astype(dtype)
        y_pred = jnp.einsum("sj,sjk->sk", pred_c, Z)
        psi = -jnp.einsum("sj,sjk->sk", alphas[:, 1:], Z[:, :-1])
        gamma = beta * hs
        t_new = c.t + hs
        w = 1.0 / (opts.rtol * jnp.abs(Z[:, 0]) + opts.atol)

        gamrat = gamma / jnp.where(c.gam_saved != 0, c.gam_saved, gamma)
        need = active & ((c.gam_saved == 0) | c.ncf_prev |
                         (c.since_jac >= msbp) |
                         (jnp.abs(gamrat - 1.0) > dgmax))

        def do_setup(_):
            J = jac(t_new, y_pred)
            return ls.soa_setup(jnp.transpose(J, (1, 2, 0)), gamma, policy)

        MJ_new = lax.cond(jnp.any(need), do_setup, lambda _: c.MJ,
                          operand=None)
        MJ = jax.tree_util.tree_map(
            lambda new, old: jnp.where(need, new, old), MJ_new, c.MJ)
        gam_saved = jnp.where(need, gamma, c.gam_saved)
        since_jac = jnp.where(need, 0, c.since_jac)
        gamrat = jnp.where(need, 1.0, gamrat)

        def nl_cond(s):
            z, it, dn_prev, crate, conv, div, nni_s = s
            return jnp.any(active & ~conv & ~div) & (it < opts.newton_max)

        def nl_body(s):
            z, it, dn_prev, crate, conv, div, nni_s = s
            iterate = active & ~conv & ~div
            g = z - gamma[:, None] * f(t_new, z) - psi
            dz_soa, _, _ = ls.soa_solve(MJ, gamma, gamrat, -g.T, policy)
            dz = dz_soa.T
            z_new = jnp.where(iterate[:, None], z + dz, z)
            dn = wrms(dz, w)
            crate_new = jnp.where(
                it > 0,
                jnp.maximum(0.3 * crate,
                            dn / jnp.maximum(dn_prev, 1e-30)), crate)
            conv_new = conv | (iterate &
                               (dn * jnp.minimum(one, crate_new) <
                                opts.newton_tol_fac))
            div_new = div | (iterate & (it > 0) & (dn > 2.0 * dn_prev))
            return (z_new, it + 1,
                    jnp.where(iterate, dn, dn_prev),
                    jnp.where(iterate, crate_new, crate),
                    conv_new, div_new, nni_s + iterate.astype(jnp.int32))

        s0 = (y_pred, jnp.zeros((), jnp.int32), jnp.zeros((nsys,), dtype),
              jnp.ones((nsys,), dtype), ~active, jnp.zeros((nsys,), bool),
              jnp.zeros((nsys,), jnp.int32))
        z, _, _, _, conv, _, nni_s = lax.while_loop(nl_cond, nl_body, s0)

        err = wrms(z - y_pred, w) / (c.q.astype(dtype) + 1.0)
        bad = ~jnp.isfinite(err) | ~conv
        err = jnp.where(bad, 2.0, err)
        accept = (err <= 1.0) & ~bad & active

        cst = ctrl.ControllerState(err_prev=c.e1, err_prev2=c.e2)
        eta, cst_new = ctrl.eta_from_error(opts.controller, cst, err,
                                           c.q + 1,
                                           after_failure=(~accept) & conv)
        eta = jnp.where(conv | ~active, eta, opts.eta_cf)
        eta = jnp.clip(eta, 0.1, 10.0)
        hs_safe = jnp.maximum(hs, jnp.finfo(dtype).tiny)
        eta = jnp.clip(eta, opts.hmin / hs_safe, opts.hmax / hs_safe)
        e1 = jnp.where(accept, cst_new.err_prev, c.e1)
        e2 = jnp.where(accept, cst_new.err_prev2, c.e2)

        Z_acc = jnp.roll(Z, 1, axis=1).at[:, 0].set(z)
        Z_next = jnp.where(accept[:, None, None], Z_acc, Z)
        q_next = jnp.where(accept, jnp.minimum(c.q + 1, order), c.q)
        nval_after = jnp.minimum(c.steps + accept.astype(jnp.int32), QMAX)
        W2 = jax.vmap(_cv._lagrange_matrix)(
            jnp.where(active, eta, one), nval_after)
        Z_next = jnp.einsum("sji,sik->sjk", W2, Z_next)

        t_next = jnp.where(accept, t_new, c.t)
        h_next = jnp.where(active, hs * eta, c.h)
        stall = c.stall | (active & (hs * eta < 1e-14))
        ncf = active & ~conv
        ai = active.astype(jnp.int32)
        return _AosCarry(
            t=t_next, h=h_next, q=q_next, Z=Z_next, e1=e1, e2=e2,
            MJ=MJ, gam_saved=gam_saved, since_jac=since_jac + ai,
            ncf_prev=ncf,
            steps=c.steps + accept.astype(jnp.int32),
            att=c.att + ai,
            netf=c.netf + ((~accept) & conv & active).astype(jnp.int32),
            nni=c.nni + nni_s,
            nsetups=c.nsetups + need.astype(jnp.int32),
            ncfn=c.ncfn + ncf.astype(jnp.int32), stall=stall)

    zero = jnp.zeros((nsys,), jnp.int32)
    Z0 = jnp.zeros((nsys, QMAX + 1, n), dtype).at[:, 0].set(y0)
    c = _AosCarry(
        t=t0, h=h0, q=jnp.ones((nsys,), jnp.int32), Z=Z0,
        e1=jnp.ones((nsys,), dtype), e2=jnp.ones((nsys,), dtype),
        MJ=ls.soa_carry_init(n, nsys, dtype),
        gam_saved=jnp.zeros((nsys,), dtype), since_jac=zero,
        ncf_prev=jnp.zeros((nsys,), bool), steps=zero, att=zero,
        netf=zero, nni=zero, nsetups=zero, ncfn=zero,
        stall=jnp.zeros((nsys,), bool))
    c = jax.lax.while_loop(cond, body, c)
    return c.Z[:, 0], c


# ---------------------------------------------------------------------------
# 1. bitwise trajectory parity, SoA carry vs pre-refactor AoS carry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nsys", [130, 512])
def test_soa_carry_bitwise_vs_pre_refactor_aos(nsys):
    f, jac, y0 = batched_robertson(nsys)
    opts = ODEOptions(rtol=1e-5, atol=1e-10, max_steps=100_000)
    y_ref, c_ref = _aos_bdf_reference(f, jac, y0, 0.0, 10.0, opts=opts)
    y_new, st = batched.ensemble_bdf_integrate(
        f, jac, y0, 0.0, 10.0, opts=opts, policy=XLA_FUSED)
    assert bool(jnp.all(st.success))
    assert np.array_equal(np.asarray(y_ref), np.asarray(y_new)), \
        "SoA-carry jnp trajectory must be bitwise-identical to the " \
        "pre-refactor AoS path"
    # decision streams pinned too, not just the endpoint
    assert np.array_equal(np.asarray(c_ref.steps), np.asarray(st.steps))
    assert np.array_equal(np.asarray(c_ref.nni), np.asarray(st.nni))
    assert np.array_equal(np.asarray(c_ref.nsetups), np.asarray(st.nsetups))
    assert np.array_equal(np.asarray(c_ref.netf), np.asarray(st.netf))


def test_native_soa_rhs_matches_wrapped_aos_bitwise():
    """batched_robertson_soa's native SoA f/jac land on the same bits
    as the transposing wrapper around the AoS forms."""
    nsys = 130
    f, jac, y0 = batched_robertson(nsys)
    f_soa, jac_soa = batched_robertson_soa(nsys)
    opts = ODEOptions(rtol=1e-5, atol=1e-10, max_steps=100_000)
    y_w, st_w = batched.ensemble_bdf_integrate(
        f, jac, y0, 0.0, 10.0, opts=opts)
    y_n, st_n = batched.ensemble_bdf_integrate(
        f, jac, y0, 0.0, 10.0, opts=opts, f_soa=f_soa, jac_soa=jac_soa)
    assert bool(jnp.all(st_n.success))
    assert np.array_equal(np.asarray(y_w), np.asarray(y_n))
    assert np.array_equal(np.asarray(st_w.steps), np.asarray(st_n.steps))


# ---------------------------------------------------------------------------
# 2. fused-op jnp vs pallas(interpret) parity, ragged batches
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb", [7, 130, 516])
@pytest.mark.parametrize("tile", [128, 512])
def test_fused_newton_ops_parity_ragged(nb, tile):
    n, q1 = 3, _cv.QMAX + 1
    pol = ExecPolicy(backend="pallas", interpret=True, batch_tile=tile)
    z = jax.random.normal(jax.random.PRNGKey(0), (n, nb))
    fv = jax.random.normal(jax.random.PRNGKey(1), (n, nb))
    psi = jax.random.normal(jax.random.PRNGKey(2), (n, nb))
    gam = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (nb,)))
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (n, nb))) + 0.1
    m = jax.random.uniform(jax.random.PRNGKey(5), (nb,)) > 0.4
    W = jax.random.normal(jax.random.PRNGKey(6), (q1, q1, nb))
    Z = jax.random.normal(jax.random.PRNGKey(7), (q1, n, nb))

    for negate in (False, True):
        a = dv.newton_residual_soa(z, fv, psi, gam, XLA_FUSED,
                                   negate=negate)
        b = dv.newton_residual_soa(z, fv, psi, gam, pol, negate=negate)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-10)
    za, dna = dv.masked_update_wrms_soa(z, fv, w, m, XLA_FUSED)
    zb, dnb = dv.masked_update_wrms_soa(z, fv, w, m, pol)
    np.testing.assert_allclose(np.asarray(za), np.asarray(zb),
                               rtol=0, atol=1e-10)
    np.testing.assert_allclose(np.asarray(dna), np.asarray(dnb),
                               rtol=0, atol=1e-10)
    ra = dv.history_rescale_soa(W, Z, m, XLA_FUSED)
    rb = dv.history_rescale_soa(W, Z, m, pol)
    np.testing.assert_allclose(np.asarray(ra), np.asarray(rb),
                               rtol=0, atol=1e-10)
    # inactive systems pass through bit-exactly on both backends
    assert np.array_equal(np.asarray(ra[:, :, ~np.asarray(m)]),
                          np.asarray(Z[:, :, ~np.asarray(m)]))
    r0 = dv.history_rescale_soa(W, Z, jnp.zeros((nb,), bool), pol)
    assert np.array_equal(np.asarray(r0), np.asarray(Z))
    wa = dv.wrms_soa(z, w, XLA_FUSED)
    wb = dv.wrms_soa(z, w, pol)
    np.testing.assert_allclose(np.asarray(wa), np.asarray(wb),
                               rtol=0, atol=1e-10)


# ---------------------------------------------------------------------------
# 3. layout gate: no layout conversions inside the Newton loop bodies
# (the sunlint jaxpr rule — the retired source grep passed on
# commented-out transposes and missed helper-function ones)
# ---------------------------------------------------------------------------


def test_newton_loop_body_has_no_transposes():
    from repro.analysis import lint
    ctx = lint.LintContext()
    violations = lint.run_rules(ctx, ["hot-loop-layout"])
    assert violations == [], "\n".join(
        f"{v.where}: {v.message}" for v in violations)


# ---------------------------------------------------------------------------
# 4. donated carry: back-to-back runs don't double-buffer the history
# ---------------------------------------------------------------------------


def test_history_not_double_buffered_across_runs():
    from repro.core.context import Context
    from repro.core.ivp import IVP, integrate

    nsys = 8
    f, jac, y0 = batched_robertson(nsys)
    prob = IVP(f=f, jac=jac, y0=y0)
    ctx = Context()
    opts = ctx.options(rtol=1e-5, atol=1e-10, max_steps=100_000)
    sol1 = integrate(prob, 0.0, 1.0, "ensemble_bdf", ctx=ctx, opts=opts)
    hw1 = ctx.memory.high_water_bytes
    live1 = ctx.memory.live_bytes
    sol2 = integrate(prob, 0.0, 1.0, "ensemble_bdf", ctx=ctx, opts=opts)
    assert bool(sol1.success) and bool(sol2.success)
    # labels were released between the calls, so the second history
    # registration reuses the same accounting slot: high-water is FLAT
    assert ctx.memory.high_water_bytes == hw1
    assert ctx.memory.live_bytes == live1
    # the donated-carry path really ran twice with identical results
    assert bool(jnp.all(sol1.y == sol2.y))
    # and the history workspace was actually accounted (nonzero)
    assert sol1.workspace_bytes >= \
        (_cv.QMAX + 1) * 3 * nsys * np.dtype(np.float64).itemsize


def test_donation_never_deletes_caller_arrays():
    """Donating the carry must not consume CALLER buffers: an (nsys,)
    t0 of the carry dtype short-circuits broadcast_to/asarray, so the
    carry takes an explicit copy (regression: the caller's t0 raised
    'Array has been deleted' after the integration)."""
    nsys = 6
    f, jac, y0 = batched_robertson(nsys)
    t0 = jnp.zeros((nsys,), jnp.float64)
    opts = ODEOptions(rtol=1e-5, atol=1e-10, max_steps=100_000)
    y, st = batched.ensemble_bdf_integrate(f, jac, y0, t0, 1.0, opts=opts)
    assert bool(jnp.all(st.success))
    # both caller arrays must still be alive and usable
    assert float(jnp.sum(t0)) == 0.0
    assert float(jnp.sum(y0)) == nsys
