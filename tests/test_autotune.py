"""Cost-model-driven dispatch tests: the analytical opcost model, the
persisted autotune cache (round-trip, schema invalidation, model
fallback), the ``backend='auto'`` resolver, the regenerated op-table
docs, and the acceptance criteria (auto trajectory parity, BENCH-winner
agreement, >=80% model-vs-measurement agreement on the committed
cache)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import opcost, roofline
from repro.core import autotune
from repro.core import dispatch as dp
from repro.core import policies
from repro.core.policies import AUTO, XLA_FUSED

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sig(op="linear_sum", n=4096, **kw):
    return opcost.OpSig(op=op, dtype="float64", n=n, **kw)


def _entry(sig, t_jnp=1e-3, t_pallas=2e-3, tile=0):
    return autotune.Entry(sig=sig, t_jnp=t_jnp, t_pallas=t_pallas,
                          tile=tile)


# ---------------------------------------------------------------------------
# satellite: unknown-op dispatch error
# ---------------------------------------------------------------------------


def test_dispatch_unknown_op_is_a_named_error():
    with pytest.raises(ValueError) as exc:
        dp.dispatch("frobnicate", XLA_FUSED)
    msg = str(exc.value)
    assert "frobnicate" in msg
    # the error enumerates the valid table so the caller can self-serve
    for op in ("linear_sum", "block_solve_soa", "csr_spmv"):
        assert op in msg


# ---------------------------------------------------------------------------
# satellite: roofline device table
# ---------------------------------------------------------------------------


def test_device_table_and_aliases():
    assert {"tpu_v5e", "tpu_v4", "interpret"} <= set(roofline.DEVICES)
    v5e = roofline.get_device("tpu_v5e")
    assert roofline.PEAK_FLOPS == v5e.peak_flops
    assert roofline.HBM_BW == v5e.hbm_bw
    assert roofline.ICI_BW == v5e.ici_bw
    # the pseudo-device has no VMEM budget and interpreter overheads
    interp = roofline.get_device("interpret")
    assert interp.interpret and interp.vmem_bytes is None
    assert interp.interp_op > 0
    with pytest.raises(ValueError, match="unknown roofline device"):
        roofline.get_device("gtx480")
    # finalize accepts a device name (the old hardcoded-v5e path)
    rl = roofline.Roofline(arch="x", shape="s", mesh="m", chips=1,
                           hlo_flops=1e12, hlo_bytes=1e9, coll_bytes=0.0,
                           model_flops=1e12)
    t_mem_v5e = rl.finalize("tpu_v5e").t_memory
    t_mem_v4 = rl.finalize("tpu_v4").t_memory
    assert t_mem_v4 < t_mem_v5e          # v4 has more HBM bandwidth


# ---------------------------------------------------------------------------
# opcost: signatures and the analytical model
# ---------------------------------------------------------------------------


def test_opcost_signature_covers_every_op():
    n, nsys, b = 256, 130, 3
    x = jnp.ones((n,))
    A = jnp.eye(b)[:, :, None] * jnp.ones((1, 1, nsys))
    r = jnp.ones((b, nsys))
    z = jnp.ones((b, nsys))
    gm = jnp.ones((nsys,))
    mk = jnp.ones((nsys,), bool)
    Wh = jnp.ones((6, 6, nsys))
    Zh = jnp.ones((6, b, nsys))
    data = jnp.ones((17,))
    pat = (tuple(range(5)), tuple(range(5)), 5)
    Vb = jnp.ones((5, b, b, nsys))
    xb = jnp.ones((5, b, nsys))
    args = {
        "linear_sum": (2.0, x, -0.5, x), "axpy": (1.7, x, x),
        "linear_combination": ([1.0, 2.0], [x, x]),
        "scale_add_multi": ([1.0, 2.0], x, [x, x]),
        "dot": (x, x), "wrms_norm": (x, x), "wrms_ss": (x, x),
        "wrms_norm_mask": (x, x, x), "dot_prod_multi": (x, [x, x]),
        "block_solve_soa": (A, r), "block_inverse_soa": (A,),
        "blockdiag_spmv_soa": (A, r),
        "newton_residual_soa": (z, z, z, gm, True),
        "masked_update_wrms_soa": (z, z, z, mk),
        "history_rescale_soa": (Wh, Zh, mk), "wrms_soa": (z, z),
        "csr_spmv": (data, x, None), "bsr_spmv_soa": (Vb, xb, pat),
        "bsr_block_jacobi_inverse_soa": (Vb, pat),
    }
    assert set(args) == set(dp.OP_TABLE)
    for op, a in args.items():
        sig = opcost.signature(op, a)
        assert sig.op == op
        assert sig.axis_len > 0
        cost = opcost.op_cost(sig)
        assert cost.flops > 0 and cost.jnp_bytes > 0
        pred = opcost.predict(sig, "interpret")
        assert pred.winner in ("jnp", "pallas")
        assert pred.tile % 128 == 0
    with pytest.raises(ValueError, match="frobnicate"):
        opcost.signature("frobnicate", (x,))
    with pytest.raises(ValueError, match="frobnicate"):
        opcost.op_cost(_sig(op="frobnicate"))


def test_tile_for_vmem_budget_vs_interpret():
    sig = opcost.OpSig(op="block_solve_soa", dtype="float64",
                       n=16, nsys=32768, b=16)
    # interpret: one big lane-padded step, capped at 2^16
    interp = opcost.tile_for(sig, roofline.get_device("interpret"))
    assert interp == 32768
    # compiled: VMEM-bounded — (b x width x tile x 8B) <= vmem_bytes
    v5e = roofline.get_device("tpu_v5e")
    comp = opcost.tile_for(sig, v5e)
    rows = opcost.op_cost(sig).vmem_rows
    assert rows * comp * sig.itemsize <= v5e.vmem_bytes
    assert comp < interp
    # a requested tile clamps further
    assert opcost.tile_for(sig, v5e, requested=256) <= 256


# ---------------------------------------------------------------------------
# satellite: autotune cache persistence + invalidation + fallback
# ---------------------------------------------------------------------------


def test_cache_round_trip(tmp_path):
    path = tmp_path / "interpret.json"
    cache = autotune.AutotuneCache("interpret", path=path)
    e1 = _entry(_sig(), t_jnp=1e-4, t_pallas=9e-4)              # jnp wins
    e2 = _entry(_sig(op="block_solve_soa", n=3, nsys=512, b=3),
                t_jnp=5e-3, t_pallas=1e-4, tile=512)            # pallas wins
    cache.put(e1)
    cache.put(e2)
    assert cache.save() == path
    fresh = autotune.AutotuneCache("interpret", path=path).load()
    assert not fresh.stale
    assert set(fresh.entries) == {e1.sig.key(), e2.sig.key()}
    got = fresh.get(e2.sig)
    assert got.winner == "pallas" and got.tile == 512
    assert got.sig == e2.sig
    assert fresh.get(e1.sig).winner == "jnp"


def test_cache_schema_bump_invalidates(tmp_path):
    path = tmp_path / "interpret.json"
    cache = autotune.AutotuneCache("interpret", path=path)
    cache.put(_entry(_sig()))
    cache.save()
    payload = json.loads(path.read_text())
    payload["schema"] = autotune.SCHEMA_VERSION + 1
    path.write_text(json.dumps(payload))
    stale = autotune.AutotuneCache("interpret", path=path).load()
    assert stale.entries == {} and stale.stale
    # wrong device in the payload is equally stale
    payload["schema"] = autotune.SCHEMA_VERSION
    payload["device"] = "tpu_v4"
    path.write_text(json.dumps(payload))
    wrong = autotune.AutotuneCache("interpret", path=path).load()
    assert wrong.entries == {} and wrong.stale


def test_cache_corrupt_entries_dropped_not_fatal(tmp_path):
    path = tmp_path / "interpret.json"
    cache = autotune.AutotuneCache("interpret", path=path)
    good = _entry(_sig())
    cache.put(good)
    cache.save()
    payload = json.loads(path.read_text())
    # a key that disagrees with its recorded signature, and raw garbage
    payload["entries"]["mismatched-key"] = good.to_json()
    payload["entries"]["garbage"] = {"no": "fields"}
    path.write_text(json.dumps(payload))
    loaded = autotune.AutotuneCache("interpret", path=path).load()
    assert loaded.stale
    assert set(loaded.entries) == {good.sig.key()}
    # a missing file is a clean cold cache, not stale and not an error
    cold = autotune.AutotuneCache("interpret",
                                  path=tmp_path / "nope.json").load()
    assert cold.entries == {} and not cold.stale


def test_resolver_cache_miss_falls_back_to_model(tmp_path):
    empty = autotune.AutotuneCache("interpret",
                                   path=tmp_path / "none.json").load()
    res = autotune.Resolver("interpret", cache=empty)
    dec = res.decide(_sig())
    assert dec.source == "model"
    assert dec.backend in ("jnp", "pallas")
    assert dec.cached_winner is None and dec.agree is None
    # memoized per signature; hit count tracks call sites
    again = res.decide(_sig())
    assert again is dec and dec.hits == 2


def test_resolver_cache_hit_near_and_override(tmp_path):
    cache = autotune.AutotuneCache("interpret",
                                   path=tmp_path / "c.json")
    meas = _entry(_sig(op="wrms_soa", n=3, nsys=4096),
                  t_jnp=5e-4, t_pallas=1e-4, tile=4096)
    cache.put(meas)
    res = autotune.Resolver("interpret", cache=cache)
    # exact hit: measured winner + measured tile (clamped to the axis)
    dec = res.decide(_sig(op="wrms_soa", n=3, nsys=4096))
    assert (dec.source, dec.backend) == ("cache", "pallas")
    assert dec.tile <= 4096
    # nearest: same op/dtype/structure, axis within 8x
    near = res.decide(_sig(op="wrms_soa", n=3, nsys=8192))
    assert (near.source, near.backend) == ("near", "pallas")
    # beyond 8x: back to the model
    far = res.decide(_sig(op="wrms_soa", n=3, nsys=4096 * 32))
    assert far.source == "model"
    # an override pins regardless of cache
    forced = res.decide(_sig(op="wrms_soa", n=3, nsys=4096),
                        override="jnp")
    assert (forced.source, forced.backend) == ("override", "jnp")
    # report carries the decisions and the model audit fields
    rep = res.report()
    assert rep["cache_entries"] == 1
    assert {"model_agreement", "mispredictions"} <= set(rep)
    assert any(d["source"] == "near" for d in rep["decisions"])


def test_policy_op_overrides_pin_without_resolver():
    pol = AUTO.override(dot="jnp", block_solve_soa="pallas")
    assert pol.backend_for("dot") == "jnp"
    assert pol.backend_for("block_solve_soa") == "pallas"
    assert pol.backend_for("axpy") == "auto"
    assert pol.backend == "auto" and hash(pol) is not None
    # a pinned op dispatches directly — the resolver is never consulted
    autotune.reset_resolver("interpret")
    x = jnp.arange(8.0)
    got = dp.dot(x, x, AUTO.override(dot="jnp"))
    np.testing.assert_allclose(np.asarray(got), float(jnp.dot(x, x)))
    assert "interpret" not in autotune._RESOLVERS


def test_auto_dispatch_matches_jnp_and_works_under_jit():
    nsys, b = 516, 3
    A = jax.random.normal(jax.random.PRNGKey(0), (b, b, nsys)) + \
        (b + 2.0) * jnp.eye(b)[:, :, None]
    r = jax.random.normal(jax.random.PRNGKey(1), (b, nsys))
    ref = dp.block_solve_soa(A, r, XLA_FUSED)
    got = dp.block_solve_soa(A, r, AUTO)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-10)
    jitted = jax.jit(lambda A, r: dp.block_solve_soa(A, r, AUTO))
    np.testing.assert_allclose(np.asarray(jitted(A, r)), np.asarray(ref),
                               atol=1e-10)
    x = jnp.linspace(0.0, 1.0, 4096)
    np.testing.assert_allclose(
        float(dp.wrms_norm(x, x + 1.0, AUTO)),
        float(dp.wrms_norm(x, x + 1.0, XLA_FUSED)), rtol=1e-12)


def test_gj_batch_tile_vmem_override():
    from repro.kernels import ops
    base = ops._gj_batch_tile(4096, 4096, b=16, width=17, itemsize=8,
                              interpret=False)
    assert base == 512                      # the pinned default-budget tile
    bigger = ops._gj_batch_tile(4096, 4096, b=16, width=17, itemsize=8,
                                interpret=False,
                                vmem_bytes=4 * 1024 * 1024)
    assert bigger > base
    # interpret mode ignores the budget entirely
    assert ops._gj_batch_tile(4096, 4096, b=16, width=17, itemsize=8,
                              interpret=True,
                              vmem_bytes=1024) == 4096


# ---------------------------------------------------------------------------
# satellite: regenerated op-table docs
# ---------------------------------------------------------------------------


def test_op_table_docs_are_generated_and_complete():
    rows = dp.op_table_rows()
    assert {r[0] for r in rows} == set(dp.OP_TABLE)
    # the policies docstring embeds the rst rendering verbatim
    assert dp.render_op_table("rst") in policies.__doc__
    # the README embeds the markdown rendering verbatim
    with open(os.path.join(REPO, "README.md")) as fh:
        readme = fh.read()
    assert dp.render_op_table("md") in readme
    # every OP_TABLE op appears by name in both renderings
    for op in dp.OP_TABLE:
        assert op in dp.render_op_table("rst")
        assert op in dp.render_op_table("md")


# ---------------------------------------------------------------------------
# acceptance: committed cache vs model, BENCH winners, auto trajectory
# ---------------------------------------------------------------------------


def _committed_cache():
    cache = autotune.AutotuneCache("interpret").load()
    if not cache.entries:
        pytest.skip("no committed autotune cache "
                    "(run: python -m benchmarks.run --tune)")
    return cache


def test_model_agrees_with_committed_cache():
    cache = _committed_cache()
    audit = autotune.model_audit(cache)
    assert audit["model_total"] == len(cache.entries)
    assert audit["model_agreement"] >= 0.8
    # mispredictions (if any) are itemized with both ratios
    for m in audit["mispredictions"]:
        assert {"sig", "measured", "predicted"} <= set(m)


def test_context_dispatch_report_surfaces_audit():
    from repro.core.context import Context
    autotune.reset_resolver("interpret")
    ctx = Context(policy=AUTO)
    x = jnp.linspace(0.0, 1.0, 4096)
    dp.dot(x, x, ctx.policy)
    rep = ctx.dispatch_report()
    assert rep["device"] == "interpret"
    assert rep["cache_entries"] > 0
    assert any(d["op"] == "dot" for d in rep["decisions"])
    assert rep["model_agreement"] is not None
    assert "mispredictions" in rep


def test_auto_resolves_bench_winners():
    """The resolved backend must agree with the committed BENCH winner
    on >= 10/12 ensemble configs (acceptance criterion)."""
    with open(os.path.join(REPO, "BENCH_ensemble.json")) as fh:
        bench = json.load(fh)
    cache = _committed_cache()
    res = autotune.Resolver("interpret", cache=cache)
    agree = total = 0
    for cfg in bench["results"]:
        b, nsys = int(cfg["block_size"]), int(cfg["nsys"])
        committed = "pallas" if cfg["pallas_interpret_systems_per_sec"] \
            > cfg["jnp_systems_per_sec"] else "jnp"
        sig = opcost.OpSig(op="block_solve_soa", dtype="float64",
                           n=b, nsys=nsys, b=b)
        dec = res.decide(sig)
        total += 1
        agree += int(dec.backend == committed)
    assert total == 12
    assert agree >= 10, f"only {agree}/{total} BENCH winners resolved"


def test_auto_ensemble_bdf_matches_fixed_backend_trajectory():
    """IVP.integrate under backend='auto' must land on the same
    trajectory as the fixed jnp backend (same tolerance discipline as
    the jnp-vs-pallas parity test)."""
    from repro.core.context import Context
    from repro.core.ivp import IVP, integrate
    from repro.core.problems import batched_robertson

    nsys = 130
    f, jac, y0 = batched_robertson(nsys)
    prob = IVP(f=f, jac=jac, y0=y0)
    ctx_j = Context(policy=XLA_FUSED)
    ctx_a = Context(policy=AUTO)
    kw = dict(rtol=1e-5, atol=1e-10, max_steps=100_000)
    sol_j = integrate(prob, 0.0, 10.0, "ensemble_bdf", ctx=ctx_j,
                      opts=ctx_j.options(**kw))
    sol_a = integrate(prob, 0.0, 10.0, "ensemble_bdf", ctx=ctx_a,
                      opts=ctx_a.options(**kw))
    assert bool(jnp.all(sol_j.success)) and bool(jnp.all(sol_a.success))
    np.testing.assert_allclose(np.asarray(sol_a.y), np.asarray(sol_j.y),
                               rtol=100 * kw["rtol"], atol=100 * kw["atol"])
    rep = ctx_a.dispatch_report()
    assert rep["decisions"], "auto dispatch resolved no call sites"
