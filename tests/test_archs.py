"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness; plus a decode-step consistency
check (prefill-by-decode == one-shot loss path logits where comparable)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import Model, ParallelCtx

B, S = 2, 16


def _batch_for(cfg):
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.mrope:
        batch["vis_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(1), (B, 4, cfg.d_model)).astype(cfg.dtype)
    if cfg.enc_dec:
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, 8, cfg.d_model)).astype(cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = configs.get(f"{arch}-smoke")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(lambda p: m.loss(p, batch))(params)
    assert np.isfinite(float(loss)), arch
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0, arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    cfg = configs.get(f"{arch}-smoke")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    caches = m.init_cache(B, 24)
    db = {"tokens": jnp.full((B, 1), 3, jnp.int32),
          "pos": jnp.zeros((), jnp.int32)}
    if cfg.enc_dec:
        db["enc_out"] = 0.02 * jnp.ones((B, 8, cfg.d_model), cfg.dtype)
    logits, caches2 = m.decode_step(params, db, caches)
    assert logits.shape == (B, 1, cfg.vocab_size), arch
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    # caches must actually change
    changed = any(
        not np.array_equal(np.asarray(a, dtype=np.float32),
                           np.asarray(b, dtype=np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(caches),
                        jax.tree_util.tree_leaves(caches2))
        if hasattr(a, "shape") and a.size)
    assert changed, arch


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "starcoder2-7b",
                                  "qwen2-72b"])
def test_decode_matches_full_forward(arch):
    """Greedy decode over a prompt gives the same next-token logits as the
    train-path forward at the corresponding position (GQA caches)."""
    cfg = configs.get(f"{arch}-smoke").replace(dtype=jnp.float32)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 6), 0,
                              cfg.vocab_size)
    # full-forward logits at last position via loss-path machinery
    from repro.models import transformer as T
    x = params["embed"][toks]
    positions = jnp.arange(6)
    rope = T._rope_for(cfg, positions)
    pctx = ParallelCtx()
    h, _ = T._scan_layers(cfg, params["layers"], x, rope, positions, pctx)
    from repro.models import layers as L
    h = L.rmsnorm_apply(params["ln_f"], h, cfg.norm_eps)
    full_logits = T._lm_head(cfg, params, h, pctx)      # (1, 6, V)
    # decode token by token
    caches = m.init_cache(1, 8)
    outs = []
    for i in range(6):
        lg, caches = m.decode_step(
            params, {"tokens": toks[:, i:i + 1],
                     "pos": jnp.asarray(i, jnp.int32)}, caches)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_mla_latent_cache_is_low_storage():
    cfg = configs.get("deepseek-v3-671b")
    m = Model(cfg)
    cs = m.cache_specs(1, 1024)
    latent_bytes = sum(np.prod(s.shape) * 2 for s in
                       jax.tree_util.tree_leaves(cs)
                       if len(s.shape) > 1)
    # dense GQA cache would be 2 * L * S * H * hd * 2 bytes
    dense = 2 * cfg.n_layers * 1024 * cfg.n_heads * cfg.hd * 2
    assert latent_bytes < dense / 20, (latent_bytes, dense)


def test_long_context_skip_rule():
    assert configs.cell_is_runnable("xlstm-125m", "long_500k")
    assert configs.cell_is_runnable("zamba2-7b", "long_500k")
    for a in ("qwen2-72b", "deepseek-v3-671b", "whisper-tiny"):
        assert not configs.cell_is_runnable(a, "long_500k")
    for a in configs.ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert configs.cell_is_runnable(a, s)


def test_full_configs_match_assignment():
    """Spot-check the exact assigned dims."""
    c = configs.get("deepseek-v3-671b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == \
        (61, 7168, 128, 129280)
    assert (c.n_experts, c.experts_per_tok, c.moe_d_ff) == (256, 8, 2048)
    c = configs.get("qwen2-72b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (80, 8192, 64, 8, 29568, 152064)
    assert c.qkv_bias
    c = configs.get("zamba2-7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    c = configs.get("whisper-tiny")
    assert c.enc_dec and (c.n_layers, c.d_model, c.d_ff) == (4, 384, 1536)
    c = configs.get("dbrx-132b")
    assert (c.n_experts, c.experts_per_tok) == (16, 4)
    c = configs.get("starcoder2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (32, 4608, 36, 4)
    c = configs.get("qwen2-vl-2b")
    assert c.mrope and (c.n_layers, c.d_model) == (28, 1536)
    c = configs.get("deepseek-coder-33b")
    assert (c.n_layers, c.d_model, c.d_ff) == (62, 7168, 19200)
    c = configs.get("internlm2-1.8b")
    assert (c.n_layers, c.d_model, c.d_ff) == (24, 2048, 8192)
    c = configs.get("xlstm-125m")
    assert (c.n_layers, c.d_model, c.n_heads) == (12, 768, 4)
