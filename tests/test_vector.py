"""N_Vector ops: unit + property tests (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; property tests
from hypothesis import given, settings, strategies as st

from repro.core import vector as nv


def arrays(n):
    return st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=n,
                    max_size=n).map(lambda l: jnp.asarray(l, jnp.float64))


@settings(max_examples=30, deadline=None)
@given(arrays(8), arrays(8), st.floats(-10, 10), st.floats(-10, 10))
def test_linear_sum_matches_numpy(x, y, a, b):
    out = nv.linear_sum(a, x, b, y)
    np.testing.assert_allclose(out, a * np.asarray(x) + b * np.asarray(y),
                               rtol=1e-12, atol=1e-12)


@settings(max_examples=30, deadline=None)
@given(arrays(16), arrays(16))
def test_dot_symmetry_and_linearity(x, y):
    assert np.isclose(float(nv.dot(x, y)), float(nv.dot(y, x)))
    assert np.isclose(float(nv.dot(nv.scale(2.0, x), y)),
                      2.0 * float(nv.dot(x, y)), rtol=1e-10)


@settings(max_examples=30, deadline=None)
@given(arrays(12))
def test_norm_relations(x):
    n = x.shape[0]
    w = jnp.ones_like(x)
    wrms = float(nv.wrms_norm(x, w))
    wl2 = float(nv.wl2_norm(x, w))
    assert np.isclose(wrms, wl2 / np.sqrt(n), rtol=1e-12)
    assert float(nv.max_norm(x)) <= wl2 + 1e-12
    assert float(nv.l1_norm(x)) >= wl2 - 1e-9


def test_ops_over_pytrees():
    x = {"a": jnp.ones((3,)), "b": (jnp.full((2,), 2.0),)}
    y = {"a": jnp.full((3,), 3.0), "b": (jnp.ones((2,)),)}
    z = nv.linear_sum(2.0, x, 1.0, y)
    np.testing.assert_allclose(z["a"], 5.0)
    np.testing.assert_allclose(z["b"][0], 5.0)
    assert nv.tree_size(x) == 5
    assert np.isclose(float(nv.dot(x, y)), 3 * 3 + 2 * 2 * 1)


def test_linear_combination_fused_equals_pairwise():
    vecs = [jax.random.normal(jax.random.PRNGKey(i), (32,)) for i in range(4)]
    coeffs = [0.5, -1.5, 2.0, 0.25]
    fused = nv.linear_combination(coeffs, vecs)
    ref = sum(c * v for c, v in zip(coeffs, vecs))
    np.testing.assert_allclose(fused, ref, rtol=1e-12)


def test_constr_mask_and_min_quotient():
    c = jnp.asarray([2.0, 1.0, 0.0, -1.0, -2.0])
    x = jnp.asarray([1.0, 0.0, 5.0, 0.0, -3.0])
    ok, m = nv.constr_mask(c, x)
    assert bool(ok)  # all constraints satisfied
    x_bad = jnp.asarray([-1.0, -0.1, 5.0, 0.1, 3.0])
    ok, m = nv.constr_mask(c, x_bad)
    assert not bool(ok)
    assert np.asarray(m).sum() == 4
    num = jnp.asarray([1.0, 4.0, 9.0])
    den = jnp.asarray([2.0, 0.0, 3.0])
    assert np.isclose(float(nv.min_quotient(num, den)), 0.5)


def test_inv_test_detects_zero():
    ok, z = nv.inv_test(jnp.asarray([1.0, 2.0]))
    assert bool(ok)
    np.testing.assert_allclose(z, [1.0, 0.5])
    ok, _ = nv.inv_test(jnp.asarray([1.0, 0.0]))
    assert not bool(ok)


def test_mesh_vector_gspmd_mode_single_device():
    mv = nv.MeshVector({"a": jnp.arange(4.0)})
    got = mv.linear_sum(2.0, 1.0, mv).data["a"]
    np.testing.assert_allclose(got, 3 * np.arange(4.0))
    assert np.isclose(float(mv.dot(mv)), float(jnp.sum(jnp.arange(4.0) ** 2)))
    w = mv.const(1.0)
    assert np.isclose(float(mv.wrms_norm(w)),
                      float(jnp.sqrt(jnp.mean(jnp.arange(4.0) ** 2))))
