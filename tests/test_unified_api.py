"""Unified solver-stack API tests (the PR-3 acceptance gates).

* method-matrix parity: ``integrate(..., method=M)`` is
  trajectory-identical (1e-12) to each legacy entry point for every
  canonical method string;
* pluggability: swapping SPGMR <-> BlockDiagGJ on the ensemble-BDF path
  changes no trajectory beyond 1e-8 while Solution reports distinct
  solver stats and a nonzero memory high-water mark;
* compat shims (lin_mode=..., bdf_fixed bare kwargs) still work but
  DeprecationWarn — and the pyproject filterwarnings gate turns any
  unguarded use in the suite into an error;
* normalized SolveStats across all five Krylov solvers;
* NewtonSolver tolerances sourced from ODEOptions;
* Context counters and MemoryHelper workspace accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import arkode, batched, butcher, cvode, krylov
from repro.core.arkode import ODEOptions
from repro.core.context import Context
from repro.core.ivp import IVP, METHOD_STRINGS, Solution, integrate
from repro.core.linsol import SPGMR, BlockDiagGJ, DenseGJ
from repro.core.memory import MemoryHelper
from repro.core.nonlinsol import FixedPointSolver, NewtonSolver

LAM = 30.0


def _f1(t, y):
    return -LAM * (y - jnp.cos(t))


def _fe1(t, y):
    return LAM * jnp.cos(t) * jnp.ones_like(y)


def _fi1(t, y):
    return -LAM * y


def _batched_decay(nsys=5, n=3):
    rates = jnp.linspace(5.0, 40.0, nsys)

    def f(t, y):
        return -rates[:, None] * (y - jnp.cos(t)[:, None])

    def jac(t, y):
        return jnp.broadcast_to(-rates[:, None, None] * jnp.eye(n),
                                (y.shape[0], n, n))

    return f, jac, jnp.zeros((nsys, n))


_FB, _JB, _YB = _batched_decay()
_OPTS = ODEOptions(rtol=1e-6, atol=1e-9)


def _problem(method):
    if method.startswith("imex"):
        return IVP(fe=_fe1, fi=_fi1, y0=jnp.zeros((2,)))
    if method.startswith("ensemble"):
        return IVP(f=_FB, jac=_JB, y0=_YB)
    return IVP(f=_f1, y0=jnp.zeros((2,)))


def _legacy(method, prob, t0, tf, opts):
    """The pre-unification entry point for each canonical string."""
    fam, _, var = method.partition(":")
    if fam == "erk":
        return arkode.erk_integrate(prob.f, prob.y0, t0, tf,
                                    butcher.ERK_TABLES[
                                        "dormand_prince" if var == "dopri5"
                                        else var], opts)
    if fam == "dirk":
        return arkode.dirk_integrate(prob.f, prob.y0, t0, tf,
                                     butcher.DIRK_TABLES[var], opts)
    if fam == "imex":
        return arkode.imex_integrate(prob.fe, prob.fi, prob.y0, t0, tf,
                                     butcher.IMEX_TABLES[var], opts)
    if fam == "bdf":
        return cvode.bdf_integrate(prob.f, prob.y0, t0, tf, order=5,
                                   opts=opts)
    if fam == "adams":
        return cvode.adams_integrate(prob.f, prob.y0, t0, tf, opts)
    if fam == "ensemble_erk":
        return batched.ensemble_erk_integrate(
            prob.f, prob.y0, t0, tf, butcher.ERK_TABLES[var], opts)
    if fam == "ensemble_dirk":
        return batched.ensemble_dirk_integrate(
            prob.f, prob.jac, prob.y0, t0, tf, butcher.DIRK_TABLES[var],
            opts)
    if fam == "ensemble_bdf":
        return batched.ensemble_bdf_integrate(
            prob.f, prob.jac, prob.y0, t0, tf, order=5, opts=opts)
    raise AssertionError(method)


@pytest.mark.parametrize("method", METHOD_STRINGS)
def test_method_matrix_parity(method):
    """integrate(method=M) == legacy entry point, to 1e-12."""
    prob = _problem(method)
    sol = integrate(prob, 0.0, 1.0, method, opts=_OPTS)
    y_ref, st_ref = _legacy(method, prob, 0.0, 1.0, _OPTS)
    assert isinstance(sol, Solution)
    assert bool(sol.success)
    np.testing.assert_allclose(np.asarray(sol.y), np.asarray(y_ref),
                               rtol=0, atol=1e-12)
    # unified stats carry the same accepted-step count
    assert int(jnp.sum(sol.stats.steps)) == int(jnp.sum(st_ref.steps))


def test_sdirk33_is_third_order():
    """The new dirk:sdirk33 table (Alexander SDIRK-3-3) really is
    order 3 (fixed-step convergence on the stiff decay problem)."""
    import math
    ls = arkode.dense_lin_solver(_f1)
    a = LAM * LAM / (1 + LAM * LAM)
    b = LAM / (1 + LAM * LAM)
    exact = a * np.cos(1.0) + b * np.sin(1.0) - a * np.exp(-LAM)
    errs = []
    for n in (40, 80, 160):
        y = arkode.dirk_fixed(_f1, jnp.zeros((1,)), 0.0, 1.0, n,
                              butcher.SDIRK33, lin_solver=ls)
        errs.append(abs(float(y[0]) - exact))
    order = math.log2(errs[-2] / errs[-1])
    assert order > 2.5, (order, errs)


# ---------------------------------------------------------------------------
# pluggability: the PR acceptance criterion
# ---------------------------------------------------------------------------


def test_ensemble_bdf_solver_swap_krylov_vs_blockdiag():
    """SPGMR <-> BlockDiagGJ on ensemble_bdf: trajectories within 1e-8,
    distinct solver stats, nonzero memory high-water mark."""
    prob = IVP(f=_FB, jac=_JB, y0=_YB)
    opts = ODEOptions(rtol=1e-6, atol=1e-10)
    ctx = Context()
    # full-subspace GMRES (restart >= nsys*n = 15) -> near-exact solves
    sol_k = integrate(prob, 0.0, 2.0, "ensemble_bdf", ctx=ctx, opts=opts,
                      lin_solver=SPGMR(tol=1e-12, restart=30,
                                       max_restarts=6))
    sol_d = integrate(prob, 0.0, 2.0, "ensemble_bdf", ctx=ctx, opts=opts,
                      lin_solver=BlockDiagGJ(factor_once=False))
    assert bool(sol_k.success) and bool(sol_d.success)
    np.testing.assert_allclose(np.asarray(sol_k.y), np.asarray(sol_d.y),
                               rtol=0, atol=1e-8)
    # distinct solver stats: the Krylov path reports inner iterations,
    # the direct path reports none; names differ
    assert sol_k.lin_solver == "spgmr" and sol_d.lin_solver == "blockdiag_gj"
    assert int(sol_k.nli) > 0
    assert int(sol_d.nli) == 0
    assert int(jnp.sum(sol_k.nsetups)) > 0
    # real workspace accounting: history + Newton blocks registered
    assert sol_k.workspace_bytes > 0
    assert ctx.memory.high_water_bytes > 0
    assert sol_k.high_water_bytes >= sol_k.workspace_bytes


def test_ensemble_bdf_default_is_factor_once_blockdiag():
    """No lin_solver -> BlockDiagGJ(factor_once=True), bitwise equal to
    passing it explicitly."""
    prob = IVP(f=_FB, jac=_JB, y0=_YB)
    opts = ODEOptions(rtol=1e-6, atol=1e-10)
    sol_def = integrate(prob, 0.0, 1.0, "ensemble_bdf", opts=opts)
    sol_exp = integrate(prob, 0.0, 1.0, "ensemble_bdf", opts=opts,
                        lin_solver=BlockDiagGJ(factor_once=True))
    assert bool(jnp.all(sol_def.y == sol_exp.y))
    assert sol_def.lin_solver == "blockdiag_gj"


def test_scalar_bdf_lin_solver_objects():
    """DenseGJ and SPGMR objects plug into the scalar BDF and agree with
    the legacy dense_jac / default paths bitwise."""
    opts = ODEOptions(rtol=1e-7, atol=1e-10)
    prob = IVP(f=_f1, y0=jnp.zeros((2,)))
    sol_dense = integrate(prob, 0.0, 1.5, "bdf", opts=opts,
                          lin_solver=DenseGJ())
    y_ref, _ = cvode.bdf_integrate(_f1, jnp.zeros((2,)), 0.0, 1.5,
                                   opts=opts, dense_jac=True)
    assert bool(jnp.all(sol_dense.y == y_ref))
    assert sol_dense.lin_solver == "dense_gj"
    sol_gm = integrate(prob, 0.0, 1.5, "bdf", opts=opts,
                       lin_solver=SPGMR())
    y_ref2, _ = cvode.bdf_integrate(_f1, jnp.zeros((2,)), 0.0, 1.5,
                                    opts=opts)
    assert bool(jnp.all(sol_gm.y == y_ref2))


# ---------------------------------------------------------------------------
# backward-compat shims: still working, but deprecation-gated
# ---------------------------------------------------------------------------


def test_lin_mode_shim_warns_and_matches_object_api():
    prob_f, prob_jac, y0 = _FB, _JB, _YB
    opts = ODEOptions(rtol=1e-6, atol=1e-10)
    with pytest.warns(DeprecationWarning, match="repro-compat"):
        y_shim, _ = batched.ensemble_bdf_integrate(
            prob_f, prob_jac, y0, 0.0, 1.0, opts=opts, lin_mode="direct")
    y_obj, _ = batched.ensemble_bdf_integrate(
        prob_f, prob_jac, y0, 0.0, 1.0, opts=opts,
        linear_solver=BlockDiagGJ(factor_once=False))
    assert bool(jnp.all(y_shim == y_obj))


def test_bdf_fixed_bare_kwargs_shim():
    with pytest.warns(DeprecationWarning, match="repro-compat"):
        y_shim = cvode.bdf_fixed(_f1, jnp.zeros((1,)), 0.0, 1.0, 40,
                                 order=2, newton_iters=8)
    y_opts = cvode.bdf_fixed(_f1, jnp.zeros((1,)), 0.0, 1.0, 40, order=2,
                             opts=ODEOptions(newton_max=8))
    assert bool(jnp.all(y_shim == y_opts))


# ---------------------------------------------------------------------------
# normalized SolveStats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,solver", [
    ("gmres", krylov.gmres), ("fgmres", krylov.fgmres),
    ("pcg", krylov.pcg), ("bicgstab", krylov.bicgstab),
    ("tfqmr", krylov.tfqmr)])
def test_solvestats_true_residual_convention(name, solver):
    """res_norm is the TRUE ||b - A x|| at exit for every solver, and
    converged is res_norm <= max(tol*||b||, atol) — identical semantics
    across the family (callers need no per-solver special cases)."""
    n = 20
    key = jax.random.PRNGKey(0)
    Q = jax.random.normal(key, (n, n)) * 0.1
    A = Q @ Q.T + 5.0 * jnp.eye(n)          # SPD: every solver applies
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    tol = 1e-10
    x, st = solver(lambda v: A @ v, b, tol=tol, **(
        {} if name in ("gmres", "fgmres") else {"maxiter": 400}))
    true_res = float(jnp.linalg.norm(b - A @ x))
    np.testing.assert_allclose(float(st.res_norm), true_res,
                               rtol=1e-6, atol=1e-13)
    target = tol * float(jnp.linalg.norm(b))
    assert bool(st.converged) == (float(st.res_norm) <= target)
    assert bool(st.converged)
    assert int(st.iters) > 0


def test_krylov_mem_registration():
    n = 64
    A = 3.0 * jnp.eye(n)
    b = jnp.ones((n,))
    mem = MemoryHelper()
    krylov.gmres(lambda v: A @ v, b, tol=1e-10, restart=10, mem=mem)
    assert "spgmr.basis" in mem.workspaces
    assert mem.high_water_bytes >= 11 * n * 8
    # idempotent per label: a second identical call must not double-count
    hw = mem.high_water_bytes
    krylov.gmres(lambda v: A @ v, b, tol=1e-10, restart=10, mem=mem)
    assert mem.high_water_bytes == hw


# ---------------------------------------------------------------------------
# nonlinear-solver objects and context plumbing
# ---------------------------------------------------------------------------


def test_newton_solver_takes_tolerances_from_options():
    opts = ODEOptions(newton_tol_fac=0.03, newton_max=7)
    nls = NewtonSolver.from_options(opts)
    assert nls.tol == 0.03 and nls.max_iters == 7
    fps = FixedPointSolver.from_options(ODEOptions(atol=1e-6,
                                                   newton_tol_fac=0.1), m=4)
    assert fps.m == 4 and fps.tol == pytest.approx(0.1 * 1e-6 + 1e-12)
    # a custom Newton config actually changes integrator behavior
    opts_run = ODEOptions(rtol=1e-6, atol=1e-9)
    prob = IVP(f=_f1, y0=jnp.zeros((2,)))
    sol_tight = integrate(prob, 0.0, 1.0, "dirk:sdirk2", opts=opts_run,
                          nonlin_solver=NewtonSolver(tol=1e-10,
                                                     max_iters=12))
    sol_def = integrate(prob, 0.0, 1.0, "dirk:sdirk2", opts=opts_run)
    assert int(sol_tight.nni) > int(sol_def.nni)


def test_context_counters_and_options():
    ctx = Context()
    opts = ctx.options(rtol=1e-5, atol=1e-8)
    assert opts.policy is ctx.policy
    prob = IVP(f=_f1, y0=jnp.zeros((2,)))
    integrate(prob, 0.0, 0.5, "erk:dopri5", ctx=ctx, opts=opts)
    integrate(prob, 0.0, 0.5, "bdf", ctx=ctx, opts=opts)
    assert ctx.counters["integrations"] == 2
    assert ctx.counters["steps"] > 0
    assert ctx.counters["newton_iters"] > 0


def test_memory_helper_register_release():
    mem = MemoryHelper()
    nb = mem.register("a", (10, 10), jnp.float64)
    assert nb == 800 and mem.live_bytes == 800
    mem.register("b", (5,), jnp.float32)
    assert mem.live_bytes == 820 and mem.high_water_bytes == 820
    mem.release("a")
    assert mem.live_bytes == 20
    assert mem.high_water_bytes == 820      # the mark persists
    mem.release()
    assert mem.live_bytes == 0


def test_solution_reports_workspace_for_scalar_bdf():
    """Krylov basis + BDF history register with the context memory
    helper (they were dead code before this layer)."""
    ctx = Context()
    prob = IVP(f=_f1, y0=jnp.zeros((4,)))
    sol = integrate(prob, 0.0, 1.0, "bdf", ctx=ctx,
                    opts=ODEOptions(rtol=1e-6, atol=1e-9))
    # bdf history (QMAX+1=6 rows) + spgmr basis/hessenberg
    assert sol.workspace_bytes >= 6 * 4 * 8
    assert "bdf.history" not in ctx.memory.workspaces  # released per-call
    assert ctx.memory.high_water_bytes == sol.high_water_bytes


def test_split_problem_through_non_imex_methods_uses_full_rhs():
    """An IMEX-split IVP run through bdf/dirk/erk must integrate fe+fi
    (the full RHS), not silently drop the explicit part."""
    prob = IVP(fe=_fe1, fi=_fi1, y0=jnp.zeros((2,)))
    opts = ODEOptions(rtol=1e-7, atol=1e-10)
    full = lambda t, y: _fe1(t, y) + _fi1(t, y)      # == _f1
    for method in ("bdf", "dirk:sdirk2", "erk:dopri5"):
        sol = integrate(prob, 0.0, 1.0, method, opts=opts)
        y_ref, _ = _legacy(method, IVP(f=full, y0=jnp.zeros((2,))),
                           0.0, 1.0, opts)
        np.testing.assert_allclose(np.asarray(sol.y), np.asarray(y_ref),
                                   rtol=0, atol=1e-12, err_msg=method)


def test_integrate_releases_only_its_own_workspaces():
    ctx = Context()
    ctx.memory.register("user.buffer", (100,), jnp.float64)
    integrate(IVP(f=_f1, y0=jnp.zeros((2,))), 0.0, 0.5, "bdf", ctx=ctx,
              opts=ODEOptions(rtol=1e-5, atol=1e-8))
    # the user's registration survives; integrate's own labels are gone
    assert ctx.memory.workspaces == {"user.buffer": 800}
    assert ctx.memory.live_bytes == 800


def test_ivp_validation():
    with pytest.raises(ValueError):
        IVP(y0=jnp.zeros((2,)))                      # no RHS
    with pytest.raises(ValueError):
        IVP(f=_f1, fe=_fe1, fi=_fi1, y0=jnp.zeros((2,)))  # both forms
    with pytest.raises(ValueError):
        IVP(f=_f1, y0=None)                          # no y0
    with pytest.raises(ValueError):
        integrate(IVP(f=_f1, y0=jnp.zeros((2,))), 0.0, 1.0, "rk4")
    with pytest.raises(ValueError):
        # ensemble_bdf needs an analytic jac
        integrate(IVP(f=_FB, y0=_YB), 0.0, 1.0, "ensemble_bdf")
    with pytest.raises(ValueError):
        # a solver the family cannot consume is an error, not a silent
        # no-op with a lying Solution.lin_solver
        integrate(IVP(f=_FB, jac=_JB, y0=_YB), 0.0, 1.0,
                  "ensemble_dirk:sdirk2", lin_solver=SPGMR())
    with pytest.raises(ValueError):
        integrate(IVP(f=_f1, y0=jnp.zeros((2,))), 0.0, 1.0, "erk:dopri5",
                  nonlin_solver=NewtonSolver())
