"""sunlint — the static verifier itself.

Covers, per ISSUE 7:

1. every rule flags its seeded bad-kernel fixture (negative controls
   from tests/fixtures/bad_kernels.py, via BOTH the API and the CLI);
2. every rule passes clean over the real repo (one shared default
   LintContext so the integrator traces happen once);
3. the suppression machinery: source-comment `# sunlint: disable=`,
   baseline exact and prefix entries;
4. the jaxpr walkers: opaque kernel boundaries, innermost-while
   selection, copying-reshape vs free-reshape discrimination;
5. the CLI contract: `--check` exits 0 on the repo, `--list` names
   every rule, unknown rules/fixtures exit 1.
"""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis import lint

FIXTURES = lint.load_fixtures()
RULE_NAMES = sorted(lint.load_rules())


@pytest.fixture(scope="module")
def clean_ctx():
    """One shared default context: traces are cached per TraceTarget,
    so the expensive integrator traces happen once for the module."""
    return lint.LintContext()


# ---------------------------------------------------------------------------
# 1. every rule flags its fixture
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_fixture_flagged_by_expected_rule(name):
    expected_rule, setup = FIXTURES[name]
    ctx = lint.LintContext()
    setup(ctx)
    violations = lint.run_rules(ctx, [expected_rule])
    assert violations, (name, expected_rule)
    assert all(v.rule == expected_rule for v in violations)


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_fixture_cli_exits_nonzero(name):
    assert lint.main(["--fixture", name, "--no-baseline"]) == 1


def test_every_rule_has_a_fixture():
    covered = {rule for rule, _ in FIXTURES.values()}
    assert covered == set(RULE_NAMES)


def test_hidden_transpose_not_flagged_when_commented():
    """The retired source grep tripped on commented-out `.T` text; the
    jaxpr rule must flag only *traced* transposes."""
    def thunk():
        def body(c):
            z, it = c
            # z = z.T  (inert comment — the old grep's false positive)
            return z * 2.0, it + 1

        def run(z):
            return lax.while_loop(lambda c: c[1] < jnp.int32(3),
                                  body, (z, jnp.int32(0)))[0]
        return jax.make_jaxpr(run)(jnp.ones((4, 4))).jaxpr

    ctx = lint.LintContext()
    ctx.hot_loop_targets = [lint.TraceTarget("commented", thunk)]
    assert lint.run_rules(ctx, ["hot-loop-layout"]) == []


# ---------------------------------------------------------------------------
# 2. clean pass over the real repo, per rule
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", RULE_NAMES)
def test_rule_clean_on_repo(clean_ctx, rule):
    assert lint.run_rules(clean_ctx, [rule]) == []


def test_check_cli_clean_on_repo():
    assert lint.main(["--check"]) == 0


# ---------------------------------------------------------------------------
# 3. suppression
# ---------------------------------------------------------------------------


def test_baseline_exact_and_prefix_matching():
    v = lint.Violation("dtype-drift", "ensemble_bdf:newton_body[0]",
                       "msg")
    assert lint.is_suppressed(v, ["dtype-drift|ensemble_bdf:"
                                  "newton_body[0]"])
    assert lint.is_suppressed(v, ["dtype-drift|ensemble_bdf*"])
    assert not lint.is_suppressed(v, ["dtype-drift|ensemble_dirk*"])
    assert not lint.is_suppressed(v, ["hot-loop-layout|ensemble_bdf*"])


def test_source_comment_suppression(tmp_path):
    src = tmp_path / "mod.py"
    src.write_text("x = 1\n"
                   "y = x.T  # sunlint: disable=hot-loop-layout\n"
                   "z = y.T  # sunlint: disable=dtype-drift\n")
    flagged = lint.Violation("hot-loop-layout", "t", "m",
                             src=(str(src), 2))
    other = lint.Violation("hot-loop-layout", "t", "m",
                           src=(str(src), 3))
    lint._SRC_CACHE.clear()
    assert lint.is_suppressed(flagged, [])
    assert not lint.is_suppressed(other, [])


def test_baseline_file_parsing(tmp_path):
    p = tmp_path / ".sunlint-baseline"
    p.write_text("# comment only\n\n"
                 "dtype-drift|ensemble_bdf*  # trailing comment\n")
    assert lint.load_baseline(p) == ["dtype-drift|ensemble_bdf*"]
    assert lint.load_baseline(tmp_path / "missing") == []


# ---------------------------------------------------------------------------
# 4. the jaxpr walkers
# ---------------------------------------------------------------------------


def test_innermost_while_selection():
    """Nested whiles: only the inner body qualifies as innermost."""
    def inner_step(z):
        return lax.while_loop(lambda c: c[1] < jnp.int32(2),
                              lambda c: (c[0] * 2.0, c[1] + 1),
                              (z, jnp.int32(0)))[0]

    def run(z):
        return lax.while_loop(
            lambda c: c[1] < jnp.int32(3),
            lambda c: (inner_step(c[0]), c[1] + 1),
            (z, jnp.int32(0)))[0]

    jpr = jax.make_jaxpr(run)(jnp.ones(4)).jaxpr
    bodies = lint.innermost_while_bodies(jpr)
    assert len(bodies) == 1
    prims = {e.primitive.name for e in lint.iter_eqns(bodies[0])}
    assert "while" not in prims and "mul" in prims


def test_opaque_pjit_boundary_not_walked():
    """A transpose hidden behind a named-opaque pjit is invisible; the
    same trace walked without the opaque set exposes it."""
    @jax.jit
    def secret_kernel(z):
        return z.T @ z

    def run(z):
        return lax.while_loop(
            lambda c: c[1] < jnp.int32(2),
            lambda c: (secret_kernel(c[0]), c[1] + 1),
            (z, jnp.int32(0)))[0]

    jpr = jax.make_jaxpr(run)(jnp.ones((3, 3))).jaxpr
    opaque = frozenset({"secret_kernel"})

    def transposes(opaque_names):
        return [e for b in lint.innermost_while_bodies(jpr,
                                                       opaque_names)
                for e in lint.iter_eqns(b, opaque_names)
                if e.primitive.name == "transpose"]

    assert transposes(opaque) == []
    assert transposes(frozenset())  # visible without the boundary


def test_plain_reshape_is_not_a_copy():
    """ravel/reshape without a dimensions permutation is free and must
    not be flagged as a layout conversion."""
    def thunk():
        def body(c):
            z, it = c
            flat = z.reshape(-1)                # free
            return flat.reshape(z.shape), it + 1

        def run(z):
            return lax.while_loop(lambda c: c[1] < jnp.int32(2),
                                  body, (z, jnp.int32(0)))[0]
        return jax.make_jaxpr(run)(jnp.ones((4, 2))).jaxpr

    ctx = lint.LintContext()
    ctx.hot_loop_targets = [lint.TraceTarget("free_reshape", thunk)]
    assert lint.run_rules(ctx, ["hot-loop-layout"]) == []


def test_kernel_wrapper_names_cover_dispatch_kernels():
    names = lint.kernel_wrapper_names()
    assert "block_solve_soa" in names
    assert "wrms_norm" in names
    assert len(names) >= 19


# ---------------------------------------------------------------------------
# 5. CLI contract
# ---------------------------------------------------------------------------


def test_cli_list_names_every_rule(capsys):
    assert lint.main(["--list"]) == 0
    out = capsys.readouterr().out
    for rule in RULE_NAMES:
        assert rule in out


def test_cli_unknown_rule_and_fixture_exit_1():
    assert lint.main(["--rule", "no-such-rule"]) == 1
    assert lint.main(["--fixture", "no-such-fixture"]) == 1
