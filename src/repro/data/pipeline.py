"""Data pipeline: deterministic synthetic token stream + memmap corpus.

Per-host input sharding (the multi-pod pattern): each process generates
or reads ONLY its slice of the global batch — ``host_slice`` maps
(process_index, process_count) -> rows.  Determinism is keyed on
(seed, step), so restart-after-failure replays the exact same batch the
lost step would have seen (required for exactly-once semantics with
checkpoint/restart).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus_path: Optional[str] = None   # .bin int32 memmap, else synthetic


def host_slice(global_batch: int, process_index: int,
               process_count: int) -> Tuple[int, int]:
    assert global_batch % process_count == 0
    per = global_batch // process_count
    return process_index * per, per


def synthetic_batch(cfg: DataConfig, step: int, process_index: int = 0,
                    process_count: int = 1) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic tokens (deterministic in (seed, step, host))."""
    start, per = host_slice(cfg.global_batch, process_index, process_count)
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, start]))
    toks = rng.integers(0, cfg.vocab_size, size=(per, cfg.seq_len + 1),
                        dtype=np.int32)
    # make it slightly learnable: every 4th token repeats the previous
    toks[:, 1::4] = toks[:, 0:-1:4]
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def memmap_batch(cfg: DataConfig, step: int, process_index: int = 0,
                 process_count: int = 1) -> Dict[str, np.ndarray]:
    data = np.memmap(cfg.corpus_path, dtype=np.int32, mode="r")
    start, per = host_slice(cfg.global_batch, process_index, process_count)
    n_tokens = data.shape[0]
    window = cfg.seq_len + 1
    out = np.empty((per, window), np.int32)
    for i in range(per):
        # strided deterministic sampling across the corpus
        off = ((step * cfg.global_batch + start + i) * 2654435761) % \
            max(n_tokens - window, 1)
        out[i] = data[off:off + window]
    return {"tokens": out[:, :-1], "targets": out[:, 1:]}


def batches(cfg: DataConfig, start_step: int = 0, process_index: int = 0,
            process_count: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    fn = memmap_batch if cfg.corpus_path else synthetic_batch
    while True:
        yield fn(cfg, step, process_index, process_count)
        step += 1
