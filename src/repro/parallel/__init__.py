from . import sharding
from .sharding import (ACT_RULES, PARAM_RULES, cache_axes_like, make_cst,
                       param_shardings, spec_for)

__all__ = ["sharding", "ACT_RULES", "PARAM_RULES", "cache_axes_like",
           "make_cst", "param_shardings", "spec_for"]
