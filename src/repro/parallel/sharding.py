"""Logical-axis sharding rules -> NamedSharding (the distribution core).

Params and activations carry *logical* axis names (models/spec.py); two
rule tables map them onto mesh axes:

* PARAM_RULES — FSDP over ('pod','data') on a non-TP dim + tensor/expert
  parallelism over 'model'.  Every large matrix is sharded on two dims.
* ACT_RULES   — batch over ('pod','data'), heads/mlp/vocab over 'model'.

``spec_for`` degrades gracefully: a dim that is not divisible by its mesh
axes, or whose mesh axis is already used by an earlier dim, falls back to
replication — this is what lets tiny smoke configs, odd head counts
(e.g. 36 heads on a 16-way model axis -> replicated) and batch=1 decode
shapes lower on any mesh without per-arch special-casing.
"""
from __future__ import annotations

import inspect
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax>=0.6 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

# the replication-check kwarg was renamed check_rep -> check_vma in
# jax 0.6; resolve it once so every shard_map call site stays portable
_SHMAP_CHECK_KW = ("check_vma" if "check_vma" in
                   inspect.signature(_shard_map).parameters else "check_rep")


def shard_map_compat(body, mesh, in_specs, out_specs, check: bool = False):
    """shard_map across jax versions (check_rep/check_vma rename)."""
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_SHMAP_CHECK_KW: check})

LogicalAxes = Tuple[Optional[str], ...]

PARAM_RULES: Dict[str, Tuple[str, ...]] = {
    "embed": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    # experts are OWNED per chip when E divides model*data (weights-
    # stationary EP — §Perf 'dsv3-ep256'); spec_for shrinks to ('model',)
    # when it does not divide (e.g. dbrx's 16 experts).
    "experts": ("model", "data"),
    "expert_mlp": None,
    "q_lora": ("pod", "data"),
    "kv_lora": ("pod", "data"),
    "head_dim": None,
    "heads_x": ("model",),
    "embed_out": None,
    "layers": None,
}

ACT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "embed": None,
    "head_dim": None,
    "experts": ("model",),
    "layers": None,
}

# Cache-only rules (decode path): head_dim takes 'model' when
# heads/kv_heads could not (axis uniqueness) — this shards the GQA KV
# cache for archs whose kv-head count doesn't divide the model axis
# (kv=8 on a 16-way axis -> 86.6 GB/chip replicated without it; the
# extra psum of contracting a sharded head_dim is negligible at Sq=1).
# NOT applied to train/prefill activations: there the induced score
# psums are (B,S,H,S)-scale and catastrophic (deepseek-coder train went
# 15s -> 457s collective when this was tried globally — §Perf).
def cache_rules_from(act_rules: Dict) -> Dict:
    out = dict(act_rules)
    out["head_dim"] = ("model",)
    return out

# --- pure-FSDP profile (no tensor parallelism): every parameter matrix is
# sharded on its d_model ('embed') dim across ALL chips; activations shard
# batch over (pod,data) and sequence over 'model'.  Removes the per-layer
# activation all-reduces of Megatron-style TP at the cost of per-layer
# weight all-gathers — the winning trade for dense decoder training at
# these shapes (§Perf 'qwen72b-fsdp').
FSDP_PARAM_RULES: Dict[str, Tuple[str, ...]] = {
    "embed": ("pod", "data", "model"),
    "vocab": None,
    "heads": None,
    "kv_heads": None,
    "mlp": None,
    "experts": ("model", "data"),
    "expert_mlp": None,
    "q_lora": ("pod", "data", "model"),
    "kv_lora": ("pod", "data", "model"),
    "head_dim": None,
    "heads_x": None,
    "embed_out": None,
    "layers": None,
}

FSDP_ACT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": ("model",),
    "heads": None,
    "kv_heads": None,
    "mlp": None,
    "vocab": None,
    "embed": None,
    "head_dim": None,
    "experts": None,
    "layers": None,
}

PROFILES = {
    "tp_fsdp": (PARAM_RULES, ACT_RULES),
    "fsdp": (FSDP_PARAM_RULES, FSDP_ACT_RULES),
}


def _mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(shape: Sequence[int], axes: LogicalAxes, mesh: Mesh,
             rules: Dict[str, Tuple[str, ...]]) -> P:
    """Build a PartitionSpec honoring divisibility + axis-uniqueness."""
    sizes = _mesh_axis_sizes(mesh)
    used = set()
    out = []
    for dim, name in zip(shape, axes):
        entry = rules.get(name) if name is not None else None
        if not entry:
            out.append(None)
            continue
        # drop mesh axes already used or absent from this mesh
        cand = tuple(a for a in entry if a in sizes and a not in used)
        if not cand:
            out.append(None)
            continue
        prod = math.prod(sizes[a] for a in cand)
        if dim % prod != 0:
            # try shrinking from the right (e.g. ('pod','data') -> ('pod',))
            while cand and dim % math.prod(sizes[a] for a in cand) != 0:
                cand = cand[:-1]
            if not cand:
                out.append(None)
                continue
        used.update(cand)
        out.append(cand if len(cand) > 1 else cand[0])
    # trim trailing Nones for tidiness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shardings_for_tree(shapes_tree, axes_tree, mesh: Mesh,
                       rules: Dict = None):
    """shapes_tree: tree of ShapeDtypeStruct (or arrays); axes_tree: tree
    of logical-axes tuples with identical structure."""
    rules = rules or PARAM_RULES
    return jax.tree_util.tree_map(
        lambda s, ax: NamedSharding(mesh, spec_for(s.shape, ax, mesh, rules)),
        shapes_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, (tuple,)) and all(
            isinstance(e, (str, type(None))) for e in x))


def param_shardings(abstract_tree, axes, mesh: Mesh, rules: Dict = None):
    rules = rules or PARAM_RULES
    flat_a, treedef = jax.tree_util.tree_flatten(abstract_tree)
    flat_x = treedef.flatten_up_to(axes)
    out = [NamedSharding(mesh, spec_for(a.shape, x, mesh, rules))
           for a, x in zip(flat_a, flat_x)]
    return jax.tree_util.tree_unflatten(treedef, out)


def make_cst(mesh: Optional[Mesh], rules: Dict = None):
    """Activation sharding-constraint applier: cst(x, logical_axes)."""
    rules = rules or ACT_RULES
    if mesh is None:
        return lambda x, axes: x

    def cst(x, axes):
        if len(axes) != x.ndim:
            return x
        spec = spec_for(x.shape, tuple(axes), mesh, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return cst


# --- cache logical axes (for serve-path in_shardings) -----------------------


def cache_axes_like(cache_specs, cfg) -> Any:
    """Return a logical-axes tree matching the cache spec tree."""

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(leaf.shape)
        if name in ("k", "v"):
            return ("layers", "batch", "seq", "kv_heads", "head_dim")[:nd]
        if name in ("c_kv", "k_rope"):
            return ("layers", "batch", "seq", None)[:nd]
        if name == "pos":
            return ("layers",) * nd   # () unstacked, (L,) when stacked
        if name == "conv":
            return ("layers", "batch", None, "mlp")[:nd]
        if name == "ssm":
            return ("layers", "batch", "heads", "head_dim", None)[:nd]
        if name in ("C",):
            return ("layers", "batch", "heads", None, None)[:nd]
        if name in ("n", "m", "c", "h"):
            # xlstm scalar states: (pairs, B, ...) — shard batch
            return (("layers", "batch") + (None,) * (nd - 2))[:nd]
        return (None,) * nd

    return jax.tree_util.tree_map_with_path(one, cache_specs)
