"""Loop-aware HLO cost model (FLOPs / HBM-bytes / collective traffic).

XLA's built-in ``HloCostAnalysis`` (what ``compiled.cost_analysis()``
exposes) visits every instruction ONCE — a ``lax.scan`` over 61 layers
contributes a single layer of FLOPs.  For scan-over-layers models that
under-counts compute by ~L, so the roofline would be garbage.  This
module re-derives the three quantities by walking the post-SPMD HLO text
*structurally*:

  * while loops multiply their body's cost by the trip count (parsed
    from the loop-condition computation's bound constant — exact for
    lax.scan/fori);
  * conditionals take the max-FLOPs branch;
  * fusions contribute their fused dots' FLOPs, but only their top-level
    operands/outputs as HBM traffic (fusion internals live in registers
    /VMEM — the TPU performance model);
  * FLOPs: 2 * prod(output dims) * prod(contracting dims) per dot;
  * HBM bytes: sum of output bytes of materializing top-level ops x2
    (write + subsequent read), a standard traffic proxy;
  * collective traffic: ring-model per-device ICI bytes (see
    ``roofline._line_traffic``).

All quantities are PER DEVICE: the SPMD module is the per-device program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_OP_RE = re.compile(r"\s([a-z][a-z0-9\-\$_]*)\(")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_COND_TF_RE = re.compile(r"true_computation=%?([\w\.\-]+),\s*"
                         r"false_computation=%?([\w\.\-]+)")
_COND_BR_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_RHS_C_RE = re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,\s]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that produce no real HBM traffic of their own
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "bitcast",
             "tuple", "after-all", "iota", "reshape", "partition-id",
             "replica-id"}

# elementwise ops: on TPU these fuse into their consumers (XLA:TPU fusion
# is far more aggressive than the XLA:CPU module we inspect), so charging
# them full HBM traffic would wildly overstate the memory term.  They are
# charged ZERO here; the traffic of a fused chain is carried by its
# endpoints (dot operands, fusion outputs, copies, cache updates).
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "and",
    "or", "xor", "not", "negate", "abs", "exponential", "exponential-minus-one",
    "log", "log-plus-one", "tanh", "sqrt", "rsqrt", "cbrt", "power",
    "compare", "select", "clamp", "convert", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sign", "cosine", "sine",
    "logistic", "atan2", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite", "broadcast", "reduce-precision",
    "real", "imag", "complex", "map", "pad", "reverse", "rng",
    "rng-bit-generator", "stochastic-convert",
}


def _strip_layout(s: str) -> str:
    return re.sub(r"\{[0-9,\s]*\}", "", s)


def _shapes_in(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(s):
        dd = tuple(int(x) for x in dims.split(",") if x)
        out.append((dt, dd))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_count: float = 0.0
    unresolved_dots: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k in COLLECTIVES:
            self.coll[k] += mult * other.coll[k]
        self.coll_count += mult * other.coll_count
        self.unresolved_dots += other.unresolved_dots

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


class HloCost:
    def __init__(self, hlo_text: str, n_devices: int = 1):
        self.n_devices = n_devices
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    # ---- parsing ----
    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            if line.rstrip().endswith("{") and "->" in line:
                m = _COMP_HDR_RE.match(line)
                if m:
                    cur = m.group(2)
                    self.comps[cur] = []
                    if m.group(1):
                        self.entry = cur
                    continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                else:
                    self.comps[cur].append(line)

    def _symtab(self, name: str) -> Dict[str, List]:
        tab = {}
        for line in self.comps.get(name, ()):
            m = _INSTR_RE.match(_strip_layout(line))
            if not m:
                continue
            lhs, rhs = m.group(1), m.group(2)
            # output type = everything before the op call
            om = _OP_RE.search(" " + rhs)
            cut = rhs.index("(", om.start() - 1) if om else len(rhs)
            tab[lhs] = _shapes_in(rhs[:cut] if om else rhs)
        return tab

    def _trip_count(self, cond_name: str) -> int:
        consts = [int(c) for line in self.comps.get(cond_name, ())
                  for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    def _dot_flops(self, line: str, tab) -> Tuple[float, int]:
        clean = _strip_layout(line)
        out_shapes = _shapes_in(clean[:clean.index(" dot(")])
        out_elems = 1
        for _, dims in out_shapes:
            for d in dims:
                out_elems *= d
        lc = _LHS_C_RE.search(line)
        rc = _RHS_C_RE.search(line)
        cdims = [int(x) for x in (lc.group(1) if lc else "").split(",") if x]
        # operand names: each operand is "<type> %name" in scheduled HLO
        # (bare "%name" in unoptimized dumps) — take the trailing token.
        # Shape types carry their own commas (f32[8,16]), so the operand
        # list must be split at bracket depth 0, not on every comma.
        call = clean[clean.index(" dot(") + 5:]
        call = call[:call.index(")")]
        ops, depth, start = [], 0, 0
        for i, ch in enumerate(call):
            if ch in "[{":
                depth += 1
            elif ch in "]}":
                depth -= 1
            elif ch == "," and depth == 0:
                ops.append(call[start:i])
                start = i + 1
        ops.append(call[start:])
        names = [o.strip().split()[-1].lstrip("%") for o in ops
                 if o.strip()]
        k = None
        if names and names[0] in tab and tab[names[0]]:
            dims = tab[names[0]][0][1]
            try:
                k = 1
                for c in cdims:
                    k *= dims[c]
            except Exception:
                k = None
        if k is None and len(names) > 1 and names[1] in tab and tab[names[1]]:
            rdims = [int(x) for x in (rc.group(1) if rc else "").split(",")
                     if x]
            dims = tab[names[1]][0][1]
            try:
                k = 1
                for c in rdims:
                    k *= dims[c]
            except Exception:
                k = None
        if k is None:
            return 0.0, 1
        return 2.0 * out_elems * k, 0

    def _group_size(self, line: str) -> int:
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            return max(int(m.group(2)), 1)
        m = _GROUPS_LIST_RE.search(line)
        if m:
            return max(len([x for x in m.group(1).split(",")
                            if x.strip() != ""]), 1)
        return self.n_devices

    def _coll_traffic(self, line: str, base: str) -> float:
        clean = _strip_layout(line)
        cut = clean.index(f" {base}(") if f" {base}(" in clean else \
            clean.index("(")
        size = _nbytes(_shapes_in(clean[:cut]))
        g = self._group_size(line)
        if base == "all-gather":
            return size * (g - 1) / g
        if base == "all-reduce":
            return 2.0 * size * (g - 1) / g
        if base == "reduce-scatter":
            return float(size * (g - 1))
        if base == "all-to-all":
            return size * (g - 1) / g
        return float(size)

    def _fusion_root_dus_update_bytes(self, called: str) -> Optional[float]:
        """If the fused computation's root is a dynamic-update-slice (a
        scan accumulator), return the UPDATE operand's bytes: the fusion
        writes only the slice in place, not the whole buffer.  Charging
        the full buffer per loop iteration overstates scan-carried
        accumulator traffic by the trip count (found via zamba2 §Perf)."""
        lines = self.comps.get(called)
        if not lines:
            return None
        root = None
        for line in lines:
            if " dynamic-update-slice(" in line and "ROOT" in line:
                root = line
                break
        if root is None:
            return None
        tab = self._symtab(called)
        names = re.findall(r"%([\w\.\-]+)",
                           _strip_layout(root.split("dynamic-update-slice(",
                                                    1)[1]))
        if len(names) >= 2 and names[1] in tab:
            return float(_nbytes(tab[names[1]]))
        return None

    def _operand_bytes(self, line: str, tab, limit: int = 8) -> float:
        """Sum bytes of named operands resolvable in the symbol table."""
        clean = _strip_layout(line)
        oidx = clean.find("(")
        if oidx < 0:
            return 0.0
        names = re.findall(r"%([\w\.\-]+)", clean[oidx:oidx + 4000])[:limit]
        total = 0.0
        for nm in names:
            if nm in tab:
                total += _nbytes(tab[nm])
        return total

    def _out_bytes(self, rhs: str) -> float:
        cut = _strip_layout(rhs)
        oidx = cut.find("(")
        hdr = cut[:oidx] if oidx > 0 else cut
        return float(_nbytes(_shapes_in(hdr)))

    # ---- cost walk ----
    def cost_of(self, name: str, as_fusion: bool = False,
                depth: int = 0) -> Cost:
        key = (name, as_fusion)
        if key in self._memo:
            return self._memo[key]
        c = Cost()
        if depth > 16 or name not in self.comps:
            return c
        tab = self._symtab(name)
        for raw in self.comps[name]:
            line = raw.strip()
            m = _INSTR_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            om = _OP_RE.search(" " + _strip_layout(rhs))
            op = om.group(1) if om else ""
            if op == "dot":
                fl, bad = self._dot_flops(line, tab)
                c.flops += fl
                c.unresolved_dots += bad
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                c.coll[base] += self._coll_traffic(line, base)
                c.coll_count += 1
            if op == "while":
                wm = _WHILE_RE.search(line)
                if wm:
                    trip = self._trip_count(wm.group(1))
                    c.add(self.cost_of(wm.group(2), False, depth + 1),
                          mult=trip)
                continue
            if op == "conditional":
                branches = []
                tf = _COND_TF_RE.search(line)
                if tf:
                    branches = [tf.group(1), tf.group(2)]
                else:
                    br = _COND_BR_RE.search(line)
                    if br:
                        branches = [b.strip().lstrip("%")
                                    for b in br.group(1).split(",")]
                if branches:
                    costs = [self.cost_of(b, False, depth + 1)
                             for b in branches]
                    best = max(costs, key=lambda x: x.flops)
                    c.add(best)
                continue
            if op == "fusion":
                cm = _CALLS_RE.search(line)
                if cm:
                    sub = self.cost_of(cm.group(1), True, depth + 1)
                    c.flops += sub.flops     # fused dots still compute
                    c.unresolved_dots += sub.unresolved_dots
            if op in ("call",):
                cm = _TOAPPLY_RE.search(line)
                if cm:
                    c.add(self.cost_of(cm.group(1), False, depth + 1))
                continue
            # ---- HBM traffic (TPU-fusion-aware proxy) ----
            if as_fusion or not op or op in _FREE_OPS or op in _ELEMENTWISE:
                continue
            if op == "dot":
                c.bytes += self._out_bytes(rhs) + \
                    self._operand_bytes(line, tab, limit=2)
            elif op == "fusion":
                # fused kernel: charge the output only — every consumed
                # tensor is charged once where it was produced.  (Charging
                # operands too double-counts chains: XLA:CPU emits many
                # more top-level fusions than XLA:TPU would.)  Fusions
                # rooted in dynamic-update-slice (scan accumulators / KV
                # cache writes) are in-place: charge the update region.
                cm = _CALLS_RE.search(line)
                dus = (self._fusion_root_dus_update_bytes(cm.group(1))
                       if cm else None)
                if dus is not None:
                    c.bytes += 2.0 * dus
                else:
                    c.bytes += 2.0 * self._out_bytes(rhs)
            elif op == "dynamic-update-slice":
                # in-place on TPU: traffic = the update region, not the
                # whole buffer (crucial for KV-cache decode steps)
                names = re.findall(r"%([\w\.\-]+)",
                                   _strip_layout(rhs))
                upd = 0.0
                if len(names) >= 2 and names[1] in tab:
                    upd = _nbytes(tab[names[1]])
                c.bytes += 2.0 * (upd or self._out_bytes(rhs) * 0.01)
            elif op in ("reduce", "reduce-window", "sort", "scatter",
                        "gather", "select-and-scatter", "dynamic-slice",
                        "slice", "concatenate", "transpose", "copy",
                        "custom-call", "cholesky", "triangular-solve"):
                c.bytes += self._out_bytes(rhs) + \
                    self._operand_bytes(line, tab, limit=4)
            elif base in COLLECTIVES:
                c.bytes += 2.0 * self._out_bytes(rhs)
            else:
                c.bytes += 2.0 * self._out_bytes(rhs)
        self._memo[key] = c
        return c

    def total(self) -> Cost:
        entry = self.entry
        if entry is None:
            entry = max(self.comps, key=lambda n: len(self.comps[n])) \
                if self.comps else ""
        return self.cost_of(entry)


def analyze(hlo_text: str, n_devices: int = 1) -> Dict[str, float]:
    c = HloCost(hlo_text, n_devices).total()
    out = {"flops": c.flops, "bytes": c.bytes,
           "coll_total": c.coll_total, "coll_count": c.coll_count,
           "unresolved_dots": c.unresolved_dots}
    out.update({f"coll_{k}": v for k, v in c.coll.items()})
    return out


def top_collectives(hlo_text: str, n_devices: int = 1, k: int = 12):
    """Largest collectives by (per-execution traffic x loop trip count) —
    the §Perf debugging view: WHAT is the collective term made of."""
    hc = HloCost(hlo_text, n_devices)
    entry = hc.entry or (max(hc.comps, key=lambda n: len(hc.comps[n]))
                         if hc.comps else "")
    rows = []

    def walk(name, mult, depth=0):
        if depth > 12 or name not in hc.comps:
            return
        for raw in hc.comps[name]:
            line = raw.strip()
            m = _INSTR_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            om = _OP_RE.search(" " + _strip_layout(rhs))
            op = om.group(1) if om else ""
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                traffic = hc._coll_traffic(line, base)
                meta = ""
                mm = re.search(r'op_name="([^"]+)"', line)
                if mm:
                    meta = mm.group(1)[-90:]
                rows.append((traffic * mult, base, mult, meta))
            if op == "while":
                wm = _WHILE_RE.search(line)
                if wm:
                    walk(wm.group(2), mult * hc._trip_count(wm.group(1)),
                         depth + 1)
            elif op == "conditional":
                tf = _COND_TF_RE.search(line)
                brs = ([tf.group(1), tf.group(2)] if tf else [])
                for b in brs:
                    walk(b, mult, depth + 1)

    walk(entry, 1.0)
    rows.sort(reverse=True)
    return rows[:k]


def top_bytes(hlo_text: str, n_devices: int = 1, k: int = 14):
    """Largest HBM-traffic ops by (bytes x trip count) — §Perf debugging."""
    hc = HloCost(hlo_text, n_devices)
    entry = hc.entry or (max(hc.comps, key=lambda n: len(hc.comps[n]))
                         if hc.comps else "")
    rows = []

    def walk(name, mult, depth=0):
        if depth > 12 or name not in hc.comps:
            return
        tab = hc._symtab(name)
        for raw in hc.comps[name]:
            line = raw.strip()
            m = _INSTR_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            om = _OP_RE.search(" " + _strip_layout(rhs))
            op = om.group(1) if om else ""
            if op == "while":
                wm = _WHILE_RE.search(line)
                if wm:
                    walk(wm.group(2), mult * hc._trip_count(wm.group(1)),
                         depth + 1)
                continue
            if op == "conditional":
                tf = _COND_TF_RE.search(line)
                for b in ([tf.group(1), tf.group(2)] if tf else []):
                    walk(b, mult, depth + 1)
                continue
            if not op or op in _FREE_OPS or op in _ELEMENTWISE:
                continue
            if op == "dot":
                b = hc._out_bytes(rhs) + hc._operand_bytes(line, tab, 2)
            elif op == "fusion":
                b = 2.0 * hc._out_bytes(rhs)
            elif op == "dynamic-update-slice":
                names = re.findall(r"%([\w\.\-]+)", _strip_layout(rhs))
                upd = _nbytes(tab[names[1]]) if len(names) > 1 and \
                    names[1] in tab else 0
                b = 2.0 * (upd or hc._out_bytes(rhs) * 0.01)
            else:
                b = 2.0 * hc._out_bytes(rhs)
            if b * mult > 1e9:
                meta = ""
                mm = re.search(r'op_name="([^"]+)"', line)
                if mm:
                    meta = mm.group(1)[-80:]
                shape = _strip_layout(rhs)
                shape = shape[:shape.find("(")][:48]
                rows.append((b * mult, op, mult, shape.strip(), meta))

    walk(entry, 1.0)
    rows.sort(reverse=True)
    return rows[:k]
