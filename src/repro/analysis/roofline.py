"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms (TPU v5e constants; per-chip quantities from the SPMD module):
  compute    = HLO_FLOPs_per_chip / peak_FLOPs        [s]
  memory     = HLO_bytes_per_chip / HBM_bw            [s]
  collective = collective_operand_bytes_per_chip / link_bw   [s]

``cost_analysis()`` reports the per-device program (post-SPMD), so no
division by chip count is needed.  collective bytes are parsed from the
compiled HLO text: the sum of operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class Device:
    """One roofline device entry (per chip).

    The physical ceilings (``peak_flops``/``hbm_bw``/``ici_bw``) drive
    :meth:`Roofline.finalize`; the remaining fields parameterize the
    per-op dispatch cost model in :mod:`repro.analysis.opcost`:

    vmem_bytes        : working-set budget for row-tiled Pallas
                        accumulators (the ``GJ_VMEM_BYTES`` knob, now a
                        device property); ``None`` = uncapped (interpret
                        mode has no VMEM — it pays per-step interpreter
                        overhead instead).
    jnp_bw/pallas_bw  : effective streamed bandwidth each backend
                        sustains on this device (<= hbm_bw; on the
                        ``interpret`` pseudo-device these are host-RAM
                        figures calibrated against ``--tune`` data).
    jnp_launch        : per-dispatch overhead on the jnp side [s] —
                        one fused XLA kernel launch on compiled
                        devices; on the ``interpret`` pseudo-device it
                        is the eager per-primitive dispatch cost the
                        oracle pays ``jnp_kernels`` times (opcost
                        counts the oracle's primitive dispatches).
    pallas_call       : fixed pallas_call entry overhead [s].
    pallas_step       : per-grid-step cost [s] — compiled program
                        prologue, or the interpreter's per-step Python
                        loop on the pseudo-device.
    interp_op         : interpret mode only: per kernel-body primitive
                        per grid step [s] (numpy dispatch overhead);
                        0.0 on compiled devices.
    interpret         : True for the CPU-emulation pseudo-device.
    """

    name: str
    peak_flops: float
    hbm_bw: float
    ici_bw: float
    vmem_bytes: Optional[int] = 2 * 1024 * 1024
    jnp_bw: float = 0.0          # 0 -> defaults to hbm_bw
    pallas_bw: float = 0.0       # 0 -> defaults to hbm_bw
    jnp_launch: float = 2e-6
    pallas_call: float = 2e-6
    pallas_step: float = 1e-7
    interp_op: float = 0.0
    interpret: bool = False

    def bw(self, backend: str) -> float:
        eff = self.jnp_bw if backend == "jnp" else self.pallas_bw
        return eff or self.hbm_bw


DEVICES: Dict[str, Device] = {
    # TPU v5e per chip (bf16 peak) — the paper-model target.
    "tpu_v5e": Device(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                      ici_bw=50e9),
    # TPU v4 per chip: larger part, same model structure.
    "tpu_v4": Device(name="tpu_v4", peak_flops=275e12, hbm_bw=1228e9,
                     ici_bw=100e9),
    # The interpret/CPU pseudo-device: Pallas kernels run under the
    # interpreter (numpy per grid step), jnp runs through XLA:CPU.  The
    # effective-rate and overhead constants are calibrated against the
    # committed .autotune/interpret.json measurements on this host
    # class (weighted relative-error fit over the 62-entry grid); they
    # exist to rank backends, not to predict wall time.  jnp_launch is
    # the eager per-primitive dispatch cost — the oracle's fixed
    # overhead scales with opcost's jnp_kernels dispatch counts.
    "interpret": Device(name="interpret", peak_flops=5e9, hbm_bw=10e9,
                        ici_bw=10e9, vmem_bytes=None,
                        jnp_bw=7e9, pallas_bw=9e9,
                        jnp_launch=70e-6, pallas_call=20e-6,
                        pallas_step=10e-6, interp_op=2e-6,
                        interpret=True),
}


def get_device(name: str) -> Device:
    try:
        return DEVICES[name]
    except KeyError:
        raise ValueError(f"unknown roofline device {name!r}; "
                         f"known: {sorted(DEVICES)}") from None


# Back-compat module constants (TPU v5e per chip) — Roofline.finalize
# and older callers read these; they alias the device-table entry.
PEAK_FLOPS = DEVICES["tpu_v5e"].peak_flops    # bf16
HBM_BW = DEVICES["tpu_v5e"].hbm_bw            # bytes/s
ICI_BW = DEVICES["tpu_v5e"].ici_bw            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|"
                       r"f64|c64|c128)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,\s]*)\}")
_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_COMP_START_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)\s*->\s*.+\{\s*$")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _out_bytes(line: str) -> int:
    """Sum of output shape bytes (lhs of '=', layouts stripped)."""
    s = re.sub(r"\{[0-9,\s]*\}", "", line)  # strip layout annotations
    eq = s.find("=")
    par = s.find("(", eq)
    region = s[eq + 1: par if par > eq else None]
    return sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(region))


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))          # [num_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _line_traffic(line: str, base: str, n_devices: int) -> int:
    """Estimated per-device ICI bytes moved by one execution of this op.

    Ring-algorithm models: all-gather out*(g-1)/g; all-reduce
    2*size*(g-1)/g; reduce-scatter in ~ out*(g-1); all-to-all
    size*(g-1)/g; collective-permute size.
    """
    size = _out_bytes(line)
    g = max(_group_size(line, n_devices), 1)
    if base == "all-gather":
        return int(size * (g - 1) / g)
    if base == "all-reduce":
        return int(2 * size * (g - 1) / g)
    if base == "reduce-scatter":
        return int(size * (g - 1))
    if base == "all-to-all":
        return int(size * (g - 1) / g)
    return size                           # collective-permute


def _parse_computations(hlo_text: str):
    """name -> list of body lines (flat, no nesting in HLO text)."""
    comps = {}
    cur, buf = None, []
    for line in hlo_text.splitlines():
        m = _COMP_START_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            buf = []
            comps[cur] = buf
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                buf.append(line)
    return comps


def collective_bytes(hlo_text: str, n_devices: int = 1) -> Dict[str, int]:
    """Per-device collective traffic, loop-aware.

    Collectives inside while bodies (scan over layers / microbatches)
    execute trip-count times but appear once in the text; we walk the
    call graph and multiply by the loop bound parsed from the condition
    computation (max integer constant — correct for lax.scan loops).
    """
    comps = _parse_computations(hlo_text)

    def comp_direct(name):
        """(per-kind bytes dict, count, list of (trip, body) sub-loops)."""
        per = {k: 0 for k in _COLLECTIVES}
        cnt = 0
        loops = []
        for line in comps.get(name, ()):
            s = line.strip()
            wm = _WHILE_RE.search(s)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = 1
                consts = [int(c) for cl in comps.get(cond, ())
                          for c in _CONST_RE.findall(cl)]
                if consts:
                    trip = max(consts)
                loops.append((trip, body))
                continue
            for base in _COLLECTIVES:
                if f" {base}(" in s or f" {base}-start(" in s:
                    per[base] += _line_traffic(s, base, n_devices)
                    cnt += 1
                    break
        return per, cnt, loops

    memo = {}

    def total(name, depth=0):
        if name in memo:
            return memo[name]
        if depth > 12:
            return ({k: 0 for k in _COLLECTIVES}, 0)
        per, cnt, loops = comp_direct(name)
        for trip, body in loops:
            sub, subcnt = total(body, depth + 1)
            for k in _COLLECTIVES:
                per[k] += trip * sub[k]
            cnt += trip * subcnt
        memo[name] = (per, cnt)
        return memo[name]

    # entry = the computation containing other computations' calls; HLO
    # marks it ENTRY but our parser drops the marker — find the one that
    # is not referenced as a fusion/branch target, or just sum over the
    # computation named like 'main'.
    entry = None
    for name in comps:
        if name.startswith("main"):
            entry = name
            break
    if entry is None:  # fallback: computation with most lines
        entry = max(comps, key=lambda n: len(comps[n])) if comps else ""
    per, cnt = total(entry)
    out = dict(per)
    out["count"] = cnt
    out["total"] = sum(per[k] for k in _COLLECTIVES)
    out["entry"] = entry
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # per chip
    hlo_bytes: float             # per chip
    coll_bytes: float            # per chip
    model_flops: float           # analytic 6ND (dense) / 6 N_active D
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0    # MODEL_FLOPS / (chips * HLO_FLOPs)
    mfu_bound: float = 0.0       # model_flops/chips/peak / max(terms)
    coll_detail: Optional[Dict] = None
    memory_per_chip: Optional[Dict] = None

    def finalize(self, device: str = "tpu_v5e"):
        dev = get_device(device)
        self.t_compute = self.hlo_flops / dev.peak_flops
        self.t_memory = self.hlo_bytes / dev.hbm_bw
        self.t_collective = self.coll_bytes / dev.ici_bw
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo = self.hlo_flops * self.chips
        self.useful_ratio = (self.model_flops / total_hlo
                             if total_hlo else 0.0)
        t_dom = max(terms.values())
        ideal = self.model_flops / self.chips / dev.peak_flops
        self.mfu_bound = ideal / t_dom if t_dom > 0 else 0.0
        return self

    def to_dict(self):
        return dataclasses.asdict(self)


def active_param_count(cfg) -> int:
    """Parameters touched per token: experts scaled by top-k/E."""
    from repro.models import Model
    from repro.models.spec import ParamSpec
    import jax

    specs = Model(cfg).specs()
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, ParamSpec)):
        n = 1
        for d in leaf.shape:
            n *= d
        if "experts" in leaf.axes:
            n = int(n * cfg.experts_per_tok / max(cfg.n_experts, 1))
        total += n
    return total


def model_flops_for(cfg, shape_cfg) -> float:
    """6*N_active*D for train; 2*N_active*tokens for decode/prefill fwd."""
    n_active = active_param_count(cfg)
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n_active * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape_cfg.global_batch


def summarize(rows):
    """Markdown table for EXPERIMENTS.md."""
    hdr = ("| arch | shape | mesh | t_compute | t_memory | t_collective | "
           "bottleneck | useful | MFU-bound |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.t_compute:.3e} | "
            f"{r.t_memory:.3e} | {r.t_collective:.3e} | {r.bottleneck} | "
            f"{r.useful_ratio:.2f} | {r.mfu_bound:.2%} |")
    return "\n".join(lines)
