"""bounded-loops: every hot-loop while terminates on a step ceiling.

The fault-containment contract (PR 10) keeps failure *in data*: a
diverging lane is quarantined by its retcode while the shared
``while_loop`` keeps running for the healthy lanes.  That only
terminates if every loop condition, besides its value-dependent
predicates (residual norms, ``t < tf``, ``retcode == 0``), also
compares an integer *counter* against a ceiling (``att <= max_steps``,
Newton's ``iter < maxcor``) — a purely float-conditioned loop spins
forever the moment a lane's values go NaN (NaN comparisons are false,
but a ``~converged`` style predicate negates them back to true).

This rule checks the trace: every ``while`` equation reachable in a
hot-loop target jaxpr (at any non-opaque depth) must carry at least one
``lt``/``le``/``gt``/``ge`` comparison over integer operands in its
``cond_jaxpr``.  Equality tests do not count — ``retcode == 0`` or
``phase != DONE`` can stay true forever; only an ordered comparison on
a monotone integer counter bounds the trip count.
"""
import jax.numpy as jnp

from repro.analysis import lint

_ORDERED_CMPS = ("lt", "le", "gt", "ge")


def _has_integer_guard(cond_jaxpr, opaque_names) -> bool:
    for eqn in lint.iter_eqns(cond_jaxpr, opaque_names):
        if eqn.primitive.name not in _ORDERED_CMPS:
            continue
        if all(jnp.issubdtype(v.aval.dtype, jnp.integer)
               for v in eqn.invars):
            return True
    return False


@lint.register(
    "bounded-loops",
    "every hot-loop while condition includes an integer step ceiling "
    "(ordered comparison on integer operands)")
def check(ctx):
    out = []
    for tgt in ctx.hot_loop_targets:
        for eqn in lint.iter_eqns(tgt.jaxpr(), ctx.opaque_names):
            if eqn.primitive.name != "while":
                continue
            cond = eqn.params["cond_jaxpr"].jaxpr
            if not _has_integer_guard(cond, ctx.opaque_names):
                out.append(lint.Violation(
                    "bounded-loops", tgt.name,
                    "while_loop condition has no integer step ceiling "
                    "(no lt/le/gt/ge over integer operands) — a NaN "
                    "lane can spin it forever",
                    src=lint.eqn_src(eqn)))
    return out
