"""hot-loop-layout: no layout conversions inside the Newton loops.

The PR 5 guarantee — the ensemble-BDF/DIRK Newton iteration performs
ZERO layout conversions — used to be a source grep, which a
commented-out ``.T`` satisfies and a helper-function transpose evades.
This rule checks the *trace*: it walks the innermost ``while_loop``
bodies (the Newton loops) of each hot-loop target jaxpr, descending
into ``scan``/``while``/``cond`` sub-jaxprs but not into opaque kernel
boundaries, and flags every ``transpose`` equation and every copying
``reshape`` (one with ``dimensions`` set — a plain reshape is a free
metadata change; a dimensions-permuting reshape materializes a copy).
"""
from repro.analysis import lint


@lint.register(
    "hot-loop-layout",
    "no transpose / copying reshape inside ensemble Newton while bodies")
def check(ctx):
    out = []
    for tgt in ctx.hot_loop_targets:
        bodies = lint.innermost_while_bodies(tgt.jaxpr(),
                                             ctx.opaque_names)
        for bi, body in enumerate(bodies):
            where = f"{tgt.name}:newton_body[{bi}]"
            for eqn in lint.iter_eqns(body, ctx.opaque_names):
                prim = eqn.primitive.name
                if prim == "transpose":
                    out.append(lint.Violation(
                        "hot-loop-layout", where,
                        f"transpose(permutation="
                        f"{eqn.params.get('permutation')}) inside a "
                        f"Newton while_loop body",
                        src=lint.eqn_src(eqn)))
                elif (prim == "reshape"
                      and eqn.params.get("dimensions") is not None):
                    out.append(lint.Violation(
                        "hot-loop-layout", where,
                        f"copying reshape (dimensions="
                        f"{eqn.params['dimensions']}) inside a Newton "
                        f"while_loop body",
                        src=lint.eqn_src(eqn)))
    return out
