"""table-coherence: one op set, named identically everywhere.

The dispatch OP_TABLE is the source of truth.  The opcost signature
extractors and cost models must cover exactly the same ops (a missing
entry means ``backend='auto'`` crashes at the first call site; an
extra entry is dead modeling), OP_NOTES must document every op, every
committed autotune-cache entry must key a known op, and the generated
README / policies-docstring op matrices must be the verbatim render of
the current table.
"""
import json

from repro.analysis import lint


def _diff(where, label, ops, keys, out, extra_only=False):
    for m in sorted(ops - keys):
        if not extra_only:
            out.append(lint.Violation(
                "table-coherence", where,
                f"{label} is missing op {m!r}"))
    for e in sorted(keys - ops):
        out.append(lint.Violation(
            "table-coherence", where,
            f"{label} names an op {e!r} that is not in the op table"))


@lint.register(
    "table-coherence",
    "OP_TABLE, opcost registries, autotune cache keys, and the "
    "generated op matrices name the same op set")
def check(ctx):
    from repro.analysis import opcost
    from repro.core import dispatch, policies

    ops = set(ctx.op_table)
    out = []
    _diff("opcost", "opcost.SIG_EXTRACTORS", ops,
          set(opcost.SIG_EXTRACTORS), out)
    _diff("opcost", "opcost.COST_MODELS", ops,
          set(opcost.COST_MODELS), out)
    _diff("dispatch", "dispatch.OP_NOTES", ops,
          set(dispatch.OP_NOTES), out)

    # committed autotune caches: a cache is allowed to be partial
    # (entries are measured on demand) but must never key an orphan op.
    cache_dir = ctx.repo_root / ".autotune"
    if cache_dir.is_dir():
        for path in sorted(cache_dir.glob("*.json")):
            try:
                payload = json.loads(path.read_text())
            except (OSError, ValueError) as e:
                out.append(lint.Violation(
                    "table-coherence", f"autotune:{path.name}",
                    f"unreadable cache file: {e}"))
                continue
            cache_ops = set()
            for entry in payload.get("entries", {}).values():
                cache_ops.add(entry.get("sig", {}).get("op"))
            cache_ops.discard(None)
            _diff(f"autotune:{path.name}", f"cache {path.name}", ops,
                  cache_ops, out, extra_only=True)

    # generated doc matrices must be the verbatim render of the table
    # (python -m repro.core.dispatch regenerates both)
    rst = dispatch.render_op_table("rst")
    if rst not in (policies.__doc__ or ""):
        out.append(lint.Violation(
            "table-coherence", "policies-docstring",
            "policies module docstring does not embed the current "
            "rst op matrix (regenerate with python -m "
            "repro.core.dispatch)"))
    md = dispatch.render_op_table("md")
    readme = ctx.repo_root / "README.md"
    if not readme.is_file() or md not in readme.read_text():
        out.append(lint.Violation(
            "table-coherence", "README",
            "README.md does not embed the current markdown op matrix "
            "(regenerate with python -m repro.core.dispatch)"))
    return out
