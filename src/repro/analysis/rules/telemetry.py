"""telemetry-purity: observability OFF adds nothing to the hot loop.

The overhead contract of :mod:`repro.observability` is structural, not
statistical: with the default (disabled) ``ObservabilityConfig``, the
``integrate()`` front-end must hand the integrators a trace that is
*equation-for-equation identical* to the raw integrator call — the step
telemetry threading lives entirely in an enabled-only loop-body wrapper,
so when it is off the adaptive step loop DCEs back to the original
program.  This rule checks that statically, two ways:

1. For every ``(name, baseline, candidate)`` pair in
   ``ctx.telemetry_targets`` it locates the *largest* ``while`` body in
   each trace (the adaptive step loop — it encloses the Newton
   iteration) and compares equation counts and the full recursive
   primitive sequence.  Any drift — one extra ``add``, a reordered
   ``select_n`` — is a violation naming the primitive-count delta.
2. Every trace in ``ctx.telemetry_enabled_targets`` (telemetry ON) is
   scanned for host-callback primitives: recording must go through the
   in-graph ring buffer, never ``io_callback``/``debug_callback`` —
   a callback in the hot loop is a device-host sync per step, exactly
   the overhead the paper's profiler/logger design avoids.
"""
from collections import Counter

from repro.analysis import lint

#: primitives that punch through to the host mid-graph — forbidden in
#: telemetry-enabled integrator traces
CALLBACK_PRIMS = frozenset({"io_callback", "pure_callback",
                            "debug_callback", "callback"})


def _eqn_count(jaxpr, opaque_names) -> int:
    return sum(1 for _ in lint.iter_eqns(jaxpr, opaque_names))


def _largest_while_body(jaxpr, opaque_names):
    """The body jaxpr of the while equation with the most (recursive)
    equations — for the integrators this is the adaptive step loop."""
    best, best_n = None, -1
    for eqn in lint.iter_eqns(jaxpr, opaque_names):
        if eqn.primitive.name != "while":
            continue
        body = eqn.params["body_jaxpr"].jaxpr
        n = _eqn_count(body, opaque_names)
        if n > best_n:
            best, best_n = body, n
    return best


def _prim_seq(jaxpr, opaque_names):
    return [e.primitive.name
            for e in lint.iter_eqns(jaxpr, opaque_names)]


def _delta_msg(base_seq, cand_seq) -> str:
    delta = Counter(cand_seq) - Counter(base_seq)
    missing = Counter(base_seq) - Counter(cand_seq)
    parts = []
    if delta:
        parts.append("extra " + ", ".join(
            f"{p} x{n}" for p, n in sorted(delta.items())))
    if missing:
        parts.append("missing " + ", ".join(
            f"{p} x{n}" for p, n in sorted(missing.items())))
    if not parts:
        parts.append("same multiset, different order")
    return "; ".join(parts)


@lint.register(
    "telemetry-purity",
    "disabled observability leaves the integrator step-loop jaxpr "
    "identical to the raw call; enabled telemetry uses no host "
    "callbacks")
def check(ctx):
    out = []
    for name, base, cand in ctx.telemetry_targets:
        bb = _largest_while_body(base.jaxpr(), ctx.opaque_names)
        cb = _largest_while_body(cand.jaxpr(), ctx.opaque_names)
        if bb is None or cb is None:
            out.append(lint.Violation(
                "telemetry-purity", name,
                f"no while loop found in "
                f"{'baseline' if bb is None else 'candidate'} trace "
                f"({base.name if bb is None else cand.name})"))
            continue
        base_seq = _prim_seq(bb, ctx.opaque_names)
        cand_seq = _prim_seq(cb, ctx.opaque_names)
        if len(base_seq) != len(cand_seq):
            out.append(lint.Violation(
                "telemetry-purity", name,
                f"step-loop op count drifted with observability "
                f"disabled: {len(base_seq)} eqns (raw) vs "
                f"{len(cand_seq)} (integrate); "
                f"{_delta_msg(base_seq, cand_seq)}"))
        elif base_seq != cand_seq:
            i = next(j for j, (a, b)
                     in enumerate(zip(base_seq, cand_seq)) if a != b)
            out.append(lint.Violation(
                "telemetry-purity", name,
                f"step-loop primitive sequence drifted at eqn {i}: "
                f"{base_seq[i]} (raw) vs {cand_seq[i]} (integrate); "
                f"{_delta_msg(base_seq, cand_seq)}"))
    for tgt in ctx.telemetry_enabled_targets:
        for eqn in lint.iter_eqns(tgt.jaxpr(), ctx.opaque_names):
            if eqn.primitive.name in CALLBACK_PRIMS:
                out.append(lint.Violation(
                    "telemetry-purity", tgt.name,
                    f"host callback {eqn.primitive.name!r} in a "
                    f"telemetry-enabled trace — step telemetry must "
                    f"record through the in-graph ring buffer",
                    src=lint.eqn_src(eqn)))
    return out
