"""trace-purity: every public entry point traces abstractly.

The sunmatrix/spsolve subsystems split work into a concrete *symbolic*
phase (host-side pattern analysis) and a traced *numeric* phase; the
integrators must likewise trace end to end under ``jax.eval_shape``.
A Python ``if`` on a tracer, an ``int()``/``bool()`` of an abstract
value, or an unhashable static pattern all raise during abstract
evaluation — this rule runs every purity target (each canonical
``IVP.integrate`` method string plus a symbolic-LU solve) and converts
those failures into violations.  Harness bugs (anything that is not a
concretization/hashability error) propagate, so a broken target cannot
masquerade as a clean pass.
"""
import jax

from repro.analysis import lint


@lint.register(
    "trace-purity",
    "integrate() method strings and sunmatrix/spsolve numeric phases "
    "trace abstractly (no concrete-value leaks)")
def check(ctx):
    out = []
    for tgt in ctx.purity_targets:
        try:
            tgt.jaxpr()
        except jax.errors.ConcretizationTypeError as e:
            out.append(lint.Violation(
                "trace-purity", tgt.name,
                f"concrete-value leak while tracing: "
                f"{type(e).__name__}: {str(e).splitlines()[0]}"))
        except TypeError as e:
            msg = str(e)
            if "hash" in msg or "Tracer" in msg:
                out.append(lint.Violation(
                    "trace-purity", tgt.name,
                    f"non-hashable static / tracer misuse while "
                    f"tracing: {msg.splitlines()[0]}"))
            else:
                raise
    return out
