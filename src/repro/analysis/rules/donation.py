"""donation-aliasing: donated buffers are exclusive and dead after use.

The ensemble-BDF step loop runs with its carry **donated**
(:func:`repro.core.batched._donated_loop`) so XLA updates the history
window in place.  That is only sound when (a) no donated argument
aliases another argument of the same call — two tree leaves bound to
one buffer would make XLA write through a live alias — and (b) nothing
reads a donated buffer after the call, since donation invalidates it.
Both properties are visible in the trace: this rule scans every
``pjit`` equation with ``donated_invars`` set, flags repeated
variables among its donated inputs, and flags any later equation (or
an enclosing output) that mentions a donated variable again.
"""
from repro.analysis import lint


def _scan(where, jaxpr, opaque_names, out):
    from jax.extend import core as jex_core
    for idx, eqn in enumerate(jaxpr.eqns):
        if eqn.primitive.name == "pjit":
            don = eqn.params.get("donated_invars", ())
            if any(don):
                invars = [v if isinstance(v, jex_core.Var) else None
                          for v in eqn.invars]
                donated = set()
                for v, d in zip(invars, don):
                    if d and v is not None:
                        donated.add(v)
                # (a) aliased leaves among the call's arguments
                for v in sorted(donated, key=str):
                    if invars.count(v) > 1:
                        out.append(lint.Violation(
                            "donation-aliasing", where,
                            f"donated call argument {v} is passed "
                            f"{invars.count(v)} times (aliased leaves "
                            f"in a donated carry)",
                            src=lint.eqn_src(eqn)))
                # (b) donated buffer read after the call
                for later in jaxpr.eqns[idx + 1:]:
                    used = [v for v in later.invars
                            if isinstance(v, jex_core.Var)
                            and v in donated]
                    for v in used:
                        out.append(lint.Violation(
                            "donation-aliasing", where,
                            f"donated buffer {v} is read after the "
                            f"donating call (by "
                            f"{later.primitive.name})",
                            src=lint.eqn_src(later)))
                escaped = [v for v in jaxpr.outvars
                           if isinstance(v, jex_core.Var)
                           and v in donated]
                for v in escaped:
                    out.append(lint.Violation(
                        "donation-aliasing", where,
                        f"donated buffer {v} escapes as an output of "
                        f"the enclosing jaxpr",
                        src=lint.eqn_src(eqn)))
        if not lint.is_opaque(eqn, opaque_names):
            for sub in lint.subjaxprs(eqn):
                _scan(where, sub, opaque_names, out)


@lint.register(
    "donation-aliasing",
    "donated carries hold no aliased leaves; no read-after-donation")
def check(ctx):
    out = []
    for tgt in ctx.donation_targets:
        _scan(tgt.name, tgt.jaxpr(), ctx.opaque_names, out)
    return out
