"""sunlint rules — importing this package registers every rule with
:data:`repro.analysis.lint.RULES` (each module calls
``lint.register`` at import time)."""
from . import bounded       # noqa: F401
from . import coherence     # noqa: F401
from . import contract      # noqa: F401
from . import donation      # noqa: F401
from . import dtype         # noqa: F401
from . import layout        # noqa: F401
from . import purity        # noqa: F401
from . import telemetry     # noqa: F401
