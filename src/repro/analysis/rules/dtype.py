"""dtype-drift: no float-width changes inside the Newton hot loops.

SUNDIALS realtype semantics: the working precision is chosen once and
nothing silently promotes (a weak f64 step-size coefficient must not
upcast an f32 state) or demotes (an f32 literal must not truncate the
f64 iterate).  This rule walks the innermost Newton ``while_loop``
bodies and flags every ``convert_element_type`` between floating
dtypes of different widths.  ``ctx.dtype_allowlist`` — a set of
``(src_dtype, dst_dtype)`` string pairs — is the seam for the planned
mixed-precision mode: its deliberate casts get allowlisted here
instead of sprinkling suppressions.
"""
import jax.numpy as jnp

from repro.analysis import lint

_FLOATS = {"float16", "bfloat16", "float32", "float64"}


@lint.register(
    "dtype-drift",
    "no f64<->f32 promotion/truncation inside Newton while bodies "
    "(allowlist = the mixed-precision seam)")
def check(ctx):
    out = []
    for tgt in ctx.hot_loop_targets:
        bodies = lint.innermost_while_bodies(tgt.jaxpr(),
                                             ctx.opaque_names)
        for bi, body in enumerate(bodies):
            where = f"{tgt.name}:newton_body[{bi}]"
            for eqn in lint.iter_eqns(body, ctx.opaque_names):
                if eqn.primitive.name != "convert_element_type":
                    continue
                src_dt = str(eqn.invars[0].aval.dtype)
                dst_dt = str(jnp.dtype(eqn.params["new_dtype"]))
                if (src_dt in _FLOATS and dst_dt in _FLOATS
                        and src_dt != dst_dt
                        and (src_dt, dst_dt)
                        not in ctx.dtype_allowlist):
                    kind = ("promotion" if jnp.dtype(dst_dt).itemsize
                            > jnp.dtype(src_dt).itemsize
                            else "truncation")
                    out.append(lint.Violation(
                        "dtype-drift", where,
                        f"float {kind} {src_dt} -> {dst_dt} inside a "
                        f"Newton while_loop body (allowlist the pair "
                        f"if deliberate)",
                        src=lint.eqn_src(eqn)))
    return out
