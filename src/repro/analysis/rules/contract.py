"""kernel-contract: the jnp oracle and the Pallas kernel are
interchangeable.

For every op in the table and every :class:`OpSig` in the context's
contract grid, both backends are abstractly evaluated
(``jax.eval_shape`` — no kernel runs) and must agree on the full
output shape/dtype tree.  Tiling is checked against the roofline
device table: :func:`repro.analysis.opcost.tile_for` must pick a
lane-multiple tile whose working set (``vmem_rows * tile * itemsize``)
fits every device row's VMEM budget, and the kernels' batch-tile
helper must return a lane-multiple divisor of the lane-padded batch.
"""
import jax
import jax.numpy as jnp

from repro.analysis import lint


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _csr_pattern(n):
    """Tridiagonal CSR (indptr, indices) hashable tuples; nnz=3n-2."""
    indptr, indices = [0], []
    for i in range(n):
        cols = [j for j in (i - 1, i, i + 1) if 0 <= j < n]
        indices.extend(cols)
        indptr.append(len(indices))
    return tuple(indptr), tuple(indices)


def _bsr_pattern(nblk):
    """Block-tridiagonal (brows, bcols, nblk); nnzb=3*nblk-2."""
    brows, bcols = [], []
    for i in range(nblk):
        for j in (i - 1, i, i + 1):
            if 0 <= j < nblk:
                brows.append(i)
                bcols.append(j)
    return tuple(brows), tuple(bcols), nblk


# Each factory: sig -> (abstract array args, call(impl, args, policy)).
# Static operands (coefficient tuples, sparsity patterns, the negate
# flag) are closed over; only arrays are traced.


def _f_linear_sum(sig):
    x = _sds((sig.n,), sig.dtype)
    return (x, x), lambda fn, a, pol: fn(2.0, a[0], -0.5, a[1],
                                         policy=pol)


def _f_axpy(sig):
    x = _sds((sig.n,), sig.dtype)
    return (x, x), lambda fn, a, pol: fn(1.5, a[0], a[1], policy=pol)


def _f_linear_combination(sig):
    x = _sds((sig.n,), sig.dtype)
    coeffs = tuple(float(i + 1) for i in range(sig.k))
    return ((x,) * sig.k,
            lambda fn, a, pol: fn(coeffs, list(a), policy=pol))


def _f_scale_add_multi(sig):
    x = _sds((sig.n,), sig.dtype)
    coeffs = tuple(float(i + 1) for i in range(sig.k))
    return ((x,) * (sig.k + 1),
            lambda fn, a, pol: fn(coeffs, a[0], list(a[1:]),
                                  policy=pol))


def _f_reduction(sig):
    x = _sds((sig.n,), sig.dtype)
    return (x, x), lambda fn, a, pol: fn(a[0], a[1], policy=pol)


def _f_reduction_mask(sig):
    x = _sds((sig.n,), sig.dtype)
    return ((x, x, x),
            lambda fn, a, pol: fn(a[0], a[1], a[2], policy=pol))


def _f_dot_prod_multi(sig):
    x = _sds((sig.n,), sig.dtype)
    return ((x,) * (sig.k + 1),
            lambda fn, a, pol: fn(a[0], list(a[1:]), policy=pol))


def _f_block_solve(sig):
    A = _sds((sig.b, sig.b, sig.nsys), sig.dtype)
    r = _sds((sig.b, sig.nsys), sig.dtype)
    return (A, r), lambda fn, a, pol: fn(a[0], a[1], policy=pol)


def _f_block_inverse(sig):
    A = _sds((sig.b, sig.b, sig.nsys), sig.dtype)
    return (A,), lambda fn, a, pol: fn(a[0], policy=pol)


def _f_newton_residual(sig):
    z = _sds((sig.n, sig.nsys), sig.dtype)
    g = _sds((sig.nsys,), sig.dtype)
    return ((z, z, z, g),
            lambda fn, a, pol: fn(a[0], a[1], a[2], a[3], False,
                                  policy=pol))


def _f_masked_update(sig):
    z = _sds((sig.n, sig.nsys), sig.dtype)
    m = _sds((sig.nsys,), jnp.bool_)
    return ((z, z, z, m),
            lambda fn, a, pol: fn(a[0], a[1], a[2], a[3], policy=pol))


def _f_history_rescale(sig):
    W = _sds((sig.k, sig.k, sig.nsys), sig.dtype)
    Z = _sds((sig.k, sig.n, sig.nsys), sig.dtype)
    act = _sds((sig.nsys,), jnp.bool_)
    return ((W, Z, act),
            lambda fn, a, pol: fn(a[0], a[1], a[2], policy=pol))


def _f_wrms_soa(sig):
    v = _sds((sig.n, sig.nsys), sig.dtype)
    return (v, v), lambda fn, a, pol: fn(a[0], a[1], policy=pol)


def _f_csr_spmv(sig):
    pattern = _csr_pattern(sig.n)
    data = _sds((sig.nnz,), sig.dtype)
    x = _sds((sig.n,), sig.dtype)
    return ((data, x),
            lambda fn, a, pol: fn(a[0], a[1], pattern, policy=pol))


def _f_bsr_spmv(sig):
    nblk = sig.n // sig.b
    pattern = _bsr_pattern(nblk)
    values = _sds((sig.nnz, sig.b, sig.b, sig.nsys), sig.dtype)
    x = _sds((nblk, sig.b, sig.nsys), sig.dtype)
    return ((values, x),
            lambda fn, a, pol: fn(a[0], a[1], pattern, policy=pol))


def _f_bsr_diag_inverse(sig):
    nblk = sig.n // sig.b
    pattern = _bsr_pattern(nblk)
    values = _sds((sig.nnz, sig.b, sig.b, sig.nsys), sig.dtype)
    return ((values,),
            lambda fn, a, pol: fn(a[0], pattern, policy=pol))


ARG_FACTORIES = {
    "linear_sum": _f_linear_sum,
    "axpy": _f_axpy,
    "linear_combination": _f_linear_combination,
    "scale_add_multi": _f_scale_add_multi,
    "dot": _f_reduction,
    "wrms_norm": _f_reduction,
    "wrms_ss": _f_reduction,
    "wrms_norm_mask": _f_reduction_mask,
    "dot_prod_multi": _f_dot_prod_multi,
    "block_solve_soa": _f_block_solve,
    "block_inverse_soa": _f_block_inverse,
    "blockdiag_spmv_soa": _f_block_solve,
    "newton_residual_soa": _f_newton_residual,
    "masked_update_wrms_soa": _f_masked_update,
    "history_rescale_soa": _f_history_rescale,
    "wrms_soa": _f_wrms_soa,
    "csr_spmv": _f_csr_spmv,
    "bsr_spmv_soa": _f_bsr_spmv,
    "bsr_block_jacobi_inverse_soa": _f_bsr_diag_inverse,
}


def _tree_spec(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return [(tuple(leaf.shape), str(leaf.dtype)) for leaf in leaves]


@lint.register(
    "kernel-contract",
    "oracle/kernel shape+dtype agreement; lane-multiple VMEM-feasible "
    "tiles on every roofline device")
def check(ctx):
    from repro.analysis.opcost import LANE, _lane_ceil, op_cost, tile_for
    from repro.analysis.roofline import DEVICES
    from repro.core.policies import ExecPolicy
    from repro.kernels.ops import _batch_tile

    pol = ExecPolicy(backend="pallas", interpret=True)
    out = []
    for op in sorted(ctx.op_table):
        sigs = ctx.contract_sigs.get(op)
        if not sigs:
            out.append(lint.Violation(
                "kernel-contract", op,
                "op has no contract OpSig grid (add it to "
                "default_contract_sigs / the context)"))
            continue
        factory = ARG_FACTORIES.get(op)
        if factory is None:
            out.append(lint.Violation(
                "kernel-contract", op,
                "op has no argument factory (add it to "
                "rules/contract.py ARG_FACTORIES)"))
            continue
        impls = ctx.op_table[op]
        for sig in sigs:
            where = sig.key()       # "op|dtype|n=..,nsys=..,..."
            arrays, call = factory(sig)
            try:
                shp_jnp = jax.eval_shape(
                    lambda *a: call(impls["jnp"], a, pol), *arrays)
                shp_pl = jax.eval_shape(
                    lambda *a: call(impls["pallas"], a, pol), *arrays)
            except Exception as e:  # a backend that cannot even trace
                out.append(lint.Violation(
                    "kernel-contract", where,
                    f"abstract evaluation failed: "
                    f"{type(e).__name__}: {str(e).splitlines()[0]}"))
                continue
            if _tree_spec(shp_jnp) != _tree_spec(shp_pl):
                out.append(lint.Violation(
                    "kernel-contract", where,
                    f"backend output mismatch: jnp={_tree_spec(shp_jnp)}"
                    f" pallas={_tree_spec(shp_pl)}"))
            # tile feasibility on every roofline device row
            for dev_name, dev in DEVICES.items():
                tile = tile_for(sig, dev)
                if tile % LANE:
                    out.append(lint.Violation(
                        "kernel-contract", where,
                        f"tile_for({dev_name}) chose {tile}, not a "
                        f"lane multiple of {LANE}"))
                if dev.vmem_bytes is not None:
                    rows = max(1, op_cost(sig).vmem_rows)
                    need = rows * tile * sig.itemsize
                    if need > dev.vmem_bytes:
                        out.append(lint.Violation(
                            "kernel-contract", where,
                            f"tile_for({dev_name}) working set "
                            f"{need}B (rows={rows}, tile={tile}) "
                            f"exceeds VMEM budget "
                            f"{dev.vmem_bytes}B"))
            # kernels' batch-tile: lane-multiple divisor of the
            # lane-padded batch, for every batched sig
            if sig.nsys:
                bt = _batch_tile(sig.nsys, pol.batch_tile)
                padded = _lane_ceil(sig.nsys)
                if bt % LANE or padded % bt:
                    out.append(lint.Violation(
                        "kernel-contract", where,
                        f"_batch_tile({sig.nsys}, "
                        f"{pol.batch_tile}) = {bt} is not a "
                        f"lane-multiple divisor of the lane-padded "
                        f"batch {padded}"))
    return out
