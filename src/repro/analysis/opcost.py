"""Analytical per-op dispatch cost model (the roofline, per OP_TABLE op).

Every op in :data:`repro.core.dispatch.OP_TABLE` gets an analytical
flops/bytes model parameterized on the call-site quantities that decide
the jnp-vs-pallas winner — (shapes, dtype, b, nsys, K, nnz) — evaluated
against the :data:`repro.analysis.roofline.DEVICES` table.  The model
feeds ``backend='auto'`` dispatch (:mod:`repro.core.autotune`): it
predicts the winning backend and a VMEM-feasible tile when no measured
autotune-cache entry covers the call site, and its predictions are
audited against every measured entry in ``ctx.dispatch_report()``.

Modeling structure (why two byte counts per backend):

* ``hbm_bytes``    — the fused kernel's minimal single-pass traffic:
  what a *compiled* Pallas kernel streams from HBM (accumulator passes
  stay in VMEM and are free at this granularity).
* ``jnp_bytes``    — the jnp oracle's *algorithmic* traffic.  Sequential
  oracles materialize intermediates: the b-pivot Gauss-Jordan scan
  rewrites the whole augmented system per pivot (read + write), so its
  traffic is ~2b x the fused single pass — the term that makes the
  batched direct solves memory-bound wins for the fused kernels.
* ``pallas_bytes`` — the Pallas kernel's traffic when "VMEM" is host
  RAM, i.e. under the interpreter: accumulator passes are real traffic
  there (one read-modify-write sweep per pivot), but without the
  oracle's double materialization.

Time model per backend (``predict``):

  jnp     : kernels * jnp_launch + max(flops/peak, jnp_bytes/bw)
  pallas  : pallas_call + steps * pallas_step
            + max(flops/peak, hbm_bytes/bw)              [compiled]
  pallas  : pallas_call + steps * pallas_step
            + body_steps * body_ops * interp_op
            + pallas_bytes/bw                            [interpret]

``jnp_kernels`` counts the oracle's *dispatches*: one fused XLA kernel
for the flat streaming ops, but per-primitive eager dispatches for the
SoA/sparse oracles (strided layouts and gathers don't fuse on the CPU
path, so the oracle pays the launch constant once per primitive — and
the b-pivot Gauss-Jordan scan pays it per pivot pass).  That fixed
overhead, not bandwidth, is what makes the fused interpret kernels win
every batched op on the pseudo-device.

``body_ops`` approximates the number of primitive array operations one
kernel-body execution issues — under the interpreter each costs a
numpy-dispatch overhead per body execution.  ``body_steps`` is the
number of body executions: the SoA kernels process a whole
(rows x tile) block per grid step (body_steps = grid steps), while the
flat streaming kernels loop over LANE-sized sub-blocks inside each
tile (body_steps = axis/LANE) — which is why the streaming jnp oracle
(one fused kernel) beats interpret mode on flat vectors while losing
every SoA op.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

from repro.analysis.roofline import Device, get_device

LANE = 128

#: ops whose tiled axis is the SoA system batch (tile knob: batch_tile);
#: everything else streams over flat elements (tile knob: block_elems /
#: reduce_tile).
BATCHED_OPS = frozenset({
    "block_solve_soa", "block_inverse_soa", "blockdiag_spmv_soa",
    "newton_residual_soa", "masked_update_wrms_soa", "history_rescale_soa",
    "wrms_soa", "bsr_spmv_soa", "bsr_block_jacobi_inverse_soa",
})

REDUCTION_OPS = frozenset({
    "dot", "wrms_norm", "wrms_norm_mask", "dot_prod_multi", "wrms_ss",
})


def _lane_ceil(n: int) -> int:
    return max(LANE, -(-int(n) // LANE) * LANE)


@dataclasses.dataclass(frozen=True)
class OpSig:
    """Shape signature of one dispatch call site — the autotune-cache
    key fields.  Unused fields stay 0 (e.g. ``b`` for streaming ops)."""

    op: str
    dtype: str          # canonical jnp dtype name ('float64', ...)
    n: int = 0          # flat elements (streaming) / state length (SoA)
    nsys: int = 0       # SoA lane-axis system batch (0 = not batched)
    b: int = 0          # block size
    k: int = 0          # operand count K / history depth q1
    nnz: int = 0        # sparse nonzeros (CSR) or pattern blocks (BSR)

    @property
    def itemsize(self) -> int:
        return {"float64": 8, "float32": 4, "float16": 2,
                "bfloat16": 2}.get(self.dtype, 8)

    @property
    def axis_len(self) -> int:
        """Length of the tiled axis (batch for SoA ops, elements else)."""
        return self.nsys if self.op in BATCHED_OPS else self.n

    def key(self) -> str:
        """Stable cache-key string for this signature."""
        return (f"{self.op}|{self.dtype}|n={self.n},nsys={self.nsys},"
                f"b={self.b},k={self.k},nnz={self.nnz}")


def _tree_size(x: Any) -> int:
    from jax import tree_util
    return sum(int(l.size) for l in tree_util.tree_leaves(x))


def _dtype_name(x: Any) -> str:
    from jax import numpy as jnp, tree_util
    leaves = tree_util.tree_leaves(x)
    return str(jnp.result_type(*[l.dtype for l in leaves]))


def _sig_pairwise(op: str, args: Tuple) -> OpSig:
    x = args[1]
    return OpSig(op, _dtype_name(x), n=_tree_size(x), k=2)


def _sig_linear_combination(op: str, args: Tuple) -> OpSig:
    coeffs, vecs = args
    return OpSig(op, _dtype_name(vecs[0]), n=_tree_size(vecs[0]),
                 k=len(coeffs))


def _sig_scale_add_multi(op: str, args: Tuple) -> OpSig:
    coeffs, x, _ys = args
    return OpSig(op, _dtype_name(x), n=_tree_size(x), k=len(coeffs))


def _sig_reduction(op: str, args: Tuple) -> OpSig:
    return OpSig(op, _dtype_name(args[0]), n=_tree_size(args[0]), k=1)


def _sig_dot_prod_multi(op: str, args: Tuple) -> OpSig:
    x, ys = args
    return OpSig(op, _dtype_name(x), n=_tree_size(x), k=len(ys))


def _sig_block(op: str, args: Tuple) -> OpSig:
    A = args[0]
    b, _, nsys = A.shape
    return OpSig(op, str(A.dtype), n=b, nsys=nsys, b=b)


def _sig_soa_elementwise(op: str, args: Tuple) -> OpSig:
    z = args[0]
    n, nsys = z.shape
    return OpSig(op, str(z.dtype), n=n, nsys=nsys)


def _sig_history_rescale(op: str, args: Tuple) -> OpSig:
    _W, Z, _active = args
    q1, n, nsys = Z.shape
    return OpSig(op, str(Z.dtype), n=n, nsys=nsys, k=q1)


def _sig_csr(op: str, args: Tuple) -> OpSig:
    data, x, _pattern = args
    return OpSig(op, str(data.dtype), n=int(x.size), nnz=int(data.size))


def _sig_bsr_spmv(op: str, args: Tuple) -> OpSig:
    values, _x, pattern = args
    nnzb, b, _, nsys = values.shape
    return OpSig(op, str(values.dtype), n=int(pattern[2]) * b,
                 nsys=nsys, b=b, nnz=nnzb)


def _sig_bsr_diag_inverse(op: str, args: Tuple) -> OpSig:
    values, pattern = args
    nnzb, b, _, nsys = values.shape
    return OpSig(op, str(values.dtype), n=int(pattern[2]) * b,
                 nsys=nsys, b=b, nnz=nnzb)


#: per-op signature extractors — keys name EXACTLY the modeled op set
#: (sunlint's table-coherence rule checks them against OP_TABLE).
SIG_EXTRACTORS = {
    "linear_sum": _sig_pairwise,
    "axpy": _sig_pairwise,
    "linear_combination": _sig_linear_combination,
    "scale_add_multi": _sig_scale_add_multi,
    "dot": _sig_reduction,
    "wrms_norm": _sig_reduction,
    "wrms_ss": _sig_reduction,
    "wrms_norm_mask": _sig_reduction,
    "dot_prod_multi": _sig_dot_prod_multi,
    "block_solve_soa": _sig_block,
    "block_inverse_soa": _sig_block,
    "blockdiag_spmv_soa": _sig_block,
    "newton_residual_soa": _sig_soa_elementwise,
    "masked_update_wrms_soa": _sig_soa_elementwise,
    "wrms_soa": _sig_soa_elementwise,
    "history_rescale_soa": _sig_history_rescale,
    "csr_spmv": _sig_csr,
    "bsr_spmv_soa": _sig_bsr_spmv,
    "bsr_block_jacobi_inverse_soa": _sig_bsr_diag_inverse,
}


def signature(op: str, args: Tuple) -> OpSig:
    """Extract the :class:`OpSig` for one dispatch call.  ``args`` are
    the positional arguments of the public wrapper (sans policy); under
    jit they are tracers with concrete shapes/dtypes, so this works at
    trace time — which is exactly when ``auto`` dispatch resolves."""
    fn = SIG_EXTRACTORS.get(op)
    if fn is None:
        raise ValueError(f"no signature extractor for dispatch op {op!r}")
    return fn(op, args)


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Analytical work/traffic of one op at one signature."""

    flops: float
    hbm_bytes: float       # fused single-pass traffic (compiled pallas)
    jnp_bytes: float       # jnp-oracle algorithmic traffic
    pallas_bytes: float    # pallas traffic with VMEM = RAM (interpret)
    jnp_kernels: int       # oracle dispatches: fused kernels (streaming)
    #                        or eager primitive launches (SoA/sparse)
    body_ops: int          # primitive array ops per kernel-body exec
    vmem_rows: int         # accumulator rows per batched system (tile
    #                        working set = vmem_rows * tile * itemsize)


def _cost_lincomb(sig: OpSig) -> OpCost:
    s, n, k = sig.itemsize, sig.n, sig.k
    io = (k + 1) * n * s
    return OpCost((2 * k - 1) * n, io, io, io, 1, k + 1, k + 1)


def _cost_scale_add_multi(sig: OpSig) -> OpCost:
    s, n, k = sig.itemsize, sig.n, sig.k
    io = (2 * k + 1) * n * s
    return OpCost(2 * k * n, io, io, io, 1, 2 * k, 2 * k + 1)


def _cost_reduction(sig: OpSig) -> OpCost:
    s, n = sig.itemsize, sig.n
    io = 2 * n * s
    return OpCost(3 * n, io, io, io, 1, 3, 2)


def _cost_reduction_mask(sig: OpSig) -> OpCost:
    s, n = sig.itemsize, sig.n
    io = 3 * n * s
    return OpCost(4 * n, io, io, io, 1, 4, 3)


def _cost_dot_prod_multi(sig: OpSig) -> OpCost:
    s, n, k = sig.itemsize, sig.n, sig.k
    io = (k + 1) * n * s
    return OpCost(2 * k * n, io, io, io, 1, 2 * k, k + 1)


def _cost_block_solve(sig: OpSig) -> OpCost:
    s, nsys, b = sig.itemsize, sig.nsys, sig.b
    width = b + 1
    io = (b * width + b) * nsys * s        # read A,r; write x
    sweep = b * (b * width) * nsys * s     # b pivot passes
    body = 2 * b * b if b <= 8 else 5 * b
    # the oracle's GJ scan dispatches its body eagerly per pivot
    return OpCost(2 * b * b * width * nsys, io, 2 * sweep, sweep,
                  b * body, body, b * width)


def _cost_block_inverse(sig: OpSig) -> OpCost:
    s, nsys, b = sig.itemsize, sig.nsys, sig.b
    io = 2 * b * b * nsys * s
    sweep = b * (2 * b * b) * nsys * s
    body = 2 * b * b if b <= 8 else 5 * b
    return OpCost(4 * b ** 3 * nsys, io, 2 * sweep, sweep,
                  b * body, body, b * b)


def _cost_blockdiag_spmv(sig: OpSig) -> OpCost:
    s, nsys, b = sig.itemsize, sig.nsys, sig.b
    io = (b * b + 2 * b) * nsys * s
    return OpCost(2 * b * b * nsys, io, io, io, 2 * b, 2 * b,
                  b * b + 2 * b)


def _cost_newton_residual(sig: OpSig) -> OpCost:
    s, n, nsys = sig.itemsize, sig.n, sig.nsys
    io = 4 * n * nsys * s
    return OpCost(3 * n * nsys, io, io, io, 4, 4, 4 * n)


def _cost_masked_update_wrms(sig: OpSig) -> OpCost:
    s, n, nsys = sig.itemsize, sig.n, sig.nsys
    io = (5 * n + 1) * nsys * s
    return OpCost(6 * n * nsys, io, io, io, 6, 6, 5 * n)


def _cost_history_rescale(sig: OpSig) -> OpCost:
    s, n, nsys, k = sig.itemsize, sig.n, sig.nsys, sig.k
    io = (2 * k * n + k * k) * nsys * s
    return OpCost(2 * k * k * n * nsys, io, io, io, 2 * k, 2 * k,
                  2 * k * n + k * k)


def _cost_wrms_soa(sig: OpSig) -> OpCost:
    s, n, nsys = sig.itemsize, sig.n, sig.nsys
    io = (2 * n + 1) * nsys * s
    return OpCost(3 * n * nsys, io, io, io, 3, 3, 2 * n)


def _cost_csr_spmv(sig: OpSig) -> OpCost:
    s, n, nnz = sig.itemsize, sig.n, sig.nnz
    io = (2 * nnz + 2 * n) * s
    # the oracle's gather + segment-sum lowers to ~a dozen eager
    # primitives (gathers don't fuse on the CPU path)
    return OpCost(2 * nnz, io, io, io, 16,
                  2 * max(1, nnz // max(n, 1)), 4)


def _cost_bsr_spmv(sig: OpSig) -> OpCost:
    s, n, nsys, b, nnz = (sig.itemsize, sig.n, sig.nsys, sig.b, sig.nnz)
    nblk = max(1, n // max(b, 1))
    io = (nnz * b * b + 2 * nblk * b) * nsys * s
    return OpCost(2 * nnz * b * b * nsys, io, io, io, 2 * nnz, 2 * nnz,
                  nnz * b * b + 2 * nblk * b)


def _cost_bsr_diag_inverse(sig: OpSig) -> OpCost:
    s, n, nsys, b, nnz = (sig.itemsize, sig.n, sig.nsys, sig.b, sig.nnz)
    nblk = max(1, n // max(b, 1))
    io = (nnz + nblk) * b * b * nsys * s
    sweep = nblk * b * (2 * b * b) * nsys * s
    body = nblk * (2 * b * b if b <= 8 else 5 * b)
    return OpCost(4 * b ** 3 * nblk * nsys, io, 2 * sweep, sweep,
                  b * body, body, 2 * b * b)


#: per-op cost models — keys name EXACTLY the modeled op set (sunlint's
#: table-coherence rule checks them against OP_TABLE and the README).
COST_MODELS = {
    "linear_sum": _cost_lincomb,
    "axpy": _cost_lincomb,
    "linear_combination": _cost_lincomb,
    "scale_add_multi": _cost_scale_add_multi,
    "dot": _cost_reduction,
    "wrms_norm": _cost_reduction,
    "wrms_ss": _cost_reduction,
    "wrms_norm_mask": _cost_reduction_mask,
    "dot_prod_multi": _cost_dot_prod_multi,
    "block_solve_soa": _cost_block_solve,
    "block_inverse_soa": _cost_block_inverse,
    "blockdiag_spmv_soa": _cost_blockdiag_spmv,
    "newton_residual_soa": _cost_newton_residual,
    "masked_update_wrms_soa": _cost_masked_update_wrms,
    "history_rescale_soa": _cost_history_rescale,
    "wrms_soa": _cost_wrms_soa,
    "csr_spmv": _cost_csr_spmv,
    "bsr_spmv_soa": _cost_bsr_spmv,
    "bsr_block_jacobi_inverse_soa": _cost_bsr_diag_inverse,
}


def op_cost(sig: OpSig) -> OpCost:
    """The per-op analytical model — flops and the three byte counts
    (see module docstring), parameterized on the signature."""
    fn = COST_MODELS.get(sig.op)
    if fn is None:
        raise ValueError(f"no cost model for dispatch op {sig.op!r}")
    return fn(sig)


# ---------------------------------------------------------------------------
# Tile selection — the policy-visible successor of ops.GJ_VMEM_BYTES /
# _gj_batch_tile: pick the tile from the device's VMEM budget (compiled)
# or maximize the tile to amortize per-step overhead (interpret).
# ---------------------------------------------------------------------------


def tile_for(sig: OpSig, device: Device,
             requested: Optional[int] = None) -> int:
    """Lane-aligned tile along the op's tiled axis.

    Interpret pseudo-device: per-grid-step interpreter overhead
    dominates, so the whole (lane-padded) axis is one step — capped at
    2^16 lanes-elements per operand row to bound working memory.
    Compiled devices: the largest lane multiple whose working set
    ``vmem_rows * tile * itemsize`` fits the device VMEM budget,
    clamped to the caller's requested tile.
    """
    axis = max(1, sig.axis_len)
    if device.vmem_bytes is None:
        tile = min(_lane_ceil(axis), 1 << 16)
    else:
        rows = max(1, op_cost(sig).vmem_rows)
        cap = device.vmem_bytes // (rows * sig.itemsize)
        tile = max(LANE, cap // LANE * LANE)
    if requested:
        tile = min(tile, max(LANE, requested // LANE * LANE))
    return min(tile, _lane_ceil(axis))


@dataclasses.dataclass(frozen=True)
class Prediction:
    """Model output for one (op signature, device)."""

    sig: OpSig
    device: str
    t_jnp: float
    t_pallas: float
    tile: int

    @property
    def winner(self) -> str:
        return "jnp" if self.t_jnp <= self.t_pallas else "pallas"

    @property
    def ratio(self) -> float:
        """Predicted jnp/pallas time ratio (>1 -> pallas wins)."""
        return self.t_jnp / max(self.t_pallas, 1e-12)


def predict(sig: OpSig, device: str | Device,
            requested_tile: Optional[int] = None) -> Prediction:
    """Roofline-evaluate both backends for ``sig`` on ``device``."""
    dev = device if isinstance(device, Device) else get_device(device)
    cost = op_cost(sig)
    tile = tile_for(sig, dev, requested_tile)
    steps = max(1, math.ceil(_lane_ceil(max(1, sig.axis_len)) / tile))
    t_jnp = (cost.jnp_kernels * dev.jnp_launch +
             max(cost.flops / dev.peak_flops, cost.jnp_bytes / dev.bw("jnp")))
    if dev.interpret:
        # SoA kernels touch a whole (rows x tile) block per grid step;
        # the flat streaming kernels sub-loop over LANE-wide blocks
        # inside each tile, so they re-dispatch the body per lane block.
        body_steps = steps if sig.op in BATCHED_OPS else \
            max(1, _lane_ceil(max(1, sig.axis_len)) // LANE)
        t_pallas = (dev.pallas_call + steps * dev.pallas_step +
                    body_steps * cost.body_ops * dev.interp_op +
                    cost.pallas_bytes / dev.bw("pallas"))
    else:
        t_pallas = (dev.pallas_call + steps * dev.pallas_step +
                    max(cost.flops / dev.peak_flops,
                        cost.hbm_bytes / dev.bw("pallas")))
    return Prediction(sig=sig, device=dev.name, t_jnp=t_jnp,
                      t_pallas=t_pallas, tile=tile)
