"""sunlint — jaxpr-level static verification of the repo's invariants.

The paper's thesis is that the GPU-enabled infrastructure adds
*negligible overhead*; PRs 1-6 established the invariants that keep it
true (SoA hot loops with zero layout conversions, jnp/pallas kernel
contracts, donated carries, dtype discipline, one coherent op table).
This module checks them *statically*: it traces the integrators and the
dispatch ops to jaxprs and walks the equations, the way
byteprofile-analysis walks HLO to assign costs — except the output is a
verdict, not a cost.

Architecture
------------
* **Rules** live in :mod:`repro.analysis.rules` and register themselves
  via :func:`register`; each is a callable ``rule(ctx) -> [Violation]``.
* A :class:`LintContext` supplies what rules inspect — the op table,
  traced hot-loop jaxprs, contract signatures, purity targets — with
  lazy defaults built from the real repo.  Fixtures
  (``tests/fixtures/bad_kernels.py``) override individual fields to
  seed deliberate violations.
* **Suppression**: a violation is muted by a ``# sunlint:
  disable=<rule>`` comment on the offending source line (when the
  jaxpr equation carries source info) or by a ``rule|where`` entry in
  the committed ``.sunlint-baseline`` file (trailing ``*`` matches a
  ``where`` prefix; ``#`` starts a comment).

CLI::

    PYTHONPATH=src python -m repro.analysis.lint --check
    PYTHONPATH=src python -m repro.analysis.lint --list
    PYTHONPATH=src python -m repro.analysis.lint --rule hot-loop-layout
    PYTHONPATH=src python -m repro.analysis.lint --fixture hidden_transpose

Exit status 0 = no unsuppressed violations, 1 = at least one (or an
unknown rule/fixture name).
"""
from __future__ import annotations

import argparse
import dataclasses
import importlib.util
import sys
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

REPO_ROOT = Path(__file__).resolve().parents[3]

#: primitives whose sub-jaxprs are implementation detail, never walked
OPAQUE_PRIMS = frozenset({"pallas_call", "custom_jvp_call",
                          "custom_vjp_call", "custom_lin"})


# ---------------------------------------------------------------------------
# Violations and the rule registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: which rule, where (a stable dotted location string),
    and what went wrong.  ``src`` is a best-effort (file, line) from the
    jaxpr equation's source info, used for comment suppression."""

    rule: str
    where: str
    message: str
    src: Optional[Tuple[str, int]] = None

    def key(self) -> str:
        return f"{self.rule}|{self.where}"


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    doc: str
    fn: Callable


RULES: Dict[str, Rule] = {}


def register(name: str, doc: str):
    """Decorator: add a ``fn(ctx) -> [Violation]`` to the registry."""
    def deco(fn):
        RULES[name] = Rule(name, doc, fn)
        return fn
    return deco


_rules_loaded = False


def load_rules():
    """Import the rules package (idempotent); registration happens at
    module import via :func:`register`."""
    global _rules_loaded
    if not _rules_loaded:
        importlib.import_module("repro.analysis.rules")
        _rules_loaded = True
    return RULES


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def subjaxprs(eqn):
    """Yield every sub-jaxpr stored in an equation's params (scan's
    ``jaxpr``, while's ``cond_jaxpr``/``body_jaxpr``, cond's
    ``branches`` list, pjit's ``jaxpr``, ...)."""
    from jax.extend import core as jex_core
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if isinstance(v, jex_core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jex_core.Jaxpr):
                yield v


def is_opaque(eqn, opaque_names=frozenset()) -> bool:
    """True when the equation is a kernel boundary the walkers must not
    descend into: a Pallas call, a custom-derivative wrapper, or a
    ``pjit`` of one of the named (jitted) kernel entry points."""
    name = eqn.primitive.name
    if name in OPAQUE_PRIMS:
        return True
    return name == "pjit" and eqn.params.get("name") in opaque_names


def iter_eqns(jaxpr, opaque_names=frozenset()):
    """Every equation of ``jaxpr`` and its sub-jaxprs (depth first),
    stopping at opaque kernel boundaries."""
    for eqn in jaxpr.eqns:
        yield eqn
        if is_opaque(eqn, opaque_names):
            continue
        for sub in subjaxprs(eqn):
            yield from iter_eqns(sub, opaque_names)


def contains_loop(jaxpr, opaque_names=frozenset()) -> bool:
    return any(e.primitive.name in ("while", "scan")
               for e in iter_eqns(jaxpr, opaque_names))


def innermost_while_bodies(jaxpr, opaque_names=frozenset()):
    """Body jaxprs of every ``while`` that contains no further
    while/scan at any non-opaque depth — for the ensemble integrators
    these are exactly the Newton iteration loops (the adaptive step
    loop encloses them; the kernels' internal scans sit behind opaque
    pjit boundaries on the pallas backend)."""
    out = []
    for eqn in iter_eqns(jaxpr, opaque_names):
        if eqn.primitive.name != "while":
            continue
        body = eqn.params["body_jaxpr"].jaxpr
        if not contains_loop(body, opaque_names):
            out.append(body)
    return out


def eqn_src(eqn) -> Optional[Tuple[str, int]]:
    """Best-effort (file, line) for an equation, for clickable reports
    and ``# sunlint: disable=`` comment suppression."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is None:
            return None
        return (frame.file_name, int(frame.start_line))
    except Exception:
        return None


def kernel_wrapper_names() -> frozenset:
    """Names of the jitted Pallas kernel entry points in
    :mod:`repro.kernels.ops` — their ``pjit`` equations carry the
    function name, which is how the walkers treat kernel internals as
    opaque."""
    from repro.kernels import ops as kops
    return frozenset(attr for attr in dir(kops)
                     if type(getattr(kops, attr)).__name__
                     == "PjitFunction")


# ---------------------------------------------------------------------------
# Trace targets and the lint context
# ---------------------------------------------------------------------------


class TraceTarget:
    """A named deferred trace: ``thunk`` builds (and caches) the jaxpr
    on first use so rules share one trace per target."""

    def __init__(self, name: str, thunk: Callable):
        self.name = name
        self._thunk = thunk
        self._jaxpr = None

    def jaxpr(self):
        if self._jaxpr is None:
            self._jaxpr = self._thunk()
        return self._jaxpr


def _hot_policy():
    # the pallas(interpret) path: kernel internals sit behind opaque
    # pjit boundaries, so the trace shows exactly the *integrator's*
    # layout behavior — what the PR 5 no-transpose guarantee is about.
    # (The jnp oracles inline einsum/transpose into the body by design.)
    from repro.core.policies import ExecPolicy
    return ExecPolicy(backend="pallas", interpret=True)


def default_hot_loop_targets() -> List[TraceTarget]:
    """The ensemble Newton hot loops, traced with native-SoA RHS forms
    (the conversion-free configuration the integrators guarantee)."""
    import jax

    def bdf():
        from repro.core import batched
        from repro.core.problems import (batched_robertson,
                                         batched_robertson_soa)
        f, jac, y0 = batched_robertson(8)
        f_soa, jac_soa = batched_robertson_soa(8)
        return jax.make_jaxpr(
            lambda y: batched.ensemble_bdf_integrate(
                f, jac, y, 0.0, 1e-3, policy=_hot_policy(),
                f_soa=f_soa, jac_soa=jac_soa)[0])(y0).jaxpr

    def dirk():
        from repro.core import batched
        from repro.core.butcher import DIRK_TABLES
        from repro.core.problems import (batched_robertson,
                                         batched_robertson_soa)
        f, jac, y0 = batched_robertson(8)
        f_soa, jac_soa = batched_robertson_soa(8)
        return jax.make_jaxpr(
            lambda y: batched.ensemble_dirk_integrate(
                f, jac, y, 0.0, 1e-3, DIRK_TABLES["sdirk2"],
                policy=_hot_policy(), f_soa=f_soa,
                jac_soa=jac_soa)[0])(y0).jaxpr

    def bdf_warm():
        # the warm-start re-entry path: a session whose lanes carry
        # nonzero h/order/history enters the loop through the copy-
        # before-donate branch — the donation-aliasing rule audits that
        # the caller's session leaves are never donated directly and
        # the exported session never aliases the donated carry
        import jax.numpy as jnp

        from repro.core import batched
        from repro.core.problems import (batched_robertson,
                                         batched_robertson_soa)
        f, jac, y0 = batched_robertson(8)
        f_soa, jac_soa = batched_robertson_soa(8)
        sess = batched.SolverSession.cold(y0, 0.0)._replace(
            h=jnp.full((8,), 1e-5), q=jnp.full((8,), 2, jnp.int32),
            steps=jnp.full((8,), 3, jnp.int32))
        return jax.make_jaxpr(
            lambda s: batched.ensemble_bdf_integrate(
                f, jac, None, None, 1e-3, policy=_hot_policy(),
                f_soa=f_soa, jac_soa=jac_soa, session=s,
                return_session=True)[0])(sess).jaxpr

    return [TraceTarget("ensemble_bdf", bdf),
            TraceTarget("ensemble_dirk", dirk),
            TraceTarget("ensemble_bdf_warm_restart", bdf_warm)]


def default_contract_sigs() -> Dict[str, list]:
    """The OpSig grid the kernel-contract rule checks per op: small and
    large instances of every OP_TABLE op (block sizes straddling the
    b<=8 single-tile / b>8 row-tiled kernel regimes)."""
    from repro.analysis.opcost import OpSig
    sigs: Dict[str, list] = {}

    def add(op, **kw):
        sigs.setdefault(op, []).append(OpSig(op, "float64", **kw))

    for n in (6, 300):
        for op in ("linear_sum", "axpy"):
            add(op, n=n, k=2)
        for op in ("linear_combination", "scale_add_multi",
                   "dot_prod_multi"):
            add(op, n=n, k=3)
        for op in ("dot", "wrms_norm", "wrms_ss"):
            add(op, n=n, k=1)
        add("wrms_norm_mask", n=n, k=1)
    for b, nsys in ((3, 8), (16, 40)):
        for op in ("block_solve_soa", "block_inverse_soa",
                   "blockdiag_spmv_soa"):
            add(op, n=b, nsys=nsys, b=b)
    for n, nsys in ((3, 8), (12, 40)):
        for op in ("newton_residual_soa", "masked_update_wrms_soa",
                   "wrms_soa"):
            add(op, n=n, nsys=nsys)
    add("history_rescale_soa", n=3, nsys=8, k=6)
    for n in (4, 8):
        add("csr_spmv", n=n, nnz=3 * n - 2)
    for nblk, b, nsys in ((4, 3, 8),):
        add("bsr_spmv_soa", n=nblk * b, nsys=nsys, b=b,
            nnz=3 * nblk - 2)
        add("bsr_block_jacobi_inverse_soa", n=nblk * b, nsys=nsys, b=b,
            nnz=3 * nblk - 2)
    return sigs


def default_purity_targets() -> List[TraceTarget]:
    """Abstract (eval_shape) traces of every canonical
    ``IVP.integrate`` method string plus the sunmatrix/spsolve symbolic
    phases — the surfaces where a Python branch on a tracer or a
    non-hashable static pattern would leak a concrete value."""
    import jax
    import jax.numpy as jnp

    targets = []

    def _integrate_thunk(method):
        def thunk():
            import numpy as np
            from repro.core.ivp import IVP, integrate
            from repro.core.problems import batched_robertson
            if method.startswith("ensemble"):
                f, jac, y0 = batched_robertson(4)
                prob_kw = dict(f=f, jac=jac)
            else:
                f, jac, y0b = batched_robertson(1)
                y0 = np.asarray(y0b)[0]
                sf = lambda t, y: f(jnp.asarray(t)[None],
                                    y[None, :])[0]
                sjac = lambda t, y: jac(jnp.asarray(t)[None],
                                        y[None, :])[0]
                if method.startswith("imex"):
                    prob_kw = dict(fe=lambda t, y: jnp.zeros_like(y),
                                   fi=sf, jac=sjac)
                else:
                    prob_kw = dict(f=sf, jac=sjac)
            return jax.eval_shape(
                lambda y: integrate(
                    IVP(y0=y, **prob_kw), 0.0, 1e-3, method).y,
                jax.ShapeDtypeStruct(jnp.shape(y0), jnp.float64))
        return thunk

    from repro.core.ivp import METHOD_STRINGS
    for m in METHOD_STRINGS:
        targets.append(TraceTarget(f"integrate[{m}]",
                                   _integrate_thunk(m)))

    def spsolve_thunk():
        import numpy as np
        from repro.core import spsolve, sunmatrix
        A = np.array([[4.0, 1, 0, 0], [1, 4, 1, 0],
                      [0, 1, 4, 1], [0, 0, 1, 4]])
        indptr, indices = sunmatrix.csr_pattern_from_dense(A)
        plan = spsolve.symbolic_lu(indptr, indices)
        nnz = len(indices)
        return jax.eval_shape(
            lambda vals, rhs: spsolve.lu_solve(
                plan,
                spsolve.numeric_lu(
                    plan, spsolve.scatter_from_csr(plan, indptr,
                                                   indices, vals)),
                rhs),
            jax.ShapeDtypeStruct((nnz, 5), jnp.float64),
            jax.ShapeDtypeStruct((4, 5), jnp.float64))

    targets.append(TraceTarget("spsolve.symbolic_lu+solve",
                               spsolve_thunk))
    return targets


def default_telemetry_targets() -> List[Tuple[str, TraceTarget,
                                              TraceTarget]]:
    """(name, baseline, candidate) trace pairs for telemetry-purity.

    Baseline is the *raw* integrator call — the pre-observability trace
    with no :class:`~repro.observability.ObservabilityConfig` anywhere
    near it.  Candidate is the same integration routed through
    ``IVP.integrate`` with the default (disabled) observability config
    on the context.  The rule demands the adaptive step-loop bodies be
    primitive-identical: a disabled config must add ZERO equations to
    the jitted hot loop."""
    import jax
    import jax.numpy as jnp

    def _ensemble_setup():
        from repro.core.problems import (batched_robertson,
                                         batched_robertson_soa)
        f, jac, y0 = batched_robertson(8)
        f_soa, jac_soa = batched_robertson_soa(8)
        return f, jac, y0, f_soa, jac_soa

    def bdf_base():
        from repro.core import batched
        f, jac, y0, f_soa, jac_soa = _ensemble_setup()
        return jax.make_jaxpr(
            lambda y: batched.ensemble_bdf_integrate(
                f, jac, y, 0.0, 1e-3, f_soa=f_soa,
                jac_soa=jac_soa)[0])(y0).jaxpr

    def bdf_cand():
        from repro.core.context import Context
        from repro.core.ivp import IVP, integrate
        f, jac, y0, f_soa, jac_soa = _ensemble_setup()
        return jax.make_jaxpr(
            lambda y: integrate(
                IVP(y0=y, f=f, jac=jac, f_soa=f_soa, jac_soa=jac_soa),
                0.0, 1e-3, "ensemble_bdf", ctx=Context()).y)(y0).jaxpr

    def dirk_base():
        from repro.core import batched
        from repro.core.butcher import DIRK_TABLES
        f, jac, y0, f_soa, jac_soa = _ensemble_setup()
        return jax.make_jaxpr(
            lambda y: batched.ensemble_dirk_integrate(
                f, jac, y, 0.0, 1e-3, DIRK_TABLES["sdirk2"],
                f_soa=f_soa, jac_soa=jac_soa)[0])(y0).jaxpr

    def dirk_cand():
        from repro.core.context import Context
        from repro.core.ivp import IVP, integrate
        f, jac, y0, f_soa, jac_soa = _ensemble_setup()
        return jax.make_jaxpr(
            lambda y: integrate(
                IVP(y0=y, f=f, jac=jac, f_soa=f_soa, jac_soa=jac_soa),
                0.0, 1e-3, "ensemble_dirk:sdirk2",
                ctx=Context()).y)(y0).jaxpr

    def _scalar_setup():
        import numpy as np
        from repro.core.problems import batched_robertson
        f, jac, y0b = batched_robertson(1)
        y0 = np.asarray(y0b)[0]
        sf = lambda t, y: f(jnp.asarray(t)[None], y[None, :])[0]
        sjac = lambda t, y: jac(jnp.asarray(t)[None], y[None, :])[0]
        return sf, sjac, y0

    def scalar_base():
        from repro.core import cvode
        sf, _, y0 = _scalar_setup()
        return jax.make_jaxpr(
            lambda y: cvode.bdf_integrate(sf, y, 0.0, 1e-3)[0])(
                y0).jaxpr

    def scalar_cand():
        from repro.core.context import Context
        from repro.core.ivp import IVP, integrate
        sf, sjac, y0 = _scalar_setup()
        return jax.make_jaxpr(
            lambda y: integrate(
                IVP(y0=y, f=sf, jac=sjac), 0.0, 1e-3, "bdf",
                ctx=Context()).y)(y0).jaxpr

    return [
        ("ensemble_bdf", TraceTarget("ensemble_bdf[raw]", bdf_base),
         TraceTarget("ensemble_bdf[integrate,obs-off]", bdf_cand)),
        ("ensemble_dirk", TraceTarget("ensemble_dirk[raw]", dirk_base),
         TraceTarget("ensemble_dirk[integrate,obs-off]", dirk_cand)),
        ("bdf", TraceTarget("bdf[raw]", scalar_base),
         TraceTarget("bdf[integrate,obs-off]", scalar_cand)),
    ]


def default_telemetry_enabled_targets() -> List[TraceTarget]:
    """Traces with step telemetry switched ON, scanned for host
    callback primitives — the enabled path must record through the
    in-graph ring buffer, never ``io_callback`` and friends."""
    import jax

    def enabled():
        from repro.core.context import Context
        from repro.core.ivp import IVP, integrate
        from repro.core.problems import (batched_robertson,
                                         batched_robertson_soa)
        from repro.observability import ObservabilityConfig
        f, jac, y0 = batched_robertson(8)
        f_soa, jac_soa = batched_robertson_soa(8)
        ctx = Context(observability=ObservabilityConfig(
            telemetry=True, telemetry_capacity=16))

        def run(y):
            sol = integrate(
                IVP(y0=y, f=f, jac=jac, f_soa=f_soa, jac_soa=jac_soa),
                0.0, 1e-3, "ensemble_bdf", ctx=ctx)
            return sol.y, sol.telemetry
        return jax.make_jaxpr(run)(y0).jaxpr

    return [TraceTarget("ensemble_bdf[integrate,telemetry=16]",
                        enabled)]


class LintContext:
    """What the rules inspect.  Every field has a lazy default built
    from the real repo; fixtures override via the setters."""

    def __init__(self, repo_root: Optional[Path] = None):
        self.repo_root = Path(repo_root) if repo_root else REPO_ROOT
        self.baseline_path = self.repo_root / ".sunlint-baseline"
        #: allowed float-width conversions inside hot-loop bodies, as
        #: (src_dtype, dst_dtype) string pairs — the mixed-precision
        #: seam: a future f32 Newton mode allowlists its casts here.
        self.dtype_allowlist: set = set()
        self._op_table = None
        self._opaque_names = None
        self._hot_loop_targets = None
        self._donation_targets = None
        self._contract_sigs = None
        self._purity_targets = None
        self._telemetry_targets = None
        self._telemetry_enabled_targets = None

    @property
    def op_table(self) -> dict:
        if self._op_table is None:
            from repro.core import dispatch
            self._op_table = dict(dispatch.OP_TABLE)
        return self._op_table

    @op_table.setter
    def op_table(self, table):
        self._op_table = dict(table)

    @property
    def opaque_names(self) -> frozenset:
        if self._opaque_names is None:
            self._opaque_names = kernel_wrapper_names()
        return self._opaque_names

    @opaque_names.setter
    def opaque_names(self, names):
        self._opaque_names = frozenset(names)

    @property
    def hot_loop_targets(self) -> List[TraceTarget]:
        if self._hot_loop_targets is None:
            self._hot_loop_targets = default_hot_loop_targets()
        return self._hot_loop_targets

    @hot_loop_targets.setter
    def hot_loop_targets(self, targets):
        self._hot_loop_targets = list(targets)

    @property
    def donation_targets(self) -> List[TraceTarget]:
        # the hot-loop traces contain the _donated_loop pjit; sharing
        # the TraceTarget objects shares the cached trace.
        if self._donation_targets is None:
            self._donation_targets = self.hot_loop_targets
        return self._donation_targets

    @donation_targets.setter
    def donation_targets(self, targets):
        self._donation_targets = list(targets)

    @property
    def contract_sigs(self) -> Dict[str, list]:
        if self._contract_sigs is None:
            self._contract_sigs = default_contract_sigs()
        return self._contract_sigs

    @contract_sigs.setter
    def contract_sigs(self, sigs):
        self._contract_sigs = dict(sigs)

    @property
    def purity_targets(self) -> List[TraceTarget]:
        if self._purity_targets is None:
            self._purity_targets = default_purity_targets()
        return self._purity_targets

    @purity_targets.setter
    def purity_targets(self, targets):
        self._purity_targets = list(targets)

    @property
    def telemetry_targets(self) -> List[Tuple[str, TraceTarget,
                                              TraceTarget]]:
        if self._telemetry_targets is None:
            self._telemetry_targets = default_telemetry_targets()
        return self._telemetry_targets

    @telemetry_targets.setter
    def telemetry_targets(self, targets):
        self._telemetry_targets = list(targets)

    @property
    def telemetry_enabled_targets(self) -> List[TraceTarget]:
        if self._telemetry_enabled_targets is None:
            self._telemetry_enabled_targets = \
                default_telemetry_enabled_targets()
        return self._telemetry_enabled_targets

    @telemetry_enabled_targets.setter
    def telemetry_enabled_targets(self, targets):
        self._telemetry_enabled_targets = list(targets)


# ---------------------------------------------------------------------------
# Suppression
# ---------------------------------------------------------------------------


def load_baseline(path: Path) -> List[str]:
    """``rule|where`` entries (trailing ``*`` = prefix match) from the
    committed baseline file; missing file = empty baseline."""
    if not path.is_file():
        return []
    out = []
    for line in path.read_text().splitlines():
        line = line.split("#", 1)[0].strip()
        if line:
            out.append(line)
    return out


_SRC_CACHE: Dict[str, List[str]] = {}


def _source_line(fname: str, lineno: int) -> str:
    lines = _SRC_CACHE.get(fname)
    if lines is None:
        try:
            lines = Path(fname).read_text().splitlines()
        except OSError:
            lines = []
        _SRC_CACHE[fname] = lines
    if 1 <= lineno <= len(lines):
        return lines[lineno - 1]
    return ""


def is_suppressed(v: Violation, baseline: Sequence[str]) -> bool:
    for entry in baseline:
        if entry.endswith("*"):
            if v.key().startswith(entry[:-1]):
                return True
        elif entry == v.key():
            return True
    if v.src is not None:
        fname, lineno = v.src
        line = _source_line(fname, lineno)
        if "# sunlint: disable=" in line:
            disabled = line.split("# sunlint: disable=", 1)[1]
            names = {s.strip() for s in disabled.split(",")}
            if v.rule in names or "all" in names:
                return True
    return False


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def run_rules(ctx: LintContext,
              names: Optional[Sequence[str]] = None) -> List[Violation]:
    """Run the named rules (default: all) and return raw violations
    (suppression NOT applied — the caller filters)."""
    load_rules()
    if names:
        unknown = sorted(set(names) - set(RULES))
        if unknown:
            raise KeyError(f"unknown rule(s) {unknown}; registered: "
                           f"{', '.join(sorted(RULES))}")
    out: List[Violation] = []
    for name in sorted(RULES):
        if names and name not in names:
            continue
        out.extend(RULES[name].fn(ctx))
    return out


def load_fixtures(repo_root: Optional[Path] = None) -> dict:
    """``{name: (expected_rule, setup_fn)}`` from
    tests/fixtures/bad_kernels.py, loaded by path (tests/ is not a
    package on sys.path)."""
    root = Path(repo_root) if repo_root else REPO_ROOT
    path = root / "tests" / "fixtures" / "bad_kernels.py"
    spec = importlib.util.spec_from_file_location("sunlint_bad_kernels",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.FIXTURES


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="sunlint: jaxpr-level static verification")
    ap.add_argument("--check", action="store_true",
                    help="run all rules over the repo (the default)")
    ap.add_argument("--rule", action="append", default=None,
                    metavar="NAME", help="run only this rule "
                    "(repeatable)")
    ap.add_argument("--fixture", default=None, metavar="NAME",
                    help="seed a deliberately-broken fixture from "
                    "tests/fixtures/bad_kernels.py (expected exit: 1)")
    ap.add_argument("--list", action="store_true",
                    help="list registered rules and exit")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore .sunlint-baseline suppressions")
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_enable_x64", True)

    load_rules()
    if args.list:
        for name in sorted(RULES):
            print(f"{name:20s} {RULES[name].doc}")
        return 0

    ctx = LintContext()
    if args.fixture:
        fixtures = load_fixtures()
        if args.fixture not in fixtures:
            print(f"unknown fixture {args.fixture!r}; available: "
                  f"{', '.join(sorted(fixtures))}", file=sys.stderr)
            return 1
        expected_rule, setup = fixtures[args.fixture]
        setup(ctx)
        print(f"fixture {args.fixture!r} seeded "
              f"(expects rule {expected_rule!r} to fire)")

    try:
        violations = run_rules(ctx, args.rule)
    except KeyError as e:
        print(e.args[0], file=sys.stderr)
        return 1
    baseline = [] if args.no_baseline else load_baseline(
        ctx.baseline_path)
    kept = [v for v in violations if not is_suppressed(v, baseline)]
    muted = len(violations) - len(kept)

    n_rules = len(args.rule) if args.rule else len(RULES)
    for v in kept:
        loc = f"  [{v.src[0]}:{v.src[1]}]" if v.src else ""
        print(f"{v.rule}: {v.where}: {v.message}{loc}")
    summary = (f"sunlint: {len(kept)} violation"
               f"{'' if len(kept) == 1 else 's'} "
               f"({n_rules} rules, {muted} suppressed)")
    print(summary)
    return 1 if kept else 0


if __name__ == "__main__":
    # under `python -m` this file is the __main__ module; delegate to
    # the canonical import so rules register into the same RULES dict.
    from repro.analysis import lint as _lint
    sys.exit(_lint.main())
