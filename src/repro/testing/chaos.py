"""Deterministic fault injection for the fault-containment contract.

The per-lane retcode machinery (:mod:`repro.core.status`, the
``ensemble_bdf``/``ensemble_dirk`` quarantine paths) and the serving
tier's graceful degradation (typed ``SolverError`` futures, deadlines,
backend fallback) are only trustworthy if faults can be *injected on
demand* and the blast radius measured.  This module provides seeded,
trace-compatible injectors plus the chaos suite that asserts the
contract end to end:

* **k faults => exactly k failures.**  Poisoning k lanes of an
  ``nsys``-lane ensemble produces exactly k non-success retcodes (at
  exactly the planned lanes) and, through the serving tier, exactly k
  failed Futures — never a hung Future, never a garbage result.
* **Healthy lanes are bitwise clean.**  Under the jnp backend the
  non-faulted lanes of a poisoned run reproduce the no-fault run
  bit for bit (trajectories AND decision streams): injection rides
  ``jnp.where`` selects whose clean branch is the unmodified value, and
  the quarantine machinery is per-lane masked, so a fault in lane i is
  *invisible* to lane j.

Injectors are **trace-compatible**: they wrap the RHS (or the server's
compiled-run seam) without changing shapes, dtypes, or the trace
signature, so a poisoned run compiles to the same program structure as
a clean one and the trace cache / autotune machinery behaves
identically.  All randomness flows from explicit seeds
(:class:`ChaosPlan`) — a chaos failure reproduces from its seed.

Run the acceptance suite::

    python -m repro.testing.chaos --smoke

(core containment at 4096 lanes under jnp + a pallas-interpret pass,
then a >= 10^4-request serving run with lane faults, deadline sheds,
and one injected executable failure exercising the jnp-oracle
fallback).
"""
from __future__ import annotations

import argparse
import json
import math
import random
import sys
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import status

__all__ = [
    "ChaosPlan", "poison_rhs", "chaotic_robertson_family",
    "failing_executions", "run_core_chaos", "run_serving_chaos", "main",
]


# ---------------------------------------------------------------------------
# seeded fault plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ChaosPlan:
    """A seeded selection of fault lanes and onset times.

    ``lanes`` are the faulted lane indices (sorted, distinct);
    ``onsets`` are the per-faulted-lane fault onset times, aligned with
    ``lanes``.  Healthy lanes have onset ``+inf`` in
    :meth:`onset_vector` — the injected predicate ``t >= onset`` is
    never true for them, so the poison select always takes the clean
    branch.
    """

    nsys: int
    lanes: Tuple[int, ...]
    onsets: Tuple[float, ...]

    @classmethod
    def draw(cls, nsys: int, k: int, t0: float, tf: float, *,
             seed: int = 0,
             window: Tuple[float, float] = (0.3, 0.7)) -> "ChaosPlan":
        """Draw ``k`` distinct fault lanes with onsets uniform in the
        fractional ``window`` of ``[t0, tf]`` (defaults keep faults away
        from the endpoints so the clean run has accepted steps both
        before and after the onset)."""
        if not 0 <= k <= nsys:
            raise ValueError(f"need 0 <= k={k} <= nsys={nsys}")
        rng = random.Random(seed)
        lanes = tuple(sorted(rng.sample(range(nsys), k)))
        w0, w1 = window
        onsets = tuple(t0 + (w0 + rng.random() * (w1 - w0)) * (tf - t0)
                       for _ in lanes)
        return cls(nsys=nsys, lanes=lanes, onsets=onsets)

    def mask(self) -> np.ndarray:
        """(nsys,) bool: True at faulted lanes."""
        m = np.zeros(self.nsys, dtype=bool)
        m[list(self.lanes)] = True
        return m

    def onset_vector(self, dtype=np.float64) -> np.ndarray:
        """(nsys,) fault onset times; ``+inf`` for healthy lanes."""
        v = np.full(self.nsys, np.inf, dtype=dtype)
        for lane, t in zip(self.lanes, self.onsets):
            v[lane] = t
        return v


# ---------------------------------------------------------------------------
# RHS injectors (closed-over batched problems)
# ---------------------------------------------------------------------------

def poison_rhs(f: Callable, plan: ChaosPlan, *, mode: str = "nan",
               soa: bool = False, scale: float = 1e12) -> Callable:
    """Wrap a batched RHS so the planned lanes fail after their onset.

    ``mode="nan"`` replaces the faulted lanes' RHS with NaN once
    ``t >= onset`` — the CV_RHSFUNC_FAIL / CV_CONV_FAILURE path (a NaN
    step is never accepted, so the lane's last accepted state stays
    finite).  ``mode="divergent"`` adds ``scale * y`` to the faulted
    lanes WITHOUT touching the Jacobian: the Newton matrix no longer
    matches the residual, the corrector diverges, and the lane
    escalates through MXNCF / hmin underflow (CV_CONV_FAILURE /
    CV_ERR_FAILURE) on finite arithmetic.

    ``soa=True`` wraps the SoA form (``y: (n, nsys)``, fault axis
    last); otherwise AoS (``y: (nsys, n)``, fault axis first).  Healthy
    lanes flow through a ``jnp.where`` whose selected value is the
    untouched clean RHS — elementwise, so the no-fault lanes of a
    poisoned run stay bitwise identical to a clean run under jnp.
    """
    if mode not in ("nan", "divergent"):
        raise ValueError(f"unknown chaos mode {mode!r}")
    mask = jnp.asarray(plan.mask())
    onset = jnp.asarray(plan.onset_vector())

    def wrapped(t, y):
        clean = f(t, y)
        tv = jnp.broadcast_to(jnp.asarray(t), mask.shape)
        hot = mask & (tv >= onset)
        hot = hot[None, :] if soa else hot[:, None]
        if mode == "nan":
            return jnp.where(hot, jnp.nan, clean)
        return jnp.where(hot, clean + scale * y, clean)

    return wrapped


def chaotic_robertson_family():
    """:func:`~repro.core.problems.robertson_family` plus a per-request
    ``t_fault`` parameter: a lane whose ``t >= t_fault`` sees a NaN RHS
    (healthy requests pass ``t_fault = inf``).  Same trace signature as
    the clean family — faultiness is data, so faulted and healthy
    requests share one bundle and one cache entry, which is exactly the
    containment scenario worth testing."""
    from repro.core.problems import robertson_family
    f, jac, f_soa, jac_soa = robertson_family()

    def f_c(t, y, p):
        return jnp.where((t >= p["t_fault"])[:, None], jnp.nan,
                         f(t, y, p))

    def f_soa_c(t, y, p):
        return jnp.where((t >= p["t_fault"])[None, :], jnp.nan,
                         f_soa(t, y, p))

    return f_c, jac, f_soa_c, jac_soa


# ---------------------------------------------------------------------------
# serving injectors
# ---------------------------------------------------------------------------

@contextmanager
def failing_executions(server, k: int = 1,
                       exc: Optional[Exception] = None):
    """Patch the server's compiled-run seam so the next ``k``
    invocations raise (a simulated executable failure).

    The one-shot jnp-oracle fallback re-enters the same seam, so
    ``k=1`` exercises graceful degradation end to end: the primary
    execution raises, the fallback runs clean, and every Future in the
    bundle resolves with a ``degraded`` Solution.  ``k=2`` fails the
    fallback too — the bundle's Futures then fail with a typed
    ``SolverError`` (resolve-don't-strand).  Yields a mutable box with
    ``raised`` / ``remaining`` counters.
    """
    orig = server._run_compiled
    box = {"remaining": int(k), "raised": 0}

    def chaotic(entry, sess, tfa, params):
        if box["remaining"] > 0:
            box["remaining"] -= 1
            box["raised"] += 1
            raise exc if exc is not None else RuntimeError(
                "chaos: injected executable failure")
        return orig(entry, sess, tfa, params)

    server._run_compiled = chaotic
    try:
        yield box
    finally:
        server._run_compiled = orig


# ---------------------------------------------------------------------------
# chaos suites
# ---------------------------------------------------------------------------

def run_core_chaos(nsys: int = 4096, k: int = 8, *, seed: int = 0,
                   tf: float = 0.4, policy=None, mode: str = "nan",
                   check_bitwise: Optional[bool] = None) -> dict:
    """Core containment: poison ``k`` of ``nsys`` Robertson lanes and
    assert exactly-k quarantine with healthy lanes unharmed.

    Asserts (raising ``AssertionError`` with a reproducing seed):

    * exactly the planned lanes carry non-success retcodes;
    * the ``ok`` mask mirrors ``retcodes == 0``;
    * healthy-lane states are finite and healthy lanes report success;
    * faulted lanes' reported states are finite (the last ACCEPTED
      state — a NaN attempt is never accepted);
    * under jnp (``check_bitwise`` defaults to backend == "jnp"):
      healthy-lane trajectories and decision streams (steps, attempts,
      netf, nni) are bitwise identical to a clean run.

    Returns a report dict for the CLI / logs.
    """
    from repro.core.batched import ensemble_bdf_integrate
    from repro.core.policies import XLA_FUSED
    from repro.core.problems import (batched_robertson,
                                     batched_robertson_soa)
    policy = XLA_FUSED if policy is None else policy
    if check_bitwise is None:
        check_bitwise = policy.backend == "jnp"
    tag = f"[core seed={seed} nsys={nsys} k={k} mode={mode}]"

    f, jac, y0 = batched_robertson(nsys)
    f_soa, jac_soa = batched_robertson_soa(nsys)
    plan = ChaosPlan.draw(nsys, k, 0.0, tf, seed=seed)
    clean_y, clean_st = ensemble_bdf_integrate(
        f, jac, y0, 0.0, tf, policy=policy,
        f_soa=f_soa, jac_soa=jac_soa)
    fy, fst = ensemble_bdf_integrate(
        poison_rhs(f, plan, mode=mode), jac, y0, 0.0, tf, policy=policy,
        f_soa=poison_rhs(f_soa, plan, mode=mode, soa=True),
        jac_soa=jac_soa)

    rcs = np.asarray(fst.retcodes)
    ok = np.asarray(fst.ok)
    failed = np.flatnonzero(rcs != 0)
    assert set(failed.tolist()) == set(plan.lanes), (
        f"{tag} expected failures exactly at {plan.lanes}, got "
        f"{failed.tolist()}")
    assert np.array_equal(ok, rcs == 0), f"{tag} ok mask != retcodes==0"
    for lane in plan.lanes:
        assert rcs[lane] in status.RETCODE_NAMES, (
            f"{tag} lane {lane} carries unknown retcode {rcs[lane]}")

    healthy = ~plan.mask()
    fy_np, cy_np = np.asarray(fy), np.asarray(clean_y)
    assert np.isfinite(fy_np[healthy]).all(), (
        f"{tag} healthy lanes contaminated with non-finite state")
    assert np.isfinite(fy_np[~healthy]).all(), (
        f"{tag} faulted lanes reported non-finite state (quarantine "
        "must freeze the last ACCEPTED state)")
    if check_bitwise:
        for name in ("steps", "attempts", "netf", "nni"):
            a = np.asarray(getattr(fst, name))[healthy]
            b = np.asarray(getattr(clean_st, name))[healthy]
            assert np.array_equal(a, b), (
                f"{tag} healthy-lane decision stream {name!r} diverged")
        if mode == "nan":
            # NaN injection is a constant select — fusion-inert, so
            # healthy lanes reproduce the clean run bit for bit
            assert np.array_equal(fy_np[healthy], cy_np[healthy]), (
                f"{tag} healthy-lane trajectories differ from the "
                "no-fault run (bitwise)")
        else:
            # the divergent injector adds arithmetic (clean + scale*y)
            # that XLA fuses into shared reductions, perturbing healthy
            # lanes by ULPs even before any onset; Robertson's stiffness
            # amplifies those seeds along the (identical) step sequence,
            # so allow rounding-seeded drift — still ~6 orders below
            # anything fault-shaped
            assert np.allclose(fy_np[healthy], cy_np[healthy],
                               rtol=1e-6, atol=1e-10), (
                f"{tag} healthy-lane trajectories drifted beyond "
                "rounding-seeded level")

    return {"suite": "core", "seed": seed, "nsys": nsys, "mode": mode,
            "backend": policy.backend, "faulted": len(plan.lanes),
            "failed": int((rcs != 0).sum()),
            "retcodes": {str(l): status.retcode_name(int(rcs[l]))
                         for l in plan.lanes},
            "bitwise_checked": bool(check_bitwise)}


def run_serving_chaos(requests: int = 10000, k: int = 32,
                      shed: int = 16, *, seed: int = 0,
                      bucket: int = 256, tf: float = 0.25) -> dict:
    """Serving containment: a >= ``requests``-request run with ``k``
    lane faults, ``shed`` expired deadlines, and one injected
    executable failure — zero hung Futures, failures exactly typed.

    Asserts:

    * every Future resolves (no hangs, no garbage);
    * the ``shed`` deadlined requests fail with ``DeadlineExceeded``
      (shed at flush, before compute);
    * the ``k`` faulted requests fail with ``SolverError`` carrying a
      known retcode and the lane's stats slice;
    * everyone else succeeds, and the fallback bundle's Solutions are
      flagged ``degraded``;
    * ``metrics()`` / ``metrics_prometheus()`` reconcile the failure
      and degraded counters against the observed Futures.
    """
    from repro.serve.solver import ProblemFamily, SolverServer
    from repro.serve.solver.server import DeadlineExceeded, SolverError
    tag = f"[serving seed={seed} requests={requests} k={k} shed={shed}]"
    if k + shed > requests:
        raise ValueError("k + shed must not exceed requests")

    fam = chaotic_robertson_family()
    srv = SolverServer(
        [ProblemFamily("chaos_rob", 3, fam[0], fam[1], fam[2], fam[3])],
        bucket_sizes=(bucket,), max_batch=bucket, max_wait=1e-3,
        max_depth=2 * bucket)
    rng = random.Random(seed)
    marked = rng.sample(range(requests), k + shed)
    faulted, deadlined = set(marked[:k]), set(marked[k:])

    def params(i):
        return {"k1": 0.04, "k2": 1.2e4, "k3": 3e7,
                "t_fault": (rng.uniform(0.3, 0.7) * tf
                            if i in faulted else math.inf)}

    futs = []
    try:
        for i in range(requests):
            futs.append(srv.submit(
                "chaos_rob", [1.0, 0.0, 0.0], 0.0, tf,
                params=params(i),
                deadline=1e-9 if i in deadlined else None))
            if len(futs) % bucket == 0:
                srv.drain()
        srv.drain()
        # one extra healthy bundle through an injected executable
        # failure: primary raises, the jnp-oracle fallback serves it
        with failing_executions(srv, k=1) as box:
            fallback_futs = [
                srv.submit("chaos_rob", [1.0, 0.0, 0.0], 0.0, tf,
                           params=params(-1))
                for _ in range(4)]
            srv.drain()
        futs.extend(fallback_futs)
    finally:
        srv.stop()

    hung = [i for i, fut in enumerate(futs) if not fut.done()]
    assert not hung, f"{tag} {len(hung)} hung futures: {hung[:16]}"
    got_deadline, got_retcode, got_ok, degraded_ok = set(), set(), 0, 0
    for i, fut in enumerate(futs):
        exc = fut.exception()
        if exc is None:
            sol = fut.result()
            assert bool(np.asarray(sol.ok).all()), (
                f"{tag} request {i} resolved with ok=False")
            got_ok += 1
            degraded_ok += bool(sol.degraded)
        elif isinstance(exc, DeadlineExceeded):
            got_deadline.add(i)
        elif isinstance(exc, SolverError):
            assert exc.retcode in status.RETCODE_NAMES and \
                exc.retcode != status.SUCCESS, (
                    f"{tag} request {i} failed with untyped retcode "
                    f"{exc.retcode}")
            assert exc.stats is not None, (
                f"{tag} request {i} SolverError carries no lane stats")
            got_retcode.add(i)
        else:                               # pragma: no cover
            raise AssertionError(
                f"{tag} request {i} failed with non-solver exception "
                f"{type(exc).__name__}: {exc}")
    assert got_deadline == deadlined, (
        f"{tag} deadline sheds {sorted(got_deadline)[:8]}... != planned")
    assert got_retcode == faulted, (
        f"{tag} retcode failures != planned faults: "
        f"extra={sorted(got_retcode - faulted)[:8]} "
        f"missing={sorted(faulted - got_retcode)[:8]}")
    assert got_ok == requests - k - shed + len(fallback_futs)
    assert degraded_ok == len(fallback_futs), (
        f"{tag} fallback bundle not flagged degraded")
    assert box["raised"] == 1

    m = srv.metrics()
    assert m["failures"].get("deadline", 0) == shed, (
        f"{tag} metrics deadline count {m['failures']} != {shed}")
    retcode_failures = sum(v for r, v in m["failures"].items()
                           if r not in ("deadline", "exec_error"))
    assert retcode_failures == k, (
        f"{tag} metrics retcode failures {m['failures']} != {k}")
    assert m["degraded"] == 1
    prom = srv.metrics_prometheus()
    assert 'repro_serve_failures_total{reason="deadline"}' in prom
    assert "repro_serve_degraded_total 1" in prom

    return {"suite": "serving", "seed": seed, "requests": len(futs),
            "failed_retcode": len(got_retcode),
            "failed_deadline": len(got_deadline),
            "succeeded": got_ok, "degraded_bundles": m["degraded"],
            "failures_by_reason": m["failures"]}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.testing.chaos",
        description="Deterministic fault-injection acceptance suite "
                    "(core quarantine containment + serving graceful "
                    "degradation).")
    ap.add_argument("--smoke", action="store_true",
                    help="run the CI-sized acceptance configuration")
    ap.add_argument("--nsys", type=int, default=4096,
                    help="ensemble width for the jnp core pass")
    ap.add_argument("--faults", type=int, default=8,
                    help="faulted lanes in the core pass")
    ap.add_argument("--requests", type=int, default=10000,
                    help="serving-pass request count")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    del args.smoke   # --smoke IS the acceptance run; flag kept for CI
    import jax
    jax.config.update("jax_enable_x64", True)

    reports = []
    try:
        print(f"[chaos] core jnp: nsys={args.nsys} k={args.faults} "
              f"seed={args.seed}", flush=True)
        reports.append(run_core_chaos(args.nsys, args.faults,
                                      seed=args.seed))
        print("[chaos] core jnp (divergent mode): nsys=64 k=4",
              flush=True)
        reports.append(run_core_chaos(64, 4, seed=args.seed + 1,
                                      mode="divergent"))
        from repro.core.policies import ExecPolicy
        print("[chaos] core pallas-interpret: nsys=64 k=3", flush=True)
        reports.append(run_core_chaos(
            64, 3, seed=args.seed + 2,
            policy=ExecPolicy(backend="pallas", interpret=True,
                              batch_tile=64),
            check_bitwise=False))
        print(f"[chaos] serving: requests={args.requests} k=32 shed=16 "
              f"seed={args.seed}", flush=True)
        reports.append(run_serving_chaos(args.requests, 32, 16,
                                         seed=args.seed))
    except AssertionError as exc:
        print(f"[chaos] FAIL: {exc}", file=sys.stderr)
        return 1
    print(json.dumps({"ok": True, "reports": reports}, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
