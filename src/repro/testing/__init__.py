"""Deterministic fault-injection (chaos) tooling for the
fault-containment contract: :mod:`repro.testing.chaos`."""
