"""repro.observability — SUNLogger/SUNProfiler analogs for the JAX
SUNDIALS repro: region profiling, structured event logging, in-loop
step telemetry, and a Prometheus metrics surface.

Everything is opt-in through :class:`ObservabilityConfig` on
``Context``; the disabled path is contractually free (jaxpr-identical
hot loops, checked by sunlint's ``telemetry-purity`` rule).
"""
from .config import ObservabilityConfig
from .logger import LEVELS, EventLogger
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      context_metrics)
from .profiler import Profiler, Span
from .telemetry import (RECORD_FIELDS, StepTelemetry, TelemetryRing,
                        ring_init, ring_record)

__all__ = [
    "ObservabilityConfig",
    "EventLogger", "LEVELS",
    "Profiler", "Span",
    "TelemetryRing", "ring_init", "ring_record", "StepTelemetry",
    "RECORD_FIELDS",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "context_metrics",
]
