"""Prometheus-style metrics registry for the serving tier and Context.

A tiny, dependency-free implementation of the three Prometheus metric
kinds the serving surface needs — counters, gauges, histograms — with
label support and text-format exposition (`# HELP` / `# TYPE` lines,
``_total`` counter naming, cumulative ``_bucket{le=...}`` histogram
rows).  ``SolverServer.metrics_prometheus()`` renders through one of
these, and :func:`context_metrics` folds ``Context.counters`` /
``dispatch_report()`` into the same registry so the solver-core and
serving numbers share one scrape.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _labelkey(labels: Optional[dict]) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: LabelKey, extra: Optional[List[Tuple[str, str]]] = None
                ) -> str:
    pairs = list(key) + list(extra or [])
    if not pairs:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in pairs)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


class Counter:
    """Monotone counter; exposed as ``<name>_total``."""

    kind = "counter"

    def __init__(self, name: str, help: str):
        self.name = name
        self.help = help
        self._values: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        key = _labelkey(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def set_cumulative(self, value: float, **labels) -> None:
        """Set the running total directly (for counters whose source of
        truth lives elsewhere, e.g. ``Context.counters``)."""
        self._values[_labelkey(labels)] = float(value)

    def value(self, **labels) -> float:
        return self._values.get(_labelkey(labels), 0.0)

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name}_total {self.help}",
                 f"# TYPE {self.name}_total counter"]
        for key in sorted(self._values):
            lines.append(f"{self.name}_total{_fmt_labels(key)} "
                         f"{_fmt_value(self._values[key])}")
        return lines


class Gauge:
    """Point-in-time value."""

    kind = "gauge"

    def __init__(self, name: str, help: str):
        self.name = name
        self.help = help
        self._values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels) -> None:
        self._values[_labelkey(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _labelkey(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._values.get(_labelkey(labels), 0.0)

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} gauge"]
        for key in sorted(self._values):
            lines.append(f"{self.name}{_fmt_labels(key)} "
                         f"{_fmt_value(self._values[key])}")
        return lines


class Histogram:
    """Fixed-bucket histogram with Prometheus cumulative exposition."""

    kind = "histogram"

    def __init__(self, name: str, help: str,
                 buckets: Sequence[float]):
        self.name = name
        self.help = help
        ub = sorted(float(b) for b in buckets)
        if not ub:
            raise ValueError("histogram needs at least one bucket")
        self.uppers = ub + [math.inf]
        self._counts: Dict[LabelKey, List[int]] = {}
        self._sum: Dict[LabelKey, float] = {}
        self._n: Dict[LabelKey, int] = {}

    def observe(self, value: float, **labels) -> None:
        key = _labelkey(labels)
        counts = self._counts.setdefault(key, [0] * len(self.uppers))
        for i, ub in enumerate(self.uppers):
            if value <= ub:
                counts[i] += 1
                break
        self._sum[key] = self._sum.get(key, 0.0) + float(value)
        self._n[key] = self._n.get(key, 0) + 1

    def set_counts(self, bucket_counts: Sequence[int], total_sum: float,
                   total_n: int, **labels) -> None:
        """Load pre-aggregated (non-cumulative) per-bucket counts, e.g.
        from the server's latency ring."""
        key = _labelkey(labels)
        counts = list(int(c) for c in bucket_counts)
        if len(counts) != len(self.uppers):
            raise ValueError(
                f"expected {len(self.uppers)} bucket counts "
                f"(incl. +Inf), got {len(counts)}")
        self._counts[key] = counts
        self._sum[key] = float(total_sum)
        self._n[key] = int(total_n)

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} histogram"]
        for key in sorted(self._counts):
            cum = 0
            for ub, c in zip(self.uppers, self._counts[key]):
                cum += c
                le = _fmt_value(ub)
                lines.append(
                    f"{self.name}_bucket"
                    f"{_fmt_labels(key, [('le', le)])} {cum}")
            lines.append(f"{self.name}_sum{_fmt_labels(key)} "
                         f"{_fmt_value(self._sum.get(key, 0.0))}")
            lines.append(f"{self.name}_count{_fmt_labels(key)} "
                         f"{self._n.get(key, 0)}")
        return lines


class MetricsRegistry:
    """Named metric store + text-format renderer.

    Re-registering an existing name returns the existing metric (so
    exporters can be written idempotently); a kind clash raises.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, kind: str, factory):
        m = self._metrics.get(name)
        if m is not None:
            if m.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}")
            return m
        m = factory()
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, "counter", lambda: Counter(name, help))

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, "gauge", lambda: Gauge(name, help))

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = (0.005, 0.05, 0.5, 5.0)
                  ) -> Histogram:
        return self._get(name, "histogram",
                         lambda: Histogram(name, help, buckets))

    def render(self) -> str:
        """The full Prometheus text exposition (``text/plain``)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].render())
        return "\n".join(lines) + "\n"


def context_metrics(reg: MetricsRegistry, ctx) -> None:
    """Export ``Context.counters`` and the dispatch/autotune report into
    ``reg`` under the ``repro_context_*`` / ``repro_autotune_*``
    namespaces (called by ``SolverServer.metrics_prometheus()`` and
    usable standalone)."""
    for k, v in ctx.counters.items():
        c = reg.counter(f"repro_context_{k}",
                        f"Context lifetime counter: {k}")
        c.set_cumulative(float(v))
    tc = getattr(ctx, "trace_cache", None)
    if tc is not None:
        stats = tc.stats() if callable(getattr(tc, "stats", None)) else {}
        for k in ("hits", "misses", "evictions"):
            if k in stats:
                reg.counter(f"repro_trace_cache_{k}",
                            f"Context trace-cache {k}"
                            ).set_cumulative(float(stats[k]))
        if "size" in stats:
            reg.gauge("repro_trace_cache_size",
                      "Context trace-cache entries").set(float(stats["size"]))
        if "hit_rate" in stats and stats["hit_rate"] is not None:
            reg.gauge("repro_trace_cache_hit_rate",
                      "Context trace-cache hit rate"
                      ).set(float(stats["hit_rate"]))
    try:
        rep = ctx.dispatch_report()
    except Exception:
        rep = None
    if rep:
        reg.gauge("repro_autotune_cache_entries",
                  "Persisted autotune cache entries"
                  ).set(float(rep.get("cache_entries", 0)))
        reg.counter("repro_autotune_decisions",
                    "Autotune dispatch decisions made"
                    ).set_cumulative(float(len(rep.get("decisions", []))))
        agree = rep.get("model_agreement")
        if agree is not None:
            reg.gauge("repro_autotune_model_agreement",
                      "Cost-model vs measured dispatch agreement"
                      ).set(float(agree))
