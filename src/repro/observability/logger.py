"""SUNLogger analog: leveled, structured, JSON-lines event logging.

SUNDIALS' SUNLogger routes leveled messages (error/warning/info/debug)
to per-level files in a greppable ``key = value`` format.  The analog
here emits one JSON object per event — machine-parseable lines carrying
arbitrary structured fields — to an optional file/stream sink, and
always into a bounded in-memory deque (what tests and the serving
metrics inspect).

This is the *host-side* channel: integrator step data never flows
through here from inside a jitted loop (no ``io_callback``) — in-loop
step telemetry is the pure ring-buffer carry in
:mod:`repro.observability.telemetry`, and host code logs around the
loop, not inside it.

A disabled logger (``level=None``) drops every event after a single
threshold check.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import IO, Callable, Optional

#: SUNLogger's four levels, ranked; an event is kept when its level
#: ranks at or above the configured threshold.
LEVELS = {"DEBUG": 10, "INFO": 20, "WARNING": 30, "ERROR": 40}


class EventLogger:
    """Leveled structured event log (JSON lines + in-memory deque)."""

    def __init__(self, level: Optional[str] = None,
                 path: Optional[str] = None,
                 stream: Optional[IO] = None,
                 clock: Callable[[], float] = time.time,
                 keep: int = 10_000):
        if level is not None and level.upper() not in LEVELS:
            raise ValueError(f"unknown log level {level!r}; "
                             f"levels: {sorted(LEVELS)}")
        self.threshold = None if level is None else LEVELS[level.upper()]
        self.clock = clock
        self.events: deque = deque(maxlen=keep)
        self._own_fh = None
        if path is not None:
            self._own_fh = open(path, "a")
            self._fh = self._own_fh
        else:
            self._fh = stream

    @property
    def enabled(self) -> bool:
        return self.threshold is not None

    def enabled_for(self, level: str) -> bool:
        return (self.threshold is not None
                and LEVELS[level] >= self.threshold)

    def log(self, level: str, event: str, **fields) -> None:
        """Record one structured event (dropped below the threshold)."""
        if self.threshold is None or LEVELS[level] < self.threshold:
            return
        rec = {"ts": round(self.clock(), 6), "level": level,
               "event": event, **fields}
        self.events.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec, default=str) + "\n")
            self._fh.flush()

    def error(self, event: str, **fields) -> None:
        self.log("ERROR", event, **fields)

    def warning(self, event: str, **fields) -> None:
        self.log("WARNING", event, **fields)

    def info(self, event: str, **fields) -> None:
        self.log("INFO", event, **fields)

    def debug(self, event: str, **fields) -> None:
        self.log("DEBUG", event, **fields)

    def close(self) -> None:
        if self._own_fh is not None:
            self._own_fh.close()
            self._own_fh = None
            self._fh = None
