"""In-loop step telemetry: a bounded ring buffer threaded through the
adaptive step-loop carries — pure, jittable, shardable.

SUNLogger's informational channel records what CVODE's adaptive loop
actually *did* — step sizes taken, orders used, Newton behavior — which
is exactly what a jitted ``lax.while_loop`` normally discards.  The
pure-functional version: the loop carry gains a :class:`TelemetryRing`
(fixed-capacity per-field buffers + one monotone write index) and every
step attempt appends one record with ``.at[idx % K].set(...)``.  No
``io_callback``, no host round-trip — the trace stays pure, donation
stays legal (all ring leaves are fresh buffers), and the sharded path
shards the ring alongside the rest of the carry.

One record per *step attempt*, per system::

    (t, h, q, newton_iters, err_ratio, lsetup_fired, converged,
     accepted, active)

where ``t``/``h`` are the attempt's target time and step size, ``q``
the BDF order (the method order for DIRK), ``err_ratio`` the weighted
local-error ratio the accept test compared against 1, and the flags
record the lsetup trigger, Newton convergence, the accept decision, and
whether the system was active at all (finished systems are masked
no-ops and record ``active=False``).

The host-side wrapper :class:`StepTelemetry` (what lands in
``Solution.telemetry``) reorders the ring chronologically, applies the
padded-bundle ``live`` mask, and reconciles exactly with the Solution
aggregates while ``records <= capacity``: ``accepted`` sums to
``stats.steps``, ``newton_iters`` sums to ``stats.nni``,
``lsetup_fired`` sums to ``stats.nsetups`` (tested in
``tests/test_observability.py``).

This module must stay import-light (no ``repro.core`` imports): the
integrators lazy-import it only on the telemetry-enabled path, which is
how the disabled path keeps a byte-identical trace.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp

#: record field order, as passed to :func:`ring_record`
RECORD_FIELDS = ("t", "h", "q", "nni", "err", "lsetup", "conv",
                 "accept", "active")


class TelemetryRing(NamedTuple):
    """The in-carry ring: ``idx`` counts records ever written; each
    field buffer is ``(capacity,) + tail`` where ``tail`` is ``()`` for
    scalar integrators and ``(nsys,)`` for ensembles."""

    idx: jnp.ndarray        # () int32, monotone
    t: jnp.ndarray          # attempt target time
    h: jnp.ndarray          # attempted step size
    q: jnp.ndarray          # int32 order
    nni: jnp.ndarray        # int32 Newton iterations this attempt
    err: jnp.ndarray        # weighted local-error ratio
    lsetup: jnp.ndarray     # bool: lsetup trigger fired
    conv: jnp.ndarray       # bool: Newton converged
    accept: jnp.ndarray     # bool: step accepted
    active: jnp.ndarray     # bool: system still integrating

    @property
    def capacity(self) -> int:
        return int(self.t.shape[0])


def ring_init(capacity: int, tail_shape: Tuple[int, ...],
              dtype) -> TelemetryRing:
    """A zeroed ring; every leaf is a fresh buffer (donation-safe)."""
    K = int(capacity)
    if K < 1:
        raise ValueError(f"telemetry capacity must be >= 1; got {K}")
    shape = (K,) + tuple(tail_shape)
    return TelemetryRing(
        idx=jnp.zeros((), jnp.int32),
        t=jnp.zeros(shape, dtype), h=jnp.zeros(shape, dtype),
        q=jnp.zeros(shape, jnp.int32), nni=jnp.zeros(shape, jnp.int32),
        err=jnp.zeros(shape, dtype),
        lsetup=jnp.zeros(shape, bool), conv=jnp.zeros(shape, bool),
        accept=jnp.zeros(shape, bool), active=jnp.zeros(shape, bool))


def ring_record(ring: TelemetryRing, rec: Sequence) -> TelemetryRing:
    """Append one record (values ordered per :data:`RECORD_FIELDS`),
    overwriting the oldest slot once the ring is full."""
    t, h, q, nni, err, lsetup, conv, accept, active = rec
    slot = jnp.mod(ring.idx, jnp.int32(ring.capacity))

    def put(buf, v):
        v = jnp.broadcast_to(jnp.asarray(v, buf.dtype), buf.shape[1:])
        return buf.at[slot].set(v)

    return TelemetryRing(
        idx=ring.idx + 1,
        t=put(ring.t, t), h=put(ring.h, h), q=put(ring.q, q),
        nni=put(ring.nni, nni), err=put(ring.err, err),
        lsetup=put(ring.lsetup, lsetup), conv=put(ring.conv, conv),
        accept=put(ring.accept, accept), active=put(ring.active, active))


class StepTelemetry:
    """Host-side view of a completed integration's ring (what
    ``Solution.telemetry`` holds).

    Records are reordered chronologically; with a ``live`` mask (padded
    serving bundles) dead lanes are zeroed out of every count exactly
    like :meth:`~repro.core.batched.EnsembleStats.masked` zeroes the
    stats, so telemetry and Solution aggregates reconcile per lane.

    Per-record arrays (``t``, ``h``, ``q``, ``newton_iters``,
    ``err_ratio``, ``lsetup_fired``, ``converged``, ``accepted``,
    ``active``) have shape ``(records,)`` for scalar integrators or
    ``(records, nsys)`` for ensembles.
    """

    def __init__(self, ring: TelemetryRing, live=None):
        import numpy as np
        idx = int(ring.idx)
        K = ring.capacity
        self.capacity = K
        self.total_records = idx
        self.truncated = idx > K
        count = min(idx, K)
        self.records = count
        if self.truncated:
            # oldest surviving record lives at slot idx % K
            order = (np.arange(K) + idx % K) % K
        else:
            order = np.arange(count)
        take = lambda buf: np.asarray(buf)[order]
        self.t = take(ring.t)
        self.h = take(ring.h)
        self.q = take(ring.q)
        self.newton_iters = take(ring.nni)
        self.err_ratio = take(ring.err)
        self.lsetup_fired = take(ring.lsetup)
        self.converged = take(ring.conv)
        self.accepted = take(ring.accept)
        self.active = take(ring.active)
        self.live = None if live is None else np.asarray(live, bool)
        if self.live is not None and self.t.ndim == 2:
            dead = ~self.live[None, :]
            for name in ("newton_iters",):
                getattr(self, name)[np.broadcast_to(
                    dead, getattr(self, name).shape)] = 0
            for name in ("lsetup_fired", "accepted", "active",
                         "converged"):
                getattr(self, name)[np.broadcast_to(
                    dead, getattr(self, name).shape)] = False

    # -- reconciliation surface (axis 0 = records) -------------------------

    def steps(self):
        """Accepted steps per system (reconciles with ``stats.steps``
        while the ring was not truncated)."""
        return self.accepted.sum(axis=0)

    def attempts(self):
        return self.active.sum(axis=0)

    def newton_iters_total(self):
        return self.newton_iters.sum(axis=0)

    def lsetups(self):
        return self.lsetup_fired.sum(axis=0)

    def summary(self) -> dict:
        """The SUNLogger-style roll-up: step-size histogram (log10 h
        over accepted steps), order occupancy, and Newton-failure hot
        spots (times where active systems failed to converge)."""
        import numpy as np
        acc = self.accepted
        h_acc = self.h[acc]
        q_acc = self.q[acc]
        out = {
            "records": self.records,
            "capacity": self.capacity,
            "truncated": self.truncated,
            "steps": int(acc.sum()),
            "attempts": int(self.active.sum()),
            "newton_iters": int(self.newton_iters.sum()),
            "lsetups": int(self.lsetup_fired.sum()),
        }
        if h_acc.size:
            logh = np.log10(np.maximum(h_acc, 1e-300))
            lo, hi = float(logh.min()), float(logh.max())
            if hi - lo < 1e-12:
                hi = lo + 1e-12
            counts, edges = np.histogram(logh, bins=12, range=(lo, hi))
            out["h_hist_log10"] = {"edges": edges.tolist(),
                                   "counts": counts.tolist()}
            occ = {int(qv): int(n) for qv, n in
                   zip(*np.unique(q_acc, return_counts=True))}
            total = sum(occ.values())
            out["order_occupancy"] = {q: n / total
                                      for q, n in occ.items()}
        else:
            out["h_hist_log10"] = {"edges": [], "counts": []}
            out["order_occupancy"] = {}
        fail = self.active & ~self.converged
        out["newton_failures"] = int(fail.sum())
        if fail.any():
            t_fail = np.unique(np.round(self.t[fail], 12))
            out["newton_failure_times"] = t_fail[:16].tolist()
        else:
            out["newton_failure_times"] = []
        return out

    def __repr__(self) -> str:
        s = self.summary()
        return (f"StepTelemetry(records={s['records']}, "
                f"steps={s['steps']}, attempts={s['attempts']}, "
                f"newton_iters={s['newton_iters']}, "
                f"truncated={self.truncated})")
