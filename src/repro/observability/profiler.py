"""SUNProfiler analog: nestable, device-sync-aware host region timers.

SUNDIALS' SUNProfiler brackets named regions (``SUNDIALS_MARK_BEGIN``/
``_END``) and renders a per-region summary; on GPU builds it syncs the
device before reading the clock so asynchronously-launched work is
charged to the region that launched it.  This is the same tool for the
JAX stack:

* ``with prof.region("integrate.execute"):`` — nestable context-manager
  regions; exit optionally blocks on an enqueued device token
  (``sync=True``) so dispatched-but-unfinished XLA work lands inside
  the region that dispatched it.
* ``prof.add_span(name, t0, t1)`` — raw span injection for events timed
  on a foreign clock (the serving queue's arrival/flush timestamps are
  mapped into the profiler timebase and recorded per bundle).
* ``prof.summary()`` / ``prof.render()`` — the per-region roll-up table
  (count, total, mean, max).
* ``prof.chrome_trace()`` / ``prof.export_chrome_trace(path)`` — the
  merged host-region + serving-queue timeline as Chrome-trace JSON
  (load in ``chrome://tracing`` or https://ui.perfetto.dev).

A disabled profiler hands out one shared no-op region object and
records nothing — the off cost is a single attribute check.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional


@dataclass(frozen=True)
class Span:
    """One closed region instance on the profiler's timebase."""

    name: str
    t0: float
    t1: float
    tid: int = 0            # OS thread ident (pump thread vs caller)
    depth: int = 0          # nesting depth at entry (render indent)
    cat: str = "host"
    args: Optional[dict] = None

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class _NullRegion:
    """The disabled-profiler region: a shared, stateless no-op."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_REGION = _NullRegion()


def _device_sync() -> None:
    """Block until previously-enqueued device work has retired, by
    enqueueing a trivial op and waiting on it (the portable analog of
    ``cudaDeviceSynchronize`` SUNProfiler uses on GPU builds)."""
    try:
        import jax
        import jax.numpy as jnp
        jax.block_until_ready(jnp.zeros(()) + 0.0)
    except Exception:       # profiling must never take the run down
        pass


class _Region:
    """An active region; created per ``with`` entry (regions nest)."""

    __slots__ = ("_prof", "name", "cat", "sync", "args", "_t0", "_depth",
                 "_tid")

    def __init__(self, prof: "Profiler", name: str, cat: str, sync: bool,
                 args: Optional[dict]):
        self._prof = prof
        self.name = name
        self.cat = cat
        self.sync = sync
        self.args = args

    def __enter__(self):
        tl = self._prof._tls
        self._depth = getattr(tl, "depth", 0)
        tl.depth = self._depth + 1
        self._tid = threading.get_ident()
        self._t0 = self._prof.clock()
        return self

    def __exit__(self, *exc):
        if self.sync:
            self._prof._sync_fn()
        t1 = self._prof.clock()
        self._prof._tls.depth = self._depth
        self._prof.add_span(self.name, self._t0, t1, cat=self.cat,
                            args=self.args, tid=self._tid,
                            depth=self._depth)
        return False


class Profiler:
    """Region timers + span store (thread-safe appends; the serving
    pump thread and the caller thread interleave freely)."""

    def __init__(self, enabled: bool = True, sync: bool = True,
                 clock: Callable[[], float] = time.perf_counter,
                 sync_fn: Callable[[], None] = _device_sync):
        self.enabled = bool(enabled)
        self.sync = bool(sync)
        self.clock = clock
        self._sync_fn = sync_fn
        self._lock = threading.Lock()
        self._tls = threading.local()
        self.spans: List[Span] = []

    # -- recording ---------------------------------------------------------

    def now(self) -> float:
        """The profiler timebase (for mapping foreign clocks onto it)."""
        return self.clock()

    def region(self, name: str, cat: str = "host",
               sync: Optional[bool] = None, **args):
        """A nestable timed region; no-op when disabled."""
        if not self.enabled:
            return _NULL_REGION
        return _Region(self, name, cat,
                       self.sync if sync is None else bool(sync),
                       args or None)

    def add_span(self, name: str, t0: float, t1: float, *,
                 cat: str = "host", args: Optional[dict] = None,
                 tid: Optional[int] = None, depth: int = 0) -> None:
        """Record one closed span on the profiler timebase (used for
        events timed elsewhere, e.g. serving queue wait per bundle)."""
        if not self.enabled:
            return
        span = Span(name=name, t0=float(t0), t1=float(t1),
                    tid=tid if tid is not None else threading.get_ident(),
                    depth=depth, cat=cat, args=args)
        with self._lock:
            self.spans.append(span)

    def clear(self) -> None:
        with self._lock:
            self.spans = []

    # -- reporting ---------------------------------------------------------

    def summary(self) -> Dict[str, dict]:
        """Per-region roll-up: count / total_s / mean_s / max_s."""
        with self._lock:
            spans = list(self.spans)
        out: Dict[str, dict] = {}
        for s in spans:
            row = out.setdefault(s.name, {"count": 0, "total_s": 0.0,
                                          "max_s": 0.0})
            row["count"] += 1
            row["total_s"] += s.dur
            row["max_s"] = max(row["max_s"], s.dur)
        for row in out.values():
            row["mean_s"] = row["total_s"] / row["count"]
        return out

    def render(self) -> str:
        """The SUNProfiler-style text table, sorted by total time."""
        rows = sorted(self.summary().items(),
                      key=lambda kv: -kv[1]["total_s"])
        width = max([len(name) for name, _ in rows] + [6])
        lines = [f"{'region':<{width}}  {'count':>7} {'total_s':>10} "
                 f"{'mean_s':>10} {'max_s':>10}"]
        for name, r in rows:
            lines.append(f"{name:<{width}}  {r['count']:>7d} "
                         f"{r['total_s']:>10.6f} {r['mean_s']:>10.6f} "
                         f"{r['max_s']:>10.6f}")
        return "\n".join(lines)

    def chrome_trace(self) -> dict:
        """Chrome-trace JSON (``traceEvents`` of complete ``"X"``
        events, microsecond timestamps relative to the first span) —
        loadable in chrome://tracing or Perfetto."""
        with self._lock:
            spans = list(self.spans)
        base = min((s.t0 for s in spans), default=0.0)
        tids = {}
        events = []
        for s in spans:
            tid = tids.setdefault(s.tid, len(tids) + 1)
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X",
                "ts": (s.t0 - base) * 1e6, "dur": s.dur * 1e6,
                "pid": 1, "tid": tid, "args": dict(s.args or {})})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> str:
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh)
        return path
