"""ObservabilityConfig — the single switchboard for the SUNLogger/
SUNProfiler analogs.

Everything is OFF by default, and the disabled path is contractually
free: with the default config, ``integrate`` takes exactly the code
path it took before this subsystem existed, so the jitted hot-loop
jaxprs are *identical* to a no-observability build (statically checked
by sunlint's ``telemetry-purity`` rule) and ``benchmarks/
observability_bench.py`` gates the wall-clock ratio at <= 1.02.  The
enabled path buys step telemetry + region profiling for <= 5% on the
BENCH_ensemble configs — the paper's "negligible overhead" thesis,
applied to our own instrumentation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ObservabilityConfig:
    """Per-:class:`~repro.core.context.Context` observability switches.

    profile            : enable the SUNProfiler analog
                         (``ctx.profiler``): region timers around
                         lower/compile/execute in ``integrate`` and the
                         serving pump stages, plus Chrome-trace export.
    profile_sync       : block on an enqueued device token at region
                         exit so async device work is attributed to the
                         region that launched it (SUNProfiler's
                         device-sync semantics).  Turn off for pure
                         host-side region timing.
    telemetry          : record in-loop step telemetry (a bounded ring
                         buffer threaded through the BDF/DIRK step-loop
                         carries), surfaced as ``Solution.telemetry``.
    telemetry_capacity : ring slots per integration.  Reconciliation
                         with the Solution aggregates is exact while
                         the loop runs fewer attempts than this; older
                         records are overwritten past it (the wrapper
                         flags ``truncated``).
    log_level          : enable the SUNLogger analog (``ctx.logger``)
                         at this level ("ERROR" | "WARNING" | "INFO" |
                         "DEBUG"); None keeps it disabled.
    log_path           : optional JSON-lines sink for logger events
                         (events are always kept in a bounded
                         in-memory deque as well).
    """

    profile: bool = False
    profile_sync: bool = True
    telemetry: bool = False
    telemetry_capacity: int = 512
    log_level: Optional[str] = None
    log_path: Optional[str] = None

    @property
    def enabled(self) -> bool:
        """Any instrumentation on at all?"""
        return bool(self.profile or self.telemetry
                    or self.log_level is not None)
