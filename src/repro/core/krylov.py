"""Matrix-free Krylov linear solvers (SUNLinearSolver analogs).

SPGMR / SPFGMR / SPBCGS / SPTFQMR / PCG from SUNDIALS, written against
the vector-ops layer only — exactly the property the paper leverages:
"the existing matrix-free Krylov solvers rely only on vector
implementations ... these solvers may immediately leverage the GPU-based
vector implementations".  Here they are pure-jnp over pytrees, so they
are jit/scan/shard-compatible and immediately leverage MeshVector
sharding.

All solvers accept:
  matvec  : v -> A v              (pytree -> pytree)
  b       : right-hand side pytree
  precond : v -> M^{-1} v         (right preconditioning; identity
            default).  For pcg this is the one canonical SPD
            preconditioner slot (z = M^{-1} r).
  precond_left : v -> M_L^{-1} v  (LEFT preconditioning: the solver
            iterates on M_L^{-1} A x = M_L^{-1} b — the SUNDIALS
            PSol(..., lr=1) path the integrators' Preconditioner
            objects use; pcg maps it onto its canonical slot)
  mem     : optional MemoryHelper — when given, the solver registers its
            workspace (Krylov basis / work vectors) for the run's
            high-water audit
and return (x, SolveStats).

SolveStats convention (identical across all five solvers)
---------------------------------------------------------
* ``res_norm``  : the TRUE unpreconditioned residual 2-norm
  ``||b - A x||_2`` evaluated at the returned ``x`` (one extra matvec at
  exit) — never the solver's internal recursive/rotation estimate, so
  callers compare solvers without per-solver special cases.  (With left
  preconditioning the INNER iteration necessarily controls the
  preconditioned residual, SUNDIALS semantics; the exit report is still
  the unpreconditioned truth.)
* ``converged`` : ``res_norm <= max(tol * ||b||_2, atol)`` under that
  same true residual, for every solver.
* ``iters``     : inner iterations actually performed (not budgeted):
  Arnoldi steps for gmres/fgmres (1 matvec each), CG iterations for pcg
  (1 matvec), full BiCGStab iterations (2 matvecs), TFQMR outer
  iterations (~3 matvecs).  Early exit (breakdown, convergence
  mid-cycle) reports the true count.
* ``npsolves``  : EXACT count of preconditioner applications (left and
  right; 0 when unpreconditioned) — the SUNDIALS ``*GetNumPrecSolves``
  counter the old stats silently dropped.
* ``npsetups``  : preconditioner setups.  Always 0 here (psetup happens
  in the LinearSolver layer, which owns the lsetup triggers); the field
  exists so one stats type serves both layers.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.flatten_util import ravel_pytree

from . import dispatch as dv
from . import vector as nv
from .policies import ExecPolicy, XLA_FUSED


class SolveStats(NamedTuple):
    """Uniform solver stats — see the module docstring for the exact
    convention (true-residual ``res_norm``, shared ``converged`` test,
    actual ``iters``, exact ``npsolves``)."""

    iters: jnp.ndarray
    res_norm: jnp.ndarray
    converged: jnp.ndarray
    npsolves: jnp.ndarray = 0
    npsetups: jnp.ndarray = 0


def _identity(v):
    return v


def _left_wrap(matvec, b, precond_left):
    """Left preconditioning: return (matvec', b', n_ml_initial) so the
    caller iterates on M_L^{-1} A x = M_L^{-1} b.  The exit-time true
    residual always uses the ORIGINAL matvec and b."""
    if precond_left is None:
        return matvec, b, 0
    return (lambda v: precond_left(matvec(v))), precond_left(b), 1


# ----------------------------------------------------------------------------
# GMRES (right-preconditioned, modified Gram-Schmidt, Givens rotations)
# ----------------------------------------------------------------------------


def gmres(matvec: Callable, b, x0=None, *, tol: float = 1e-8,
          atol: float = 0.0, restart: int = 30, max_restarts: int = 10,
          precond: Optional[Callable] = None,
          precond_left: Optional[Callable] = None,
          policy: ExecPolicy = XLA_FUSED, flexible: bool = False,
          mem=None):
    """Restarted GMRES(m).  Solves A x = b with right preconditioning:
    A M^{-1} u = b, x = M^{-1} u.

    ``flexible=True`` is true FGMRES (Saad 1993 / SUNDIALS SPFGMR): the
    preconditioned basis vectors z_j = M^{-1} v_j are stored and the
    correction is formed as Z y, so ``precond`` may vary from iteration
    to iteration (an inner iterative solve, a lagged factorization, ...).
    Plain GMRES applies M once to the assembled correction instead,
    which is only equivalent when M is fixed for the whole solve.
    """
    M = precond or _identity
    mv_in, b_in, ml = _left_wrap(matvec, b, precond_left)
    mr = 1 if precond is not None else 0
    b_flat, unravel = ravel_pytree(b)
    bin_flat = ravel_pytree(b_in)[0]
    n = b_flat.shape[0]
    dtype = b_flat.dtype
    m = min(restart, n)
    if mem is not None:
        label = "spfgmr" if flexible else "spgmr"
        mem.register(f"{label}.basis",
                     (m + 1 + (m if flexible else 0), n), dtype)
        mem.register(f"{label}.hessenberg", (m + 1, m), dtype)
    # the dispatched dot is sum(x*y) (real, no conjugation — the pallas
    # kernels are real-only); keep jnp.vdot/norm for complex systems.
    is_complex = jnp.issubdtype(dtype, jnp.complexfloating)

    def _vdot(a, c):
        return jnp.vdot(a, c) if is_complex else dv.dot(a, c, policy)

    def _norm(a):
        return jnp.linalg.norm(a) if is_complex \
            else jnp.sqrt(dv.dot(a, a, policy))

    def mv_flat(v_flat):
        out = mv_in(M(unravel(v_flat)))
        return ravel_pytree(out)[0]

    x0_flat = jnp.zeros_like(b_flat) if x0 is None else ravel_pytree(x0)[0]
    bnorm = jnp.linalg.norm(b_flat)
    target = jnp.maximum(tol * bnorm, atol)
    # left preconditioning: the inner iteration controls the
    # PRECONDITIONED residual (SUNDIALS semantics); exit reporting below
    # stays on the unpreconditioned truth.
    target_in = jnp.maximum(tol * jnp.linalg.norm(bin_flat), atol)

    def cycle(carry):
        x, _, restarts, _, iters = carry
        # x lives in solution space: (inner) residual is M_L^{-1}(b - A x)
        r = bin_flat - ravel_pytree(mv_in(unravel(x)))[0]
        beta = _norm(r)
        # Arnoldi with MGS + Givens
        V = jnp.zeros((m + 1, n), dtype=dtype)
        V = V.at[0].set(jnp.where(beta > 0, r / jnp.where(beta > 0, beta, 1.0), r))
        # FGMRES keeps the preconditioned basis Z[j] = M^{-1} V[j]
        Z = jnp.zeros((m if flexible else 0, n), dtype=dtype)
        H = jnp.zeros((m + 1, m), dtype=dtype)
        cs = jnp.zeros((m,), dtype=dtype)
        sn = jnp.zeros((m,), dtype=dtype)
        g = jnp.zeros((m + 1,), dtype=dtype).at[0].set(beta)

        def arnoldi_step(j, st):
            V, Z, H, cs, sn, g, done = st
            if flexible:
                zj = ravel_pytree(M(unravel(V[j])))[0]
                Z = Z.at[j].set(zj)
                w = ravel_pytree(mv_in(unravel(zj)))[0]
            else:
                w = mv_flat(V[j])
            # modified Gram-Schmidt against all basis vectors (masked > j)
            def mgs(i, wh):
                w, hcol = wh
                hij = jnp.where(i <= j, _vdot(V[i], w), 0.0)
                w = w - hij * V[i]
                return w, hcol.at[i].set(hij)

            w, hcol = lax.fori_loop(0, m + 1, mgs, (w, jnp.zeros((m + 1,), dtype)))
            hj1 = _norm(w)
            hcol = hcol.at[j + 1].set(hj1)
            V = V.at[j + 1].set(jnp.where(hj1 > 0, w / jnp.where(hj1 > 0, hj1, 1.0), w))

            # apply previous Givens rotations to the new column
            def rot(i, hc):
                t = cs[i] * hc[i] + sn[i] * hc[i + 1]
                hc = hc.at[i + 1].set(-sn[i] * hc[i] + cs[i] * hc[i + 1])
                return hc.at[i].set(t)

            hcol = lax.fori_loop(0, j, rot, hcol)
            # new rotation to zero hcol[j+1]
            denom = jnp.sqrt(hcol[j] ** 2 + hcol[j + 1] ** 2)
            c = jnp.where(denom > 0, hcol[j] / jnp.where(denom > 0, denom, 1.0), 1.0)
            s = jnp.where(denom > 0, hcol[j + 1] / jnp.where(denom > 0, denom, 1.0), 0.0)
            cs = cs.at[j].set(c)
            sn = sn.at[j].set(s)
            hcol = hcol.at[j].set(denom).at[j + 1].set(0.0)
            H = H.at[:, j].set(hcol)
            gj = g[j]
            g = g.at[j].set(c * gj).at[j + 1].set(-s * gj)
            done = done | (jnp.abs(g[j + 1]) <= target_in) | (hj1 == 0.0)
            return V, Z, H, cs, sn, g, done

        def arnoldi_cond_body(j, st):
            # run step only while not done (frozen updates otherwise);
            # nit counts the Arnoldi steps actually taken, so early exit
            # (lucky breakdown / converged mid-cycle) reports the true
            # iteration count instead of restarts * m.
            core, nit = st[:7], st[7]
            done = core[6]
            new_core = arnoldi_step(j, core)
            merged = jax.tree_util.tree_map(
                lambda a, b: jnp.where(done, a, b), core, new_core)
            return merged + (nit + (~done).astype(jnp.int32),)

        V, Z, H, cs, sn, g, done, nit = lax.fori_loop(
            0, m, arnoldi_cond_body,
            (V, Z, H, cs, sn, g, jnp.zeros((), bool),
             jnp.zeros((), jnp.int32)))

        # back substitution on the m x m triangular system (padded cols have
        # H[j,j]=0 and g[j]=0 for inactive; guard the division)
        y = jnp.zeros((m,), dtype)

        def backsub(idx, y):
            j = m - 1 - idx
            s = g[j] - jnp.dot(H[j, :], y)
            yj = jnp.where(H[j, j] != 0, s / jnp.where(H[j, j] != 0, H[j, j], 1.0), 0.0)
            return y.at[j].set(yj)

        y = lax.fori_loop(0, m, backsub, y)
        if flexible:
            x_new = x + Z.T @ y
        else:
            dx_u = V[:m].T @ y
            x_new = x + ravel_pytree(M(unravel(dx_u)))[0]
        res = jnp.abs(g[m])  # estimate; exact residual recomputed in cond
        return x_new, res, restarts + 1, res <= target_in, iters + nit

    def cond(carry):
        x, res, restarts, conv, iters = carry
        return (~conv) & (restarts < max_restarts)

    x = x0_flat
    r0 = bin_flat - ravel_pytree(mv_in(unravel(x)))[0]
    carry = (x, jnp.linalg.norm(r0), jnp.zeros((), jnp.int32),
             jnp.linalg.norm(r0) <= target_in, jnp.zeros((), jnp.int32))
    x, res, restarts, conv, iters = lax.while_loop(cond, cycle, carry)
    # uniform SolveStats convention: report the TRUE residual at exit
    # (the in-loop `res` is the Givens-rotation estimate).  Callers that
    # discard the stats (e.g. the integrators' Newton loops, which run
    # traced) pay nothing: the matvec is dead code and XLA eliminates it.
    rn = jnp.linalg.norm(b_flat - ravel_pytree(matvec(unravel(x)))[0])
    # exact psolve count: (ml + mr) per Arnoldi step, ml per cycle
    # (initial residual) plus — non-flexible only — mr per cycle (final
    # correction), plus 2*ml pre-loop (M_L b and the initial residual).
    nps = iters * (ml + mr) + \
        restarts * (ml + (0 if flexible else mr)) + 2 * ml
    return unravel(x), SolveStats(iters=iters, res_norm=rn,
                                  converged=rn <= target,
                                  npsolves=nps)


# ----------------------------------------------------------------------------
# Conjugate Gradient (PCG)
# ----------------------------------------------------------------------------


def pcg(matvec: Callable, b, x0=None, *, tol: float = 1e-8, atol: float = 0.0,
        maxiter: int = 200, precond: Optional[Callable] = None,
        precond_left: Optional[Callable] = None,
        policy: ExecPolicy = XLA_FUSED, mem=None):
    """Preconditioned CG for SPD systems.

    CG has ONE canonical (SPD) preconditioner slot, ``z = M^{-1} r``;
    ``precond_left`` is accepted for interface uniformity and maps onto
    that same slot.  ``precond=None`` is plain CG: the identity is
    substituted inline — bit-identical iterates to an explicit identity
    ``precond`` — and ``npsolves`` stays 0 (identity applications are
    not preconditioner work).
    """
    if precond is None and precond_left is not None:
        precond = precond_left
    mp = 1 if precond is not None else 0
    M = precond or _identity
    if mem is not None:
        mem.register("pcg.work", (4, nv.tree_size(b)),
                     jnp.result_type(*jax.tree_util.tree_leaves(b)))
    x = x0 if x0 is not None else nv.const_like(0.0, b)
    r = dv.linear_sum(1.0, b, -1.0, matvec(x), policy)
    z = M(r)
    p = z
    rz = dv.dot(r, z, policy)
    bnorm = jnp.sqrt(dv.dot(b, b, policy))
    target = jnp.maximum(tol * bnorm, atol)

    def cond(c):
        x, r, z, p, rz, it = c
        return (jnp.sqrt(dv.dot(r, r, policy)) > target) & (it < maxiter)

    def body(c):
        x, r, z, p, rz, it = c
        Ap = matvec(p)
        alpha = rz / dv.dot(p, Ap, policy)
        x = dv.axpy(alpha, p, x, policy)
        r = dv.axpy(-alpha, Ap, r, policy)
        z = M(r)
        rz_new = dv.dot(r, z, policy)
        beta = rz_new / rz
        p = dv.linear_sum(1.0, z, beta, p, policy)
        return x, r, z, p, rz_new, it + 1

    x, r, z, p, rz, it = lax.while_loop(cond, body, (x, r, z, p, rz,
                                                     jnp.zeros((), jnp.int32)))
    # uniform convention: true residual at exit, not the recursive one
    rt = dv.linear_sum(1.0, b, -1.0, matvec(x), policy)
    rn = jnp.sqrt(dv.dot(rt, rt, policy))
    # exact psolve count: one z = M r before the loop, one per iteration
    return x, SolveStats(iters=it, res_norm=rn, converged=rn <= target,
                         npsolves=(it + 1) * mp)


# ----------------------------------------------------------------------------
# BiCGStab
# ----------------------------------------------------------------------------


def bicgstab(matvec: Callable, b, x0=None, *, tol: float = 1e-8,
             atol: float = 0.0, maxiter: int = 200,
             precond: Optional[Callable] = None,
             precond_left: Optional[Callable] = None,
             policy: ExecPolicy = XLA_FUSED, mem=None):
    M = precond or _identity
    mr = 1 if precond is not None else 0
    mv_in, b_in, ml = _left_wrap(matvec, b, precond_left)
    if mem is not None:
        mem.register("spbcgs.work", (8, nv.tree_size(b)),
                     jnp.result_type(*jax.tree_util.tree_leaves(b)))
    x = x0 if x0 is not None else nv.const_like(0.0, b)
    r = dv.linear_sum(1.0, b_in, -1.0, mv_in(x), policy)
    rhat = r
    rho = dv.dot(rhat, r, policy)
    p = r
    bnorm = jnp.sqrt(dv.dot(b, b, policy))
    target = jnp.maximum(tol * bnorm, atol)
    # inner loop controls the (left-)preconditioned residual
    target_in = jnp.maximum(tol * jnp.sqrt(dv.dot(b_in, b_in, policy)),
                            atol)

    def cond(c):
        x, r, p, rho, it, brk = c
        return (jnp.sqrt(dv.dot(r, r, policy)) > target_in) & \
            (it < maxiter) & (~brk)

    def body(c):
        x, r, p, rho, it, brk = c
        ph = M(p)
        v = mv_in(ph)
        denom = dv.dot(rhat, v, policy)
        alpha = rho / jnp.where(denom != 0, denom, 1.0)
        s = dv.axpy(-alpha, v, r, policy)
        sh = M(s)
        t = mv_in(sh)
        tt = dv.dot(t, t, policy)
        omega = dv.dot(t, s, policy) / jnp.where(tt != 0, tt, 1.0)
        x_new = dv.linear_combination([1.0, alpha, omega], [x, ph, sh],
                                      policy)
        r_new = dv.axpy(-omega, t, s, policy)
        rho_new = dv.dot(rhat, r_new, policy)
        beta = (rho_new / jnp.where(rho != 0, rho, 1.0)) * \
               (alpha / jnp.where(omega != 0, omega, 1.0))
        p_new = dv.linear_combination([1.0, beta, -beta * omega],
                                      [r_new, p, v], policy)
        # Breakdowns must not poison the carry this iteration (the old
        # code computed brk here but only the *next* cond saw it, so a
        # garbage alpha/omega update was still committed):
        #  * denom = <rhat, v> = 0: alpha is garbage -> freeze everything;
        #  * tt = <t, t> = 0: t = A M s = 0, i.e. s = 0 in the regular
        #    case ("lucky" breakdown after the BiCG half-step): commit
        #    the half-update x + alpha p_hat, whose residual is s.
        brk_denom = (denom == 0)
        brk_tt = (~brk_denom) & (tt == 0)
        brk = brk_denom | brk_tt
        x_half = dv.axpy(alpha, ph, x, policy)
        sel = lambda full, half, old: jax.tree_util.tree_map(
            lambda fu, ha, ol: jnp.where(
                brk_denom, ol, jnp.where(brk_tt, ha, fu)), full, half, old)
        keep = lambda new, old: jax.tree_util.tree_map(
            lambda a, b: jnp.where(brk, b, a), new, old)
        return (sel(x_new, x_half, x), sel(r_new, s, r), keep(p_new, p),
                jnp.where(brk, rho, rho_new), it + 1, brk)

    x, r, p, rho, it, brk = lax.while_loop(
        cond, body, (x, r, p, rho, jnp.zeros((), jnp.int32),
                     jnp.zeros((), bool)))
    # uniform convention: true residual at exit, not the recursive one
    rt = dv.linear_sum(1.0, b, -1.0, matvec(x), policy)
    rn = jnp.sqrt(dv.dot(rt, rt, policy))
    # exact psolve count: 2 right (ph, sh) + 2 left (inside each of the
    # two matvecs) per iteration, plus 2*ml pre-loop (M_L b + residual)
    return x, SolveStats(iters=it, res_norm=rn, converged=rn <= target,
                         npsolves=it * 2 * (mr + ml) + 2 * ml)


# ----------------------------------------------------------------------------
# TFQMR (transpose-free QMR)
# ----------------------------------------------------------------------------


def tfqmr(matvec: Callable, b, x0=None, *, tol: float = 1e-8,
          atol: float = 0.0, maxiter: int = 200,
          precond: Optional[Callable] = None,
          precond_left: Optional[Callable] = None,
          policy: ExecPolicy = XLA_FUSED, mem=None):
    M = precond or _identity
    mr = 1 if precond is not None else 0
    mv_in, b_in, ml = _left_wrap(matvec, b, precond_left)
    if mem is not None:
        mem.register("sptfqmr.work", (7, nv.tree_size(b)),
                     jnp.result_type(*jax.tree_util.tree_leaves(b)))

    def amv(v):
        return mv_in(M(v))

    u = x0 if x0 is not None else nv.const_like(0.0, b)
    r0 = dv.linear_sum(1.0, b_in, -1.0, mv_in(u), policy)
    w = r0
    y = r0
    v = amv(y)
    d = nv.const_like(0.0, b)
    tau = jnp.sqrt(dv.dot(r0, r0, policy))
    # carry scalars must match the input dtype: a bare zeros(()) follows
    # the x64 default, so under jax_enable_x64 an f32 system gets an f64
    # init while the body produces f32 — the while_loop carry dtypes
    # mismatch and the solve fails to trace.
    theta = jnp.zeros((), dtype=tau.dtype)
    eta = jnp.zeros((), dtype=tau.dtype)
    rho = dv.dot(r0, r0, policy)
    bnorm = jnp.sqrt(dv.dot(b, b, policy))
    target = jnp.maximum(tol * bnorm, atol)
    # tau tracks the (left-)preconditioned residual estimate
    target_in = jnp.maximum(tol * jnp.sqrt(dv.dot(b_in, b_in, policy)),
                            atol)

    def cond(c):
        (u, w, y, v, d, tau, theta, eta, rho, it, brk) = c
        return (tau > target_in) & (it < maxiter) & (~brk)

    def body(c):
        (u, w, y, v, d, tau, theta, eta, rho, it, brk) = c
        sigma = dv.dot(r0, v, policy)
        alpha = rho / jnp.where(sigma != 0, sigma, 1.0)
        # two half-iterations
        y2 = dv.axpy(-alpha, v, y, policy)

        def half(carry, ym):
            u, w, d, tau, theta, eta = carry
            w = dv.axpy(-alpha, amv(ym), w, policy)
            d = dv.linear_sum(1.0, ym, (theta ** 2) * eta / jnp.where(alpha != 0, alpha, 1.0), d, policy)
            theta_n = jnp.sqrt(dv.dot(w, w, policy)) / jnp.where(tau != 0, tau, 1.0)
            cfac = 1.0 / jnp.sqrt(1.0 + theta_n ** 2)
            tau_n = tau * theta_n * cfac
            eta_n = (cfac ** 2) * alpha
            u = dv.axpy(eta_n, d, u, policy)
            return (u, w, d, tau_n, theta_n, eta_n)

        st = (u, w, d, tau, theta, eta)
        st = half(st, y)
        st = half(st, y2)
        u, w, d, tau, theta, eta = st
        rho_new = dv.dot(r0, w, policy)
        beta = rho_new / jnp.where(rho != 0, rho, 1.0)
        y = dv.axpy(beta, y2, w, policy)
        # v = A y_new + beta (A y2 + beta v)   (Freund's transpose-free QMR)
        v = dv.linear_sum(1.0, amv(y), beta,
                          dv.linear_sum(1.0, amv(y2), beta, v, policy),
                          policy)
        brk = (sigma == 0) | (rho == 0)
        return (u, w, y, v, d, tau, theta, eta, rho_new, it + 1, brk)

    c0 = (u, w, y, v, d, tau, theta, eta, rho, jnp.zeros((), jnp.int32),
          jnp.zeros((), bool))
    (u, w, y, v, d, tau, theta, eta, rho, it, brk) = lax.while_loop(cond, body, c0)
    x = M(u) if precond is not None else u
    r = dv.linear_sum(1.0, b, -1.0, matvec(x), policy)
    rn = jnp.sqrt(dv.dot(r, r, policy))
    # exact psolve count — right: 4 amv per iteration + the initial
    # v = amv(y) + the final x = M u; left: those same amv calls plus
    # M_L b and the initial residual's matvec.
    nps = it * 4 * (mr + ml) + mr * 2 + ml * 3
    return x, SolveStats(iters=it, res_norm=rn, converged=rn <= target,
                         npsolves=nps)


def fgmres(matvec: Callable, b, x0=None, *, tol: float = 1e-8,
           atol: float = 0.0, restart: int = 30, max_restarts: int = 10,
           precond: Optional[Callable] = None,
           precond_left: Optional[Callable] = None,
           policy: ExecPolicy = XLA_FUSED, mem=None):
    """Flexible GMRES (SUNDIALS SPFGMR): stores the preconditioned basis
    Z[j] = M^{-1} v_j and assembles the correction as Z y, so the
    preconditioner may change between iterations — unlike plain
    :func:`gmres`, which applies a (necessarily fixed) M once to the
    assembled correction."""
    return gmres(matvec, b, x0, tol=tol, atol=atol, restart=restart,
                 max_restarts=max_restarts, precond=precond,
                 precond_left=precond_left, policy=policy,
                 flexible=True, mem=mem)
