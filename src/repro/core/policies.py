"""Execution policies — the *ExecPolicy analog (paper §4.1).

SUNDIALS lets users swap kernel-launch policies (ThreadDirect /
GridStride / BlockReduce) per vector without touching integrator code.
On TPU the tunable quantities are (a) whether an op runs as plain jnp
(XLA-fused) or as a hand-written Pallas kernel, and (b) the Pallas
BlockSpec tile shape (the VMEM working set — the analog of grid/block
size).  A policy object carries those choices; native data structures
accept one and thread it through to the kernels.

Backend selection
-----------------
The policy is consumed by :mod:`repro.core.dispatch`, whose **op table**
routes each hot N_Vector operation to the implementation the policy
names:

====================  ==============================  =======================
op                    'jnp' backend                   'pallas' backend
====================  ==============================  =======================
linear_sum            vector.linear_sum               vecops lincomb (K=2)
linear_combination    vector.linear_combination       vecops._lincomb_kernel
scale_add_multi       vector.scale_add_multi          vecops scale_add_multi
axpy                  vector.axpy                     vecops lincomb (K=2)
dot                   vector.dot                      vecops dot_partial
wrms_norm             vector.wrms_norm                vecops wrms_partial
wrms_norm_mask        vector.wrms_norm_mask           vecops wrms_mask_partial
dot_prod_multi        vector.dot_prod_multi           vecops multi_dot_partial
block_solve_soa       direct.gauss_jordan_batched     block_solve GJ kernel
                                                      (b>8: row-tiled GJ)
block_inverse_soa     ref.block_inverse_soa_ref       block_solve GJ inverse
                                                      (b>8: row-tiled GJ)
blockdiag_spmv_soa    jnp.einsum                      blockdiag_spmv kernel
newton_residual_soa   ref (z - gamma*f - psi)         newton fused residual
masked_update_wrms_   ref (where + wrms)              newton fused update+
soa                                                   per-system WRMS
history_rescale_soa   ref (masked AoS einsum)         newton lane-parallel
                                                      masked rebuild
wrms_soa              ref (per-system WRMS)           newton wrms_soa kernel
csr_spmv              segment_sum                     sparse ELL gather kernel
bsr_spmv_soa          einsum+segment_sum              sparse unrolled-pattern
bsr_block_jacobi_     jnp.linalg.inv                  static diag gather +
inverse_soa                                           GJ inverse kernel
====================  ==============================  =======================

The ``*_soa`` entries are the ensemble (batched-BDF) linear algebra:
the system batch rides the 128-wide lane axis and ``batch_tile`` sets
how many systems one grid program owns — the TPU analog of the paper's
CUDA-stream bundle size.  The sparse entries carry their static
pattern as hashable tuples (see :mod:`repro.core.sunmatrix`), so the
structure is compiled into the program.

Integrators thread the policy via ``ODEOptions(policy=...)``; Krylov and
Newton solvers take a ``policy=`` kwarg; :class:`MeshVectorSpec` carries
one per vector.  At the run level, a
:class:`repro.core.context.Context` owns the policy and
``ctx.options(...)`` builds ODEOptions bound to it.  ``backend='jnp'`` (XLA_FUSED, the default) reproduces
the pre-dispatch behavior exactly; ``backend='pallas'`` with
``interpret=True`` runs the fused kernels CPU-emulated (CI parity
checks), with ``interpret=False`` compiled to Mosaic on TPU.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ExecPolicy:
    """Execution policy for vector/matrix/solver operations.

    backend       : 'jnp'    — plain jnp ops, XLA fuses (default; used by
                                the dry-run path since XLA:CPU cannot
                                lower TPU pallas_call);
                    'pallas' — hand-written kernels from repro.kernels.
    block_elems   : streaming-kernel tile length (lane-aligned, /128).
    reduce_tile   : reduction-kernel tile length (BlockReduce analog).
    batch_tile    : batched block-solver bundle tile (systems per grid
                    program; kernels/ops.py takes the largest lane-
                    multiple divisor of the lane-padded batch not above
                    this, so any nsys — including non-multiples of 128 —
                    pads by less than one lane of identity blocks).
    interpret     : run Pallas in interpret mode (CPU validation).
    """

    backend: str = "jnp"
    block_elems: int = 8 * 128
    reduce_tile: int = 64 * 128
    batch_tile: int = 128
    interpret: bool = True  # flipped to False on real TPU deployments


# ThreadDirect analog: one element per "thread" -> smallest aligned tiles.
THREAD_DIRECT = ExecPolicy(backend="pallas", block_elems=128)
# GridStride analog: each program strides over a large tile.
GRID_STRIDE = ExecPolicy(backend="pallas", block_elems=64 * 128)
# BlockReduce analog for reductions.
BLOCK_REDUCE = ExecPolicy(backend="pallas", reduce_tile=64 * 128)
# Pure-XLA default.
XLA_FUSED = ExecPolicy(backend="jnp")
