"""Execution policies — the *ExecPolicy analog (paper §4.1).

SUNDIALS lets users swap kernel-launch policies (ThreadDirect /
GridStride / BlockReduce) per vector without touching integrator code.
On TPU the tunable quantities are (a) whether an op runs as plain jnp
(XLA-fused) or as a hand-written Pallas kernel, and (b) the Pallas
BlockSpec tile shape (the VMEM working set — the analog of grid/block
size).  A policy object carries those choices; native data structures
accept one and thread it through to the kernels.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ExecPolicy:
    """Execution policy for vector/matrix/solver operations.

    backend       : 'jnp'    — plain jnp ops, XLA fuses (default; used by
                                the dry-run path since XLA:CPU cannot
                                lower TPU pallas_call);
                    'pallas' — hand-written kernels from repro.kernels.
    block_elems   : streaming-kernel tile length (lane-aligned, /128).
    reduce_tile   : reduction-kernel tile length (BlockReduce analog).
    batch_tile    : batched block-solver tile (systems per program).
    interpret     : run Pallas in interpret mode (CPU validation).
    """

    backend: str = "jnp"
    block_elems: int = 8 * 128
    reduce_tile: int = 64 * 128
    batch_tile: int = 128
    interpret: bool = True  # flipped to False on real TPU deployments


# ThreadDirect analog: one element per "thread" -> smallest aligned tiles.
THREAD_DIRECT = ExecPolicy(backend="pallas", block_elems=128)
# GridStride analog: each program strides over a large tile.
GRID_STRIDE = ExecPolicy(backend="pallas", block_elems=64 * 128)
# BlockReduce analog for reductions.
BLOCK_REDUCE = ExecPolicy(backend="pallas", reduce_tile=64 * 128)
# Pure-XLA default.
XLA_FUSED = ExecPolicy(backend="jnp")
