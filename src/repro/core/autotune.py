"""Persisted autotune cache + the ``backend='auto'`` resolver.

The measured half of cost-model-driven dispatch: ``benchmarks/run.py
--tune`` times every OP_TABLE op over a grid of signatures on both
backends and writes the winners to ``.autotune/<device>.json``
(committed alongside the BENCH files).  ``backend='auto'`` dispatch
resolves each call site at trace time:

1. per-op override on the policy (``ExecPolicy.op_overrides``) — forced;
2. exact autotune-cache hit for (op, shape-signature, dtype) — the
   measured winner and its measured-best tile;
3. nearest cache entry (same op/dtype/structural params, closest tiled-
   axis length within 8x) — measurement generalizes along the batch
   axis far better than across block sizes;
4. the analytical model (:mod:`repro.analysis.opcost`) — always
   evaluated anyway, so every decision records whether model and
   measurement agree (``ctx.dispatch_report()`` surfaces mismatches).

Cache files are schema-versioned: a loader seeing a different
``schema`` (or an entry whose key disagrees with its recorded
signature) drops the stale data and falls back to the model — never an
error, exactly like a cold cache.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from pathlib import Path
from typing import Dict, Optional

from repro.analysis import opcost
from repro.analysis.opcost import OpSig
from repro.analysis.roofline import get_device

#: bump when the key derivation or entry layout changes; mismatched
#: files are discarded wholesale (stale winners are worse than a cold
#: cache — they would silently pin yesterday's loser).
SCHEMA_VERSION = 1

#: nearest-entry fallback range along the tiled axis (log-distance cap).
NEAREST_MAX_FACTOR = 8.0


def default_cache_dir() -> Path:
    """``$REPRO_AUTOTUNE_DIR`` or ``<repo_root>/.autotune`` (resolved
    from this file so tests/benchmarks work from any cwd)."""
    env = os.environ.get("REPRO_AUTOTUNE_DIR")
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[3] / ".autotune"


@dataclasses.dataclass
class Entry:
    """One measured (op, signature) record."""

    sig: OpSig
    t_jnp: float              # best-of-reps seconds
    t_pallas: float
    tile: int = 0             # measured-best pallas tile (0 = default)

    @property
    def winner(self) -> str:
        return "jnp" if self.t_jnp <= self.t_pallas else "pallas"

    @property
    def ratio(self) -> float:
        """Measured jnp/pallas time ratio (>1 -> pallas wins)."""
        return self.t_jnp / max(self.t_pallas, 1e-12)

    def to_json(self) -> dict:
        return {"sig": dataclasses.asdict(self.sig), "t_jnp": self.t_jnp,
                "t_pallas": self.t_pallas, "tile": self.tile,
                "winner": self.winner}

    @classmethod
    def from_json(cls, d: dict) -> "Entry":
        return cls(sig=OpSig(**d["sig"]), t_jnp=float(d["t_jnp"]),
                   t_pallas=float(d["t_pallas"]), tile=int(d.get("tile", 0)))


class AutotuneCache:
    """Schema-versioned, per-device persisted measurement store."""

    def __init__(self, device: str, path: Optional[Path] = None):
        self.device = device
        self.path = Path(path) if path is not None else \
            default_cache_dir() / f"{device}.json"
        self.entries: Dict[str, Entry] = {}
        self.stale = False        # a file existed but was invalidated

    # -- persistence --------------------------------------------------------

    def load(self) -> "AutotuneCache":
        """Read the cache file; schema or key mismatches discard the
        file's (or entry's) data silently — a cold cache, not an error."""
        self.entries = {}
        try:
            payload = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return self
        if payload.get("schema") != SCHEMA_VERSION or \
                payload.get("device") != self.device:
            self.stale = True
            return self
        for key, raw in payload.get("entries", {}).items():
            try:
                entry = Entry.from_json(raw)
            except (KeyError, TypeError, ValueError):
                self.stale = True
                continue
            if entry.sig.key() != key:          # mismatched/corrupt key
                self.stale = True
                continue
            self.entries[key] = entry
        return self

    def save(self) -> Path:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        payload = {"schema": SCHEMA_VERSION, "device": self.device,
                   "note": ("measured best-of-reps seconds per backend; "
                            "regenerate with: PYTHONPATH=src python -m "
                            "benchmarks.run --tune"),
                   "entries": {k: e.to_json()
                               for k, e in sorted(self.entries.items())}}
        self.path.write_text(json.dumps(payload, indent=2) + "\n")
        return self.path

    # -- lookup -------------------------------------------------------------

    def put(self, entry: Entry) -> None:
        self.entries[entry.sig.key()] = entry

    def get(self, sig: OpSig) -> Optional[Entry]:
        return self.entries.get(sig.key())

    def nearest(self, sig: OpSig) -> Optional[Entry]:
        """Closest entry with the same op/dtype and structural params
        (b, k, nnz), ranked by log-distance along the tiled axis and
        capped at :data:`NEAREST_MAX_FACTOR` — batch size extrapolates;
        block structure does not."""
        best, best_d = None, math.inf
        for e in self.entries.values():
            es = e.sig
            if (es.op, es.dtype, es.b, es.k, es.nnz) != \
                    (sig.op, sig.dtype, sig.b, sig.k, sig.nnz):
                continue
            a, c = max(1, es.axis_len), max(1, sig.axis_len)
            d = abs(math.log(a / c))
            if d < best_d:
                best, best_d = e, d
        if best is not None and best_d <= math.log(NEAREST_MAX_FACTOR):
            return best
        return None


@dataclasses.dataclass
class Decision:
    """One resolved call site (recorded once per unique signature)."""

    sig: OpSig
    backend: str
    source: str               # 'override' | 'cache' | 'near' | 'model'
    tile: int
    model_winner: str
    cached_winner: Optional[str] = None
    hits: int = 1

    @property
    def agree(self) -> Optional[bool]:
        """Model-vs-measurement agreement (None without a measurement)."""
        if self.cached_winner is None:
            return None
        return self.model_winner == self.cached_winner

    def to_dict(self) -> dict:
        return {"op": self.sig.op, "sig": self.sig.key(),
                "backend": self.backend, "source": self.source,
                "tile": self.tile, "model_winner": self.model_winner,
                "cached_winner": self.cached_winner, "agree": self.agree,
                "hits": self.hits}


class Resolver:
    """Per-device decision engine for ``backend='auto'`` dispatch."""

    def __init__(self, device: str, cache: Optional[AutotuneCache] = None):
        self.device = device
        self.cache = cache if cache is not None else \
            AutotuneCache(device).load()
        self.decisions: Dict[str, Decision] = {}

    def decide(self, sig: OpSig, requested_tile: Optional[int] = None,
               override: Optional[str] = None) -> Decision:
        """Resolve one call site; memoized per unique signature."""
        key = sig.key()
        hit = self.decisions.get(key)
        if hit is not None and override is None:
            hit.hits += 1
            return hit
        pred = opcost.predict(sig, self.device, requested_tile)
        entry = self.cache.get(sig)
        near = None if entry is not None else self.cache.nearest(sig)
        measured = entry or near
        if override:
            backend, source = override, "override"
        elif entry is not None:
            backend, source = entry.winner, "cache"
        elif near is not None:
            backend, source = near.winner, "near"
        else:
            backend, source = pred.winner, "model"
        tile = pred.tile
        if measured is not None and measured.tile and backend == "pallas":
            tile = min(measured.tile,
                       opcost._lane_ceil(max(1, sig.axis_len)))
        dec = Decision(sig=sig, backend=backend, source=source, tile=tile,
                       model_winner=pred.winner,
                       cached_winner=measured.winner if measured else None)
        self.decisions[key] = dec
        return dec

    # -- reporting ----------------------------------------------------------

    def report(self) -> dict:
        """Decisions so far + a model-vs-cache audit over the *whole*
        cache (the >=80%-agreement acceptance metric), mispredictions
        listed explicitly."""
        audit = model_audit(self.cache)
        return {"device": self.device,
                "cache_path": str(self.cache.path),
                "cache_entries": len(self.cache.entries),
                "cache_stale": self.cache.stale,
                "decisions": [d.to_dict()
                              for d in self.decisions.values()],
                **audit}


def model_audit(cache: AutotuneCache) -> dict:
    """Compare the analytical model's predicted winner against every
    measured cache entry."""
    agree, mispredictions = 0, []
    for e in cache.entries.values():
        pred = opcost.predict(e.sig, cache.device)
        if pred.winner == e.winner:
            agree += 1
        else:
            mispredictions.append(
                {"sig": e.sig.key(), "measured": e.winner,
                 "predicted": pred.winner,
                 "measured_ratio": round(e.ratio, 3),
                 "predicted_ratio": round(pred.ratio, 3)})
    total = len(cache.entries)
    return {"model_agreement": (agree / total) if total else None,
            "model_agree": agree, "model_total": total,
            "mispredictions": mispredictions}


# ---------------------------------------------------------------------------
# Process-wide resolver registry.  ExecPolicy stays a frozen hashable
# value type (it keys jit caches), so it carries only the device *name*;
# the mutable resolver/cache state lives here and Context fronts it.
# ---------------------------------------------------------------------------

_RESOLVERS: Dict[str, Resolver] = {}


def get_resolver(device: str) -> Resolver:
    get_device(device)                      # validate the name early
    res = _RESOLVERS.get(device)
    if res is None:
        res = _RESOLVERS[device] = Resolver(device)
    return res


def reset_resolver(device: Optional[str] = None) -> None:
    """Drop memoized resolvers (tests; after regenerating a cache)."""
    if device is None:
        _RESOLVERS.clear()
    else:
        _RESOLVERS.pop(device, None)


def resolve(op: str, policy, *args):
    """Trace-time entry point for ``backend='auto'`` dispatch: extract
    the call-site signature, decide, and run the chosen implementation
    under a concretized policy.  Imported lazily by
    :func:`repro.core.dispatch.dispatch` to avoid an import cycle."""
    from . import dispatch as dp
    sig = opcost.signature(op, args)
    res = get_resolver(policy.device_name())
    dec = res.decide(sig, requested_tile=None)
    fields = {"backend": dec.backend}
    if dec.backend == "pallas":
        if op in opcost.BATCHED_OPS:
            fields["batch_tile"] = dec.tile
        elif op in opcost.REDUCTION_OPS:
            fields["reduce_tile"] = dec.tile
        else:
            fields["block_elems"] = dec.tile
    concrete = dataclasses.replace(policy, op_overrides=(), **fields)
    fn = dp.OP_TABLE[op].get(dec.backend, dp.OP_TABLE[op]["jnp"])
    return fn(*args, policy=concrete)


def decisions_report(policy) -> dict:
    """Report for the resolver belonging to ``policy``'s device."""
    return get_resolver(policy.device_name()).report()
