"""SUNMatrix analogs: dense and low-storage block-diagonal matrices.

The paper's ``SUNMatrix_cuSparse`` supports CSR and a *low-storage
block-diagonal* format where all blocks A_j share one sparsity pattern
(Fig. 1), storing the integer index arrays once.  The TPU adaptation
(DESIGN.md §2) keeps the low-storage idea but makes blocks dense:

* :class:`BlockDiagMatrix` stores ``data: (nblocks, b, b)`` — structure
  (the block layout) is implicit and shared, exactly one copy of
  "indexing" information (none needed) regardless of nblocks.
* An optional shared sparsity ``mask: (b, b)`` preserves the paper's
  sparse-blocks case: masked entries are structurally zero for every
  block, applied once for all blocks (memory already saved by density
  b<<n; compute saved by the kernels honoring the mask where profitable).

Ops mirror SUNMatScaleAdd / SUNMatScaleAddI / SUNMatMatvec.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class BlockDiagMatrix(NamedTuple):
    """Block-diagonal matrix: n = nblocks * b, blocks stacked densely."""
    data: jnp.ndarray                 # (nblocks, b, b)
    mask: Optional[jnp.ndarray] = None  # (b, b) shared sparsity or None

    @property
    def nblocks(self) -> int:
        return self.data.shape[0]

    @property
    def block_size(self) -> int:
        return self.data.shape[1]

    @property
    def shape(self):
        n = self.nblocks * self.block_size
        return (n, n)


def bd_zero_like(A: BlockDiagMatrix) -> BlockDiagMatrix:
    return BlockDiagMatrix(jnp.zeros_like(A.data), A.mask)


def bd_scale_add(c, A: BlockDiagMatrix, B: BlockDiagMatrix) -> BlockDiagMatrix:
    """A <- c*A + B   (SUNMatScaleAdd)."""
    return BlockDiagMatrix(c * A.data + B.data, A.mask)


def bd_scale_addi(c, A: BlockDiagMatrix) -> BlockDiagMatrix:
    """A <- c*A + I   (SUNMatScaleAddI) — the Newton matrix M = I - gamma*J."""
    b = A.block_size
    eye = jnp.eye(b, dtype=A.data.dtype)
    return BlockDiagMatrix(c * A.data + eye[None, :, :], A.mask)


def bd_matvec(A: BlockDiagMatrix, x: jnp.ndarray) -> jnp.ndarray:
    """y = A @ x for x of shape (nblocks*b,) or (nblocks, b)."""
    nb, b = A.nblocks, A.block_size
    xb = x.reshape(nb, b)
    data = A.data if A.mask is None else A.data * A.mask[None]
    yb = jnp.einsum("nij,nj->ni", data, xb)
    return yb.reshape(x.shape)


def bd_from_jacfn(jac_blocks: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> BlockDiagMatrix:
    return BlockDiagMatrix(jac_blocks, mask)


def dense_scale_addi(c, A: jnp.ndarray) -> jnp.ndarray:
    return c * A + jnp.eye(A.shape[-1], dtype=A.dtype)
