"""repro.core — SUNDIALS-on-TPU: the paper's contribution in JAX.

Layers (mirroring the SUNDIALS class structure):
  context    — SUNContext analog: ExecPolicy + MemoryHelper + counters
  vector     — N_Vector ops, MeshVector (MPIPlusX), ManyVector
  memory     — SUNMemoryHelper analog (workspace high-water audit)
  policies   — ExecPolicy analogs (jnp vs Pallas, tile shapes)
  butcher    — ERK/DIRK/IMEX Butcher tables
  controller — step-size controllers
  linsol     — SUNLinearSolver objects (SPGMR/.../DenseGJ/BlockDiagGJ)
  nonlinsol  — SUNNonlinearSolver objects (Newton, Anderson fixed-point)
  arkode     — adaptive ERK / DIRK / IMEX-ARK integrators
  cvode      — adaptive BDF + functional Adams
  kinsol     — Newton + Anderson fixed-point kernels
  krylov     — GMRES/FGMRES/BiCGStab/TFQMR/PCG (matrix-free)
  matrix     — dense + low-storage block-diagonal matrices
  direct     — batched block-diagonal direct solver
  batched    — vmap'd ensemble integration (submodel use case)
  ivp        — unified front-end: IVP + integrate(method=...) -> Solution
"""
from . import (arkode, batched, butcher, context, controller, cvode, direct,
               events, ivp, kinsol, krylov, linsol, matrix, memory,
               nonlinsol, policies, vector)

__all__ = ["arkode", "batched", "butcher", "context", "controller", "cvode",
           "direct", "events", "ivp", "kinsol", "krylov", "linsol",
           "matrix", "memory", "nonlinsol", "policies", "vector"]
