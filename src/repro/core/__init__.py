"""repro.core — SUNDIALS-on-TPU: the paper's contribution in JAX.

Layers (mirroring the SUNDIALS class structure):
  vector     — N_Vector ops, MeshVector (MPIPlusX), ManyVector
  memory     — SUNMemoryHelper analog
  policies   — ExecPolicy analogs (jnp vs Pallas, tile shapes)
  butcher    — ERK/DIRK/IMEX Butcher tables
  controller — step-size controllers
  arkode     — adaptive ERK / DIRK / IMEX-ARK integrators
  cvode      — adaptive BDF + functional Adams
  kinsol     — Newton + Anderson fixed-point
  krylov     — GMRES/FGMRES/BiCGStab/TFQMR/PCG (matrix-free)
  matrix     — dense + low-storage block-diagonal matrices
  direct     — batched block-diagonal direct solver
  batched    — vmap'd ensemble integration (submodel use case)
"""
from . import (arkode, batched, butcher, controller, cvode, direct, events,
               kinsol, krylov, matrix, memory, policies, vector)

__all__ = ["arkode", "batched", "butcher", "controller", "cvode", "direct",
           "events", "kinsol", "krylov", "matrix", "memory", "policies",
           "vector"]
