"""Batched block-diagonal direct linear solver.

The SUNLinearSolver_cuSolverSp_batchQR analog: solves n independent
small systems A_j x_j = b_j in one batched call.  The factorization
structure is shared across blocks (the paper's shared-sparsity /
shared-QR-pattern point); on TPU we express that as one vectorized
elimination whose control flow is identical for every block (DESIGN.md
§2 — symbolic Gauss-Jordan ≙ unrolled vectorized GJ).

Two backends, selected by ExecPolicy:
* 'jnp'    — jnp.linalg LU solve (XLA batched) or our vectorized GJ;
* 'pallas' — repro.kernels.block_solve (VMEM-tiled, lane-major layout).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .matrix import BlockDiagMatrix
from .policies import ExecPolicy, XLA_FUSED


class DirectStats(NamedTuple):
    nblocks: int
    block_size: int


def gauss_jordan_batched(A: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Vectorized Gauss-Jordan with partial pivoting over a block batch.

    A: (nb, n, n), b: (nb, n) -> x: (nb, n).  The elimination sequence is
    identical across blocks (shared structure); pivoting is a vectorized
    row swap per block.  Unrolled over n (n is small and static).
    """
    nb, n, _ = A.shape
    # augmented system
    M = jnp.concatenate([A, b[:, :, None]], axis=2)  # (nb, n, n+1)
    for k in range(n):
        # partial pivot: pick argmax |M[:, k:, k]| per block
        piv_rel = jnp.argmax(jnp.abs(M[:, k:, k]), axis=1)        # (nb,)
        piv = piv_rel + k
        rows = jnp.arange(n)[None, :]                             # (1, n)
        batch = jnp.arange(nb)
        # swap rows k and piv (vectorized gather-based permutation)
        perm = jnp.where(rows == k, piv[:, None],
                         jnp.where(rows == piv[:, None], k, rows))  # (nb, n)
        M = M[batch[:, None], perm, :]
        # eliminate column k from all other rows
        pivval = M[:, k, k]                                       # (nb,)
        pivrow = M[:, k, :] / pivval[:, None]                     # (nb, n+1)
        factors = M[:, :, k]                                      # (nb, n)
        M = M - factors[:, :, None] * pivrow[:, None, :]
        M = M.at[:, k, :].set(pivrow)
    return M[:, :, n]


def block_solve(A: BlockDiagMatrix, b: jnp.ndarray,
                policy: ExecPolicy = XLA_FUSED) -> jnp.ndarray:
    """Solve the block-diagonal system; b flat (nb*bs,) or (nb, bs)."""
    nb, bs = A.nblocks, A.block_size
    data = A.data if A.mask is None else A.data * A.mask[None]
    bb = b.reshape(nb, bs)
    if policy.backend == "pallas":
        from repro.kernels import ops as kops
        xb = kops.block_solve(data, bb, batch_tile=policy.batch_tile,
                              interpret=policy.interpret)
    else:
        xb = gauss_jordan_batched(data, bb)
    return xb.reshape(b.shape)


def block_lu_factor(A: BlockDiagMatrix):
    """Factor once / solve many (SUNLinSolSetup / SUNLinSolSolve split)."""
    data = A.data if A.mask is None else A.data * A.mask[None]
    lu, piv = jax.vmap(jax.scipy.linalg.lu_factor)(data)
    return lu, piv


def block_lu_solve(factors, b: jnp.ndarray, block_size: int) -> jnp.ndarray:
    lu, piv = factors
    nb = lu.shape[0]
    bb = b.reshape(nb, block_size)
    xb = jax.vmap(lambda l, p, r: jax.scipy.linalg.lu_solve((l, p), r))(lu, piv, bb)
    return xb.reshape(b.shape)
