"""SUNMemoryHelper analog for JAX/TPU.

The paper's SUNMemoryHelper is a *minimal* memory abstraction — not a
full resource manager — with three jobs: allocate, deallocate, and copy
between memory spaces (host / device / UVM / pinned), plus an ownership
flag so user-provided buffers are never freed by the library.

On TPU under JAX the analogous spaces are JAX *memory kinds*:

* ``device``       — chip HBM (the default),
* ``pinned_host``  — host RAM addressable for fast DMA (≙ CUDA pinned),
* UVM has no TPU analog (single per-chip HBM space); we map it to
  ``device`` and record the request so callers can introspect.

Deallocation is delegated to JAX (buffer refcounts + donation); the
helper exposes :meth:`donate` to mark arrays for buffer reuse, which is
the XLA-native version of returning memory to a pool.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp


class MemoryType(enum.Enum):
    HOST = "host"            # plain host memory (numpy / CPU jax buffer)
    DEVICE = "device"        # chip HBM
    UVM = "uvm"              # no TPU analog -> mapped to DEVICE (recorded)
    PINNED = "pinned_host"   # host memory pinned for DMA


@dataclass
class SUNMemory:
    """Wraps an array with its memory type and ownership flag (paper §3)."""

    data: Any
    mem_type: MemoryType
    own: bool = True
    requested_type: Optional[MemoryType] = None  # e.g. UVM downgraded to DEVICE


@dataclass
class MemoryHelper:
    """Minimal alloc/copy interface the native data structures build on.

    ``stats`` counts bytes allocated/copied per space — the
    SUNMemoryHelper bookkeeping that lets applications audit data motion
    (the paper's "minimize host<->device transfers" guidance becomes
    checkable).
    """

    device: Optional[jax.Device] = None
    stats: dict = field(default_factory=lambda: {
        "alloc_bytes": 0, "copy_bytes": 0, "copies_h2d": 0, "copies_d2h": 0,
        "live_bytes": 0, "high_water_bytes": 0})
    workspaces: dict = field(default_factory=dict)  # label -> live bytes

    # -- workspace registration (high-water accounting) --------------------
    #
    # Solvers and integrators *register* their working sets (Krylov bases,
    # BDF history windows, saved Newton matrices, ...) instead of routing
    # every jnp.zeros through alloc(): JAX owns the actual buffers, but the
    # helper keeps the SUNMemoryHelper-style audit — live bytes per label
    # and the run's high-water mark.  Registration happens at trace time
    # (shapes are static), so one traced instance == one concurrent
    # workspace, which is exactly the high-water semantics we want.

    @staticmethod
    def nbytes_of(shape, dtype) -> int:
        n = 1
        for s in shape:
            n *= int(s)
        return n * jnp.dtype(dtype).itemsize

    def register(self, label: str, shape, dtype=jnp.float64) -> int:
        """Account a workspace buffer under ``label``; returns its bytes.

        Idempotent per label: a solver traced several times per step
        (e.g. one Krylov solve per implicit stage) still owns ONE
        workspace of that shape, so re-registering the same label only
        grows the accounted size if the new shape is larger.
        """
        nbytes = self.nbytes_of(shape, dtype)
        delta = max(0, nbytes - self.workspaces.get(label, 0))
        if delta == 0:
            return nbytes
        self.workspaces[label] = self.workspaces.get(label, 0) + delta
        self.stats["alloc_bytes"] += delta
        self.stats["live_bytes"] += delta
        self.stats["high_water_bytes"] = max(self.stats["high_water_bytes"],
                                             self.stats["live_bytes"])
        return nbytes

    def release(self, label: Optional[str] = None) -> None:
        """Release one labelled workspace (or all of them)."""
        labels = list(self.workspaces) if label is None else [label]
        for lb in labels:
            self.stats["live_bytes"] -= self.workspaces.pop(lb, 0)

    @property
    def high_water_bytes(self) -> int:
        return self.stats["high_water_bytes"]

    @property
    def live_bytes(self) -> int:
        return self.stats["live_bytes"]

    # -- allocation --------------------------------------------------------
    def alloc(self, shape, dtype=jnp.float32,
              mem_type: MemoryType = MemoryType.DEVICE) -> SUNMemory:
        requested = mem_type
        if mem_type == MemoryType.UVM:
            mem_type = MemoryType.DEVICE  # single HBM space on TPU
        arr = jnp.zeros(shape, dtype=dtype)
        arr = self._place(arr, mem_type)
        nbytes = arr.size * arr.dtype.itemsize
        self.stats["alloc_bytes"] += int(nbytes)
        return SUNMemory(arr, mem_type, own=True,
                         requested_type=requested)

    def wrap(self, data, mem_type: MemoryType = MemoryType.DEVICE) -> SUNMemory:
        """Wrap a user-provided buffer — ownership stays with the user."""
        return SUNMemory(data, mem_type, own=False)

    # -- copy between spaces -------------------------------------------------
    def copy(self, dst: SUNMemory, src: SUNMemory) -> SUNMemory:
        """Copy src contents into dst's memory space (returns new SUNMemory
        since JAX arrays are immutable; dst identity = space + shape)."""
        arr = self._place(jnp.asarray(src.data), dst.mem_type)
        nbytes = arr.size * arr.dtype.itemsize
        self.stats["copy_bytes"] += int(nbytes)
        if src.mem_type in (MemoryType.HOST, MemoryType.PINNED) and \
           dst.mem_type == MemoryType.DEVICE:
            self.stats["copies_h2d"] += 1
        if src.mem_type == MemoryType.DEVICE and \
           dst.mem_type in (MemoryType.HOST, MemoryType.PINNED):
            self.stats["copies_d2h"] += 1
        return SUNMemory(arr, dst.mem_type, own=dst.own,
                         requested_type=dst.requested_type)

    def _place(self, arr, mem_type: MemoryType):
        """Move to the right memory kind; degrade gracefully on CPU-only."""
        if mem_type == MemoryType.DEVICE:
            return arr if self.device is None else jax.device_put(arr, self.device)
        kind = "pinned_host" if mem_type == MemoryType.PINNED else None
        if kind is not None:
            try:
                dev = self.device or jax.devices()[0]
                sharding = jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
                return jax.device_put(arr, sharding)
            except Exception:
                return arr  # backend lacks the memory kind (CPU): keep default
        return arr

    # -- donation (pool-reuse analog) -----------------------------------------
    @staticmethod
    def donate_argnums_for(fn, *argnums):
        """Return jit(fn) with donated args — XLA reuses their buffers, the
        TPU-native equivalent of handing memory back to an application pool."""
        return jax.jit(fn, donate_argnums=argnums)
