"""Preconditioners — the PSetup/PSolve plug-in point (SUNDIALS §"Enabling
New Flexibility": user-supplied preconditioning is a first-class
interface, not a solver detail).

A :class:`Preconditioner` exposes two surfaces, mirroring the
``LinearSolver`` split:

**Scalar** (single system; used by ``SPGMR(precond=...).bind``):

* ``psetup(t, y, gamma, policy=None) -> pdata`` — build the
  preconditioner data for the Newton matrix ``M = I - gamma*J`` at the
  current iterate (called at each lin_solve, the PSetup moment);
* ``psolve(pdata, r, policy=None) -> z`` — apply ``P^{-1} r`` on the
  raveled (n,) residual.

**Ensemble SoA** (used by the ``ensemble_bdf`` Krylov path; setup runs
at CVODE's lsetup triggers, so psetup counts ride ``nsetups``):

* ``soa_psetup(vals, pattern, gamma, policy=None) -> pdata`` where the
  Newton matrix arrives either dense (``vals: (n, n, nsys)``,
  ``pattern=None``) or as shared-pattern CSR values
  (``vals: (nnz, nsys)``, ``pattern=(indptr, indices)``);
* ``soa_psolve(pdata, r, policy=None) -> z`` with ``r: (n, nsys)``;
* ``soa_pdata_init(n, nsys, dtype)`` — zero pdata for the integrator
  carry (every leaf keeps the ``nsys`` lane axis LAST so the masked
  per-system carry update broadcasts).

Implementations:

=================  ========================================================
JacobiPrecond      diagonal of M (the cheapest; exact for decoupled systems)
BlockJacobiPrecond b x b diagonal blocks of M, inverted once per psetup via
                   the batched GJ inverse kernel (reuses
                   ``block_inverse_soa`` over the flattened nblk*nsys batch)
ILU0Precond        incomplete LU with zero fill on the shared CSR pattern
                   (exact LU whenever the pattern's elimination has no
                   fill-in, e.g. tridiagonal)
=================  ========================================================

All are frozen dataclasses (hashable, safe inside ``lax.while_loop``).
Preconditioner applications are counted by the Krylov solvers in
``SolveStats.npsolves``; setups surface as ``Solution.npsetups``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional

import jax.numpy as jnp

from . import dispatch as dv
from . import spsolve
from .sunmatrix import csr_diag_positions as _csr_diag_positions


class Preconditioner:
    """Base protocol; see the module docstring for the two surfaces."""

    name = "precond"

    # -- scalar surface ----------------------------------------------------
    def psetup(self, t, y, gamma, policy=None):
        raise NotImplementedError(
            f"{type(self).__name__} has no scalar psetup")

    def psolve(self, pdata, r, policy=None):
        raise NotImplementedError

    # -- ensemble SoA surface ----------------------------------------------
    def soa_psetup(self, vals, pattern, gamma, policy=None):
        raise NotImplementedError(
            f"{type(self).__name__} has no ensemble psetup")

    def soa_psolve(self, pdata, r, policy=None):
        raise NotImplementedError

    def soa_pdata_init(self, n, nsys, dtype):
        raise NotImplementedError


@dataclass(frozen=True)
class JacobiPrecond(Preconditioner):
    """Diagonal (point-Jacobi) preconditioner: P = diag(M).

    ``jac_diag(t, y) -> (n,)`` supplies the Jacobian diagonal for the
    scalar surface (matrix-free integrators cannot extract it); the
    ensemble surface reads it from the Newton matrix directly.
    """

    name = "jacobi"
    jac_diag: Optional[Callable] = None

    def psetup(self, t, y, gamma, policy=None):
        if self.jac_diag is None:
            raise ValueError("scalar JacobiPrecond needs jac_diag=")
        d = 1.0 - gamma * self.jac_diag(t, y)
        return 1.0 / d

    def psolve(self, pdata, r, policy=None):
        return pdata * r

    def soa_psetup(self, vals, pattern, gamma, policy=None):
        if pattern is None:
            n = vals.shape[0]
            idx = jnp.arange(n)
            d = vals[idx, idx]                       # (n, nsys)
        else:
            indptr, indices = pattern
            d = vals[jnp.asarray(_csr_diag_positions(indptr, indices))]
        return 1.0 / d

    def soa_psolve(self, pdata, r, policy=None):
        return pdata * r

    def soa_pdata_init(self, n, nsys, dtype):
        return jnp.zeros((n, nsys), dtype)


@dataclass(frozen=True)
class BlockJacobiPrecond(Preconditioner):
    """Block-Jacobi: invert the ``b x b`` diagonal blocks of M once per
    psetup (one batched GJ-inverse over the flattened ``nblk * nsys``
    batch — the ``block_inverse_soa`` kernel the direct ensemble solver
    already uses); psolve is one block-diagonal SpMV.

    For ensemble problems whose per-system size equals ``block_size``
    this is an exact solve, and a preconditioned Krylov method
    converges in one inner iteration (a useful correctness probe).
    ``jac(t, y) -> (n, n)`` supplies the dense Jacobian on the scalar
    surface.
    """

    name = "block_jacobi"
    block_size: int = 1
    jac: Optional[Callable] = None

    def psetup(self, t, y, gamma, policy=None):
        if self.jac is None:
            raise ValueError("scalar BlockJacobiPrecond needs jac=")
        J = self.jac(t, y)
        n = J.shape[0]
        b = self.block_size
        nblk = n // b
        Jb = J.reshape(nblk, b, nblk, b)
        D = jnp.eye(b)[None] - gamma * \
            Jb[jnp.arange(nblk), :, jnp.arange(nblk), :]
        return jnp.linalg.inv(D)                     # (nblk, b, b)

    def psolve(self, pdata, r, policy=None):
        nblk, b, _ = pdata.shape
        return jnp.einsum("nij,nj->ni", pdata,
                          r.reshape(nblk, b)).reshape(-1)

    # -- ensemble ----------------------------------------------------------
    def _diag_block_values(self, vals, pattern, n, nsys):
        """(nblk, b, b, nsys) diagonal-block values of M."""
        b = self.block_size
        nblk = n // b
        if pattern is None:
            V5 = vals.reshape(nblk, b, nblk, b, nsys)
            return V5[jnp.arange(nblk), :, jnp.arange(nblk), :, :]
        # static pattern -> precompute every in-diagonal-block slot on
        # the host and scatter them in ONE vectorized update
        indptr, indices = pattern
        Is, bis, bjs, ks = [], [], [], []
        for i in range(n):
            I, bi = divmod(i, b)
            for k in range(indptr[i], indptr[i + 1]):
                J_, bj = divmod(indices[k], b)
                if J_ == I:
                    Is.append(I)
                    bis.append(bi)
                    bjs.append(bj)
                    ks.append(k)
        D = jnp.zeros((nblk, b, b, nsys), vals.dtype)
        return D.at[jnp.asarray(Is), jnp.asarray(bis),
                    jnp.asarray(bjs)].set(vals[jnp.asarray(ks)])

    def soa_psetup(self, vals, pattern, gamma, policy=None):
        n = vals.shape[0] if pattern is None else len(pattern[0]) - 1
        nsys = vals.shape[-1]
        b = self.block_size
        nblk = n // b
        D = self._diag_block_values(vals, pattern, n, nsys)
        diag_pat = (tuple(range(nblk)), tuple(range(nblk)), nblk)
        inv = dv.bsr_block_jacobi_inverse_soa(
            D.reshape(nblk, b, b, nsys), diag_pat, policy)
        # carry layout: keep the nsys lane axis last and separate
        return inv.reshape(b, b, nblk, nsys)

    def soa_psolve(self, pdata, r, policy=None):
        b, _, nblk, nsys = pdata.shape
        r_soa = r.reshape(nblk, b, nsys).transpose(1, 0, 2) \
            .reshape(b, nblk * nsys)
        z = dv.blockdiag_spmv_soa(pdata.reshape(b, b, nblk * nsys),
                                  r_soa, policy)
        return z.reshape(b, nblk, nsys).transpose(1, 0, 2) \
            .reshape(nblk * b, nsys)

    def soa_pdata_init(self, n, nsys, dtype):
        b = self.block_size
        return jnp.zeros((b, b, n // b, nsys), dtype)


@functools.lru_cache(maxsize=64)
def _ilu0_plan(indptr: tuple, indices: tuple) -> spsolve.LUPlan:
    """ILU(0) symbolic phase: no reordering, no fill — the factored
    pattern IS the matrix pattern, updates outside it are dropped."""
    return spsolve.symbolic_lu(indptr, indices, order=False, fill=False)


@dataclass(frozen=True)
class ILU0Precond(Preconditioner):
    """Incomplete LU with zero fill on the shared CSR pattern.

    ``sparsity`` is the static pattern — an encoded ``(indptr,
    indices)`` pair or anything :func:`repro.core.spsolve.
    encode_pattern` accepts.  The symbolic phase runs once per pattern
    (host, cached); each psetup is a numeric refactor unrolled over the
    pattern, elementwise across the ensemble lanes.  ``jac(t, y) ->
    (n, n)`` supplies the dense Jacobian on the scalar surface.
    """

    name = "ilu0"
    sparsity: Optional[tuple] = None
    jac: Optional[Callable] = None

    def __post_init__(self):
        if self.sparsity is not None and not (
                isinstance(self.sparsity, tuple)
                and len(self.sparsity) == 2
                and isinstance(self.sparsity[0], tuple)):
            object.__setattr__(self, "sparsity",
                               spsolve.encode_pattern(self.sparsity))

    def with_sparsity(self, enc) -> "ILU0Precond":
        import dataclasses
        return self if self.sparsity is not None else \
            dataclasses.replace(self, sparsity=enc)

    def _plan(self) -> spsolve.LUPlan:
        if self.sparsity is None:
            raise ValueError("ILU0Precond needs sparsity= (or a "
                             "jac_sparsity on the problem)")
        return _ilu0_plan(*self.sparsity)

    def psetup(self, t, y, gamma, policy=None):
        if self.jac is None:
            raise ValueError("scalar ILU0Precond needs jac=")
        plan = self._plan()
        J = self.jac(t, y)
        M = jnp.eye(J.shape[0], dtype=J.dtype) - gamma * J
        return spsolve.numeric_lu(plan, spsolve.gather_filled(plan, M))

    def psolve(self, pdata, r, policy=None):
        return spsolve.lu_solve(self._plan(), pdata, r)

    def soa_psetup(self, vals, pattern, gamma, policy=None):
        plan = self._plan()
        if pattern is None:
            vals0 = spsolve.gather_filled(plan, vals)
        else:
            vals0 = spsolve.scatter_from_csr(plan, pattern[0],
                                             pattern[1], vals)
        return spsolve.numeric_lu(plan, vals0)

    def soa_psolve(self, pdata, r, policy=None):
        return spsolve.lu_solve(self._plan(), pdata, r)

    def soa_pdata_init(self, n, nsys, dtype):
        return jnp.zeros((self._plan().nnz_factored, nsys), dtype)
