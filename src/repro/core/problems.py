"""Shared ensemble test problems (paper Fig. 5 submodel workload).

The batched Robertson kinetics problem is the canonical driver of the
ensemble subsystem: the example (``examples/batched_kinetics.py``), the
benchmark (``benchmarks/ensemble_bench.py``) and the test suite all
integrate the SAME problem, so it lives here once instead of as copies
that could drift apart.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batched_robertson(nsys: int):
    """Robertson kinetics with per-cell rate constants — ``nsys``
    independent 3-species systems whose stiffness varies cell to cell
    (k3 spans two orders of magnitude), the "large variations in
    stiffness" regime the paper warns about.

    Returns ``(f, jac, y0)``: ``f(t, y) -> (nsys, 3)`` and
    ``jac(t, y) -> (nsys, 3, 3)`` are vectorized over the batch with the
    rates closed over; ``y0`` is the standard ``[1, 0, 0]`` start.
    """
    key = jax.random.PRNGKey(0)
    k1 = 0.04 * jnp.ones((nsys,))
    k2 = 1e4 * (0.5 + jax.random.uniform(key, (nsys,)))
    k3 = 3e7 * 10.0 ** jax.random.uniform(jax.random.PRNGKey(1), (nsys,),
                                          minval=-1.0, maxval=1.0)

    def f(t, y):  # y: (nsys, 3)
        a, b, c = y[:, 0], y[:, 1], y[:, 2]
        r1, r2, r3 = k1 * a, k2 * b * c, k3 * b * b
        return jnp.stack([-r1 + r2, r1 - r2 - r3, r3], axis=1)

    def jac(t, y):
        a, b, c = y[:, 0], y[:, 1], y[:, 2]
        z = jnp.zeros_like(a)
        return jnp.stack([
            jnp.stack([-k1, k2 * c, k2 * b], axis=1),
            jnp.stack([k1, -k2 * c - 2 * k3 * b, -k2 * b], axis=1),
            jnp.stack([z, 2 * k3 * b, z], axis=1)], axis=1)

    y0 = jnp.concatenate([jnp.ones((nsys, 1)), jnp.zeros((nsys, 2))],
                         axis=1)
    return f, jac, y0
