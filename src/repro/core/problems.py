"""Shared ensemble test problems (paper Fig. 5 submodel workload).

The batched Robertson kinetics problem is the canonical driver of the
ensemble subsystem: the example (``examples/batched_kinetics.py``), the
benchmark (``benchmarks/ensemble_bench.py``) and the test suite all
integrate the SAME problem, so it lives here once instead of as copies
that could drift apart.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batched_robertson(nsys: int):
    """Robertson kinetics with per-cell rate constants — ``nsys``
    independent 3-species systems whose stiffness varies cell to cell
    (k3 spans two orders of magnitude), the "large variations in
    stiffness" regime the paper warns about.

    Returns ``(f, jac, y0)``: ``f(t, y) -> (nsys, 3)`` and
    ``jac(t, y) -> (nsys, 3, 3)`` are vectorized over the batch with the
    rates closed over; ``y0`` is the standard ``[1, 0, 0]`` start.
    """
    key = jax.random.PRNGKey(0)
    k1 = 0.04 * jnp.ones((nsys,))
    k2 = 1e4 * (0.5 + jax.random.uniform(key, (nsys,)))
    k3 = 3e7 * 10.0 ** jax.random.uniform(jax.random.PRNGKey(1), (nsys,),
                                          minval=-1.0, maxval=1.0)

    def f(t, y):  # y: (nsys, 3)
        a, b, c = y[:, 0], y[:, 1], y[:, 2]
        r1, r2, r3 = k1 * a, k2 * b * c, k3 * b * b
        return jnp.stack([-r1 + r2, r1 - r2 - r3, r3], axis=1)

    def jac(t, y):
        a, b, c = y[:, 0], y[:, 1], y[:, 2]
        z = jnp.zeros_like(a)
        return jnp.stack([
            jnp.stack([-k1, k2 * c, k2 * b], axis=1),
            jnp.stack([k1, -k2 * c - 2 * k3 * b, -k2 * b], axis=1),
            jnp.stack([z, 2 * k3 * b, z], axis=1)], axis=1)

    y0 = jnp.concatenate([jnp.ones((nsys, 1)), jnp.zeros((nsys, 2))],
                         axis=1)
    return f, jac, y0


def batched_robertson_soa(nsys: int):
    """Native SoA companions to :func:`batched_robertson` — the same
    per-cell rates (identical PRNG keys), with the system axis LAST:
    ``f_soa(t, y:(3,nsys)) -> (3,nsys)`` and ``jac_soa -> (3,3,nsys)``.

    Passing these to ``ensemble_bdf``/``ensemble_dirk`` (directly or via
    ``IVP(f_soa=..., jac_soa=...)``) makes the Newton hot loop fully
    conversion-free: the arithmetic is expression-for-expression the
    AoS form's, only the stacking axes differ, so trajectories stay
    bitwise-identical to the wrapped-AoS path (tests/test_soa_carry.py).
    """
    key = jax.random.PRNGKey(0)
    k1 = 0.04 * jnp.ones((nsys,))
    k2 = 1e4 * (0.5 + jax.random.uniform(key, (nsys,)))
    k3 = 3e7 * 10.0 ** jax.random.uniform(jax.random.PRNGKey(1), (nsys,),
                                          minval=-1.0, maxval=1.0)

    def f_soa(t, y):  # y: (3, nsys)
        a, b, c = y[0], y[1], y[2]
        r1, r2, r3 = k1 * a, k2 * b * c, k3 * b * b
        return jnp.stack([-r1 + r2, r1 - r2 - r3, r3], axis=0)

    def jac_soa(t, y):  # -> (3, 3, nsys)
        a, b, c = y[0], y[1], y[2]
        z = jnp.zeros_like(a)
        return jnp.stack([
            jnp.stack([-k1, k2 * c, k2 * b], axis=0),
            jnp.stack([k1, -k2 * c - 2 * k3 * b, -k2 * b], axis=0),
            jnp.stack([z, 2 * k3 * b, z], axis=0)], axis=0)

    return f_soa, jac_soa


def robertson_family():
    """Parametric Robertson kinetics for the serving front-end: the same
    3-species problem as :func:`batched_robertson`, but with the rate
    constants supplied as *per-request data* instead of closed over —
    ``params = {"k1": (nsys,), "k2": (nsys,), "k3": (nsys,)}`` rides the
    bundle as a traced argument, so requests with different chemistry
    share ONE trace-cache entry (the shape-bucketed jit cache never
    recompiles on new rate constants).

    Returns ``(f, jac, f_soa, jac_soa)`` with signatures
    ``f(t:(nsys,), y:(nsys,3), params) -> (nsys,3)`` etc.; state size
    n = 3.
    """

    def f(t, y, p):  # y: (nsys, 3)
        a, b, c = y[:, 0], y[:, 1], y[:, 2]
        r1, r2, r3 = p["k1"] * a, p["k2"] * b * c, p["k3"] * b * b
        return jnp.stack([-r1 + r2, r1 - r2 - r3, r3], axis=1)

    def jac(t, y, p):
        a, b, c = y[:, 0], y[:, 1], y[:, 2]
        k1, k2, k3 = p["k1"], p["k2"], p["k3"]
        z = jnp.zeros_like(a)
        return jnp.stack([
            jnp.stack([-k1, k2 * c, k2 * b], axis=1),
            jnp.stack([k1, -k2 * c - 2 * k3 * b, -k2 * b], axis=1),
            jnp.stack([z, 2 * k3 * b, z], axis=1)], axis=1)

    def f_soa(t, y, p):  # y: (3, nsys)
        a, b, c = y[0], y[1], y[2]
        r1, r2, r3 = p["k1"] * a, p["k2"] * b * c, p["k3"] * b * b
        return jnp.stack([-r1 + r2, r1 - r2 - r3, r3], axis=0)

    def jac_soa(t, y, p):  # -> (3, 3, nsys)
        a, b, c = y[0], y[1], y[2]
        k1, k2, k3 = p["k1"], p["k2"], p["k3"]
        z = jnp.zeros_like(a)
        return jnp.stack([
            jnp.stack([-k1, k2 * c, k2 * b], axis=0),
            jnp.stack([k1, -k2 * c - 2 * k3 * b, -k2 * b], axis=0),
            jnp.stack([z, 2 * k3 * b, z], axis=0)], axis=0)

    return f, jac, f_soa, jac_soa


def decay_chain_family(n: int = 6):
    """Parametric linear decay chain (n species) — the serving suite's
    second shape, so mixed-shape traffic exercises distinct buckets:
    ``dy_0/dt = -k_0 y_0``, ``dy_i/dt = k_{i-1} y_{i-1} - k_i y_i``,
    with per-request decay rates ``params = {"k": (nsys, n)}``.  Mildly
    stiff when the rates span decades; the Jacobian is lower bidiagonal.

    Returns ``(f, jac, f_soa, jac_soa)`` in the batch conventions of
    :func:`robertson_family`.
    """

    def f(t, y, p):  # y: (nsys, n)
        r = p["k"] * y
        return -r + jnp.concatenate(
            [jnp.zeros_like(r[:, :1]), r[:, :-1]], axis=1)

    def jac(t, y, p):  # -> (nsys, n, n)
        k = p["k"]
        J = -jax.vmap(jnp.diag)(k)
        sub = jax.vmap(lambda kk: jnp.diag(kk, k=-1))(k[:, :-1])
        return J + sub

    def f_soa(t, y, p):  # y: (n, nsys)
        r = p["k"].T * y
        return -r + jnp.concatenate(
            [jnp.zeros_like(r[:1]), r[:-1]], axis=0)

    def jac_soa(t, y, p):  # -> (n, n, nsys)
        return jnp.transpose(jac(t, y.T, p), (1, 2, 0))

    return f, jac, f_soa, jac_soa


def ensemble_brusselator(nsys: int, nx: int = 16, du: float = 0.02,
                         dv: float = 0.02, a: float = 1.0):
    """An ensemble of 1-D Brusselator reaction-diffusion systems — the
    sparse-Jacobian submodel workload (arXiv:2405.01713's many-
    independent-ODE-systems regime with *banded* per-system Jacobians).

    Each of the ``nsys`` members is the classic 2-species Brusselator
    on ``nx`` cells (no-flux boundaries), with a per-member reaction
    parameter ``b`` spanning the oscillatory threshold, so stiffness
    varies across the ensemble.  State layout is interleaved
    ``[u_0, v_0, u_1, v_1, ...]`` (n = 2*nx), which makes the Jacobian
    banded: dense 2x2 reaction blocks on the diagonal plus
    species-diagonal Laplacian coupling to the neighbor cells —
    fill fraction ~ 4/nx, the exploit-the-sparsity regime.

    Returns ``(f, jac, jac_sparsity, y0)``: batched RHS/Jacobian in the
    ensemble convention (``(t:(nsys,), y:(nsys, n))``), the static
    (n, n) boolean pattern, and a perturbed near-steady start.
    """
    n = 2 * nx
    bpar = jnp.linspace(1.8, 3.2, nsys)
    h2 = 1.0 / ((1.0 / max(nx, 2)) ** 2)

    def lap(w):                       # (nsys, nx), no-flux (reflecting)
        wl = jnp.concatenate([w[:, :1], w[:, :-1]], axis=1)
        wr = jnp.concatenate([w[:, 1:], w[:, -1:]], axis=1)
        return (wl - 2.0 * w + wr) * h2

    def f(t, y):                      # y: (nsys, 2*nx)
        u, v = y[:, 0::2], y[:, 1::2]
        uv2 = u * u * v
        fu = a - (bpar[:, None] + 1.0) * u + uv2 + du * lap(u)
        fv = bpar[:, None] * u - uv2 + dv * lap(v)
        return jnp.stack([fu, fv], axis=2).reshape(y.shape[0], n)

    def f_single(t1, y1, b1):
        u, v = y1[0::2], y1[1::2]
        ul = jnp.concatenate([u[:1], u[:-1]])
        ur = jnp.concatenate([u[1:], u[-1:]])
        vl = jnp.concatenate([v[:1], v[:-1]])
        vr = jnp.concatenate([v[1:], v[-1:]])
        uv2 = u * u * v
        fu = a - (b1 + 1.0) * u + uv2 + du * (ul - 2.0 * u + ur) * h2
        fv = b1 * u - uv2 + dv * (vl - 2.0 * v + vr) * h2
        return jnp.stack([fu, fv], axis=1).reshape(n)

    def jac(t, y):
        # per-member dense (n, n) Jacobians; ensemble BDF compresses
        # them to the banded pattern at lsetup when jac_sparsity is set
        tb = jnp.broadcast_to(jnp.asarray(t), (y.shape[0],))
        return jax.vmap(lambda t1, y1, b1: jax.jacfwd(
            lambda yy: f_single(t1, yy, b1))(y1))(tb, y, bpar)

    import numpy as np
    P = np.zeros((n, n), bool)
    for i in range(nx):
        P[2 * i:2 * i + 2, 2 * i:2 * i + 2] = True    # reaction block
        for j in (i - 1, i + 1):                      # Laplacian coupling
            if 0 <= j < nx:
                P[2 * i, 2 * j] = True                # u_i <- u_j
                P[2 * i + 1, 2 * j + 1] = True        # v_i <- v_j
    x = jnp.linspace(0.0, 1.0, nx)
    u0 = a + 0.1 * jnp.sin(2 * jnp.pi * x)
    v0 = (bpar / a)[:, None] + 0.1 * jnp.cos(2 * jnp.pi * x)[None, :]
    y0 = jnp.stack([jnp.broadcast_to(u0, (nsys, nx)), v0],
                   axis=2).reshape(nsys, n)
    return f, jac, P, y0
