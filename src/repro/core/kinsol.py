"""Nonlinear solvers (SUNNonlinearSolver / KINSOL analogs).

* :func:`newton_solve` — (modified/inexact) Newton iteration used by the
  implicit integrators.  The linear solve is a callback, so the same
  Newton code runs with matrix-free GMRES, dense direct, or the batched
  block-diagonal direct solver — the paper's class-encapsulation point.
* :func:`fixed_point_solve` — fixed-point iteration with Anderson
  acceleration (KINSOL FP / CVODE functional iteration).

Everything is while_loop-based and jit/vmap-safe.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.flatten_util import ravel_pytree

from . import dispatch as dv
from . import vector as nv
from .policies import ExecPolicy, XLA_FUSED


class NonlinStats(NamedTuple):
    iters: jnp.ndarray
    fnorm: jnp.ndarray
    converged: jnp.ndarray


def newton_solve(gfun: Callable, z0, lin_solve: Callable, *,
                 wnorm: Optional[Callable] = None, tol: float = 0.1,
                 max_iters: int = 4, damping: float = 1.0,
                 policy: ExecPolicy = XLA_FUSED):
    """Solve G(z) = 0 by Newton iteration.

    gfun      : z -> G(z)                    (pytree -> pytree)
    lin_solve : (z, rhs) -> dz  with  J_G(z) dz ≈ rhs
    wnorm     : pytree -> scalar; convergence test is wnorm(dz) < tol
                (defaults to RMS norm).  This mirrors CVODE/ARKODE where
                the Newton tolerance is relative to the integrator's WRMS
                weights and a fraction (0.1) of the error-test tolerance.

    Tolerances come from one place: integrators build their Newton
    config via :class:`repro.core.nonlinsol.NewtonSolver.from_options`
    (ODEOptions.newton_tol_fac / newton_max) rather than relying on the
    defaults here.
    """
    if wnorm is None:
        # tree_size is static — hoist it out of the traced loop body
        # instead of re-walking the pytree every Newton iteration
        n_static = nv.tree_size(z0)

        def wnorm(v):
            return jnp.sqrt(dv.dot(v, v, policy) / n_static)

    def cond(c):
        z, it, delta_norm, conv, div = c
        return (~conv) & (~div) & (it < max_iters)

    def body(c):
        z, it, prev_norm, conv, div = c
        g = gfun(z)
        dz = lin_solve(z, nv.scale(-1.0, g))
        z_new = dv.axpy(damping, dz, z, policy)
        dn = wnorm(dz)
        # CVODE-style convergence rate estimate: crate = dn/prev
        crate = jnp.where(it > 0, dn / jnp.maximum(prev_norm, 1e-30), 1.0)
        conv = (dn * jnp.minimum(1.0, crate) < tol)
        div = (it > 0) & (crate > 2.0)   # diverging -> give up, let the
        # integrator shrink h (ARKODE's convergence-failure path)
        return z_new, it + 1, dn, conv, div

    z, it, dn, conv, div = lax.while_loop(
        cond, body,
        (z0, jnp.zeros((), jnp.int32), jnp.zeros(()),
         jnp.zeros((), bool), jnp.zeros((), bool)))
    return z, NonlinStats(iters=it, fnorm=dn, converged=conv & ~div)


def fixed_point_solve(gfun: Callable, y0, *, m: int = 3, tol: float = 1e-9,
                      max_iters: int = 50, wnorm: Optional[Callable] = None):
    """Solve y = G(y) by Anderson-accelerated fixed-point iteration.

    Depth-m Anderson: keep the last m residual/value differences, solve
    the small least-squares problem min ||F_k - dF gamma||, combine.
    Matches KINSOL's Anderson acceleration (QR-free lstsq variant).
    """
    if wnorm is None:
        def wnorm(v):
            return jnp.sqrt(nv.dot(v, v) / nv.tree_size(v))

    y0_flat, unravel = ravel_pytree(y0)
    n = y0_flat.shape[0]
    dtype = y0_flat.dtype

    def gf(yf):
        return ravel_pytree(gfun(unravel(yf)))[0]

    dF = jnp.zeros((m, n), dtype)   # residual differences  f_k - f_{k-1}
    dG = jnp.zeros((m, n), dtype)   # g-value differences   g_k - g_{k-1}

    def cond(c):
        y, f_prev, g_prev, dF, dG, it, conv = c
        return (~conv) & (it < max_iters)

    def body(c):
        y, f_prev, g_prev, dF, dG, it, conv = c
        g = gf(y)
        f = g - y                     # residual
        # update difference histories (circular by shifting; masked for it==0)
        dF_new = jnp.where(it > 0, jnp.roll(dF, -1, axis=0).at[m - 1].set(f - f_prev), dF)
        dG_new = jnp.where(it > 0, jnp.roll(dG, -1, axis=0).at[m - 1].set(g - g_prev), dG)
        k = jnp.minimum(it, m)       # number of valid history rows
        # mask invalid rows to zero -> they contribute gamma = 0 via damped lstsq
        row_ids = jnp.arange(m)
        valid = (row_ids >= (m - k))[:, None]
        dFm = jnp.where(valid, dF_new, 0.0)
        # regularized normal equations (m is tiny: <= 5)
        A = dFm @ dFm.T + 1e-12 * jnp.eye(m, dtype=dtype)
        rhs = dFm @ f
        gamma = jnp.linalg.solve(A, rhs)
        y_and = g - gamma @ jnp.where(valid, dG_new, 0.0)
        y_next = jnp.where(it > 0, y_and, g)   # plain Picard on first iter
        dn = jnp.sqrt(jnp.sum((y_next - y) ** 2) / n)
        conv = dn < tol
        return y_next, f, g, dF_new, dG_new, it + 1, conv

    c0 = (y0_flat, jnp.zeros_like(y0_flat), jnp.zeros_like(y0_flat),
          dF, dG, jnp.zeros((), jnp.int32), jnp.zeros((), bool))
    y, f, g, dF, dG, it, conv = lax.while_loop(cond, body, c0)
    fn = jnp.sqrt(jnp.sum((gf(y) - y) ** 2) / n)
    return unravel(y), NonlinStats(iters=it, fnorm=fn, converged=conv)
