"""Root-finding / event detection (CVodeRootInit analog).

SUNDIALS integrators can monitor user functions g_i(t, y) and stop at
their roots (event detection: ignition, zero-crossings, switching
surfaces).  The classic algorithm: after each accepted step check for a
sign change of any g_i over [t_n, t_{n+1}]; if found, localize the root
with bisection/regula-falsi on the dense-output interpolant.

Here the integrator is jittable, so we implement event detection as a
wrapper around the adaptive ERK integrator: a while_loop that advances
step-by-step, detects the first sign change, then bisects on a cubic
Hermite interpolant (y, f available at both ends — the same dense output
CVODE uses between mesh points).  Everything stays pure-jax.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from . import vector as nv
from .arkode import ODEOptions, _erk_step, _ewt, _initial_h
from . import controller as ctrl
from .butcher import ButcherTable


class EventResult(NamedTuple):
    t_event: jnp.ndarray      # time of the first root (or tf if none)
    y_event: jnp.ndarray      # state at the root
    found: jnp.ndarray        # bool
    which: jnp.ndarray        # index of the triggered g_i
    steps: jnp.ndarray


def _hermite(t0, y0, f0, t1, y1, f1, t):
    """Cubic Hermite dense output on [t0, t1] (CVODE's interpolant)."""
    h = t1 - t0
    s = (t - t0) / h
    h00 = (1 + 2 * s) * (1 - s) ** 2
    h10 = s * (1 - s) ** 2
    h01 = s * s * (3 - 2 * s)
    h11 = s * s * (s - 1)
    return jax.tree_util.tree_map(
        lambda a, fa, b, fb: h00 * a + h10 * h * fa + h01 * b + h11 * h * fb,
        y0, f0, y1, f1)


def erk_integrate_with_events(f: Callable, g: Callable, y0, t0, tf,
                              table: ButcherTable,
                              opts: ODEOptions = ODEOptions(),
                              n_bisect: int = 40) -> EventResult:
    """Integrate y' = f(t,y), stopping at the first root of any component
    of g(t, y) (vector-valued).  Returns the event (or tf, found=False).
    """
    t0 = jnp.asarray(t0, dtype=jnp.result_type(float))
    tf = jnp.asarray(tf, dtype=t0.dtype)
    h0 = jnp.where(opts.h0 > 0, opts.h0,
                   _initial_h(f, t0, y0, tf, opts.rtol, opts.atol))
    p = max(table.emb_order + 1, 2)
    g0 = jnp.atleast_1d(g(t0, y0))

    class Carry(NamedTuple):
        t: jnp.ndarray
        y: jnp.ndarray
        gv: jnp.ndarray
        h: jnp.ndarray
        cst: ctrl.ControllerState
        steps: jnp.ndarray
        attempts: jnp.ndarray
        hit_t: jnp.ndarray
        hit_which: jnp.ndarray
        found: jnp.ndarray

    def cond(c: Carry):
        return ((c.t < tf * (1 - 1e-12) - 1e-300) & (~c.found) &
                (c.attempts < opts.max_steps))

    def body(c: Carry):
        h = jnp.minimum(c.h, tf - c.t)
        y_new, y_err, _ = _erk_step(f, c.t, c.y, h, table)
        w = _ewt(c.y, opts.rtol, opts.atol)
        err = nv.wrms_norm(y_err, w)
        bad = ~jnp.isfinite(err)
        err = jnp.where(bad, 2.0, err)
        accept = (err <= 1.0) & ~bad
        eta, cst = ctrl.eta_from_error(opts.controller, c.cst, err, p,
                                       after_failure=~accept)
        cst = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), cst, c.cst)
        t1 = c.t + h
        g1 = jnp.atleast_1d(g(t1, y_new))
        # a root exists in (t, t1] iff some component changes sign
        crossed = (jnp.sign(c.gv) * jnp.sign(g1) < 0) | (g1 == 0.0)
        any_cross = accept & jnp.any(crossed)
        which = jnp.argmax(crossed).astype(jnp.int32)

        def localize(_):
            f0 = f(c.t, c.y)
            f1v = f(t1, y_new)

            def bisect(i, ab):
                lo, hi = ab
                mid = 0.5 * (lo + hi)
                ym = _hermite(c.t, c.y, f0, t1, y_new, f1v, mid)
                gm = jnp.atleast_1d(g(mid, ym))[which]
                glo_y = _hermite(c.t, c.y, f0, t1, y_new, f1v, lo)
                glo = jnp.atleast_1d(g(lo, glo_y))[which]
                same = jnp.sign(gm) == jnp.sign(glo)
                return (jnp.where(same, mid, lo), jnp.where(same, hi, mid))

            lo, hi = lax.fori_loop(0, n_bisect, bisect, (c.t, t1))
            return 0.5 * (lo + hi)

        hit_t = lax.cond(any_cross, localize, lambda _: c.hit_t,
                         operand=None)
        t_n = jnp.where(accept, t1, c.t)
        y_n = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), y_new, c.y)
        g_n = jnp.where(accept, g1, c.gv)
        h_n = jnp.clip(h * eta, opts.hmin, opts.hmax)
        return Carry(t_n, y_n, g_n, h_n, cst,
                     c.steps + accept.astype(jnp.int32),
                     c.attempts + 1,
                     jnp.where(any_cross, hit_t, c.hit_t),
                     jnp.where(any_cross, which, c.hit_which),
                     c.found | any_cross)

    c0 = Carry(t0, y0, g0, h0, ctrl.init_state(t0.dtype),
               jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
               tf, jnp.zeros((), jnp.int32), jnp.zeros((), bool))
    c = lax.while_loop(cond, body, c0)
    # state at the event via one final dense-output evaluation: re-take a
    # small exact step to hit_t from the last accepted point <= hit_t
    # (cheap: the integrator state is already just past the root)
    y_event = c.y

    def refine(_):
        # integrate precisely from the last point BEFORE the event is not
        # tracked; use Hermite between the bracketing states we kept:
        # c.y is post-step; take a fixed small ERK step backward
        hback = c.hit_t - c.t

        def fneg(t, y):
            return f(t, y)

        ye, _, _ = _erk_step(fneg, c.t, c.y, hback, table)
        return ye

    y_event = lax.cond(c.found, refine, lambda _: c.y, operand=None)
    return EventResult(t_event=jnp.where(c.found, c.hit_t, tf),
                       y_event=y_event, found=c.found,
                       which=c.hit_which, steps=c.steps)
