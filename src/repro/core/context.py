"""SUNContext analog: one object owning the run-wide execution state.

SUNDIALS v6 threads a ``SUNContext`` through every object constructor so
that profiling, logging, and error handling have a single owner instead
of ad-hoc globals.  Our analog bundles the three per-run singletons this
codebase grew separately:

* the :class:`~repro.core.policies.ExecPolicy` (which kernel backend and
  tile shapes every dispatched vector/matrix op uses),
* the :class:`~repro.core.memory.MemoryHelper` (workspace registration
  and the high-water audit — the SUNMemoryHelper job), and
* run-wide counters (integrations run, accepted steps, Newton
  iterations) accumulated across :func:`repro.core.ivp.integrate` calls.

A ``Context`` is cheap and mutable; create one per logical run and pass
it to ``integrate(..., ctx=ctx)``.  Everything still works without one —
``integrate`` creates a private throwaway context — but then the
counters and the memory high-water mark are discarded with it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax

from ..observability.config import ObservabilityConfig
from .memory import MemoryHelper
from .policies import ExecPolicy, XLA_FUSED


def _counter_dict():
    return {"integrations": 0, "steps": 0, "step_attempts": 0,
            "newton_iters": 0, "lin_iters": 0}


@dataclass
class Context:
    """ExecPolicy + MemoryHelper + run-wide counters (SUNContext analog)."""

    policy: ExecPolicy = XLA_FUSED
    memory: MemoryHelper = field(default_factory=MemoryHelper)
    counters: dict = field(default_factory=_counter_dict)
    #: the serving front-end's shape-bucketed jit/trace cache
    #: (:class:`repro.serve.solver.trace_cache.TraceCache`), attached by
    #: :class:`repro.serve.solver.server.SolverServer` so its hit/miss/
    #: evict counters surface through :meth:`dispatch_report`; None for
    #: contexts that never served traffic.
    trace_cache: Optional[Any] = None
    #: observability switchboard — everything OFF by default; the
    #: disabled path is jaxpr-identical to a no-observability build
    #: (sunlint ``telemetry-purity``).
    observability: ObservabilityConfig = field(
        default_factory=ObservabilityConfig)
    _profiler: Optional[Any] = field(default=None, repr=False,
                                     compare=False)
    _logger: Optional[Any] = field(default=None, repr=False,
                                   compare=False)

    # -- observability singletons (SUNProfiler / SUNLogger analogs) ----------

    @property
    def profiler(self) -> Any:
        """The context-owned :class:`~repro.observability.profiler.
        Profiler`, built lazily from :attr:`observability` (a disabled
        profiler when ``profile=False`` — regions are shared no-ops)."""
        if self._profiler is None:
            from ..observability.profiler import Profiler
            obs = self.observability
            self._profiler = Profiler(enabled=obs.profile,
                                      sync=obs.profile_sync)
        return self._profiler

    @property
    def logger(self) -> Any:
        """The context-owned :class:`~repro.observability.logger.
        EventLogger` (disabled, dropping every event, when
        ``log_level`` is None)."""
        if self._logger is None:
            from ..observability.logger import EventLogger
            obs = self.observability
            self._logger = EventLogger(level=obs.log_level,
                                       path=obs.log_path)
        return self._logger

    def options(self, **kw) -> Any:
        """Build :class:`~repro.core.arkode.ODEOptions` bound to this
        context's policy (kwargs override any field, including policy)."""
        from .arkode import ODEOptions
        kw.setdefault("policy", self.policy)
        return ODEOptions(**kw)

    # -- cost-model-driven dispatch ('auto' backend) -------------------------

    @property
    def autotune(self) -> Any:
        """The :class:`~repro.core.autotune.Resolver` for this context's
        policy device — loading the persisted ``.autotune/<device>.json``
        cache on first touch.  The resolver is process-wide per device
        (ExecPolicy must stay a hashable value type), so the context is
        the owning front-end, not a second copy."""
        from . import autotune
        return autotune.get_resolver(self.policy.device_name())

    def dispatch_report(self) -> dict:
        """Inspectable record of every ``backend='auto'`` decision made
        for this context's device — per-signature backend/tile/source —
        plus the model-vs-measurement audit over the whole autotune
        cache (agreement fraction and explicit mispredictions).  When a
        serving front-end owns this context, the report additionally
        carries its trace-cache counters under ``"trace_cache"``
        (hits / misses / evictions / size — the no-steady-state-
        recompiles audit)."""
        report = dict(self.autotune.report())
        if self.trace_cache is not None:
            report["trace_cache"] = self.trace_cache.stats()
        return report

    # -- counter accumulation ------------------------------------------------

    @staticmethod
    def _concrete(x) -> Optional[int]:
        """int(x) for concrete scalars/arrays; None for tracers."""
        if x is None or isinstance(x, jax.core.Tracer):
            return None
        try:
            import numpy as np
            return int(np.sum(np.asarray(x)))
        except Exception:
            return None

    def record(self, stats: Any, nli=None) -> None:
        """Fold one integration's stats into the run-wide counters.

        Works with both :class:`~repro.core.arkode.IntegratorStats`
        (scalars) and :class:`~repro.core.batched.EnsembleStats`
        (per-system arrays — summed).  Inside a jit trace the values are
        tracers and accumulation is skipped (counters are host-side).
        """
        self.counters["integrations"] += 1
        for key, name in (("steps", "steps"),
                          ("step_attempts", "attempts"),
                          ("newton_iters", "nni")):
            v = self._concrete(getattr(stats, name, None))
            if v is not None:
                self.counters[key] += v
        v = self._concrete(nli)
        if v is not None:
            self.counters["lin_iters"] += v
