"""N_Vector analog: streaming + reduction operations over JAX pytrees.

The SUNDIALS ``N_Vector`` class defines two families of operations:

* **streaming** ops (elementwise, no communication): ``N_VLinearSum``,
  ``N_VConst``, ``N_VProd``, ``N_VDiv``, ``N_VScale``, ``N_VAbs``,
  ``N_VInv``, ``N_VAddConst``, ``N_VCompare`` and the fused variants
  (``N_VLinearCombination``, ``N_VScaleAddMulti``, ...).
* **reduction** ops (produce a scalar, require a global reduction in the
  distributed setting): ``N_VDotProd``, ``N_VMaxNorm``, ``N_VWrmsNorm``,
  ``N_VMin``, ``N_VL1Norm``, ``N_VWL2Norm``, ``N_VConstrMask``,
  ``N_VMinQuotient``, ``N_VInvTest``.

Here a "vector" is any JAX pytree of arrays (a flat ``jnp.ndarray``, a
tuple of arrays — the ManyVector case — or a full parameter pytree).
Streaming ops map elementwise over leaves; reductions reduce over every
leaf and combine.

The :class:`MeshVector` mirrors the paper's ``MPIPlusX`` vector: it pairs
pytree data with the *name of a mesh axis*. Streaming ops remain purely
node-local; reduction ops perform the node-local partial reduction and
then a single collective (``lax.psum`` etc.) over the mesh axis — exactly
the MPI_Allreduce the MPIPlusX vector appends. Two execution modes exist:

* ``gspmd`` — data are global arrays with ``NamedSharding``; the ops are
  ordinary jnp code and XLA's SPMD partitioner inserts the collectives.
* ``explicit`` — ops run inside ``shard_map`` and issue ``lax.psum`` /
  ``lax.pmax`` themselves (the literal MPIPlusX structure).

Both modes produce bit-identical math; tests assert so.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax, tree_util

from .policies import ExecPolicy, XLA_FUSED

Pytree = Any

# ---------------------------------------------------------------------------
# Leaf helpers
# ---------------------------------------------------------------------------


def _tmap(f: Callable, *trees: Pytree) -> Pytree:
    return tree_util.tree_map(f, *trees)


def _treduce(per_leaf: Callable, combine: Callable, tree: Pytree, init):
    leaves = tree_util.tree_leaves(tree)
    acc = init
    for leaf in leaves:
        acc = combine(acc, per_leaf(leaf))
    return acc


def tree_size(tree: Pytree) -> int:
    """Global number of elements (static)."""
    return sum(int(x.size) for x in tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# Streaming operations (N_V* analogs).  Pure elementwise jnp — XLA fuses.
# ---------------------------------------------------------------------------


def _keep_dtype(out, *operands):
    """SUNDIALS realtype semantics: ops preserve the operand dtype — a
    float64 scalar coefficient (e.g. the integrator's step size under
    x64) must not upcast a float32 state pytree (while_loop carries would
    change type)."""
    want = jnp.result_type(*operands)
    return out.astype(want) if out.dtype != want else out


def linear_sum(a, x: Pytree, b, y: Pytree) -> Pytree:
    """z = a*x + b*y   (N_VLinearSum)."""
    return _tmap(lambda xl, yl: _keep_dtype(a * xl + b * yl, xl, yl), x, y)


def const_like(c, x: Pytree) -> Pytree:
    """z_i = c   (N_VConst)."""
    return _tmap(lambda xl: jnp.full_like(xl, c), x)


def prod(x: Pytree, y: Pytree) -> Pytree:
    """z = x .* y   (N_VProd)."""
    return _tmap(jnp.multiply, x, y)


def div(x: Pytree, y: Pytree) -> Pytree:
    """z = x ./ y   (N_VDiv)."""
    return _tmap(jnp.divide, x, y)


def scale(c, x: Pytree) -> Pytree:
    """z = c*x   (N_VScale)."""
    return _tmap(lambda xl: _keep_dtype(c * xl, xl), x)


def vabs(x: Pytree) -> Pytree:
    """z = |x|   (N_VAbs)."""
    return _tmap(jnp.abs, x)


def inv(x: Pytree) -> Pytree:
    """z = 1./x   (N_VInv)."""
    return _tmap(lambda xl: 1.0 / xl, x)


def add_const(x: Pytree, b) -> Pytree:
    """z = x + b   (N_VAddConst)."""
    return _tmap(lambda xl: _keep_dtype(xl + b, xl), x)


def compare(c, x: Pytree) -> Pytree:
    """z_i = 1 if |x_i| >= c else 0   (N_VCompare)."""
    return _tmap(lambda xl: (jnp.abs(xl) >= c).astype(xl.dtype), x)


def axpy(a, x: Pytree, y: Pytree) -> Pytree:
    return _tmap(lambda xl, yl: _keep_dtype(a * xl + yl, xl, yl), x, y)


# Fused streaming ops (the paper's N_VLinearCombination & friends).


def linear_combination(coeffs: Sequence, vecs: Sequence[Pytree]) -> Pytree:
    """z = sum_k c_k * X_k   (N_VLinearCombination), fused in one pass."""
    assert len(coeffs) == len(vecs) and len(vecs) >= 1

    def leaf_comb(*leaves):
        acc = coeffs[0] * leaves[0]
        for c, l in zip(coeffs[1:], leaves[1:]):
            acc = acc + c * l
        return _keep_dtype(acc, *leaves)

    return _tmap(leaf_comb, *vecs)


def scale_add_multi(coeffs: Sequence, x: Pytree, ys: Sequence[Pytree]):
    """Z_k = c_k * x + Y_k   (N_VScaleAddMulti)."""
    return [_tmap(lambda xl, yl, c=c: _keep_dtype(c * xl + yl, xl, yl),
                  x, y) for c, y in zip(coeffs, ys)]


# ---------------------------------------------------------------------------
# Reduction operations.
# ---------------------------------------------------------------------------


def dot(x: Pytree, y: Pytree):
    """<x, y>   (N_VDotProd).

    Implemented as an all-axis sum of the elementwise product — NOT
    ``jnp.vdot`` — because vdot reshapes to 1-D, and under GSPMD a
    reshape of a tensor sharded on an interior dim cannot be partitioned:
    the partitioner replicates it (a full all-gather of e.g. the 917 GB
    stacked expert gradients; see EXPERIMENTS §Perf 'grad-norm-reshape').
    A shape-preserving reduction partitions cleanly into local reduce +
    one psum.
    """
    leaves_x = tree_util.tree_leaves(x)
    leaves_y = tree_util.tree_leaves(y)
    acc = jnp.zeros((), dtype=jnp.result_type(
        *(l.dtype for l in leaves_x), *(l.dtype for l in leaves_y)))
    for xl, yl in zip(leaves_x, leaves_y):
        acc = acc + jnp.sum(xl * yl)
    return acc


def max_norm(x: Pytree):
    """max |x_i|   (N_VMaxNorm)."""
    return _treduce(lambda l: jnp.max(jnp.abs(l)), jnp.maximum, x,
                    jnp.zeros(()))


def vmin(x: Pytree):
    """min x_i   (N_VMin)."""
    return _treduce(jnp.min, jnp.minimum, x, jnp.full((), jnp.inf))


def l1_norm(x: Pytree):
    """sum |x_i|   (N_VL1Norm)."""
    return _treduce(lambda l: jnp.sum(jnp.abs(l)), jnp.add, x, jnp.zeros(()))


def wrms_norm(x: Pytree, w: Pytree):
    """sqrt( (1/N) sum (x_i w_i)^2 )   (N_VWrmsNorm) — THE integrator norm."""
    n = tree_size(x)
    xw = prod(x, w)
    ss = dot(xw, xw)
    return jnp.sqrt(ss / n)


def wrms_norm_mask(x: Pytree, w: Pytree, mask: Pytree):
    """N_VWrmsNormMask: only entries with mask>0 contribute."""
    n = tree_size(x)
    xm = prod(prod(x, w), mask)
    return jnp.sqrt(dot(xm, xm) / n)


def wl2_norm(x: Pytree, w: Pytree):
    """sqrt( sum (x_i w_i)^2 )   (N_VWL2Norm)."""
    xw = prod(x, w)
    return jnp.sqrt(dot(xw, xw))


def constr_mask(c: Pytree, x: Pytree):
    """N_VConstrMask: returns (all_ok, mask of violations).

    c_i =  2 : x_i >  0 required;  1 : x_i >= 0;  0 : none;
    c_i = -1 : x_i <= 0;          -2 : x_i <  0.
    """
    def leaf(cl, xl):
        viol = jnp.where(jnp.abs(cl) > 1.5,
                         xl * cl <= 0.0,          # strict
                         jnp.where(jnp.abs(cl) > 0.5, xl * cl < 0.0, False))
        return viol.astype(xl.dtype)

    m = _tmap(leaf, c, x)
    ok = l1_norm(m) == 0
    return ok, m


def min_quotient(num: Pytree, den: Pytree):
    """min num_i/den_i over den_i != 0   (N_VMinQuotient)."""
    def leaf(nl, dl):
        q = jnp.where(dl != 0, nl / jnp.where(dl != 0, dl, 1.0), jnp.inf)
        return jnp.min(q)

    return functools.reduce(
        jnp.minimum,
        [leaf(nl, dl) for nl, dl in zip(tree_util.tree_leaves(num),
                                        tree_util.tree_leaves(den))],
        jnp.full((), jnp.inf))


def inv_test(x: Pytree):
    """N_VInvTest: z = 1/x where x != 0; returns (no_zero_found, z)."""
    def leaf(xl):
        return jnp.where(xl != 0, 1.0 / jnp.where(xl != 0, xl, 1.0), 0.0)

    z = _tmap(leaf, x)
    has_zero = _treduce(lambda l: jnp.any(l == 0), jnp.logical_or, x,
                        jnp.zeros((), dtype=bool))
    return jnp.logical_not(has_zero), z


def dot_prod_multi(x: Pytree, ys: Sequence[Pytree]):
    """d_k = <x, Y_k>   (N_VDotProdMulti) — one fused pass."""
    return jnp.stack([dot(x, y) for y in ys])


# ---------------------------------------------------------------------------
# MeshVector — the MPIPlusX analog.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshVectorSpec:
    """Pairs node-local vector data with mesh axes for global reductions.

    ``axis_names`` lists the mesh axes across which this vector's data is
    *distributed* (the "MPI communicator").  Streaming ops never touch
    them; reduction ops finish with one collective over these axes.

    ``mode`` selects 'gspmd' (rely on jit+NamedSharding to insert the
    collectives) or 'explicit' (ops must run inside shard_map and issue
    lax collectives themselves — the literal MPIPlusX structure).

    ``policy`` selects the node-local op backend (jnp vs fused Pallas
    kernels) via :mod:`repro.core.dispatch` — the paper's per-vector
    ExecPolicy: collectives are unchanged, only the node-local partials
    and streaming ops swap implementation.
    """

    axis_names: tuple = ()
    mode: str = "gspmd"
    policy: ExecPolicy = XLA_FUSED


class MeshVector:
    """MPIPlusX analog: node-local data + mesh-axis 'communicator'.

    In 'explicit' mode, the reduction methods must execute inside a
    ``shard_map`` context over ``spec.axis_names`` — they perform a
    node-local partial reduction followed by exactly one collective, just
    as MPIPlusX performs the node-local op then ``MPI_Allreduce``.
    """

    def __init__(self, data: Pytree, spec: MeshVectorSpec = MeshVectorSpec()):
        self.data = data
        self.spec = spec

    # -- plumbing so MeshVector is itself a pytree ------------------------
    def tree_flatten(self):
        return (self.data,), self.spec

    @classmethod
    def tree_unflatten(cls, spec, children):
        return cls(children[0], spec)

    def wrap(self, data: Pytree) -> "MeshVector":
        return MeshVector(data, self.spec)

    def _dv(self):
        # function-level import: dispatch imports this module's jnp ops
        from . import dispatch
        return dispatch

    # -- streaming ops: purely node-local ---------------------------------
    def linear_sum(self, a, b, other: "MeshVector") -> "MeshVector":
        return self.wrap(self._dv().linear_sum(a, self.data, b, other.data,
                                               self.spec.policy))

    def scale(self, c) -> "MeshVector":
        return self.wrap(scale(c, self.data))

    def const(self, c) -> "MeshVector":
        return self.wrap(const_like(c, self.data))

    def prod(self, other: "MeshVector") -> "MeshVector":
        return self.wrap(prod(self.data, other.data))

    def div(self, other: "MeshVector") -> "MeshVector":
        return self.wrap(div(self.data, other.data))

    def abs(self) -> "MeshVector":
        return self.wrap(vabs(self.data))

    def inv(self) -> "MeshVector":
        return self.wrap(inv(self.data))

    def add_const(self, b) -> "MeshVector":
        return self.wrap(add_const(self.data, b))

    # -- reductions: node-local partial + one collective -------------------
    def _finish_sum(self, partial):
        if self.spec.mode == "explicit" and self.spec.axis_names:
            return lax.psum(partial, self.spec.axis_names)
        return partial  # gspmd mode: jit/GSPMD already made this global

    def _finish_max(self, partial):
        if self.spec.mode == "explicit" and self.spec.axis_names:
            return lax.pmax(partial, self.spec.axis_names)
        return partial

    def _finish_min(self, partial):
        if self.spec.mode == "explicit" and self.spec.axis_names:
            return lax.pmin(partial, self.spec.axis_names)
        return partial

    def dot(self, other: "MeshVector"):
        return self._finish_sum(self._dv().dot(self.data, other.data,
                                               self.spec.policy))

    def l1_norm(self):
        return self._finish_sum(l1_norm(self.data))

    def max_norm(self):
        return self._finish_max(max_norm(self.data))

    def min(self):
        return self._finish_min(vmin(self.data))

    def wrms_norm(self, w: "MeshVector", global_size: int | None = None):
        """WRMS norm; in explicit mode the caller must pass the GLOBAL
        element count (node-local tree_size is the shard size only)."""
        n = global_size if global_size is not None else tree_size(self.data)
        ss = self._finish_sum(self._dv().wrms_ss(self.data, w.data,
                                                 self.spec.policy))
        return jnp.sqrt(ss / n)


tree_util.register_pytree_node(
    MeshVector, MeshVector.tree_flatten, MeshVector.tree_unflatten)


# ---------------------------------------------------------------------------
# ManyVector — wrap n vectors into one cohesive vector (paper §4).
# In pytree land a ManyVector is simply a tuple of subvector pytrees; we
# provide a thin named wrapper for API parity and provenance.
# ---------------------------------------------------------------------------


def many_vector(*subvectors: Pytree) -> tuple:
    """Combine subvectors into a single cohesive vector (tuple pytree)."""
    return tuple(subvectors)


def many_vector_num_subvectors(mv: tuple) -> int:
    return len(mv)
