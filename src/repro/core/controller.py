"""Step-size controllers (SUNAdaptController analogs).

SUNDIALS controls the step with eta = h_new/h_old computed from the WRMS
error estimate of the embedded pair, with safety factor, growth clamps
and special-casing of the first step / post-failure steps.  We implement
the I, PI and PID controllers with ARKODE's default constants.

All functions are pure and jit-safe: state is a small NamedTuple.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class ControllerState(NamedTuple):
    err_prev: jnp.ndarray      # eps_{n-1}
    err_prev2: jnp.ndarray     # eps_{n-2}


def init_state(dtype=jnp.float64) -> ControllerState:
    one = jnp.ones((), dtype=dtype)
    return ControllerState(err_prev=one, err_prev2=one)


class ControllerConfig(NamedTuple):
    kind: str = "pi"           # 'i' | 'pi' | 'pid'
    safety: float = 0.96       # ARKODE default
    eta_max_first: float = 10000.0
    eta_max: float = 20.0      # ARKODE growth clamp
    eta_min: float = 0.1
    eta_max_fail: float = 0.3  # shrink cap after an error-test failure
    small_nef: int = 2
    # PI gains (ARKODE defaults k1=0.8, k2=0.31 applied with 1/(p+1))
    k1: float = 0.8
    k2: float = 0.31
    k3: float = 0.1


def eta_from_error(cfg: ControllerConfig, state: ControllerState,
                   err: jnp.ndarray, order: int,
                   after_failure: jnp.ndarray) -> tuple:
    """Compute eta = h_new/h and the updated controller state.

    ``err`` is the WRMS norm of the scaled local error (<=1 means accept).
    ``order`` is the order of the *embedded* estimate + 1 (method order
    used for the exponent, per ARKODE convention p = emb_order + 1).
    """
    e = jnp.maximum(err, 1e-10)
    p = jnp.asarray(order, dtype=e.dtype)  # may be traced (BDF order ramp)
    e1 = jnp.maximum(state.err_prev, 1e-10)
    e2 = jnp.maximum(state.err_prev2, 1e-10)

    if cfg.kind == "i":
        eta = e ** (-1.0 / p)
    elif cfg.kind == "pi":
        eta = e ** (-cfg.k1 / p) * e1 ** (cfg.k2 / p)
    else:  # pid
        eta = e ** (-cfg.k1 / p) * e1 ** (cfg.k2 / p) * e2 ** (-cfg.k3 / p)

    eta = cfg.safety * eta
    eta = jnp.clip(eta, cfg.eta_min, cfg.eta_max)
    # after an error-test failure only allow shrinking (ARKODE etamxf)
    eta = jnp.where(after_failure, jnp.minimum(eta, cfg.eta_max_fail), eta)
    new_state = ControllerState(err_prev=e, err_prev2=e1)
    return eta, new_state
