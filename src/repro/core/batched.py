"""Ensemble (submodel) integration — the paper's Fig. 5 use case, TPU-native.

SUNDIALS' submodel pattern: many small independent ODE systems (one per
grid cell) are grouped into bundles and integrated concurrently by
distinct CVODE instances on different CUDA streams.  On TPU, concurrency
comes from *batching*: one vectorized integrator advances every system
simultaneously, each with its own adaptive step size; systems that have
reached ``tf`` are masked no-ops inside the shared ``while_loop``.
This removes the stream/thread machinery entirely while preserving the
semantics (independent adaptive integrations) — see DESIGN.md §2.

The block-diagonal Jacobian of Fig. 1 appears here as the vmapped dense
(b×b) stage Jacobian; the batched Newton solve uses the batched
Gauss-Jordan / Pallas block-solve kernel.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import controller as ctrl
from .arkode import ODEOptions
from .butcher import ButcherTable
from .direct import gauss_jordan_batched
from .policies import ExecPolicy, XLA_FUSED


class EnsembleStats(NamedTuple):
    steps: jnp.ndarray       # (nsys,) accepted steps per system
    attempts: jnp.ndarray
    netf: jnp.ndarray
    nni: jnp.ndarray
    success: jnp.ndarray     # (nsys,) bool


def ensemble_erk_integrate(f: Callable, y0: jnp.ndarray, t0, tf,
                           table: ButcherTable,
                           opts: ODEOptions = ODEOptions()):
    """Adaptive ERK over a batch of independent systems.

    f  : (t:(nsys,), y:(nsys, n)) -> (nsys, n)   vectorized RHS
    y0 : (nsys, n);  t0, tf broadcastable to (nsys,)
    Each system carries its own (t, h); the loop runs until all done.

    Tables without an embedding (``table.b_emb is None``) provide no
    error estimate, so adaptivity is impossible: the integrator falls
    back to fixed-step semantics (every step accepted, h never grown)
    instead of silently disabling error control and letting h run away
    at ``eta_max``.
    """
    nsys, n = y0.shape
    has_emb = table.b_emb is not None
    dtype = y0.dtype
    t0 = jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,))
    tf = jnp.broadcast_to(jnp.asarray(tf, dtype), (nsys,))
    # opts.h0 seeds the step; without an embedding it IS the fixed step.
    h = jnp.where(opts.h0 > 0, jnp.full((nsys,), opts.h0, dtype),
                  jnp.maximum(1e-6 * (tf - t0), 1e-12))
    p = max(table.emb_order + 1, 2)

    def cond(c):
        t, y, h, e1, steps, att, netf, stall = c
        return jnp.any((t < tf * (1 - 1e-12)) & (~stall)) & \
            jnp.all(att < opts.max_steps)

    def body(c):
        t, y, h, e1, steps, att, netf, stall = c
        active = (t < tf * (1 - 1e-12)) & (~stall)
        hs = jnp.minimum(h, tf - t)                      # (nsys,)
        ks = []
        for i in range(table.stages):
            yi = y
            for j in range(i):
                if table.A[i][j] != 0.0:
                    yi = yi + (hs * table.A[i][j])[:, None] * ks[j]
            ks.append(f(t + table.c[i] * hs, yi))
        y_new = y
        for bi, k in zip(table.b, ks):
            if bi != 0.0:
                y_new = y_new + (hs * bi)[:, None] * k
        y_err = jnp.zeros_like(y)
        if has_emb:
            for bi, bh, k in zip(table.b, table.b_emb, ks):
                if (bi - bh) != 0.0:
                    y_err = y_err + (hs * (bi - bh))[:, None] * k
        w = 1.0 / (opts.rtol * jnp.abs(y) + opts.atol)
        err = jnp.sqrt(jnp.mean((y_err * w) ** 2, axis=1))  # (nsys,)
        bad = ~jnp.isfinite(err) | ~jnp.all(jnp.isfinite(y_new), axis=1)
        err = jnp.where(bad, 2.0, err)
        accept = (err <= 1.0) & ~bad & active
        if has_emb:
            # per-system PI controller
            e = jnp.maximum(err, 1e-10)
            eprev = jnp.maximum(e1, 1e-10)
            eta = opts.controller.safety * e ** (-opts.controller.k1 / p) * \
                eprev ** (opts.controller.k2 / p)
            eta = jnp.clip(eta, opts.controller.eta_min,
                           opts.controller.eta_max)
            eta = jnp.where(accept | ~active, eta, jnp.minimum(eta, 0.3))
        else:
            # no embedding -> no error signal: keep h fixed (shrink only
            # on a non-finite step so the loop can still bail out)
            e = jnp.maximum(err, 1e-10)
            eta = jnp.where(bad & active, 0.5, 1.0)
        t = jnp.where(accept, t + hs, t)
        y = jnp.where(accept[:, None], y_new, y)
        h_next = jnp.where(active, jnp.clip(hs * eta, 1e-14, None), h)
        stall = stall | (active & (h_next < 1e-13))
        e1 = jnp.where(accept, e, e1)
        return (t, y, h_next, e1,
                steps + accept.astype(jnp.int32),
                att + active.astype(jnp.int32),
                netf + (active & ~accept).astype(jnp.int32), stall)

    zero = jnp.zeros((nsys,), jnp.int32)
    c = (t0, y0, h, jnp.ones((nsys,), dtype), zero, zero, zero,
         jnp.zeros((nsys,), bool))
    t, y, h, e1, steps, att, netf, stall = lax.while_loop(cond, body, c)
    return y, EnsembleStats(steps=steps, attempts=att, netf=netf,
                            nni=zero, success=t >= tf * (1 - 1e-10))


def ensemble_dirk_integrate(fi: Callable, jac: Callable, y0: jnp.ndarray,
                            t0, tf, table: ButcherTable,
                            opts: ODEOptions = ODEOptions(),
                            policy: ExecPolicy = XLA_FUSED,
                            newton_iters: int = 4):
    """Adaptive DIRK over a batch of independent *stiff* systems with the
    batched block-diagonal Newton solve (the paper's submodel solver).

    fi  : (t:(nsys,), y:(nsys,n)) -> (nsys,n)
    jac : (t:(nsys,), y:(nsys,n)) -> (nsys,n,n)   per-system Jacobian
    Newton matrix M_j = I - h a_ii J_j is solved for ALL systems in one
    batched Gauss-Jordan (kernels/block_solve on TPU).
    """
    nsys, n = y0.shape
    dtype = y0.dtype
    t0 = jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,))
    tf = jnp.broadcast_to(jnp.asarray(tf, dtype), (nsys,))
    h = jnp.maximum(1e-6 * (tf - t0), 1e-12)
    p = max(table.emb_order + 1, 2)
    eye = jnp.eye(n, dtype=dtype)

    def solve_blocks(A, rhs):
        if policy.backend == "pallas":
            from repro.kernels import ops as kops
            return kops.block_solve(A, rhs, batch_tile=policy.batch_tile,
                                    interpret=policy.interpret)
        return gauss_jordan_batched(A, rhs)

    def cond(c):
        t, y, h, e1, steps, att, netf, nni, stall = c
        return jnp.any((t < tf * (1 - 1e-12)) & (~stall)) & \
            jnp.all(att < opts.max_steps)

    def body(c):
        t, y, h, e1, steps, att, netf, nni, stall = c
        active = (t < tf * (1 - 1e-12)) & (~stall)
        hs = jnp.minimum(h, tf - t)
        ks = []
        nl_ok = jnp.ones((nsys,), bool)
        nni_step = jnp.zeros((), jnp.int32)
        for i in range(table.stages):
            r = y
            for j in range(i):
                if table.A[i][j] != 0.0:
                    r = r + (hs * table.A[i][j])[:, None] * ks[j]
            aii = table.A[i][i]
            ti = t + table.c[i] * hs
            if aii == 0.0:
                z = r
            else:
                gam = hs * aii                            # (nsys,)
                z = r
                for _ in range(newton_iters):
                    g = z - gam[:, None] * fi(ti, z) - r
                    J = jac(ti, z)                        # (nsys,n,n)
                    M = eye[None] - gam[:, None, None] * J
                    dz = solve_blocks(M, -g)
                    z = z + dz
                    nni_step = nni_step + 1
                g = z - gam[:, None] * fi(ti, z) - r
                res = jnp.sqrt(jnp.mean(g ** 2, axis=1))
                tol_nl = opts.newton_tol_fac * (opts.rtol *
                                                jnp.sqrt(jnp.mean(z ** 2, axis=1))
                                                + opts.atol)
                nl_ok = nl_ok & ((res <= jnp.maximum(tol_nl, 1e-12)) |
                                 ~active)
            ks.append(fi(ti, z))
        y_new = y
        for bi, k in zip(table.b, ks):
            if bi != 0.0:
                y_new = y_new + (hs * bi)[:, None] * k
        y_err = jnp.zeros_like(y)
        if table.b_emb is not None:
            for bi, bh, k in zip(table.b, table.b_emb, ks):
                if (bi - bh) != 0.0:
                    y_err = y_err + (hs * (bi - bh))[:, None] * k
        w = 1.0 / (opts.rtol * jnp.abs(y) + opts.atol)
        err = jnp.sqrt(jnp.mean((y_err * w) ** 2, axis=1))
        bad = ~jnp.isfinite(err) | ~nl_ok
        err = jnp.where(bad, 2.0, err)
        accept = (err <= 1.0) & ~bad & active
        e = jnp.maximum(err, 1e-10)
        eprev = jnp.maximum(e1, 1e-10)
        eta = opts.controller.safety * e ** (-opts.controller.k1 / p) * \
            eprev ** (opts.controller.k2 / p)
        eta = jnp.clip(eta, opts.controller.eta_min, opts.controller.eta_max)
        eta = jnp.where(accept | ~active, eta, jnp.minimum(eta, 0.3))
        eta = jnp.where(nl_ok | ~active, eta, opts.eta_cf)
        t = jnp.where(accept, t + hs, t)
        y = jnp.where(accept[:, None], y_new, y)
        h_next = jnp.where(active, jnp.clip(hs * eta, 1e-14, None), h)
        stall = stall | (active & (h_next < 1e-13))
        e1 = jnp.where(accept, e, e1)
        return (t, y, h_next, e1,
                steps + accept.astype(jnp.int32),
                att + active.astype(jnp.int32),
                netf + (active & ~accept).astype(jnp.int32),
                nni + nni_step, stall)

    zero = jnp.zeros((nsys,), jnp.int32)
    c = (t0, y0, h, jnp.ones((nsys,), dtype), zero, zero, zero,
         jnp.zeros((), jnp.int32), jnp.zeros((nsys,), bool))
    t, y, h, e1, steps, att, netf, nni, stall = lax.while_loop(cond, body, c)
    return y, EnsembleStats(steps=steps, attempts=att, netf=netf,
                            nni=jnp.broadcast_to(nni, (nsys,)),
                            success=t >= tf * (1 - 1e-10))
