"""Ensemble (submodel) integration — the paper's Fig. 5 use case, TPU-native.

SUNDIALS' submodel pattern: many small independent ODE systems (one per
grid cell) are grouped into bundles and integrated concurrently by
distinct CVODE instances on different CUDA streams.  On TPU, concurrency
comes from *batching*: one vectorized integrator advances every system
simultaneously, each with its own adaptive step size; systems that have
reached ``tf`` are masked no-ops inside the shared ``while_loop``.
This removes the stream/thread machinery entirely while preserving the
semantics (independent adaptive integrations) — see DESIGN.md §2.

The block-diagonal Jacobian of Fig. 1 appears here as the vmapped dense
(b×b) stage Jacobian; the batched Newton solve uses the batched
Gauss-Jordan / Pallas block-solve kernel.

Three integrators share the masked-while_loop pattern:

* :func:`ensemble_erk_integrate`  — adaptive explicit RK (nonstiff);
* :func:`ensemble_dirk_integrate` — adaptive DIRK, fixed-unroll Newton;
* :func:`ensemble_bdf_integrate`  — the CVODE-style subsystem: adaptive
  order (BDF 1-5) + step per system, convergence-tested modified Newton
  with Jacobian reuse and gamma-refresh (lsetup/lsolve split), linear
  algebra routed through the SoA block kernels via ExecPolicy dispatch,
  and a :func:`ensemble_bdf_integrate_sharded` shard_map path that
  scales the system axis across devices.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import controller as ctrl
from . import cvode as _cv
from . import dispatch as dv
from .arkode import ODEOptions
from .butcher import ButcherTable
from .direct import gauss_jordan_batched
from .policies import ExecPolicy, XLA_FUSED


class EnsembleStats(NamedTuple):
    steps: jnp.ndarray       # (nsys,) accepted steps per system
    attempts: jnp.ndarray
    netf: jnp.ndarray
    nni: jnp.ndarray
    success: jnp.ndarray     # (nsys,) bool
    nsetups: Optional[jnp.ndarray] = None   # (nsys,) lsetup count (BDF)
    ncfn: Optional[jnp.ndarray] = None      # (nsys,) Newton conv failures
    nli: Optional[jnp.ndarray] = None       # (nsys,) linear (Krylov) iters,
    # a solver-level count broadcast per system (direct solvers report 0)
    npsolves: Optional[jnp.ndarray] = None  # (nsys,) preconditioner solves,
    # broadcast like nli (0 without a Preconditioner object)


def ensemble_erk_integrate(f: Callable, y0: jnp.ndarray, t0, tf,
                           table: ButcherTable,
                           opts: ODEOptions = ODEOptions()):
    """Adaptive ERK over a batch of independent systems.

    f  : (t:(nsys,), y:(nsys, n)) -> (nsys, n)   vectorized RHS
    y0 : (nsys, n);  t0, tf broadcastable to (nsys,)
    Each system carries its own (t, h); the loop runs until all done.

    Tables without an embedding (``table.b_emb is None``) provide no
    error estimate, so adaptivity is impossible: the integrator falls
    back to fixed-step semantics (every step accepted, h never grown)
    instead of silently disabling error control and letting h run away
    at ``eta_max``.
    """
    nsys, n = y0.shape
    has_emb = table.b_emb is not None
    dtype = y0.dtype
    t0 = jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,))
    tf = jnp.broadcast_to(jnp.asarray(tf, dtype), (nsys,))
    # opts.h0 seeds the step; without an embedding it IS the fixed step.
    h = jnp.where(opts.h0 > 0, jnp.full((nsys,), opts.h0, dtype),
                  jnp.maximum(1e-6 * (tf - t0), 1e-12))
    p = max(table.emb_order + 1, 2)

    def cond(c):
        t, y, h, e1, steps, att, netf, stall = c
        return jnp.any((t < tf * (1 - 1e-12)) & (~stall)) & \
            jnp.all(att < opts.max_steps)

    def body(c):
        t, y, h, e1, steps, att, netf, stall = c
        active = (t < tf * (1 - 1e-12)) & (~stall)
        hs = jnp.minimum(h, tf - t)                      # (nsys,)
        ks = []
        for i in range(table.stages):
            yi = y
            for j in range(i):
                if table.A[i][j] != 0.0:
                    yi = yi + (hs * table.A[i][j])[:, None] * ks[j]
            ks.append(f(t + table.c[i] * hs, yi))
        y_new = y
        for bi, k in zip(table.b, ks):
            if bi != 0.0:
                y_new = y_new + (hs * bi)[:, None] * k
        y_err = jnp.zeros_like(y)
        if has_emb:
            for bi, bh, k in zip(table.b, table.b_emb, ks):
                if (bi - bh) != 0.0:
                    y_err = y_err + (hs * (bi - bh))[:, None] * k
        w = 1.0 / (opts.rtol * jnp.abs(y) + opts.atol)
        err = jnp.sqrt(jnp.mean((y_err * w) ** 2, axis=1))  # (nsys,)
        bad = ~jnp.isfinite(err) | ~jnp.all(jnp.isfinite(y_new), axis=1)
        err = jnp.where(bad, 2.0, err)
        accept = (err <= 1.0) & ~bad & active
        if has_emb:
            # per-system PI controller
            e = jnp.maximum(err, 1e-10)
            eprev = jnp.maximum(e1, 1e-10)
            eta = opts.controller.safety * e ** (-opts.controller.k1 / p) * \
                eprev ** (opts.controller.k2 / p)
            eta = jnp.clip(eta, opts.controller.eta_min,
                           opts.controller.eta_max)
            eta = jnp.where(accept | ~active, eta, jnp.minimum(eta, 0.3))
        else:
            # no embedding -> no error signal: keep h fixed (shrink only
            # on a non-finite step so the loop can still bail out)
            e = jnp.maximum(err, 1e-10)
            eta = jnp.where(bad & active, 0.5, 1.0)
        t = jnp.where(accept, t + hs, t)
        y = jnp.where(accept[:, None], y_new, y)
        h_next = jnp.where(active, jnp.clip(hs * eta, 1e-14, None), h)
        stall = stall | (active & (h_next < 1e-13))
        e1 = jnp.where(accept, e, e1)
        return (t, y, h_next, e1,
                steps + accept.astype(jnp.int32),
                att + active.astype(jnp.int32),
                netf + (active & ~accept).astype(jnp.int32), stall)

    zero = jnp.zeros((nsys,), jnp.int32)
    c = (t0, y0, h, jnp.ones((nsys,), dtype), zero, zero, zero,
         jnp.zeros((nsys,), bool))
    t, y, h, e1, steps, att, netf, stall = lax.while_loop(cond, body, c)
    return y, EnsembleStats(steps=steps, attempts=att, netf=netf,
                            nni=zero, success=t >= tf * (1 - 1e-10))


def ensemble_dirk_integrate(fi: Callable, jac: Callable, y0: jnp.ndarray,
                            t0, tf, table: ButcherTable,
                            opts: ODEOptions = ODEOptions(),
                            policy: ExecPolicy = XLA_FUSED,
                            newton_iters: int = 4):
    """Adaptive DIRK over a batch of independent *stiff* systems with the
    batched block-diagonal Newton solve (the paper's submodel solver).

    fi  : (t:(nsys,), y:(nsys,n)) -> (nsys,n)
    jac : (t:(nsys,), y:(nsys,n)) -> (nsys,n,n)   per-system Jacobian
    Newton matrix M_j = I - h a_ii J_j is solved for ALL systems in one
    batched Gauss-Jordan (kernels/block_solve on TPU).
    """
    nsys, n = y0.shape
    dtype = y0.dtype
    t0 = jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,))
    tf = jnp.broadcast_to(jnp.asarray(tf, dtype), (nsys,))
    # opts.h0 seeds the step, same contract as ensemble_erk_integrate
    h = jnp.where(opts.h0 > 0, jnp.full((nsys,), opts.h0, dtype),
                  jnp.maximum(1e-6 * (tf - t0), 1e-12))
    p = max(table.emb_order + 1, 2)
    eye = jnp.eye(n, dtype=dtype)

    def solve_blocks(A, rhs):
        if policy.backend == "pallas":
            from repro.kernels import ops as kops
            return kops.block_solve(A, rhs, batch_tile=policy.batch_tile,
                                    interpret=policy.interpret)
        return gauss_jordan_batched(A, rhs)

    def cond(c):
        t, y, h, e1, steps, att, netf, nni, stall = c
        return jnp.any((t < tf * (1 - 1e-12)) & (~stall)) & \
            jnp.all(att < opts.max_steps)

    def body(c):
        t, y, h, e1, steps, att, netf, nni, stall = c
        active = (t < tf * (1 - 1e-12)) & (~stall)
        hs = jnp.minimum(h, tf - t)
        ks = []
        nl_ok = jnp.ones((nsys,), bool)
        nni_step = jnp.zeros((nsys,), jnp.int32)
        for i in range(table.stages):
            r = y
            for j in range(i):
                if table.A[i][j] != 0.0:
                    r = r + (hs * table.A[i][j])[:, None] * ks[j]
            aii = table.A[i][i]
            ti = t + table.c[i] * hs
            if aii == 0.0:
                z = r
            else:
                gam = hs * aii                            # (nsys,)
                z = r
                for _ in range(newton_iters):
                    g = z - gam[:, None] * fi(ti, z) - r
                    J = jac(ti, z)                        # (nsys,n,n)
                    M = eye[None] - gam[:, None, None] * J
                    dz = solve_blocks(M, -g)
                    z = z + dz
                    # nni counts per ACTIVE system: finished systems are
                    # masked no-ops and must not accrue iterations
                    nni_step = nni_step + active.astype(jnp.int32)
                g = z - gam[:, None] * fi(ti, z) - r
                res = jnp.sqrt(jnp.mean(g ** 2, axis=1))
                tol_nl = opts.newton_tol_fac * (opts.rtol *
                                                jnp.sqrt(jnp.mean(z ** 2, axis=1))
                                                + opts.atol)
                nl_ok = nl_ok & ((res <= jnp.maximum(tol_nl, 1e-12)) |
                                 ~active)
            ks.append(fi(ti, z))
        y_new = y
        for bi, k in zip(table.b, ks):
            if bi != 0.0:
                y_new = y_new + (hs * bi)[:, None] * k
        y_err = jnp.zeros_like(y)
        if table.b_emb is not None:
            for bi, bh, k in zip(table.b, table.b_emb, ks):
                if (bi - bh) != 0.0:
                    y_err = y_err + (hs * (bi - bh))[:, None] * k
        w = 1.0 / (opts.rtol * jnp.abs(y) + opts.atol)
        err = jnp.sqrt(jnp.mean((y_err * w) ** 2, axis=1))
        bad = ~jnp.isfinite(err) | ~nl_ok
        err = jnp.where(bad, 2.0, err)
        accept = (err <= 1.0) & ~bad & active
        e = jnp.maximum(err, 1e-10)
        eprev = jnp.maximum(e1, 1e-10)
        eta = opts.controller.safety * e ** (-opts.controller.k1 / p) * \
            eprev ** (opts.controller.k2 / p)
        eta = jnp.clip(eta, opts.controller.eta_min, opts.controller.eta_max)
        eta = jnp.where(accept | ~active, eta, jnp.minimum(eta, 0.3))
        eta = jnp.where(nl_ok | ~active, eta, opts.eta_cf)
        t = jnp.where(accept, t + hs, t)
        y = jnp.where(accept[:, None], y_new, y)
        h_next = jnp.where(active, jnp.clip(hs * eta, 1e-14, None), h)
        stall = stall | (active & (h_next < 1e-13))
        e1 = jnp.where(accept, e, e1)
        return (t, y, h_next, e1,
                steps + accept.astype(jnp.int32),
                att + active.astype(jnp.int32),
                netf + (active & ~accept).astype(jnp.int32),
                nni + nni_step, stall)

    zero = jnp.zeros((nsys,), jnp.int32)
    c = (t0, y0, h, jnp.ones((nsys,), dtype), zero, zero, zero,
         zero, jnp.zeros((nsys,), bool))
    t, y, h, e1, steps, att, netf, nni, stall = lax.while_loop(cond, body, c)
    return y, EnsembleStats(steps=steps, attempts=att, netf=netf,
                            nni=nni,
                            success=t >= tf * (1 - 1e-10))


# ---------------------------------------------------------------------------
# Batched adaptive BDF (the CVODE-style ensemble integrator)
# ---------------------------------------------------------------------------


class _BdfCarry(NamedTuple):
    t: jnp.ndarray            # (nsys,)
    h: jnp.ndarray            # (nsys,)
    q: jnp.ndarray            # (nsys,) current BDF order
    Z: jnp.ndarray            # (nsys, QMAX+1, n) uniform-grid history
    e1: jnp.ndarray           # (nsys,) controller err_prev
    e2: jnp.ndarray           # (nsys,) controller err_prev2
    MJ: Any                   # saved linear object (solver-defined pytree;
    #                           every leaf keeps the nsys axis LAST)
    gam_saved: jnp.ndarray    # (nsys,) gamma at last lsetup
    since_jac: jnp.ndarray    # (nsys,) attempts since last Jacobian refresh
    ncf_prev: jnp.ndarray     # (nsys,) Newton failed last attempt -> refresh
    steps: jnp.ndarray
    att: jnp.ndarray
    netf: jnp.ndarray
    nni: jnp.ndarray
    nsetups: jnp.ndarray
    ncfn: jnp.ndarray
    nli: jnp.ndarray          # scalar: inner linear iterations (Krylov)
    nps: jnp.ndarray          # scalar: preconditioner applications
    stall: jnp.ndarray


def ensemble_bdf_integrate(f: Callable, jac: Callable, y0: jnp.ndarray,
                           t0, tf, *, order: int = 5,
                           opts: ODEOptions = ODEOptions(),
                           policy: ExecPolicy = XLA_FUSED,
                           linear_solver=None,
                           lin_mode: Optional[str] = None,
                           jac_sparsity=None,
                           msbp: int = 20, dgmax: float = 0.3,
                           mem=None):
    """Adaptive batched BDF (orders 1-``order``) over ``nsys`` independent
    stiff systems — the CVODE submodel pipeline, TPU-native.

    f   : (t:(nsys,), y:(nsys,n)) -> (nsys,n)   vectorized RHS
    jac : (t:(nsys,), y:(nsys,n)) -> (nsys,n,n) per-system dense Jacobian
    y0  : (nsys, n);  t0, tf broadcastable to (nsys,)

    Each system carries its own (t, h, order, history, controller state):
    step size and order ramp are controlled per system, and systems that
    reach ``tf`` become masked no-ops inside the shared ``while_loop``.

    The nonlinear corrector is a convergence-tested **modified Newton**
    (CVODE semantics, not a fixed unroll): the Newton matrix
    ``M_j = I - gamma_j J_j`` is built from a *saved* Jacobian and only
    refreshed when it is stale — on the first step, after a Newton
    convergence failure, every ``msbp`` attempts, or when gamma has
    drifted by more than ``dgmax`` since the last lsetup (CVODE's
    ``CVLsetup`` triggers).

    Linear algebra is a **pluggable object**: ``linear_solver`` is any
    :class:`repro.core.linsol.LinearSolver` with an SoA batch path
    (``soa_setup`` / ``soa_solve``), dispatched through ``policy``:

    * :class:`~repro.core.linsol.BlockDiagGJ` ``(factor_once=True)`` —
      the default: lsetup inverts every block once
      (:func:`repro.core.dispatch.block_inverse_soa`, the batched
      factor-once analog of the paper's cuSolver batchQR setup) and each
      Newton iteration is a single block-diagonal SpMV
      (:func:`repro.core.dispatch.blockdiag_spmv_soa`); gamma drift
      between lsetups is absorbed by CVODE's ``2/(1+gamrat)`` step
      scaling.
    * :class:`~repro.core.linsol.BlockDiagGJ` ``(factor_once=False)`` —
      the saved Jacobian is kept instead, M is rebuilt with the current
      gamma and every Newton iteration solves it with
      :func:`repro.core.dispatch.block_solve_soa`; the refresh logic
      then gates only Jacobian evaluations.
    * any Krylov solver (:class:`~repro.core.linsol.SPGMR`, ...) — the
      saved Jacobian backs a matrix-free solve of the flattened
      block-diagonal system (one batched SpMV per inner iteration);
      inner iterations are reported in ``stats.nli``, and a
      :class:`~repro.core.precond.Preconditioner` passed as the
      solver's ``precond=`` has its psetup run at the lsetup triggers
      and its psolve applications counted in ``stats.npsolves``.
    * :class:`~repro.core.linsol.EnsembleSparseGJ` — the batched sparse
      direct solver: symbolic analysis once per run, numeric refactor
      at the lsetup triggers, O(nnz) saved storage.

    ``jac_sparsity`` (an (n, n) boolean pattern, or the problem's
    ``IVP.jac_sparsity`` via the unified front-end) is bound to any
    solver with a sparse path (``with_sparsity``): the persistent
    Newton carry then holds only the pattern's values — dense ``jac``
    output is compressed at each lsetup and never stored.

    ``lin_mode='setup' | 'direct'`` is the deprecated string form of the
    two ``BlockDiagGJ`` configurations (kept as a compat shim).

    The block kernels pad the system batch to the policy's
    ``batch_tile`` internally, so ``nsys`` need not be a multiple of
    128.  ``mem`` (a :class:`~repro.core.memory.MemoryHelper`) registers
    the history window and saved Newton blocks for workspace accounting.

    Simplifications vs CVODE proper match :func:`repro.core.cvode.
    bdf_integrate`: order ramps 1 -> ``order`` but is not adaptively
    lowered, and every lsetup re-evaluates the Jacobian (no ``jok``
    fast path — the batched analytic ``jac`` is one fused elementwise
    pass, cheaper than the bookkeeping).
    """
    from .linsol import BlockDiagGJ

    assert 1 <= order <= _cv.QMAX
    if lin_mode is not None:
        warnings.warn(
            "repro-compat: ensemble_bdf_integrate(lin_mode=...) is "
            "deprecated; pass linear_solver=BlockDiagGJ(factor_once="
            f"{lin_mode == 'setup'}) (or any LinearSolver with an SoA "
            "batch path)", DeprecationWarning, stacklevel=2)
        assert lin_mode in ("setup", "direct")
        if linear_solver is None:
            linear_solver = BlockDiagGJ(factor_once=(lin_mode == "setup"))
    ls = linear_solver if linear_solver is not None else BlockDiagGJ()
    if jac_sparsity is not None:
        from .linsol import encode_sparsity
        ls = ls.with_sparsity(encode_sparsity(jac_sparsity))
    nsys, n = y0.shape
    dtype = y0.dtype
    QMAX = _cv.QMAX
    if mem is not None:
        mem.register("ensemble_bdf.history", (nsys, QMAX + 1, n), dtype)
        # the persistent saved linear object is solver-defined: dense
        # Newton blocks, sparse values, preconditioner data, ...
        for suffix, shape in ls.soa_workspace_shapes(n, nsys):
            mem.register(f"ensemble_bdf.{suffix}", shape, dtype)
    t0 = jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,))
    tf = jnp.broadcast_to(jnp.asarray(tf, dtype), (nsys,))
    h0 = jnp.where(opts.h0 > 0, jnp.full((nsys,), opts.h0, dtype),
                   jnp.maximum(1e-6 * (tf - t0), 1e-12))
    one = jnp.ones((), dtype)

    def wrms(v, w):                                  # (nsys,n) -> (nsys,)
        return jnp.sqrt(jnp.mean((v * w) ** 2, axis=1))

    def cond(c):
        return jnp.any((c.t < tf * (1 - 1e-12)) & (~c.stall)) & \
            jnp.all(c.att < opts.max_steps)

    def body(c):
        active = (c.t < tf * (1 - 1e-12)) & (~c.stall)
        hs = jnp.where(active, jnp.minimum(c.h, tf - c.t), c.h)
        nvalid = jnp.minimum(c.steps, QMAX)
        # if h was clipped to hit tf, rescale the history accordingly
        eta_clip = jnp.where(active, hs / c.h, one)
        W = jax.vmap(_cv._lagrange_matrix)(eta_clip, nvalid)
        Z = jnp.einsum("sji,sik->sjk", W, c.Z)
        qi = c.q - 1
        alphas = _cv._ALPHA_T[qi].astype(dtype)      # (nsys, QMAX+1)
        beta = _cv._BETA_T[qi].astype(dtype)         # (nsys,)
        p_pred = jnp.minimum(nvalid, c.q)
        pred_c = _cv._PREDP_T[p_pred].astype(dtype)
        y_pred = jnp.einsum("sj,sjk->sk", pred_c, Z)
        psi = -jnp.einsum("sj,sjk->sk", alphas[:, 1:], Z[:, :-1])
        gamma = beta * hs                            # (nsys,)
        t_new = c.t + hs
        w = 1.0 / (opts.rtol * jnp.abs(Z[:, 0]) + opts.atol)

        # ---- lsetup: refresh J (and in 'setup' mode the block inverse)
        # only where stale; skipped entirely when no system needs it ----
        gamrat = gamma / jnp.where(c.gam_saved != 0, c.gam_saved, gamma)
        need = active & ((c.gam_saved == 0) | c.ncf_prev |
                         (c.since_jac >= msbp) |
                         (jnp.abs(gamrat - 1.0) > dgmax))

        def do_setup(_):
            J = jac(t_new, y_pred)                   # (nsys, n, n)
            Jsoa = jnp.transpose(J, (1, 2, 0))       # (n, n, nsys)
            return ls.soa_setup(Jsoa, gamma, policy)

        MJ_new = lax.cond(jnp.any(need), do_setup, lambda _: c.MJ,
                          operand=None)
        # solver-defined pytree; every leaf keeps nsys LAST, so the
        # per-system mask broadcasts against the trailing axis
        MJ = jax.tree_util.tree_map(
            lambda new, old: jnp.where(need, new, old), MJ_new, c.MJ)
        gam_saved = jnp.where(need, gamma, c.gam_saved)
        since_jac = jnp.where(need, 0, c.since_jac)
        gamrat = jnp.where(need, 1.0, gamrat)

        # ---- convergence-tested modified Newton; the linear solve is
        # the pluggable object's lsolve (rhs is SoA: (n, nsys)) ----
        def lsolve(rhs):
            return ls.soa_solve(MJ, gamma, gamrat, rhs, policy, mem=mem)

        def nl_cond(s):
            z, it, dn_prev, crate, conv, div, nni_s, nli_s, nps_s = s
            return jnp.any(active & ~conv & ~div) & (it < opts.newton_max)

        def nl_body(s):
            z, it, dn_prev, crate, conv, div, nni_s, nli_s, nps_s = s
            iterate = active & ~conv & ~div
            g = z - gamma[:, None] * f(t_new, z) - psi
            dz_soa, nli_inc, nps_inc = lsolve(-g.T)
            dz = dz_soa.T
            z_new = jnp.where(iterate[:, None], z + dz, z)
            dn = wrms(dz, w)
            crate_new = jnp.where(
                it > 0,
                jnp.maximum(0.3 * crate,
                            dn / jnp.maximum(dn_prev, 1e-30)), crate)
            conv_new = conv | (iterate &
                               (dn * jnp.minimum(one, crate_new) <
                                opts.newton_tol_fac))
            div_new = div | (iterate & (it > 0) & (dn > 2.0 * dn_prev))
            return (z_new, it + 1,
                    jnp.where(iterate, dn, dn_prev),
                    jnp.where(iterate, crate_new, crate),
                    conv_new, div_new, nni_s + iterate.astype(jnp.int32),
                    nli_s + nli_inc, nps_s + nps_inc)

        s0 = (y_pred, jnp.zeros((), jnp.int32), jnp.zeros((nsys,), dtype),
              jnp.ones((nsys,), dtype), ~active, jnp.zeros((nsys,), bool),
              jnp.zeros((nsys,), jnp.int32), jnp.zeros((), jnp.int32),
              jnp.zeros((), jnp.int32))
        z, _, _, _, conv, _, nni_s, nli_s, nps_s = lax.while_loop(
            nl_cond, nl_body, s0)

        # ---- local error test (LTE ~ (z - pred)/(q+1), uniform grid) ----
        err = wrms(z - y_pred, w) / (c.q.astype(dtype) + 1.0)
        bad = ~jnp.isfinite(err) | ~conv
        err = jnp.where(bad, 2.0, err)
        accept = (err <= 1.0) & ~bad & active

        cst = ctrl.ControllerState(err_prev=c.e1, err_prev2=c.e2)
        eta, cst_new = ctrl.eta_from_error(opts.controller, cst, err,
                                           c.q + 1,
                                           after_failure=(~accept) & conv)
        eta = jnp.where(conv | ~active, eta, opts.eta_cf)
        eta = jnp.clip(eta, 0.1, 10.0)
        # fold the [hmin, hmax] step bounds into eta itself: the history
        # below is rescaled onto the hs*eta grid, so clamping h after the
        # fact would leave the stored grid and the carried h disagreeing
        # whenever the bound engages
        hs_safe = jnp.maximum(hs, jnp.finfo(dtype).tiny)
        eta = jnp.clip(eta, opts.hmin / hs_safe, opts.hmax / hs_safe)
        e1 = jnp.where(accept, cst_new.err_prev, c.e1)
        e2 = jnp.where(accept, cst_new.err_prev2, c.e2)

        # accepted systems: shift history, insert z, ramp order
        Z_acc = jnp.roll(Z, 1, axis=1).at[:, 0].set(z)
        Z_next = jnp.where(accept[:, None, None], Z_acc, Z)
        q_next = jnp.where(accept, jnp.minimum(c.q + 1, order), c.q)
        # rescale each system's history onto its new uniform grid
        nval_after = jnp.minimum(c.steps + accept.astype(jnp.int32), QMAX)
        W2 = jax.vmap(_cv._lagrange_matrix)(
            jnp.where(active, eta, one), nval_after)
        Z_next = jnp.einsum("sji,sik->sjk", W2, Z_next)

        t_next = jnp.where(accept, t_new, c.t)
        h_next = jnp.where(active, hs * eta, c.h)
        stall = c.stall | (active & (hs * eta < 1e-14))
        ncf = active & ~conv
        ai = active.astype(jnp.int32)
        return _BdfCarry(
            t=t_next, h=h_next, q=q_next, Z=Z_next, e1=e1, e2=e2,
            MJ=MJ, gam_saved=gam_saved, since_jac=since_jac + ai,
            ncf_prev=ncf,
            steps=c.steps + accept.astype(jnp.int32),
            att=c.att + ai,
            netf=c.netf + ((~accept) & conv & active).astype(jnp.int32),
            nni=c.nni + nni_s,
            nsetups=c.nsetups + need.astype(jnp.int32),
            ncfn=c.ncfn + ncf.astype(jnp.int32),
            nli=c.nli + nli_s, nps=c.nps + nps_s, stall=stall)

    zero = jnp.zeros((nsys,), jnp.int32)
    Z0 = jnp.zeros((nsys, QMAX + 1, n), dtype).at[:, 0].set(y0)
    c = _BdfCarry(
        t=t0, h=h0, q=jnp.ones((nsys,), jnp.int32), Z=Z0,
        e1=jnp.ones((nsys,), dtype), e2=jnp.ones((nsys,), dtype),
        MJ=ls.soa_carry_init(n, nsys, dtype),
        gam_saved=jnp.zeros((nsys,), dtype), since_jac=zero,
        ncf_prev=jnp.zeros((nsys,), bool), steps=zero, att=zero,
        netf=zero, nni=zero, nsetups=zero, ncfn=zero,
        nli=jnp.zeros((), jnp.int32), nps=jnp.zeros((), jnp.int32),
        stall=jnp.zeros((nsys,), bool))
    c = lax.while_loop(cond, body, c)
    return c.Z[:, 0], EnsembleStats(
        steps=c.steps, attempts=c.att, netf=c.netf, nni=c.nni,
        success=c.t >= tf * (1 - 1e-10), nsetups=c.nsetups, ncfn=c.ncfn,
        nli=jnp.broadcast_to(c.nli, (nsys,)),
        npsolves=jnp.broadcast_to(c.nps, (nsys,)))


def ensemble_bdf_integrate_sharded(f: Callable, jac: Callable,
                                   y0: jnp.ndarray, t0, tf, *,
                                   params=None, mesh=None,
                                   axis: str = "systems", **kw):
    """Shard :func:`ensemble_bdf_integrate` over the system axis.

    One call advances ``device_count x`` more systems: the batch is split
    across ``mesh`` with ``shard_map`` and every device runs the masked
    adaptive loop on its shard *independently* — there are no collectives,
    and per-device ``while_loop`` trip counts diverge freely (a device
    whose systems finish early simply stops stepping).  This is the TPU
    expression of the paper's one-CVODE-instance-per-stream bundles, with
    the bundle size per device further tiled by ``ExecPolicy.batch_tile``.

    params : optional pytree of per-system arrays (leading axis nsys),
             sharded alongside ``y0``; ``f``/``jac`` are then called as
             ``f(t, y, params_shard)``.  Closed-over global arrays sized
             (nsys, ...) would NOT be sharded — route them through
             ``params`` instead.
    mesh   : a 1-D ('systems',) mesh by default
             (:func:`repro.launch.mesh.make_ensemble_mesh`).
    If nsys is not a multiple of the device count the batch is padded
    with finished dummy systems (tf = t0: masked no-ops from step one).
    """
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_ensemble_mesh
    from repro.parallel.sharding import shard_map_compat

    if mesh is None:
        mesh = make_ensemble_mesh()
    ndev = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    nsys, n = y0.shape
    dtype = y0.dtype
    t0a = jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,))
    tfa = jnp.broadcast_to(jnp.asarray(tf, dtype), (nsys,))
    pad = (-nsys) % ndev
    if pad:
        y0 = jnp.concatenate([y0, jnp.broadcast_to(y0[-1:], (pad, n))])
        t0a = jnp.concatenate([t0a, jnp.full((pad,), t0a[-1], dtype)])
        # tf = t0 -> padded systems are inactive from the first cond
        tfa = jnp.concatenate([tfa, jnp.full((pad,), t0a[-1], dtype)])
        if params is not None:
            params = jax.tree_util.tree_map(
                lambda p: jnp.concatenate(
                    [p, jnp.broadcast_to(p[-1:], (pad,) + p.shape[1:])]),
                params)

    spec = P(axis)

    def body(y0_l, t0_l, tf_l, params_l):
        if params is None:
            f_l, jac_l = f, jac
        else:
            f_l = lambda t, y: f(t, y, params_l)
            jac_l = lambda t, y: jac(t, y, params_l)
        return ensemble_bdf_integrate(f_l, jac_l, y0_l, t0_l, tf_l, **kw)

    stats_spec = EnsembleStats(*([spec] * len(EnsembleStats._fields)))
    params_spec = jax.tree_util.tree_map(lambda _: spec, params)
    fn = shard_map_compat(body, mesh,
                          in_specs=(spec, spec, spec, params_spec),
                          out_specs=(spec, stats_spec))
    y, st = fn(y0, t0a, tfa, params)
    if st.nli is not None:
        # each shard broadcast its own local Krylov total over its slice;
        # restore the documented invariant (every entry == the GLOBAL
        # total) by summing one representative entry per shard
        shard = y0.shape[0] // ndev
        st = st._replace(nli=jnp.broadcast_to(jnp.sum(st.nli[::shard]),
                                              st.nli.shape))
    if st.npsolves is not None:
        shard = y0.shape[0] // ndev
        st = st._replace(npsolves=jnp.broadcast_to(
            jnp.sum(st.npsolves[::shard]), st.npsolves.shape))
    if pad:
        y = y[:nsys]
        st = jax.tree_util.tree_map(lambda s: s[:nsys], st)
    return y, st
