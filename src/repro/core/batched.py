"""Ensemble (submodel) integration — the paper's Fig. 5 use case, TPU-native.

SUNDIALS' submodel pattern: many small independent ODE systems (one per
grid cell) are grouped into bundles and integrated concurrently by
distinct CVODE instances on different CUDA streams.  On TPU, concurrency
comes from *batching*: one vectorized integrator advances every system
simultaneously, each with its own adaptive step size; systems that have
reached ``tf`` are masked no-ops inside the shared ``while_loop``.
This removes the stream/thread machinery entirely while preserving the
semantics (independent adaptive integrations) — see DESIGN.md §2.

The block-diagonal Jacobian of Fig. 1 appears here as the vmapped dense
(b×b) stage Jacobian; the batched Newton solve uses the batched
Gauss-Jordan / Pallas block-solve kernel.

Three integrators share the masked-while_loop pattern:

* :func:`ensemble_erk_integrate`  — adaptive explicit RK (nonstiff);
* :func:`ensemble_dirk_integrate` — adaptive DIRK, fixed-count Newton
  ``while_loop`` per stage;
* :func:`ensemble_bdf_integrate`  — the CVODE-style subsystem: adaptive
  order (BDF 1-5) + step per system, convergence-tested modified Newton
  with Jacobian reuse and gamma-refresh (lsetup/lsolve split), linear
  algebra routed through the SoA block kernels via ExecPolicy dispatch,
  and a :func:`ensemble_bdf_integrate_sharded` shard_map path that
  scales the system axis across devices.

**Hot-loop layout (SoA everywhere, nsys LAST).**  The BDF and DIRK
Newton paths carry every iteration-sized array — BDF history ``Z``
(QMAX+1, n, nsys), Newton iterate ``z`` (n, nsys), weights, residuals —
in the structure-of-arrays layout the kernels and the LinearSolver SoA
surface speak natively, so the loop body performs ZERO layout
conversions per Newton iteration (the old AoS carry transposed the
residual in and the correction out on every iteration, and the Jacobian
at every lsetup).  User RHS/Jacobian callables stay in the documented
AoS batch convention (``(t:(nsys,), y:(nsys,n))``); pass native SoA
forms (``f_soa(t, y:(n,nsys))``, ``jac_soa -> (n,n,nsys)``) to make the
boundary conversion-free as well — otherwise a thin wrapper transposes
at the call site only (same cost as the old layout, paid once per RHS
evaluation instead of spread over every op).

The per-iteration work runs through three fused dispatch ops
(``newton_residual_soa``, ``masked_update_wrms_soa``,
``history_rescale_soa``; see :mod:`repro.kernels.newton`), and the BDF
step loop is executed with its carry **donated** so XLA updates the
history window in place instead of double-buffering it.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import controller as ctrl
from . import cvode as _cv
from . import dispatch as dv
from . import status
from .arkode import ODEOptions
from .butcher import ButcherTable
from .policies import ExecPolicy, XLA_FUSED


def _donated_loop(cond, body, carry):
    """Run the masked step loop with the carry buffers donated.

    Only safe when every carry leaf is a distinct buffer freshly
    allocated inside the integrator — true for the BDF carry (``y0``
    is copied into the history window and ``t`` is an explicit copy,
    since broadcast_to can alias a caller-shaped ``t0``), NOT for the
    ERK/DIRK carries, which hold ``y0`` itself and must leave the
    caller's buffer alive.
    At top level XLA may then reuse the carry in place — back-to-back
    integrations never hold two live copies of the (QMAX+1, n, nsys)
    history.  Under an outer trace (an enclosing jit or shard_map) the
    inner jit inlines and donation is a no-op, which is exactly the
    while_loop carry aliasing XLA applies there anyway.
    """
    return jax.jit(lambda c: lax.while_loop(cond, body, c),
                   donate_argnums=0)(carry)


def _wrap_soa(f, jac, f_soa, jac_soa):
    """Default SoA RHS/Jacobian forms: thin transposing wrappers around
    the AoS batch callables when no native SoA form is supplied (the
    only remaining layout conversion, at the user-function boundary)."""
    if f_soa is None:
        f_soa = lambda t, z: f(t, z.T).T
    if jac_soa is None:
        jac_soa = lambda t, z: jnp.transpose(jac(t, z.T), (1, 2, 0))
    return f_soa, jac_soa


class EnsembleStats(NamedTuple):
    steps: jnp.ndarray       # (nsys,) accepted steps per system
    attempts: jnp.ndarray
    netf: jnp.ndarray
    nni: jnp.ndarray
    success: jnp.ndarray     # (nsys,) bool
    nsetups: Optional[jnp.ndarray] = None   # (nsys,) lsetup count (BDF)
    ncfn: Optional[jnp.ndarray] = None      # (nsys,) Newton conv failures
    nli: Optional[jnp.ndarray] = None       # (nsys,) linear (Krylov) iters,
    # a solver-level count broadcast per system (direct solvers report 0)
    npsolves: Optional[jnp.ndarray] = None  # (nsys,) preconditioner solves,
    # broadcast like nli (0 without a Preconditioner object)
    retcodes: Optional[jnp.ndarray] = None  # (nsys,) int32 CV_*-style flag
    # per system (repro.core.status; 0 == SUCCESS, negative == quarantined)
    ok: Optional[jnp.ndarray] = None        # (nsys,) bool, retcodes == 0

    def masked(self, live) -> "EnsembleStats":
        """Stats restricted to the ``live`` lanes of a padded bundle.

        A serving bundle padded to a bucket size carries dead lanes
        (``tf == t0`` no-op systems) whose mere presence must not leak
        into aggregates: a dead lane did no work, so its per-lane
        counters are zeroed and it reports success (sums and means over
        the batch then describe live systems only).  The solver-level
        broadcast counters (``nli``, ``npsolves``) are GLOBAL totals of
        the batched inner solves — they are not per-lane attributable
        and pass through unchanged.
        """
        live = jnp.asarray(live, bool)

        def z(x):
            return None if x is None else jnp.where(live, x, 0)

        return self._replace(
            steps=z(self.steps), attempts=z(self.attempts),
            netf=z(self.netf), nni=z(self.nni),
            success=self.success | ~live,
            nsetups=z(self.nsetups), ncfn=z(self.ncfn),
            retcodes=z(self.retcodes),      # dead lane -> SUCCESS (0)
            ok=None if self.ok is None else self.ok | ~live)


class SolverSession(NamedTuple):
    """Opaque warm-start continuation state for ``ensemble_bdf``.

    The final SoA step-loop carry of one integration, exported with
    ``return_session=True`` and accepted back via ``session=`` so a
    repeat/streaming client re-enters the BDF loop at its terminal
    order and step size instead of paying the cold order-1 restart.
    Every leaf keeps the system axis LAST (the hot-loop layout), so
    per-lane slicing (``lanes``) and bundle assembly (``concat``) are
    uniform ``[..., idx]`` / concatenate-on-last-axis operations — the
    serving layer composes mixed warm/cold bundles this way.

    ``h <= 0`` is the cold-lane sentinel: re-entry substitutes the
    default ``h0`` there, which is how :meth:`cold` sessions reproduce
    the plain ``y0`` start exactly (one trace serves any warm/cold lane
    mix).  The exported leaves are fresh loop outputs and NEVER alias
    the donated step-loop carry; on re-entry the session is copied into
    fresh buffers before donation so the caller's handle stays valid
    (audited by sunlint's donation-aliasing rule).
    """

    t: jnp.ndarray        # (nsys,) time reached
    h: jnp.ndarray        # (nsys,) step size; <= 0 marks a cold lane
    q: jnp.ndarray        # (nsys,) int32 current BDF order
    Z: jnp.ndarray        # (QMAX+1, n, nsys) uniform-grid history, SoA
    e1: jnp.ndarray       # (nsys,) controller err_prev
    e2: jnp.ndarray       # (nsys,) controller err_prev2
    steps: jnp.ndarray    # (nsys,) int32 cumulative accepted steps
    #                       (bounds how much of Z is valid history)

    @property
    def nsys(self) -> int:
        return self.Z.shape[-1]

    @property
    def n(self) -> int:
        return self.Z.shape[-2]

    @classmethod
    def cold(cls, y0: jnp.ndarray, t0) -> "SolverSession":
        """A cold-start session for ``y0`` (nsys, n) at ``t0`` — the
        value-exact equivalent of passing ``y0`` without a session."""
        nsys, n = y0.shape
        dtype = y0.dtype
        return cls(
            t=jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,)),
            h=jnp.zeros((nsys,), dtype),                   # cold sentinel
            q=jnp.ones((nsys,), jnp.int32),
            Z=jnp.zeros((_cv.QMAX + 1, n, nsys), dtype).at[0].set(y0.T),
            e1=jnp.ones((nsys,), dtype), e2=jnp.ones((nsys,), dtype),
            steps=jnp.zeros((nsys,), jnp.int32))

    def lanes(self, idx) -> "SolverSession":
        """The session restricted to lane(s) ``idx`` (kept as an nsys
        axis: pass a slice/array so the result can be re-concatenated)."""
        return jax.tree_util.tree_map(lambda x: x[..., idx], self)

    @staticmethod
    def concat(sessions) -> "SolverSession":
        """Stack per-lane sessions into one bundle along the system
        axis (the serving layer's mixed warm/cold bundle assembly)."""
        sessions = list(sessions)
        return jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=-1), *sessions)


def ensemble_erk_integrate(f: Callable, y0: jnp.ndarray, t0, tf,
                           table: ButcherTable,
                           opts: ODEOptions = ODEOptions()):
    """Adaptive ERK over a batch of independent systems.

    f  : (t:(nsys,), y:(nsys, n)) -> (nsys, n)   vectorized RHS
    y0 : (nsys, n);  t0, tf broadcastable to (nsys,)
    Each system carries its own (t, h); the loop runs until all done.

    Tables without an embedding (``table.b_emb is None``) provide no
    error estimate, so adaptivity is impossible: the integrator falls
    back to fixed-step semantics (every step accepted, h never grown)
    instead of silently disabling error control and letting h run away
    at ``eta_max``.
    """
    nsys, n = y0.shape
    has_emb = table.b_emb is not None
    dtype = y0.dtype
    t0 = jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,))
    tf = jnp.broadcast_to(jnp.asarray(tf, dtype), (nsys,))
    # opts.h0 seeds the step; without an embedding it IS the fixed step.
    h = jnp.where(opts.h0 > 0, jnp.full((nsys,), opts.h0, dtype),
                  jnp.maximum(1e-6 * (tf - t0), 1e-12))
    p = max(table.emb_order + 1, 2)

    def cond(c):
        t, y, h, e1, steps, att, netf, stall = c
        return jnp.any((t < tf * (1 - 1e-12)) & (~stall)) & \
            jnp.all(att < opts.max_steps)

    def body(c):
        t, y, h, e1, steps, att, netf, stall = c
        active = (t < tf * (1 - 1e-12)) & (~stall)
        hs = jnp.minimum(h, tf - t)                      # (nsys,)
        ks = []
        for i in range(table.stages):
            yi = y
            for j in range(i):
                if table.A[i][j] != 0.0:
                    yi = yi + (hs * table.A[i][j])[:, None] * ks[j]
            ks.append(f(t + table.c[i] * hs, yi))
        y_new = y
        for bi, k in zip(table.b, ks):
            if bi != 0.0:
                y_new = y_new + (hs * bi)[:, None] * k
        y_err = jnp.zeros_like(y)
        if has_emb:
            for bi, bh, k in zip(table.b, table.b_emb, ks):
                if (bi - bh) != 0.0:
                    y_err = y_err + (hs * (bi - bh))[:, None] * k
        w = 1.0 / (opts.rtol * jnp.abs(y) + opts.atol)
        # per-system WRMS through the dispatched op (ExecPolicy-routed;
        # the .T views are exact layout changes XLA folds away on the
        # jnp backend — the ERK carry itself stays AoS, it has no
        # Newton hot loop to justify an SoA flip)
        err = dv.wrms_soa(y_err.T, w.T, opts.policy)        # (nsys,)
        bad = ~jnp.isfinite(err) | ~jnp.all(jnp.isfinite(y_new), axis=1)
        err = jnp.where(bad, 2.0, err)
        accept = (err <= 1.0) & ~bad & active
        if has_emb:
            # per-system PI controller
            e = jnp.maximum(err, 1e-10)
            eprev = jnp.maximum(e1, 1e-10)
            eta = opts.controller.safety * e ** (-opts.controller.k1 / p) * \
                eprev ** (opts.controller.k2 / p)
            eta = jnp.clip(eta, opts.controller.eta_min,
                           opts.controller.eta_max)
            eta = jnp.where(accept | ~active, eta, jnp.minimum(eta, 0.3))
        else:
            # no embedding -> no error signal: keep h fixed (shrink only
            # on a non-finite step so the loop can still bail out)
            e = jnp.maximum(err, 1e-10)
            eta = jnp.where(bad & active, 0.5, 1.0)
        t = jnp.where(accept, t + hs, t)
        y = jnp.where(accept[:, None], y_new, y)
        h_next = jnp.where(active, jnp.clip(hs * eta, 1e-14, None), h)
        stall = stall | (active & (h_next < 1e-13))
        e1 = jnp.where(accept, e, e1)
        return (t, y, h_next, e1,
                steps + accept.astype(jnp.int32),
                att + active.astype(jnp.int32),
                netf + (active & ~accept).astype(jnp.int32), stall)

    zero = jnp.zeros((nsys,), jnp.int32)
    c = (t0, y0, h, jnp.ones((nsys,), dtype), zero, zero, zero,
         jnp.zeros((nsys,), bool))
    t, y, h, e1, steps, att, netf, stall = lax.while_loop(cond, body, c)
    return y, EnsembleStats(steps=steps, attempts=att, netf=netf,
                            nni=zero, success=t >= tf * (1 - 1e-10))


def ensemble_dirk_integrate(fi: Callable, jac: Callable, y0: jnp.ndarray,
                            t0, tf, table: ButcherTable,
                            opts: ODEOptions = ODEOptions(),
                            policy: ExecPolicy = XLA_FUSED,
                            newton_iters: int = 4,
                            f_soa: Optional[Callable] = None,
                            jac_soa: Optional[Callable] = None,
                            telemetry: Optional[int] = None):
    """Adaptive DIRK over a batch of independent *stiff* systems with the
    batched block-diagonal Newton solve (the paper's submodel solver).

    fi  : (t:(nsys,), y:(nsys,n)) -> (nsys,n)
    jac : (t:(nsys,), y:(nsys,n)) -> (nsys,n,n)   per-system Jacobian
    Newton matrix M_j = I - h a_ii J_j is solved for ALL systems in one
    batched Gauss-Jordan (kernels/block_solve on TPU).

    The stage Newton iterations run in the SoA hot-loop layout shared
    with :func:`ensemble_bdf_integrate` (iterate/residual ``(n, nsys)``,
    fused ``newton_residual_soa`` + dispatched ``block_solve_soa``);
    the layout flips once per *stage*, not once per iteration.  Native
    SoA forms ``f_soa(t, y:(n,nsys)) -> (n,nsys)`` /
    ``jac_soa -> (n,n,nsys)`` remove even the per-RHS-call transposes.
    """
    from .linsol import newton_blocks_soa

    nsys, n = y0.shape
    dtype = y0.dtype
    f_s, jac_s = _wrap_soa(fi, jac, f_soa, jac_soa)
    t0 = jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,))
    tf = jnp.broadcast_to(jnp.asarray(tf, dtype), (nsys,))
    # opts.h0 seeds the step, same contract as ensemble_erk_integrate
    h = jnp.where(opts.h0 > 0, jnp.full((nsys,), opts.h0, dtype),
                  jnp.maximum(1e-6 * (tf - t0), 1e-12))
    p = max(table.emb_order + 1, 2)
    unit_w = jnp.ones((n, nsys), dtype)      # unweighted per-system RMS

    def cond(c):
        t, y, h, e1, steps, att, netf, nni, rc, ncf_cur, nef_cur = c
        # integer att ceiling kept in the cond (sunlint bounded-loops);
        # it never binds — lanes quarantine with TOO_MUCH_WORK first
        return jnp.any((t < tf * (1 - 1e-12)) & (rc == 0)) & \
            jnp.all(att <= opts.max_steps)

    def step(c):
        t, y, h, e1, steps, att, netf, nni, rc, ncf_cur, nef_cur = c
        active = (t < tf * (1 - 1e-12)) & (rc == 0)
        hs = jnp.minimum(h, tf - t)
        ks = []
        nl_ok = jnp.ones((nsys,), bool)
        nni_step = jnp.zeros((nsys,), jnp.int32)
        for i in range(table.stages):
            r = y
            for j in range(i):
                if table.A[i][j] != 0.0:
                    r = r + (hs * table.A[i][j])[:, None] * ks[j]
            aii = table.A[i][i]
            ti = t + table.c[i] * hs
            if aii == 0.0:
                ks.append(fi(ti, r))
            else:
                # ---- SoA stage Newton (shared hot-loop layout) ----
                gam = hs * aii                            # (nsys,)
                rs = r.T                                  # (n, nsys), once
                # a real while_loop (not a Python unroll) so the body is
                # a single jaxpr sunlint's hot-loop-layout rule audits,
                # exactly like the BDF Newton loop
                def nl_cond(nc, _k=newton_iters):
                    _z, it, _nni = nc
                    return it < _k

                def nl_body(nc, ti=ti, rs=rs, gam=gam):
                    z_s, it, nni_s = nc
                    rhs = dv.newton_residual_soa(z_s, f_s(ti, z_s), rs,
                                                 gam, policy, negate=True)
                    M = newton_blocks_soa(jac_s(ti, z_s), gam)
                    z_s = z_s + dv.block_solve_soa(M, rhs, policy)
                    # nni counts per ACTIVE system: finished systems are
                    # masked no-ops and must not accrue iterations
                    return (z_s, it + 1,
                            nni_s + active.astype(jnp.int32))

                z_s, _, nni_step = lax.while_loop(
                    nl_cond, nl_body, (rs, jnp.int32(0), nni_step))
                fz = f_s(ti, z_s)          # final RHS: residual AND stage
                g = dv.newton_residual_soa(z_s, fz, rs, gam, policy)
                res = dv.wrms_soa(g, unit_w, policy)
                tol_nl = opts.newton_tol_fac * (
                    opts.rtol * dv.wrms_soa(z_s, unit_w, policy)
                    + opts.atol)
                nl_ok = nl_ok & ((res <= jnp.maximum(tol_nl, 1e-12)) |
                                 ~active)
                # the stage derivative is the SAME evaluation the
                # residual used (a native f_soa has no AoS twin XLA
                # could CSE against) — back to AoS once per stage
                ks.append(fz.T)
        y_new = y
        for bi, k in zip(table.b, ks):
            if bi != 0.0:
                y_new = y_new + (hs * bi)[:, None] * k
        y_err = jnp.zeros_like(y)
        if table.b_emb is not None:
            for bi, bh, k in zip(table.b, table.b_emb, ks):
                if (bi - bh) != 0.0:
                    y_err = y_err + (hs * (bi - bh))[:, None] * k
        w = 1.0 / (opts.rtol * jnp.abs(y) + opts.atol)
        # dispatched per-system WRMS (.T views fuse on the jnp backend)
        err_raw = dv.wrms_soa(y_err.T, w.T, policy)
        bad = ~jnp.isfinite(err_raw) | ~nl_ok
        err = jnp.where(bad, 2.0, err_raw)
        accept = (err <= 1.0) & ~bad & active
        e = jnp.maximum(err, 1e-10)
        eprev = jnp.maximum(e1, 1e-10)
        eta = opts.controller.safety * e ** (-opts.controller.k1 / p) * \
            eprev ** (opts.controller.k2 / p)
        eta = jnp.clip(eta, opts.controller.eta_min, opts.controller.eta_max)
        eta = jnp.where(accept | ~active, eta, jnp.minimum(eta, 0.3))
        eta = jnp.where(nl_ok | ~active, eta, opts.eta_cf)
        t_new = t + hs
        t = jnp.where(accept, t_new, t)
        y = jnp.where(accept[:, None], y_new, y)
        h_next = jnp.where(active, jnp.clip(hs * eta, 1e-14, None), h)
        e1 = jnp.where(accept, e, e1)
        # per-lane retcode escalation, same contract as the BDF loop:
        # decided only for active lanes, sticky once nonzero
        ncf = active & ~nl_ok
        etf = active & nl_ok & ~accept & jnp.isfinite(err_raw)
        ncf_cur = jnp.where(accept, 0, ncf_cur + ncf.astype(jnp.int32))
        nef_cur = jnp.where(accept, 0, nef_cur + etf.astype(jnp.int32))
        # relative underflow check (t + h == t), as in the BDF loop
        hfail = active & (t + h_next == t)
        nanstep = active & nl_ok & ~jnp.isfinite(err_raw)
        att_next = att + active.astype(jnp.int32)
        unfinished = t < tf * (1 - 1e-12)
        rc = jnp.where(active & unfinished & (att_next >= opts.max_steps),
                       status.TOO_MUCH_WORK, rc)
        rc = jnp.where(active & ((nef_cur >= status.MXNEF) |
                                 (hfail & nl_ok)),
                       status.ERR_FAILURE, rc)
        rc = jnp.where(active & ((ncf_cur >= status.MXNCF) |
                                 (hfail & ~nl_ok)),
                       status.CONV_FAILURE, rc)
        rc = jnp.where(nanstep, status.RHSFUNC_FAIL, rc)
        carry = (t, y, h_next, e1,
                 steps + accept.astype(jnp.int32),
                 att_next,
                 netf + (active & ~accept).astype(jnp.int32),
                 nni + nni_step, rc, ncf_cur, nef_cur)
        # telemetry record: existing intermediates only (DIRK has no
        # order ramp and no lsetup trigger — those fields are constants
        # filled in by the telemetry-enabled wrapper below, so the
        # disabled trace gains no equations)
        rec = (t_new, hs, nni_step, err, nl_ok, accept, active)
        return carry, rec

    def body(c):
        return step(c)[0]

    zero = jnp.zeros((nsys,), jnp.int32)
    c = (t0, y0, h, jnp.ones((nsys,), dtype), zero, zero, zero,
         zero, zero, zero, zero)
    ring = None
    if telemetry is None:
        c = lax.while_loop(cond, body, c)
    else:
        from ..observability.telemetry import ring_init, ring_record

        def tel_body(cr):
            new_c, (t_new, hs, nni_step, err, nl_ok, accept,
                    active) = step(cr[0])
            rec = (t_new, hs, jnp.full((nsys,), p, jnp.int32), nni_step,
                   err, jnp.zeros((nsys,), bool), nl_ok, accept, active)
            return new_c, ring_record(cr[1], rec)

        c, ring = lax.while_loop(
            lambda cr: cond(cr[0]), tel_body,
            (c, ring_init(telemetry, (nsys,), dtype)))
    t, y, h, e1, steps, att, netf, nni, rc, _, _ = c
    retcodes = jnp.where((rc == 0) & (t < tf * (1 - 1e-10)),
                         status.TOO_MUCH_WORK, rc)
    st = EnsembleStats(steps=steps, attempts=att, netf=netf, nni=nni,
                       success=t >= tf * (1 - 1e-10),
                       retcodes=retcodes, ok=retcodes == 0)
    if ring is not None:
        return y, st, ring
    return y, st


# ---------------------------------------------------------------------------
# Batched adaptive BDF (the CVODE-style ensemble integrator)
# ---------------------------------------------------------------------------


class _BdfCarry(NamedTuple):
    t: jnp.ndarray            # (nsys,)
    h: jnp.ndarray            # (nsys,)
    q: jnp.ndarray            # (nsys,) current BDF order
    Z: jnp.ndarray            # (QMAX+1, n, nsys) uniform-grid history, SoA
    e1: jnp.ndarray           # (nsys,) controller err_prev
    e2: jnp.ndarray           # (nsys,) controller err_prev2
    MJ: Any                   # saved linear object (solver-defined pytree;
    #                           every leaf keeps the nsys axis LAST)
    gam_saved: jnp.ndarray    # (nsys,) gamma at last lsetup
    since_jac: jnp.ndarray    # (nsys,) attempts since last Jacobian refresh
    ncf_prev: jnp.ndarray     # (nsys,) Newton failed last attempt -> refresh
    steps: jnp.ndarray
    att: jnp.ndarray
    netf: jnp.ndarray
    nni: jnp.ndarray
    nsetups: jnp.ndarray
    ncfn: jnp.ndarray
    nli: jnp.ndarray          # scalar: inner linear iterations (Krylov)
    nps: jnp.ndarray          # scalar: preconditioner applications
    retcode: jnp.ndarray      # (nsys,) int32 CV_*-style status lane;
    #                           nonzero == quarantined (repro.core.status)
    ncf_cur: jnp.ndarray      # (nsys,) consecutive Newton conv failures
    #                           on the CURRENT step (reset on accept)
    nef_cur: jnp.ndarray      # (nsys,) consecutive error-test failures
    #                           on the CURRENT step (reset on accept)


def ensemble_bdf_integrate(f: Callable, jac: Callable, y0: jnp.ndarray,
                           t0, tf, *, order: int = 5,
                           opts: ODEOptions = ODEOptions(),
                           policy: ExecPolicy = XLA_FUSED,
                           linear_solver=None,
                           lin_mode: Optional[str] = None,
                           jac_sparsity=None,
                           msbp: int = 20, dgmax: float = 0.3,
                           mem=None,
                           f_soa: Optional[Callable] = None,
                           jac_soa: Optional[Callable] = None,
                           session: Optional[SolverSession] = None,
                           return_session: bool = False,
                           telemetry: Optional[int] = None):
    """Adaptive batched BDF (orders 1-``order``) over ``nsys`` independent
    stiff systems — the CVODE submodel pipeline, TPU-native.

    f   : (t:(nsys,), y:(nsys,n)) -> (nsys,n)   vectorized RHS
    jac : (t:(nsys,), y:(nsys,n)) -> (nsys,n,n) per-system dense Jacobian
    y0  : (nsys, n);  t0, tf broadcastable to (nsys,)

    **SoA hot loop.**  The entire step-loop carry is structure-of-arrays
    with the system axis LAST: history ``Z`` is (QMAX+1, n, nsys), the
    Newton iterate/residual/weights are (n, nsys) — the layout the
    LinearSolver SoA surface and the fused kernels consume natively, so
    the Newton body performs no transposes at all.  Each iteration is
    exactly: one fused residual (``newton_residual_soa``, emitting the
    rhs ``-g`` in a single HBM pass), one lsolve, and one fused masked
    update + correction norm (``masked_update_wrms_soa``).  The
    twice-per-step Lagrange history rebuild runs through
    ``history_rescale_soa``, which short-circuits bundles with no
    active system instead of sweeping the full (QMAX+1, n, nsys) window.
    ``f_soa`` / ``jac_soa`` (signatures ``(t:(nsys,), y:(n,nsys)) ->
    (n,nsys)`` and ``-> (n,n,nsys)``) supply native SoA RHS/Jacobian
    forms; without them the AoS callables are wrapped with a transpose
    at the call boundary only.  The step loop runs with its carry
    donated (:func:`_donated_loop`), so repeated integrations reuse the
    history buffers in place.

    Each system carries its own (t, h, order, history, controller state):
    step size and order ramp are controlled per system, and systems that
    reach ``tf`` become masked no-ops inside the shared ``while_loop``.

    The nonlinear corrector is a convergence-tested **modified Newton**
    (CVODE semantics, not a fixed unroll): the Newton matrix
    ``M_j = I - gamma_j J_j`` is built from a *saved* Jacobian and only
    refreshed when it is stale — on the first step, after a Newton
    convergence failure, every ``msbp`` attempts, or when gamma has
    drifted by more than ``dgmax`` since the last lsetup (CVODE's
    ``CVLsetup`` triggers).

    **lsetup cost note:** the refresh is a single ``lax.cond`` over the
    whole batch, so whenever ANY system trips a trigger, ``jac`` (and
    the solver's setup) is evaluated over ALL ``nsys`` systems and the
    fresh results are merged into the carry only where ``need`` holds.
    This is the right trade for a vectorized ensemble (per-system
    branching would serialize the batch), but it means lsetup cost
    scales with nsys, not with the number of stale systems.  The merge
    select itself is skipped when every system needs the refresh (the
    cold-start and post-failure common case) — the fresh object is
    taken wholesale instead of paying an MJ-sized ``where`` per leaf.

    Linear algebra is a **pluggable object**: ``linear_solver`` is any
    :class:`repro.core.linsol.LinearSolver` with an SoA batch path
    (``soa_setup`` / ``soa_solve``), dispatched through ``policy``:

    * :class:`~repro.core.linsol.BlockDiagGJ` ``(factor_once=True)`` —
      the default: lsetup inverts every block once
      (:func:`repro.core.dispatch.block_inverse_soa`, the batched
      factor-once analog of the paper's cuSolver batchQR setup) and each
      Newton iteration is a single block-diagonal SpMV
      (:func:`repro.core.dispatch.blockdiag_spmv_soa`); gamma drift
      between lsetups is absorbed by CVODE's ``2/(1+gamrat)`` step
      scaling.
    * :class:`~repro.core.linsol.BlockDiagGJ` ``(factor_once=False)`` —
      the saved Jacobian is kept instead, M is rebuilt with the current
      gamma and every Newton iteration solves it with
      :func:`repro.core.dispatch.block_solve_soa`; the refresh logic
      then gates only Jacobian evaluations.
    * any Krylov solver (:class:`~repro.core.linsol.SPGMR`, ...) — the
      saved Jacobian backs a matrix-free solve of the flattened
      block-diagonal system (one batched SpMV per inner iteration);
      inner iterations are reported in ``stats.nli``, and a
      :class:`~repro.core.precond.Preconditioner` passed as the
      solver's ``precond=`` has its psetup run at the lsetup triggers
      and its psolve applications counted in ``stats.npsolves``.
    * :class:`~repro.core.linsol.EnsembleSparseGJ` — the batched sparse
      direct solver: symbolic analysis once per run, numeric refactor
      at the lsetup triggers, O(nnz) saved storage.

    ``jac_sparsity`` (an (n, n) boolean pattern, or the problem's
    ``IVP.jac_sparsity`` via the unified front-end) is bound to any
    solver with a sparse path (``with_sparsity``): the persistent
    Newton carry then holds only the pattern's values — dense ``jac``
    output is compressed at each lsetup and never stored.

    ``lin_mode='setup' | 'direct'`` is the deprecated string form of the
    two ``BlockDiagGJ`` configurations (kept as a compat shim).

    **Warm-start continuation.**  ``session=`` re-enters the step loop
    from a :class:`SolverSession` exported by a previous call with
    ``return_session=True`` (the return value becomes ``(y, stats,
    session)``): history window, per-system order, step size, and
    controller memory all resume, so a streaming client skips the cold
    BDF order-1 ramp entirely.  With a session, ``y0``/``t0`` may be
    ``None`` (shapes and start times come from the session; a non-None
    ``y0`` is shape-checked against it).  ``h <= 0`` lanes are cold
    (default ``h0`` is substituted), so :meth:`SolverSession.cold`
    lanes and warm lanes mix freely in one bundle under ONE trace.  The
    saved linear object (``MJ``) is deliberately NOT part of the
    session — the first warm step trips the ``gam_saved == 0`` lsetup
    trigger and refreshes the Jacobian at the re-entry point.  Session
    leaves are copied into fresh buffers before the carry is donated
    (the caller's session handle must survive the call), and the
    exported session is built from the loop *outputs* — it never
    aliases a donated buffer.  ``stats.steps`` counts THIS call's
    accepted steps; the exported ``session.steps`` stays cumulative
    (it bounds the valid history depth).

    The block kernels pad the system batch to the policy's
    ``batch_tile`` internally, so ``nsys`` need not be a multiple of
    128.  ``mem`` (a :class:`~repro.core.memory.MemoryHelper`) registers
    the history window and saved Newton blocks for workspace accounting.

    Simplifications vs CVODE proper match :func:`repro.core.cvode.
    bdf_integrate`: order ramps 1 -> ``order`` but is not adaptively
    lowered, and every lsetup re-evaluates the Jacobian (no ``jok``
    fast path — the batched analytic ``jac`` is one fused elementwise
    pass, cheaper than the bookkeeping).

    **Step telemetry.**  ``telemetry=K`` threads a K-slot
    :class:`~repro.observability.telemetry.TelemetryRing` through the
    step-loop carry, recording one ``(t, h, q, newton_iters, err_ratio,
    lsetup_fired, converged, accepted, active)`` record per step attempt
    per system; the ring is appended LAST to the return tuple.  Every
    recorded value is an intermediate the step already computes, so with
    ``telemetry=None`` (the default) the loop trace is *identical* to a
    build without this feature (sunlint ``telemetry-purity``).
    """
    from .linsol import BlockDiagGJ

    assert 1 <= order <= _cv.QMAX
    if lin_mode is not None:
        warnings.warn(
            "repro-compat: ensemble_bdf_integrate(lin_mode=...) is "
            "deprecated; pass linear_solver=BlockDiagGJ(factor_once="
            f"{lin_mode == 'setup'}) (or any LinearSolver with an SoA "
            "batch path)", DeprecationWarning, stacklevel=2)
        assert lin_mode in ("setup", "direct")
        if linear_solver is None:
            linear_solver = BlockDiagGJ(factor_once=(lin_mode == "setup"))
    ls = linear_solver if linear_solver is not None else BlockDiagGJ()
    if jac_sparsity is not None:
        from .linsol import encode_sparsity
        ls = ls.with_sparsity(encode_sparsity(jac_sparsity))
    if session is not None:
        n, nsys = session.n, session.nsys
        dtype = session.Z.dtype
        if y0 is not None and tuple(y0.shape) != (nsys, n):
            raise ValueError(
                f"y0 shape {tuple(y0.shape)} disagrees with the session "
                f"({(nsys, n)}); pass y0=None to resume from the session")
    else:
        if y0 is None:
            raise ValueError("ensemble_bdf_integrate needs y0 (or a "
                             "session= to resume from)")
        nsys, n = y0.shape
        dtype = y0.dtype
    QMAX = _cv.QMAX
    f_s, jac_s = _wrap_soa(f, jac, f_soa, jac_soa)
    if mem is not None:
        mem.register("ensemble_bdf.history", (QMAX + 1, n, nsys), dtype)
        # the persistent saved linear object is solver-defined: dense
        # Newton blocks, sparse values, preconditioner data, ...
        for suffix, shape in ls.soa_workspace_shapes(n, nsys):
            mem.register(f"ensemble_bdf.{suffix}", shape, dtype)
    if session is not None:
        t0 = session.t          # per-lane resume times
    t0 = jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,))
    tf = jnp.broadcast_to(jnp.asarray(tf, dtype), (nsys,))
    h0 = jnp.where(opts.h0 > 0, jnp.full((nsys,), opts.h0, dtype),
                   jnp.maximum(1e-6 * (tf - t0), 1e-12))
    one = jnp.ones((), dtype)

    def cond(c):
        # the integer att backstop can never bind — a lane reaching
        # max_steps attempts quarantines itself with TOO_MUCH_WORK and
        # drops out of the retcode mask — but it keeps an explicit
        # iteration ceiling in the cond (sunlint bounded-loops)
        return jnp.any((c.t < tf * (1 - 1e-12)) & (c.retcode == 0)) & \
            jnp.all(c.att <= opts.max_steps)

    def step(c):
        active = (c.t < tf * (1 - 1e-12)) & (c.retcode == 0)
        hs = jnp.where(active, jnp.minimum(c.h, tf - c.t), c.h)
        nvalid = jnp.minimum(c.steps, QMAX)
        # if h was clipped to hit tf, rescale the history accordingly
        # (fused masked rebuild).  Unclipped systems have eta_clip ==
        # 1.0 exactly (hs == c.h -> hs/c.h == 1.0) and _lagrange_matrix
        # at eta=1 is the exact identity, so masking them out is a
        # value-level no-op that lets the kernel short-circuit whole
        # bundles in the common no-clip case instead of sweeping the
        # full (QMAX+1, n, nsys) window every step
        eta_clip = jnp.where(active, hs / c.h, one)
        W = jax.vmap(_cv._lagrange_matrix)(eta_clip, nvalid)
        Z = dv.history_rescale_soa(jnp.transpose(W, (1, 2, 0)), c.Z,
                                   active & (eta_clip != one), policy)
        qi = c.q - 1
        alphas = _cv._ALPHA_T[qi].astype(dtype)      # (nsys, QMAX+1)
        beta = _cv._BETA_T[qi].astype(dtype)         # (nsys,)
        p_pred = jnp.minimum(nvalid, c.q)
        pred_c = _cv._PREDP_T[p_pred].astype(dtype)
        # predictor / psi: per-system coefficient contractions over the
        # history, evaluated as the AoS einsum on transposed views so
        # the jnp backend keeps the pre-SoA accumulation order bitwise
        # (XLA folds the layout changes into the contraction).  O(Q*n*
        # nsys) once per step — NOT per Newton iteration.
        Zaos = jnp.transpose(Z, (2, 0, 1))           # (nsys, QMAX+1, n)
        y_pred = jnp.einsum("sj,sjk->sk", pred_c, Zaos).T    # (n, nsys)
        psi = (-jnp.einsum("sj,sjk->sk", alphas[:, 1:], Zaos[:, :-1])).T
        gamma = beta * hs                            # (nsys,)
        t_new = c.t + hs
        w = 1.0 / (opts.rtol * jnp.abs(Z[0]) + opts.atol)   # (n, nsys)

        # ---- lsetup: refresh J (and in 'setup' mode the block inverse)
        # only where stale; skipped entirely when no system needs it.
        # NOTE the batch-granular cost: one system tripping a trigger
        # evaluates jac over ALL nsys systems (docstring lsetup note) --
        gamrat = gamma / jnp.where(c.gam_saved != 0, c.gam_saved, gamma)
        need = active & ((c.gam_saved == 0) | c.ncf_prev |
                         (c.since_jac >= msbp) |
                         (jnp.abs(gamrat - 1.0) > dgmax))

        def do_setup(_):
            return ls.soa_setup(jac_s(t_new, y_pred), gamma, policy)

        MJ_new = lax.cond(jnp.any(need), do_setup, lambda _: c.MJ,
                          operand=None)
        # solver-defined pytree; every leaf keeps nsys LAST, so the
        # per-system mask broadcasts against the trailing axis.  When
        # EVERY system needs the refresh (cold start, the common case)
        # the fresh object is taken wholesale — no MJ-sized select.
        MJ = lax.cond(
            jnp.all(need),
            lambda: MJ_new,
            lambda: jax.tree_util.tree_map(
                lambda new, old: jnp.where(need, new, old), MJ_new, c.MJ))
        gam_saved = jnp.where(need, gamma, c.gam_saved)
        since_jac = jnp.where(need, 0, c.since_jac)
        gamrat = jnp.where(need, 1.0, gamrat)

        # ---- convergence-tested modified Newton, all-SoA: residual,
        # lsolve, masked update and correction norm each one fused op
        # on (n, nsys) arrays — no layout conversion per iteration ----
        def lsolve(rhs):
            return ls.soa_solve(MJ, gamma, gamrat, rhs, policy, mem=mem)

        def nl_cond(s):
            z, it, dn_prev, crate, conv, div, nni_s, nli_s, nps_s = s
            return jnp.any(active & ~conv & ~div) & (it < opts.newton_max)

        def nl_body(s):
            z, it, dn_prev, crate, conv, div, nni_s, nli_s, nps_s = s
            iterate = active & ~conv & ~div
            rhs = dv.newton_residual_soa(z, f_s(t_new, z), psi, gamma,
                                         policy, negate=True)
            dz, nli_inc, nps_inc = lsolve(rhs)
            z_new, dn = dv.masked_update_wrms_soa(z, dz, w, iterate,
                                                  policy)
            crate_new = jnp.where(
                it > 0,
                jnp.maximum(0.3 * crate,
                            dn / jnp.maximum(dn_prev, 1e-30)), crate)
            conv_new = conv | (iterate &
                               (dn * jnp.minimum(one, crate_new) <
                                opts.newton_tol_fac))
            div_new = div | (iterate & (it > 0) & (dn > 2.0 * dn_prev))
            return (z_new, it + 1,
                    jnp.where(iterate, dn, dn_prev),
                    jnp.where(iterate, crate_new, crate),
                    conv_new, div_new, nni_s + iterate.astype(jnp.int32),
                    nli_s + nli_inc, nps_s + nps_inc)

        s0 = (y_pred, jnp.zeros((), jnp.int32), jnp.zeros((nsys,), dtype),
              jnp.ones((nsys,), dtype), ~active, jnp.zeros((nsys,), bool),
              jnp.zeros((nsys,), jnp.int32), jnp.zeros((), jnp.int32),
              jnp.zeros((), jnp.int32))
        z, _, _, _, conv, _, nni_s, nli_s, nps_s = lax.while_loop(
            nl_cond, nl_body, s0)

        # ---- local error test (LTE ~ (z - pred)/(q+1), uniform grid) ----
        err_raw = dv.wrms_soa(z - y_pred, w, policy) / \
            (c.q.astype(dtype) + 1.0)
        bad = ~jnp.isfinite(err_raw) | ~conv
        err = jnp.where(bad, 2.0, err_raw)
        accept = (err <= 1.0) & ~bad & active

        cst = ctrl.ControllerState(err_prev=c.e1, err_prev2=c.e2)
        eta, cst_new = ctrl.eta_from_error(opts.controller, cst, err,
                                           c.q + 1,
                                           after_failure=(~accept) & conv)
        eta = jnp.where(conv | ~active, eta, opts.eta_cf)
        eta = jnp.clip(eta, 0.1, 10.0)
        # fold the [hmin, hmax] step bounds into eta itself: the history
        # below is rescaled onto the hs*eta grid, so clamping h after the
        # fact would leave the stored grid and the carried h disagreeing
        # whenever the bound engages
        hs_safe = jnp.maximum(hs, jnp.finfo(dtype).tiny)
        eta = jnp.clip(eta, opts.hmin / hs_safe, opts.hmax / hs_safe)
        e1 = jnp.where(accept, cst_new.err_prev, c.e1)
        e2 = jnp.where(accept, cst_new.err_prev2, c.e2)

        # accepted systems: shift history, insert z, ramp order
        Z_acc = jnp.roll(Z, 1, axis=0).at[0].set(z)
        Z_next = jnp.where(accept[None, None, :], Z_acc, Z)
        q_next = jnp.where(accept, jnp.minimum(c.q + 1, order), c.q)
        # rescale each system's history onto its new uniform grid
        nval_after = jnp.minimum(c.steps + accept.astype(jnp.int32), QMAX)
        W2 = jax.vmap(_cv._lagrange_matrix)(
            jnp.where(active, eta, one), nval_after)
        Z_next = dv.history_rescale_soa(jnp.transpose(W2, (1, 2, 0)),
                                        Z_next, active, policy)

        t_next = jnp.where(accept, t_new, c.t)
        h_next = jnp.where(active, hs * eta, c.h)
        ncf = active & ~conv
        etf = (~accept) & conv & active
        ai = active.astype(jnp.int32)
        att_next = c.att + ai

        # ---- per-lane retcode escalation (CVODE CVHandleFailure
        # semantics, carried in data).  Failure is only ever DECIDED for
        # currently-active lanes, so a quarantined lane's retcode is
        # sticky and healthy lanes see pure where() no-ops — the
        # no-fault trace stays value-identical.  Priority (last write
        # wins): TOO_MUCH_WORK < ERR_FAILURE < CONV_FAILURE <
        # RHSFUNC_FAIL, mirroring CVODE's specific-beats-generic flags.
        ncf_cur = jnp.where(accept, 0, c.ncf_cur + ncf.astype(jnp.int32))
        nef_cur = jnp.where(accept, 0, c.nef_cur + etf.astype(jnp.int32))
        # step-size underflow is RELATIVE (t + h == t, the classic
        # "h below the ULP of t" check): stiff lanes legitimately visit
        # tiny absolute h near transients and recover, so an absolute
        # floor would quarantine healthy integrations
        hfail = active & (c.t + hs * eta == c.t)
        nanstep = active & conv & ~jnp.isfinite(err_raw)
        unfinished = t_next < tf * (1 - 1e-12)
        rc = c.retcode
        rc = jnp.where(active & unfinished & (att_next >= opts.max_steps),
                       status.TOO_MUCH_WORK, rc)
        rc = jnp.where(active & ((nef_cur >= status.MXNEF) |
                                 (hfail & conv)),
                       status.ERR_FAILURE, rc)
        rc = jnp.where(active & ((ncf_cur >= status.MXNCF) |
                                 (hfail & ~conv)),
                       status.CONV_FAILURE, rc)
        rc = jnp.where(nanstep, status.RHSFUNC_FAIL, rc)

        carry = _BdfCarry(
            t=t_next, h=h_next, q=q_next, Z=Z_next, e1=e1, e2=e2,
            MJ=MJ, gam_saved=gam_saved, since_jac=since_jac + ai,
            ncf_prev=ncf,
            steps=c.steps + accept.astype(jnp.int32),
            att=att_next,
            netf=c.netf + etf.astype(jnp.int32),
            nni=c.nni + nni_s,
            nsetups=c.nsetups + need.astype(jnp.int32),
            ncfn=c.ncfn + ncf.astype(jnp.int32),
            nli=c.nli + nli_s, nps=c.nps + nps_s,
            retcode=rc, ncf_cur=ncf_cur, nef_cur=nef_cur)
        # telemetry record: every element is an intermediate the step
        # computed anyway — with telemetry off the tuple is discarded
        # and the traced loop is identical to a build without it
        rec = (t_new, hs, c.q, nni_s, err, need, conv, accept, active)
        return carry, rec

    def body(c):
        return step(c)[0]

    # donation requires every carry leaf to be a DISTINCT, internally
    # owned buffer: each counter gets its own zeros, and t is an
    # explicit copy — broadcast_to/asarray short-circuit when the
    # caller already passes an (nsys,) array of the right dtype, and
    # donating that alias would delete the CALLER's t0.  The session
    # re-entry leaves (t, Z, e1, e2, steps) are copied for the same
    # reason: donating them would invalidate the caller's session
    # handle (h and q pass through `where`/`clip`, which already
    # produce fresh buffers).
    zero = lambda: jnp.zeros((nsys,), jnp.int32)
    if session is None:
        steps0 = jnp.zeros((nsys,), jnp.int32)
        Z0 = jnp.zeros((QMAX + 1, n, nsys), dtype).at[0].set(y0.T)
        h_init = h0
        q_init = jnp.ones((nsys,), jnp.int32)
        e1_init = jnp.ones((nsys,), dtype)
        e2_init = jnp.ones((nsys,), dtype)
        steps_init = zero()
    else:
        steps0 = jnp.asarray(session.steps, jnp.int32)
        Z0 = jnp.array(session.Z, copy=True)
        # h <= 0 marks a cold lane: substitute the default h0 there so
        # cold sessions reproduce the plain-y0 start exactly
        h_init = jnp.where(session.h > 0, session.h, h0)
        q_init = jnp.clip(jnp.asarray(session.q, jnp.int32), 1, order)
        e1_init = jnp.array(session.e1, copy=True)
        e2_init = jnp.array(session.e2, copy=True)
        steps_init = jnp.array(steps0, copy=True)
    c = _BdfCarry(
        t=jnp.array(t0, copy=True), h=h_init,
        q=q_init, Z=Z0,
        e1=e1_init, e2=e2_init,
        MJ=ls.soa_carry_init(n, nsys, dtype),
        gam_saved=jnp.zeros((nsys,), dtype), since_jac=zero(),
        ncf_prev=jnp.zeros((nsys,), bool), steps=steps_init, att=zero(),
        netf=zero(), nni=zero(), nsetups=zero(), ncfn=zero(),
        nli=jnp.zeros((), jnp.int32), nps=jnp.zeros((), jnp.int32),
        retcode=zero(), ncf_cur=zero(), nef_cur=zero())
    # every carry leaf is freshly allocated above -> donate, so the
    # history window is updated in place across the step loop
    ring = None
    if telemetry is None:
        c = _donated_loop(cond, body, c)
    else:
        from ..observability.telemetry import ring_init, ring_record

        def tel_body(cr):
            new_c, rec = step(cr[0])
            return new_c, ring_record(cr[1], rec)

        c, ring = _donated_loop(
            lambda cr: cond(cr[0]), tel_body,
            (c, ring_init(telemetry, (nsys,), dtype)))
    # cond's integer backstop can in principle exit the loop with lanes
    # still marked healthy but unfinished; reconcile them to
    # TOO_MUCH_WORK so retcodes == 0 <=> the lane actually reached tf
    retcodes = jnp.where(
        (c.retcode == 0) & (c.t < tf * (1 - 1e-10)),
        status.TOO_MUCH_WORK, c.retcode)
    st = EnsembleStats(
        steps=c.steps - steps0, attempts=c.att, netf=c.netf, nni=c.nni,
        success=c.t >= tf * (1 - 1e-10), nsetups=c.nsetups, ncfn=c.ncfn,
        nli=jnp.broadcast_to(c.nli, (nsys,)),
        npsolves=jnp.broadcast_to(c.nps, (nsys,)),
        retcodes=retcodes, ok=retcodes == 0)
    out = [c.Z[0].T, st]
    if return_session:
        # built from the loop OUTPUTS — fresh buffers, never the
        # donated inputs (sunlint donation-aliasing audits this path).
        # Quarantine hygiene: a failed lane must NOT resume from its
        # poisoned step size / order / history depth — it is exported
        # as a cold lane (h <= 0 sentinel, order 1, zero valid history
        # depth) anchored at its last accepted state Z[0] (failed step
        # attempts never update Z[0], so it is the last good y).
        lane_ok = retcodes == 0
        out.append(SolverSession(
            t=c.t,
            h=jnp.where(lane_ok, c.h, jnp.zeros((), dtype)),
            q=jnp.where(lane_ok, c.q, 1),
            Z=c.Z, e1=jnp.where(lane_ok, c.e1, one),
            e2=jnp.where(lane_ok, c.e2, one),
            steps=jnp.where(lane_ok, c.steps, 0)))
    if ring is not None:
        out.append(ring)
    return tuple(out)


def ensemble_bdf_integrate_sharded(f: Callable, jac: Callable,
                                   y0: jnp.ndarray, t0, tf, *,
                                   params=None, mesh=None,
                                   axis: str = "systems", **kw):
    """Shard :func:`ensemble_bdf_integrate` over the system axis.

    One call advances ``device_count x`` more systems: the batch is split
    across ``mesh`` with ``shard_map`` and every device runs the masked
    adaptive loop on its shard *independently* — there are no collectives,
    and per-device ``while_loop`` trip counts diverge freely (a device
    whose systems finish early simply stops stepping).  This is the TPU
    expression of the paper's one-CVODE-instance-per-stream bundles, with
    the bundle size per device further tiled by ``ExecPolicy.batch_tile``.

    params : optional pytree of per-system arrays (leading axis nsys),
             sharded alongside ``y0``; ``f``/``jac`` are then called as
             ``f(t, y, params_shard)``.  Closed-over global arrays sized
             (nsys, ...) would NOT be sharded — route them through
             ``params`` instead.
    mesh   : a 1-D ('systems',) mesh by default
             (:func:`repro.launch.mesh.make_ensemble_mesh`).
    If nsys is not a multiple of the device count the batch is padded
    with finished dummy systems (tf = t0: masked no-ops from step one).
    """
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_ensemble_mesh
    from repro.parallel.sharding import shard_map_compat

    # an explicit None is the documented "no native SoA form" default of
    # the non-sharded API — only an actual callable is rejected here
    if kw.pop("f_soa", None) is not None or \
            kw.pop("jac_soa", None) is not None:
        raise ValueError(
            "ensemble_bdf_integrate_sharded takes the AoS f/jac only: a "
            "native SoA callable would close over unsharded (.., nsys) "
            "arrays; route per-system data through params= instead (the "
            "per-shard SoA wrapping happens inside each device's loop)")
    if kw.pop("session", None) is not None or kw.pop("return_session",
                                                    False):
        raise ValueError(
            "ensemble_bdf_integrate_sharded takes no session=/"
            "return_session=: a SolverSession's (.., nsys) leaves would "
            "close over the shard_map body unsharded; warm-start "
            "continuation is a serving-layer (single-mesh-shard) "
            "feature for now")
    if mesh is None:
        mesh = make_ensemble_mesh()
    ndev = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    nsys, n = y0.shape
    dtype = y0.dtype
    t0a = jnp.broadcast_to(jnp.asarray(t0, dtype), (nsys,))
    tfa = jnp.broadcast_to(jnp.asarray(tf, dtype), (nsys,))
    pad = (-nsys) % ndev
    if pad:
        y0 = jnp.concatenate([y0, jnp.broadcast_to(y0[-1:], (pad, n))])
        t0a = jnp.concatenate([t0a, jnp.full((pad,), t0a[-1], dtype)])
        # tf = t0 -> padded systems are inactive from the first cond
        tfa = jnp.concatenate([tfa, jnp.full((pad,), t0a[-1], dtype)])
        if params is not None:
            params = jax.tree_util.tree_map(
                lambda p: jnp.concatenate(
                    [p, jnp.broadcast_to(p[-1:], (pad,) + p.shape[1:])]),
                params)

    spec = P(axis)

    def body(y0_l, t0_l, tf_l, params_l):
        if params is None:
            f_l, jac_l = f, jac
        else:
            f_l = lambda t, y: f(t, y, params_l)
            jac_l = lambda t, y: jac(t, y, params_l)
        return ensemble_bdf_integrate(f_l, jac_l, y0_l, t0_l, tf_l, **kw)

    stats_spec = EnsembleStats(*([spec] * len(EnsembleStats._fields)))
    params_spec = jax.tree_util.tree_map(lambda _: spec, params)
    fn = shard_map_compat(body, mesh,
                          in_specs=(spec, spec, spec, params_spec),
                          out_specs=(spec, stats_spec))
    y, st = fn(y0, t0a, tfa, params)
    if st.nli is not None:
        # each shard broadcast its own local Krylov total over its slice;
        # restore the documented invariant (every entry == the GLOBAL
        # total) by summing one representative entry per shard
        shard = y0.shape[0] // ndev
        st = st._replace(nli=jnp.broadcast_to(jnp.sum(st.nli[::shard]),
                                              st.nli.shape))
    if st.npsolves is not None:
        shard = y0.shape[0] // ndev
        st = st._replace(npsolves=jnp.broadcast_to(
            jnp.sum(st.npsolves[::shard]), st.npsolves.shape))
    if pad:
        y = y[:nsys]
        st = jax.tree_util.tree_map(lambda s: s[:nsys], st)
    return y, st
