"""Sparse SUNMatrix analogs: scalar CSR and ensemble shared-pattern BSR.

The paper's GPU matrix is ``SUNMATRIX_CUSPARSE``: CSR, plus a
*block-diagonal/block-sparse* variant where every block of the batched
Newton matrix shares one sparsity pattern so the integer index arrays
are stored exactly once for the whole ensemble.  These are the JAX/TPU
analogs:

* :class:`SparseCSR` — one sparse matrix; the pattern
  (``indptr``/``indices``) is **static** (hashable tuples), only
  ``data`` is traced.  One jit cache entry per pattern — the
  store-the-pattern-once economics, taken to its TPU conclusion where
  the pattern lives in the compiled program, not in device memory.
* :class:`EnsembleBSR` — ``nsys`` block-sparse matrices sharing one
  block pattern, values ``(nsys, nnzb, b, b)`` (SoA across the
  ensemble; :meth:`values_soa` exposes the lane-major kernel layout).
  Built from an :attr:`repro.core.ivp.IVP.jac_sparsity` pattern so the
  ensemble BDF pipeline materializes only the nonzero blocks.

Both types implement ``scale_addI`` — SUNDIALS' ``SUNMatScaleAddI``
(``A <- c*A + I``), the in-place Newton update ``M = I - gamma*J`` done
on values only with the pattern reused (the diagonal must be in the
pattern; the constructors guarantee it when ``ensure_diag=True``).

SpMV routes through :mod:`repro.core.dispatch` (``csr_spmv`` /
``bsr_spmv_soa``) so the ExecPolicy picks the jnp oracle or the Pallas
kernel exactly like the vector ops.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def csr_pattern_from_dense(A, tol: float = 0.0,
                           ensure_diag: bool = False) -> Tuple[tuple, tuple]:
    """Static (indptr, indices) tuples from a concrete (host) matrix."""
    An = np.asarray(A)
    n, m = An.shape
    keep = np.abs(An) > tol
    if ensure_diag:
        for i in range(min(n, m)):
            keep[i, i] = True
    indptr, indices = [0], []
    for i in range(n):
        cols = np.nonzero(keep[i])[0]
        indices.extend(int(c) for c in cols)
        indptr.append(len(indices))
    return tuple(indptr), tuple(indices)


def csr_diag_positions(indptr, indices) -> tuple:
    """Static nnz slot of entry (i, i) per row of a CSR pattern; raises
    if any diagonal entry is absent (the Newton/ScaleAddI contract)."""
    pos = []
    for i in range(len(indptr) - 1):
        hits = [k for k in range(indptr[i], indptr[i + 1])
                if indices[k] == i]
        if not hits:
            raise ValueError(
                f"CSR pattern lacks diagonal entry ({i},{i}); build "
                "with ensure_diag=True for SUNMatScaleAddI use")
        pos.append(hits[0])
    return tuple(pos)


def block_pattern_from_element(pattern, block_size: int,
                               ensure_diag: bool = True
                               ) -> Tuple[tuple, tuple, int]:
    """Collapse an elementwise (n, n) sparsity pattern to a block
    pattern ``(brows, bcols, nblk)`` with ``b = block_size`` blocks —
    a block is nonzero iff ANY of its b*b entries is.  Row-major block
    order (the CSR-of-blocks convention)."""
    P = np.asarray(pattern).astype(bool)
    n = P.shape[0]
    assert P.shape == (n, n) and n % block_size == 0, (P.shape, block_size)
    nblk = n // block_size
    Pb = P.reshape(nblk, block_size, nblk, block_size).any(axis=(1, 3))
    if ensure_diag:
        np.fill_diagonal(Pb, True)
    br, bc = np.nonzero(Pb)
    return (tuple(int(i) for i in br), tuple(int(j) for j in bc), nblk)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class SparseCSR:
    """CSR matrix with a static pattern: ``data`` traced, structure
    (``indptr``/``indices``/``shape``) hashable aux data."""

    data: jnp.ndarray          # (nnz,)
    indptr: tuple              # (nrows + 1,) static
    indices: tuple             # (nnz,) static
    shape: tuple               # (nrows, ncols)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.data,), (self.indptr, self.indices, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    # -- construction ------------------------------------------------------
    @classmethod
    def from_dense(cls, A, tol: float = 0.0,
                   ensure_diag: bool = False) -> "SparseCSR":
        """Compress a dense matrix.  ``A`` may be traced IF a concrete
        twin determines the pattern — here the pattern is read from
        ``A`` itself, so ``A`` must be concrete (host-side setup, the
        SUNSparseFromDenseMatrix moment)."""
        indptr, indices = csr_pattern_from_dense(np.asarray(A), tol,
                                                 ensure_diag)
        Aj = jnp.asarray(A)
        rows = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
        data = Aj[jnp.asarray(rows), jnp.asarray(np.asarray(indices,
                                                            np.int64))]
        return cls(data, indptr, indices, tuple(np.asarray(A).shape))

    @classmethod
    def from_pattern(cls, indptr, indices, shape, data=None,
                     dtype=jnp.float64) -> "SparseCSR":
        indptr, indices = tuple(int(i) for i in indptr), \
            tuple(int(i) for i in indices)
        if data is None:
            data = jnp.zeros((len(indices),), dtype)
        return cls(jnp.asarray(data), indptr, indices, tuple(shape))

    # -- structure ---------------------------------------------------------
    @property
    def nnz(self) -> int:
        return len(self.indices)

    @property
    def pattern(self) -> Tuple[tuple, tuple]:
        return (self.indptr, self.indices)

    def _diag_positions(self) -> tuple:
        return csr_diag_positions(self.indptr, self.indices)

    # -- ops (SUNMatScaleAdd / ScaleAddI / Matvec) -------------------------
    def scale_add(self, c, B: "SparseCSR") -> "SparseCSR":
        """A <- c*A + B; B must share the pattern (SUNMatScaleAdd's
        fast path — the only one a shared static pattern permits)."""
        assert B.pattern == self.pattern, "patterns must match"
        return SparseCSR(c * self.data + B.data, self.indptr,
                         self.indices, self.shape)

    def scale_addI(self, c) -> "SparseCSR":
        """A <- c*A + I in place on values, pattern reused — the Newton
        update ``M = I - gamma*J`` is ``J.scale_addI(-gamma)``."""
        diag = jnp.asarray(self._diag_positions())
        data = c * self.data
        data = data.at[diag].add(jnp.ones((), data.dtype))
        return SparseCSR(data, self.indptr, self.indices, self.shape)

    def matvec(self, x: jnp.ndarray, policy=None) -> jnp.ndarray:
        from . import dispatch as dv
        return dv.csr_spmv(self.data, x, self.pattern, policy)

    def to_dense(self) -> jnp.ndarray:
        rows = np.repeat(np.arange(self.shape[0]),
                         np.diff(np.asarray(self.indptr)))
        out = jnp.zeros(self.shape, self.data.dtype)
        return out.at[jnp.asarray(rows),
                      jnp.asarray(np.asarray(self.indices,
                                             np.int64))].set(self.data)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class EnsembleBSR:
    """``nsys`` block-sparse matrices sharing ONE block pattern.

    values : (nsys, nnzb, b, b) — only the nonzero blocks, SoA across
             the ensemble (:meth:`values_soa` gives the lane-major
             kernel layout ``(nnzb, b, b, nsys)``)
    brows / bcols : static block pattern (row-major block order)
    nblk   : block rows per system (n = nblk * b)
    """

    values: jnp.ndarray
    brows: tuple
    bcols: tuple
    nblk: int

    def tree_flatten(self):
        return (self.values,), (self.brows, self.bcols, self.nblk)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    # -- construction ------------------------------------------------------
    @classmethod
    def from_sparsity(cls, pattern, block_size: int, nsys: int,
                      dtype=jnp.float64) -> "EnsembleBSR":
        """Allocate zero values for an elementwise ``jac_sparsity``
        pattern — only the nonzero blocks are materialized (the
        diagonal blocks are always included for scale_addI)."""
        brows, bcols, nblk = block_pattern_from_element(pattern, block_size)
        values = jnp.zeros((nsys, len(brows), block_size, block_size),
                           dtype)
        return cls(values, brows, bcols, nblk)

    @classmethod
    def from_dense(cls, J: jnp.ndarray, block_size: int,
                   pattern=None) -> "EnsembleBSR":
        """Compress dense per-system Jacobians ``J: (nsys, n, n)``.
        ``pattern`` is the elementwise sparsity; if omitted, ``J`` must
        be concrete and the union pattern over systems is used."""
        nsys, n, _ = J.shape
        if pattern is None:
            pattern = np.any(np.abs(np.asarray(J)) > 0, axis=0)
        brows, bcols, nblk = block_pattern_from_element(pattern, block_size)
        values = cls._gather_blocks(jnp.asarray(J), brows, bcols,
                                    block_size)
        return cls(values, brows, bcols, nblk)

    @staticmethod
    def _gather_blocks(J: jnp.ndarray, brows, bcols,
                       b: int) -> jnp.ndarray:
        """(nsys, n, n) -> (nsys, nnzb, b, b) at the static positions
        (works on traced J: the gather indices are static)."""
        nsys, n, _ = J.shape
        nblk = n // b
        Jb = J.reshape(nsys, nblk, b, nblk, b).transpose(0, 1, 3, 2, 4)
        return Jb[:, jnp.asarray(brows), jnp.asarray(bcols)]

    # -- structure ---------------------------------------------------------
    @property
    def nnz_blocks(self) -> int:
        return len(self.brows)

    @property
    def block_size(self) -> int:
        return self.values.shape[-1]

    @property
    def nsys(self) -> int:
        return self.values.shape[0]

    @property
    def shape(self):
        n = self.nblk * self.block_size
        return (self.nsys, n, n)

    @property
    def values_soa(self) -> jnp.ndarray:
        """Lane-major kernel layout: (nnzb, b, b, nsys)."""
        return jnp.transpose(self.values, (1, 2, 3, 0))

    @property
    def block_pattern(self) -> Tuple[tuple, tuple, int]:
        return (self.brows, self.bcols, self.nblk)

    def _diag_block_positions(self) -> tuple:
        pos = []
        for I in range(self.nblk):
            hits = [e for e, (i, j) in enumerate(zip(self.brows,
                                                     self.bcols))
                    if i == I and j == I]
            if not hits:
                raise ValueError(
                    f"block pattern lacks diagonal block ({I},{I})")
            pos.append(hits[0])
        return tuple(pos)

    # -- ops ---------------------------------------------------------------
    def scale_addI(self, c) -> "EnsembleBSR":
        """A_s <- c_s * A_s + I for every system, in place on values
        with the pattern reused; ``c`` is scalar or per-system
        ``(nsys,)`` (the per-system gamma of the ensemble BDF)."""
        c = jnp.asarray(c)
        cexp = c.reshape((-1,) + (1,) * 3) if c.ndim else c
        vals = cexp * self.values
        b = self.block_size
        eye = jnp.eye(b, dtype=vals.dtype)
        diag = jnp.asarray(self._diag_block_positions())
        vals = vals.at[:, diag].add(eye[None, None])
        return EnsembleBSR(vals, self.brows, self.bcols, self.nblk)

    def matvec(self, x: jnp.ndarray, policy=None) -> jnp.ndarray:
        """y_s = A_s @ x_s for every system; x: (nsys, n) -> (nsys, n)."""
        from . import dispatch as dv
        nsys, n, _ = self.shape
        b = self.block_size
        x_soa = x.reshape(nsys, self.nblk, b).transpose(1, 2, 0)
        y = dv.bsr_spmv_soa(self.values_soa, x_soa, self.block_pattern,
                            policy)
        return y.transpose(2, 0, 1).reshape(nsys, n)

    def to_dense(self) -> jnp.ndarray:
        nsys, n, _ = self.shape
        b = self.block_size
        out = jnp.zeros((nsys, self.nblk, self.nblk, b, b),
                        self.values.dtype)
        out = out.at[:, jnp.asarray(self.brows),
                     jnp.asarray(self.bcols)].set(self.values)
        return out.transpose(0, 1, 3, 2, 4).reshape(nsys, n, n)
