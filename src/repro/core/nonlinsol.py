"""Pluggable nonlinear solvers — the SUNNonlinearSolver object layer.

The integrators' implicit stages used to call
:func:`repro.core.kinsol.newton_solve` /
:func:`~repro.core.kinsol.fixed_point_solve` directly, each passing its
own ad-hoc tolerance defaults.  These objects give the nonlinear solve
the same pluggable shape as :mod:`repro.core.linsol`: a frozen config
object the integrator threads through its step loop, with tolerances
taken from the one place they are defined —
:class:`~repro.core.arkode.ODEOptions` (``newton_tol_fac`` /
``newton_max``) via :meth:`NewtonSolver.from_options`.

* :class:`NewtonSolver`      — (modified/inexact) Newton; wraps
  :func:`kinsol.newton_solve`; the linear solve is still a callback, so
  any :class:`~repro.core.linsol.LinearSolver` plugs in underneath.
* :class:`FixedPointSolver`  — Anderson-accelerated fixed point; wraps
  :func:`kinsol.fixed_point_solve` (CVODE functional iteration).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from . import kinsol
from .policies import ExecPolicy, XLA_FUSED


@dataclass(frozen=True)
class NewtonSolver:
    """Config object for the Newton iteration (SUNNonlinSol_Newton).

    ``tol`` is the WRMS-weighted step tolerance *factor* (the fraction
    of the integrator's error-test tolerance the nonlinear solve must
    reach — CVODE's ``epcon``); ``max_iters`` caps iterations per solve.
    """

    tol: float = 0.1
    max_iters: int = 4
    damping: float = 1.0

    @classmethod
    def from_options(cls, opts) -> "NewtonSolver":
        """The one source of truth for integrator Newton tolerances."""
        return cls(tol=opts.newton_tol_fac, max_iters=opts.newton_max)

    def solve(self, gfun: Callable, z0, lin_solve: Callable, *,
              wnorm: Optional[Callable] = None,
              policy: ExecPolicy = XLA_FUSED):
        return kinsol.newton_solve(gfun, z0, lin_solve, wnorm=wnorm,
                                   tol=self.tol, max_iters=self.max_iters,
                                   damping=self.damping, policy=policy)


@dataclass(frozen=True)
class FixedPointSolver:
    """Config object for Anderson fixed-point (SUNNonlinSol_FixedPoint).

    ``m`` is the Anderson depth; ``tol`` the absolute RMS step
    tolerance (unlike Newton's relative factor — functional iteration
    has no WRMS weighting in the legacy path, preserved here).
    """

    m: int = 3
    tol: float = 1e-9
    max_iters: int = 50

    @classmethod
    def from_options(cls, opts, m: int = 2) -> "FixedPointSolver":
        # the legacy adams_integrate tolerance: a newton_tol_fac slice of
        # atol, floored so atol=0 still terminates
        return cls(m=m, tol=opts.newton_tol_fac * opts.atol + 1e-12,
                   max_iters=10)

    def solve(self, gfun: Callable, y0, *,
              wnorm: Optional[Callable] = None):
        return kinsol.fixed_point_solve(gfun, y0, m=self.m, tol=self.tol,
                                        max_iters=self.max_iters,
                                        wnorm=wnorm)
