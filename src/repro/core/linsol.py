"""Pluggable linear solvers — the SUNLinearSolver object layer.

The paper's headline design point is that integrators never name a
linear-algebra implementation: they talk to a ``SUNLinearSolver`` object
with a ``setup``/``solve`` split, and applications swap Krylov for
batched-direct (cuSolverSp batchQR) without touching integrator source.
This module is that layer for the JAX port.  Every implicit integrator
in :mod:`repro.core` accepts any of these objects via its ``lin_solver``
argument; the ensemble BDF additionally drives the SoA batch interface.

Two call surfaces, one object:

**Scalar (single-system) interface** — used by ``arkode``/``cvode``:

* :meth:`LinearSolver.bind` ``(fi, policy=..., mem=...)`` returns the
  callable ``lin_solve(t, z, gamma, rhs) -> dz`` solving the Newton
  system ``(I - gamma*J_fi(t, z)) dz = rhs`` that the integrators
  consume.  Krylov solvers are matrix-free (jvp); :class:`DenseGJ`
  builds the dense Jacobian with ``jacfwd``.

**SoA batch interface** — used by ``batched.ensemble_bdf_integrate``
(the CVODE lsetup/lsolve split; ``A`` is ``(n, n, nsys)`` with the
system batch on the lane axis):

* :meth:`LinearSolver.soa_setup` ``(Jsoa, gamma, policy)`` -> the saved
  per-step linear object (a block inverse for the factor-once direct
  solver, the bare Jacobian otherwise);
* :meth:`LinearSolver.soa_solve` ``(MJ, gamma, gamrat, rhs, policy)``
  -> ``(dz, nli)`` where ``nli`` is the number of inner linear
  iterations this solve cost (0 for direct solvers).

Implementations (names follow SUNDIALS):

=============  ==========================================================
SPGMR          restarted GMRES (matrix-free; the integrator default)
SPFGMR         flexible GMRES (stores the preconditioned basis)
SPBCGS         BiCGStab
SPTFQMR        transpose-free QMR
PCG            preconditioned conjugate gradient (SPD systems)
DenseGJ        dense jacfwd Jacobian + LU solve (small systems)
BlockDiagGJ    batched block-diagonal Gauss-Jordan over the SoA kernels;
               ``factor_once=True`` inverts at lsetup and lsolves with
               one SpMV per Newton iteration (the batchQR analog),
               ``factor_once=False`` re-solves with the current gamma
               every iteration
=============  ==========================================================

All objects are frozen dataclasses: hashable, jit-stable, and safe to
close over inside ``lax.while_loop`` bodies.  ``mem`` (a
:class:`~repro.core.memory.MemoryHelper`) is optional everywhere; when
given, solvers register their workspace (Krylov bases, saved block
matrices) so the run reports a real high-water mark.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import dispatch as dv
from . import krylov
from .policies import ExecPolicy

Pytree = Any


class LinearSolver:
    """Base protocol; see the module docstring for the two surfaces."""

    name = "linear_solver"

    # -- scalar (single-system) surface ------------------------------------
    def bind(self, fi: Callable, *, policy: Optional[ExecPolicy] = None,
             mem=None) -> Callable:
        """Return ``lin_solve(t, z, gamma, rhs) -> dz`` for ``fi``."""
        raise NotImplementedError

    # -- SoA ensemble surface (lsetup / lsolve split) ----------------------
    def soa_setup(self, Jsoa: jnp.ndarray, gamma: jnp.ndarray,
                  policy: Optional[ExecPolicy] = None) -> jnp.ndarray:
        """lsetup: turn the fresh Jacobian (n,n,nsys) into the saved
        linear object (same shape — it lives in the integrator carry)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no SoA batch path")

    def soa_solve(self, MJ: jnp.ndarray, gamma: jnp.ndarray,
                  gamrat: jnp.ndarray, rhs: jnp.ndarray,
                  policy: Optional[ExecPolicy] = None, mem=None):
        """lsolve: solve (I - gamma*J) dz = rhs; rhs/dz are (n, nsys).
        Returns ``(dz, nli)``."""
        raise NotImplementedError(
            f"{type(self).__name__} has no SoA batch path")


def as_lin_solve(lin_solver, fi: Callable, *,
                 policy: Optional[ExecPolicy] = None, mem=None,
                 default: Optional[LinearSolver] = None) -> Callable:
    """Normalize the integrators' ``lin_solver`` argument.

    Accepts a :class:`LinearSolver` object (bound here), a bare legacy
    callable ``(t, z, gamma, rhs) -> dz`` (returned unchanged), or
    ``None`` (falls back to ``default``, itself a :class:`LinearSolver`).
    """
    if lin_solver is None:
        lin_solver = default if default is not None else SPGMR()
    if isinstance(lin_solver, LinearSolver) or hasattr(lin_solver, "bind"):
        return lin_solver.bind(fi, policy=policy, mem=mem)
    return lin_solver


# ---------------------------------------------------------------------------
# Matrix-free Krylov family
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class _KrylovSolver(LinearSolver):
    """Shared machinery: matvec construction + the SoA global solve.

    Defaults match the integrators' historical built-in Newton-Krylov
    setting (``arkode.default_lin_solver``): an inexact solve to 1e-4,
    which the convergence-tested Newton wrapper is calibrated for.
    """

    tol: float = 1e-4
    atol: float = 0.0
    precond: Optional[Callable] = None

    def _run(self, matvec, b, *, policy=None, mem=None):
        raise NotImplementedError

    def bind(self, fi, *, policy=None, mem=None):
        def lin_solve(t, z, gamma, rhs):
            def matvec(v):
                _, jv = jax.jvp(lambda zz: fi(t, zz), (z,), (v,))
                return dv.linear_sum(1.0, v, -gamma, jv, policy)

            x, _ = self._run(matvec, rhs, policy=policy, mem=mem)
            return x

        return lin_solve

    # SoA path: the saved object is the Jacobian; each solve runs one
    # global Krylov iteration over the flattened block-diagonal system
    # (the matvec is a single batched SpMV, so per-iteration cost matches
    # the factor-once lsolve — convergence is on the aggregate residual).
    def soa_setup(self, Jsoa, gamma, policy=None):
        return Jsoa

    def soa_solve(self, MJ, gamma, gamrat, rhs, policy=None, mem=None):
        n = MJ.shape[0]
        eye = jnp.eye(n, dtype=MJ.dtype)
        M_cur = eye[:, :, None] - gamma[None, None, :] * MJ

        def matvec(v):
            return dv.blockdiag_spmv_soa(M_cur, v, policy)

        x, st = self._run(matvec, rhs, policy=policy, mem=mem)
        return x, st.iters


@dataclass(frozen=True)
class SPGMR(_KrylovSolver):
    name = "spgmr"
    restart: int = 20
    max_restarts: int = 2

    def _run(self, matvec, b, *, policy=None, mem=None):
        return krylov.gmres(matvec, b, tol=self.tol, atol=self.atol,
                            restart=self.restart,
                            max_restarts=self.max_restarts,
                            precond=self.precond, policy=policy, mem=mem)


@dataclass(frozen=True)
class SPFGMR(_KrylovSolver):
    name = "spfgmr"
    restart: int = 20
    max_restarts: int = 2

    def _run(self, matvec, b, *, policy=None, mem=None):
        return krylov.fgmres(matvec, b, tol=self.tol, atol=self.atol,
                             restart=self.restart,
                             max_restarts=self.max_restarts,
                             precond=self.precond, policy=policy, mem=mem)


@dataclass(frozen=True)
class SPBCGS(_KrylovSolver):
    name = "spbcgs"
    maxiter: int = 200

    def _run(self, matvec, b, *, policy=None, mem=None):
        return krylov.bicgstab(matvec, b, tol=self.tol, atol=self.atol,
                               maxiter=self.maxiter, precond=self.precond,
                               policy=policy, mem=mem)


@dataclass(frozen=True)
class SPTFQMR(_KrylovSolver):
    name = "sptfqmr"
    maxiter: int = 200

    def _run(self, matvec, b, *, policy=None, mem=None):
        return krylov.tfqmr(matvec, b, tol=self.tol, atol=self.atol,
                            maxiter=self.maxiter, precond=self.precond,
                            policy=policy, mem=mem)


@dataclass(frozen=True)
class PCG(_KrylovSolver):
    name = "pcg"
    maxiter: int = 200

    def _run(self, matvec, b, *, policy=None, mem=None):
        return krylov.pcg(matvec, b, tol=self.tol, atol=self.atol,
                          maxiter=self.maxiter, precond=self.precond,
                          policy=policy, mem=mem)


# ---------------------------------------------------------------------------
# Direct solvers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DenseGJ(LinearSolver):
    """Dense direct Newton solver: J by ``jacfwd``, solve by LU.

    Identical math to the legacy ``arkode.dense_lin_solver`` helper; the
    Jacobian is rebuilt at the current iterate on every call (full
    Newton), which is the right trade for the small systems this
    targets.
    """

    name = "dense_gj"

    def bind(self, fi, *, policy=None, mem=None):
        from jax.flatten_util import ravel_pytree

        def lin_solve(t, z, gamma, rhs):
            z_flat, unravel = ravel_pytree(z)
            rhs_flat, _ = ravel_pytree(rhs)
            if mem is not None:
                n = z_flat.shape[0]
                mem.register("densegj.newton_matrix", (n, n), z_flat.dtype)

            def f_flat(zf):
                return ravel_pytree(fi(t, unravel(zf)))[0]

            J = jax.jacfwd(f_flat)(z_flat)
            M = jnp.eye(J.shape[0], dtype=J.dtype) - gamma * J
            return unravel(jnp.linalg.solve(M, rhs_flat))

        return lin_solve


@dataclass(frozen=True)
class BlockDiagGJ(LinearSolver):
    """Batched block-diagonal Gauss-Jordan over the SoA dispatch ops.

    ``factor_once=True`` (the ensemble default, CVODE's lsetup/lsolve
    split): lsetup inverts every Newton block once with
    :func:`~repro.core.dispatch.block_inverse_soa` and each Newton
    iteration is a single :func:`~repro.core.dispatch.blockdiag_spmv_soa`
    against the saved inverse; gamma drift since the lsetup is absorbed
    by CVODE's ``2/(1+gamrat)`` correction.  ``factor_once=False``
    keeps the bare Jacobian and re-solves ``(I - gamma*J) dz = rhs``
    with the current gamma every iteration via
    :func:`~repro.core.dispatch.block_solve_soa`.
    """

    name = "blockdiag_gj"
    factor_once: bool = True

    def soa_setup(self, Jsoa, gamma, policy=None):
        if not self.factor_once:
            return Jsoa
        n = Jsoa.shape[0]
        eye = jnp.eye(n, dtype=Jsoa.dtype)
        M = eye[:, :, None] - gamma[None, None, :] * Jsoa
        return dv.block_inverse_soa(M, policy)

    def soa_solve(self, MJ, gamma, gamrat, rhs, policy=None, mem=None):
        zero = jnp.zeros((), jnp.int32)
        if self.factor_once:
            corr = 2.0 / (1.0 + gamrat)
            return corr[None, :] * dv.blockdiag_spmv_soa(MJ, rhs, policy), \
                zero
        n = MJ.shape[0]
        eye = jnp.eye(n, dtype=MJ.dtype)
        M_cur = eye[:, :, None] - gamma[None, None, :] * MJ
        return dv.block_solve_soa(M_cur, rhs, policy), zero

    def bind(self, fi, *, policy=None, mem=None):
        raise NotImplementedError(
            "BlockDiagGJ is the ensemble (SoA) solver; scalar integrators "
            "want DenseGJ or a Krylov solver")
