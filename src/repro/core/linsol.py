"""Pluggable linear solvers — the SUNLinearSolver object layer.

The paper's headline design point is that integrators never name a
linear-algebra implementation: they talk to a ``SUNLinearSolver`` object
with a ``setup``/``solve`` split, and applications swap Krylov for
batched-direct (cuSolverSp batchQR) without touching integrator source.
This module is that layer for the JAX port.  Every implicit integrator
in :mod:`repro.core` accepts any of these objects via its ``lin_solver``
argument; the ensemble BDF additionally drives the SoA batch interface.

Two call surfaces, one object:

**Scalar (single-system) interface** — used by ``arkode``/``cvode``:

* :meth:`LinearSolver.bind` ``(fi, policy=..., mem=...)`` returns the
  callable ``lin_solve(t, z, gamma, rhs) -> dz`` solving the Newton
  system ``(I - gamma*J_fi(t, z)) dz = rhs`` that the integrators
  consume.  Krylov solvers are matrix-free (jvp); :class:`DenseGJ`
  builds the dense Jacobian with ``jacfwd``.

**SoA batch interface** — used by ``batched.ensemble_bdf_integrate``
(the CVODE lsetup/lsolve split; the system batch rides the lane axis):

* :meth:`LinearSolver.soa_setup` ``(Jsoa, gamma, policy)`` -> the saved
  per-step linear object, an arbitrary pytree whose every leaf keeps
  the ``nsys`` axis LAST (so the integrator's masked per-system carry
  update broadcasts).  Dense solvers save ``(n, n, nsys)``; sparse
  solvers save only values ``(nnz, nsys)``; preconditioned Krylov
  additionally saves the psetup product.
* :meth:`LinearSolver.soa_solve` ``(MJ, gamma, gamrat, rhs, policy)``
  -> ``(dz, nli, npsolves)`` where ``nli`` counts inner linear
  iterations and ``npsolves`` preconditioner applications (both 0 for
  direct solvers).
* :meth:`LinearSolver.soa_carry_init` / :meth:`soa_workspace_shapes`
  describe the saved object so the integrator can allocate the carry
  and register honest workspace bytes — the mechanism by which sparse
  solvers report O(nnz) instead of O(n^2) storage.
* :meth:`LinearSolver.with_sparsity` binds a static ``jac_sparsity``
  pattern (encoded ``(indptr, indices)``); solvers without a sparse
  path return themselves unchanged.

Preconditioning: ``precond=`` on every Krylov solver accepts either a
legacy bare callable ``v -> M^{-1} v`` (applied as right
preconditioning, unchanged behavior) or a
:class:`repro.core.precond.Preconditioner` object, whose ``psetup``
runs at the solver's setup moment (each scalar lin_solve; the ensemble
lsetup triggers) and whose ``psolve`` is threaded through the Krylov
iteration as LEFT preconditioning with ``SolveStats.npsolves``
accounting.

Implementations (names follow SUNDIALS):

================  =======================================================
SPGMR             restarted GMRES (matrix-free; the integrator default)
SPFGMR            flexible GMRES (stores the preconditioned basis)
SPBCGS            BiCGStab
SPTFQMR           transpose-free QMR
PCG               preconditioned conjugate gradient (SPD systems)
DenseGJ           dense jacfwd Jacobian + LU solve (small systems)
BlockDiagGJ       batched block-diagonal Gauss-Jordan over the SoA
                  kernels; ``factor_once=True`` inverts at lsetup and
                  lsolves with one SpMV per Newton iteration (the
                  batchQR analog), ``factor_once=False`` re-solves with
                  the current gamma every iteration
EnsembleSparseGJ  the SUNLINSOL_CUSOLVERSP_BATCHQR analog: shared
                  static sparsity, symbolic analysis ONCE per run
                  (fill ordering + fill-in, host-cached), numeric
                  refactor only on lsetup triggers, O(nnz) storage
================  =======================================================

All objects are frozen dataclasses: hashable, jit-stable, and safe to
close over inside ``lax.while_loop`` bodies.  ``mem`` (a
:class:`~repro.core.memory.MemoryHelper`) is optional everywhere; when
given, solvers register their workspace (Krylov bases, saved block
matrices) so the run reports a real high-water mark.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import dispatch as dv
from . import krylov
from . import spsolve
from .policies import ExecPolicy

Pytree = Any


def encode_sparsity(pattern) -> tuple:
    """Normalize a ``jac_sparsity`` to the hashable static encoding the
    solvers carry: an (n, n) boolean/0-1 array (or an already-encoded
    ``(indptr, indices)`` pair) -> ``(indptr, indices)`` tuples with
    the diagonal forced in."""
    if isinstance(pattern, tuple) and len(pattern) == 2 and \
            isinstance(pattern[0], tuple):
        return pattern
    return spsolve.encode_pattern(pattern)


def _csr_rows_cols(indptr, indices):
    rows = np.repeat(np.arange(len(indptr) - 1),
                     np.diff(np.asarray(indptr)))
    return rows, np.asarray(indices, np.int64)


def _is_precond_obj(p) -> bool:
    return p is not None and hasattr(p, "psetup") and hasattr(p, "psolve")


def newton_blocks_soa(Jsoa: jnp.ndarray, gamma: jnp.ndarray) -> jnp.ndarray:
    """Dense SoA Newton blocks M = I - gamma*J: Jsoa (n, n, nsys),
    gamma (nsys,) -> (n, n, nsys).  Shared by the BlockDiagGJ lsetup,
    its factor_once=False lsolve, and the dense Krylov matvec — one
    definition so every solver forms the identical matrix (the ensemble
    integrator's SoA layout contract: nsys stays LAST)."""
    n = Jsoa.shape[0]
    eye = jnp.eye(n, dtype=Jsoa.dtype)
    return eye[:, :, None] - gamma[None, None, :] * Jsoa


class LinearSolver:
    """Base protocol; see the module docstring for the two surfaces."""

    name = "linear_solver"

    # -- scalar (single-system) surface ------------------------------------
    def bind(self, fi: Callable, *, policy: Optional[ExecPolicy] = None,
             mem=None) -> Callable:
        """Return ``lin_solve(t, z, gamma, rhs) -> dz`` for ``fi``."""
        raise NotImplementedError

    # -- SoA ensemble surface (lsetup / lsolve split) ----------------------
    def soa_setup(self, Jsoa: jnp.ndarray, gamma: jnp.ndarray,
                  policy: Optional[ExecPolicy] = None) -> Pytree:
        """lsetup: turn the fresh Jacobian (n,n,nsys) into the saved
        linear object (a pytree whose leaves keep nsys last — it lives
        in the integrator carry)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no SoA batch path")

    def soa_solve(self, MJ: Pytree, gamma: jnp.ndarray,
                  gamrat: jnp.ndarray, rhs: jnp.ndarray,
                  policy: Optional[ExecPolicy] = None, mem=None):
        """lsolve: solve (I - gamma*J) dz = rhs; rhs/dz are (n, nsys).
        Returns ``(dz, nli, npsolves)``."""
        raise NotImplementedError(
            f"{type(self).__name__} has no SoA batch path")

    def soa_carry_init(self, n: int, nsys: int, dtype) -> Pytree:
        """Zero saved-object pytree for the integrator carry."""
        return jnp.zeros((n, n, nsys), dtype)

    def soa_workspace_shapes(self, n: int, nsys: int):
        """Shapes of the persistent saved object, for MemoryHelper
        registration (list of (label_suffix, shape))."""
        return [("newton_blocks", (n, n, nsys))]

    # -- static sparsity ---------------------------------------------------
    def with_sparsity(self, enc: tuple) -> "LinearSolver":
        """Bind an encoded ``jac_sparsity``; solvers without a sparse
        path ignore it."""
        return self


def as_lin_solve(lin_solver, fi: Callable, *,
                 policy: Optional[ExecPolicy] = None, mem=None,
                 default: Optional[LinearSolver] = None) -> Callable:
    """Normalize the integrators' ``lin_solver`` argument.

    Accepts a :class:`LinearSolver` object (bound here), a bare legacy
    callable ``(t, z, gamma, rhs) -> dz`` (returned unchanged), or
    ``None`` (falls back to ``default``, itself a :class:`LinearSolver`).
    """
    if lin_solver is None:
        lin_solver = default if default is not None else SPGMR()
    if isinstance(lin_solver, LinearSolver) or hasattr(lin_solver, "bind"):
        return lin_solver.bind(fi, policy=policy, mem=mem)
    return lin_solver


# ---------------------------------------------------------------------------
# Matrix-free Krylov family
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class _KrylovSolver(LinearSolver):
    """Shared machinery: matvec construction + the SoA global solve.

    Defaults match the integrators' historical built-in Newton-Krylov
    setting (``arkode.default_lin_solver``): an inexact solve to 1e-4,
    which the convergence-tested Newton wrapper is calibrated for.

    ``precond`` — a bare callable (legacy right preconditioning) or a
    :class:`~repro.core.precond.Preconditioner` (psetup/psolve, applied
    LEFT, with npsolves accounting).  ``sparsity`` — an encoded static
    pattern (see :func:`encode_sparsity`); when set, the SoA path saves
    only the ``(nnz, nsys)`` Jacobian values and the flattened
    block-diagonal matvec becomes the shared-pattern sparse SpMV
    (``bsr_spmv_soa`` with 1x1 blocks) instead of the dense sweep.
    """

    tol: float = 1e-4
    atol: float = 0.0
    precond: Optional[Any] = None
    sparsity: Optional[tuple] = None

    def _run(self, matvec, b, *, policy=None, mem=None, precond=None,
             precond_left=None):
        raise NotImplementedError

    def with_sparsity(self, enc: tuple) -> "_KrylovSolver":
        new = self
        if new.sparsity is None:
            new = dataclasses.replace(new, sparsity=enc)
        # pattern-needing preconditioners (ILU0) pick the pattern up from
        # the same jac_sparsity binding
        p = new.precond
        if p is not None and hasattr(p, "with_sparsity"):
            p2 = p.with_sparsity(enc)
            if p2 is not p:
                new = dataclasses.replace(new, precond=p2)
        return new

    def _resolved_precond(self):
        """-> (legacy_right_callable, precond_object); at most one set."""
        p = self.precond
        if _is_precond_obj(p):
            return None, p
        return p, None

    # -- scalar surface ----------------------------------------------------
    def bind(self, fi, *, policy=None, mem=None):
        from jax.flatten_util import ravel_pytree
        legacy, pobj = self._resolved_precond()

        def lin_solve(t, z, gamma, rhs):
            def matvec(v):
                _, jv = jax.jvp(lambda zz: fi(t, zz), (z,), (v,))
                return dv.linear_sum(1.0, v, -gamma, jv, policy)

            if pobj is not None:
                pdata = pobj.psetup(t, z, gamma, policy=policy)
                _, unravel = ravel_pytree(rhs)

                def psolve(v):
                    vf = ravel_pytree(v)[0]
                    return unravel(pobj.psolve(pdata, vf, policy=policy))

                x, _ = self._run(matvec, rhs, policy=policy, mem=mem,
                                 precond_left=psolve)
            else:
                x, _ = self._run(matvec, rhs, policy=policy, mem=mem,
                                 precond=legacy)
            return x

        return lin_solve

    # -- SoA ensemble surface ----------------------------------------------
    # The saved object is ``(Jrepr, pdata)``: the Jacobian (dense SoA, or
    # values-only when a sparsity pattern is bound) plus the
    # preconditioner's psetup product (empty tuple when unpreconditioned).
    # Each solve runs one global Krylov iteration over the flattened
    # block-diagonal system (the matvec is a single batched SpMV, so
    # per-iteration cost matches the factor-once lsolve — convergence is
    # on the aggregate residual).

    def _sparse_newton_vals(self, Jvals, gamma):
        """(nnz, nsys) values of M = I - gamma*J on the static pattern."""
        indptr, indices = self.sparsity
        rows, cols = _csr_rows_cols(indptr, indices)
        diag = jnp.asarray(np.nonzero(rows == cols)[0])
        mvals = -gamma[None, :] * Jvals
        return mvals.at[diag].add(jnp.ones((), mvals.dtype))

    def soa_setup(self, Jsoa, gamma, policy=None):
        legacy, pobj = self._resolved_precond()
        if self.sparsity is not None:
            indptr, indices = self.sparsity
            rows, cols = _csr_rows_cols(indptr, indices)
            Jrepr = Jsoa[jnp.asarray(rows), jnp.asarray(cols)]
            if pobj is not None:
                mvals = self._sparse_newton_vals(Jrepr, gamma)
                pdata = pobj.soa_psetup(mvals, self.sparsity, gamma,
                                        policy=policy)
            else:
                pdata = ()
            return (Jrepr, pdata)
        if pobj is not None:
            M = newton_blocks_soa(Jsoa, gamma)
            pdata = pobj.soa_psetup(M, None, gamma, policy=policy)
        else:
            pdata = ()
        return (Jsoa, pdata)

    def soa_solve(self, MJ, gamma, gamrat, rhs, policy=None, mem=None):
        legacy, pobj = self._resolved_precond()
        Jrepr, pdata = MJ
        if self.sparsity is not None:
            indptr, indices = self.sparsity
            rows, cols = _csr_rows_cols(indptr, indices)
            n = len(indptr) - 1
            pat = (tuple(int(r) for r in rows),
                   tuple(int(c) for c in cols), n)
            mvals = self._sparse_newton_vals(Jrepr, gamma)
            V = mvals[:, None, None, :]          # 1x1 blocks

            def matvec(v):
                return dv.bsr_spmv_soa(V, v[:, None, :], pat,
                                       policy)[:, 0, :]
        else:
            M_cur = newton_blocks_soa(Jrepr, gamma)

            def matvec(v):
                return dv.blockdiag_spmv_soa(M_cur, v, policy)

        kw = {}
        if pobj is not None:
            kw["precond_left"] = \
                lambda v: pobj.soa_psolve(pdata, v, policy=policy)
        elif legacy is not None:
            kw["precond"] = legacy
        x, st = self._run(matvec, rhs, policy=policy, mem=mem, **kw)
        return x, st.iters, jnp.asarray(st.npsolves, jnp.int32)

    def soa_carry_init(self, n, nsys, dtype):
        _, pobj = self._resolved_precond()
        if self.sparsity is not None:
            nnz = len(self.sparsity[1])
            Jrepr = jnp.zeros((nnz, nsys), dtype)
        else:
            Jrepr = jnp.zeros((n, n, nsys), dtype)
        pdata = pobj.soa_pdata_init(n, nsys, dtype) \
            if pobj is not None else ()
        return (Jrepr, pdata)

    def soa_workspace_shapes(self, n, nsys):
        shapes = []
        if self.sparsity is not None:
            shapes.append(("newton_vals", (len(self.sparsity[1]), nsys)))
        else:
            shapes.append(("newton_blocks", (n, n, nsys)))
        _, pobj = self._resolved_precond()
        if pobj is not None:
            # shapes only — eval_shape avoids allocating the pdata
            leaves = jax.tree_util.tree_leaves(jax.eval_shape(
                lambda: pobj.soa_pdata_init(n, nsys, jnp.float64)))
            shapes.extend((f"precond{i}", leaf.shape)
                          for i, leaf in enumerate(leaves))
        return shapes


@dataclass(frozen=True)
class SPGMR(_KrylovSolver):
    name = "spgmr"
    restart: int = 20
    max_restarts: int = 2

    def _run(self, matvec, b, *, policy=None, mem=None, precond=None,
             precond_left=None):
        return krylov.gmres(matvec, b, tol=self.tol, atol=self.atol,
                            restart=self.restart,
                            max_restarts=self.max_restarts,
                            precond=precond, precond_left=precond_left,
                            policy=policy, mem=mem)


@dataclass(frozen=True)
class SPFGMR(_KrylovSolver):
    name = "spfgmr"
    restart: int = 20
    max_restarts: int = 2

    def _run(self, matvec, b, *, policy=None, mem=None, precond=None,
             precond_left=None):
        return krylov.fgmres(matvec, b, tol=self.tol, atol=self.atol,
                             restart=self.restart,
                             max_restarts=self.max_restarts,
                             precond=precond, precond_left=precond_left,
                             policy=policy, mem=mem)


@dataclass(frozen=True)
class SPBCGS(_KrylovSolver):
    name = "spbcgs"
    maxiter: int = 200

    def _run(self, matvec, b, *, policy=None, mem=None, precond=None,
             precond_left=None):
        return krylov.bicgstab(matvec, b, tol=self.tol, atol=self.atol,
                               maxiter=self.maxiter, precond=precond,
                               precond_left=precond_left,
                               policy=policy, mem=mem)


@dataclass(frozen=True)
class SPTFQMR(_KrylovSolver):
    name = "sptfqmr"
    maxiter: int = 200

    def _run(self, matvec, b, *, policy=None, mem=None, precond=None,
             precond_left=None):
        return krylov.tfqmr(matvec, b, tol=self.tol, atol=self.atol,
                            maxiter=self.maxiter, precond=precond,
                            precond_left=precond_left,
                            policy=policy, mem=mem)


@dataclass(frozen=True)
class PCG(_KrylovSolver):
    name = "pcg"
    maxiter: int = 200

    def _run(self, matvec, b, *, policy=None, mem=None, precond=None,
             precond_left=None):
        return krylov.pcg(matvec, b, tol=self.tol, atol=self.atol,
                          maxiter=self.maxiter, precond=precond,
                          precond_left=precond_left,
                          policy=policy, mem=mem)


# ---------------------------------------------------------------------------
# Direct solvers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DenseGJ(LinearSolver):
    """Dense direct Newton solver: J by ``jacfwd``, solve by LU.

    Identical math to the legacy ``arkode.dense_lin_solver`` helper; the
    Jacobian is rebuilt at the current iterate on every call (full
    Newton), which is the right trade for the small systems this
    targets.
    """

    name = "dense_gj"

    def bind(self, fi, *, policy=None, mem=None):
        from jax.flatten_util import ravel_pytree

        def lin_solve(t, z, gamma, rhs):
            z_flat, unravel = ravel_pytree(z)
            rhs_flat, _ = ravel_pytree(rhs)
            if mem is not None:
                n = z_flat.shape[0]
                mem.register("densegj.newton_matrix", (n, n), z_flat.dtype)

            def f_flat(zf):
                return ravel_pytree(fi(t, unravel(zf)))[0]

            J = jax.jacfwd(f_flat)(z_flat)
            M = jnp.eye(J.shape[0], dtype=J.dtype) - gamma * J
            return unravel(jnp.linalg.solve(M, rhs_flat))

        return lin_solve


@dataclass(frozen=True)
class BlockDiagGJ(LinearSolver):
    """Batched block-diagonal Gauss-Jordan over the SoA dispatch ops.

    ``factor_once=True`` (the ensemble default, CVODE's lsetup/lsolve
    split): lsetup inverts every Newton block once with
    :func:`~repro.core.dispatch.block_inverse_soa` and each Newton
    iteration is a single :func:`~repro.core.dispatch.blockdiag_spmv_soa`
    against the saved inverse; gamma drift since the lsetup is absorbed
    by CVODE's ``2/(1+gamrat)`` correction.  ``factor_once=False``
    keeps the bare Jacobian and re-solves ``(I - gamma*J) dz = rhs``
    with the current gamma every iteration via
    :func:`~repro.core.dispatch.block_solve_soa`.
    """

    name = "blockdiag_gj"
    factor_once: bool = True

    def soa_setup(self, Jsoa, gamma, policy=None):
        if not self.factor_once:
            return Jsoa
        return dv.block_inverse_soa(newton_blocks_soa(Jsoa, gamma), policy)

    def soa_solve(self, MJ, gamma, gamrat, rhs, policy=None, mem=None):
        zero = jnp.zeros((), jnp.int32)
        if self.factor_once:
            corr = 2.0 / (1.0 + gamrat)
            return corr[None, :] * dv.blockdiag_spmv_soa(MJ, rhs, policy), \
                zero, zero
        M_cur = newton_blocks_soa(MJ, gamma)
        return dv.block_solve_soa(M_cur, rhs, policy), zero, zero

    def bind(self, fi, *, policy=None, mem=None):
        raise NotImplementedError(
            "BlockDiagGJ is the ensemble (SoA) solver; scalar integrators "
            "want DenseGJ or a Krylov solver")


@dataclass(frozen=True)
class EnsembleSparseGJ(LinearSolver):
    """Batched sparse direct solver — the SUNLINSOL_CUSOLVERSP_BATCHQR
    analog for ensembles sharing one Jacobian sparsity pattern.

    The cuSolverSp batchQR split, TPU-native:

    * **symbolic setup once per run** — host-side (cached per pattern,
      :func:`repro.core.spsolve.symbolic_lu`): reverse Cuthill-McKee
      fill ordering, fill-in analysis, and the unrolled elimination
      schedule.  Nothing of this lives in device memory.
    * **numeric refactor on lsetup triggers only** — ``soa_setup``
      gathers the ``(nnzf, nsys)`` Newton values ``M = I - gamma*J``
      at the static (filled, permuted) positions and runs the
      straight-line no-pivot LU, elementwise across the system lanes.
    * **lsolve** — two unrolled triangular sweeps on the saved factor,
      with CVODE's ``2/(1+gamrat)`` correction for gamma drift since
      the last refactor (factor-once semantics, like
      ``BlockDiagGJ(factor_once=True)``).

    The carry and registered workspace are ``(nnzf, nsys)`` — O(nnz)
    instead of the dense O(n^2) Newton blocks, which is the paper's
    exploit-the-block-sparsity scaling win.  Construct with
    ``sparsity=`` or let ``integrate(..., method="ensemble_bdf")`` bind
    the problem's ``jac_sparsity`` via :meth:`with_sparsity`.
    """

    name = "ensemble_sparse_gj"
    sparsity: Optional[tuple] = None
    reorder: bool = True

    def __post_init__(self):
        if self.sparsity is not None:
            object.__setattr__(self, "sparsity",
                               encode_sparsity(self.sparsity))

    def with_sparsity(self, enc: tuple) -> "EnsembleSparseGJ":
        return self if self.sparsity is not None else \
            dataclasses.replace(self, sparsity=enc)

    def _plan(self) -> spsolve.LUPlan:
        if self.sparsity is None:
            raise ValueError(
                "EnsembleSparseGJ needs a sparsity pattern: pass "
                "sparsity= or set IVP.jac_sparsity")
        return spsolve.symbolic_lu(*self.sparsity, order=self.reorder,
                                   fill=True)

    def soa_setup(self, Jsoa, gamma, policy=None):
        plan = self._plan()
        # gather FIRST, then form M = I - gamma*J on the (nnzf, nsys)
        # values — no O(n^2 * nsys) dense intermediate at lsetup
        jvals = spsolve.gather_filled(plan, Jsoa)
        mvals = -gamma[None, :] * jvals
        mvals = mvals.at[jnp.asarray(plan.diag)].add(
            jnp.ones((), mvals.dtype))
        return spsolve.numeric_lu(plan, mvals)

    def soa_solve(self, MJ, gamma, gamrat, rhs, policy=None, mem=None):
        corr = 2.0 / (1.0 + gamrat)
        x = spsolve.lu_solve(self._plan(), MJ, rhs)
        zero = jnp.zeros((), jnp.int32)
        return corr[None, :] * x, zero, zero

    def soa_carry_init(self, n, nsys, dtype):
        return jnp.zeros((self._plan().nnz_factored, nsys), dtype)

    def soa_workspace_shapes(self, n, nsys):
        return [("newton_vals", (self._plan().nnz_factored, nsys))]

    def bind(self, fi, *, policy=None, mem=None):
        raise NotImplementedError(
            "EnsembleSparseGJ is the ensemble (SoA) solver; scalar "
            "integrators want DenseGJ or a Krylov solver")
