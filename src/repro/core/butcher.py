"""Butcher tables for ERK / DIRK / additive IMEX-ARK methods (ARKODE).

Tables are plain named tuples of numpy-convertible nested lists so they
stay static under jit (stage loops unroll at trace time, as in ARKODE
where the table is fixed per integrator instance).

Included (all from the ARKODE set / literature):
* ERK: euler (1), heun_euler 2(1), bogacki_shampine 3(2),
  zonneveld 4(3) omitted, dormand_prince 5(4).
* DIRK: sdirk2 2(1) (L-stable, gamma = 1 - 1/sqrt(2)),
  esdirk3 = the implicit half of ARK3(2)4L[2]SA.
* IMEX: ars222 (Ascher-Ruuth-Spiteri 2,2,2),
  ark324 = ARK3(2)4L[2]SA (Kennedy & Carpenter 2003) — ARKODE's default
  3rd-order IMEX pair with embedded 2nd-order error estimate.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Sequence


class ButcherTable(NamedTuple):
    A: Sequence[Sequence[float]]
    b: Sequence[float]
    c: Sequence[float]
    order: int
    b_emb: Optional[Sequence[float]] = None   # embedded weights (order-1 est.)
    emb_order: int = 0

    @property
    def stages(self) -> int:
        return len(self.b)

    @property
    def explicit(self) -> bool:
        return all(self.A[i][j] == 0.0
                   for i in range(self.stages)
                   for j in range(i, self.stages))

    @property
    def diag(self) -> Sequence[float]:
        return [self.A[i][i] for i in range(self.stages)]


class IMEXTable(NamedTuple):
    """Additive pair: explicit table for f_E, implicit table for f_I.

    Shared c and (for our pairs) shared b, per Kennedy-Carpenter ARK.
    """
    expl: ButcherTable
    impl: ButcherTable
    order: int
    emb_order: int


# ----------------------------------------------------------------------------
# Explicit tables
# ----------------------------------------------------------------------------

EULER = ButcherTable(A=[[0.0]], b=[1.0], c=[0.0], order=1)

HEUN_EULER = ButcherTable(  # 2(1)
    A=[[0.0, 0.0],
       [1.0, 0.0]],
    b=[0.5, 0.5],
    c=[0.0, 1.0],
    order=2,
    b_emb=[1.0, 0.0],
    emb_order=1,
)

BOGACKI_SHAMPINE = ButcherTable(  # 3(2), FSAL ignored (we re-eval)
    A=[[0.0, 0.0, 0.0, 0.0],
       [1 / 2, 0.0, 0.0, 0.0],
       [0.0, 3 / 4, 0.0, 0.0],
       [2 / 9, 1 / 3, 4 / 9, 0.0]],
    b=[2 / 9, 1 / 3, 4 / 9, 0.0],
    c=[0.0, 1 / 2, 3 / 4, 1.0],
    order=3,
    b_emb=[7 / 24, 1 / 4, 1 / 3, 1 / 8],
    emb_order=2,
)

DORMAND_PRINCE = ButcherTable(  # 5(4)
    A=[[0, 0, 0, 0, 0, 0, 0],
       [1 / 5, 0, 0, 0, 0, 0, 0],
       [3 / 40, 9 / 40, 0, 0, 0, 0, 0],
       [44 / 45, -56 / 15, 32 / 9, 0, 0, 0, 0],
       [19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729, 0, 0, 0],
       [9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656, 0, 0],
       [35 / 384, 0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0]],
    b=[35 / 384, 0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0],
    c=[0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1, 1],
    order=5,
    b_emb=[5179 / 57600, 0, 7571 / 16695, 393 / 640,
           -92097 / 339200, 187 / 2100, 1 / 40],
    emb_order=4,
)

# ----------------------------------------------------------------------------
# Diagonally implicit tables
# ----------------------------------------------------------------------------

_G = 1.0 - 1.0 / math.sqrt(2.0)  # SDIRK gamma, L-stable

SDIRK2 = ButcherTable(  # SDIRK-2-1-2 (ARKODE): 2 stages, order 2, emb 1
    A=[[_G, 0.0],
       [1.0 - _G, _G]],
    b=[1.0 - _G, _G],
    c=[_G, 1.0],
    order=2,
    b_emb=[0.5, 0.5],
    emb_order=1,
)

# Implicit Euler (for very stiff sanity tests)
IMPLICIT_EULER = ButcherTable(A=[[1.0]], b=[1.0], c=[1.0], order=1)

# Alexander (1977) 3-stage L-stable SDIRK of order 3 ("SDIRK-3-3").
# gamma is the root of x^3 - 3x^2 + 3x/2 - 1/6 in (0.3, 0.6); the
# embedded order-2 weights solve sum(bh)=1, bh.c=1/2 with bh[2]=0.
_G3 = 0.43586652150845967
_C32 = (1.0 + _G3) / 2.0
_B31 = -(6.0 * _G3 * _G3 - 16.0 * _G3 + 1.0) / 4.0
_B32 = (6.0 * _G3 * _G3 - 20.0 * _G3 + 5.0) / 4.0
_BH32 = (0.5 - _G3) / (_C32 - _G3)

SDIRK33 = ButcherTable(
    A=[[_G3, 0.0, 0.0],
       [_C32 - _G3, _G3, 0.0],
       [_B31, _B32, _G3]],
    b=[_B31, _B32, _G3],
    c=[_G3, _C32, 1.0],
    order=3,
    b_emb=[1.0 - _BH32, _BH32, 0.0],
    emb_order=2,
)

# ----------------------------------------------------------------------------
# ARK3(2)4L[2]SA — Kennedy & Carpenter (2003).  ARKODE's default 3rd-order
# IMEX pair (4 stages, ESDIRK implicit part, stiffly accurate, L-stable).
# ----------------------------------------------------------------------------

_g = 1767732205903 / 4055673282236  # the ESDIRK diagonal

_ARK324_c = [0.0, 1767732205903 / 2027836641118, 3 / 5, 1.0]
_ARK324_b = [1471266399579 / 7840856788654,
             -4482444167858 / 7529755066697,
             11266239266428 / 11593286722821,
             _g]
_ARK324_bemb = [2756255671327 / 12835298489170,
                -10771552573575 / 22201958757719,
                9247589265047 / 10645013368117,
                2193209047091 / 5459859503100]

ARK324_ERK = ButcherTable(
    A=[[0.0, 0.0, 0.0, 0.0],
       [1767732205903 / 2027836641118, 0.0, 0.0, 0.0],
       [5535828885825 / 10492691773637, 788022342437 / 10882634858940, 0.0, 0.0],
       [6485989280629 / 16251701735622, -4246266847089 / 9704473918619,
        10755448449292 / 10357097424841, 0.0]],
    b=_ARK324_b, c=_ARK324_c, order=3, b_emb=_ARK324_bemb, emb_order=2)

ARK324_ESDIRK = ButcherTable(
    A=[[0.0, 0.0, 0.0, 0.0],
       [_g, _g, 0.0, 0.0],
       [2746238789719 / 10658868560708, -640167445237 / 6845629431997, _g, 0.0],
       [1471266399579 / 7840856788654, -4482444167858 / 7529755066697,
        11266239266428 / 11593286722821, _g]],
    b=_ARK324_b, c=_ARK324_c, order=3, b_emb=_ARK324_bemb, emb_order=2)

ARK324 = IMEXTable(expl=ARK324_ERK, impl=ARK324_ESDIRK, order=3, emb_order=2)

# ----------------------------------------------------------------------------
# ARS(2,2,2) — Ascher, Ruuth & Spiteri 1997.  2nd order, no embedding
# (used fixed-step or with step-doubling error estimation).
# ----------------------------------------------------------------------------

_d = 1.0 - 1.0 / (2.0 * _G)

ARS222_ERK = ButcherTable(
    A=[[0.0, 0.0, 0.0],
       [_G, 0.0, 0.0],
       [_d, 1.0 - _d, 0.0]],
    b=[_d, 1.0 - _d, 0.0],
    c=[0.0, _G, 1.0],
    order=2)

ARS222_DIRK = ButcherTable(
    A=[[0.0, 0.0, 0.0],
       [0.0, _G, 0.0],
       [0.0, 1.0 - _G, _G]],
    b=[0.0, 1.0 - _G, _G],
    c=[0.0, _G, 1.0],
    order=2)

ARS222 = IMEXTable(expl=ARS222_ERK, impl=ARS222_DIRK, order=2, emb_order=0)

ERK_TABLES = {"euler": EULER, "heun_euler": HEUN_EULER,
              "bogacki_shampine": BOGACKI_SHAMPINE,
              "dormand_prince": DORMAND_PRINCE}
DIRK_TABLES = {"sdirk2": SDIRK2, "sdirk33": SDIRK33,
               "implicit_euler": IMPLICIT_EULER,
               "ark324_esdirk": ARK324_ESDIRK}
IMEX_TABLES = {"ark324": ARK324, "ars222": ARS222}
