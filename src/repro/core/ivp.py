"""Unified IVP front-end: one problem object, one ``integrate`` call.

Before this layer the integrator surface was divergent free functions
(``arkode.erk_integrate``/``imex_integrate`` taking ``ODEOptions``,
``cvode.bdf_integrate`` with its own kwargs, ``batched.ensemble_*``
selecting linear algebra by string).  This module is the SUNDIALS-style
composition point:

* :class:`IVP` — the problem: ``f`` (or ``fe`` + ``fi`` for additive
  IMEX splittings), optional analytic ``jac``, and ``y0``.  For
  ``ensemble_*`` methods ``f``/``jac`` are the vectorized batch forms
  (``(t:(nsys,), y:(nsys,n))``).
* :func:`integrate` — ``(problem, t0, tf, method, *, ctx, opts, ...)``
  returning one :class:`Solution` regardless of method.  The method is
  a string ``family[:variant]``:

  ===========================  =========================================
  ``"erk[:dopri5]"``           adaptive explicit RK (any ERK table)
  ``"dirk[:sdirk2|sdirk33]"``  adaptive DIRK + Newton
  ``"imex[:ark324]"``          adaptive additive IMEX-ARK
  ``"bdf"``                    adaptive BDF 1-5 (CVODE; ``order=`` kwarg)
  ``"adams"``                  functional-iteration Adams (nonstiff)
  ``"ensemble_erk[:table]"``   batched adaptive ERK
  ``"ensemble_dirk[:table]"``  batched adaptive DIRK, block-diag Newton
  ``"ensemble_bdf"``           batched adaptive-order BDF (SoA kernels)
  ===========================  =========================================

* pluggable solvers: ``lin_solver`` takes any
  :class:`repro.core.linsol.LinearSolver` (SPGMR/SPFGMR/SPBCGS/SPTFQMR/
  PCG/DenseGJ for scalar methods, BlockDiagGJ or a Krylov solver for
  ``ensemble_bdf``); ``nonlin_solver`` takes a
  :class:`repro.core.nonlinsol.NewtonSolver` /
  :class:`~repro.core.nonlinsol.FixedPointSolver`.
* the :class:`repro.core.context.Context` carries the ExecPolicy, the
  MemoryHelper (so :class:`Solution` reports a real workspace
  high-water mark), and run-wide counters.

Every method string routes to the corresponding legacy entry point with
identical numerics — the parity suite in ``tests/test_unified_api.py``
pins trajectory equality to 1e-12.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import arkode, batched, butcher, cvode
from .arkode import ODEOptions
from .context import Context

Pytree = Any

# canonical method strings (one per family:variant the parity suite and
# the CI front-end smoke iterate over)
METHOD_STRINGS = (
    "erk:dopri5",
    "erk:bogacki_shampine",
    "dirk:sdirk2",
    "dirk:sdirk33",
    "imex:ark324",
    "bdf",
    "adams",
    "ensemble_erk:bogacki_shampine",
    "ensemble_dirk:sdirk2",
    "ensemble_bdf",
)

_ERK_ALIASES = {"dopri5": "dormand_prince", "bs32": "bogacki_shampine",
                "heun": "heun_euler"}
_DIRK_ALIASES = {"esdirk3": "ark324_esdirk"}


@dataclass(frozen=True)
class IVP:
    """An initial-value problem: RHS (full or additive split), optional
    analytic Jacobian, and the initial state.

    f   : full RHS ``f(t, y)`` — exclusive with ``fe``+``fi``
    fe  : explicit (nonstiff) part for IMEX methods
    fi  : implicit (stiff) part for IMEX methods
    jac : analytic Jacobian — required by the ``ensemble_dirk`` /
          ``ensemble_bdf`` methods (batched ``(t, y) -> (nsys, n, n)``)
    f_soa, jac_soa : optional native SoA forms of ``f``/``jac`` for the
          ensemble hot loop (system axis LAST: ``f_soa(t, y:(n,nsys))
          -> (n,nsys)``, ``jac_soa -> (n,n,nsys)``).  When supplied,
          ``ensemble_bdf``/``ensemble_dirk`` evaluate the RHS/Jacobian
          with ZERO layout conversions; otherwise the AoS forms are
          wrapped with a transpose at the call boundary.
    jac_sparsity : static per-system Jacobian sparsity, an (n, n)
          boolean/0-1 pattern shared by every ensemble member.  When
          set, ``ensemble_bdf`` binds it to any ``lin_solver`` with a
          sparse path (``EnsembleSparseGJ``, sparse Krylov): the
          persistent Newton storage drops from O(n^2) to O(nnz) per
          system and sparse kernels replace the dense sweeps.
    y0  : initial state pytree (``(nsys, n)`` for ensemble methods)
    """

    f: Optional[Callable] = None
    fe: Optional[Callable] = None
    fi: Optional[Callable] = None
    jac: Optional[Callable] = None
    f_soa: Optional[Callable] = None
    jac_soa: Optional[Callable] = None
    jac_sparsity: Optional[Any] = None
    y0: Pytree = None

    def __post_init__(self):
        if (self.f is None) == (self.fe is None and self.fi is None):
            raise ValueError("IVP wants either f=... or fe=... and fi=...")
        if (self.fe is None) != (self.fi is None):
            raise ValueError("IMEX splittings need BOTH fe and fi")
        if self.y0 is None:
            raise ValueError("IVP needs y0")

    @property
    def full_rhs(self) -> Callable:
        """The complete RHS: ``f``, or ``fe + fi`` for split problems —
        what the non-IMEX method families integrate, so an IMEX-split
        problem run through e.g. ``bdf`` treats the WHOLE system
        implicitly instead of silently dropping ``fe``."""
        if self.f is not None:
            return self.f
        fe, fi = self.fe, self.fi
        return lambda t, y: jax.tree_util.tree_map(
            jnp.add, fe(t, y), fi(t, y))


class Solution(NamedTuple):
    """One result type for every method (the CVodeGetXxx roll-up)."""

    y: Pytree                      # state at tf
    t: jnp.ndarray                 # time reached (scalar methods); the
    #                                target tf for ensemble methods, whose
    #                                per-system progress lives in stats
    success: jnp.ndarray           # bool (scalar, or all-systems for ensemble)
    stats: Any                     # the raw IntegratorStats / EnsembleStats
    method: str
    lin_solver: str                # linear-solver name ("spgmr", ...)
    nonlin_solver: str             # "newton" | "fixed_point" | "none"
    nni: jnp.ndarray               # nonlinear iterations (summed over systems)
    nli: Optional[jnp.ndarray]     # inner linear iterations (None if untracked)
    nsetups: Optional[jnp.ndarray]  # lsetup count (ensemble_bdf only)
    workspace_bytes: int           # this call's registered workspace
    high_water_bytes: int          # run-wide memory high-water (ctx.memory)
    npsolves: Optional[jnp.ndarray] = None   # preconditioner applications
    npsetups: Optional[jnp.ndarray] = None   # preconditioner setups (ride
    #                                          the lsetup triggers)
    session: Optional[Any] = None  # ensemble_bdf warm-start continuation
    #                                state (return_session=True); see
    #                                repro.core.batched.SolverSession
    timings: Optional[dict] = None  # wall-clock split: {"queue_wait",
    #                                 "compile", "execute"} via the serving
    #                                 front-end, or {"lower", "compile",
    #                                 "execute"} from a timed direct
    #                                 integrate() call (None otherwise)
    telemetry: Optional[Any] = None  # StepTelemetry (step-level ring
    #                                  records) when the context enables
    #                                  observability telemetry or the call
    #                                  passes telemetry=K; None otherwise
    retcodes: Optional[jnp.ndarray] = None  # CV_*-style status
    #   (repro.core.status): (nsys,) int32 for ensemble methods, scalar
    #   for threaded scalar methods (bdf); None where not yet threaded
    ok: Optional[jnp.ndarray] = None  # retcodes == 0 (same shape); a
    #   quarantined lane's y is its last ACCEPTED state, not garbage
    degraded: bool = False         # True when the serving tier re-ran
    #   this bundle under the jnp oracle policy after a pallas-side
    #   failure (one-shot backend fallback)


def _split(method: str):
    fam, _, var = method.partition(":")
    return fam, (var or None)


def _erk_table(var):
    name = _ERK_ALIASES.get(var or "dopri5", var or "dopri5")
    return butcher.ERK_TABLES[name]


def _dirk_table(var):
    name = _DIRK_ALIASES.get(var or "sdirk2", var or "sdirk2")
    return butcher.DIRK_TABLES[name]


def _need(problem: IVP, attr: str, method: str):
    if attr == "f":          # every non-IMEX family integrates fe+fi whole
        return problem.full_rhs
    v = getattr(problem, attr)
    if v is None:
        raise ValueError(f"method {method!r} needs IVP.{attr}")
    return v


#: families that accept the step-telemetry ring (the implicit adaptive
#: loops whose per-step behavior the SUNLogger analog records)
_TELEMETRY_FAMILIES = ("bdf", "ensemble_dirk", "ensemble_bdf")

_KNOWN_FAMILIES = ("erk", "dirk", "imex", "bdf", "adams",
                   "ensemble_erk", "ensemble_dirk", "ensemble_bdf")


def integrate(problem: IVP, t0, tf, method: str = "bdf", *,
              ctx: Optional[Context] = None,
              opts: Optional[ODEOptions] = None,
              lin_solver=None, nonlin_solver=None,
              order: int = 5, live=None,
              timed: Optional[bool] = None, **method_kw) -> Solution:
    """Integrate ``problem`` from t0 to tf with ``method``.

    ctx           : :class:`~repro.core.context.Context`; a private one
                    is created (and its counters discarded) if omitted.
    opts          : ODEOptions; defaults to ``ctx.options()`` so the
                    context's ExecPolicy is applied.  An explicit opts
                    wins entirely (its policy included).
    lin_solver    : LinearSolver object (or legacy callable) for the
                    pluggable-linear-solver families (dirk, imex, bdf,
                    ensemble_bdf); a ValueError elsewhere.
    nonlin_solver : NewtonSolver / FixedPointSolver config object
                    (dirk, imex, bdf, adams); a ValueError elsewhere.
    order         : max BDF order for the ``bdf`` / ``ensemble_bdf``
                    families.
    live          : optional (nsys,) bool mask for ensemble methods on
                    bundles padded with dead lanes (a serving bundle
                    padded to its bucket size): the Solution's stats and
                    aggregates (nni, nsetups, success, ...) then count
                    LIVE lanes only (:meth:`~repro.core.batched.
                    EnsembleStats.masked`); a ValueError for scalar
                    methods.
    timed         : True runs the dispatch through the AOT pipeline
                    (``jit(...).lower().compile()``) and reports the
                    ``{lower, compile, execute}`` wall-time split in
                    ``Solution.timings`` — the same keys the serving
                    path populates, so profiler regions and timings
                    agree.  Defaults to ``ctx.observability.profile``;
                    falls back to the untimed path under an outer trace.
    method_kw     : passed through to the underlying integrator
                    (``dense_jac``, ``msbp``, ``m_aa``, ...;
                    ``ensemble_bdf`` additionally takes ``session=`` /
                    ``return_session=`` for warm-start continuation —
                    the exported session lands in ``Solution.session``;
                    ``telemetry=K`` on the ``bdf``/``ensemble_dirk``/
                    ``ensemble_bdf`` families threads a K-slot step-
                    telemetry ring through the loop, surfaced as
                    ``Solution.telemetry`` — also switched on for all
                    three via ``ctx.observability.telemetry``).
    """
    ctx = ctx if ctx is not None else Context()
    opts = opts if opts is not None else ctx.options()
    mem = ctx.memory
    live0 = mem.live_bytes
    labels0 = set(mem.workspaces)
    fam, var = _split(method)
    if fam not in _KNOWN_FAMILIES:
        raise ValueError(
            f"unknown method {method!r}; families: {', '.join(_KNOWN_FAMILIES)} "
            f"(canonical strings: {', '.join(METHOD_STRINGS)})")
    nli = None
    nsetups = None
    npsolves = None
    npsetups = None
    obs = ctx.observability
    # -- step telemetry: explicit telemetry=K wins; the context config
    # switches it on for every telemetry-capable family
    tel_cap = method_kw.pop("telemetry", None)
    if tel_cap is not None and fam not in _TELEMETRY_FAMILIES:
        raise ValueError(
            f"method {method!r} takes no telemetry= (step telemetry "
            f"covers the implicit adaptive families: "
            f"{', '.join(_TELEMETRY_FAMILIES)})")
    if tel_cap is None and obs.telemetry and fam in _TELEMETRY_FAMILIES:
        tel_cap = obs.telemetry_capacity
    if live is not None and not fam.startswith("ensemble"):
        raise ValueError(f"method {method!r} takes no live= mask (dead-"
                         "lane masking applies to ensemble bundles only)")
    # a solver object passed to a family that cannot consume it is an
    # error, not a silent no-op (Solution must never report a swap that
    # did not happen)
    if lin_solver is not None and fam not in ("dirk", "imex", "bdf",
                                              "ensemble_bdf"):
        raise ValueError(f"method {method!r} takes no lin_solver (the "
                         "pluggable families are dirk, imex, bdf, "
                         "ensemble_bdf)")
    if nonlin_solver is not None and fam not in ("dirk", "imex", "bdf",
                                                 "adams"):
        raise ValueError(f"method {method!r} takes no nonlin_solver (the "
                         "pluggable families are dirk, imex, bdf, adams)")
    lname = getattr(lin_solver, "name",
                    "custom" if lin_solver is not None else None)
    nlname = "newton" if fam in ("dirk", "imex", "bdf", "ensemble_dirk",
                                 "ensemble_bdf") else \
             "fixed_point" if fam == "adams" else "none"

    return_session = bool(method_kw.pop("return_session", False)) \
        if fam == "ensemble_bdf" else False
    if lname is None:
        if fam in ("dirk", "imex"):
            lname = "spgmr"
        elif fam == "bdf":
            lname = "dense_gj" if method_kw.get("dense_jac") else "spgmr"
        elif fam in ("ensemble_dirk", "ensemble_bdf"):
            lname = "blockdiag_gj"
        else:
            lname = "none"

    def _dispatch():
        """The family dispatch as a nullary closure, so the timed path
        can push the WHOLE call through jit().lower().compile() and
        report the AOT stage split.  Returns ``(y, st, session, ring)``
        (session/ring None when not requested)."""
        session = None
        ring = None
        if fam == "erk":
            f = _need(problem, "f", method)
            y, st = arkode.erk_integrate(f, problem.y0, t0, tf,
                                         _erk_table(var), opts, mem=mem)
        elif fam == "dirk":
            fi = _need(problem, "f", method)  # full RHS, treated implicitly
            y, st = arkode.dirk_integrate(fi, problem.y0, t0, tf,
                                          _dirk_table(var), opts,
                                          lin_solver=lin_solver,
                                          nonlin_solver=nonlin_solver,
                                          mem=mem)
        elif fam == "imex":
            fe = _need(problem, "fe", method)
            fi = _need(problem, "fi", method)
            tab = butcher.IMEX_TABLES[var or "ark324"]
            y, st = arkode.imex_integrate(fe, fi, problem.y0, t0, tf, tab,
                                          opts, lin_solver=lin_solver,
                                          nonlin_solver=nonlin_solver,
                                          mem=mem)
        elif fam == "bdf":
            f = _need(problem, "f", method)  # full RHS, treated implicitly
            out = cvode.bdf_integrate(f, problem.y0, t0, tf, order=order,
                                      opts=opts, lin_solver=lin_solver,
                                      nonlin_solver=nonlin_solver, mem=mem,
                                      telemetry=tel_cap, **method_kw)
            if tel_cap is not None:
                y, st, ring = out
            else:
                y, st = out
        elif fam == "adams":
            f = _need(problem, "f", method)
            y, st = cvode.adams_integrate(f, problem.y0, t0, tf, opts,
                                          nonlin_solver=nonlin_solver,
                                          mem=mem, **method_kw)
        elif fam == "ensemble_erk":
            f = _need(problem, "f", method)
            y, st = batched.ensemble_erk_integrate(f, problem.y0, t0, tf,
                                                   _erk_table(var), opts)
        elif fam == "ensemble_dirk":
            f = _need(problem, "f", method)
            jac = _need(problem, "jac", method)
            out = batched.ensemble_dirk_integrate(
                f, jac, problem.y0, t0, tf, _dirk_table(var), opts,
                policy=opts.policy, f_soa=problem.f_soa,
                jac_soa=problem.jac_soa, telemetry=tel_cap, **method_kw)
            if tel_cap is not None:
                y, st, ring = out
            else:
                y, st = out
        else:  # ensemble_bdf (families validated above)
            f = _need(problem, "f", method)
            jac = _need(problem, "jac", method)
            out = list(batched.ensemble_bdf_integrate(
                f, jac, problem.y0, t0, tf, order=order, opts=opts,
                policy=opts.policy, linear_solver=lin_solver,
                jac_sparsity=problem.jac_sparsity, mem=mem,
                f_soa=problem.f_soa, jac_soa=problem.jac_soa,
                return_session=return_session, telemetry=tel_cap,
                **method_kw))
            if tel_cap is not None:
                ring = out.pop()
            if return_session:
                session = out.pop()
            y, st = out
        return y, st, session, ring

    # -- timed (AOT) vs plain dispatch.  The timed path reports the
    # {lower, compile, execute} split (same keys the serving path uses)
    # and brackets each stage in a profiler region; it cannot run under
    # an outer trace (block_until_ready on tracers), so it degrades to
    # the plain path there.
    profile_on = obs.profile if timed is None else bool(timed)
    if profile_on and any(
            isinstance(leaf, jax.core.Tracer) for leaf in
            jax.tree_util.tree_leaves((problem.y0, t0, tf, method_kw))):
        profile_on = False
    timings = None
    if profile_on:
        import time as _time
        prof = ctx.profiler
        t_a = _time.perf_counter()
        with prof.region("integrate.lower", method=method):
            lowered = jax.jit(_dispatch).lower()
        t_b = _time.perf_counter()
        with prof.region("integrate.compile", method=method):
            compiled = lowered.compile()
        t_c = _time.perf_counter()
        with prof.region("integrate.execute", method=method):
            out = jax.block_until_ready(compiled())
        t_d = _time.perf_counter()
        timings = {"lower": t_b - t_a, "compile": t_c - t_b,
                   "execute": t_d - t_c}
    else:
        out = _dispatch()
    y, st, session, ring = out

    if fam == "ensemble_bdf":
        nli = st.nli[0] if st.nli is not None else None
        nsetups = st.nsetups
        npsolves = st.npsolves[0] if st.npsolves is not None else None
        # SUNDIALS accounting: psetup rides the lsetup triggers, so the
        # setup count is the lsetup total whenever a psetup/psolve
        # preconditioner is configured on the solver (same duck test
        # the solver layer applies)
        from .linsol import _is_precond_obj
        if _is_precond_obj(getattr(lin_solver, "precond", None)):
            npsetups = jnp.sum(st.nsetups)

    is_ensemble = fam.startswith("ensemble")
    if live is not None:
        # padded-bundle hygiene: dead lanes are zeroed out of the stats
        # BEFORE any aggregate below (success, nni sum, nsetups), so a
        # bundle padded to its bucket size reports live-lane work only
        st = st.masked(jnp.asarray(live, bool))
        if nsetups is not None:
            nsetups = st.nsetups
    success = jnp.all(st.success) if is_ensemble else st.success
    t_reached = getattr(st, "t", None)
    if t_reached is None:
        # EnsembleStats carries no per-system t; this is the TARGET time
        # (check `success` / stats.success for systems that stalled)
        t_reached = jnp.asarray(tf)
    nni = jnp.sum(st.nni) if is_ensemble else st.nni
    workspace = mem.live_bytes - live0
    # workspaces are per-call: release only the labels THIS call added
    # (foreign registrations on a shared ctx.memory stay live); the
    # high-water mark persists either way
    for label in set(mem.workspaces) - labels0:
        mem.release(label)
    ctx.record(st, nli)
    tel_obj = None
    if ring is not None:
        if any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree_util.tree_leaves(ring)):
            # under an outer trace the host-side wrapper cannot be
            # built; hand the raw (traced) ring through and let the
            # caller wrap it once values are concrete
            tel_obj = ring
        else:
            from ..observability.telemetry import StepTelemetry
            tel_obj = StepTelemetry(
                ring, live=None if live is None else live)
    # -- CV_*-style status surface: ensemble stats carry a per-lane
    # retcodes array, threaded scalar methods a scalar retcode; both
    # land on the same Solution fields (after dead-lane masking above,
    # so padded bundle lanes always read SUCCESS)
    retcodes = getattr(st, "retcodes", None)
    if retcodes is None:
        retcodes = getattr(st, "retcode", None)
    ok = getattr(st, "ok", None)
    if ok is None and retcodes is not None:
        ok = retcodes == 0
    if ctx.logger.enabled_for("WARNING") and retcodes is not None \
            and not isinstance(retcodes, jax.core.Tracer):
        import numpy as _np

        arr = _np.atleast_1d(_np.asarray(retcodes))
        failed = _np.nonzero(arr != 0)[0]
        if failed.size:
            from . import status as _status
            by_code = {
                _status.retcode_name(int(code)):
                    int((arr == code).sum())
                for code in _np.unique(arr[failed])}
            ctx.logger.warning(
                "integrate.lane_failed", method=method,
                failed=int(failed.size), nsys=int(arr.size),
                retcodes=by_code,
                lanes=[int(i) for i in failed[:16]])
    if ctx.logger.enabled_for("INFO"):
        ctx.logger.info(
            "integrate.done", method=method, lin_solver=lname or "none",
            steps=Context._concrete(getattr(st, "steps", None)),
            nni=Context._concrete(nni),
            success=Context._concrete(success))
    return Solution(y=y, t=t_reached, success=success, stats=st,
                    method=method, lin_solver=lname or "none",
                    nonlin_solver=nlname, nni=nni, nli=nli,
                    nsetups=nsetups, workspace_bytes=workspace,
                    high_water_bytes=mem.high_water_bytes,
                    npsolves=npsolves, npsetups=npsetups,
                    session=session, timings=timings, telemetry=tel_obj,
                    retcodes=retcodes, ok=ok)
