"""ARKODE analog: adaptive explicit / implicit / IMEX additive Runge-Kutta.

The integrator control logic is written **only** against the vector-ops
layer (streaming ops + WRMS reductions) and solver callbacks — the
paper's core design point: the same integrator source runs on any data
layout / parallel backend, because every hardware-specific detail lives
in the vector / solver implementations.

Public entry points:
* :func:`erk_integrate`  — adaptive explicit RK (embedded pairs).
* :func:`dirk_integrate` — adaptive diagonally-implicit RK + Newton.
* :func:`imex_integrate` — adaptive additive IMEX-ARK (ARKODE's IMEX).
* ``*_fixed`` variants   — fixed-step (for convergence-order tests).

All are jit-, vmap- and shard-compatible: state is a flat NamedTuple of
scalars + the solution pytree; loops are ``lax.while_loop``/``scan``.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import controller as ctrl
from . import dispatch as dv
from . import vector as nv
from .butcher import ButcherTable, IMEXTable
from .nonlinsol import NewtonSolver
from .policies import ExecPolicy, XLA_FUSED

Pytree = Any


class IntegratorStats(NamedTuple):
    steps: jnp.ndarray          # accepted steps
    attempts: jnp.ndarray       # step attempts
    nfe: jnp.ndarray            # explicit RHS evals
    nfi: jnp.ndarray            # implicit RHS evals
    nni: jnp.ndarray            # Newton iterations
    netf: jnp.ndarray           # error-test failures
    ncfn: jnp.ndarray           # nonlinear convergence failures
    last_h: jnp.ndarray
    t: jnp.ndarray
    success: jnp.ndarray
    retcode: Optional[jnp.ndarray] = None   # scalar int32 CV_*-style
    # flag (repro.core.status); None for integrators not yet threaded


class ODEOptions(NamedTuple):
    rtol: float = 1e-6
    atol: float = 1e-9
    h0: float = 0.0             # 0 -> auto
    hmin: float = 0.0
    hmax: float = jnp.inf
    max_steps: int = 100_000
    newton_max: int = 4
    newton_tol_fac: float = 0.1   # Newton tol = fac * (error-test tol 1.0)
    controller: ctrl.ControllerConfig = ctrl.ControllerConfig()
    eta_cf: float = 0.25          # h reduction after a Newton failure
    policy: ExecPolicy = XLA_FUSED  # vector-op backend (dispatch table)


def _tree_where(pred, a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda x, y: jnp.where(pred, x, y), a, b)


def _ewt(y: Pytree, rtol, atol) -> Pytree:
    """SUNDIALS error weights: ewt_i = 1/(rtol*|y_i| + atol)."""
    return jax.tree_util.tree_map(
        lambda yl: 1.0 / (rtol * jnp.abs(yl) + atol), y)


def _initial_h(f, t0, y0, tf, rtol, atol, policy: ExecPolicy = XLA_FUSED):
    """Cheap h0 heuristic (Hairer-Wanner-style, simplified)."""
    w = _ewt(y0, rtol, atol)
    f0 = f(t0, y0)
    d0 = dv.wrms_norm(y0, w, policy)
    d1 = dv.wrms_norm(f0, w, policy)
    h = jnp.where(d1 > 1e-10, 0.01 * d0 / jnp.maximum(d1, 1e-10),
                  1e-6 * (tf - t0))
    h = jnp.clip(h, 1e-12 * (tf - t0), 0.1 * (tf - t0))
    return jnp.maximum(h, 1e-14)


# ----------------------------------------------------------------------------
# Explicit RK
# ----------------------------------------------------------------------------


def _erk_step(f, t, y, h, table: ButcherTable,
              policy: ExecPolicy = XLA_FUSED):
    """One explicit step: returns (y_new, y_err, nfe)."""
    s = table.stages
    ks = []
    for i in range(s):
        if i == 0:
            yi = y
        else:
            coeffs = [1.0] + [h * table.A[i][j] for j in range(i)]
            yi = dv.linear_combination(coeffs, [y] + ks, policy)
        ks.append(f(t + table.c[i] * h, yi))
    y_new = dv.linear_combination([1.0] + [h * bi for bi in table.b],
                                  [y] + ks, policy)
    if table.b_emb is not None:
        dcoef = [h * (bi - bh) for bi, bh in zip(table.b, table.b_emb)]
        y_err = dv.linear_combination(dcoef, ks, policy)
    else:
        y_err = nv.const_like(0.0, y)
    return y_new, y_err, s


def erk_integrate(f: Callable, y0: Pytree, t0, tf,
                  table: ButcherTable, opts: ODEOptions = ODEOptions(),
                  mem=None):
    """Adaptive explicit RK from t0 to tf. Returns (y(tf), stats)."""
    if mem is not None:
        mem.register("erk.stages", (table.stages, nv.tree_size(y0)),
                     jnp.result_type(*jax.tree_util.tree_leaves(y0)))
    t0 = jnp.asarray(t0, dtype=jnp.result_type(float))
    tf = jnp.asarray(tf, dtype=t0.dtype)
    h0 = jnp.where(opts.h0 > 0, opts.h0,
                   _initial_h(f, t0, y0, tf, opts.rtol, opts.atol,
                              opts.policy))
    p = max(table.emb_order + 1, 2)  # controller exponent (ARKODE style)

    class Carry(NamedTuple):
        t: jnp.ndarray
        y: Pytree
        h: jnp.ndarray
        cst: ctrl.ControllerState
        stats: IntegratorStats
        after_fail: jnp.ndarray
        give_up: jnp.ndarray

    def cond(c: Carry):
        return ((c.t < tf * (1 - 1e-12) - 1e-300) &
                (c.stats.attempts < opts.max_steps) & (~c.give_up))

    def body(c: Carry) -> Carry:
        h = jnp.minimum(c.h, tf - c.t)
        y_new, y_err, nfe = _erk_step(f, c.t, c.y, h, table, opts.policy)
        w = _ewt(c.y, opts.rtol, opts.atol)
        err = dv.wrms_norm(y_err, w, opts.policy)
        # guard NaN/Inf: treat as failed step
        bad = ~jnp.isfinite(err)
        err = jnp.where(bad, 2.0, err)
        accept = (err <= 1.0) & ~bad
        eta, cst = ctrl.eta_from_error(opts.controller, c.cst, err, p,
                                       after_failure=~accept)
        cst = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), cst, c.cst)
        t_n = jnp.where(accept, c.t + h, c.t)
        y_n = _tree_where(accept, y_new, c.y)
        h_n = jnp.clip(h * eta, opts.hmin, opts.hmax)
        give_up = (h_n <= opts.hmin) & (opts.hmin > 0) | (h * eta < 1e-14)
        st = c.stats
        st = st._replace(
            steps=st.steps + accept.astype(jnp.int32),
            attempts=st.attempts + 1,
            nfe=st.nfe + nfe,
            netf=st.netf + (~accept).astype(jnp.int32),
            last_h=h, t=t_n)
        return Carry(t_n, y_n, h_n, cst, st, ~accept, give_up)

    zero = jnp.zeros((), jnp.int32)
    stats0 = IntegratorStats(zero, zero, zero, zero, zero, zero, zero,
                             h0, t0, jnp.zeros((), bool))
    c = Carry(t0, y0, h0, ctrl.init_state(t0.dtype), stats0,
              jnp.zeros((), bool), jnp.zeros((), bool))
    c = lax.while_loop(cond, body, c)
    stats = c.stats._replace(success=c.t >= tf * (1 - 1e-10))
    return c.y, stats


def erk_fixed(f: Callable, y0: Pytree, t0, tf, n_steps: int,
              table: ButcherTable, policy: ExecPolicy = XLA_FUSED):
    """Fixed-step ERK via scan (for convergence-order tests)."""
    h = (tf - t0) / n_steps

    def step(carry, i):
        t, y = carry
        y_new, _, _ = _erk_step(f, t, y, h, table, policy)
        return (t + h, y_new), None

    (t, y), _ = lax.scan(step, (jnp.asarray(t0, jnp.result_type(float)), y0),
                         jnp.arange(n_steps))
    return y


# ----------------------------------------------------------------------------
# Implicit stage machinery (shared by DIRK and IMEX)
# ----------------------------------------------------------------------------


def default_lin_solver(fi: Callable, policy: ExecPolicy = XLA_FUSED):
    """Matrix-free Newton linear solver (legacy helper): the bound form
    of :class:`repro.core.linsol.SPGMR` with ARKODE's default Newton
    setting.  Prefer passing ``lin_solver=linsol.SPGMR()`` (or any other
    :class:`~repro.core.linsol.LinearSolver`) to the integrators."""
    from .linsol import SPGMR
    return SPGMR().bind(fi, policy=policy)


def dense_lin_solver(fi: Callable):
    """Direct dense Newton solver via jacfwd (legacy helper): the bound
    form of :class:`repro.core.linsol.DenseGJ`."""
    from .linsol import DenseGJ
    return DenseGJ().bind(fi)


def _bind_lin_solver(lin_solver, fi, opts, mem=None):
    """Normalize lin_solver (LinearSolver object | legacy callable | None)
    to the internal ``(t, z, gamma, rhs) -> dz`` callable."""
    from .linsol import SPGMR, as_lin_solve
    return as_lin_solve(lin_solver, fi, policy=opts.policy, mem=mem,
                        default=SPGMR())


def _implicit_stage(fi, t_i, r, h_aii, z0, lin_solve, wnorm, opts,
                    nls: Optional[NewtonSolver] = None):
    """Solve z = r + h*aii*fi(t_i, z) by Newton; returns (z, iters, ok)."""
    gamma = h_aii
    nls = nls or NewtonSolver.from_options(opts)

    def gfun(z):
        return dv.linear_combination([1.0, -gamma, -1.0],
                                     [z, fi(t_i, z), r], opts.policy)

    def nlin_solve(z, rhs):
        return lin_solve(t_i, z, gamma, rhs)

    z, st = nls.solve(gfun, z0, nlin_solve, wnorm=wnorm,
                      policy=opts.policy)
    return z, st.iters, st.converged


# ----------------------------------------------------------------------------
# IMEX-ARK (and DIRK as the fe=0 special case)
# ----------------------------------------------------------------------------


def _ark_step(fe, fi, t, y, h, tab: IMEXTable, lin_solve, wnorm, opts,
              nls: Optional[NewtonSolver] = None):
    """One additive RK step. Returns (y_new, y_err, nfe, nfi, nni, ok)."""
    AE, AI = tab.expl.A, tab.impl.A
    bE, bI = tab.expl.b, tab.impl.b
    cE, cI = tab.expl.c, tab.impl.c
    s = tab.impl.stages
    kE, kI = [], []
    nni = jnp.zeros((), jnp.int32)
    ok = jnp.ones((), bool)
    for i in range(s):
        coeffs, vecs = [1.0], [y]
        for j in range(i):
            if AE[i][j] != 0.0:
                coeffs.append(h * AE[i][j]); vecs.append(kE[j])
            if AI[i][j] != 0.0:
                coeffs.append(h * AI[i][j]); vecs.append(kI[j])
        r = dv.linear_combination(coeffs, vecs, opts.policy)
        aii = AI[i][i]
        if aii == 0.0:
            z = r
        else:
            z, it, conv = _implicit_stage(fi, t + cI[i] * h, r, h * aii,
                                          r, lin_solve, wnorm, opts, nls)
            nni = nni + it
            ok = ok & conv
        kE.append(fe(t + cE[i] * h, z))
        kI.append(fi(t + cI[i] * h, z))
    y_new = dv.linear_combination(
        [1.0] + [h * b for b in bE] + [h * b for b in bI],
        [y] + kE + kI, opts.policy)
    if tab.expl.b_emb is not None:
        dE = [h * (b - bh) for b, bh in zip(bE, tab.expl.b_emb)]
        dI = [h * (b - bh) for b, bh in zip(bI, tab.impl.b_emb)]
        y_err = dv.linear_combination(dE + dI, kE + kI, opts.policy)
    else:
        y_err = nv.const_like(0.0, y)
    # fi evals: one per stage k_I plus one per Newton iteration (G eval).
    return y_new, y_err, s, s + nni, nni, ok


def imex_integrate(fe: Callable, fi: Callable, y0: Pytree, t0, tf,
                   tab: IMEXTable, opts: ODEOptions = ODEOptions(),
                   lin_solver: Optional[Callable] = None,
                   nonlin_solver: Optional[NewtonSolver] = None,
                   mem=None):
    """Adaptive IMEX-ARK: y' = fe(t,y) + fi(t,y); fe explicit, fi implicit.

    ``lin_solver`` is a :class:`repro.core.linsol.LinearSolver` object
    or a legacy callable ``(t, z, gamma, rhs) -> dz`` solving
    (I - gamma*J_fi) dz = rhs.  Defaults to matrix-free SPGMR (jvp).
    ``nonlin_solver`` (:class:`~repro.core.nonlinsol.NewtonSolver`)
    defaults to the ODEOptions Newton tolerances; ``mem`` is an optional
    :class:`~repro.core.memory.MemoryHelper` for workspace accounting.
    """
    lin_solve = _bind_lin_solver(lin_solver, fi, opts, mem)
    nls = nonlin_solver or NewtonSolver.from_options(opts)
    if mem is not None:
        mem.register("ark.stages", (2 * tab.impl.stages, nv.tree_size(y0)),
                     jnp.result_type(*jax.tree_util.tree_leaves(y0)))
    t0 = jnp.asarray(t0, dtype=jnp.result_type(float))
    tf = jnp.asarray(tf, dtype=t0.dtype)

    def ftot(t, y):
        return dv.linear_sum(1.0, fe(t, y), 1.0, fi(t, y), opts.policy)

    h0 = jnp.where(opts.h0 > 0, opts.h0,
                   _initial_h(ftot, t0, y0, tf, opts.rtol, opts.atol,
                              opts.policy))
    p = max(tab.emb_order + 1, 2)

    class Carry(NamedTuple):
        t: jnp.ndarray
        y: Pytree
        h: jnp.ndarray
        cst: ctrl.ControllerState
        stats: IntegratorStats
        give_up: jnp.ndarray

    def cond(c):
        return ((c.t < tf * (1 - 1e-12) - 1e-300) &
                (c.stats.attempts < opts.max_steps) & (~c.give_up))

    def body(c):
        h = jnp.minimum(c.h, tf - c.t)
        w = _ewt(c.y, opts.rtol, opts.atol)

        def wnorm(v):
            return dv.wrms_norm(v, w, opts.policy)

        y_new, y_err, nfe, nfi, nni, nl_ok = _ark_step(
            fe, fi, c.t, c.y, h, tab, lin_solve, wnorm, opts, nls)
        err = dv.wrms_norm(y_err, w, opts.policy)
        bad = ~jnp.isfinite(err) | ~nl_ok
        err = jnp.where(bad, 2.0, err)
        accept = (err <= 1.0) & ~bad
        eta, cst = ctrl.eta_from_error(opts.controller, c.cst, err, p,
                                       after_failure=(~accept) & nl_ok)
        # Newton failure: fixed shrink factor (ARKODE's etacf)
        eta = jnp.where(nl_ok, eta, opts.eta_cf)
        cst = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), cst, c.cst)
        t_n = jnp.where(accept, c.t + h, c.t)
        y_n = _tree_where(accept, y_new, c.y)
        h_n = jnp.clip(h * eta, opts.hmin, opts.hmax)
        give_up = h * eta < 1e-14
        st = c.stats
        st = st._replace(
            steps=st.steps + accept.astype(jnp.int32),
            attempts=st.attempts + 1,
            nfe=st.nfe + nfe, nfi=st.nfi + nfi, nni=st.nni + nni,
            netf=st.netf + ((~accept) & nl_ok).astype(jnp.int32),
            ncfn=st.ncfn + (~nl_ok).astype(jnp.int32),
            last_h=h, t=t_n)
        return Carry(t_n, y_n, h_n, cst, st, give_up)

    zero = jnp.zeros((), jnp.int32)
    stats0 = IntegratorStats(zero, zero, zero, zero, zero, zero, zero,
                             h0, t0, jnp.zeros((), bool))
    c = Carry(t0, y0, h0, ctrl.init_state(t0.dtype), stats0,
              jnp.zeros((), bool))
    c = lax.while_loop(cond, body, c)
    stats = c.stats._replace(success=c.t >= tf * (1 - 1e-10))
    return c.y, stats


def dirk_integrate(fi: Callable, y0: Pytree, t0, tf, table: ButcherTable,
                   opts: ODEOptions = ODEOptions(),
                   lin_solver: Optional[Callable] = None,
                   nonlin_solver: Optional[NewtonSolver] = None,
                   mem=None):
    """Adaptive DIRK for stiff y' = fi(t, y) (zero explicit part)."""
    def fe(t, y):
        return nv.const_like(0.0, y)

    tab = IMEXTable(expl=ButcherTable(A=[[0.0] * table.stages
                                         for _ in range(table.stages)],
                                      b=[0.0] * table.stages,
                                      c=table.c, order=table.order,
                                      b_emb=([0.0] * table.stages
                                             if table.b_emb is not None
                                             else None),
                                      emb_order=table.emb_order),
                    impl=table, order=table.order,
                    emb_order=table.emb_order)
    return imex_integrate(fe, fi, y0, t0, tf, tab, opts, lin_solver,
                          nonlin_solver=nonlin_solver, mem=mem)


def imex_fixed(fe, fi, y0, t0, tf, n_steps: int, tab: IMEXTable,
               lin_solver: Optional[Callable] = None,
               opts: ODEOptions = ODEOptions(newton_max=12)):
    """Fixed-step IMEX (convergence tests).  Newton tol tightened so the
    nonlinear-solve error never pollutes the measured order."""
    lin_solve = _bind_lin_solver(lin_solver, fi, opts)
    h = (tf - t0) / n_steps

    def wnorm(v):
        return jnp.sqrt(dv.dot(v, v, opts.policy) / nv.tree_size(v))

    o = opts._replace(newton_tol_fac=1e-10, newton_max=12)

    def step(carry, _):
        t, y = carry
        y_new, *_ = _ark_step(fe, fi, t, y, h, tab, lin_solve, wnorm, o)
        return (t + h, y_new), None

    (t, y), _ = lax.scan(step, (jnp.asarray(t0, jnp.result_type(float)), y0),
                         jnp.arange(n_steps))
    return y


def dirk_fixed(fi, y0, t0, tf, n_steps, table: ButcherTable,
               lin_solver=None):
    def fe(t, y):
        return nv.const_like(0.0, y)

    s = table.stages
    tab = IMEXTable(expl=ButcherTable(A=[[0.0] * s for _ in range(s)],
                                      b=[0.0] * s, c=table.c,
                                      order=table.order,
                                      b_emb=([0.0] * s if table.b_emb
                                             is not None else None),
                                      emb_order=table.emb_order),
                    impl=table, order=table.order, emb_order=table.emb_order)
    return imex_fixed(fe, fi, y0, t0, tf, n_steps, tab, lin_solver)
