"""CV_*-style integer return codes — failure status carried in data.

SUNDIALS' contract is that every solve returns a structured flag
(``CV_SUCCESS``, ``CV_CONV_FAILURE``, ``CV_TOO_MUCH_WORK``, ...).  On
an accelerator a device kernel cannot signal an error mid-flight (the
source paper calls this out for its GPU vectors), so the status must be
*carried in data*: each system of an ensemble owns one int32 retcode
lane threaded through the step-loop carry, and the host reduces the
lane back into typed results after the loop exits.

The numeric values follow CVODE's ``cvode.h`` flags where an exact
analog exists, so a reader coming from SUNDIALS can pattern-match:

=====================  =====  ============================================
name                   value  CVODE analog / meaning
=====================  =====  ============================================
``SUCCESS``                0  ``CV_SUCCESS``
``TOO_MUCH_WORK``         -1  ``CV_TOO_MUCH_WORK`` — the lane spent
                              ``max_steps`` attempts without reaching tf
``ERR_FAILURE``           -3  ``CV_ERR_FAILURE`` — repeated local error
                              test failures, or h underflowed while the
                              corrector was still converging
``CONV_FAILURE``          -4  ``CV_CONV_FAILURE`` — repeated Newton
                              convergence failures, or h underflowed
                              while Newton was failing
``RHSFUNC_FAIL``          -8  ``CV_RHSFUNC_FAIL`` — unrecoverable
                              NaN/Inf: the corrector converged onto a
                              non-finite iterate (poisoned RHS)
=====================  =====  ============================================

A lane whose retcode goes nonzero is **quarantined**: it drops out of
the step loop's ``active`` mask, so it stops participating in Newton,
WRMS, and step-control reductions — healthy bundle-mates proceed
bitwise-identically to a run where the failed lane never existed in a
fault state (chaos suite: ``repro.testing.chaos``).

Escalation ceilings follow CVODE's ``cv_mem`` defaults: ``MXNCF`` (10)
consecutive Newton convergence failures or ``MXNEF`` consecutive
error-test failures on one step quarantine the lane.
"""
from __future__ import annotations

SUCCESS = 0
TOO_MUCH_WORK = -1
ERR_FAILURE = -3
CONV_FAILURE = -4
RHSFUNC_FAIL = -8

#: consecutive Newton convergence failures before quarantine (CVODE MXNCF)
MXNCF = 10
#: consecutive local-error-test failures before quarantine.  CVODE uses
#: 7, but it also estimates the initial step (CVHin) — this repro seeds
#: ``h0 ~ 1e-6 * (tf - t0)`` and legitimately burns ~5-7 consecutive
#: error-test failures calibrating h on a cold start, so the ceiling is
#: doubled; a genuine error-failure spiral shrinks h by ~10x per
#: failure and trips the hmin-underflow ERR_FAILURE path first anyway.
MXNEF = 15

#: retcode -> symbolic name (for logs, typed errors, metric labels)
RETCODE_NAMES = {
    SUCCESS: "SUCCESS",
    TOO_MUCH_WORK: "TOO_MUCH_WORK",
    ERR_FAILURE: "ERR_FAILURE",
    CONV_FAILURE: "CONV_FAILURE",
    RHSFUNC_FAIL: "RHSFUNC_FAIL",
}

#: retcode -> the SUNDIALS flag it mirrors (README failure-semantics table)
SUNDIALS_FLAGS = {
    SUCCESS: "CV_SUCCESS",
    TOO_MUCH_WORK: "CV_TOO_MUCH_WORK",
    ERR_FAILURE: "CV_ERR_FAILURE",
    CONV_FAILURE: "CV_CONV_FAILURE",
    RHSFUNC_FAIL: "CV_RHSFUNC_FAIL",
}


def retcode_name(code: int) -> str:
    """Symbolic name for ``code`` (``"UNKNOWN(<n>)"`` off the table)."""
    return RETCODE_NAMES.get(int(code), f"UNKNOWN({int(code)})")


def is_success(code: int) -> bool:
    return int(code) == SUCCESS
