"""CVODE analog: adaptive-step BDF (orders 1-5) for stiff ODEs.

Fixed-leading-coefficient BDF on a uniform history window:
* history Z holds y at t, t-h, ..., t-q*h (flattened);
* predictor = degree-q polynomial extrapolation;
* corrector solves  y - gamma f(t+h, y) = psi  by Newton (gamma = beta_q h);
* on step-size change the history is rebuilt by evaluating the degree-q
  interpolant on the new uniform grid (this is how VODE/CVODE's
  fixed-leading-coefficient strategy handles variable h);
* order ramps 1 -> q_target during startup (one order per accepted step).

Simplifications vs CVODE proper (documented in DESIGN.md): order is
ramped up but not adaptively lowered, and the LTE constant is the
uniform-grid value.  Functional (Adams/fixed-point) mode is provided for
nonstiff problems via :func:`adams_integrate`.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.flatten_util import ravel_pytree

import warnings

from . import controller as ctrl
from . import dispatch as dv
from . import status
from .nonlinsol import FixedPointSolver, NewtonSolver
from .policies import ExecPolicy
from .arkode import ODEOptions, IntegratorStats, _bind_lin_solver

QMAX = 5

# Uniform-grid BDF coefficients, normalized alpha_0 = 1:
#   sum_j alpha_j y_{n+1-j} = h * beta * f_{n+1}
_BDF_ALPHA = [
    [1.0, -1.0, 0.0, 0.0, 0.0, 0.0],
    [1.0, -4 / 3, 1 / 3, 0.0, 0.0, 0.0],
    [1.0, -18 / 11, 9 / 11, -2 / 11, 0.0, 0.0],
    [1.0, -48 / 25, 36 / 25, -16 / 25, 3 / 25, 0.0],
    [1.0, -300 / 137, 300 / 137, -200 / 137, 75 / 137, -12 / 137],
]
_BDF_BETA = [1.0, 2 / 3, 6 / 11, 12 / 25, 60 / 137]

# Extrapolation predictor coefficients on a uniform grid, by polynomial
# DEGREE p (row p uses Z[0..p]):  y_pred = sum_j (-1)^j C(p+1, j+1) y_{n-j}.
# Degree 0 = constant predictor (used on the very first step, when only
# y0 is in the history — CVODE instead seeds the Nordsieck array with
# h*f0; our constant guess only weakens the first-step error estimate).
_PREDP = [[1.0] + [0.0] * QMAX]
for p in range(1, QMAX + 1):
    row = [((-1.0) ** j) * math.comb(p + 1, j + 1) for j in range(p + 1)]
    _PREDP.append(row + [0.0] * (QMAX + 1 - len(row)))

_ALPHA_T = jnp.array(_BDF_ALPHA)
_BETA_T = jnp.array(_BDF_BETA)
_PREDP_T = jnp.array(_PREDP)


def _lagrange_matrix(eta, q_cur):
    """(QMAX+1, QMAX+1) matrix W with  Z_new[j] = sum_i W[j,i] Z_old[i].

    Old nodes sit at x_i = -i (units of h_old); new nodes at -j*eta.
    Rows/cols beyond q_cur are masked to identity so stale history slots
    stay untouched (they are ignored by the masked coefficient tables).
    """
    idx = jnp.arange(QMAX + 1, dtype=eta.dtype)
    pts = -idx * eta                                    # new node positions
    # Lagrange basis L_i(p) = prod_{k != i} (p + k) / (k - i)
    p = pts[:, None, None]                              # (j, 1, 1)
    k = idx[None, None, :]                              # (1, 1, k)
    i = idx[None, :, None]                              # (1, i, 1)
    num = jnp.where(k == i, 1.0, p + k)
    den = jnp.where(k == i, 1.0, k - i)
    # only product over k <= q_cur
    mask_k = (idx[None, None, :] <= q_cur)
    ratio = jnp.where(mask_k, num / den, 1.0)
    W = jnp.prod(ratio, axis=2)                         # (j, i)
    valid_i = (idx[None, :] <= q_cur)
    W = jnp.where(valid_i, W, 0.0)
    valid_j = (idx[:, None] <= q_cur)
    eye = jnp.eye(QMAX + 1, dtype=eta.dtype)
    return jnp.where(valid_j, W, eye)


def bdf_integrate(f: Callable, y0, t0, tf, *, order: int = 5,
                  opts: ODEOptions = ODEOptions(),
                  lin_solver: Optional[Callable] = None,
                  dense_jac: bool = False,
                  nonlin_solver: Optional[NewtonSolver] = None,
                  mem=None, telemetry: Optional[int] = None):
    """Integrate stiff y' = f(t, y) with BDF up to ``order``.

    ``lin_solver`` is a :class:`repro.core.linsol.LinearSolver` object
    or a legacy callable ``(t, z, gamma, rhs) -> dz`` solving
    (I - gamma J) dz = rhs; defaults to matrix-free SPGMR, or
    :class:`~repro.core.linsol.DenseGJ` if ``dense_jac=True``.
    ``nonlin_solver`` defaults to the ODEOptions Newton tolerances;
    ``mem`` registers the BDF history workspace when given.
    ``telemetry=K`` threads a K-slot step-telemetry ring through the
    loop carry (one scalar record per step attempt, every value an
    already-computed intermediate) and appends it to the return tuple;
    the default ``None`` leaves the traced loop byte-identical to a
    build without the feature (sunlint ``telemetry-purity``).
    """
    assert 1 <= order <= QMAX
    if lin_solver is None and dense_jac:
        from .linsol import DenseGJ
        lin_solver = DenseGJ()
    lin_solve = _bind_lin_solver(lin_solver, f, opts, mem)
    nls = nonlin_solver or NewtonSolver.from_options(opts)
    y0_flat, unravel = ravel_pytree(y0)
    n = y0_flat.shape[0]
    if mem is not None:
        mem.register("bdf.history", (QMAX + 1, n), y0_flat.dtype)
    t0 = jnp.asarray(t0, dtype=y0_flat.dtype)
    tf = jnp.asarray(tf, dtype=t0.dtype)

    def f_flat(t, yf):
        return ravel_pytree(f(t, unravel(yf)))[0]

    def lin_solve_flat(t, zf, gamma, rhsf):
        dz = lin_solve(t, unravel(zf), gamma, unravel(rhsf))
        return ravel_pytree(dz)[0]

    from .arkode import _initial_h
    h0 = jnp.where(opts.h0 > 0, opts.h0,
                   _initial_h(lambda t, y: unravel(f_flat(t, ravel_pytree(y)[0])),
                              t0, y0, tf, opts.rtol, opts.atol,
                              opts.policy))

    class Carry(NamedTuple):
        t: jnp.ndarray
        h: jnp.ndarray
        q: jnp.ndarray               # current order
        Z: jnp.ndarray               # (QMAX+1, n) history, Z[0] = y(t)
        cst: ctrl.ControllerState
        stats: IntegratorStats
        retcode: jnp.ndarray         # scalar int32 CV_*-style status
        ncf_cur: jnp.ndarray         # consecutive Newton conv failures
        nef_cur: jnp.ndarray         # consecutive error-test failures

    def cond(c):
        return ((c.t < tf * (1 - 1e-12) - 1e-300) &
                (c.stats.attempts < opts.max_steps) & (c.retcode == 0))

    def step(c):
        h = jnp.minimum(c.h, tf - c.t)
        # number of valid history entries is steps+1 -> max usable degree
        nvalid_m1 = jnp.minimum(c.stats.steps, QMAX)
        # if we clipped h to hit tf, rescale history accordingly
        eta_clip = h / c.h
        Z = jnp.einsum("ji,ik->jk", _lagrange_matrix(eta_clip, nvalid_m1),
                       c.Z)
        qi = c.q - 1
        alphas = _ALPHA_T[qi]                       # (QMAX+1,)
        beta = _BETA_T[qi]
        p_pred = jnp.minimum(nvalid_m1, c.q)        # predictor degree
        pred_c = _PREDP_T[p_pred]
        y_pred = pred_c @ Z                          # (n,)
        psi = -(alphas[1:] @ Z[:-1])                 # uses y_n .. y_{n-q+1}
        # NOTE: alphas[j] multiplies y_{n+1-j}; history Z[i] = y_{n-i}
        # so sum_{j>=1} alpha_j y_{n+1-j} = sum_{i>=0} alpha_{i+1} Z[i].
        gamma = beta * h
        t_new = c.t + h
        w_flat = 1.0 / (opts.rtol * jnp.abs(Z[0]) + opts.atol)

        def wnorm(v):
            return dv.wrms_norm(v, w_flat, opts.policy)

        def gfun(z):
            return z - gamma * f_flat(t_new, z) - psi

        def nsolve(z, rhs):
            return lin_solve_flat(t_new, z, gamma, rhs)

        z, nst = nls.solve(gfun, y_pred, nsolve, wnorm=wnorm,
                           policy=opts.policy)
        nl_ok = nst.converged
        # LTE estimate ~ C_q (y - y_pred); C_q = 1/(q+1) (uniform grid)
        err_raw = wnorm(z - y_pred) / (c.q.astype(h.dtype) + 1.0)
        bad = ~jnp.isfinite(err_raw) | ~nl_ok
        err = jnp.where(bad, 2.0, err_raw)
        accept = (err <= 1.0) & ~bad
        eta, cst = ctrl.eta_from_error(
            opts.controller, c.cst, err, c.q + 1, after_failure=(~accept) & nl_ok)
        eta = jnp.where(nl_ok, eta, opts.eta_cf)
        cst = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), cst, c.cst)
        # accepted: shift history and insert z at slot 0
        Z_acc = jnp.roll(Z, 1, axis=0).at[0].set(z)
        Z_next = jnp.where(accept, Z_acc, Z)
        q_next = jnp.where(accept, jnp.minimum(c.q + 1, order), c.q)
        # rescale history onto the new uniform grid (only over the rows
        # that hold computed values: steps+accept of them + the new one)
        eta = jnp.clip(eta, 0.1, 10.0)
        nval_after = jnp.minimum(c.stats.steps + accept.astype(jnp.int32),
                                 QMAX)
        Z_next = jnp.einsum("ji,ik->jk",
                            _lagrange_matrix(eta, nval_after), Z_next)
        t_n = jnp.where(accept, t_new, c.t)
        h_n = jnp.clip(h * eta, opts.hmin, opts.hmax)
        # CV_*-style escalation, scalar form of the ensemble contract:
        # consecutive-failure ceilings, h underflow, non-finite iterate
        ncf_cur = jnp.where(accept, 0,
                            c.ncf_cur + (~nl_ok).astype(jnp.int32))
        nef_cur = jnp.where(
            accept, 0,
            c.nef_cur + ((~accept) & nl_ok &
                         jnp.isfinite(err_raw)).astype(jnp.int32))
        # relative underflow (t + h == t): stiff problems legitimately
        # visit tiny absolute h near transients and recover
        hfail = c.t + h * eta == c.t
        rc = c.retcode
        rc = jnp.where((nef_cur >= status.MXNEF) | (hfail & nl_ok),
                       status.ERR_FAILURE, rc)
        rc = jnp.where((ncf_cur >= status.MXNCF) | (hfail & ~nl_ok),
                       status.CONV_FAILURE, rc)
        rc = jnp.where(nl_ok & ~jnp.isfinite(err_raw),
                       status.RHSFUNC_FAIL, rc)
        st = c.stats
        st = st._replace(
            steps=st.steps + accept.astype(jnp.int32),
            attempts=st.attempts + 1,
            nfi=st.nfi + 1 + nst.iters, nni=st.nni + nst.iters,
            netf=st.netf + ((~accept) & nl_ok).astype(jnp.int32),
            ncfn=st.ncfn + (~nl_ok).astype(jnp.int32),
            last_h=h, t=t_n)
        carry = Carry(t_n, h_n, q_next, Z_next, cst, st, rc, ncf_cur,
                      nef_cur)
        # telemetry record: already-computed intermediates only
        rec = (t_new, h, c.q, nst.iters, err, nl_ok, accept)
        return carry, rec

    def body(c):
        return step(c)[0]

    Z0 = jnp.zeros((QMAX + 1, n), dtype=y0_flat.dtype).at[0].set(y0_flat)
    zero = jnp.zeros((), jnp.int32)
    stats0 = IntegratorStats(zero, zero, zero, zero, zero, zero, zero,
                             h0, t0, jnp.zeros((), bool))
    c = Carry(t0, h0, jnp.ones((), jnp.int32), Z0,
              ctrl.init_state(t0.dtype), stats0, zero, zero, zero)
    ring = None
    if telemetry is None:
        c = lax.while_loop(cond, body, c)
    else:
        from ..observability.telemetry import ring_init, ring_record

        def tel_body(cr):
            new_c, (t_new, h, q, iters, err, nl_ok, accept) = step(cr[0])
            # scalar integrator: there is no lsetup trigger (matrix-free
            # or per-iteration solve) and no masked-lane concept — the
            # constants are built here, outside the disabled trace
            rec = (t_new, h, q, iters, err, jnp.zeros((), bool), nl_ok,
                   accept, jnp.ones((), bool))
            return new_c, ring_record(cr[1], rec)

        c, ring = lax.while_loop(
            lambda cr: cond(cr[0]), tel_body,
            (c, ring_init(telemetry, (), y0_flat.dtype)))
    success = c.t >= tf * (1 - 1e-10)
    # loop exit with a healthy retcode but tf unreached == the attempts
    # ceiling fired: reconcile to TOO_MUCH_WORK (CV_TOO_MUCH_WORK)
    retcode = jnp.where((c.retcode == 0) & ~success,
                        status.TOO_MUCH_WORK, c.retcode)
    stats = c.stats._replace(success=success, retcode=retcode)
    if ring is not None:
        return unravel(c.Z[0]), stats, ring
    return unravel(c.Z[0]), stats


def bdf_fixed(f: Callable, y0, t0, tf, n_steps: int, *, order: int = 2,
              lin_solver: Optional[Callable] = None, dense_jac: bool = True,
              newton_iters: Optional[int] = None,
              policy: Optional[ExecPolicy] = None,
              opts: Optional[ODEOptions] = None):
    """Fixed-step BDF(order) with exact startup via high-order ERK.

    For convergence-order tests: global error should scale as h^order.
    Newton depth and the vector-op policy route through ``opts``
    (``newton_max``, floored at 8 — fixed-step Newton has no retry
    path — and ``policy``); the bare ``newton_iters`` / ``policy``
    kwargs are deprecated compat shims.
    """
    from .arkode import erk_fixed
    from .butcher import DORMAND_PRINCE

    if opts is None:
        opts = ODEOptions()
    # Fixed-step Newton has no failure/retry path, so its depth is
    # floored at 8 regardless of the adaptive default (newton_max=4):
    # a generic opts=ctx.options() must not silently halve the legacy
    # depth and let nonlinear error pollute the measured orders.  Raise
    # it with opts=ODEOptions(newton_max=12).
    newton_depth = max(opts.newton_max, 8)
    if newton_iters is not None:
        warnings.warn("repro-compat: bdf_fixed(newton_iters=...) is "
                      "deprecated; pass opts=ODEOptions(newton_max=...)",
                      DeprecationWarning, stacklevel=2)
        newton_depth = newton_iters    # exact, for backward compat
    if policy is not None:
        warnings.warn("repro-compat: bdf_fixed(policy=...) is deprecated; "
                      "pass opts=ODEOptions(policy=...)",
                      DeprecationWarning, stacklevel=2)
        opts = opts._replace(policy=policy)
    if lin_solver is None and dense_jac:
        from .linsol import DenseGJ
        lin_solver = DenseGJ()
    lin_solve = _bind_lin_solver(lin_solver, f, opts)
    y0_flat, unravel = ravel_pytree(y0)
    n = y0_flat.shape[0]
    h = (tf - t0) / n_steps
    qi = order - 1
    alphas = _ALPHA_T[qi]
    beta = _BETA_T[qi]

    def f_flat(t, yf):
        return ravel_pytree(f(t, unravel(yf)))[0]

    def lin_solve_flat(t, zf, gamma, rhsf):
        return ravel_pytree(lin_solve(t, unravel(zf), gamma,
                                      unravel(rhsf)))[0]

    # startup: seed history with DP5 fixed steps (accurate enough)
    hist = [y0_flat]
    y_cur = y0
    for k in range(order - 1):
        y_cur = erk_fixed(f, y_cur, t0 + k * h, t0 + (k + 1) * h, 4,
                          DORMAND_PRINCE)
        hist.insert(0, ravel_pytree(y_cur)[0])
    Z = jnp.stack(hist + [jnp.zeros_like(y0_flat)] *
                  (QMAX + 1 - len(hist)))   # Z[0] most recent

    def step(carry, k):
        Z, = carry
        t_new = t0 + (k + order) * h     # t of the new point
        psi = -(alphas[1:] @ Z[:-1])
        gamma = beta * h

        def wnorm(v):
            return jnp.sqrt(dv.dot(v, v, opts.policy) / n)

        def gfun(z):
            return z - gamma * f_flat(t_new, z) - psi

        def nsolve(z, rhs):
            return lin_solve_flat(t_new, z, gamma, rhs)

        # fixed tol=1e-10: the nonlinear error must stay far below the
        # discretization error being measured by the order tests
        nls = NewtonSolver(tol=1e-10, max_iters=newton_depth)
        z, _ = nls.solve(gfun, Z[0], nsolve, wnorm=wnorm,
                         policy=opts.policy)
        Z = jnp.roll(Z, 1, axis=0).at[0].set(z)
        return (Z,), None

    (Z,), _ = lax.scan(step, (Z,), jnp.arange(n_steps - (order - 1)))
    return unravel(Z[0])


def adams_integrate(f: Callable, y0, t0, tf,
                    opts: ODEOptions = ODEOptions(), m_aa: int = 2,
                    nonlin_solver: Optional[FixedPointSolver] = None,
                    mem=None):
    """CVODE functional-iteration mode for nonstiff problems:
    Adams-Moulton(2) (trapezoid) corrector solved by Anderson-accelerated
    fixed-point, AB2 predictor, adaptive h via predictor-corrector diff.
    ``nonlin_solver`` (:class:`~repro.core.nonlinsol.FixedPointSolver`)
    defaults to the ODEOptions-derived tolerance."""
    fps = nonlin_solver or FixedPointSolver.from_options(opts, m=m_aa)
    y0_flat, unravel = ravel_pytree(y0)
    n = y0_flat.shape[0]
    if mem is not None:
        mem.register("adams.anderson", (2 * fps.m, n), y0_flat.dtype)
    t0 = jnp.asarray(t0, dtype=y0_flat.dtype)
    tf = jnp.asarray(tf, dtype=t0.dtype)

    def f_flat(t, yf):
        return ravel_pytree(f(t, unravel(yf)))[0]

    from .arkode import _initial_h
    h0 = jnp.where(opts.h0 > 0, opts.h0,
                   _initial_h(lambda t, y: unravel(f_flat(t, ravel_pytree(y)[0])),
                              t0, y0, tf, opts.rtol, opts.atol,
                              opts.policy))

    class Carry(NamedTuple):
        t: jnp.ndarray
        y: jnp.ndarray
        fprev: jnp.ndarray
        h: jnp.ndarray
        cst: ctrl.ControllerState
        stats: IntegratorStats
        give_up: jnp.ndarray

    def cond(c):
        return ((c.t < tf * (1 - 1e-12) - 1e-300) &
                (c.stats.attempts < opts.max_steps) & (~c.give_up))

    def body(c):
        h = jnp.minimum(c.h, tf - c.t)
        fn = f_flat(c.t, c.y)
        # AB2 predictor (falls back to Euler when fprev invalid = first step)
        first = c.stats.steps == 0
        y_pred = jnp.where(first, c.y + h * fn,
                           c.y + h * (1.5 * fn - 0.5 * c.fprev))
        t_new = c.t + h

        def gfun(z):
            return c.y + 0.5 * h * (fn + f_flat(t_new, z))

        z, fst = fps.solve(gfun, y_pred)
        w = 1.0 / (opts.rtol * jnp.abs(c.y) + opts.atol)
        err = dv.wrms_norm(z - y_pred, w, opts.policy) / 6.0
        bad = ~jnp.isfinite(err) | ~fst.converged
        err = jnp.where(bad, 2.0, err)
        accept = (err <= 1.0) & ~bad
        eta, cst = ctrl.eta_from_error(opts.controller, c.cst, err, 3,
                                       after_failure=~accept)
        eta = jnp.where(fst.converged, eta, opts.eta_cf)
        cst = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), cst, c.cst)
        t_n = jnp.where(accept, t_new, c.t)
        y_n = jnp.where(accept, z, c.y)
        f_n = jnp.where(accept, fn, c.fprev)
        h_n = jnp.clip(h * eta, opts.hmin, opts.hmax)
        st = c.stats
        st = st._replace(steps=st.steps + accept.astype(jnp.int32),
                         attempts=st.attempts + 1,
                         nfe=st.nfe + 2 + fst.iters,
                         netf=st.netf + (~accept).astype(jnp.int32),
                         last_h=h, t=t_n)
        return Carry(t_n, y_n, f_n, h_n, cst, st, h * eta < 1e-14)

    zero = jnp.zeros((), jnp.int32)
    stats0 = IntegratorStats(zero, zero, zero, zero, zero, zero, zero,
                             h0, t0, jnp.zeros((), bool))
    c = Carry(t0, y0_flat, jnp.zeros_like(y0_flat), h0,
              ctrl.init_state(t0.dtype), stats0, jnp.zeros((), bool))
    c = lax.while_loop(cond, body, c)
    stats = c.stats._replace(success=c.t >= tf * (1 - 1e-10))
    return unravel(c.y), stats
