"""Static-pattern sparse LU: the SUNLINSOL_CUSOLVERSP_BATCHQR analog's
symbolic/numeric split, TPU-native.

cuSolverSp's batched QR does its *symbolic analysis once* for the whole
batch (every system shares the sparsity pattern) and then refactors
numerically per solve.  The TPU expression of that split (same idea as
the offline-generated Gauss-Jordan the paper cites for 3x3 chemistry
blocks) is even stronger: because the pattern is static at trace time,
the symbolic phase runs on the HOST (numpy, cached per pattern) and
emits an *elimination schedule* that the numeric phase unrolls into
straight-line lane-wide vector ops — the factorization of ``nsys``
systems is one fused elementwise program with zero index arrays in
device memory.

Three host-side products per pattern (``lru_cache`` on the hashable
pattern tuples):

* **fill ordering** — reverse Cuthill-McKee on the symmetrized pattern
  (bandwidth reduction == fill reduction for the banded Jacobians the
  ensemble problems produce); identity order for ILU-style use.
* **symbolic factorization** — simulate no-pivot elimination on the
  pattern; ``fill=True`` grows the pattern to L+U (exact LU),
  ``fill=False`` keeps it fixed (ILU(0): updates outside the pattern
  are dropped).
* **schedules** — flat (k, i, j) index triples for the Doolittle
  updates and the two triangular sweeps.

The numeric phase operates on a values array ``(nnzf, *batch)`` whose
trailing axes are the ensemble lanes; every op is elementwise across
them.  No pivoting — Newton matrices ``I - gamma*J`` are strongly
diagonally dominant for acceptable gamma (same assumption as the GJ
block kernels; ``scale_rows`` equilibration is available there).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np


def encode_pattern(pattern) -> Tuple[tuple, tuple]:
    """(n, n) boolean/0-1 array -> hashable CSR (indptr, indices) with
    the diagonal forced in (Newton matrices need it)."""
    P = np.asarray(pattern).astype(bool).copy()
    n = P.shape[0]
    assert P.shape == (n, n), P.shape
    np.fill_diagonal(P, True)
    indptr, indices = [0], []
    for i in range(n):
        cols = np.nonzero(P[i])[0]
        indices.extend(int(c) for c in cols)
        indptr.append(len(indices))
    return tuple(indptr), tuple(indices)


def _rcm_order(P: np.ndarray) -> np.ndarray:
    """Reverse Cuthill-McKee on the symmetrized pattern — the 'fill
    ordering' of the symbolic setup (BFS from a min-degree peripheral
    vertex, neighbors by ascending degree, order reversed)."""
    S = P | P.T
    n = S.shape[0]
    deg = S.sum(axis=1)
    visited = np.zeros(n, bool)
    order = []
    while len(order) < n:
        rest = np.nonzero(~visited)[0]
        start = rest[np.argmin(deg[rest])]
        queue = [int(start)]
        visited[start] = True
        while queue:
            v = queue.pop(0)
            order.append(v)
            nbrs = [int(u) for u in np.nonzero(S[v])[0] if not visited[u]]
            for u in sorted(nbrs, key=lambda u: deg[u]):
                visited[u] = True
                queue.append(u)
    return np.asarray(order[::-1], np.int64)


class LUPlan(NamedTuple):
    """Host-side symbolic product: everything the numeric phase unrolls
    over.  All fields are static (numpy / tuples)."""

    n: int
    perm: np.ndarray          # row/col permutation (fill ordering)
    rows: np.ndarray          # (nnzf,) filled-pattern row of each slot
    cols: np.ndarray          # (nnzf,) filled-pattern col of each slot
    diag: np.ndarray          # (n,) slot index of (k, k)
    schedule: tuple           # ((f_slot, piv_k, ((tgt, src), ...)), ...)
    lower: tuple              # per row i: ((slot_ij, j), ...) for j < i
    upper: tuple              # per row i (reversed): ((slot_ij, j), ...) j > i

    @property
    def nnz_factored(self) -> int:
        return len(self.rows)


@functools.lru_cache(maxsize=64)
def symbolic_lu(indptr: tuple, indices: tuple, *, order: bool = True,
                fill: bool = True) -> LUPlan:
    """Symbolic factorization of the static CSR pattern (cached)."""
    n = len(indptr) - 1
    P = np.zeros((n, n), bool)
    for i in range(n):
        P[i, list(indices[indptr[i]:indptr[i + 1]])] = True
    np.fill_diagonal(P, True)
    perm = _rcm_order(P) if order else np.arange(n)
    F = P[perm][:, perm].copy()
    if fill:                       # simulate elimination, record fill-in
        for k in range(n):
            below = np.nonzero(F[k + 1:, k])[0] + k + 1
            right = np.nonzero(F[k, k + 1:])[0] + k + 1
            for i in below:
                F[i, right] = True
    rows, cols = np.nonzero(F)
    slot = {(int(i), int(j)): s for s, (i, j) in enumerate(zip(rows, cols))}
    diag = np.asarray([slot[(k, k)] for k in range(n)], np.int64)
    # Doolittle schedule: for k, for i > k with (i,k) present:
    #   f = A[i,k] / A[k,k];  A[i,k] = f;  A[i,j] -= f * A[k,j]  (j > k)
    schedule = []
    for k in range(n):
        right = [j for j in range(k + 1, n) if F[k, j]]
        for i in range(k + 1, n):
            if not F[i, k]:
                continue
            ups = tuple((slot[(i, j)], slot[(k, j)]) for j in right
                        if F[i, j])   # always true when fill=True
            schedule.append((slot[(i, k)], int(diag[k]), ups))
    lower = tuple(tuple((slot[(i, j)], j) for j in range(i) if F[i, j])
                  for i in range(n))
    upper = tuple(tuple((slot[(i, j)], j) for j in range(i + 1, n)
                        if F[i, j])
                  for i in range(n))
    return LUPlan(n=n, perm=perm, rows=rows, cols=cols, diag=diag,
                  schedule=tuple(schedule), lower=lower, upper=upper)


def gather_filled(plan: LUPlan, M: jnp.ndarray) -> jnp.ndarray:
    """Extract the (permuted) filled-pattern values from a dense SoA
    Newton matrix ``M: (n, n, *batch)`` -> ``(nnzf, *batch)``."""
    pr = plan.perm[plan.rows]
    pc = plan.perm[plan.cols]
    return M[jnp.asarray(pr), jnp.asarray(pc)]


def scatter_from_csr(plan: LUPlan, indptr: tuple, indices: tuple,
                     vals: jnp.ndarray) -> jnp.ndarray:
    """Place original-pattern CSR values ``(nnz, *batch)`` into the
    factored layout ``(nnzf, *batch)`` (fill slots start at zero)."""
    n = plan.n
    ip = np.asarray(indptr)
    orig = {}
    for i in range(n):
        for s in range(ip[i], ip[i + 1]):
            orig[(i, int(indices[s]))] = s
    src, mask = [], []
    for i, j in zip(plan.rows, plan.cols):
        key = (int(plan.perm[i]), int(plan.perm[j]))
        src.append(orig.get(key, 0))
        mask.append(key in orig)
    out = vals[jnp.asarray(src, np.int64)]
    m = jnp.asarray(mask).reshape((-1,) + (1,) * (vals.ndim - 1))
    return jnp.where(m, out, jnp.zeros_like(out))


def numeric_lu(plan: LUPlan, vals: jnp.ndarray) -> jnp.ndarray:
    """Factor in place on the filled values ``(nnzf, *batch)``; every
    update is elementwise across the trailing batch (lane) axes.  The
    schedule is unrolled — straight-line code, no pivoting."""
    v = [vals[s] for s in range(plan.nnz_factored)]   # unstack: no .at[]
    for f_slot, piv, ups in plan.schedule:
        f = v[f_slot] / v[piv]
        v[f_slot] = f
        for tgt, src in ups:
            v[tgt] = v[tgt] - f * v[src]
    return jnp.stack(v)


def lu_solve(plan: LUPlan, fvals: jnp.ndarray,
             rhs: jnp.ndarray) -> jnp.ndarray:
    """Solve ``A x = rhs`` from the factored values: two unrolled
    triangular sweeps.  ``rhs: (n, *batch)`` -> ``x: (n, *batch)``."""
    v = [fvals[s] for s in range(plan.nnz_factored)]
    b = [rhs[int(plan.perm[i])] for i in range(plan.n)]
    y = [None] * plan.n
    for i in range(plan.n):                 # L y = b (unit lower)
        acc = b[i]
        for s, j in plan.lower[i]:
            acc = acc - v[s] * y[j]
        y[i] = acc
    x = [None] * plan.n
    for i in range(plan.n - 1, -1, -1):     # U x = y
        acc = y[i]
        for s, j in plan.upper[i]:
            acc = acc - v[s] * x[j]
        x[i] = acc / v[int(plan.diag[i])]
    out = [None] * plan.n
    for i in range(plan.n):                 # undo the fill ordering
        out[int(plan.perm[i])] = x[i]
    return jnp.stack(out)
