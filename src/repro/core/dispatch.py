"""Policy-driven N_Vector op dispatch — the ExecPolicy wiring (paper §4.1).

SUNDIALS lets applications swap kernel-launch policies per vector
without touching integrator source.  This module is the analog: a
single **op table** maps each hot vector operation to its two
implementations —

* ``'jnp'``    — the pure-jnp oracles in :mod:`repro.core.vector`
                 (XLA fuses; the default, and the only backend XLA:CPU
                 can lower without ``interpret``), and
* ``'pallas'`` — the fused Pallas kernels in :mod:`repro.kernels`
                 (one HBM pass per fused op; tile sizes come from the
                 :class:`~repro.core.policies.ExecPolicy`).

Integrators call the module-level wrappers (``linear_combination``,
``wrms_norm``, ...) with an optional ``policy``; ``None`` or a
``backend='jnp'`` policy falls through to :mod:`repro.core.vector`
unchanged, so existing callers keep bit-identical behavior.

The pallas boundary handles, per pytree leaf:

* **flattening** — each leaf is raveled to 1-D; fused multi-operand ops
  stack corresponding leaves into a ``(K, n)`` operand;
* **lane padding** — tiles are lane-aligned (multiples of 128) and
  clamped to the leaf size so a 6-element vector pads to 128, not to the
  policy's full streaming tile; the kernels' wrappers zero-pad ragged
  tails (zero weights/coeffs contribute nothing to reductions);
* **dtype preservation** — outputs keep ``jnp.result_type`` of the data
  operands (SUNDIALS realtype semantics: a float64 step-size coefficient
  must not upcast a float32 state), matching ``vector._keep_dtype``.

Reductions return the *node-local* value; :class:`MeshVector` finishes
them with its single collective exactly as before.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Optional, Sequence

import jax.numpy as jnp
from jax import tree_util

from . import vector as nv
from .policies import ExecPolicy, XLA_FUSED

Pytree = Any

LANE = 128


# ---------------------------------------------------------------------------
# Boundary helpers (pytree <-> flat lane-padded kernel operands)
# ---------------------------------------------------------------------------


def _ceil_lane(n: int) -> int:
    return max(LANE, -(-n // LANE) * LANE)


def _stream_tile(n: int, policy: ExecPolicy) -> int:
    """Streaming tile: the policy's block, clamped to the (lane-padded)
    leaf so small vectors don't pad to a full GridStride tile."""
    return max(LANE, min(policy.block_elems, _ceil_lane(n)))


def _reduce_tile(n: int, policy: ExecPolicy) -> int:
    return max(LANE, min(policy.reduce_tile, _ceil_lane(n)))


def _leaves(tree: Pytree):
    return tree_util.tree_leaves(tree)


def _rebuild(tree: Pytree, flat_leaves):
    treedef = tree_util.tree_structure(tree)
    shapes = [l.shape for l in _leaves(tree)]
    return tree_util.tree_unflatten(
        treedef, [f.reshape(s) for f, s in zip(flat_leaves, shapes)])


def _coeff_array(coeffs: Sequence, dtype) -> jnp.ndarray:
    return jnp.stack([jnp.asarray(c) for c in coeffs]).astype(dtype)


# ---------------------------------------------------------------------------
# Pallas-backed implementations (leaf-wise over pytrees)
# ---------------------------------------------------------------------------


def _pl_linear_combination(coeffs, vecs, *, policy: ExecPolicy) -> Pytree:
    from repro.kernels import ops as kops
    assert len(coeffs) == len(vecs) and len(vecs) >= 1
    leaf_rows = [_leaves(v) for v in vecs]          # [K][L] leaves
    out = []
    for leaves in zip(*leaf_rows):                  # iterate leaf positions
        want = jnp.result_type(*leaves)
        X = jnp.stack([l.ravel().astype(want) for l in leaves])
        n = X.shape[1]
        z = kops.linear_combination(
            _coeff_array(coeffs, want), X,
            block_elems=_stream_tile(n, policy), interpret=policy.interpret)
        out.append(z)
    return _rebuild(vecs[0], out)


def _pl_linear_sum(a, x, b, y, *, policy: ExecPolicy) -> Pytree:
    return _pl_linear_combination([a, b], [x, y], policy=policy)


def _pl_axpy(a, x, y, *, policy: ExecPolicy) -> Pytree:
    return _pl_linear_combination([a, 1.0], [x, y], policy=policy)


def _pl_scale_add_multi(coeffs, x, ys, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    K = len(coeffs)
    assert len(ys) == K
    x_leaves = _leaves(x)
    y_rows = [_leaves(y) for y in ys]
    per_leaf = []                                   # [L] arrays of (K, n)
    for pos, xl in enumerate(x_leaves):
        want = jnp.result_type(xl, *(row[pos] for row in y_rows))
        Y = jnp.stack([row[pos].ravel().astype(want) for row in y_rows])
        n = Y.shape[1]
        Z = kops.scale_add_multi(
            _coeff_array(coeffs, want), xl.ravel().astype(want), Y,
            block_elems=_stream_tile(n, policy), interpret=policy.interpret)
        per_leaf.append(Z)
    return [_rebuild(x, [Z[k] for Z in per_leaf]) for k in range(K)]


def _pl_dot(x, y, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    lx, ly = _leaves(x), _leaves(y)
    acc_t = jnp.result_type(*(l.dtype for l in lx + ly))
    acc = jnp.zeros((), dtype=acc_t)
    for xl, yl in zip(lx, ly):
        n = xl.size
        acc = acc + kops.dot(
            xl.ravel().astype(acc_t), yl.ravel().astype(acc_t),
            reduce_tile=_reduce_tile(n, policy), interpret=policy.interpret)
    return acc


def _pl_wrms_norm(x, w, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    n_total = nv.tree_size(x)
    lx, lw = _leaves(x), _leaves(w)
    acc_t = jnp.result_type(*(l.dtype for l in lx + lw))
    ss = jnp.zeros((), dtype=acc_t)
    for xl, wl in zip(lx, lw):
        ss = ss + kops.wrms_ss(
            xl.ravel().astype(acc_t), wl.ravel().astype(acc_t),
            reduce_tile=_reduce_tile(xl.size, policy),
            interpret=policy.interpret)
    return jnp.sqrt(ss / n_total)


def _pl_wrms_norm_mask(x, w, mask, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    n_total = nv.tree_size(x)
    lx, lw, lm = _leaves(x), _leaves(w), _leaves(mask)
    acc_t = jnp.result_type(*(l.dtype for l in lx + lw + lm))
    ss = jnp.zeros((), dtype=acc_t)
    for xl, wl, ml in zip(lx, lw, lm):
        ss = ss + kops.wrms_mask_ss(
            xl.ravel().astype(acc_t), wl.ravel().astype(acc_t),
            ml.ravel().astype(acc_t),
            reduce_tile=_reduce_tile(xl.size, policy),
            interpret=policy.interpret)
    return jnp.sqrt(ss / n_total)


def _pl_dot_prod_multi(x, ys, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    K = len(ys)
    x_leaves = _leaves(x)
    y_rows = [_leaves(y) for y in ys]
    acc_t = jnp.result_type(*(l.dtype for l in x_leaves),
                            *(l.dtype for row in y_rows for l in row))
    acc = jnp.zeros((K,), dtype=acc_t)
    for pos, xl in enumerate(x_leaves):
        Y = jnp.stack([row[pos].ravel().astype(acc_t) for row in y_rows])
        acc = acc + kops.dot_prod_multi(
            xl.ravel().astype(acc_t), Y,
            reduce_tile=_reduce_tile(xl.size, policy),
            interpret=policy.interpret)
    return acc


def _pl_wrms_ss(x, w, *, policy: ExecPolicy):
    """Node-local raw sum((x*w)^2) — MeshVector's partial before psum."""
    from repro.kernels import ops as kops
    lx, lw = _leaves(x), _leaves(w)
    acc_t = jnp.result_type(*(l.dtype for l in lx + lw))
    ss = jnp.zeros((), dtype=acc_t)
    for xl, wl in zip(lx, lw):
        ss = ss + kops.wrms_ss(
            xl.ravel().astype(acc_t), wl.ravel().astype(acc_t),
            reduce_tile=_reduce_tile(xl.size, policy),
            interpret=policy.interpret)
    return ss


def _jnp_wrms_ss(x, w, *, policy=None):
    xw = nv.prod(x, w)
    return nv.dot(xw, xw)


# ---------------------------------------------------------------------------
# Batched block-diagonal linear algebra (the ensemble subsystem's SoA ops:
# A is (b, b, NB) with the system batch on the lane axis).  The jnp
# oracles are the semantic ground truth the Pallas kernels are parity-
# tested against; the pallas implementations pad NB to the policy's
# batch_tile (the bundle-size knob) inside repro.kernels.ops.
# ---------------------------------------------------------------------------


def _gj_vmem(policy: ExecPolicy):
    """VMEM budget for the row-tiled GJ accumulator, from the policy's
    roofline device entry (None -> the kernels' GJ_VMEM_BYTES default;
    only consulted in compiled mode)."""
    from repro.analysis.roofline import get_device
    try:
        return get_device(policy.device_name()).vmem_bytes
    except ValueError:
        return None


def _jnp_block_solve_soa(A, r, *, policy=None):
    from .direct import gauss_jordan_batched
    x = gauss_jordan_batched(jnp.transpose(A, (2, 0, 1)),
                             jnp.transpose(r, (1, 0)))
    return jnp.transpose(x, (1, 0))


def _pl_block_solve_soa(A, r, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    return kops.block_solve_soa(A, r, batch_tile=policy.batch_tile,
                                interpret=policy.interpret,
                                vmem_bytes=_gj_vmem(policy))


def _jnp_block_inverse_soa(A, *, policy=None):
    from repro.kernels import ref as kref
    return kref.block_inverse_soa_ref(A)


def _pl_block_inverse_soa(A, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    return kops.block_inverse_soa(A, batch_tile=policy.batch_tile,
                                  interpret=policy.interpret,
                                  vmem_bytes=_gj_vmem(policy))


def _jnp_blockdiag_spmv_soa(A, x, *, policy=None):
    from repro.kernels import ref as kref
    return kref.blockdiag_spmv_soa_ref(A, x)


def _pl_blockdiag_spmv_soa(A, x, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    return kops.blockdiag_spmv_soa(A, x, batch_tile=policy.batch_tile,
                                   interpret=policy.interpret)


# ---------------------------------------------------------------------------
# Fused ensemble-Newton ops (SoA (n, nsys) layout, nsys on the lanes).
# The jnp oracles are the bitwise ground truth of the pre-SoA integrator
# (the history-rescale oracle deliberately evaluates the AoS einsum on
# transposed views so the jnp backend keeps its accumulation order; see
# kernels/ref.py); the pallas kernels are the one-HBM-pass fusions.
# ---------------------------------------------------------------------------


def _jnp_newton_residual_soa(z, fval, psi, gamma, negate, *, policy=None):
    from repro.kernels import ref as kref
    return kref.newton_residual_soa_ref(z, fval, psi, gamma, negate)


def _pl_newton_residual_soa(z, fval, psi, gamma, negate, *,
                            policy: ExecPolicy):
    from repro.kernels import ops as kops
    return kops.newton_residual_soa(z, fval, psi, gamma,
                                    batch_tile=policy.batch_tile,
                                    interpret=policy.interpret,
                                    negate=negate)


def _jnp_masked_update_wrms_soa(z, dz, w, mask, *, policy=None):
    from repro.kernels import ref as kref
    return kref.masked_update_wrms_soa_ref(z, dz, w, mask)


def _pl_masked_update_wrms_soa(z, dz, w, mask, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    return kops.masked_update_wrms_soa(z, dz, w, mask,
                                       batch_tile=policy.batch_tile,
                                       interpret=policy.interpret)


def _jnp_history_rescale_soa(W, Z, active, *, policy=None):
    from repro.kernels import ref as kref
    return kref.history_rescale_soa_ref(W, Z, active)


def _pl_history_rescale_soa(W, Z, active, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    return kops.history_rescale_soa(W, Z, active,
                                    batch_tile=policy.batch_tile,
                                    interpret=policy.interpret)


def _jnp_wrms_soa(v, w, *, policy=None):
    from repro.kernels import ref as kref
    return kref.wrms_soa_ref(v, w)


def _pl_wrms_soa(v, w, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    return kops.wrms_soa(v, w, batch_tile=policy.batch_tile,
                         interpret=policy.interpret)


# ---------------------------------------------------------------------------
# Sparse ops (static shared patterns).  Patterns ride along as hashable
# tuples — ``csr_spmv`` takes ``(indptr, indices)``, the BSR ops take
# ``(brows, bcols, nblk)`` — so they key the kernel jit caches and the
# structure is compiled into the program (SUNMATRIX_CUSPARSE's
# store-the-pattern-once, with zero index arrays in device memory).
# ---------------------------------------------------------------------------


def _jnp_csr_spmv(data, x, pattern, *, policy=None):
    from repro.kernels import ref as kref
    indptr, indices = pattern
    return kref.csr_spmv_ref(data, x, indptr, indices)


def _pl_csr_spmv(data, x, pattern, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    indptr, indices = pattern
    return kops.csr_spmv(data, x, indptr=tuple(indptr),
                         indices=tuple(indices),
                         block_elems=policy.block_elems,
                         interpret=policy.interpret)


def _jnp_bsr_spmv_soa(values, x, pattern, *, policy=None):
    from repro.kernels import ref as kref
    brows, bcols, nblk = pattern
    return kref.bsr_spmv_soa_ref(values, x, brows, bcols, nblk)


def _pl_bsr_spmv_soa(values, x, pattern, *, policy: ExecPolicy):
    from repro.kernels import ops as kops
    brows, bcols, nblk = pattern
    return kops.bsr_spmv_soa(values, x, brows=tuple(brows),
                             bcols=tuple(bcols), nblk=nblk,
                             batch_tile=policy.batch_tile,
                             interpret=policy.interpret)


def _jnp_bsr_block_jacobi_inverse_soa(values, pattern, *, policy=None):
    from repro.kernels import ref as kref
    brows, bcols, nblk = pattern
    return kref.bsr_diag_inverse_soa_ref(values, brows, bcols, nblk)


def _pl_bsr_block_jacobi_inverse_soa(values, pattern, *,
                                     policy: ExecPolicy):
    from repro.kernels import ops as kops
    brows, bcols, nblk = pattern
    return kops.bsr_diag_inverse_soa(values, brows=tuple(brows),
                                     bcols=tuple(bcols), nblk=nblk,
                                     batch_tile=policy.batch_tile,
                                     interpret=policy.interpret)


def _ignore_policy(fn):
    @functools.wraps(fn)
    def wrapped(*args, policy=None):
        return fn(*args)
    return wrapped


# ---------------------------------------------------------------------------
# The op table.  Every entry has a 'jnp' and (for the hot ops) a 'pallas'
# implementation with identical signatures plus a keyword-only `policy`.
# ---------------------------------------------------------------------------

OP_TABLE = {
    # streaming
    "linear_sum": {"jnp": _ignore_policy(nv.linear_sum),
                   "pallas": _pl_linear_sum},
    "linear_combination": {"jnp": _ignore_policy(nv.linear_combination),
                           "pallas": _pl_linear_combination},
    "scale_add_multi": {"jnp": _ignore_policy(nv.scale_add_multi),
                        "pallas": _pl_scale_add_multi},
    "axpy": {"jnp": _ignore_policy(nv.axpy), "pallas": _pl_axpy},
    # reductions
    "dot": {"jnp": _ignore_policy(nv.dot), "pallas": _pl_dot},
    "wrms_norm": {"jnp": _ignore_policy(nv.wrms_norm),
                  "pallas": _pl_wrms_norm},
    "wrms_norm_mask": {"jnp": _ignore_policy(nv.wrms_norm_mask),
                       "pallas": _pl_wrms_norm_mask},
    "dot_prod_multi": {"jnp": _ignore_policy(nv.dot_prod_multi),
                       "pallas": _pl_dot_prod_multi},
    "wrms_ss": {"jnp": _jnp_wrms_ss, "pallas": _pl_wrms_ss},
    # batched block-diagonal (ensemble) linear algebra, SoA layout
    "block_solve_soa": {"jnp": _jnp_block_solve_soa,
                        "pallas": _pl_block_solve_soa},
    "block_inverse_soa": {"jnp": _jnp_block_inverse_soa,
                          "pallas": _pl_block_inverse_soa},
    "blockdiag_spmv_soa": {"jnp": _jnp_blockdiag_spmv_soa,
                           "pallas": _pl_blockdiag_spmv_soa},
    # fused ensemble-Newton hot-loop ops (SoA, nsys last)
    "newton_residual_soa": {"jnp": _jnp_newton_residual_soa,
                            "pallas": _pl_newton_residual_soa},
    "masked_update_wrms_soa": {"jnp": _jnp_masked_update_wrms_soa,
                               "pallas": _pl_masked_update_wrms_soa},
    "history_rescale_soa": {"jnp": _jnp_history_rescale_soa,
                            "pallas": _pl_history_rescale_soa},
    "wrms_soa": {"jnp": _jnp_wrms_soa, "pallas": _pl_wrms_soa},
    # sparse matrices (static shared patterns)
    "csr_spmv": {"jnp": _jnp_csr_spmv, "pallas": _pl_csr_spmv},
    "bsr_spmv_soa": {"jnp": _jnp_bsr_spmv_soa,
                     "pallas": _pl_bsr_spmv_soa},
    "bsr_block_jacobi_inverse_soa": {
        "jnp": _jnp_bsr_block_jacobi_inverse_soa,
        "pallas": _pl_bsr_block_jacobi_inverse_soa},
}


def op_names() -> frozenset:
    """The canonical dispatch op set — the single source of truth that
    :class:`~repro.core.policies.ExecPolicy` override validation and
    sunlint's table-coherence rule check against."""
    return frozenset(OP_TABLE)


def _positional_arity(fn):
    """Number of positional parameters, following ``functools.wraps``
    chains (so ``_ignore_policy(nv.axpy)`` reports nv.axpy's arity).
    ``None`` for variadic implementations."""
    sig = inspect.signature(fn)
    n = 0
    for p in sig.parameters.values():
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
            n += 1
        elif p.kind is p.VAR_POSITIONAL:
            return None
    return n


def _accepts_policy(fn) -> bool:
    # the dispatch contract is on the callable actually invoked, so do
    # NOT follow __wrapped__ here: _ignore_policy's wrapper adds the
    # policy kwarg that its wrapped oracle lacks.
    sig = inspect.signature(fn, follow_wrapped=False)
    return ("policy" in sig.parameters
            or any(p.kind is p.VAR_KEYWORD
                   for p in sig.parameters.values()))


def validate_op_table(table=None):
    """Fail fast on a half-registered op.

    Checks every entry of ``table`` (default :data:`OP_TABLE`) for: a
    callable ``'jnp'`` oracle AND a callable ``'pallas'`` kernel, no
    stray backend keys, matching positional arities between the two
    implementations, and the keyword-only ``policy`` argument the
    dispatcher passes.  All offenders are collected and reported in ONE
    aggregated ``ValueError`` — previously a half-registered op
    surfaced as a late ``AttributeError`` at first dispatch.
    """
    table = OP_TABLE if table is None else table
    problems = []
    for op in sorted(table):
        impls = table[op]
        if not isinstance(impls, dict):
            problems.append(f"{op}: entry is {type(impls).__name__}, "
                            f"expected a {{'jnp', 'pallas'}} dict")
            continue
        stray = sorted(set(impls) - {"jnp", "pallas"})
        if stray:
            problems.append(f"{op}: unknown backend keys {stray}")
        for backend in ("jnp", "pallas"):
            fn = impls.get(backend)
            if fn is None:
                problems.append(f"{op}: missing {backend!r} "
                                f"implementation")
            elif not callable(fn):
                problems.append(f"{op}: {backend!r} implementation is "
                                f"not callable")
            elif not _accepts_policy(fn):
                problems.append(f"{op}: {backend!r} implementation does "
                                f"not accept the keyword-only `policy` "
                                f"argument")
        jnp_fn, pl_fn = impls.get("jnp"), impls.get("pallas")
        if callable(jnp_fn) and callable(pl_fn):
            a_j, a_p = _positional_arity(jnp_fn), _positional_arity(pl_fn)
            if a_j is not None and a_p is not None and a_j != a_p:
                problems.append(f"{op}: arity mismatch — jnp oracle "
                                f"takes {a_j} positional args, pallas "
                                f"kernel takes {a_p}")
    if problems:
        raise ValueError(
            "OP_TABLE validation failed (%d problem%s):\n  - %s"
            % (len(problems), "" if len(problems) == 1 else "s",
               "\n  - ".join(problems)))


validate_op_table()


def dispatch(op: str, policy: Optional[ExecPolicy] = None):
    """Resolve `op` to the implementation selected by `policy`.

    ``None`` means :data:`~repro.core.policies.XLA_FUSED`.  Unknown ops
    and backends raise ``ValueError``; ops without a pallas
    implementation fall back to jnp (there are none today, but the
    table is the extension point).

    ``backend='auto'`` defers the choice to the call site: the returned
    callable extracts the argument shape signature at trace time and
    lets :mod:`repro.core.autotune` pick the backend and tile from the
    measured cache (falling back to the analytical model in
    :mod:`repro.analysis.opcost`).  Per-op ``policy.op_overrides`` pin
    individual ops first.
    """
    policy = XLA_FUSED if policy is None else policy
    impls = OP_TABLE.get(op)
    if impls is None:
        raise ValueError(f"unknown dispatch op {op!r}; valid OP_TABLE "
                         f"ops: {', '.join(sorted(OP_TABLE))}")
    backend = policy.backend_for(op) if hasattr(policy, "backend_for") \
        else policy.backend
    if backend == "auto":
        from . import autotune
        return functools.partial(autotune.resolve, op, policy)
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown ExecPolicy backend: {backend!r}")
    fn = impls.get(backend, impls["jnp"])
    return functools.partial(fn, policy=policy)


# ---------------------------------------------------------------------------
# Documentation rendering: the op-table matrices in the policies module
# docstring and the README are generated FROM this table (one row per
# OP_TABLE key), so new ops cannot drift out of the docs — a test
# asserts the rendered text is embedded verbatim.
# ---------------------------------------------------------------------------

# short impl descriptions per backend; the renderer iterates OP_TABLE
# keys, so an op missing here still gets a row (with generic text).
OP_NOTES = {
    "linear_sum": ("vector.linear_sum", "vecops lincomb (K=2)"),
    "linear_combination": ("vector.linear_combination",
                           "vecops lincomb kernel"),
    "scale_add_multi": ("vector.scale_add_multi", "vecops scale_add_multi"),
    "axpy": ("vector.axpy", "vecops lincomb (K=2)"),
    "dot": ("vector.dot", "vecops dot_partial"),
    "wrms_norm": ("vector.wrms_norm", "vecops wrms_partial"),
    "wrms_norm_mask": ("vector.wrms_norm_mask", "vecops wrms_mask_partial"),
    "dot_prod_multi": ("vector.dot_prod_multi", "vecops multi_dot_partial"),
    "wrms_ss": ("vector prod+dot", "vecops wrms_partial (raw ss)"),
    "block_solve_soa": ("direct.gauss_jordan_batched",
                        "GJ kernel (b>8: row-tiled)"),
    "block_inverse_soa": ("ref.block_inverse_soa_ref",
                          "GJ inverse (b>8: row-tiled)"),
    "blockdiag_spmv_soa": ("jnp.einsum", "blockdiag_spmv kernel"),
    "newton_residual_soa": ("ref (z - gamma*f - psi)",
                            "newton fused residual"),
    "masked_update_wrms_soa": ("ref (where + wrms)",
                               "newton fused update+WRMS"),
    "history_rescale_soa": ("ref (masked AoS einsum)",
                            "newton masked rebuild"),
    "wrms_soa": ("ref (per-system WRMS)", "newton wrms_soa kernel"),
    "csr_spmv": ("segment_sum", "sparse ELL gather kernel"),
    "bsr_spmv_soa": ("einsum+segment_sum", "sparse unrolled-pattern"),
    "bsr_block_jacobi_inverse_soa": ("jnp.linalg.inv",
                                     "diag gather + GJ inverse"),
}


def op_table_rows():
    """(op, jnp description, pallas description) per OP_TABLE entry."""
    return [(op,) + OP_NOTES.get(op, ("jnp oracle", "pallas kernel"))
            for op in OP_TABLE]


def render_op_table(fmt: str = "rst") -> str:
    """Render the backend matrix from :data:`OP_TABLE` ('rst' for the
    policies-module docstring, 'md' for the README)."""
    rows = op_table_rows()
    heads = ("op", "'jnp' backend", "'pallas' backend")
    widths = [max(len(r[i]) for r in rows + [heads]) for i in range(3)]
    if fmt == "md":
        lines = ["| " + " | ".join(h.ljust(w)
                                   for h, w in zip(heads, widths)) + " |",
                 "|" + "|".join("-" * (w + 2) for w in widths) + "|"]
        lines += ["| " + " | ".join(c.ljust(w)
                                    for c, w in zip(r, widths)) + " |"
                  for r in rows]
        return "\n".join(lines)
    rule = "  ".join("=" * w for w in widths)
    lines = [rule, "  ".join(h.ljust(w)
                             for h, w in zip(heads, widths)).rstrip(), rule]
    lines += ["  ".join(c.ljust(w)
                        for c, w in zip(r, widths)).rstrip() for r in rows]
    lines.append(rule)
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Public wrappers — what the integrators call.
# ---------------------------------------------------------------------------


def linear_sum(a, x: Pytree, b, y: Pytree,
               policy: Optional[ExecPolicy] = None) -> Pytree:
    return dispatch("linear_sum", policy)(a, x, b, y)


def linear_combination(coeffs: Sequence, vecs: Sequence[Pytree],
                       policy: Optional[ExecPolicy] = None) -> Pytree:
    return dispatch("linear_combination", policy)(coeffs, vecs)


def scale_add_multi(coeffs: Sequence, x: Pytree, ys: Sequence[Pytree],
                    policy: Optional[ExecPolicy] = None):
    return dispatch("scale_add_multi", policy)(coeffs, x, ys)


def axpy(a, x: Pytree, y: Pytree,
         policy: Optional[ExecPolicy] = None) -> Pytree:
    return dispatch("axpy", policy)(a, x, y)


def dot(x: Pytree, y: Pytree, policy: Optional[ExecPolicy] = None):
    return dispatch("dot", policy)(x, y)


def wrms_norm(x: Pytree, w: Pytree, policy: Optional[ExecPolicy] = None):
    return dispatch("wrms_norm", policy)(x, w)


def wrms_norm_mask(x: Pytree, w: Pytree, mask: Pytree,
                   policy: Optional[ExecPolicy] = None):
    return dispatch("wrms_norm_mask", policy)(x, w, mask)


def dot_prod_multi(x: Pytree, ys: Sequence[Pytree],
                   policy: Optional[ExecPolicy] = None):
    return dispatch("dot_prod_multi", policy)(x, ys)


def wrms_ss(x: Pytree, w: Pytree, policy: Optional[ExecPolicy] = None):
    """Node-local sum((x*w)^2) (no sqrt, no /N) — the partial MeshVector
    feeds to its collective."""
    return dispatch("wrms_ss", policy)(x, w)


def block_solve_soa(A: jnp.ndarray, r: jnp.ndarray,
                    policy: Optional[ExecPolicy] = None) -> jnp.ndarray:
    """Solve every block system: A:(b,b,NB), r:(b,NB) -> x:(b,NB)."""
    return dispatch("block_solve_soa", policy)(A, r)


def block_inverse_soa(A: jnp.ndarray,
                      policy: Optional[ExecPolicy] = None) -> jnp.ndarray:
    """Invert every block: A:(b,b,NB) -> A^{-1}:(b,b,NB) (lsetup)."""
    return dispatch("block_inverse_soa", policy)(A)


def blockdiag_spmv_soa(A: jnp.ndarray, x: jnp.ndarray,
                       policy: Optional[ExecPolicy] = None) -> jnp.ndarray:
    """y = blockdiag(A) @ x: A:(b,b,NB), x:(b,NB) -> (b,NB) (lsolve)."""
    return dispatch("blockdiag_spmv_soa", policy)(A, x)


def newton_residual_soa(z: jnp.ndarray, fval: jnp.ndarray,
                        psi: jnp.ndarray, gamma: jnp.ndarray,
                        policy: Optional[ExecPolicy] = None, *,
                        negate: bool = False) -> jnp.ndarray:
    """Fused Newton residual g = z - gamma*f - psi; z/f/psi (n, nsys),
    gamma (nsys,).  ``negate=True`` emits -g (the Newton rhs) in the
    same pass; the sign is applied to the computed g so both variants
    round identically."""
    return dispatch("newton_residual_soa", policy)(z, fval, psi, gamma,
                                                   negate)


def masked_update_wrms_soa(z: jnp.ndarray, dz: jnp.ndarray,
                           w: jnp.ndarray, mask: jnp.ndarray,
                           policy: Optional[ExecPolicy] = None):
    """Fused masked iterate update + per-system WRMS of the correction:
    -> (where(mask, z+dz, z), wrms-per-system of dz)."""
    return dispatch("masked_update_wrms_soa", policy)(z, dz, w, mask)


def history_rescale_soa(W: jnp.ndarray, Z: jnp.ndarray,
                        active: jnp.ndarray,
                        policy: Optional[ExecPolicy] = None) -> jnp.ndarray:
    """Masked per-system Lagrange history rebuild: W (q1,q1,nsys),
    Z (q1,n,nsys) -> where(active, sum_i W[j,i]*Z[i], Z[j]); inactive
    bundles are short-circuited on the pallas backend."""
    return dispatch("history_rescale_soa", policy)(W, Z, active)


def wrms_soa(v: jnp.ndarray, w: jnp.ndarray,
             policy: Optional[ExecPolicy] = None) -> jnp.ndarray:
    """Per-system WRMS over the state axis: v/w (n, nsys) -> (nsys,) —
    the batched row of the wrms_norm family (ensemble error tests)."""
    return dispatch("wrms_soa", policy)(v, w)


def csr_spmv(data: jnp.ndarray, x: jnp.ndarray, pattern,
             policy: Optional[ExecPolicy] = None) -> jnp.ndarray:
    """y = A @ x for a static-pattern CSR matrix: data:(nnz,), x:(m,),
    pattern = (indptr, indices) hashable tuples."""
    return dispatch("csr_spmv", policy)(data, x, pattern)


def bsr_spmv_soa(values: jnp.ndarray, x: jnp.ndarray, pattern,
                 policy: Optional[ExecPolicy] = None) -> jnp.ndarray:
    """Ensemble shared-pattern BSR SpMV: values:(nnzb,b,b,NB),
    x:(nblk,b,NB), pattern = (brows, bcols, nblk) -> y:(nblk,b,NB)."""
    return dispatch("bsr_spmv_soa", policy)(values, x, pattern)


def bsr_block_jacobi_inverse_soa(values: jnp.ndarray, pattern,
                                 policy: Optional[ExecPolicy] = None
                                 ) -> jnp.ndarray:
    """Invert every diagonal block of the shared pattern (block-Jacobi
    psetup): values:(nnzb,b,b,NB) -> (b,b,nblk*NB), block-major."""
    return dispatch("bsr_block_jacobi_inverse_soa", policy)(values,
                                                            pattern)


if __name__ == "__main__":      # regenerate the docs' op-table matrices
    print(render_op_table("rst"))
    print()
    print(render_op_table("md"))
