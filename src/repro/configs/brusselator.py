"""The paper's demonstration problem config (§7): 1D advection-reaction
brusselator.  Not an LM arch — consumed by examples/ and benchmarks/."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BrusselatorConfig:
    name: str = "brusselator1d"
    nx: int = 512            # mesh points (paper: up to 1.536e8)
    c: float = 0.01          # advection speed
    A: float = 1.0
    B: float = 3.5
    eps: float = 5e-6        # stiffness parameter
    b_domain: float = 10.0   # domain size (paper b in {10..2560})
    t_final: float = 10.0
    alpha: float = 0.1       # initial-bump amplitude
    rtol: float = 1e-6
    atol: float = 1e-9
    solver: str = "task-local"   # 'task-local' | 'global'


CONFIGS = []  # not an ArchConfig; registry skips it
DEFAULT = BrusselatorConfig()
