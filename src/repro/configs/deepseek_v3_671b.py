"""deepseek-v3-671b  [moe]  — MLA + 1 shared + 256 routed top-8 + MTP.

61L d_model=7168 128H (kv=128 via MLA absorption) d_ff=2048(expert)
vocab=129280, 256 experts top-8.  [arXiv:2412.19437; hf]
MLA dims from the HF config: q_lora 1536, kv_lora 512, qk_nope 128,
qk_rope 64, v_head 128.
"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab_size=129280, head_dim=192,
    n_experts=256, experts_per_tok=8, n_shared_experts=1, moe_d_ff=2048,
    router_impl="sigmoid",
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    rope_theta=1e4, mtp=True,
)

SMOKE = FULL.replace(
    name="deepseek-v3-671b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=128, vocab_size=256, n_experts=8, experts_per_tok=2,
    moe_d_ff=32, q_lora_rank=24, kv_lora_rank=16, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16, mtp=True, remat=False,
)

CONFIGS = [FULL, SMOKE]
