"""qwen2-vl-2b  [vlm] — M-RoPE, dynamic resolution (frontend stubbed).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
[arXiv:2409.12191; hf]  input_specs provides precomputed patch embeds.
"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, mrope=True, rope_theta=1e6,
)

SMOKE = FULL.replace(
    name="qwen2-vl-2b-smoke",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=256, remat=False,
)

CONFIGS = [FULL, SMOKE]
