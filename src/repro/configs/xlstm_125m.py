"""xlstm-125m  [ssm] — alternating sLSTM + mLSTM blocks.

12L d_model=768 4H d_ff=0 (blocks carry their own projections)
vocab=50304.  [arXiv:2405.04517; unverified]
"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
)

SMOKE = FULL.replace(
    name="xlstm-125m-smoke",
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, vocab_size=128,
    remat=False,
)

CONFIGS = [FULL, SMOKE]
