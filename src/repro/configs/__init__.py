"""Architecture config registry: get("deepseek-v3-671b") etc.

Every assigned arch has a full config and a reduced ``-smoke`` variant
(same family/topology, tiny dims) used by the per-arch CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ArchConfig, SHAPES, ShapeConfig

_MODULES = [
    "deepseek_v3_671b", "dbrx_132b", "xlstm_125m", "qwen2_vl_2b",
    "internlm2_1_8b", "deepseek_coder_33b", "qwen2_72b", "starcoder2_7b",
    "zamba2_7b", "whisper_tiny", "brusselator",
]

_REGISTRY: Dict[str, ArchConfig] = {}


def _load():
    if _REGISTRY:
        return
    for m in _MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        for cfg in getattr(mod, "CONFIGS", []):
            _REGISTRY[cfg.name] = cfg


def get(name: str) -> ArchConfig:
    _load()
    return _REGISTRY[name]


def names():
    _load()
    return sorted(_REGISTRY)


ARCH_IDS = [
    "deepseek-v3-671b", "dbrx-132b", "xlstm-125m", "qwen2-vl-2b",
    "internlm2-1.8b", "deepseek-coder-33b", "qwen2-72b", "starcoder2-7b",
    "zamba2-7b", "whisper-tiny",
]


def cell_is_runnable(arch_id: str, shape_name: str) -> bool:
    """long_500k needs sub-quadratic sequence mixing (spec'd skip rule)."""
    cfg = get(arch_id)
    shp = SHAPES[shape_name]
    if shp.name == "long_500k" and not cfg.supports_long_context:
        return False
    return True
