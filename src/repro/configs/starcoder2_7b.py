"""starcoder2-7b  [dense] — GQA, RoPE (4k sliding window).

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
[arXiv:2402.19173; hf]
"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab_size=49152, rope_theta=1e5,
    sliding_window=4096,
)

SMOKE = FULL.replace(
    name="starcoder2-7b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, sliding_window=64, remat=False,
)

CONFIGS = [FULL, SMOKE]
