"""deepseek-coder-33b  [dense] — llama-arch.

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
[arXiv:2401.14196; hf]
"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab_size=32256, rope_theta=1e5,
)

SMOKE = FULL.replace(
    name="deepseek-coder-33b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
    vocab_size=256, remat=False,
)

CONFIGS = [FULL, SMOKE]
