"""dbrx-132b  [moe] — 16 experts top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.
[hf:databricks/dbrx-base; unverified]
"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    n_experts=16, experts_per_tok=4, moe_d_ff=10752,
    rope_theta=5e5,
)

SMOKE = FULL.replace(
    name="dbrx-132b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, n_experts=4, experts_per_tok=2, moe_d_ff=128,
    remat=False,
)

CONFIGS = [FULL, SMOKE]
