"""whisper-tiny  [audio] — enc-dec, conv frontend stubbed.

4L (enc=dec=4) d_model=384 6H d_ff=1536 vocab=51865.
[arXiv:2212.04356; unverified]  input_specs provides precomputed frame
embeddings (the 2xConv1d stem output).
"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    enc_dec=True, enc_layers=4, tie_embeddings=True,
)

SMOKE = FULL.replace(
    name="whisper-tiny-smoke",
    n_layers=2, enc_layers=2, d_model=48, n_heads=2, n_kv_heads=2,
    d_ff=96, vocab_size=256, remat=False,
)

CONFIGS = [FULL, SMOKE]
