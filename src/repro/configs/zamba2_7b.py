"""zamba2-7b  [hybrid] — Mamba2 stack + shared attention block.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64.
[arXiv:2411.15242; unverified]  Shared attn applied every 6 layers over
concat(hidden, embedding) — the zamba shared-block design.
"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    attn_every=6,
)

SMOKE = FULL.replace(
    name="zamba2-7b-smoke",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, ssm_state=16, ssm_head_dim=16, attn_every=2,
    remat=False,
)

CONFIGS = [FULL, SMOKE]
