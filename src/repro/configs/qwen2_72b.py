"""qwen2-72b  [dense] — GQA + QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
[arXiv:2407.10671; hf]
"""
from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
)

SMOKE = FULL.replace(
    name="qwen2-72b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=256, qkv_bias=True, remat=False,
)

CONFIGS = [FULL, SMOKE]
