"""Pallas TPU kernel: block-diagonal matrix-vector product.

The paper's SUNMatrix_cuSparse provides a custom low-storage
block-diagonal SpMV.  TPU version in the SoA layout of block_solve.py:
A:(b,b,NB), x:(b,NB) -> y:(b,NB); the b^2 multiply-adds are unrolled and
every operation is a LANE-wide elementwise op — memory-bound streaming,
exactly one read of A and x per element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _spmv_kernel(a_ref, x_ref, y_ref, *, b: int):
    for i in range(b):
        acc = a_ref[i, 0, :] * x_ref[0, :]
        for j in range(1, b):
            acc = acc + a_ref[i, j, :] * x_ref[j, :]
        y_ref[i, :] = acc


def blockdiag_spmv_soa(A: jnp.ndarray, x: jnp.ndarray, *,
                       batch_tile: int = 4 * LANE,
                       interpret: bool = True) -> jnp.ndarray:
    b, b2, NB = A.shape
    assert b == b2 and x.shape == (b, NB)
    assert NB % batch_tile == 0
    grid = (NB // batch_tile,)
    kernel = functools.partial(_spmv_kernel, b=b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, b, batch_tile), lambda g: (0, 0, g)),
            pl.BlockSpec((b, batch_tile), lambda g: (0, g)),
        ],
        out_specs=pl.BlockSpec((b, batch_tile), lambda g: (0, g)),
        out_shape=jax.ShapeDtypeStruct((b, NB), A.dtype),
        interpret=interpret,
    )(A, x)
