"""Pallas TPU kernels for the ensemble Newton hot loop (SoA layout).

The batched-BDF corrector runs these three ops on every Newton
iteration / step over ``(n, NB)`` state arrays with the system batch on
the 128-wide lane axis (the repo's SoA-everywhere convention, nsys
LAST).  Unfused, each costs one HBM pass per constituent op; fused,
each is exactly one pass:

* :func:`newton_residual` — ``g = z - gamma*f - psi`` (three streaming
  operands, one output; ``negate=True`` emits the Newton right-hand
  side ``-g`` directly, folding the sign flip into the same pass);
* :func:`masked_update_wrms` — the masked iterate update
  ``z += dz (where mask)`` FUSED with the per-system WRMS of ``dz``:
  the correction is read once from HBM instead of once for the update
  and once for the convergence-rate reduction;
* :func:`history_rescale` — the Lagrange history rebuild
  ``Z_new[j] = sum_i W[j,i] * Z[i]`` as a lane-parallel kernel that
  SHORT-CIRCUITS inactive systems: a bundle whose systems are all
  masked (finished, or unclipped steps with identity W) copies Z
  through instead of running the (QMAX+1)^2 multiply-add sweep, and
  inactive lanes inside a live bundle pass through unchanged;
* :func:`wrms_soa` — the per-system WRMS reduction ``(n, NB) -> (NB,)``
  (the batched row of the N_VWrmsNorm family; the BDF error test and
  the DIRK residual checks go through it).

Like the block kernels, the n (state) axis rides the sublanes and is
small/static; ``ops.py`` pads the batch axis to the bundle tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _newton_residual_kernel(z_ref, f_ref, psi_ref, gam_ref, out_ref, *,
                            negate: bool):
    g = z_ref[...] - gam_ref[...][None, :] * f_ref[...] - psi_ref[...]
    out_ref[...] = -g if negate else g


def newton_residual(z: jnp.ndarray, fval: jnp.ndarray, psi: jnp.ndarray,
                    gamma: jnp.ndarray, *, batch_tile: int = 4 * LANE,
                    interpret: bool = True,
                    negate: bool = False) -> jnp.ndarray:
    """Fused g = z - gamma*f - psi; all of z/f/psi are (n, NB), gamma is
    (NB,).  ``negate=True`` returns -g (the Newton rhs) in the same
    pass."""
    n, NB = z.shape
    assert fval.shape == (n, NB) and psi.shape == (n, NB)
    assert gamma.shape == (NB,) and NB % batch_tile == 0
    grid = (NB // batch_tile,)
    kernel = functools.partial(_newton_residual_kernel, negate=negate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, batch_tile), lambda g: (0, g)),
            pl.BlockSpec((n, batch_tile), lambda g: (0, g)),
            pl.BlockSpec((n, batch_tile), lambda g: (0, g)),
            pl.BlockSpec((batch_tile,), lambda g: (g,)),
        ],
        out_specs=pl.BlockSpec((n, batch_tile), lambda g: (0, g)),
        out_shape=jax.ShapeDtypeStruct((n, NB), z.dtype),
        interpret=interpret,
    )(z, fval, psi, gamma)


def _masked_update_wrms_kernel(z_ref, dz_ref, w_ref, m_ref, zout_ref,
                               dn_ref, *, n: int):
    m = m_ref[...] > 0.5                     # float mask on the lanes
    dz = dz_ref[...]
    zout_ref[...] = jnp.where(m[None, :], z_ref[...] + dz, z_ref[...])
    t = dz * w_ref[...]
    dn_ref[...] = jnp.sqrt(jnp.sum(t * t, axis=0) / n)


def masked_update_wrms(z: jnp.ndarray, dz: jnp.ndarray, w: jnp.ndarray,
                       mask: jnp.ndarray, *, batch_tile: int = 4 * LANE,
                       interpret: bool = True):
    """Fused masked iterate update + per-system WRMS of the correction.

    z/dz/w: (n, NB), mask: (NB,) (nonzero = update) ->
    ``(z_new, dn)`` with z_new = where(mask, z+dz, z) and
    dn[s] = sqrt(mean_k (dz[k,s]*w[k,s])^2).  The norm is over ALL
    systems (masked systems still report their dn; the caller decides
    what to keep), matching the unfused update-then-wrms pair.
    """
    n, NB = z.shape
    assert dz.shape == (n, NB) and w.shape == (n, NB)
    assert mask.shape == (NB,) and NB % batch_tile == 0
    grid = (NB // batch_tile,)
    kernel = functools.partial(_masked_update_wrms_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, batch_tile), lambda g: (0, g)),
            pl.BlockSpec((n, batch_tile), lambda g: (0, g)),
            pl.BlockSpec((n, batch_tile), lambda g: (0, g)),
            pl.BlockSpec((batch_tile,), lambda g: (g,)),
        ],
        out_specs=[
            pl.BlockSpec((n, batch_tile), lambda g: (0, g)),
            pl.BlockSpec((batch_tile,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, NB), z.dtype),
            jax.ShapeDtypeStruct((NB,), z.dtype),
        ],
        interpret=interpret,
    )(z, dz, w, mask)


def _history_rescale_kernel(w_ref, z_ref, a_ref, out_ref, *, q1: int):
    act = a_ref[...] > 0.5

    @pl.when(jnp.any(act))
    def _():
        for j in range(q1):
            acc = w_ref[j, 0, :][None, :] * z_ref[0]
            for i in range(1, q1):
                acc = acc + w_ref[j, i, :][None, :] * z_ref[i]
            out_ref[j, :, :] = jnp.where(act[None, :], acc, z_ref[j])

    @pl.when(jnp.logical_not(jnp.any(act)))
    def _():
        out_ref[...] = z_ref[...]


def history_rescale(W: jnp.ndarray, Z: jnp.ndarray, active: jnp.ndarray,
                    *, batch_tile: int = 4 * LANE,
                    interpret: bool = True) -> jnp.ndarray:
    """Lane-parallel Lagrange history rebuild with inactive short-circuit.

    W: (q1, q1, NB) per-system rescale matrices, Z: (q1, n, NB) history,
    active: (NB,) (nonzero = rescale) -> Z_new with
    Z_new[j,k,s] = sum_i W[j,i,s] * Z[i,k,s] where active, else Z[j,k,s].
    A bundle tile with NO active system skips the q1^2 multiply-add
    sweep entirely and copies Z through (the common case between step
    rejections and once most systems reach tf).
    """
    q1, q1b, NB = W.shape
    _, n, _ = Z.shape
    assert q1 == q1b and Z.shape == (q1, n, NB)
    assert active.shape == (NB,) and NB % batch_tile == 0
    grid = (NB // batch_tile,)
    kernel = functools.partial(_history_rescale_kernel, q1=q1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q1, q1, batch_tile), lambda g: (0, 0, g)),
            pl.BlockSpec((q1, n, batch_tile), lambda g: (0, 0, g)),
            pl.BlockSpec((batch_tile,), lambda g: (g,)),
        ],
        out_specs=pl.BlockSpec((q1, n, batch_tile), lambda g: (0, 0, g)),
        out_shape=jax.ShapeDtypeStruct((q1, n, NB), Z.dtype),
        interpret=interpret,
    )(W, Z, active)


def _wrms_soa_kernel(v_ref, w_ref, out_ref, *, n: int):
    t = v_ref[...] * w_ref[...]
    out_ref[...] = jnp.sqrt(jnp.sum(t * t, axis=0) / n)


def wrms_soa(v: jnp.ndarray, w: jnp.ndarray, *,
             batch_tile: int = 4 * LANE,
             interpret: bool = True) -> jnp.ndarray:
    """Per-system WRMS: v/w (n, NB) -> (NB,), one fused pass (the
    sublane reduction stays inside the tile, so no partials)."""
    n, NB = v.shape
    assert w.shape == (n, NB) and NB % batch_tile == 0
    grid = (NB // batch_tile,)
    kernel = functools.partial(_wrms_soa_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, batch_tile), lambda g: (0, g)),
            pl.BlockSpec((n, batch_tile), lambda g: (0, g)),
        ],
        out_specs=pl.BlockSpec((batch_tile,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((NB,), v.dtype),
        interpret=interpret,
    )(v, w)
