"""jit'd public wrappers for the Pallas kernels (padding, layout, dispatch).

Callers use these; the raw kernels live in their own modules and the
pure-jnp oracles in ref.py.  On this CPU container ``interpret=True``
runs the kernel bodies in Python for validation; on TPU deployments the
same entry points compile to Mosaic (``interpret=False`` via ExecPolicy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import block_solve as _bs
from . import blockdiag_spmv as _sp
from . import newton as _nw
from . import sparse as _sx
from . import vecops as _vo

LANE = 128

# VMEM budget for the row-tiled Gauss-Jordan accumulator (compiled
# mode): the (b, width, tile) working set is kept under this many
# bytes, so the bundle tile shrinks ~1/b^2 as blocks grow (b=16 f64
# caps near 7 lanes, b=24 near 3) instead of spilling.  Interpret mode
# (CPU emulation) has no VMEM and pays per-grid-step interpreter
# overhead instead, so the cap only applies when compiling.
GJ_VMEM_BYTES = 2 * 1024 * 1024


def _lane_ceil(n: int) -> int:
    """Smallest lane-aligned size >= n (tile clamp for short vectors)."""
    return max(LANE, -(-n // LANE) * LANE)


def _pad_to(x: jnp.ndarray, mult: int, axis: int, fill=0.0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill), n


def _batch_tile(nb: int, batch_tile: int) -> int:
    """Bundle tile for the batched block kernels: the largest
    lane-multiple divisor of the lane-padded batch that does not exceed
    the policy's ``batch_tile`` (systems per grid program — the TPU
    analog of the paper's CUDA-stream bundle size).  Requiring the tile
    to divide the padded batch bounds the padding below one lane of
    identity blocks; a tile that merely rounds ``batch_tile`` up could
    force the batch itself to pad up to a tile multiple (e.g. nb=516
    with a 512 tile would eliminate 1024 blocks, ~2x the work)."""
    lanes = _lane_ceil(nb) // LANE
    dmax = max(1, min(batch_tile // LANE, lanes))
    d = max(dd for dd in range(1, dmax + 1) if lanes % dd == 0)
    return d * LANE


def _gj_batch_tile(nb: int, batch_tile: int, *, b: int, width: int,
                   itemsize: int, interpret: bool,
                   vmem_bytes=None) -> int:
    """Bundle tile for the Gauss-Jordan kernels: :func:`_batch_tile`
    with, in compiled mode, the requested tile first clamped so the
    row-tiled accumulator ``(b, width, tile)`` fits the VMEM budget
    — i.e. the tile shrinks with b^2.  Small blocks (the unrolled
    kernels) are unaffected: their cap exceeds any practical tile.

    ``vmem_bytes`` overrides the default :data:`GJ_VMEM_BYTES` budget —
    the cost-model dispatch layer passes the roofline device table's
    budget here so the clamp is a policy-visible decision rather than a
    module constant."""
    if not interpret:
        budget = GJ_VMEM_BYTES if vmem_bytes is None else vmem_bytes
        cap = budget // (itemsize * b * width)
        batch_tile = min(batch_tile, max(LANE, cap // LANE * LANE))
    return _batch_tile(nb, batch_tile)


def _pad_blocks_identity(Ap: jnp.ndarray, nb: int) -> jnp.ndarray:
    """Make padding blocks (SoA batch axis 2 beyond ``nb``) identity so
    the no-pivot elimination stays well-defined on them."""
    if Ap.shape[2] == nb:
        return Ap
    b = Ap.shape[0]
    eye = jnp.eye(b, dtype=Ap.dtype)[:, :, None]
    padmask = (jnp.arange(Ap.shape[2]) >= nb)[None, None, :]
    return jnp.where(padmask, eye, Ap)


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret",
                                             "scale_rows", "vmem_bytes"))
def block_solve(A: jnp.ndarray, r: jnp.ndarray, *, batch_tile: int = 4 * LANE,
                interpret: bool = True, scale_rows: bool = True,
                vmem_bytes=None):
    """Batched block solve, AoS API: A:(nb,b,b), r:(nb,b) -> x:(nb,b).

    Transposes to the SoA lane-major layout, pads the batch to the tile
    (padding blocks are identity so the no-pivot elimination is safe),
    runs the kernel, and transposes back.  TPU callers holding SoA data
    should call :func:`block_solve_soa` directly and skip the transposes.
    """
    nb, b, _ = A.shape
    tile = _gj_batch_tile(nb, batch_tile, b=b, width=b + 1,
                          itemsize=A.dtype.itemsize, interpret=interpret,
                          vmem_bytes=vmem_bytes)
    Asoa = jnp.transpose(A, (1, 2, 0))          # (b, b, nb)
    rsoa = jnp.transpose(r, (1, 0))             # (b, nb)
    Ap, _ = _pad_to(Asoa, tile, axis=2)
    # make padded blocks identity to keep the elimination well-defined
    Ap = _pad_blocks_identity(Ap, nb)
    rp, _ = _pad_to(rsoa, tile, axis=1)
    x = _bs.block_solve_soa(Ap, rp, batch_tile=tile, interpret=interpret,
                            scale_rows=scale_rows)
    return jnp.transpose(x[:, :nb], (1, 0))


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret",
                                             "scale_rows", "vmem_bytes"))
def block_solve_soa(A: jnp.ndarray, r: jnp.ndarray, *,
                    batch_tile: int = 4 * LANE, interpret: bool = True,
                    scale_rows: bool = True, vmem_bytes=None):
    """SoA API (lane-major batch): A:(b,b,NB), r:(b,NB) -> x:(b,NB)."""
    b, _, nb = A.shape
    tile = _gj_batch_tile(nb, batch_tile, b=b, width=b + 1,
                          itemsize=A.dtype.itemsize, interpret=interpret,
                          vmem_bytes=vmem_bytes)
    Ap, _ = _pad_to(A, tile, axis=2)
    Ap = _pad_blocks_identity(Ap, nb)
    rp, _ = _pad_to(r, tile, axis=1)
    x = _bs.block_solve_soa(Ap, rp, batch_tile=tile, interpret=interpret,
                            scale_rows=scale_rows)
    return x[:, :nb]


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret",
                                             "scale_rows", "vmem_bytes"))
def block_inverse_soa(A: jnp.ndarray, *, batch_tile: int = 4 * LANE,
                      interpret: bool = True, scale_rows: bool = True,
                      vmem_bytes=None):
    """Per-block inverse, SoA layout: A:(b,b,NB) -> A^{-1}:(b,b,NB).

    The lsetup half of the ensemble Newton pipeline: invert every Newton
    block once, then each Newton iteration applies it with one
    :func:`blockdiag_spmv_soa` pass (lsolve)."""
    b, _, nb = A.shape
    tile = _gj_batch_tile(nb, batch_tile, b=b, width=b,
                          itemsize=A.dtype.itemsize, interpret=interpret,
                          vmem_bytes=vmem_bytes)
    Ap, _ = _pad_to(A, tile, axis=2)
    Ap = _pad_blocks_identity(Ap, nb)
    x = _bs.block_inverse_soa(Ap, batch_tile=tile, interpret=interpret,
                              scale_rows=scale_rows)
    return x[:, :, :nb]


@functools.partial(jax.jit, static_argnames=("block_elems", "interpret"))
def linear_combination(coeffs: jnp.ndarray, X: jnp.ndarray, *,
                       block_elems: int = 8 * LANE, interpret: bool = True):
    """Fused Z = sum_k coeffs[k] X[k];  X:(K, N) any N (padded inside)."""
    K, N = X.shape
    Xp, _ = _pad_to(X, block_elems, axis=1)
    z = _vo.linear_combination(coeffs, Xp, block_elems=block_elems,
                               interpret=interpret)
    return z[:N]


@functools.partial(jax.jit, static_argnames=("block_elems", "interpret"))
def scale_add_multi(coeffs: jnp.ndarray, x: jnp.ndarray, Y: jnp.ndarray, *,
                    block_elems: int = 8 * LANE, interpret: bool = True):
    """Fused Z[k] = coeffs[k]*x + Y[k];  x:(N,), Y:(K,N) any N."""
    K, N = Y.shape
    xp, _ = _pad_to(x, block_elems, axis=0)
    Yp, _ = _pad_to(Y, block_elems, axis=1)
    z = _vo.scale_add_multi(coeffs, xp, Yp, block_elems=block_elems,
                            interpret=interpret)
    return z[:, :N]


@functools.partial(jax.jit, static_argnames=("reduce_tile", "interpret"))
def wrms_norm(x: jnp.ndarray, w: jnp.ndarray, *, reduce_tile: int = 64 * LANE,
              interpret: bool = True):
    """Fused WRMS norm of 1-D x with weights w (BlockReduce policy)."""
    (N,) = x.shape
    tile = min(reduce_tile, _lane_ceil(N))
    xp, _ = _pad_to(x, tile, axis=0)
    wp, _ = _pad_to(w, tile, axis=0)   # pad weights with 0 -> no contribution
    parts = _vo.wrms_partial(xp, wp, reduce_tile=tile, interpret=interpret)
    return jnp.sqrt(jnp.sum(parts) / N)


@functools.partial(jax.jit, static_argnames=("reduce_tile", "interpret"))
def dot(x: jnp.ndarray, y: jnp.ndarray, *, reduce_tile: int = 64 * LANE,
        interpret: bool = True):
    (N,) = x.shape
    tile = min(reduce_tile, _lane_ceil(N))
    xp, _ = _pad_to(x, tile, axis=0)
    yp, _ = _pad_to(y, tile, axis=0)
    parts = _vo.dot_partial(xp, yp, reduce_tile=tile, interpret=interpret)
    return jnp.sum(parts)


@functools.partial(jax.jit, static_argnames=("reduce_tile", "interpret"))
def wrms_ss(x: jnp.ndarray, w: jnp.ndarray, *, reduce_tile: int = 64 * LANE,
            interpret: bool = True):
    """Raw sum((x*w)^2) of 1-D x — the per-leaf partial the dispatch
    layer accumulates across pytree leaves before the final sqrt(/N)."""
    (N,) = x.shape
    tile = min(reduce_tile, _lane_ceil(N))
    xp, _ = _pad_to(x, tile, axis=0)
    wp, _ = _pad_to(w, tile, axis=0)
    parts = _vo.wrms_partial(xp, wp, reduce_tile=tile, interpret=interpret)
    return jnp.sum(parts)


@functools.partial(jax.jit, static_argnames=("reduce_tile", "interpret"))
def wrms_mask_ss(x: jnp.ndarray, w: jnp.ndarray, m: jnp.ndarray, *,
                 reduce_tile: int = 64 * LANE, interpret: bool = True):
    """Raw sum((x*w*m)^2) of 1-D x (masked WRMS partial)."""
    (N,) = x.shape
    tile = min(reduce_tile, _lane_ceil(N))
    xp, _ = _pad_to(x, tile, axis=0)
    wp, _ = _pad_to(w, tile, axis=0)
    mp, _ = _pad_to(m, tile, axis=0)
    parts = _vo.wrms_mask_partial(xp, wp, mp, reduce_tile=tile,
                                  interpret=interpret)
    return jnp.sum(parts)


@functools.partial(jax.jit, static_argnames=("reduce_tile", "interpret"))
def wrms_norm_mask(x: jnp.ndarray, w: jnp.ndarray, m: jnp.ndarray, *,
                   reduce_tile: int = 64 * LANE, interpret: bool = True):
    """Masked WRMS norm of 1-D x: sqrt(sum((x*w*m)^2)/N)."""
    (N,) = x.shape
    tile = min(reduce_tile, _lane_ceil(N))
    xp, _ = _pad_to(x, tile, axis=0)
    wp, _ = _pad_to(w, tile, axis=0)   # zero weights -> no contribution
    mp, _ = _pad_to(m, tile, axis=0)
    parts = _vo.wrms_mask_partial(xp, wp, mp, reduce_tile=tile,
                                  interpret=interpret)
    return jnp.sqrt(jnp.sum(parts) / N)


@functools.partial(jax.jit, static_argnames=("reduce_tile", "interpret"))
def dot_prod_multi(x: jnp.ndarray, Y: jnp.ndarray, *,
                   reduce_tile: int = 64 * LANE, interpret: bool = True):
    """d_k = <x, Y[k]>;  x:(N,), Y:(K,N) -> (K,), single fused pass."""
    (N,) = x.shape
    tile = min(reduce_tile, _lane_ceil(N))
    xp, _ = _pad_to(x, tile, axis=0)
    Yp, _ = _pad_to(Y, tile, axis=1)
    parts = _vo.multi_dot_partial(xp, Yp, reduce_tile=tile,
                                  interpret=interpret)
    return jnp.sum(parts, axis=1)


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def blockdiag_spmv(A: jnp.ndarray, x: jnp.ndarray, *,
                   batch_tile: int = 4 * LANE, interpret: bool = True):
    """AoS API: A:(nb,b,b), x:(nb,b) -> y:(nb,b)."""
    nb, b, _ = A.shape
    tile = _batch_tile(nb, batch_tile)
    Asoa = jnp.transpose(A, (1, 2, 0))
    xsoa = jnp.transpose(x, (1, 0))
    Ap, _ = _pad_to(Asoa, tile, axis=2)
    xp, _ = _pad_to(xsoa, tile, axis=1)
    y = _sp.blockdiag_spmv_soa(Ap, xp, batch_tile=tile, interpret=interpret)
    return jnp.transpose(y[:, :nb], (1, 0))


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def blockdiag_spmv_soa(A: jnp.ndarray, x: jnp.ndarray, *,
                       batch_tile: int = 4 * LANE, interpret: bool = True):
    """SoA API: A:(b,b,NB), x:(b,NB) -> y:(b,NB); pads NB to the bundle
    tile (zero-padded systems produce zeros, sliced off)."""
    b, _, nb = A.shape
    tile = _batch_tile(nb, batch_tile)
    Ap, _ = _pad_to(A, tile, axis=2)
    xp, _ = _pad_to(x, tile, axis=1)
    y = _sp.blockdiag_spmv_soa(Ap, xp, batch_tile=tile, interpret=interpret)
    return y[:, :nb]


# ---------------------------------------------------------------------------
# Fused ensemble-Newton ops (SoA layout, batch on the lane axis)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret",
                                             "negate"))
def newton_residual_soa(z: jnp.ndarray, fval: jnp.ndarray,
                        psi: jnp.ndarray, gamma: jnp.ndarray, *,
                        batch_tile: int = 4 * LANE, interpret: bool = True,
                        negate: bool = False):
    """Fused g = z - gamma*f - psi (``negate=True`` -> -g, the Newton
    rhs); z/f/psi (n, NB), gamma (NB,), any NB (padded inside)."""
    n, nb = z.shape
    tile = _batch_tile(nb, batch_tile)
    zp, _ = _pad_to(z, tile, axis=1)
    fp, _ = _pad_to(fval, tile, axis=1)
    pp, _ = _pad_to(psi, tile, axis=1)
    gp, _ = _pad_to(gamma, tile, axis=0)
    g = _nw.newton_residual(zp, fp, pp, gp, batch_tile=tile,
                            interpret=interpret, negate=negate)
    return g[:, :nb]


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def masked_update_wrms_soa(z: jnp.ndarray, dz: jnp.ndarray, w: jnp.ndarray,
                           mask: jnp.ndarray, *,
                           batch_tile: int = 4 * LANE,
                           interpret: bool = True):
    """Fused masked z += dz and per-system WRMS of dz: z/dz/w (n, NB),
    mask (NB,) -> (z_new, dn); padded systems report dn = 0."""
    n, nb = z.shape
    tile = _batch_tile(nb, batch_tile)
    zp, _ = _pad_to(z, tile, axis=1)
    dp, _ = _pad_to(dz, tile, axis=1)
    wp, _ = _pad_to(w, tile, axis=1)
    mp, _ = _pad_to(mask.astype(z.dtype), tile, axis=0)
    z_new, dn = _nw.masked_update_wrms(zp, dp, wp, mp, batch_tile=tile,
                                       interpret=interpret)
    return z_new[:, :nb], dn[:nb]


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def history_rescale_soa(W: jnp.ndarray, Z: jnp.ndarray,
                        active: jnp.ndarray, *,
                        batch_tile: int = 4 * LANE,
                        interpret: bool = True):
    """Masked Lagrange history rebuild: W (q1,q1,NB), Z (q1,n,NB),
    active (NB,) -> Z_new; padded systems are inactive (Z copied)."""
    q1, _, nb = W.shape
    tile = _batch_tile(nb, batch_tile)
    Wp, _ = _pad_to(W, tile, axis=2)
    Zp, _ = _pad_to(Z, tile, axis=2)
    ap, _ = _pad_to(active.astype(Z.dtype), tile, axis=0)
    Zn = _nw.history_rescale(Wp, Zp, ap, batch_tile=tile,
                             interpret=interpret)
    return Zn[:, :, :nb]


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def wrms_soa(v: jnp.ndarray, w: jnp.ndarray, *,
             batch_tile: int = 4 * LANE, interpret: bool = True):
    """Per-system WRMS over the state axis: v/w (n, NB) -> (NB,)."""
    n, nb = v.shape
    tile = _batch_tile(nb, batch_tile)
    vp, _ = _pad_to(v, tile, axis=1)
    wp, _ = _pad_to(w, tile, axis=1)
    return _nw.wrms_soa(vp, wp, batch_tile=tile,
                        interpret=interpret)[:nb]


# ---------------------------------------------------------------------------
# Sparse ops (static shared patterns, passed as hashable tuples)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("indptr", "indices",
                                             "block_elems", "interpret"))
def csr_spmv(data: jnp.ndarray, x: jnp.ndarray, *, indptr: tuple,
             indices: tuple, block_elems: int = 8 * LANE,
             interpret: bool = True):
    """y = A @ x for CSR A with a STATIC pattern: data:(nnz,), x:(ncol,).

    The pattern is ELL-ized at trace time (host numpy on the static
    tuples): kmax = max row length, padded slots get zero data and
    column 0, rows ride the lane axis.  ``indptr``/``indices`` must be
    hashable tuples — they key the jit cache, one compile per pattern,
    exactly the SUNMATRIX_CUSPARSE store-the-pattern-once economics.
    """
    import numpy as np
    ip = np.asarray(indptr)
    ci = np.asarray(indices, np.int32)
    n_rows = len(ip) - 1
    row_len = np.diff(ip)
    kmax = max(1, int(row_len.max()) if n_rows else 1)
    src = np.zeros((n_rows, kmax), np.int32)
    valid = np.zeros((n_rows, kmax), bool)
    for i in range(n_rows):
        s, e = int(ip[i]), int(ip[i + 1])
        src[i, : e - s] = np.arange(s, e)
        valid[i, : e - s] = True
    cols = np.where(valid, ci[src] if len(ci) else 0, 0).astype(np.int32)
    data_ell = jnp.where(jnp.asarray(valid), data[jnp.asarray(src)], 0.0)
    tile = min(block_elems, _lane_ceil(n_rows))
    d_t, _ = _pad_to(data_ell.T, tile, axis=1)       # (kmax, NR)
    c_t, _ = _pad_to(jnp.asarray(cols.T), tile, axis=1)
    xp, _ = _pad_to(x, LANE, axis=0)
    y = _sx.csr_spmv_ell(d_t, c_t, xp, row_tile=tile, interpret=interpret)
    return y[:n_rows]


@functools.partial(jax.jit, static_argnames=("brows", "bcols", "nblk",
                                             "batch_tile", "interpret"))
def bsr_spmv_soa(values: jnp.ndarray, x: jnp.ndarray, *, brows: tuple,
                 bcols: tuple, nblk: int, batch_tile: int = 4 * LANE,
                 interpret: bool = True):
    """Ensemble shared-pattern BSR SpMV: values (nnzb, b, b, NB),
    x (nblk, b, NB) -> y (nblk, b, NB); pads the system batch NB to the
    bundle tile (zero-padded systems produce zeros, sliced off)."""
    nnzb, b, _, nb = values.shape
    tile = _batch_tile(nb, batch_tile)
    Vp, _ = _pad_to(values, tile, axis=3)
    xp, _ = _pad_to(x, tile, axis=2)
    y = _sx.bsr_spmv_soa(Vp, xp, brows=tuple(brows), bcols=tuple(bcols),
                         nblk=nblk, batch_tile=tile, interpret=interpret)
    return y[:, :, :nb]


@functools.partial(jax.jit, static_argnames=("brows", "bcols", "nblk",
                                             "batch_tile", "interpret"))
def bsr_diag_inverse_soa(values: jnp.ndarray, *, brows: tuple,
                         bcols: tuple, nblk: int,
                         batch_tile: int = 4 * LANE,
                         interpret: bool = True):
    """Invert every diagonal block of the shared pattern — the
    block-Jacobi psetup: values (nnzb, b, b, NB) -> (b, b, nblk*NB),
    flattened batch block-major (block I of system s at I*NB + s).

    No new kernel: the diagonal-block positions are static, so this is
    a trace-time gather plus the existing Gauss-Jordan inverse kernel
    over the flattened nblk*NB batch.
    """
    nnzb, b, _, NB = values.shape
    diag_idx = []
    for I in range(nblk):
        hits = [e for e, (i, j) in enumerate(zip(brows, bcols))
                if i == I and j == I]
        if not hits:
            raise ValueError(f"pattern lacks diagonal block ({I},{I})")
        diag_idx.append(hits[0])
    D = values[jnp.asarray(diag_idx)]                # (nblk, b, b, NB)
    Dsoa = jnp.transpose(D, (1, 2, 0, 3)).reshape(b, b, nblk * NB)
    return block_inverse_soa(Dsoa, batch_tile=batch_tile,
                             interpret=interpret)
