"""Pure-jnp oracles for every Pallas kernel (the ref.py contract).

Each function must be the semantic ground truth the kernels are tested
against with assert_allclose over shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_solve_ref(A: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Batched solve, AoS layout A:(nb,b,b), r:(nb,b) -> (nb,b)."""
    return jnp.linalg.solve(A, r[..., None])[..., 0]


def block_solve_soa_ref(A: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """SoA layout A:(b,b,NB), r:(b,NB) -> x:(b,NB)."""
    Aaos = jnp.transpose(A, (2, 0, 1))
    raos = jnp.transpose(r, (1, 0))
    x = jnp.linalg.solve(Aaos, raos[..., None])[..., 0]
    return jnp.transpose(x, (1, 0))


def linear_combination_ref(coeffs: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """Z = sum_k c_k X[k];  X:(K,N), coeffs:(K,) -> (N,)."""
    return jnp.einsum("k,kn->n", coeffs, X)


def scale_add_multi_ref(coeffs: jnp.ndarray, x: jnp.ndarray,
                        Y: jnp.ndarray) -> jnp.ndarray:
    """Z[k] = c_k x + Y[k];  x:(N,), Y:(K,N) -> (K,N)."""
    return coeffs[:, None] * x[None, :] + Y


def wrms_partial_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """sum((x*w)^2) over the whole array -> scalar."""
    return jnp.sum((x * w) ** 2)


def wrms_mask_partial_ref(x: jnp.ndarray, w: jnp.ndarray,
                          m: jnp.ndarray) -> jnp.ndarray:
    """sum((x*w*m)^2) over the whole array -> scalar."""
    return jnp.sum((x * w * m) ** 2)


def dot_prod_multi_ref(x: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
    """d_k = <x, Y[k]>;  x:(N,), Y:(K,N) -> (K,)."""
    return Y @ x


def dot_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.vdot(x, y)


def blockdiag_spmv_soa_ref(A: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y = blockdiag(A) @ x in SoA; A:(b,b,NB), x:(b,NB) -> y:(b,NB)."""
    return jnp.einsum("ijn,jn->in", A, x)


def block_inverse_soa_ref(A: jnp.ndarray) -> jnp.ndarray:
    """Per-block inverse in SoA; A:(b,b,NB) -> A^{-1}:(b,b,NB)."""
    Ainv = jnp.linalg.inv(jnp.transpose(A, (2, 0, 1)))
    return jnp.transpose(Ainv, (1, 2, 0))


def newton_residual_soa_ref(z: jnp.ndarray, fval: jnp.ndarray,
                            psi: jnp.ndarray, gamma: jnp.ndarray,
                            negate: bool = False) -> jnp.ndarray:
    """g = z - gamma*f - psi in SoA; z/f/psi (n, NB), gamma (NB,).
    ``negate=True`` returns -g (the Newton rhs); the sign flip is
    applied to the computed g so both variants round identically."""
    g = z - gamma[None, :] * fval - psi
    return -g if negate else g


def masked_update_wrms_soa_ref(z: jnp.ndarray, dz: jnp.ndarray,
                               w: jnp.ndarray, mask: jnp.ndarray):
    """(z_new, dn): z_new = where(mask, z+dz, z); dn = per-system WRMS
    of dz (over ALL systems, masked or not); SoA (n, NB) / mask (NB,)."""
    z_new = jnp.where(mask[None, :] != 0, z + dz, z)
    t = dz * w
    return z_new, jnp.sqrt(jnp.mean(t * t, axis=0))


def history_rescale_soa_ref(W: jnp.ndarray, Z: jnp.ndarray,
                            active: jnp.ndarray) -> jnp.ndarray:
    """Z_new[j,k,s] = sum_i W[j,i,s] Z[i,k,s] where active[s], else
    Z[j,k,s];  W (q1,q1,NB), Z (q1,n,NB), active (NB,).

    The contraction is evaluated as the AoS einsum on transposed views
    (exact layout changes XLA folds into the contraction) so the jnp
    backend reproduces the pre-SoA integrator's accumulation order
    bitwise — a reformulated sum reassociates and breaks the
    bitwise-trajectory pin (tests/test_soa_carry.py).
    """
    Waos = jnp.transpose(W, (2, 0, 1))
    Zaos = jnp.transpose(Z, (2, 0, 1))
    R = jnp.transpose(jnp.einsum("sji,sik->sjk", Waos, Zaos), (1, 2, 0))
    return jnp.where(active[None, None, :] != 0, R, Z)


def wrms_soa_ref(v: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Per-system WRMS over the state axis: v/w (n, NB) -> (NB,)."""
    t = v * w
    return jnp.sqrt(jnp.mean(t * t, axis=0))


def csr_spmv_ref(data: jnp.ndarray, x: jnp.ndarray, indptr,
                 indices) -> jnp.ndarray:
    """y = A @ x for CSR A with static (indptr, indices); data:(nnz,)."""
    import numpy as np
    ip = np.asarray(indptr)
    n = len(ip) - 1
    seg = jnp.asarray(np.repeat(np.arange(n), np.diff(ip)))
    cols = jnp.asarray(np.asarray(indices, np.int32))
    return jax.ops.segment_sum(data * x[cols], seg, num_segments=n)


def bsr_spmv_soa_ref(values: jnp.ndarray, x: jnp.ndarray, brows, bcols,
                     nblk: int) -> jnp.ndarray:
    """Shared-pattern ensemble BSR SpMV oracle: values (nnzb, b, b, NB),
    x (nblk, b, NB) -> y (nblk, b, NB)."""
    bc = jnp.asarray(bcols)
    contrib = jnp.einsum("eijn,ejn->ein", values, x[bc])
    return jax.ops.segment_sum(contrib, jnp.asarray(brows),
                               num_segments=nblk)


def bsr_diag_inverse_soa_ref(values: jnp.ndarray, brows, bcols,
                             nblk: int) -> jnp.ndarray:
    """Inverse of every diagonal block of the shared pattern:
    values (nnzb, b, b, NB) -> (b, b, nblk*NB), block (I, sys) ordered
    with the block index major (matches the op's flattened SoA batch)."""
    diag_idx = []
    for I in range(nblk):
        hits = [e for e, (i, j) in enumerate(zip(brows, bcols))
                if i == I and j == I]
        assert hits, f"pattern lacks diagonal block ({I},{I})"
        diag_idx.append(hits[0])
    D = values[jnp.asarray(diag_idx)]                # (nblk, b, b, NB)
    Dinv = jnp.linalg.inv(jnp.transpose(D, (0, 3, 1, 2)))
    b = values.shape[1]
    return jnp.transpose(Dinv, (2, 3, 0, 1)).reshape(b, b, -1)
