"""Pallas TPU kernel: batched small-block linear solve (cuSolver batchQR analog).

Solves nb independent b-by-b systems A_j x_j = r_j — the submodel
use-case Newton solve with the Fig.-1 block-diagonal Jacobian.

TPU-native layout (DESIGN.md §2 hardware adaptation): the GPU batched-QR
assigns one block per thread-block; on TPU we use a *structure-of-arrays*
layout with the **batch on the lane dimension**:

    A : (b, b, NB)   — A[i, j, :] is the (i,j) entry of every block
    r : (b, NB)

so every elimination operation is an elementwise vector op across 128
lanes (VPU), and the b^2 loop structure is fully unrolled at trace time
(b is static and small — the paper's 3x3 chemistry blocks, up to ~16).
The elimination sequence is *identical for every block* — the TPU
expression of the paper's shared-sparsity/shared-factorization-structure
point (the symbolic offline-generated Gauss-Jordan of ref. [21]).

No pivoting: Newton matrices M = I - gamma*J of chemical-kinetics blocks
are strongly diagonally dominant for acceptable gamma (same assumption
as the paper's embedded symbolic solver).  A diagonal-scaling variant is
exposed for robustness.  ``ref.py`` holds the pure-jnp oracle.

Two elimination kernels, selected by block size:

* ``b <= UNROLL_MAX_B`` — the fully-unrolled form above: every block
  entry is its own live lane-vector (b^2 of them), which is the fastest
  shape while they all fit in vector registers;
* ``b > UNROLL_MAX_B``  — a **row-tiled** elimination: the b^2 live
  vectors of the unrolled form spill registers at b=16 (256 vectors per
  tile — the BENCH_ensemble.json regression this replaces), so the
  augmented system instead lives in ONE ``(b, b+1, TN)`` VMEM-resident
  accumulator and each of the b pivot steps is a handful of whole-array
  VPU ops (normalize pivot row, mask it out of the factor column, one
  rank-1 update).  ``ops.py`` additionally shrinks the bundle tile with
  b^2 so the accumulator stays inside a fixed VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128

# largest block size the fully-unrolled kernels handle before register
# pressure wins over unrolling (b^2 live lane-vectors; 64 at b=8 is
# fine, 256 at b=16 spills — measured in BENCH_ensemble.json)
UNROLL_MAX_B = 8


def _gj_kernel(a_ref, r_ref, x_ref, *, b: int, scale_rows: bool):
    """Gauss-Jordan elimination, unrolled over the (static) block size.

    a_ref: (b, b, TN) VMEM tile;  r_ref: (b, TN);  x_ref: (b, TN) out.
    """
    # load rows into registers (lists of (TN,) vectors — fully unrolled)
    A = [[a_ref[i, j, :] for j in range(b)] for i in range(b)]
    r = [r_ref[i, :] for i in range(b)]

    if scale_rows:
        for i in range(b):
            m = jnp.maximum(
                functools.reduce(jnp.maximum,
                                 [jnp.abs(A[i][j]) for j in range(b)]),
                1e-30)
            inv = 1.0 / m
            A[i] = [A[i][j] * inv for j in range(b)]
            r[i] = r[i] * inv

    for k in range(b):
        piv = A[k][k]
        inv_piv = 1.0 / piv
        # normalize pivot row
        A[k] = [A[k][j] * inv_piv for j in range(b)]
        r[k] = r[k] * inv_piv
        # eliminate column k from every other row
        for i in range(b):
            if i == k:
                continue
            f = A[i][k]
            A[i] = [A[i][j] - f * A[k][j] for j in range(b)]
            r[i] = r[i] - f * r[k]

    for i in range(b):
        x_ref[i, :] = r[i]


def _gj_inverse_kernel(a_ref, x_ref, *, b: int, scale_rows: bool):
    """Gauss-Jordan inversion: eliminate [A | I] -> [I | A^{-1}].

    a_ref: (b, b, TN) VMEM tile; x_ref: (b, b, TN) out = A^{-1} in the
    same SoA layout.  Same unrolled shared-structure elimination as
    :func:`_gj_kernel` with the b-column identity as the right-hand side
    — this is the lsetup product of the batched-BDF ensemble pipeline
    (factor once here, then every Newton iteration is one spmv).
    """
    A = [[a_ref[i, j, :] for j in range(b)] for i in range(b)]
    one = jnp.ones_like(A[0][0])
    zero = jnp.zeros_like(A[0][0])
    R = [[one if i == j else zero for j in range(b)] for i in range(b)]

    if scale_rows:
        for i in range(b):
            m = jnp.maximum(
                functools.reduce(jnp.maximum,
                                 [jnp.abs(A[i][j]) for j in range(b)]),
                1e-30)
            inv = 1.0 / m
            A[i] = [A[i][j] * inv for j in range(b)]
            R[i] = [R[i][j] * inv for j in range(b)]

    for k in range(b):
        inv_piv = 1.0 / A[k][k]
        A[k] = [A[k][j] * inv_piv for j in range(b)]
        R[k] = [R[k][j] * inv_piv for j in range(b)]
        for i in range(b):
            if i == k:
                continue
            fkt = A[i][k]
            A[i] = [A[i][j] - fkt * A[k][j] for j in range(b)]
            R[i] = [R[i][j] - fkt * R[k][j] for j in range(b)]

    for i in range(b):
        for j in range(b):
            x_ref[i, j, :] = R[i][j]


def _gj_tiled_kernel(a_ref, r_ref, x_ref, *, b: int, scale_rows: bool):
    """Row-tiled Gauss-Jordan for large blocks (b > UNROLL_MAX_B).

    The augmented system [A | r] lives in one (b, b+1, TN) accumulator;
    each pivot step is three whole-array ops instead of b^2 per-entry
    register updates, so the live set is O(b*TN) (one pivot row + one
    factor column) rather than O(b^2*TN).  The accumulator is held as a
    functional value: Mosaic materializes it in VMEM either way, and
    under interpret emulation an explicit ``scratch_shapes`` ref
    measures 3-7x slower (every ref op round-trips the interpreter's
    state), which would mask the very regression this kernel fixes.
    """
    a = a_ref[...]
    rr = r_ref[...]
    if scale_rows:
        inv_m = 1.0 / jnp.maximum(jnp.max(jnp.abs(a), axis=1), 1e-30)
        a = a * inv_m[:, None, :]
        rr = rr * inv_m
    S = jnp.concatenate([a, rr[:, None, :]], axis=1)    # (b, b+1, TN)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)
    for k in range(b):
        inv = 1.0 / S[k, k, :]
        rowk = S[k, :, :] * inv[None, :]                # normalized pivot row
        f = jnp.where(row_ids == k, 0.0, S[:, k, :])    # factor column
        S = S - f[:, None, :] * rowk[None, :, :]        # rank-1 eliminate
        S = S.at[k, :, :].set(rowk)
    x_ref[...] = S[:, b, :]


def _gj_tiled_inverse_kernel(a_ref, x_ref, *, b: int, scale_rows: bool):
    """Row-tiled in-place Gauss-Jordan inversion (b > UNROLL_MAX_B).

    Classic in-place GJ: the inverse replaces A in the same (b, b, TN)
    accumulator (no [A | I] augmentation, so the working set is half the
    unrolled kernel's).  Per pivot step: normalized pivot row with the
    pivot slot replaced by 1/piv, rank-1 update, then column k is
    rewritten as -f/piv (the in-place bookkeeping for the identity
    columns the augmented form would carry).
    """
    a = a_ref[...]
    if scale_rows:
        inv_m = 1.0 / jnp.maximum(jnp.max(jnp.abs(a), axis=1), 1e-30)
        a = a * inv_m[:, None, :]                       # (b, b, TN)
    S = a
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)
    for k in range(b):
        inv = 1.0 / S[k, k, :]
        rowk = jnp.where(row_ids == k, inv[None, :],
                         S[k, :, :] * inv[None, :])     # (b, TN), col-indexed
        f = jnp.where(row_ids == k, 0.0, S[:, k, :])
        S = S - f[:, None, :] * rowk[None, :, :]
        S = S.at[k, :, :].set(rowk)
        S = S.at[:, k, :].set(jnp.where(row_ids == k, inv[None, :],
                                        -f * inv[None, :]))
    if scale_rows:
        # rows of A were pre-scaled by D = diag(inv_m):  S = (D A)^-1
        # = A^-1 D^-1, so post-scale the COLUMNS to recover A^-1
        S = S * inv_m[None, :, :]
    x_ref[...] = S


def block_inverse_soa(A: jnp.ndarray, *, batch_tile: int = 4 * LANE,
                      interpret: bool = True,
                      scale_rows: bool = True) -> jnp.ndarray:
    """Invert every block: A:(b,b,NB) -> Ainv:(b,b,NB), NB % tile == 0
    (ops.py pads).  b <= UNROLL_MAX_B uses the unrolled [A | I] kernel
    (2*b*b*tile VMEM words); larger b the row-tiled IN-PLACE inversion
    (b*b*tile words) — ops.py additionally shrinks the tile with b^2 to
    hold a fixed VMEM budget."""
    b, b2, NB = A.shape
    assert b == b2
    assert NB % batch_tile == 0, (NB, batch_tile)
    grid = (NB // batch_tile,)
    kern = _gj_inverse_kernel if b <= UNROLL_MAX_B \
        else _gj_tiled_inverse_kernel
    kernel = functools.partial(kern, b=b, scale_rows=scale_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((b, b, batch_tile), lambda g: (0, 0, g))],
        out_specs=pl.BlockSpec((b, b, batch_tile), lambda g: (0, 0, g)),
        out_shape=jax.ShapeDtypeStruct((b, b, NB), A.dtype),
        interpret=interpret,
    )(A)


def block_solve_soa(A: jnp.ndarray, r: jnp.ndarray, *,
                    batch_tile: int = 4 * LANE, interpret: bool = True,
                    scale_rows: bool = True) -> jnp.ndarray:
    """Solve with SoA layout A:(b,b,NB), r:(b,NB) -> x:(b,NB).

    NB must be a multiple of ``batch_tile`` (ops.py pads).  Each grid
    program owns a (b, b, batch_tile) VMEM tile: for b=8, tile=512 that
    is 8*8*512*4B = 128 KiB of A — comfortably inside ~16 MiB VMEM.
    b > UNROLL_MAX_B routes to the row-tiled kernel, whose (b, b+1,
    tile) augmented accumulator ops.py keeps under GJ_VMEM_BYTES by
    shrinking the tile with b^2.
    """
    b, b2, NB = A.shape
    assert b == b2 and r.shape == (b, NB)
    assert NB % batch_tile == 0, (NB, batch_tile)
    grid = (NB // batch_tile,)
    kern = _gj_kernel if b <= UNROLL_MAX_B else _gj_tiled_kernel
    kernel = functools.partial(kern, b=b, scale_rows=scale_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, b, batch_tile), lambda g: (0, 0, g)),
            pl.BlockSpec((b, batch_tile), lambda g: (0, g)),
        ],
        out_specs=pl.BlockSpec((b, batch_tile), lambda g: (0, g)),
        out_shape=jax.ShapeDtypeStruct((b, NB), A.dtype),
        interpret=interpret,
    )(A, r)
