"""Pallas TPU kernel: batched small-block linear solve (cuSolver batchQR analog).

Solves nb independent b-by-b systems A_j x_j = r_j — the submodel
use-case Newton solve with the Fig.-1 block-diagonal Jacobian.

TPU-native layout (DESIGN.md §2 hardware adaptation): the GPU batched-QR
assigns one block per thread-block; on TPU we use a *structure-of-arrays*
layout with the **batch on the lane dimension**:

    A : (b, b, NB)   — A[i, j, :] is the (i,j) entry of every block
    r : (b, NB)

so every elimination operation is an elementwise vector op across 128
lanes (VPU), and the b^2 loop structure is fully unrolled at trace time
(b is static and small — the paper's 3x3 chemistry blocks, up to ~16).
The elimination sequence is *identical for every block* — the TPU
expression of the paper's shared-sparsity/shared-factorization-structure
point (the symbolic offline-generated Gauss-Jordan of ref. [21]).

No pivoting: Newton matrices M = I - gamma*J of chemical-kinetics blocks
are strongly diagonally dominant for acceptable gamma (same assumption
as the paper's embedded symbolic solver).  A diagonal-scaling variant is
exposed for robustness.  ``ref.py`` holds the pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _gj_kernel(a_ref, r_ref, x_ref, *, b: int, scale_rows: bool):
    """Gauss-Jordan elimination, unrolled over the (static) block size.

    a_ref: (b, b, TN) VMEM tile;  r_ref: (b, TN);  x_ref: (b, TN) out.
    """
    # load rows into registers (lists of (TN,) vectors — fully unrolled)
    A = [[a_ref[i, j, :] for j in range(b)] for i in range(b)]
    r = [r_ref[i, :] for i in range(b)]

    if scale_rows:
        for i in range(b):
            m = jnp.maximum(
                functools.reduce(jnp.maximum,
                                 [jnp.abs(A[i][j]) for j in range(b)]),
                1e-30)
            inv = 1.0 / m
            A[i] = [A[i][j] * inv for j in range(b)]
            r[i] = r[i] * inv

    for k in range(b):
        piv = A[k][k]
        inv_piv = 1.0 / piv
        # normalize pivot row
        A[k] = [A[k][j] * inv_piv for j in range(b)]
        r[k] = r[k] * inv_piv
        # eliminate column k from every other row
        for i in range(b):
            if i == k:
                continue
            f = A[i][k]
            A[i] = [A[i][j] - f * A[k][j] for j in range(b)]
            r[i] = r[i] - f * r[k]

    for i in range(b):
        x_ref[i, :] = r[i]


def _gj_inverse_kernel(a_ref, x_ref, *, b: int, scale_rows: bool):
    """Gauss-Jordan inversion: eliminate [A | I] -> [I | A^{-1}].

    a_ref: (b, b, TN) VMEM tile; x_ref: (b, b, TN) out = A^{-1} in the
    same SoA layout.  Same unrolled shared-structure elimination as
    :func:`_gj_kernel` with the b-column identity as the right-hand side
    — this is the lsetup product of the batched-BDF ensemble pipeline
    (factor once here, then every Newton iteration is one spmv).
    """
    A = [[a_ref[i, j, :] for j in range(b)] for i in range(b)]
    one = jnp.ones_like(A[0][0])
    zero = jnp.zeros_like(A[0][0])
    R = [[one if i == j else zero for j in range(b)] for i in range(b)]

    if scale_rows:
        for i in range(b):
            m = jnp.maximum(
                functools.reduce(jnp.maximum,
                                 [jnp.abs(A[i][j]) for j in range(b)]),
                1e-30)
            inv = 1.0 / m
            A[i] = [A[i][j] * inv for j in range(b)]
            R[i] = [R[i][j] * inv for j in range(b)]

    for k in range(b):
        inv_piv = 1.0 / A[k][k]
        A[k] = [A[k][j] * inv_piv for j in range(b)]
        R[k] = [R[k][j] * inv_piv for j in range(b)]
        for i in range(b):
            if i == k:
                continue
            fkt = A[i][k]
            A[i] = [A[i][j] - fkt * A[k][j] for j in range(b)]
            R[i] = [R[i][j] - fkt * R[k][j] for j in range(b)]

    for i in range(b):
        for j in range(b):
            x_ref[i, j, :] = R[i][j]


def block_inverse_soa(A: jnp.ndarray, *, batch_tile: int = 4 * LANE,
                      interpret: bool = True,
                      scale_rows: bool = True) -> jnp.ndarray:
    """Invert every block: A:(b,b,NB) -> Ainv:(b,b,NB), NB % tile == 0
    (ops.py pads).  VMEM per program is 2*b*b*tile words (A + R), so the
    default tile keeps even b=16 f64 at ~2 MiB."""
    b, b2, NB = A.shape
    assert b == b2
    assert NB % batch_tile == 0, (NB, batch_tile)
    grid = (NB // batch_tile,)
    kernel = functools.partial(_gj_inverse_kernel, b=b,
                               scale_rows=scale_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((b, b, batch_tile), lambda g: (0, 0, g))],
        out_specs=pl.BlockSpec((b, b, batch_tile), lambda g: (0, 0, g)),
        out_shape=jax.ShapeDtypeStruct((b, b, NB), A.dtype),
        interpret=interpret,
    )(A)


def block_solve_soa(A: jnp.ndarray, r: jnp.ndarray, *,
                    batch_tile: int = 4 * LANE, interpret: bool = True,
                    scale_rows: bool = True) -> jnp.ndarray:
    """Solve with SoA layout A:(b,b,NB), r:(b,NB) -> x:(b,NB).

    NB must be a multiple of ``batch_tile`` (ops.py pads).  Each grid
    program owns a (b, b, batch_tile) VMEM tile: for b=8, tile=512 that
    is 8*8*512*4B = 128 KiB of A — comfortably inside ~16 MiB VMEM.
    """
    b, b2, NB = A.shape
    assert b == b2 and r.shape == (b, NB)
    assert NB % batch_tile == 0, (NB, batch_tile)
    grid = (NB // batch_tile,)
    kernel = functools.partial(_gj_kernel, b=b, scale_rows=scale_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, b, batch_tile), lambda g: (0, 0, g)),
            pl.BlockSpec((b, batch_tile), lambda g: (0, g)),
        ],
        out_specs=pl.BlockSpec((b, batch_tile), lambda g: (0, g)),
        out_shape=jax.ShapeDtypeStruct((b, NB), A.dtype),
        interpret=interpret,
    )(A, r)
