"""Pallas TPU kernels for the hot N_Vector operations.

The paper's Fig. 9/Table 1 show that for time integration the dominant
cost is the *vector* operations (``N_VLinearSum`` above all), which are
memory-bandwidth-bound.  Two kernels:

* :func:`linear_combination` — Z = sum_k c_k X_k in ONE pass over the
  operands.  ARKODE evaluates y_new = y + h*sum b_i k_i (s+1 operands);
  done with pairwise N_VLinearSum this reads/writes 3 vectors per pair
  (2(s+1) vector reads + s+1 writes); fused it is s+1 reads + 1 write —
  the SUNDIALS "fused vector operation" realized as a single VMEM-tiled
  kernel.  Streaming op -> ThreadDirect/GridStride policy sets the tile.

* :func:`scale_add_multi` — Z_k = c_k * x + Y_k for all k in one pass:
  x is read ONCE from HBM instead of once per destination
  (N_VScaleAddMulti, the fused op ARKODE uses to form stage RHS data).

* :func:`wrms_partial` / :func:`dot_partial` — BlockReduce-policy
  reductions: each grid program reduces its tile to one partial in a
  (grid,) output; the final (tiny) sum happens in XLA.  One pass, no
  intermediate (x*w)^2 vector materialized in HBM.

* :func:`wrms_mask_partial` — masked WRMS partials (N_VWrmsNormMask):
  the mask multiply happens in-register, never in HBM.

* :func:`multi_dot_partial` — d_k = <x, Y_k> partials for all k with x
  read once (N_VDotProdMulti, the fused Gram-Schmidt reduction).

Layouts are 1-D with LANE*k tiles; ops.py pads ragged tails.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _lincomb_kernel(c_ref, x_ref, z_ref, *, K: int):
    """z tile = sum_k c[k] * x[k] tile.  x_ref: (K, TN), z_ref: (TN,)."""
    acc = c_ref[0] * x_ref[0, :]
    for k in range(1, K):
        acc = acc + c_ref[k] * x_ref[k, :]
    z_ref[:] = acc


def linear_combination(coeffs: jnp.ndarray, X: jnp.ndarray, *,
                       block_elems: int = 8 * LANE,
                       interpret: bool = True) -> jnp.ndarray:
    """Fused Z = sum_k coeffs[k] * X[k];  X: (K, N) with N % tile == 0."""
    K, N = X.shape
    assert N % block_elems == 0, (N, block_elems)
    grid = (N // block_elems,)
    kernel = functools.partial(_lincomb_kernel, K=K)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K,), lambda g: (0,)),           # coeffs: whole
            pl.BlockSpec((K, block_elems), lambda g: (0, g)),
        ],
        out_specs=pl.BlockSpec((block_elems,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((N,), X.dtype),
        interpret=interpret,
    )(coeffs, X)


def _scale_add_multi_kernel(c_ref, x_ref, y_ref, z_ref, *, K: int):
    """z[k] tile = c[k] * x tile + y[k] tile.  x read once per tile."""
    xt = x_ref[:]
    for k in range(K):
        z_ref[k, :] = c_ref[k] * xt + y_ref[k, :]


def scale_add_multi(coeffs: jnp.ndarray, x: jnp.ndarray, Y: jnp.ndarray, *,
                    block_elems: int = 8 * LANE,
                    interpret: bool = True) -> jnp.ndarray:
    """Fused Z[k] = coeffs[k]*x + Y[k];  x:(N,), Y:(K,N), N % tile == 0."""
    K, N = Y.shape
    assert x.shape == (N,) and N % block_elems == 0, (x.shape, Y.shape)
    grid = (N // block_elems,)
    kernel = functools.partial(_scale_add_multi_kernel, K=K)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K,), lambda g: (0,)),
            pl.BlockSpec((block_elems,), lambda g: (g,)),
            pl.BlockSpec((K, block_elems), lambda g: (0, g)),
        ],
        out_specs=pl.BlockSpec((K, block_elems), lambda g: (0, g)),
        out_shape=jax.ShapeDtypeStruct((K, N), Y.dtype),
        interpret=interpret,
    )(coeffs, x, Y)


def _wrms_kernel(x_ref, w_ref, out_ref):
    xw = x_ref[:] * w_ref[:]
    out_ref[0] = jnp.sum(xw * xw)


def wrms_partial(x: jnp.ndarray, w: jnp.ndarray, *,
                 reduce_tile: int = 64 * LANE,
                 interpret: bool = True) -> jnp.ndarray:
    """Per-tile partials of sum((x*w)^2); final sum done by the caller."""
    (N,) = x.shape
    assert N % reduce_tile == 0
    grid = (N // reduce_tile,)
    return pl.pallas_call(
        _wrms_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((reduce_tile,), lambda g: (g,)),
            pl.BlockSpec((reduce_tile,), lambda g: (g,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((grid[0],), x.dtype),
        interpret=interpret,
    )(x, w)


def _wrms_mask_kernel(x_ref, w_ref, m_ref, out_ref):
    xwm = x_ref[:] * w_ref[:] * m_ref[:]
    out_ref[0] = jnp.sum(xwm * xwm)


def wrms_mask_partial(x: jnp.ndarray, w: jnp.ndarray, m: jnp.ndarray, *,
                      reduce_tile: int = 64 * LANE,
                      interpret: bool = True) -> jnp.ndarray:
    """Per-tile partials of sum((x*w*m)^2) (N_VWrmsNormMask reduction)."""
    (N,) = x.shape
    assert N % reduce_tile == 0
    grid = (N // reduce_tile,)
    return pl.pallas_call(
        _wrms_mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((reduce_tile,), lambda g: (g,)),
            pl.BlockSpec((reduce_tile,), lambda g: (g,)),
            pl.BlockSpec((reduce_tile,), lambda g: (g,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((grid[0],), x.dtype),
        interpret=interpret,
    )(x, w, m)


def _dot_kernel(x_ref, y_ref, out_ref):
    out_ref[0] = jnp.sum(x_ref[:] * y_ref[:])


def dot_partial(x: jnp.ndarray, y: jnp.ndarray, *,
                reduce_tile: int = 64 * LANE,
                interpret: bool = True) -> jnp.ndarray:
    (N,) = x.shape
    assert N % reduce_tile == 0
    grid = (N // reduce_tile,)
    return pl.pallas_call(
        _dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((reduce_tile,), lambda g: (g,)),
            pl.BlockSpec((reduce_tile,), lambda g: (g,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((grid[0],), x.dtype),
        interpret=interpret,
    )(x, y)


def _multidot_kernel(x_ref, y_ref, out_ref, *, K: int):
    """out[k, 0] = <x tile, Y[k] tile>.  x is read once for all K dots."""
    xt = x_ref[:]
    for k in range(K):
        out_ref[k, 0] = jnp.sum(xt * y_ref[k, :])


def multi_dot_partial(x: jnp.ndarray, Y: jnp.ndarray, *,
                      reduce_tile: int = 64 * LANE,
                      interpret: bool = True) -> jnp.ndarray:
    """Per-tile partials of d_k = <x, Y[k]> -> (K, grid) (N_VDotProdMulti)."""
    K, N = Y.shape
    assert x.shape == (N,) and N % reduce_tile == 0
    grid = (N // reduce_tile,)
    kernel = functools.partial(_multidot_kernel, K=K)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((reduce_tile,), lambda g: (g,)),
            pl.BlockSpec((K, reduce_tile), lambda g: (0, g)),
        ],
        out_specs=pl.BlockSpec((K, 1), lambda g: (0, g)),
        out_shape=jax.ShapeDtypeStruct((K, grid[0]), x.dtype),
        interpret=interpret,
    )(x, Y)
