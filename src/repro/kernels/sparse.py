"""Pallas TPU kernels: sparse SpMV (CSR + ensemble shared-pattern BSR).

The paper pairs its GPU vectors with ``SUNMATRIX_CUSPARSE`` — a CSR
matrix plus a *low-storage block-diagonal / block-sparse* variant where
every block shares one sparsity pattern and the index arrays are stored
once.  The TPU adaptation keeps that shared-pattern idea and pushes it
further: because the pattern is shared across the whole ensemble it is
**static at trace time**, so the kernels below carry no index arrays at
all — the sparsity structure is compiled into the instruction stream
(the "symbolic offline-generated" elimination idea of the batched GJ
kernels, applied to SpMV):

* :func:`csr_spmv_ell` — scalar CSR SpMV in ELL form: rows ride the
  128-wide lane axis, the (static) max-row-length loop is unrolled, and
  each step is one gather + one fused multiply-add across lanes.
* :func:`bsr_spmv_soa` — ensemble block-sparse SpMV, SoA layout with
  the **system batch on the lane axis** (same convention as
  block_solve.py): values ``(nnzb, b, b, NB)``, x ``(nblk, b, NB)``.
  The block pattern (``brows``/``bcols``) is a static tuple, so the
  e-loop over nonzero blocks and the b^2 inner products are fully
  unrolled elementwise vector ops — no gather at all.

The per-block diagonal inverse (``bsr_block_jacobi_inverse_soa``) needs
no new kernel: ops.py statically gathers the diagonal blocks and reuses
the Gauss-Jordan inverse kernel from block_solve.py over the flattened
``nblk * NB`` batch.

``ref.py`` holds the pure-jnp oracles both kernels are parity-tested
against.  The CSR kernel's lane gather (``jnp.take`` from a VMEM-
resident x) is the one op that leans on newer Mosaic gather support; on
this container everything runs with ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _csr_ell_kernel(d_ref, c_ref, x_ref, y_ref, *, kmax: int):
    """ELL-form CSR SpMV tile: d/c are (kmax, TR) with rows on lanes,
    x is fully VMEM-resident, y is (TR,).  Padded slots carry d == 0
    (and col 0), so they contribute nothing."""
    xv = x_ref[:]
    acc = d_ref[0, :] * jnp.take(xv, c_ref[0, :], axis=0)
    for k in range(1, kmax):
        acc = acc + d_ref[k, :] * jnp.take(xv, c_ref[k, :], axis=0)
    y_ref[:] = acc


def csr_spmv_ell(data_ell: jnp.ndarray, cols_ell: jnp.ndarray,
                 x: jnp.ndarray, *, row_tile: int = 8 * LANE,
                 interpret: bool = True) -> jnp.ndarray:
    """y = A @ x with A in lane-major ELL form.

    data_ell : (kmax, NR) — NR lane-padded row count, NR % row_tile == 0
    cols_ell : (kmax, NR) int32 column of each slot (0 where padded)
    x        : (NC,) the full input vector (stays resident per program)
    """
    kmax, NR = data_ell.shape
    assert cols_ell.shape == (kmax, NR)
    assert NR % row_tile == 0, (NR, row_tile)
    (NC,) = x.shape
    grid = (NR // row_tile,)
    kernel = functools.partial(_csr_ell_kernel, kmax=kmax)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((kmax, row_tile), lambda g: (0, g)),
            pl.BlockSpec((kmax, row_tile), lambda g: (0, g)),
            pl.BlockSpec((NC,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((row_tile,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((NR,), data_ell.dtype),
        interpret=interpret,
    )(data_ell, cols_ell, x)


def _bsr_spmv_kernel(v_ref, x_ref, y_ref, *, b: int, nblk: int,
                     brows: tuple, bcols: tuple):
    """Shared-pattern block-sparse SpMV, fully unrolled.

    v_ref: (nnzb, b, b, TN);  x_ref/y_ref: (nblk, b, TN).  The pattern
    (brows, bcols) is static, so every accumulation below is a plain
    lane-wide FMA — the TPU expression of storing the index arrays once
    for all ensemble members (here: zero times, they are compiled in).
    """
    acc = [[None] * b for _ in range(nblk)]
    for e, (bi, bj) in enumerate(zip(brows, bcols)):
        for i in range(b):
            contrib = v_ref[e, i, 0, :] * x_ref[bj, 0, :]
            for j in range(1, b):
                contrib = contrib + v_ref[e, i, j, :] * x_ref[bj, j, :]
            if acc[bi][i] is None:
                acc[bi][i] = contrib
            else:
                acc[bi][i] = acc[bi][i] + contrib
    zeros = jnp.zeros_like(x_ref[0, 0, :])
    for bi in range(nblk):
        for i in range(b):
            y_ref[bi, i, :] = zeros if acc[bi][i] is None else acc[bi][i]


def bsr_spmv_soa(values: jnp.ndarray, x: jnp.ndarray, *, brows: tuple,
                 bcols: tuple, nblk: int, batch_tile: int = 4 * LANE,
                 interpret: bool = True) -> jnp.ndarray:
    """y_I = sum_{e: brows[e]=I} A_e @ x_{bcols[e]} for every ensemble
    member: values (nnzb, b, b, NB), x (nblk, b, NB) -> y (nblk, b, NB).
    NB % batch_tile == 0 (ops.py pads; zero-padded systems yield zeros).
    """
    nnzb, b, b2, NB = values.shape
    assert b == b2 and x.shape == (nblk, b, NB)
    assert len(brows) == len(bcols) == nnzb
    assert NB % batch_tile == 0, (NB, batch_tile)
    grid = (NB // batch_tile,)
    kernel = functools.partial(_bsr_spmv_kernel, b=b, nblk=nblk,
                               brows=tuple(brows), bcols=tuple(bcols))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nnzb, b, b, batch_tile), lambda g: (0, 0, 0, g)),
            pl.BlockSpec((nblk, b, batch_tile), lambda g: (0, 0, g)),
        ],
        out_specs=pl.BlockSpec((nblk, b, batch_tile), lambda g: (0, 0, g)),
        out_shape=jax.ShapeDtypeStruct((nblk, b, NB), values.dtype),
        interpret=interpret,
    )(values, x)
