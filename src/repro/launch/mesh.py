"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_ensemble_mesh(n_devices: int = 0):
    """1-D ('systems',) mesh for the ensemble subsystem: the batch of
    independent ODE systems is sharded across all (or the first
    ``n_devices``) local devices; each device advances its shard with no
    collectives (the paper's one-integrator-per-stream bundles)."""
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    if n_devices:
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), ("systems",))
