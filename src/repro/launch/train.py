"""End-to-end training driver (example application + production launcher).

Runs real steps on whatever devices exist: on this CPU container use a
smoke config; on a TPU pod slice pass --arch <full> --mesh production.
Features exercised: sharded state, data pipeline, checkpoint/restart
(resume is automatic), straggler/fault bookkeeping, metrics logging.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b-smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import pipeline
from repro.models import Model, ParallelCtx
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.train import checkpoint as ckpt
from repro.train import fault
from repro.train import step as tstep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b-smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "production", "production-multi"])
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "gradflow"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    model = Model(cfg)
    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh.endswith("multi"))
    pctx = ParallelCtx(mesh=mesh, cst=shd.make_cst(mesh),
                       moe_impl="ep" if (cfg.is_moe and mesh is not None)
                       else "dense",
                       dp_axes=tuple(a for a in ("pod", "data")
                                     if mesh and a in mesh.axis_names) or
                       ("data",))
    ocfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                             warmup_steps=max(args.steps // 20, 1))

    # --- init or resume ---
    start_step = 0
    state = tstep.init_state(model, jax.random.PRNGKey(args.seed), ocfg)
    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            print(f"resuming from checkpoint step {last}")
            state = ckpt.restore(
                jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
                args.ckpt_dir, last)
            start_step = last

    dcfg = pipeline.DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch, seed=args.seed)
    train_step = jax.jit(tstep.make_train_step(
        model, pctx, ocfg, microbatches=args.microbatches),
        donate_argnums=(0,))

    if args.optimizer == "gradflow":
        from repro.optim import gradflow
        gf = gradflow.GradFlowConfig(tau=0.5, max_steps=10)

    mon = fault.HeartbeatMonitor(n_workers=jax.process_count())
    hist = []
    t_ckpt = 0.0
    for step_i, batch_np in zip(range(start_step, args.steps),
                                pipeline.batches(dcfg, start_step)):
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.time()
        if args.optimizer == "gradflow":
            lf = lambda p: model.loss(p, batch, pctx)
            new_params, st = gradflow.step(lf, state.params, gf)
            state = state._replace(params=new_params)
            metrics = {"loss": model.loss(state.params, batch, pctx),
                       "ode_steps": st.steps}
        else:
            state, metrics = train_step(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.time() - t0
        mon.heartbeat(jax.process_index())
        mon.record_step(jax.process_index(), dt)
        hist.append(metrics["loss"])
        print(f"step {step_i:5d} loss={metrics['loss']:.4f} "
              f"dt={dt*1e3:.1f}ms " +
              " ".join(f"{k}={v:.3g}" for k, v in metrics.items()
                       if k != "loss"), flush=True)
        if args.ckpt_dir and (step_i + 1) % args.ckpt_every == 0:
            tc = time.time()
            ckpt.save(state, args.ckpt_dir, step_i + 1)
            ckpt.prune(args.ckpt_dir, keep=3)
            t_ckpt = time.time() - tc
    if args.ckpt_dir:
        ckpt.save(state, args.ckpt_dir, args.steps)
    print(f"done. first loss={hist[0]:.4f} last={hist[-1]:.4f} "
          f"(ckpt write {t_ckpt:.2f}s)")
    return hist


if __name__ == "__main__":
    main()
