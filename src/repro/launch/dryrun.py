import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with ZERO real allocation (abstract params
via ShapeDtypeStructs):
  * compiled = jit(step).lower(...).compile()  on the production mesh,
  * compiled.memory_analysis()  -> per-chip bytes (does it fit HBM?),
  * compiled.cost_analysis()    -> per-chip FLOPs / bytes accessed,
  * collective operand bytes parsed from the post-SPMD HLO,
  * the three roofline terms (analysis/roofline.py).

Results land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json;
EXPERIMENTS.md §Dry-run / §Roofline are generated from them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import configs
from repro.analysis import roofline as rf
from repro.models import Model, SHAPES, ParallelCtx
from repro.parallel import sharding as shd
from repro.serve.decode import make_serve_step
from repro.train import step as tstep
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "targets": ("batch", "seq"),
    "vis_embeds": ("batch", "seq", "embed"),
    "frames": ("batch", "seq", "embed"),
    "enc_out": ("batch", "seq", "embed"),
    "pos": (),
}


def batch_shardings(batch_specs, mesh):
    return {k: NamedSharding(mesh, shd.spec_for(v.shape, BATCH_AXES[k],
                                                mesh, shd.ACT_RULES))
            for k, v in batch_specs.items()}


def make_pctx(cfg, mesh, shape_kind: str, profile: str = "tp_fsdp"):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    # weights-stationary EP when the experts divide model*data (§Perf);
    # REPRO_EP_MULTI=0 forces single-axis EP for A/B comparisons
    ep_axis = "model"
    if (cfg.is_moe and os.environ.get("REPRO_EP_MULTI", "1") != "0"
            and cfg.n_experts % (sizes["model"] *
                                 sizes.get("data", 1)) == 0):
        ep_axis = ("model", "data")
    _, act_rules = shd.PROFILES[profile]
    return ParallelCtx(
        mesh=mesh, cst=shd.make_cst(mesh, act_rules),
        moe_impl="ep" if cfg.is_moe else "dense",
        dp_axes=dp, ep_axis=ep_axis,
        moe_token_layout="split" if shape_kind != "decode" else "replicated")


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               ocfg=None, compile_it: bool = True,
               profile: str = "tp_fsdp",
               microbatches: int = 1) -> Dict[str, Any]:
    from repro.optim import adamw
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    pctx = make_pctx(cfg, mesh, shape.kind, profile)
    param_rules, act_rules = shd.PROFILES[profile]
    ocfg = ocfg or adamw.AdamWConfig(
        moment_dtype=jnp.bfloat16 if cfg.is_moe else jnp.float32)
    chips = mesh.devices.size
    t0 = time.time()

    batch_specs = model.input_specs(shape)
    b_shd = {k: NamedSharding(mesh, shd.spec_for(v.shape, BATCH_AXES[k],
                                                 mesh, act_rules))
             for k, v in batch_specs.items()}

    if shape.kind in ("train", "prefill"):
        if shape.kind == "train":
            astate = tstep.abstract_state(model, ocfg)
            saxes = tstep.state_axes(model)
            s_shd = shd.param_shardings(astate, saxes, mesh, param_rules)
            step_fn = tstep.make_train_step(
                model, pctx, ocfg, microbatches=microbatches,
                grad_shardings=None if os.environ.get("REPRO_GRAD_RS",
                                                      "1") == "0"
                else s_shd.params)
            # out_shardings pinned to the (donated) input state shardings:
            # otherwise the partitioner may choose different output
            # layouts and emit full resharding all-gathers of the biggest
            # tensors in the module (§Perf 'out-shardings' finding).
            jfn = jax.jit(step_fn, in_shardings=(s_shd, b_shd),
                          out_shardings=(s_shd, None),
                          donate_argnums=(0,))
            lowered = jfn.lower(astate, batch_specs)
        else:
            # prefill = forward loss only (inference prefill cost proxy)
            fwd = lambda params, batch: model.loss(params, batch, pctx)
            aparams = model.abstract_params()
            p_shd = shd.param_shardings(aparams, model.param_axes(), mesh,
                                        param_rules)
            jfn = jax.jit(fwd, in_shardings=(p_shd, b_shd))
            lowered = jfn.lower(aparams, batch_specs)
    else:  # decode
        serve_fn = make_serve_step(model, pctx)
        aparams = model.abstract_params()
        p_shd = shd.param_shardings(aparams, model.param_axes(), mesh,
                                    param_rules)
        cspecs = model.cache_specs(shape.global_batch, shape.seq_len)
        caxes = shd.cache_axes_like(cspecs, cfg)
        c_shd = shd.param_shardings(cspecs, caxes, mesh,
                                    shd.cache_rules_from(act_rules))
        jfn = jax.jit(serve_fn, in_shardings=(p_shd, b_shd, c_shd),
                      donate_argnums=(2,))
        lowered = jfn.lower(aparams, batch_specs, cspecs)

    t_lower = time.time() - t0
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "lower_s": t_lower, "ok": False}
    if not compile_it:
        result["ok"] = True
        return result

    t0 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = time.time() - t0

    # ---- memory ----
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not support it
        mem["error"] = str(e)
    result["memory"] = mem

    # ---- cost ----
    cost = {}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        for k in ("flops", "bytes accessed", "transcendentals",
                  "utilization operand 0 {}"):
            if k in ca:
                cost[k.replace(" ", "_")] = float(ca[k])
        # keep all bytes-accessed subkeys summed implicitly via main key
    except Exception as e:
        cost["error"] = str(e)
    result["cost"] = cost

    # ---- loop-aware HLO cost walk (flops / bytes / collectives) ----
    from repro.analysis import hlocost
    try:
        hlo = compiled.as_text()
        hc = hlocost.analyze(hlo, chips)
    except Exception as e:
        hc = {"error": str(e), "flops": 0.0, "bytes": 0.0, "coll_total": 0.0}
    result["hlocost"] = hc

    # ---- roofline (per-chip terms from the loop-aware walk; XLA's own
    # cost_analysis is kept in result["cost"] as a cross-check — it
    # counts while bodies once, so it undercounts scan-over-layers) ----
    row = rf.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=hc.get("flops", 0.0),
        hlo_bytes=hc.get("bytes", 0.0),
        coll_bytes=float(hc.get("coll_total", 0.0)),
        model_flops=rf.model_flops_for(cfg, shape),
        coll_detail={k: v for k, v in hc.items() if k.startswith("coll")},
        memory_per_chip=mem or None,
    ).finalize()
    result["roofline"] = row.to_dict()
    result["ok"] = True
    return result


def run_cells(archs, shapes, meshes, out_dir: str, compile_it=True,
              profile: str = "tp_fsdp", microbatches: int = 1,
              tag: str = ""):
    os.makedirs(out_dir, exist_ok=True)
    mesh_objs = {}
    if "single" in meshes:
        mesh_objs["single"] = make_production_mesh(multi_pod=False)
    if "multi" in meshes:
        mesh_objs["multi"] = make_production_mesh(multi_pod=True)
    summary = []
    for arch in archs:
        for shape_name in shapes:
            if not configs.cell_is_runnable(arch, shape_name):
                row = {"arch": arch, "shape": shape_name, "mesh": "-",
                       "skipped": "long_500k needs sub-quadratic attention",
                       "ok": True}
                summary.append(row)
                _write(out_dir, arch, shape_name, "skipped", row, tag)
                print(f"SKIP {arch} {shape_name} (full attention)")
                continue
            for mesh_name, mesh in mesh_objs.items():
                label = f"{arch} {shape_name} {mesh_name}"
                try:
                    res = lower_cell(arch, shape_name, mesh, mesh_name,
                                     compile_it=compile_it, profile=profile,
                                     microbatches=microbatches)
                    res["profile"] = profile
                    res["microbatches"] = microbatches
                    summary.append(res)
                    _write(out_dir, arch, shape_name, mesh_name, res, tag)
                    rl = res.get("roofline", {})
                    print(f"OK   {label}: lower={res['lower_s']:.1f}s "
                          f"compile={res.get('compile_s', 0):.1f}s "
                          f"bottleneck={rl.get('bottleneck', '?')}",
                          flush=True)
                except Exception as e:
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "ok": False,
                           "error": f"{type(e).__name__}: {e}"}
                    summary.append(res)
                    _write(out_dir, arch, shape_name, mesh_name, res, tag)
                    print(f"FAIL {label}: {e}", flush=True)
    return summary


def _write(out_dir, arch, shape, mesh, res, tag: str = ""):
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh}{suffix}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--profile", default="tp_fsdp",
                    choices=["tp_fsdp", "fsdp"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="",
                    help="suffix for result filenames (perf iterations)")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    summary = run_cells(archs, shapes, meshes, args.out,
                        compile_it=not args.no_compile,
                        profile=args.profile, microbatches=args.microbatches,
                        tag=args.tag)
    n_ok = sum(1 for r in summary if r.get("ok"))
    print(f"\n{n_ok}/{len(summary)} cells OK")
    if n_ok < len(summary):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
