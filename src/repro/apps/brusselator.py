"""The paper's demonstration problem (§7): 1D advection-reaction brusselator.

    u_t = -c u_x + A - (w+1) u + v u^2
    v_t = -c v_x + w u - v u^2
    w_t = -c w_x + (B - w)/eps - w u

First-order upwind on a periodic uniform mesh; IMEX integration with
ARKODE's ARK3(2)4L[2]SA: advection explicit, stiff reactions implicit.

Two nonlinear-solver configurations, exactly the paper's:

* **task-local** — Newton where the linear solve is the batched 3x3
  block-diagonal direct solve (reactions are point-local, so the stage
  Jacobian is Fig. 1's block-diagonal matrix).  The ONLY global
  communication in the solve is the WRMS norm reduction — the paper's
  "requires no parallel communication" property.  The 3x3 solves use
  the vectorized Gauss-Jordan (= the paper's offline-generated symbolic
  solver [21]) or the Pallas block-solve kernel.

* **global** — Newton + GMRES on the full system with the block solve
  as preconditioner (the paper's fallback for pre-custom-solver
  SUNDIALS versions).

On a mesh, the state shards over the 'data' axis; the upwind stencil's
``jnp.roll`` becomes a halo exchange (collective-permute) — the direct
analog of the paper's GPU-GPU NVLink point-to-point transfers.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import arkode, butcher, direct, krylov, matrix
from repro.core.arkode import ODEOptions
from repro.core.policies import ExecPolicy, XLA_FUSED
from repro.configs.brusselator import BrusselatorConfig


def initial_state(cfg: BrusselatorConfig) -> jnp.ndarray:
    """y: (nx, 3) with the gaussian-bump initial condition."""
    x = jnp.linspace(0.0, cfg.b_domain, cfg.nx, endpoint=False)
    mu, sigma = cfg.b_domain / 2.0, cfg.b_domain / 4.0
    p = cfg.alpha * jnp.exp(-((x - mu) ** 2) / (2 * sigma ** 2))
    u = cfg.A + p
    v = cfg.B / cfg.A + p
    w = 3.0 + p
    return jnp.stack([u, v, w], axis=1)


def advection_rhs(cfg: BrusselatorConfig, cst: Callable = lambda x, a: x):
    dx = cfg.b_domain / cfg.nx

    def fe(t, y):
        # first-order upwind (c > 0), periodic: the roll is the halo
        # exchange (collective-permute under sharding)
        ym1 = jnp.roll(y, 1, axis=0)
        return -(cfg.c / dx) * (y - ym1)

    return fe


def reaction_rhs(cfg: BrusselatorConfig):
    def fi(t, y):
        u, v, w = y[:, 0], y[:, 1], y[:, 2]
        du = cfg.A - (w + 1.0) * u + v * u * u
        dv = w * u - v * u * u
        dw = (cfg.B - w) / cfg.eps - w * u
        return jnp.stack([du, dv, dw], axis=1)

    return fi


def reaction_jacobian(cfg: BrusselatorConfig):
    """Analytic per-point 3x3 Jacobian blocks: (nx, 3, 3)."""

    def jac(t, y):
        u, v, w = y[:, 0], y[:, 1], y[:, 2]
        z = jnp.zeros_like(u)
        row0 = jnp.stack([-(w + 1.0) + 2.0 * v * u, u * u, -u], axis=1)
        row1 = jnp.stack([w - 2.0 * v * u, -u * u, u], axis=1)
        row2 = jnp.stack([-w, z, -1.0 / cfg.eps - u], axis=1)
        return jnp.stack([row0, row1, row2], axis=1)

    return jac


def task_local_lin_solver(cfg: BrusselatorConfig,
                          policy: ExecPolicy = XLA_FUSED):
    """(t, z, gamma, rhs) -> dz via batched 3x3 block elimination."""
    jac = reaction_jacobian(cfg)

    def solve(t, z, gamma, rhs):
        J = jac(t, z)                               # (nx, 3, 3)
        M = matrix.bd_scale_addi(-gamma, matrix.BlockDiagMatrix(J))
        return direct.block_solve(M, rhs, policy=policy)

    return solve


def global_gmres_lin_solver(cfg: BrusselatorConfig,
                            policy: ExecPolicy = XLA_FUSED):
    """(t, z, gamma, rhs) -> dz via GMRES with block-Jacobi preconditioner
    (the paper's 'global' configuration)."""
    fi = reaction_rhs(cfg)
    jac = reaction_jacobian(cfg)

    def solve(t, z, gamma, rhs):
        def matvec(v):
            _, jv = jax.jvp(lambda zz: fi(t, zz), (z,), (v,))
            return v - gamma * jv

        J = jac(t, z)
        M = matrix.bd_scale_addi(-gamma, matrix.BlockDiagMatrix(J))

        def precond(v):
            return direct.block_solve(M, v, policy=policy)

        dz, _ = krylov.gmres(matvec, rhs, tol=1e-4, restart=16,
                             max_restarts=2, precond=precond)
        return dz

    return solve


def integrate(cfg: BrusselatorConfig, *, t_final: Optional[float] = None,
              policy: ExecPolicy = XLA_FUSED,
              opts: Optional[ODEOptions] = None):
    """Run the IMEX integration; returns (y_final, stats)."""
    tf = t_final if t_final is not None else cfg.t_final
    y0 = initial_state(cfg)
    fe = advection_rhs(cfg)
    fi = reaction_rhs(cfg)
    if cfg.solver == "task-local":
        lin = task_local_lin_solver(cfg, policy)
    else:
        lin = global_gmres_lin_solver(cfg, policy)
    o = opts or ODEOptions(rtol=cfg.rtol, atol=cfg.atol, max_steps=100_000,
                           newton_max=6)
    return arkode.imex_integrate(fe, fi, y0, 0.0, tf, butcher.ARK324,
                                 o, lin_solver=lin)


def reference_solution(cfg: BrusselatorConfig, t_final: float,
                       n_steps: int = 20000):
    """Fine fixed-step explicit reference (expensive; small tf only)."""
    y0 = initial_state(cfg)
    fe = advection_rhs(cfg)
    fi = reaction_rhs(cfg)

    def f(t, y):
        return fe(t, y) + fi(t, y)

    return arkode.erk_fixed(f, y0, 0.0, t_final, n_steps,
                            butcher.DORMAND_PRINCE)
