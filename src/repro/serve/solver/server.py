"""The serving driver: a synchronous core with an async facade.

``SolverServer`` composes the three serving layers over the solver
stack: the :class:`~repro.serve.solver.queue.AdmissionQueue` groups
requests into shape buckets, the
:class:`~repro.serve.solver.trace_cache.TraceCache` maps each padded
bundle shape to a compiled executable, and every bundle is pumped
through the unified ``IVP.integrate`` front-end with a
:class:`~repro.core.batched.SolverSession` carry — so cold requests
and warm-start continuations mix freely in one bundle under one trace.

The **synchronous core** is :meth:`pump`: flush due bundles, execute
each, resolve its per-request futures.  Tests and benchmarks drive it
directly (deterministic, no threads); the **async facade**
(:meth:`start`/:meth:`stop`) runs the same pump on a background thread
so :meth:`submit` is a non-blocking enqueue returning a
``concurrent.futures.Future``.

Every response is a full :class:`~repro.core.ivp.Solution` restricted
to the request's lane — padded dead lanes never leak into a client's
stats — extended with the serving wall-clock split
(``timings = {"queue_wait", "compile", "execute"}``; compile is the
bundle's trace+compile cost, nonzero only for the bundle that missed
the trace cache) and the warm-start ``session`` handle for follow-up
requests.  :meth:`metrics` reports queue depth, batch occupancy
(live vs padded lanes), p50/p99 latency, and the trace-cache counters.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.batched import SolverSession
from repro.core.context import Context
from repro.core.ivp import IVP, Solution, integrate

from .queue import AdmissionQueue, Bundle, IVPRequest, RetryAfter
from .trace_cache import TraceCache, TraceKey

__all__ = ["ProblemFamily", "SolverServer", "RetryAfter"]


@dataclass(frozen=True)
class ProblemFamily:
    """A served problem class: parametric batched RHS/Jacobian.

    The callables take the bundle's stacked per-request ``params``
    pytree as a third argument (traced data, so new parameter values
    never recompile): ``f(t:(nsys,), y:(nsys,n), params) -> (nsys,n)``,
    ``jac -> (nsys,n,n)``; the optional SoA forms follow the hot-loop
    convention (``f_soa(t, y:(n,nsys), params) -> (n,nsys)``,
    ``jac_soa -> (n,n,nsys)``).  ``params=None`` families close over
    everything.
    """

    name: str
    n: int
    f: Callable
    jac: Callable
    f_soa: Optional[Callable] = None
    jac_soa: Optional[Callable] = None


@dataclass
class _CompiledBundle:
    fn: Any            # AOT-compiled (session, tf, params) -> (y, st, sess)
    compile_s: float   # trace + lower + compile wall clock
    meta: dict         # trace-time Solution fields (method, solver names,
    #                    workspace bytes) reused for every hit


class SolverServer:
    """Dynamic-batching IVP server over the ensemble solver stack."""

    def __init__(self, families, ctx: Optional[Context] = None, *,
                 method: str = "ensemble_bdf", order: int = 5,
                 lin_solver=None,
                 bucket_sizes: Optional[Tuple[int, ...]] = None,
                 max_batch: Optional[int] = None,
                 max_wait: float = 2e-3, max_depth: int = 4096,
                 cache_size: int = 32, max_steps: int = 100_000,
                 warmup_bundles: int = 16,
                 clock: Callable[[], float] = time.monotonic):
        if isinstance(families, ProblemFamily):
            families = [families]
        self.families: Dict[str, ProblemFamily] = {
            fam.name: fam for fam in families}
        if not self.families:
            raise ValueError("SolverServer needs at least one ProblemFamily")
        self.ctx = ctx if ctx is not None else Context()
        self.method = method
        self.order = order
        self.lin_solver = lin_solver
        self.max_steps = max_steps
        self.clock = clock
        self.dtype = str(jnp.asarray(0.0).dtype)
        if bucket_sizes is None:
            from .queue import bucket_sizes_from_bench
            bucket_sizes = bucket_sizes_from_bench()
        self.queue = AdmissionQueue(bucket_sizes=bucket_sizes,
                                    max_batch=max_batch,
                                    max_wait=max_wait,
                                    max_depth=max_depth,
                                    dtype=self.dtype, clock=clock)
        self.cache = TraceCache(maxsize=cache_size)
        # surface the cache counters through ctx.dispatch_report()
        self.ctx.trace_cache = self.cache
        self.warmup_bundles = int(warmup_bundles)
        self._lock = threading.Lock()       # queue admission/flush
        self._mlock = threading.Lock()      # metrics accumulators
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._latencies: List[float] = []
        self._requests = 0
        self._bundles = 0
        self._live_lanes = 0
        self._padded_lanes = 0
        self._steady_misses = 0

    # ------------------------------------------------------------------
    # submission (async facade surface)
    # ------------------------------------------------------------------

    def submit(self, family: str, y0, t0: float, tf: float, *,
               rtol: float = 1e-6, atol: float = 1e-9,
               params: Any = None, session: Any = None,
               method: Optional[str] = None) -> Future:
        """Enqueue one IVP; returns a Future resolving to its
        :class:`~repro.core.ivp.Solution` (with ``timings`` and a
        warm-start ``session``).  Raises :class:`RetryAfter` when the
        queue is at depth — resubmit after ``exc.retry_after`` seconds.
        """
        fam = self.families.get(family)
        if fam is None:
            raise ValueError(f"unknown family {family!r}; registered: "
                             f"{sorted(self.families)}")
        y0 = jnp.asarray(y0, self.dtype)
        if y0.shape != (fam.n,):
            raise ValueError(f"family {family!r} serves n={fam.n} "
                             f"systems; got y0 shape {tuple(y0.shape)}")
        if session is not None and (session.n != fam.n or
                                    session.nsys != 1):
            raise ValueError(
                f"session must be a single-lane handle for n={fam.n} "
                f"(got n={session.n}, nsys={session.nsys})")
        req = IVPRequest(family=family, y0=y0, t0=float(t0),
                         tf=float(tf), rtol=rtol, atol=atol,
                         method=method or self.method, params=params,
                         session=session, future=Future())
        with self._lock:
            self.queue.offer(req)      # may raise RetryAfter
        self._wake.set()
        return req.future

    # ------------------------------------------------------------------
    # the synchronous core
    # ------------------------------------------------------------------

    def pump(self, now: Optional[float] = None,
             flush_all: bool = False) -> int:
        """Flush due bundles and execute them; returns bundles run.
        The deterministic core — tests drive it directly."""
        with self._lock:
            bundles = self.queue.poll(now, flush_all=flush_all)
        for bundle in bundles:
            self._execute(bundle)
        return len(bundles)

    def drain(self) -> int:
        """Pump (flushing partial buckets) until the queue is empty."""
        total = 0
        while self.queue.depth:
            total += self.pump(flush_all=True)
        return total

    # ------------------------------------------------------------------
    # async facade
    # ------------------------------------------------------------------

    def start(self) -> "SolverServer":
        """Run the pump loop on a daemon thread (idempotent)."""
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self._wake.wait(timeout=0.5 * self.queue.max_wait)
                self._wake.clear()
                self.pump()
            self.pump(flush_all=True)   # don't strand queued futures

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="solver-serve-pump")
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._wake.set()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "SolverServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # bundle execution
    # ------------------------------------------------------------------

    def _assemble(self, bundle: Bundle):
        """Gather per-request lane sessions (warm handles as-is, cold
        lanes built from y0) into one SoA bundle session, pad dead
        lanes by replicating the last live lane with ``tf = t`` (a
        masked no-op from step one), and stack the params pytree."""
        lanes = []
        for req in bundle.requests:
            if req.session is not None:
                lanes.append(req.session)
            else:
                lanes.append(SolverSession.cold(req.y0[None, :], req.t0))
        npad = bundle.nsys - bundle.live
        if npad:
            lanes.extend([lanes[-1]] * npad)
        sess = SolverSession.concat(lanes)
        tf_live = [req.tf for req in bundle.requests]
        # dead lanes: tf == the replicated lane's current t -> inactive
        tfa = jnp.concatenate([
            jnp.asarray(tf_live, sess.t.dtype),
            jnp.broadcast_to(sess.t[-1], (npad,))]) if npad else \
            jnp.asarray(tf_live, sess.t.dtype)
        p0 = bundle.requests[0].params
        if p0 is None:
            if any(r.params is not None for r in bundle.requests):
                raise ValueError("mixed params/None requests in one "
                                 "family bundle")
            params = None
        else:
            stacked = [r.params for r in bundle.requests]
            stacked.extend([stacked[-1]] * npad)
            params = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(
                    [jnp.asarray(x, self.dtype) for x in xs]), *stacked)
        return sess, tfa, params

    def _compile(self, key: TraceKey, sess, tfa, params) -> _CompiledBundle:
        """Trace, lower, and AOT-compile one bundle shape (the cache
        miss path); records the compile wall clock and the trace-time
        Solution metadata reused for every subsequent hit."""
        fam = self.families[key.bucket.family]
        rtol = 10.0 ** key.bucket.tol_class[0]
        atol = 10.0 ** key.bucket.tol_class[1]
        opts = self.ctx.options(rtol=rtol, atol=atol,
                                max_steps=self.max_steps)
        method = key.bucket.method
        meta: dict = {}

        def run(sess, tfa, params):
            fb = lambda t, y: fam.f(t, y, params)
            jb = lambda t, y: fam.jac(t, y, params)
            fs = (lambda t, z: fam.f_soa(t, z, params)) \
                if fam.f_soa is not None else None
            js = (lambda t, z: fam.jac_soa(t, z, params)) \
                if fam.jac_soa is not None else None
            prob = IVP(f=fb, jac=jb, f_soa=fs, jac_soa=js,
                       y0=sess.Z[0].T)
            sol = integrate(prob, sess.t[0], tfa, method, ctx=self.ctx,
                            opts=opts, order=self.order,
                            lin_solver=self.lin_solver,
                            session=sess, return_session=True)
            # trace-time capture: these Solution fields are concrete
            # Python values (strings / host ints) even under tracing
            meta.update(method=sol.method, lin_solver=sol.lin_solver,
                        nonlin_solver=sol.nonlin_solver,
                        workspace_bytes=sol.workspace_bytes)
            return sol.y, sol.stats, sol.session

        t0 = time.perf_counter()
        compiled = jax.jit(run).lower(sess, tfa, params).compile()
        return _CompiledBundle(fn=compiled,
                               compile_s=time.perf_counter() - t0,
                               meta=dict(meta))

    def _execute(self, bundle: Bundle) -> None:
        try:
            sess, tfa, params = self._assemble(bundle)
            key = TraceKey(bucket=bundle.key, nsys=bundle.nsys,
                           policy=self.ctx.policy)
            entry, hit = self.cache.get(
                key, lambda: self._compile(key, sess, tfa, params))
            if not hit and self._bundles >= self.warmup_bundles:
                with self._mlock:
                    self._steady_misses += 1
            t0 = time.perf_counter()
            y, st, sess_out = entry.fn(sess, tfa, params)
            jax.block_until_ready(y)
            exec_s = time.perf_counter() - t0
        except Exception as exc:       # resolve, don't strand, futures
            for req in bundle.requests:
                if not req.future.set_running_or_notify_cancel():
                    continue
                req.future.set_exception(exc)
            raise
        done = self.clock()
        with self._mlock:
            self._bundles += 1
            self._requests += bundle.live
            self._live_lanes += bundle.live
            self._padded_lanes += bundle.nsys
            for req in bundle.requests:
                self._latencies.append(done - req.arrival)
            if len(self._latencies) > 100_000:
                del self._latencies[:-100_000]
        for i, req in enumerate(bundle.requests):
            sol = self._lane_solution(i, req, bundle, y, st, sess_out,
                                      entry, hit, exec_s)
            if req.future.set_running_or_notify_cancel():
                req.future.set_result(sol)

    def _lane_solution(self, i: int, req: IVPRequest, bundle: Bundle,
                       y, st, sess_out, entry: _CompiledBundle,
                       hit: bool, exec_s: float) -> Solution:
        """One request's Solution: the bundle result restricted to its
        lane (dead padded lanes never reach a client), plus the serving
        wall-clock split and the warm-start session handle."""
        lane_stats = jax.tree_util.tree_map(lambda a: a[..., i], st)
        meta = entry.meta
        timings = {"queue_wait": bundle.flushed - req.arrival,
                   "compile": 0.0 if hit else entry.compile_s,
                   "execute": exec_s}
        return Solution(
            y=y[i], t=sess_out.t[i], success=st.success[i],
            stats=lane_stats, method=meta["method"],
            lin_solver=meta["lin_solver"],
            nonlin_solver=meta["nonlin_solver"],
            nni=st.nni[i],
            nli=st.nli[i] if st.nli is not None else None,
            nsetups=st.nsetups[i] if st.nsetups is not None else None,
            workspace_bytes=meta["workspace_bytes"],
            high_water_bytes=self.ctx.memory.high_water_bytes,
            npsolves=st.npsolves[i] if st.npsolves is not None else None,
            npsetups=None,
            session=sess_out.lanes(slice(i, i + 1)),
            timings=timings)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    @staticmethod
    def _quantile(sorted_vals: List[float], q: float) -> float:
        if not sorted_vals:
            return 0.0
        idx = min(len(sorted_vals) - 1,
                  max(0, int(round(q * (len(sorted_vals) - 1)))))
        return sorted_vals[idx]

    def take_latencies(self) -> List[float]:
        """Return and clear the request-latency window (seconds) — lets
        a benchmark attribute percentiles to one load point."""
        with self._mlock:
            out, self._latencies = self._latencies, []
        return out

    def metrics(self) -> dict:
        """Serving health: queue depth, occupancy (live vs padded
        lanes), latency percentiles, trace-cache counters, and the
        zero-steady-state-recompiles audit (``steady_misses``)."""
        with self._mlock:
            lat = sorted(self._latencies)
            live, padded = self._live_lanes, self._padded_lanes
            out = {
                "queue_depth": self.queue.depth,
                "rejected": self.queue.rejected,
                "requests": self._requests,
                "bundles": self._bundles,
                "live_lanes": live,
                "padded_lanes": padded,
                "occupancy": (live / padded) if padded else 0.0,
                "latency_p50_s": self._quantile(lat, 0.50),
                "latency_p99_s": self._quantile(lat, 0.99),
                "steady_misses": self._steady_misses,
                "warmup_bundles": self.warmup_bundles,
                "trace_cache": self.cache.stats(),
            }
        return out
