"""The serving driver: a synchronous core with an async facade.

``SolverServer`` composes the three serving layers over the solver
stack: the :class:`~repro.serve.solver.queue.AdmissionQueue` groups
requests into shape buckets, the
:class:`~repro.serve.solver.trace_cache.TraceCache` maps each padded
bundle shape to a compiled executable, and every bundle is pumped
through the unified ``IVP.integrate`` front-end with a
:class:`~repro.core.batched.SolverSession` carry — so cold requests
and warm-start continuations mix freely in one bundle under one trace.

The **synchronous core** is :meth:`pump`: flush due bundles, execute
each, resolve its per-request futures.  Tests and benchmarks drive it
directly (deterministic, no threads); the **async facade**
(:meth:`start`/:meth:`stop`) runs the same pump on a background thread
so :meth:`submit` is a non-blocking enqueue returning a
``concurrent.futures.Future``.

Every response is a full :class:`~repro.core.ivp.Solution` restricted
to the request's lane — padded dead lanes never leak into a client's
stats — extended with the serving wall-clock split
(``timings = {"queue_wait", "compile", "execute"}``; compile is the
bundle's trace+compile cost, nonzero only for the bundle that missed
the trace cache) and the warm-start ``session`` handle for follow-up
requests.  :meth:`metrics` reports queue depth, batch occupancy
(live vs padded lanes), p50/p99 latency, and the trace-cache counters.
"""
from __future__ import annotations

import random
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import status as _status
from repro.core.batched import SolverSession
from repro.core.context import Context
from repro.core.ivp import IVP, Solution, integrate
from repro.core.policies import XLA_FUSED

from .queue import AdmissionQueue, Bundle, IVPRequest, RetryAfter
from .trace_cache import TraceCache, TraceKey

__all__ = ["ProblemFamily", "SolverServer", "RetryAfter",
           "SolverError", "DeadlineExceeded"]


class SolverError(RuntimeError):
    """A request's lane ended with a non-success CV_*-style retcode.

    Only the OFFENDING lane's Future fails with this — bundle-mates
    resolve normally (fault containment).  Carries the structured
    status so clients can dispatch on it:

    ``retcode``      — the integer flag (:mod:`repro.core.status`)
    ``retcode_name`` — its symbolic name (``"CONV_FAILURE"``, ...)
    ``stats``        — the lane's :class:`~repro.core.batched.
                       EnsembleStats` slice (steps, attempts, netf,
                       ncfn, ... for THIS lane)
    """

    def __init__(self, message: str, *, retcode: int = 0,
                 stats: Any = None):
        super().__init__(message)
        self.retcode = int(retcode)
        self.retcode_name = _status.retcode_name(retcode)
        self.stats = stats


class DeadlineExceeded(SolverError):
    """The request's deadline passed before its bundle executed; it was
    shed at flush time — no solver compute was spent on it."""


@dataclass(frozen=True)
class ProblemFamily:
    """A served problem class: parametric batched RHS/Jacobian.

    The callables take the bundle's stacked per-request ``params``
    pytree as a third argument (traced data, so new parameter values
    never recompile): ``f(t:(nsys,), y:(nsys,n), params) -> (nsys,n)``,
    ``jac -> (nsys,n,n)``; the optional SoA forms follow the hot-loop
    convention (``f_soa(t, y:(n,nsys), params) -> (n,nsys)``,
    ``jac_soa -> (n,n,nsys)``).  ``params=None`` families close over
    everything.
    """

    name: str
    n: int
    f: Callable
    jac: Callable
    f_soa: Optional[Callable] = None
    jac_soa: Optional[Callable] = None


@dataclass
class _CompiledBundle:
    fn: Any            # AOT-compiled (session, tf, params) -> (y, st, sess)
    compile_s: float   # trace + lower + compile wall clock
    meta: dict         # trace-time Solution fields (method, solver names,
    #                    workspace bytes) reused for every hit


#: request-latency histogram bucket upper bounds (seconds) — the
#: Prometheus exposition adds the implicit +Inf bucket
_LATENCY_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                    0.25, 0.5, 1.0, 2.5, 5.0)


class _LatencyRing:
    """Fixed-size latency sample window + lifetime histogram totals.

    Replaces the grow-then-truncate list: observations land in a
    preallocated ring (O(1) per sample, no 100k-entry spike before the
    truncate) and percentile reads copy out at most ``size`` samples.
    The Prometheus accumulators (per-bucket counts / sum / count) are
    LIFETIME totals and survive :meth:`clear` — scrapes stay monotone
    even when a benchmark drains the percentile window per load point.
    """

    def __init__(self, size: int = 8192,
                 buckets: Tuple[float, ...] = _LATENCY_BUCKETS):
        self.size = int(size)
        if self.size < 1:
            raise ValueError("latency window must hold >= 1 sample")
        self.buckets = tuple(buckets)
        self._slots = [0.0] * self.size
        self._pos = 0
        self._n = 0
        self.total = 0                  # lifetime observation count
        self.sum_s = 0.0                # lifetime latency sum
        # non-cumulative per-bucket counts, last slot = +Inf overflow
        self.bucket_counts = [0] * (len(self.buckets) + 1)

    def observe(self, v: float) -> None:
        self._slots[self._pos] = v
        self._pos = (self._pos + 1) % self.size
        self._n = min(self._n + 1, self.size)
        self.total += 1
        self.sum_s += v
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.bucket_counts[i] += 1
                break
        else:
            self.bucket_counts[-1] += 1

    @property
    def count(self) -> int:
        """Samples currently in the percentile window."""
        return self._n

    def window(self) -> List[float]:
        """The window's samples, oldest first."""
        if self._n < self.size:
            return self._slots[:self._n]
        return self._slots[self._pos:] + self._slots[:self._pos]

    def clear(self) -> List[float]:
        """Return the window and reset it (lifetime totals persist)."""
        out = self.window()
        self._pos = 0
        self._n = 0
        return out


class SolverServer:
    """Dynamic-batching IVP server over the ensemble solver stack."""

    def __init__(self, families, ctx: Optional[Context] = None, *,
                 method: str = "ensemble_bdf", order: int = 5,
                 lin_solver=None,
                 bucket_sizes: Optional[Tuple[int, ...]] = None,
                 max_batch: Optional[int] = None,
                 max_wait: float = 2e-3, max_depth: int = 4096,
                 cache_size: int = 32, max_steps: int = 100_000,
                 warmup_bundles: int = 16,
                 clock: Callable[[], float] = time.monotonic,
                 latency_window: int = 8192):
        if isinstance(families, ProblemFamily):
            families = [families]
        self.families: Dict[str, ProblemFamily] = {
            fam.name: fam for fam in families}
        if not self.families:
            raise ValueError("SolverServer needs at least one ProblemFamily")
        self.ctx = ctx if ctx is not None else Context()
        self.method = method
        self.order = order
        self.lin_solver = lin_solver
        self.max_steps = max_steps
        self.clock = clock
        self.dtype = str(jnp.asarray(0.0).dtype)
        if bucket_sizes is None:
            from .queue import bucket_sizes_from_bench
            bucket_sizes = bucket_sizes_from_bench()
        self.queue = AdmissionQueue(bucket_sizes=bucket_sizes,
                                    max_batch=max_batch,
                                    max_wait=max_wait,
                                    max_depth=max_depth,
                                    dtype=self.dtype, clock=clock,
                                    on_event=self._queue_event)
        self.cache = TraceCache(maxsize=cache_size)
        # surface the cache counters through ctx.dispatch_report()
        self.ctx.trace_cache = self.cache
        self.warmup_bundles = int(warmup_bundles)
        self._lock = threading.Lock()       # queue admission/flush
        self._mlock = threading.Lock()      # metrics accumulators
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lat = _LatencyRing(latency_window)
        self._requests = 0
        self._bundles = 0
        self._live_lanes = 0
        self._padded_lanes = 0
        self._steady_misses = 0
        # fault-containment accumulators: failed requests by reason
        # (retcode name / "deadline" / "exec_error") and bundles
        # re-pumped under the jnp oracle policy (backend fallback)
        self._failures: Dict[str, int] = {}
        self._degraded = 0
        # per-bucket throughput: (family, n, nsys) -> accumulators
        self._bucket_stats: Dict[Tuple[str, int, int], dict] = {}

    def _queue_event(self, event: str, fields: dict) -> None:
        """AdmissionQueue observability hook -> the context logger
        (rejects are WARNING — they shed client load; the rest DEBUG)."""
        log = self.ctx.logger
        if not log.enabled:
            return
        if event == "queue.reject":
            log.warning(event, **fields)
        else:
            log.debug(event, **fields)

    # ------------------------------------------------------------------
    # submission (async facade surface)
    # ------------------------------------------------------------------

    def submit(self, family: str, y0, t0: float, tf: float, *,
               rtol: float = 1e-6, atol: float = 1e-9,
               params: Any = None, session: Any = None,
               method: Optional[str] = None,
               deadline: Optional[float] = None) -> Future:
        """Enqueue one IVP; returns a Future resolving to its
        :class:`~repro.core.ivp.Solution` (with ``timings`` and a
        warm-start ``session``).  Raises :class:`RetryAfter` when the
        queue is at depth — resubmit after ``exc.retry_after`` seconds.

        ``deadline`` is a RELATIVE budget in seconds: if the request is
        still queued when its bundle flushes past ``now + deadline``,
        it is shed with :class:`DeadlineExceeded` before any compute.
        A lane that fails inside the solver resolves its Future with a
        typed :class:`SolverError` (retcode + per-lane stats) while its
        bundle-mates resolve normally.
        """
        fam = self.families.get(family)
        if fam is None:
            raise ValueError(f"unknown family {family!r}; registered: "
                             f"{sorted(self.families)}")
        y0 = jnp.asarray(y0, self.dtype)
        if y0.shape != (fam.n,):
            raise ValueError(f"family {family!r} serves n={fam.n} "
                             f"systems; got y0 shape {tuple(y0.shape)}")
        if session is not None and (session.n != fam.n or
                                    session.nsys != 1):
            raise ValueError(
                f"session must be a single-lane handle for n={fam.n} "
                f"(got n={session.n}, nsys={session.nsys})")
        abs_deadline = None
        if deadline is not None:
            if deadline <= 0:
                raise ValueError(f"deadline must be > 0 (relative "
                                 f"seconds); got {deadline!r}")
            abs_deadline = self.clock() + float(deadline)
        req = IVPRequest(family=family, y0=y0, t0=float(t0),
                         tf=float(tf), rtol=rtol, atol=atol,
                         method=method or self.method, params=params,
                         session=session, deadline=abs_deadline,
                         future=Future())
        with self._lock:
            self.queue.offer(req)      # may raise RetryAfter
        self._wake.set()
        return req.future

    def submit_with_retry(self, family: str, y0, t0: float, tf: float,
                          *, retries: int = 6, jitter: float = 0.5,
                          seed: Optional[int] = None,
                          sleep: Callable[[float], None] = time.sleep,
                          **kw) -> Future:
        """:meth:`submit` with jittered exponential backoff on
        :class:`RetryAfter`.

        The rejection's depth-proportional ``retry_after`` hint seeds
        the delay, doubled per consecutive reject and spread by up to
        ``jitter * delay`` of seeded uniform noise so a rejected cohort
        does not re-arrive in lockstep.  ``seed`` makes the jitter
        deterministic (tests/chaos); ``sleep`` is injectable for
        synchronous drivers that pump the server themselves between
        attempts.  Re-raises the final :class:`RetryAfter` once
        ``retries`` rejections have been consumed.
        """
        rng = random.Random(seed)
        for attempt in range(retries + 1):
            try:
                return self.submit(family, y0, t0, tf, **kw)
            except RetryAfter as exc:
                if attempt >= retries:
                    raise
                delay = exc.retry_after * (2.0 ** attempt)
                delay *= 1.0 + jitter * rng.random()
                sleep(delay)
        raise AssertionError("unreachable")

    # ------------------------------------------------------------------
    # the synchronous core
    # ------------------------------------------------------------------

    def pump(self, now: Optional[float] = None,
             flush_all: bool = False) -> int:
        """Flush due bundles and execute them; returns bundles run.
        The deterministic core — tests drive it directly."""
        with self._lock:
            bundles = self.queue.poll(now, flush_all=flush_all)
        if not bundles:
            return 0
        with self.ctx.profiler.region("serve.pump", cat="serve",
                                      sync=False, bundles=len(bundles)):
            for bundle in bundles:
                self._execute(bundle)
        return len(bundles)

    def drain(self) -> int:
        """Pump (flushing partial buckets) until the queue is empty."""
        total = 0
        while self.queue.depth:
            total += self.pump(flush_all=True)
        return total

    # ------------------------------------------------------------------
    # async facade
    # ------------------------------------------------------------------

    def start(self) -> "SolverServer":
        """Run the pump loop on a daemon thread (idempotent)."""
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self._wake.wait(timeout=0.5 * self.queue.max_wait)
                self._wake.clear()
                self.pump()
            self.pump(flush_all=True)   # don't strand queued futures

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="solver-serve-pump")
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._wake.set()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "SolverServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # bundle execution
    # ------------------------------------------------------------------

    def _assemble(self, bundle: Bundle):
        """Gather per-request lane sessions (warm handles as-is, cold
        lanes built from y0) into one SoA bundle session, pad dead
        lanes by replicating the last live lane with ``tf = t`` (a
        masked no-op from step one), and stack the params pytree."""
        lanes = []
        for req in bundle.requests:
            if req.session is not None:
                lanes.append(req.session)
            else:
                lanes.append(SolverSession.cold(req.y0[None, :], req.t0))
        npad = bundle.nsys - bundle.live
        if npad:
            lanes.extend([lanes[-1]] * npad)
        sess = SolverSession.concat(lanes)
        tf_live = [req.tf for req in bundle.requests]
        # dead lanes: tf == the replicated lane's current t -> inactive
        tfa = jnp.concatenate([
            jnp.asarray(tf_live, sess.t.dtype),
            jnp.broadcast_to(sess.t[-1], (npad,))]) if npad else \
            jnp.asarray(tf_live, sess.t.dtype)
        p0 = bundle.requests[0].params
        if p0 is None:
            if any(r.params is not None for r in bundle.requests):
                raise ValueError("mixed params/None requests in one "
                                 "family bundle")
            params = None
        else:
            stacked = [r.params for r in bundle.requests]
            stacked.extend([stacked[-1]] * npad)
            params = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(
                    [jnp.asarray(x, self.dtype) for x in xs]), *stacked)
        return sess, tfa, params

    def _compile(self, key: TraceKey, sess, tfa, params,
                 policy=None) -> _CompiledBundle:
        """Trace, lower, and AOT-compile one bundle shape (the cache
        miss path); records the compile wall clock and the trace-time
        Solution metadata reused for every subsequent hit.  ``policy``
        overrides the context policy (the backend-fallback path
        recompiles the bundle under the jnp oracle)."""
        fam = self.families[key.bucket.family]
        rtol = 10.0 ** key.bucket.tol_class[0]
        atol = 10.0 ** key.bucket.tol_class[1]
        pol_kw = {} if policy is None else {"policy": policy}
        opts = self.ctx.options(rtol=rtol, atol=atol,
                                max_steps=self.max_steps, **pol_kw)
        method = key.bucket.method
        meta: dict = {}

        def run(sess, tfa, params):
            fb = lambda t, y: fam.f(t, y, params)
            jb = lambda t, y: fam.jac(t, y, params)
            fs = (lambda t, z: fam.f_soa(t, z, params)) \
                if fam.f_soa is not None else None
            js = (lambda t, z: fam.jac_soa(t, z, params)) \
                if fam.jac_soa is not None else None
            prob = IVP(f=fb, jac=jb, f_soa=fs, jac_soa=js,
                       y0=sess.Z[0].T)
            sol = integrate(prob, sess.t[0], tfa, method, ctx=self.ctx,
                            opts=opts, order=self.order,
                            lin_solver=self.lin_solver,
                            session=sess, return_session=True)
            # trace-time capture: these Solution fields are concrete
            # Python values (strings / host ints) even under tracing
            meta.update(method=sol.method, lin_solver=sol.lin_solver,
                        nonlin_solver=sol.nonlin_solver,
                        workspace_bytes=sol.workspace_bytes)
            return sol.y, sol.stats, sol.session

        t0 = time.perf_counter()
        with self.ctx.profiler.region("serve.compile", cat="serve",
                                      family=key.bucket.family,
                                      nsys=key.nsys):
            compiled = jax.jit(run).lower(sess, tfa, params).compile()
        return _CompiledBundle(fn=compiled,
                               compile_s=time.perf_counter() - t0,
                               meta=dict(meta))

    def _count_failures(self, reason: str, k: int = 1) -> None:
        with self._mlock:
            self._failures[reason] = self._failures.get(reason, 0) + k

    def _run_compiled(self, entry: _CompiledBundle, sess, tfa, params):
        """The compiled-executable invocation, isolated so the chaos
        harness can wrap it (simulated executable raise) and the
        fallback path can reuse it."""
        y, st, sess_out = entry.fn(sess, tfa, params)
        jax.block_until_ready(y)
        return y, st, sess_out

    def _needs_fallback(self, y) -> bool:
        """All-NaN bundle state under a non-oracle backend: the kernel
        path itself is implicated (a single diverging system quarantines
        per-lane instead), so the bundle qualifies for the one-shot
        jnp-oracle re-pump."""
        if self.ctx.policy.backend == "jnp":
            return False
        import numpy as np

        arr = np.asarray(y)
        return arr.size > 0 and not np.isfinite(arr).any()

    def _shed_expired(self, bundle: Bundle) -> Optional[Bundle]:
        """Fail expired requests' Futures at FLUSH time (no compute is
        spent on them) and rebuild the bundle from the survivors;
        returns None when nothing is left to execute."""
        now = self.clock()
        if not any(r.deadline is not None and now >= r.deadline
                   for r in bundle.requests):
            return bundle
        live: List[IVPRequest] = []
        shed = 0
        for req in bundle.requests:
            if req.deadline is not None and now >= req.deadline:
                shed += 1
                exc = DeadlineExceeded(
                    f"deadline exceeded before execution "
                    f"(queued {now - req.arrival:.3f}s)")
                if req.future.set_running_or_notify_cancel():
                    req.future.set_exception(exc)
            else:
                live.append(req)
        self._count_failures("deadline", shed)
        log = self.ctx.logger
        if log.enabled_for("WARNING"):
            log.warning("serve.deadline_shed", family=bundle.key.family,
                        shed=shed, live=len(live))
        if not live:
            return None
        return Bundle(key=bundle.key, requests=live,
                      nsys=self.queue.pad_to(len(live)),
                      flushed=bundle.flushed)

    def _degrade(self, bundle: Bundle, sess, tfa, params, exc):
        """One-shot backend fallback: re-pump the bundle under the jnp
        oracle policy (its own TraceKey, so the degraded executable is
        cached too).  A failure HERE propagates — the fallback is not
        retried."""
        fkey = TraceKey(bucket=bundle.key, nsys=bundle.nsys,
                        policy=XLA_FUSED)
        entry, hit = self.cache.get(
            fkey,
            lambda: self._compile(fkey, sess, tfa, params,
                                  policy=XLA_FUSED))
        y, st, sess_out = self._run_compiled(entry, sess, tfa, params)
        with self._mlock:
            self._degraded += 1
        log = self.ctx.logger
        if log.enabled_for("WARNING"):
            log.warning("serve.bundle.degraded",
                        family=bundle.key.family, nsys=bundle.nsys,
                        reason=f"{type(exc).__name__}: {exc}"[:200])
        return y, st, sess_out, entry, hit

    def _execute(self, bundle: Bundle) -> None:
        prof = self.ctx.profiler
        if prof.enabled:
            # the queue stamps arrival/flushed on the SERVER clock
            # (time.monotonic by default); capture both clocks at one
            # instant so queue events can be mapped onto the profiler
            # timebase and merged into the Chrome trace
            p_anchor, s_anchor = prof.now(), self.clock()
        shed = self._shed_expired(bundle)
        if shed is None:
            return
        bundle = shed
        degraded = False
        try:
            with prof.region("serve.assemble", cat="serve", sync=False):
                sess, tfa, params = self._assemble(bundle)
            key = TraceKey(bucket=bundle.key, nsys=bundle.nsys,
                           policy=self.ctx.policy)
            entry, hit = self.cache.get(
                key, lambda: self._compile(key, sess, tfa, params))
            if not hit and self._bundles >= self.warmup_bundles:
                with self._mlock:
                    self._steady_misses += 1
            t0 = time.perf_counter()
            try:
                y, st, sess_out = self._run_compiled(entry, sess, tfa,
                                                     params)
                if self._needs_fallback(y):
                    raise RuntimeError(
                        "bundle state is entirely non-finite under "
                        f"backend {self.ctx.policy.backend!r}")
            except Exception as fallback_exc:
                y, st, sess_out, entry, hit = self._degrade(
                    bundle, sess, tfa, params, fallback_exc)
                degraded = True
            t1 = time.perf_counter()
            exec_s = t1 - t0
        except Exception as exc:       # resolve, don't strand, futures
            self._count_failures("exec_error", len(bundle.requests))
            for req in bundle.requests:
                if not req.future.set_running_or_notify_cancel():
                    continue
                req.future.set_exception(
                    exc if isinstance(exc, SolverError) else
                    SolverError(f"bundle execution failed: {exc}"))
            raise
        done = self.clock()
        bkey = (bundle.key.family, bundle.key.n, bundle.nsys)
        with self._mlock:
            self._bundles += 1
            self._requests += bundle.live
            self._live_lanes += bundle.live
            self._padded_lanes += bundle.nsys
            for req in bundle.requests:
                self._lat.observe(done - req.arrival)
            row = self._bucket_stats.setdefault(
                bkey, {"requests": 0, "bundles": 0, "exec_s": 0.0})
            row["requests"] += bundle.live
            row["bundles"] += 1
            row["exec_s"] += exec_s
        if prof.enabled:
            # per-bundle serving timeline (arrival -> flush -> compile
            # -> execute), mapped onto the profiler timebase; profiler
            # clock defaults to perf_counter, the execute stamps' base
            pmap = lambda ts: p_anchor + (ts - s_anchor)
            wait0 = pmap(min(r.arrival for r in bundle.requests))
            flush = pmap(bundle.flushed)
            args = {"family": bundle.key.family, "live": bundle.live,
                    "nsys": bundle.nsys}
            prof.add_span("serve.bundle.queue_wait", wait0, flush,
                          cat="serve", args=args)
            prof.add_span("serve.bundle.compile", flush,
                          flush + (0.0 if hit else entry.compile_s),
                          cat="serve", args={**args, "cached": hit})
            prof.add_span("serve.bundle.execute", t0, t1,
                          cat="serve", args=args)
        log = self.ctx.logger
        if log.enabled_for("INFO"):
            log.info("serve.bundle", family=bundle.key.family,
                     live=bundle.live, nsys=bundle.nsys, cached=hit,
                     compile_s=0.0 if hit else entry.compile_s,
                     exec_s=exec_s)
        # per-lane retcode inspection: only OFFENDING lanes fail (typed
        # SolverError with retcode + per-lane stats); bundle-mates
        # resolve normally — the serving face of quarantine containment
        retcodes = None
        if getattr(st, "retcodes", None) is not None:
            import numpy as np

            retcodes = np.asarray(st.retcodes)
        failed_lanes = []
        for i, req in enumerate(bundle.requests):
            rc = int(retcodes[i]) if retcodes is not None else 0
            if rc != 0:
                lane_stats = jax.tree_util.tree_map(
                    lambda a: a[..., i], st)
                exc = SolverError(
                    f"lane failed with {_status.retcode_name(rc)} "
                    f"({rc}) [{_status.SUNDIALS_FLAGS.get(rc, '?')}]",
                    retcode=rc, stats=lane_stats)
                self._count_failures(_status.retcode_name(rc))
                failed_lanes.append(i)
                if req.future.set_running_or_notify_cancel():
                    req.future.set_exception(exc)
                continue
            sol = self._lane_solution(i, req, bundle, y, st, sess_out,
                                      entry, hit, exec_s, degraded)
            if req.future.set_running_or_notify_cancel():
                req.future.set_result(sol)
        if failed_lanes and log.enabled_for("WARNING"):
            log.warning("serve.lane_failed", family=bundle.key.family,
                        failed=len(failed_lanes), live=bundle.live,
                        lanes=failed_lanes[:16])

    def _lane_solution(self, i: int, req: IVPRequest, bundle: Bundle,
                       y, st, sess_out, entry: _CompiledBundle,
                       hit: bool, exec_s: float,
                       degraded: bool = False) -> Solution:
        """One request's Solution: the bundle result restricted to its
        lane (dead padded lanes never reach a client), plus the serving
        wall-clock split and the warm-start session handle.

        ``degraded`` marks results recomputed under the jnp oracle
        after the configured backend failed (one-shot fallback)."""
        lane_stats = jax.tree_util.tree_map(lambda a: a[..., i], st)
        meta = entry.meta
        timings = {"queue_wait": bundle.flushed - req.arrival,
                   "compile": 0.0 if hit else entry.compile_s,
                   "execute": exec_s}
        rcs = getattr(st, "retcodes", None)
        oks = getattr(st, "ok", None)
        return Solution(
            y=y[i], t=sess_out.t[i], success=st.success[i],
            stats=lane_stats, method=meta["method"],
            lin_solver=meta["lin_solver"],
            nonlin_solver=meta["nonlin_solver"],
            nni=st.nni[i],
            nli=st.nli[i] if st.nli is not None else None,
            nsetups=st.nsetups[i] if st.nsetups is not None else None,
            workspace_bytes=meta["workspace_bytes"],
            high_water_bytes=self.ctx.memory.high_water_bytes,
            npsolves=st.npsolves[i] if st.npsolves is not None else None,
            npsetups=None,
            session=sess_out.lanes(slice(i, i + 1)),
            timings=timings,
            retcodes=rcs[i] if rcs is not None else None,
            ok=oks[i] if oks is not None else None,
            degraded=degraded)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    @staticmethod
    def _quantile(sorted_vals: List[float], q: float) -> float:
        if not sorted_vals:
            return 0.0
        idx = min(len(sorted_vals) - 1,
                  max(0, int(round(q * (len(sorted_vals) - 1)))))
        return sorted_vals[idx]

    def take_latencies(self) -> List[float]:
        """Return and clear the request-latency window (seconds) — lets
        a benchmark attribute percentiles to one load point.  The
        lifetime histogram accumulators behind ``metrics_prometheus()``
        are unaffected (scrapes stay monotone)."""
        with self._mlock:
            return self._lat.clear()

    def metrics(self) -> dict:
        """Serving health: queue depth, occupancy (live vs padded
        lanes), latency percentiles over the bounded sample window
        (``latency_samples`` of ``latency_observed`` lifetime
        observations), trace-cache counters, and the
        zero-steady-state-recompiles audit (``steady_misses``)."""
        with self._mlock:
            lat = sorted(self._lat.window())
            live, padded = self._live_lanes, self._padded_lanes
            out = {
                "queue_depth": self.queue.depth,
                "rejected": self.queue.rejected,
                "requests": self._requests,
                "bundles": self._bundles,
                "live_lanes": live,
                "padded_lanes": padded,
                "occupancy": (live / padded) if padded else 0.0,
                "latency_p50_s": self._quantile(lat, 0.50),
                "latency_p99_s": self._quantile(lat, 0.99),
                "latency_samples": self._lat.count,
                "latency_observed": self._lat.total,
                "steady_misses": self._steady_misses,
                "warmup_bundles": self.warmup_bundles,
                "trace_cache": self.cache.stats(),
                "failures": dict(self._failures),
                "degraded": self._degraded,
            }
        return out

    def metrics_prometheus(self) -> str:
        """The same serving health as :meth:`metrics`, rendered in
        Prometheus text exposition format, plus the context counters and
        autotune/trace-cache report (one scrape covers the serving tier
        AND the solver core).  Metric names: ``repro_serve_*`` for the
        serving tier (per-bucket throughput labeled ``{family, n,
        nsys}``), ``repro_context_*`` / ``repro_trace_cache_*`` /
        ``repro_autotune_*`` from :func:`repro.observability.metrics.
        context_metrics`."""
        from repro.observability.metrics import (MetricsRegistry,
                                                 context_metrics)
        reg = MetricsRegistry()
        m = self.metrics()
        reg.counter("repro_serve_requests",
                    "Requests served").set_cumulative(m["requests"])
        reg.counter("repro_serve_bundles",
                    "Bundles executed").set_cumulative(m["bundles"])
        reg.counter("repro_serve_rejected",
                    "Requests rejected at max queue depth"
                    ).set_cumulative(m["rejected"])
        reg.counter("repro_serve_steady_misses",
                    "Trace-cache misses after warmup"
                    ).set_cumulative(m["steady_misses"])
        fail = reg.counter(
            "repro_serve_failures",
            "Requests failed, labeled by reason (retcode name, "
            "deadline, exec_error)")
        for reason, count in sorted(m["failures"].items()):
            fail.set_cumulative(count, reason=reason)
        reg.counter("repro_serve_degraded",
                    "Bundles recomputed under the jnp oracle after a "
                    "backend failure").set_cumulative(m["degraded"])
        reg.counter("repro_serve_live_lanes",
                    "Live lanes executed").set_cumulative(m["live_lanes"])
        reg.counter("repro_serve_padded_lanes",
                    "Total lanes executed incl. padding"
                    ).set_cumulative(m["padded_lanes"])
        reg.gauge("repro_serve_queue_depth",
                  "Queued, unflushed requests").set(m["queue_depth"])
        reg.gauge("repro_serve_occupancy",
                  "Live / padded lane ratio").set(m["occupancy"])
        reg.gauge("repro_serve_latency_p50_seconds",
                  "Window median request latency"
                  ).set(m["latency_p50_s"])
        reg.gauge("repro_serve_latency_p99_seconds",
                  "Window p99 request latency").set(m["latency_p99_s"])
        reg.gauge("repro_serve_latency_samples",
                  "Samples in the percentile window"
                  ).set(m["latency_samples"])
        with self._mlock:
            hist = reg.histogram("repro_serve_latency_seconds",
                                 "Request latency (admission to result)",
                                 buckets=self._lat.buckets)
            hist.set_counts(list(self._lat.bucket_counts),
                            self._lat.sum_s, self._lat.total)
            bucket_rows = {k: dict(v)
                           for k, v in self._bucket_stats.items()}
        breq = reg.counter("repro_serve_bucket_requests",
                           "Requests served per shape bucket")
        bbun = reg.counter("repro_serve_bucket_bundles",
                           "Bundles executed per shape bucket")
        bexe = reg.counter("repro_serve_bucket_exec_seconds",
                           "Execute wall-clock per shape bucket")
        for (family, n, nsys), row in sorted(bucket_rows.items()):
            labels = {"family": family, "n": str(n), "nsys": str(nsys)}
            breq.set_cumulative(row["requests"], **labels)
            bbun.set_cumulative(row["bundles"], **labels)
            bexe.set_cumulative(row["exec_s"], **labels)
        context_metrics(reg, self.ctx)
        return reg.render()
