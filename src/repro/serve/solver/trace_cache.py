"""Shape-bucketed jit/trace cache: steady-state traffic never recompiles.

Tracing + compiling one bundle integration is orders of magnitude more
expensive than executing it (the whole BDF step loop lowers through
XLA), so the serving layer must amortize it perfectly: the admission
queue quantizes every bundle to a small fixed set of shapes
(:mod:`repro.serve.solver.queue`), and this cache maps each
:class:`TraceKey` — (bucket key, padded nsys, ExecPolicy fingerprint) —
to its compiled executable.  After the warmup window (first touch of
each key) every bundle is a hit: zero steady-state recompiles is the
acceptance bar, and the counters here are the audit trail (surfaced via
``Context.dispatch_report()['trace_cache']``).

Eviction is LRU with a bounded entry count — compiled executables pin
device memory, so a shape-churning client cannot grow the cache without
bound; an evicted key simply recompiles on next touch (counted, so the
regression gate sees thrash).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, NamedTuple, Optional, Tuple

from .queue import BucketKey


class TraceKey(NamedTuple):
    """What one compiled bundle executable is specialized on: the
    bucket key (family, n, method, tol class, dtype), the padded lane
    count, and the ExecPolicy (hashable frozen dataclass — backend,
    tiles, op overrides; a policy change is a different program)."""

    bucket: BucketKey
    nsys: int
    policy: Any


class TraceCache:
    """LRU cache of compiled bundle executables with hit/miss/evict
    accounting.

    ``get(key, builder)`` returns ``(entry, hit)``; on a miss the
    ``builder`` thunk is invoked (this is where the server lowers and
    compiles) and its result stored.  ``builder=None`` makes a miss
    raise ``KeyError`` — the inspection path.
    """

    def __init__(self, maxsize: int = 32):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = int(maxsize)
        self._entries: "OrderedDict[TraceKey, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: TraceKey) -> bool:
        return key in self._entries

    def keys(self) -> Tuple[TraceKey, ...]:
        return tuple(self._entries)

    def get(self, key: TraceKey,
            builder: Optional[Callable[[], Any]] = None):
        entry = self._entries.get(key)
        if entry is not None:
            self.hits += 1
            self._entries.move_to_end(key)
            return entry, True
        if builder is None:
            raise KeyError(key)
        self.misses += 1
        entry = builder()
        self._entries[key] = entry
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
            self.evictions += 1
        return entry, False

    def stats(self) -> dict:
        """The counters ``Context.dispatch_report()`` embeds."""
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._entries),
                "hit_rate": (self.hits / total) if total else 0.0}

    def clear(self) -> None:
        self._entries.clear()
