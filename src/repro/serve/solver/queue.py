"""Admission queue: shape-bucketed dynamic batching for IVP requests.

The batching decision is where serving throughput is won or lost (the
many-independent-ODE-systems follow-up, arXiv:2405.01713): independent
systems only amortize the per-step dispatch cost when they ride one
bundle, but a bundle is one trace — so only requests that agree on
everything the trace is specialized on may share one.  The bucket key
is exactly that specialization set:

* ``family`` + ``n`` — the RHS/Jacobian callables and the state size;
* ``method`` — the integrator the bundle runs;
* ``tol_class`` — the tolerance decade ``(floor(log10 rtol),
  floor(log10 atol))``: requests are served at their class
  representative ``10**class`` (at least as tight as asked);
* ``dtype`` — trace input dtypes.

Flush policy is the classic dynamic-batching pair: a bucket flushes
when it holds ``max_batch`` requests (full bundle) or when its oldest
request has waited ``max_wait`` seconds (latency bound).  Flushed
groups are padded up to the nearest *bucket size* — the lane-friendly
batch shapes the committed ``BENCH_ensemble.json`` sweep says are
throughput sweet spots (:func:`bucket_sizes_from_bench`) — so the
trace cache sees a tiny, fixed set of shapes no matter what sizes
traffic arrives in.

Backpressure is bounded-depth admission: when ``max_depth`` requests
are queued, :meth:`AdmissionQueue.offer` raises :class:`RetryAfter`
(carrying a suggested retry delay) instead of growing without bound —
the reject-with-retry-after contract lets clients shed load while the
queue drains at the solver's pace.
"""
from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


def tolerance_class(rtol: float, atol: float) -> Tuple[int, int]:
    """The tolerance decade a request is bucketed (and served) at:
    ``(floor(log10 rtol), floor(log10 atol))``.  Serving at
    ``10**class`` is at least as tight as the request asked for."""
    if not (0 < rtol < 1 and 0 < atol < 1):
        raise ValueError(f"tolerances must be in (0, 1); got "
                         f"rtol={rtol!r}, atol={atol!r}")
    return (int(math.floor(math.log10(rtol))),
            int(math.floor(math.log10(atol))))


class RetryAfter(RuntimeError):
    """Admission rejected: the queue is at ``max_depth``.  ``retry_after``
    (seconds) is the server's drain-rate hint; resubmit after it."""

    def __init__(self, retry_after: float, depth: int, max_depth: int):
        self.retry_after = float(retry_after)
        self.depth = depth
        self.max_depth = max_depth
        super().__init__(
            f"queue at max depth ({depth}/{max_depth}); "
            f"retry after {retry_after:.3f}s")


@dataclass
class IVPRequest:
    """One client request: integrate ``y0`` (n,) from t0 to tf.

    ``params`` is a pytree of per-system leaves (scalars or arrays
    WITHOUT a system axis) handed to the family's RHS/Jacobian as
    traced data — per-request physics without per-request traces.
    ``session`` is an optional single-lane
    :class:`~repro.core.batched.SolverSession` from a previous
    response: the warm-start continuation handle.
    ``deadline`` is an absolute timestamp on the server's clock; a
    request whose deadline has passed when its bundle flushes is shed
    (its Future fails with ``DeadlineExceeded``) BEFORE any compute is
    spent on it.
    """

    family: str
    y0: Any
    t0: float
    tf: float
    rtol: float = 1e-6
    atol: float = 1e-9
    method: str = "ensemble_bdf"
    params: Any = None
    session: Any = None
    deadline: Optional[float] = None
    # filled in by the queue / server:
    arrival: float = 0.0
    future: Any = None

    @property
    def n(self) -> int:
        return int(self.y0.shape[-1])


@dataclass(frozen=True)
class BucketKey:
    """Everything a bundle's trace is specialized on (except nsys,
    which padding quantizes separately)."""

    family: str
    n: int
    method: str
    tol_class: Tuple[int, int]
    dtype: str


def bucket_key(req: IVPRequest, dtype: str) -> BucketKey:
    return BucketKey(family=req.family, n=req.n, method=req.method,
                     tol_class=tolerance_class(req.rtol, req.atol),
                     dtype=dtype)


@dataclass
class Bundle:
    """A flushed group of same-bucket requests, to be padded to
    ``nsys`` lanes (``len(requests) <= nsys``) and executed as one
    batched integration."""

    key: BucketKey
    requests: List[IVPRequest]
    nsys: int                  # padded lane count (the bucket size)
    flushed: float             # queue-exit timestamp

    @property
    def live(self) -> int:
        return len(self.requests)

    @property
    def occupancy(self) -> float:
        return self.live / self.nsys


DEFAULT_BUCKET_SIZES = (64, 128, 256, 512)


def bucket_sizes_from_bench(path: str = "BENCH_ensemble.json",
                            max_size: int = 512,
                            fill: Tuple[int, ...] = (64, 128, 256)
                            ) -> Tuple[int, ...]:
    """Derive padded bundle sizes from the committed ensemble sweep.

    Every ``nsys`` the benchmark measured where the pallas kernels beat
    the jnp oracle (ratio >= 1) is a demonstrated sweet spot; sizes
    above ``max_size`` are dropped (a serving flush should not wait for
    32768 requests), and the small ``fill`` sizes are merged in so
    light traffic pads to tens of lanes, not hundreds.  Falls back to
    :data:`DEFAULT_BUCKET_SIZES` when the file is missing — the queue
    must admit traffic on a fresh checkout too.
    """
    sizes = set(fill)
    try:
        with open(path) as fh:
            bench = json.load(fh)
        for row in bench.get("results", []):
            ratio = (row["pallas_interpret_systems_per_sec"]
                     / row["jnp_systems_per_sec"])
            if ratio >= 1.0 and row["nsys"] <= max_size:
                sizes.add(int(row["nsys"]))
    except (OSError, ValueError, KeyError):
        return DEFAULT_BUCKET_SIZES
    return tuple(sorted(sizes))


@dataclass
class _Bucket:
    requests: List[IVPRequest] = field(default_factory=list)
    oldest: float = 0.0


class AdmissionQueue:
    """Bucketed admission with max-batch-or-max-wait flushing and
    bounded-depth backpressure.

    The queue is time-explicit: :meth:`offer` and :meth:`poll` take an
    optional ``now`` so servers (and tests) can drive it from their own
    clock; the default is ``time.monotonic``.  Thread safety is the
    owner's job (:class:`~repro.serve.solver.server.SolverServer` holds
    one lock around both).
    """

    def __init__(self, bucket_sizes: Tuple[int, ...] = DEFAULT_BUCKET_SIZES,
                 max_batch: Optional[int] = None,
                 max_wait: float = 2e-3,
                 max_depth: int = 4096,
                 dtype: str = "float64",
                 clock: Callable[[], float] = time.monotonic,
                 on_event: Optional[Callable[[str, dict], None]] = None):
        if not bucket_sizes:
            raise ValueError("need at least one bucket size")
        self.bucket_sizes = tuple(sorted(set(int(s) for s in bucket_sizes)))
        self.max_batch = int(max_batch or self.bucket_sizes[-1])
        if self.max_batch > self.bucket_sizes[-1]:
            raise ValueError(
                f"max_batch={self.max_batch} exceeds the largest bucket "
                f"size {self.bucket_sizes[-1]} — a full flush could not "
                "be padded")
        self.max_wait = float(max_wait)
        self.max_depth = int(max_depth)
        self.dtype = dtype
        self.clock = clock
        self._buckets: Dict[BucketKey, _Bucket] = {}
        self._depth = 0
        self.rejected = 0
        # drain-rate EMA (requests/sec over flushes) backing the
        # depth-proportional RetryAfter hint
        self._drain_rate = 0.0
        self._last_flush: Optional[float] = None
        #: observability hook — called as ``on_event(name, fields)`` for
        #: ``queue.admit`` / ``queue.reject`` / ``queue.flush`` (the
        #: server forwards these into its EventLogger)
        self.on_event = on_event

    def _emit(self, event: str, **fields) -> None:
        if self.on_event is not None:
            self.on_event(event, fields)

    @property
    def depth(self) -> int:
        """Total queued (not yet flushed) requests."""
        return self._depth

    def retry_hint(self, now: Optional[float] = None) -> float:
        """Backoff hint for a rejected request: the time the CURRENT
        backlog needs to drain at the measured flush rate.

        The old flat ``2.0 * max_wait`` hint was load-blind — every
        rejected client came back after the same tiny delay no matter
        how deep the queue was, so under sustained overload the whole
        rejected cohort thundering-herded back into another reject.
        Depth-proportional hints make the
        :meth:`~repro.serve.solver.server.SolverServer.submit_with_retry`
        jittered-exponential backoff converge: the deeper the backlog
        (or the slower the drain), the longer the hint.  Before any
        flush has been observed the hint falls back to backlog-in-
        flush-windows (``max_wait * depth / max_batch``).  Clamped to
        ``[max_wait, 30s]``.
        """
        del now  # reserved for an age-aware hint; EMA is time-free
        if self._drain_rate > 0.0:
            hint = self._depth / self._drain_rate
        else:
            hint = self.max_wait * (self._depth / max(self.max_batch, 1))
        return float(min(max(hint, self.max_wait), 30.0))

    def pad_to(self, count: int) -> int:
        """The bucket size a ``count``-request group is padded to: the
        smallest size that fits (groups are capped at ``max_batch``,
        which is itself capped at the largest size)."""
        for s in self.bucket_sizes:
            if count <= s:
                return s
        return self.bucket_sizes[-1]

    def offer(self, req: IVPRequest, now: Optional[float] = None) -> None:
        """Admit one request, or raise :class:`RetryAfter` when the
        queue is at ``max_depth`` (bounded backpressure)."""
        now = self.clock() if now is None else now
        if self._depth >= self.max_depth:
            self.rejected += 1
            hint = self.retry_hint(now)
            self._emit("queue.reject", depth=self._depth,
                       retry_after=hint)
            raise RetryAfter(hint, self._depth, self.max_depth)
        req.arrival = now
        key = bucket_key(req, self.dtype)
        bucket = self._buckets.get(key)
        if bucket is None:
            bucket = self._buckets[key] = _Bucket()
        if not bucket.requests:
            bucket.oldest = now
        bucket.requests.append(req)
        self._depth += 1
        self._emit("queue.admit", family=req.family, depth=self._depth)

    def poll(self, now: Optional[float] = None,
             flush_all: bool = False) -> List[Bundle]:
        """Flush every due bucket: full (``>= max_batch``) or stale
        (oldest waited ``>= max_wait``).  ``flush_all=True`` drains
        everything regardless of age (shutdown / synchronous drive)."""
        now = self.clock() if now is None else now
        bundles: List[Bundle] = []
        for key, bucket in self._buckets.items():
            while len(bucket.requests) >= self.max_batch:
                take = bucket.requests[:self.max_batch]
                bucket.requests = bucket.requests[self.max_batch:]
                self._depth -= len(take)
                bundles.append(Bundle(key=key, requests=take,
                                      nsys=self.pad_to(len(take)),
                                      flushed=now))
            if bucket.requests and (flush_all or
                                    now - bucket.oldest >= self.max_wait):
                take, bucket.requests = bucket.requests, []
                self._depth -= len(take)
                bundles.append(Bundle(key=key, requests=take,
                                      nsys=self.pad_to(len(take)),
                                      flushed=now))
            if bucket.requests:
                # remaining requests are in arrival order; the clock
                # for the next stale-flush starts at the new head
                bucket.oldest = bucket.requests[0].arrival
        if bundles:
            flushed = sum(b.live for b in bundles)
            if self._last_flush is not None and now > self._last_flush:
                inst = flushed / (now - self._last_flush)
                self._drain_rate = inst if self._drain_rate == 0.0 else \
                    0.2 * inst + 0.8 * self._drain_rate
            self._last_flush = now
        if self.on_event is not None:
            for b in bundles:
                self._emit("queue.flush", family=b.key.family,
                           live=b.live, nsys=b.nsys,
                           wait_s=now - min(r.arrival for r in b.requests))
        return bundles
