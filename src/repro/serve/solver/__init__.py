"""Dynamic-batching IVP serving front-end (the ROADMAP "heavy traffic"
tier): admission queue -> shape bucket -> trace cache -> warm-start
continuation.

Layers (one module each, composed by :class:`SolverServer`):

* :mod:`repro.serve.solver.queue` — admission and dynamic batching:
  requests are bucketed by (problem family, shape n, method, tolerance
  class, dtype) and flushed on a max-batch-or-max-wait policy, padded
  to benched bucket sizes, with bounded-depth backpressure
  (:class:`RetryAfter` instead of unbounded queue growth).
* :mod:`repro.serve.solver.trace_cache` — the shape-bucketed jit/trace
  cache keyed on (bucket shape, method, ExecPolicy fingerprint):
  steady-state traffic never recompiles; hit/miss/evict counters
  surface through ``Context.dispatch_report()``.
* :mod:`repro.serve.solver.server` — the synchronous-core,
  async-facade driver: pumps bundles through ``IVP.integrate``,
  resolves per-request futures, reports queue depth, batch occupancy,
  and p50/p99 latency.

Warm-start continuation rides :class:`repro.core.batched.SolverSession`
(exported/consumed by ``ensemble_bdf``): responses carry a session
handle, and resubmitting with it re-enters the BDF loop at the
terminal order/step instead of the cold order-1 restart.
"""
from repro.core.batched import SolverSession  # re-export: the warm-start handle

from .queue import (AdmissionQueue, Bundle, BucketKey, IVPRequest,
                    RetryAfter, bucket_key, bucket_sizes_from_bench,
                    tolerance_class)
from .server import ProblemFamily, SolverServer
from .trace_cache import TraceCache, TraceKey

__all__ = [
    "AdmissionQueue", "Bundle", "BucketKey", "IVPRequest", "RetryAfter",
    "bucket_key", "bucket_sizes_from_bench", "tolerance_class",
    "ProblemFamily", "SolverServer", "SolverSession",
    "TraceCache", "TraceKey",
]
