"""Serving: batched autoregressive decode over the Model decode_step.

``make_serve_step`` is THE unit the dry-run lowers for decode shapes:
one new token against a KV cache of seq_len.  ``generate`` drives it in
a host loop (greedy or temperature sampling) for the examples.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Model, ParallelCtx


def make_serve_step(model: Model, pctx: ParallelCtx = ParallelCtx()):
    def serve_step(params, batch, caches):
        logits, new_caches = model.decode_step(params, batch, caches, pctx)
        return logits, new_caches

    return serve_step


def sample_token(logits: jnp.ndarray, key, temperature: float = 0.0):
    """logits (B, 1, V) -> (B, 1) int32."""
    lf = logits[:, -1].astype(jnp.float32)
    if temperature <= 0:
        return jnp.argmax(lf, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(key, lf / temperature)[:, None].astype(
        jnp.int32)


def generate(model: Model, params, prompt: jnp.ndarray, max_new: int,
             *, temperature: float = 0.0, key=None,
             pctx: ParallelCtx = ParallelCtx(), extra_batch: Optional[Dict] = None):
    """Greedy/temperature generation.  prompt: (B, S0) int32.

    Prefill is done token-by-token through the same decode path (simple
    and universal across cache types); a chunked prefill is a perf
    optimization left to the serve benchmarks.
    """
    B, S0 = prompt.shape
    key = key if key is not None else jax.random.PRNGKey(0)
    caches = model.init_cache(B, S0 + max_new)
    step_fn = jax.jit(make_serve_step(model, pctx))
    toks = prompt
    logits = None
    for i in range(S0):
        batch = {"tokens": toks[:, i:i + 1], "pos": jnp.asarray(i, jnp.int32)}
        if extra_batch:
            batch.update(extra_batch)
        logits, caches = step_fn(params, batch, caches)
    out = [toks]
    cur = sample_token(logits, key, temperature)
    for i in range(max_new):
        out.append(cur)
        batch = {"tokens": cur, "pos": jnp.asarray(S0 + i, jnp.int32)}
        if extra_batch:
            batch.update(extra_batch)
        logits, caches = step_fn(params, batch, caches)
        key, sub = jax.random.split(key)
        cur = sample_token(logits, sub, temperature)
    return jnp.concatenate(out, axis=1)
