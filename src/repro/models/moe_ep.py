"""Expert-parallel MoE via shard_map + all_to_all (the production path).

Mapping of the paper's design to MoE (DESIGN.md §5): expert FFNs are the
"submodel" pattern — many independent small systems batched for device
saturation, with a block-diagonal structure (Fig. 1: each expert's weights
are one block).  The dispatch/combine is the MPIPlusX contract taken to
its limit: local routing decisions + exactly two collectives (all_to_all
out and back) over the 'model' mesh axis.

Two token layouts:
* ``split``      — tokens are partitioned over the EP axis too (sequence
  split inside the MoE block).  Dispatch = all_to_all. Used for
  train/prefill shapes.
* ``replicated`` — tokens replicated over the EP axis (decode: too few
  tokens to split).  Each shard computes only items routed to ITS local
  experts; the combine is one psum.  No all_to_all.

Both paths use capacity buffers with drop (standard GShard/Switch
semantics; cf = cfg.moe_cap_factor) and are validated against the dense
oracle ``moe_dense_apply`` in tests (tokens under capacity -> exact).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .config import ArchConfig
from . import layers

from repro.parallel.sharding import shard_map_compat


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _scatter_to_buffer(values, dest, pos, nbuckets, cap):
    """Scatter values (N, ...) into (nbuckets, cap, ...) at [dest, pos],
    dropping items with pos >= cap.  Collision-free by construction
    (pos is a rank within its bucket)."""
    valid = pos < cap
    d = jnp.where(valid, dest, 0)
    s = jnp.where(valid, pos, 0)
    buf = jnp.zeros((nbuckets, cap) + values.shape[1:], values.dtype)
    vmask = valid.reshape((-1,) + (1,) * (values.ndim - 1))
    return buf.at[d, s].add(values * vmask)


def _rank_in_bucket(dest: jnp.ndarray, nbuckets: int) -> jnp.ndarray:
    """pos[i] = number of j<i with dest[j]==dest[i]  (cumsum of one-hot)."""
    onehot = jax.nn.one_hot(dest, nbuckets, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - 1
    return jnp.take_along_axis(ranks, dest[:, None], axis=1)[:, 0]


def _expert_ffn(xe, w1, w3, w2):
    """xe: (E_loc, C, d); w*: (E_loc, d, f)/(E_loc, f, d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w1)) * \
        jnp.einsum("ecd,edf->ecf", xe, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _moe_local(cfg: ArchConfig, ep: int, cap: int, cap_e: int,
               x_loc, router, w1, w3, w2, *, axis_name: str,
               replicated_tokens: bool):
    """Per-device MoE body (runs inside shard_map).

    x_loc: (T_loc, d) local tokens; w*: (E_loc, ...) local experts.
    """
    T, d = x_loc.shape
    E_loc = w1.shape[0]
    k = cfg.experts_per_tok
    my_shard = lax.axis_index(axis_name)

    logits = jnp.einsum("td,de->te", x_loc.astype(jnp.float32), router)
    wgt, ids = layers.router_topk(logits, k, cfg.router_impl)  # (T,k)

    # flatten routed items
    item_tok = jnp.repeat(jnp.arange(T), k)              # (N,)
    item_eid = ids.reshape(-1)                            # global expert id
    item_w = wgt.reshape(-1)

    if replicated_tokens:
        # keep only items owned by my shard; combine with psum at the end
        mine = (item_eid // E_loc) == my_shard
        eloc = jnp.where(mine, item_eid % E_loc, 0)
        pos = _rank_in_bucket(jnp.where(mine, eloc, E_loc), E_loc + 1)
        pos = jnp.where(mine, pos, cap_e)                 # drop foreign items
        xe = _scatter_to_buffer(x_loc[item_tok], eloc, pos, E_loc, cap_e)
        ye = _expert_ffn(xe, w1, w3, w2)                  # (E_loc, cap_e, d)
        got = ye[jnp.where(pos < cap_e, eloc, 0),
                 jnp.where(pos < cap_e, pos, 0)]          # (N, d)
        got = got * ((pos < cap_e) & mine)[:, None]
        out = jnp.zeros((T, d), jnp.float32).at[item_tok].add(
            got.astype(jnp.float32) * item_w[:, None])
        out = lax.psum(out, axis_name)
        return out.astype(x_loc.dtype)

    # ---- split tokens: all_to_all dispatch ----
    dest = item_eid // E_loc                              # destination shard
    pos = _rank_in_bucket(dest, ep)                       # rank within dest
    x_send = _scatter_to_buffer(x_loc[item_tok], dest, pos, ep, cap)
    eid_send = _scatter_to_buffer(item_eid[:, None] + 1, dest, pos, ep,
                                  cap)[..., 0]            # 0 = invalid
    # fp8 dispatch (DeepSeek-V3-style): quantize the OUT leg of the
    # all_to_all to e4m3 — halves dispatch ICI traffic; the combine leg
    # (expert outputs) stays bf16 for quality.  §Perf 'dsv3-fp8-dispatch'.
    import os as _os
    fp8 = _os.environ.get("REPRO_MOE_FP8", "0") == "1"
    if fp8:
        x_recv = lax.all_to_all(x_send.astype(jnp.float8_e4m3fn),
                                axis_name, 0, 0,
                                tiled=False).astype(x_loc.dtype)
    else:
        x_recv = lax.all_to_all(x_send, axis_name, 0, 0, tiled=False)
    eid_recv = lax.all_to_all(eid_send, axis_name, 0, 0, tiled=False)
    R = ep * cap
    xr = x_recv.reshape(R, d)
    er = eid_recv.reshape(R)
    rvalid = er > 0
    eloc = jnp.where(rvalid, (er - 1) % E_loc, 0)
    pos2 = _rank_in_bucket(jnp.where(rvalid, eloc, E_loc), E_loc + 1)
    pos2 = jnp.where(rvalid, pos2, cap_e)
    xe = _scatter_to_buffer(xr, eloc, pos2, E_loc, cap_e)
    ye = _expert_ffn(xe, w1, w3, w2)                      # (E_loc, cap_e, d)
    yr = ye[jnp.where(pos2 < cap_e, eloc, 0),
            jnp.where(pos2 < cap_e, pos2, 0)]
    yr = yr * ((pos2 < cap_e) & rvalid)[:, None]
    y_back = lax.all_to_all(yr.reshape(ep, cap, d), axis_name, 0, 0,
                            tiled=False)                  # (ep, cap, d)
    # item i finds its result at y_back[dest_i, pos_i] (if not dropped)
    got = y_back[jnp.where(pos < cap, dest, 0),
                 jnp.where(pos < cap, pos, 0)]
    got = got * (pos < cap)[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[item_tok].add(
        got.astype(jnp.float32) * item_w[:, None])
    return out.astype(x_loc.dtype)


def moe_ep_apply(p: Dict, cfg: ArchConfig, x: jnp.ndarray, mesh, *,
                 dp_axes: Tuple[str, ...] = ("data",),
                 ep_axis="model",
                 cst: Callable = layers._id_cst,
                 token_layout: str = "split") -> jnp.ndarray:
    """Expert-parallel MoE layer.  x: (B, S, d) global array under jit.

    ``ep_axis`` may be one mesh axis ('model') or a TUPLE — e.g.
    ('model','data') gives 256-way EP on the 16x16 pod where every chip
    *owns* its experts outright (E_loc = E/256): expert weights never
    move (no FSDP all-gather), only tokens do (two all_to_alls).  This is
    the weights-stationary layout (§Perf iteration 'dsv3-ep256').

    Token layouts:
    * 'split'      — train/prefill: tokens partitioned over dp_axes
                     (batch) and 'model' (sequence).
    * 'replicated' — decode: sequence length 1 cannot split over 'model'.
      Single-axis EP uses the psum-combine path; multi-axis EP reuses the
      all_to_all path with tokens replicated over 'model' (each model
      replica dispatches its copy — duplicated expert compute, negligible
      at decode token counts, and zero weight movement).
    """
    B, S, d = x.shape
    ep_axes = (ep_axis,) if isinstance(ep_axis, str) else tuple(ep_axis)
    ep = 1
    for a in ep_axes:
        ep *= mesh.shape[a]
    E = cfg.n_experts
    assert E % ep == 0, (E, ep)
    E_loc = E // ep
    k = cfg.experts_per_tok
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]

    multi_axis = len(ep_axes) > 1
    if token_layout == "split":
        assert S % mesh.shape["model"] == 0 and B % dp == 0, (B, S, dp)
        T_loc = (B // dp) * (S // mesh.shape["model"])
        x_spec = P(dp_axes, "model", None)
        use_a2a = True
        dup = 1
    else:
        assert B % dp == 0
        T_loc = (B // dp) * S
        x_spec = P(dp_axes, None, None)
        use_a2a = multi_axis          # single-axis: psum-combine path
        dup = mesh.shape["model"] if multi_axis else 1

    n_items = T_loc * k
    cap = _round_up(max(int(n_items / ep * cfg.moe_cap_factor * dup), 8), 8)
    cap_e = _round_up(max(int(n_items / max(E_loc, 1) *
                              cfg.moe_cap_factor), 8), 8) \
        if not use_a2a else \
        _round_up(max(int(ep * cap / max(E_loc, 1) * 1.25), 8), 8)

    coll_axes = ep_axes if use_a2a else ep_axes[0]
    local = functools.partial(
        _moe_local, cfg, ep, cap, cap_e, axis_name=coll_axes,
        replicated_tokens=not use_a2a)

    def body(x_l, router, w1, w3, w2):
        Bl, Sl, _ = x_l.shape
        out = local(x_l.reshape(Bl * Sl, d), router, w1, w3, w2)
        return out.reshape(Bl, Sl, d)

    w_spec = P(ep_axes if multi_axis else ep_axes[0], None, None)
    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=x_spec,
    )
    out = fn(x, p["router"], p["w1"], p["w3"], p["w2"])
    if "shared" in p:
        out = out + layers.swiglu_apply(p["shared"], x, cst=cst)
    return cst(out, ("batch", "seq", "embed"))
