from . import config, layers, moe_ep, spec, ssm, transformer
from .config import ArchConfig, ShapeConfig, SHAPES
from .transformer import Model, ParallelCtx

__all__ = ["config", "layers", "moe_ep", "spec", "ssm", "transformer",
           "ArchConfig", "ShapeConfig", "SHAPES", "Model", "ParallelCtx"]
