"""Architecture configuration — every assigned arch is an ArchConfig."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0             # routed-expert hidden dim
    moe_cap_factor: float = 1.25
    router_impl: str = "softmax"  # 'softmax' | 'sigmoid' (dsv3)

    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    sliding_window: int = 0       # 0 = full causal
    causal: bool = True

    # --- ssm / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0           # zamba2: shared attn block cadence
    xlstm_slstm_every: int = 2    # xlstm: every k-th block is sLSTM

    # --- multimodal stubs ---
    mrope: bool = False           # qwen2-vl
    vis_prefix_frac: float = 0.25 # fraction of seq that is patch embeds
    enc_dec: bool = False         # whisper
    enc_layers: int = 0
    enc_len_frac: float = 0.25    # encoder frames as fraction of seq_len

    # --- extras ---
    mtp: bool = False             # deepseek multi-token prediction head
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM/hybrid) -> long_500k runs."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
