"""Parameter-spec system: one source of truth for shape/dtype/sharding/init.

A model definition builds a pytree of :class:`ParamSpec`.  From it we derive
  * materialized parameters   (init_params)
  * abstract parameters       (ShapeDtypeStructs, for the dry-run)
  * the logical-axes tree     (for sharding rules)
without any risk of the three drifting apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis name per dim
    dtype: Any = jnp.float32
    init: str = "normal"                 # 'normal' | 'zeros' | 'ones' | 'scaled'
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _materialize(spec: ParamSpec, key) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "scaled":  # fan-in scaled normal
        fan_in = spec.shape[0] if spec.shape else 1
        std = (1.0 / max(fan_in, 1)) ** 0.5
        return (std * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    return (spec.scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)


def init_params(specs, key) -> Any:
    """Materialize a spec tree into a parameter pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_materialize(s, k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(specs) -> Any:
    """ShapeDtypeStruct tree (no allocation) for .lower()."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=is_spec)


def axes_tree(specs) -> Any:
    return jax.tree_util.tree_map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_count(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    total = 0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total
