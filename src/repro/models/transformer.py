"""Model assembly for all assigned architectures.

One generic decoder-only LM (GQA/MLA attention, dense/MoE FFN) covers 7
of the 10 archs; zamba2 (hybrid Mamba2 + shared attn), xlstm
(mLSTM/sLSTM), and whisper (enc-dec) get dedicated assemblies.  All use
``lax.scan`` over stacked per-layer parameters so the traced/compiled
HLO contains each layer body once (essential for the 512-device dry-run
on this 1-core container, and for real compile times at scale).

``Model`` is a thin namespace of pure functions:
  specs()                       -> ParamSpec tree (stacked layers)
  init(key)                     -> params
  loss(params, batch, pctx)     -> scalar loss   (train path)
  decode_step(params, batch, caches, pctx) -> (logits, caches)
  init_cache_specs(batch, max_len)         -> cache ShapeDtypeStruct tree
  input_specs(shape)            -> batch ShapeDtypeStruct dict
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import layers, moe_ep, ssm
from .config import ArchConfig, ShapeConfig
from .spec import ParamSpec, abstract_params, axes_tree, init_params

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Distribution context threaded through apply functions."""
    mesh: Any = None
    cst: Callable = layers._id_cst        # activation sharding constraint
    moe_impl: str = "dense"               # 'dense' | 'ep'
    dp_axes: Tuple[str, ...] = ("data",)
    ep_axis: str = "model"
    moe_token_layout: str = "split"       # 'split' | 'replicated'


def _stack_specs(tree, n: int):
    """Add a stacked leading 'layers' dim to every spec in the tree."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype,
                            s.init, s.scale),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


# ----------------------------------------------------------------------------
# Generic decoder layer (attention/MLA + dense-MLP/MoE)
# ----------------------------------------------------------------------------


def _decoder_layer_spec(cfg: ArchConfig) -> Params:
    p = {"ln1": layers.rmsnorm_spec(cfg.d_model),
         "ln2": layers.rmsnorm_spec(cfg.d_model)}
    if cfg.use_mla:
        p["attn"] = layers.mla_spec(cfg)
    else:
        p["attn"] = layers.attention_spec(cfg)
    if cfg.is_moe:
        p["ffn"] = layers.moe_spec(cfg)
    else:
        p["ffn"] = layers.swiglu_spec(cfg)
    return p


def _decoder_layer_apply(p: Params, cfg: ArchConfig, x, rope_cs, positions,
                         pctx: ParallelCtx, cache=None):
    cst = pctx.cst
    h = layers.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a, new_cache = layers.mla_apply(p["attn"], cfg, h, positions,
                                        cst=cst, cache=cache)
    else:
        cos, sin = rope_cs
        a, new_cache = layers.attention_apply(p["attn"], cfg, h, cos, sin,
                                              cst=cst, causal=cfg.causal,
                                              cache=cache)
    x = x + a
    h = layers.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        if pctx.moe_impl == "ep" and pctx.mesh is not None:
            f = moe_ep.moe_ep_apply(p["ffn"], cfg, h, pctx.mesh,
                                    dp_axes=pctx.dp_axes,
                                    ep_axis=pctx.ep_axis, cst=cst,
                                    token_layout=pctx.moe_token_layout)
        else:
            f = layers.moe_dense_apply(p["ffn"], cfg, h, cst=cst)
    else:
        f = layers.swiglu_apply(p["ffn"], h, cst=cst)
    return x + f, new_cache


# ----------------------------------------------------------------------------
# Generic decoder-only LM (dense / MoE / VLM)
# ----------------------------------------------------------------------------


def lm_specs(cfg: ArchConfig) -> Params:
    p = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", "embed"), cfg.dtype, "normal"),
        "layers": _stack_specs(_decoder_layer_spec(cfg), cfg.n_layers),
        "ln_f": layers.rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                 ("embed", "vocab"), cfg.dtype, "scaled")
    if cfg.mtp:
        p["mtp_proj"] = ParamSpec((2 * cfg.d_model, cfg.d_model),
                                  ("mlp", "embed"), cfg.dtype, "scaled")
        p["mtp_layer"] = _decoder_layer_spec(
            cfg.replace(n_experts=0, d_ff=cfg.moe_d_ff or cfg.d_ff))
        p["mtp_norm"] = layers.rmsnorm_spec(cfg.d_model)
    return p


def _positions_for(cfg: ArchConfig, B: int, S: int, vis_len: int,
                   offset=0):
    """Position ids; for mrope returns (B,S,3) else (S,)."""
    if not cfg.mrope:
        return jnp.arange(S) + offset
    # M-RoPE: vision prefix on a (t=0, h, w) grid, text sequential
    grid_w = max(int(math.sqrt(max(vis_len, 1))), 1)
    i = jnp.arange(S)
    is_vis = i < vis_len
    t = jnp.where(is_vis, 0, i - vis_len + (vis_len + grid_w - 1) // grid_w)
    hpos = jnp.where(is_vis, i // grid_w, t)
    wpos = jnp.where(is_vis, i % grid_w, t)
    pos3 = jnp.stack([t, hpos, wpos], axis=-1) + offset   # (S, 3)
    return jnp.broadcast_to(pos3[None], (B, S, 3))


def _rope_for(cfg: ArchConfig, positions):
    if cfg.use_mla:
        return None
    if cfg.mrope:
        return layers.mrope_cos_sin(cfg.hd, cfg.rope_theta, positions)
    return layers.rope_freqs(cfg.hd, cfg.rope_theta, positions)


def _scan_layers(cfg, stacked, x, rope_cs, positions, pctx, caches=None):
    """Run all decoder layers via scan; caches (stacked, optional)."""

    def body(carry, xs):
        xc = carry
        if caches is None:
            lp = xs
            y, _ = _decoder_layer_apply(lp, cfg, xc, rope_cs, positions,
                                        pctx, cache=None)
            return y, None
        lp, lcache = xs
        y, ncache = _decoder_layer_apply(lp, cfg, xc, rope_cs, positions,
                                         pctx, cache=lcache)
        return y, ncache

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = stacked if caches is None else (stacked, caches)
    x, new_caches = lax.scan(body, x, xs)
    return x, new_caches


def _embed_inputs(cfg: ArchConfig, params, batch, pctx):
    """Token (+ vision/audio stub) embedding -> (B, S, d), vis_len."""
    cst = pctx.cst
    tokens = batch["tokens"]
    x = params["embed"][tokens]  # gather
    vis_len = 0
    if cfg.mrope and "vis_embeds" in batch:
        ve = batch["vis_embeds"].astype(x.dtype)        # (B, Sv, d)
        vis_len = ve.shape[1]
        x = jnp.concatenate([ve, x], axis=1)
    return cst(x, ("batch", "seq", "embed")), vis_len


def _lm_head(cfg, params, x, pctx):
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return pctx.cst(logits, ("batch", "seq", "vocab"))


def _xent(logits, targets, mask=None):
    """Mean cross-entropy in f32; targets < 0 are ignored."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    tgt = jnp.maximum(targets, 0)
    picked = jnp.take_along_axis(lf, tgt[..., None], axis=-1)[..., 0]
    nll = lse - picked
    valid = (targets >= 0).astype(jnp.float32)
    if mask is not None:
        valid = valid * mask
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def lm_loss(cfg: ArchConfig, params: Params, batch: Dict, pctx: ParallelCtx):
    x, vis_len = _embed_inputs(cfg, params, batch, pctx)
    B, S, _ = x.shape
    positions = _positions_for(cfg, B, S, vis_len)
    rope_cs = _rope_for(cfg, positions)
    x, _ = _scan_layers(cfg, params["layers"], x, rope_cs, positions, pctx)
    x = layers.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = _lm_head(cfg, params, x, pctx)
    targets = batch["targets"]
    if vis_len:
        # loss only over the text region
        logits = logits[:, vis_len:]
    loss = _xent(logits, targets)
    if cfg.mtp:
        # light-weight multi-token prediction: combine h with next-token
        # embedding, one extra layer, predict t+2 (DeepSeek-V3 MTP, D=1).
        emb_next = params["embed"][jnp.maximum(batch["targets"], 0)]
        h = x[:, vis_len:] if vis_len else x
        hcat = jnp.concatenate([h, emb_next.astype(h.dtype)], axis=-1)
        hm = jnp.einsum("bse,ed->bsd", hcat, params["mtp_proj"])
        pos2 = _positions_for(cfg, B, hm.shape[1], 0)
        hm, _ = _decoder_layer_apply(params["mtp_layer"], cfg.replace(
            n_experts=0, d_ff=cfg.moe_d_ff or cfg.d_ff), hm,
            _rope_for(cfg, pos2), pos2, pctx)
        hm = layers.rmsnorm_apply(params["mtp_norm"], hm, cfg.norm_eps)
        logits2 = _lm_head(cfg, params, hm, pctx)
        tgt2 = jnp.concatenate(
            [batch["targets"][:, 1:],
             -jnp.ones_like(batch["targets"][:, :1])], axis=1)
        loss = loss + 0.3 * _xent(logits2, tgt2)
    return loss


def lm_decode_step(cfg: ArchConfig, params: Params, batch: Dict, caches,
                   pctx: ParallelCtx):
    """One-token decode: batch = {'tokens': (B,1), 'pos': ()} ."""
    tokens, pos = batch["tokens"], batch["pos"]
    B = tokens.shape[0]
    x = params["embed"][tokens]
    positions = (_positions_for(cfg, B, 1, 0, offset=pos) if cfg.mrope
                 else jnp.arange(1) + pos)
    rope_cs = _rope_for(cfg, positions)
    pctx2 = dataclasses.replace(pctx, moe_token_layout="replicated")
    x, new_caches = _scan_layers(cfg, params["layers"], x, rope_cs,
                                 positions, pctx2, caches=caches)
    x = layers.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = _lm_head(cfg, params, x, pctx)
    return logits, new_caches


def lm_cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    L = cfg.n_layers
    if cfg.use_mla:
        per = {"c_kv": jax.ShapeDtypeStruct(
                   (batch, max_len, cfg.kv_lora_rank), cfg.dtype),
               "k_rope": jax.ShapeDtypeStruct(
                   (batch, max_len, cfg.qk_rope_dim), cfg.dtype),
               "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    else:
        per = {"k": jax.ShapeDtypeStruct(
                   (batch, max_len, cfg.n_kv_heads, cfg.hd), cfg.dtype),
               "v": jax.ShapeDtypeStruct(
                   (batch, max_len, cfg.n_kv_heads, cfg.hd), cfg.dtype),
               "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((L,) + s.shape, s.dtype), per)


# ----------------------------------------------------------------------------
# xLSTM assembly (alternating mLSTM / sLSTM blocks)
# ----------------------------------------------------------------------------


def xlstm_specs(cfg: ArchConfig) -> Params:
    n_pairs = cfg.n_layers // 2
    pair = {
        "m_ln": layers.rmsnorm_spec(cfg.d_model),
        "m": ssm.mlstm_spec(cfg),
        "s_ln": layers.rmsnorm_spec(cfg.d_model),
        "s": ssm.slstm_spec(cfg),
    }
    return {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", "embed"), cfg.dtype, "normal"),
        "pairs": _stack_specs(pair, n_pairs),
        "ln_f": layers.rmsnorm_spec(cfg.d_model),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size),
                             ("embed", "vocab"), cfg.dtype, "scaled"),
    }


def _xlstm_pair_apply(lp, cfg, x, pctx, cache=None):
    cm = cache["m"] if cache is not None else None
    cs_ = cache["s"] if cache is not None else None
    h = layers.rmsnorm_apply(lp["m_ln"], x, cfg.norm_eps)
    a, ncm = ssm.mlstm_apply(lp["m"], cfg, h, cst=pctx.cst, cache=cm)
    x = x + a
    h = layers.rmsnorm_apply(lp["s_ln"], x, cfg.norm_eps)
    a, ncs = ssm.slstm_apply(lp["s"], cfg, h, cst=pctx.cst, cache=cs_)
    x = x + a
    ncache = {"m": ncm, "s": ncs} if cache is not None else None
    return x, ncache


def xlstm_loss(cfg, params, batch, pctx):
    x = params["embed"][batch["tokens"]]
    x = pctx.cst(x, ("batch", "seq", "embed"))

    def body(xc, lp):
        y, _ = _xlstm_pair_apply(lp, cfg, xc, pctx)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["pairs"])
    x = layers.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return _xent(pctx.cst(logits, ("batch", "seq", "vocab")),
                 batch["targets"])


def xlstm_decode_step(cfg, params, batch, caches, pctx):
    x = params["embed"][batch["tokens"]]

    def body(xc, xs):
        lp, lcache = xs
        y, nc = _xlstm_pair_apply(lp, cfg, xc, pctx, cache=lcache)
        return y, nc

    x, new_caches = lax.scan(body, x, (params["pairs"], caches))
    x = layers.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, new_caches


def xlstm_cache_specs(cfg, batch, max_len):
    n_pairs = cfg.n_layers // 2
    per = {"m": ssm.mlstm_cache_spec(cfg, batch),
           "s": ssm.slstm_cache_spec(cfg, batch)}
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((n_pairs,) + s.shape, s.dtype), per)


# ----------------------------------------------------------------------------
# Zamba2 assembly (Mamba2 stack + ONE shared attention block every k layers)
# ----------------------------------------------------------------------------


def zamba_n_sites(cfg: ArchConfig) -> int:
    return (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every


def zamba_specs(cfg: ArchConfig) -> Params:
    mamba_layer = {"ln": layers.rmsnorm_spec(cfg.d_model),
                   "mamba": ssm.mamba2_spec(cfg)}
    # the shared attention block consumes concat(hidden, embedding) — the
    # zamba "shared block with concatenated input" design
    attn_cfg = cfg
    shared = {
        "ln": layers.rmsnorm_spec(2 * cfg.d_model),
        "attn": layers.attention_spec(attn_cfg, d_in=2 * cfg.d_model,
                                      d_out=cfg.d_model),
        "out": ParamSpec((cfg.d_model, cfg.d_model),
                         ("embed", "embed_out"), cfg.dtype, "scaled"),
    }
    return {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", "embed"), cfg.dtype, "normal"),
        "mamba_layers": _stack_specs(mamba_layer, cfg.n_layers),
        "shared_attn": shared,
        "ln_f": layers.rmsnorm_spec(cfg.d_model),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size),
                             ("embed", "vocab"), cfg.dtype, "scaled"),
    }


def _zamba_shared_attn(sp, cfg, x, x0, rope_cs, pctx, cache=None):
    """Shared block: attn over concat(x, x0), projected back to d."""
    h = jnp.concatenate([x, x0], axis=-1)
    h = layers.rmsnorm_apply(sp["ln"], h, cfg.norm_eps)
    cos, sin = rope_cs
    a, ncache = layers.attention_apply(sp["attn"], cfg, h, cos, sin,
                                       cst=pctx.cst, causal=True,
                                       cache=cache)
    return x + jnp.einsum("bsd,de->bse", a, sp["out"]), ncache


def zamba_loss(cfg, params, batch, pctx):
    x = params["embed"][batch["tokens"]]
    x = pctx.cst(x, ("batch", "seq", "embed"))
    x0 = x
    S = x.shape[1]
    positions = jnp.arange(S)
    rope_cs = layers.rope_freqs(cfg.hd, cfg.rope_theta, positions)
    sp = params["shared_attn"]

    def body(carry, xs):
        xc, i = carry
        lp = xs

        def with_attn(xx):
            y, _ = _zamba_shared_attn(sp, cfg, xx, x0, rope_cs, pctx)
            return y

        xc = lax.cond(i % cfg.attn_every == 0, with_attn, lambda z: z, xc)
        h = layers.rmsnorm_apply(lp["ln"], xc, cfg.norm_eps)
        a, _ = ssm.mamba2_apply(lp["mamba"], cfg, h, cst=pctx.cst)
        return (xc + a, i + 1), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, _), _ = lax.scan(body_fn, (x, jnp.zeros((), jnp.int32)),
                         params["mamba_layers"])
    x = layers.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return _xent(pctx.cst(logits, ("batch", "seq", "vocab")),
                 batch["targets"])


def zamba_decode_step(cfg, params, batch, caches, pctx):
    """caches = {'mamba': stacked(L), 'attn': stacked(n_sites)}."""
    x = params["embed"][batch["tokens"]]
    x0 = x
    pos = batch["pos"]
    positions = jnp.arange(1) + pos
    rope_cs = layers.rope_freqs(cfg.hd, cfg.rope_theta, positions)
    sp = params["shared_attn"]
    attn_caches = caches["attn"]

    def body(carry, xs):
        xc, i, acaches = carry
        lp, mcache = xs
        site = i // cfg.attn_every

        def with_attn(args):
            xx, ac = args
            one = jax.tree_util.tree_map(lambda c: c[site], ac)
            y, nc = _zamba_shared_attn(sp, cfg, xx, x0, rope_cs, pctx,
                                       cache=one)
            ac = jax.tree_util.tree_map(
                lambda full, new: lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), site, 0), ac, nc)
            return y, ac

        def no_attn(args):
            xx, ac = args
            return xx, ac

        xc, acaches = lax.cond(i % cfg.attn_every == 0, with_attn, no_attn,
                               (xc, acaches))
        h = layers.rmsnorm_apply(lp["ln"], xc, cfg.norm_eps)
        a, nmcache = ssm.mamba2_apply(lp["mamba"], cfg, h, cst=pctx.cst,
                                      cache=mcache)
        return (xc + a, i + 1, acaches), nmcache

    (x, _, attn_caches), mamba_caches = lax.scan(
        body, (x, jnp.zeros((), jnp.int32), attn_caches),
        (params["mamba_layers"], caches["mamba"]))
    x = layers.rmsnorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, {"mamba": mamba_caches, "attn": attn_caches}


def zamba_cache_specs(cfg, batch, max_len):
    L = cfg.n_layers
    ns = zamba_n_sites(cfg)
    mamba = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((L,) + s.shape, s.dtype),
        ssm.mamba2_cache_spec(cfg, batch))
    attn_per = {"k": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads,
                                           cfg.hd), cfg.dtype),
                "v": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads,
                                           cfg.hd), cfg.dtype),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    attn = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((ns,) + s.shape, s.dtype), attn_per)
    return {"mamba": mamba, "attn": attn}


# ----------------------------------------------------------------------------
# Whisper (enc-dec) assembly — conv frontend is a stub: the batch provides
# precomputed frame embeddings (B, enc_len, d).
# ----------------------------------------------------------------------------


def whisper_specs(cfg: ArchConfig, max_len: int = 65536) -> Params:
    enc_layer = {
        "ln1": layers.layernorm_spec(cfg.d_model),
        "attn": layers.attention_spec(cfg),
        "ln2": layers.layernorm_spec(cfg.d_model),
        "mlp": layers.gelu_mlp_spec(cfg),
    }
    dec_layer = {
        "ln1": layers.layernorm_spec(cfg.d_model),
        "attn": layers.attention_spec(cfg),
        "ln_x": layers.layernorm_spec(cfg.d_model),
        "xattn": layers.attention_spec(cfg),
        "ln2": layers.layernorm_spec(cfg.d_model),
        "mlp": layers.gelu_mlp_spec(cfg),
    }
    return {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", "embed"), cfg.dtype, "normal"),
        "enc_pos": ParamSpec((max_len, cfg.d_model), (None, "embed"),
                             cfg.dtype, "normal"),
        "dec_pos": ParamSpec((max_len, cfg.d_model), (None, "embed"),
                             cfg.dtype, "normal"),
        "enc_layers": _stack_specs(enc_layer, cfg.enc_layers),
        "dec_layers": _stack_specs(dec_layer, cfg.n_layers),
        "ln_enc": layers.layernorm_spec(cfg.d_model),
        "ln_f": layers.layernorm_spec(cfg.d_model),
        # whisper ties the output head to the token embedding
    }


def _whisper_encode(cfg, params, frames, pctx):
    S = frames.shape[1]
    x = frames + params["enc_pos"][:S][None]
    x = pctx.cst(x, ("batch", "seq", "embed"))

    def body(xc, lp):
        h = layers.layernorm_apply(lp["ln1"], xc, cfg.norm_eps)
        a, _ = layers.attention_apply(lp["attn"], cfg, h, None, None,
                                      cst=pctx.cst, causal=False,
                                      use_rope=False)
        xc = xc + a
        h = layers.layernorm_apply(lp["ln2"], xc, cfg.norm_eps)
        return xc + layers.gelu_mlp_apply(lp["mlp"], h, cst=pctx.cst), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["enc_layers"])
    return layers.layernorm_apply(params["ln_enc"], x, cfg.norm_eps)


def _whisper_dec_layer(lp, cfg, x, enc_out, pctx, cache=None):
    h = layers.layernorm_apply(lp["ln1"], x, cfg.norm_eps)
    a, ncache = layers.attention_apply(lp["attn"], cfg, h, None, None,
                                       cst=pctx.cst, causal=True,
                                       cache=cache, use_rope=False)
    x = x + a
    h = layers.layernorm_apply(lp["ln_x"], x, cfg.norm_eps)
    x = x + layers.cross_attention_apply(lp["xattn"], cfg, h, enc_out,
                                         cst=pctx.cst)
    h = layers.layernorm_apply(lp["ln2"], x, cfg.norm_eps)
    return x + layers.gelu_mlp_apply(lp["mlp"], h, cst=pctx.cst), ncache


def whisper_loss(cfg, params, batch, pctx):
    enc_out = _whisper_encode(cfg, params, batch["frames"], pctx)
    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = params["embed"][tokens] + params["dec_pos"][:S][None]
    x = pctx.cst(x, ("batch", "seq", "embed"))

    def body(xc, lp):
        y, _ = _whisper_dec_layer(lp, cfg, xc, enc_out, pctx)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["dec_layers"])
    x = layers.layernorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return _xent(pctx.cst(logits, ("batch", "seq", "vocab")),
                 batch["targets"])


def whisper_decode_step(cfg, params, batch, caches, pctx):
    """caches = {'self': stacked dec self-attn caches, 'enc_out': computed
    once at prefill and carried outside}."""
    tokens, pos = batch["tokens"], batch["pos"]
    enc_out = batch["enc_out"]
    x = params["embed"][tokens] + params["dec_pos"][pos][None, None]

    def body(xc, xs):
        lp, lcache = xs
        y, nc = _whisper_dec_layer(lp, cfg, xc, enc_out, pctx, cache=lcache)
        return y, nc

    x, new_caches = lax.scan(body, x, (params["dec_layers"], caches))
    x = layers.layernorm_apply(params["ln_f"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, new_caches


def whisper_cache_specs(cfg, batch, max_len):
    per = {"k": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads,
                                      cfg.hd), cfg.dtype),
           "v": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads,
                                      cfg.hd), cfg.dtype),
           "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
        per)


# ----------------------------------------------------------------------------
# Model facade
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # --- specs/init ---
    def specs(self):
        if self.cfg.family == "ssm":
            return xlstm_specs(self.cfg)
        if self.cfg.family == "hybrid":
            return zamba_specs(self.cfg)
        if self.cfg.enc_dec:
            return whisper_specs(self.cfg)
        return lm_specs(self.cfg)

    def init(self, key):
        return init_params(self.specs(), key)

    def abstract_params(self):
        return abstract_params(self.specs())

    def param_axes(self):
        return axes_tree(self.specs())

    # --- forward paths ---
    def loss(self, params, batch, pctx: ParallelCtx = ParallelCtx()):
        if self.cfg.family == "ssm":
            return xlstm_loss(self.cfg, params, batch, pctx)
        if self.cfg.family == "hybrid":
            return zamba_loss(self.cfg, params, batch, pctx)
        if self.cfg.enc_dec:
            return whisper_loss(self.cfg, params, batch, pctx)
        return lm_loss(self.cfg, params, batch, pctx)

    def decode_step(self, params, batch, caches,
                    pctx: ParallelCtx = ParallelCtx()):
        if self.cfg.family == "ssm":
            return xlstm_decode_step(self.cfg, params, batch, caches, pctx)
        if self.cfg.family == "hybrid":
            return zamba_decode_step(self.cfg, params, batch, caches, pctx)
        if self.cfg.enc_dec:
            return whisper_decode_step(self.cfg, params, batch, caches, pctx)
        return lm_decode_step(self.cfg, params, batch, caches, pctx)

    def cache_specs(self, batch: int, max_len: int):
        if self.cfg.family == "ssm":
            return xlstm_cache_specs(self.cfg, batch, max_len)
        if self.cfg.family == "hybrid":
            return zamba_cache_specs(self.cfg, batch, max_len)
        if self.cfg.enc_dec:
            return whisper_cache_specs(self.cfg, batch, max_len)
        return lm_cache_specs(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_specs(batch, max_len))

    # --- dry-run inputs ---
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                     "targets": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.mrope:
                vis = int(S * cfg.vis_prefix_frac)
                batch["tokens"] = jax.ShapeDtypeStruct((B, S - vis), i32)
                batch["targets"] = jax.ShapeDtypeStruct((B, S - vis), i32)
                batch["vis_embeds"] = jax.ShapeDtypeStruct(
                    (B, vis, cfg.d_model), cfg.dtype)
            if cfg.enc_dec:
                enc_len = int(S * cfg.enc_len_frac)
                batch["frames"] = jax.ShapeDtypeStruct(
                    (B, enc_len, cfg.d_model), cfg.dtype)
            return batch
        # decode: one token with a KV cache of S
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                 "pos": jax.ShapeDtypeStruct((), i32)}
        if cfg.enc_dec:
            enc_len = int(S * cfg.enc_len_frac)
            batch["enc_out"] = jax.ShapeDtypeStruct(
                (B, enc_len, cfg.d_model), cfg.dtype)
        return batch
